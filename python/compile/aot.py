"""AOT exporter: lower the L2 graphs once to HLO *text* artifacts.

Interchange format is HLO text, NOT `.serialize()` — jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Emits into --outdir:
    forward.hlo.txt      masked inference forward pass (Pallas kernels)
    train_step.hlo.txt   SGD + reweighted group-Lasso step (kernel fwd,
                         analytic custom-VJP bwd)
    group_norms.hlo.txt  elementwise w^2 per prunable tensor
    block_matmul.hlo.txt standalone block-sparse matmul (runtime microbench)
    manifest.json        input/output names, shapes, dtypes per artifact

Run via `make artifacts` (no-op if inputs are unchanged, courtesy of make).
Python never runs again after this: the Rust binary consumes the artifacts.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import List

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

BENCH_M, BENCH_K, BENCH_N = 256, 512, 512


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype="f32"):
    return {"shape": list(shape), "dtype": dtype}


def _abstract(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def build_manifest() -> dict:
    params = [
        {"name": n, "kind": k, **_spec(s)} for n, k, s in model.PARAM_SPECS
    ]
    weights = [
        {"name": n, **_spec(dict((pn, s) for pn, _, s in model.PARAM_SPECS)[n])}
        for n in model.WEIGHT_NAMES
    ]
    return {
        "batch": model.BATCH,
        "img": model.IMG,
        "in_ch": model.IN_CH,
        "num_classes": model.NUM_CLASSES,
        "params": params,
        "weight_idx": model.WEIGHT_IDX,
        "weight_names": model.WEIGHT_NAMES,
        "artifacts": {
            "forward": {
                "file": "forward.hlo.txt",
                "inputs": (
                    [p["name"] for p in params]
                    + [f"mask:{n}" for n in model.WEIGHT_NAMES]
                    + ["x"]
                ),
                "outputs": ["logits"],
            },
            "train_step": {
                "file": "train_step.hlo.txt",
                "inputs": (
                    [p["name"] for p in params]
                    + [f"mask:{n}" for n in model.WEIGHT_NAMES]
                    + [f"alpha:{n}" for n in model.WEIGHT_NAMES]
                    + ["x", "y", "lr", "lam"]
                ),
                "outputs": [f"new:{p['name']}" for p in params] + ["ce", "acc"],
            },
            "group_norms": {
                "file": "group_norms.hlo.txt",
                # jax.jit(keep_unused=False) drops unused args from the HLO
                # signature, so this artifact takes only the prunable
                # weight tensors (not biases).
                "inputs": list(model.WEIGHT_NAMES),
                "outputs": [f"sq:{n}" for n in model.WEIGHT_NAMES],
            },
            "block_matmul": {
                "file": "block_matmul.hlo.txt",
                "inputs": ["x", "w", "mask"],
                "outputs": ["y"],
                "m": BENCH_M,
                "k": BENCH_K,
                "n": BENCH_N,
            },
        },
        "weights": weights,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)

    b = model.BATCH
    param_abs = [_abstract(s) for _, _, s in model.PARAM_SPECS]
    mask_abs = [
        _abstract(dict((n, s) for n, _, s in model.PARAM_SPECS)[w])
        for w in model.WEIGHT_NAMES
    ]
    alpha_abs = list(mask_abs)
    x_abs = _abstract((b, model.IN_CH, model.IMG, model.IMG))
    y_abs = _abstract((b,), jnp.int32)
    scalar = _abstract((), jnp.float32)

    def fwd_flat(*args):
        params = list(args[:10])
        masks = list(args[10:15])
        x = args[15]
        return (model.forward(params, masks, x, use_kernels=True),)

    def step_flat(*args):
        params = list(args[:10])
        masks = list(args[10:15])
        alphas = list(args[15:20])
        x, y, lr, lam = args[20], args[21], args[22], args[23]
        new_params, ce, acc = model.train_step(
            params, masks, alphas, x, y, lr, lam, use_kernels=True
        )
        return tuple(new_params) + (ce, acc)

    def norms_flat(*weights):
        return tuple(w * w for w in weights)

    def bmm(x, w, m):
        from .kernels import block_sparse_matmul

        # Perf-tuned tiles (EXPERIMENTS.md §Perf item 5): 128^3 tiles cut
        # the grid from 256 to 32 steps; VMEM footprint 3*128*128*4B ≈
        # 196KB (well under a real TPU's 16MB), lanes stay 8x128-aligned.
        return (block_sparse_matmul(x, w, m, bm=128, bn=128, bk=128),)

    jobs = [
        ("forward.hlo.txt", fwd_flat, param_abs + mask_abs + [x_abs]),
        (
            "train_step.hlo.txt",
            step_flat,
            param_abs + mask_abs + alpha_abs + [x_abs, y_abs, scalar, scalar],
        ),
        ("group_norms.hlo.txt", norms_flat, mask_abs),
        (
            "block_matmul.hlo.txt",
            bmm,
            [
                _abstract((BENCH_M, BENCH_K)),
                _abstract((BENCH_K, BENCH_N)),
                _abstract((BENCH_K, BENCH_N)),
            ],
        ),
    ]
    for fname, fn, abstracts in jobs:
        lowered = jax.jit(fn).lower(*abstracts)
        text = to_hlo_text(lowered)
        path = os.path.join(args.outdir, fname)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {fname}: {len(text)} chars")

    with open(os.path.join(args.outdir, "manifest.json"), "w") as f:
        json.dump(build_manifest(), f, indent=2)
    print("wrote manifest.json")


if __name__ == "__main__":
    main()
