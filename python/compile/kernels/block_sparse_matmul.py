"""Layer-1 Pallas kernel: block-sparse (masked) matmul.

This is the compute hot-spot of the paper's block-based / block-punched
pruning scheme (Gong & Yuan et al., TODAES'21).  The paper tiles the sparse
weight matrix into threadblock-sized tiles on a mobile GPU; here the same
insight is re-thought for the TPU shape of the problem (see DESIGN.md
section "Hardware-Adaptation"):

  * the pruning *block* becomes a VMEM tile expressed through ``BlockSpec``;
  * the punched/row/column mask is applied to the VMEM-resident weight tile
    so the MXU always multiplies dense tiles (no branch divergence — the
    mobile-GPU analogue of the paper's pattern-branch overhead simply does
    not exist in this formulation);
  * the (HBM -> VMEM) schedule that the paper expressed with threadblocks is
    the grid + index_map below.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered to plain HLO ops.  Correctness is
pinned against the pure-jnp oracle in ``ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "block_sparse_matmul",
    "masked_matmul_unblocked",
    "block_sparse_matmul_ad",
]


def _pad_to(x: jax.Array, m: int, axis: int) -> jax.Array:
    """Zero-pad ``x`` along ``axis`` up to the next multiple of ``m``."""
    size = x.shape[axis]
    rem = (-size) % m
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


def _bsmm_kernel(x_ref, w_ref, m_ref, o_ref):
    """One (bm, bn) output tile; K-loop is the innermost grid axis.

    The mask tile is multiplied into the weight tile *in VMEM*, keeping the
    MXU contraction dense.  Accumulation is in f32 regardless of the input
    dtype (the usual TPU matmul idiom).
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x_tile = x_ref[...].astype(jnp.float32)
    w_tile = (w_ref[...] * m_ref[...]).astype(jnp.float32)
    o_ref[...] += jnp.dot(x_tile, w_tile, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def block_sparse_matmul(
    x: jax.Array,
    w: jax.Array,
    mask: jax.Array,
    *,
    bm: int = 32,
    bn: int = 32,
    bk: int = 32,
) -> jax.Array:
    """``x @ (w * mask)`` with a blocked Pallas schedule.

    Args:
      x:    (M, K) activations.
      w:    (K, N) weight matrix.
      mask: (K, N) {0,1} pruning mask — block-based (row/col-in-block) or
            block-punched masks both take this form once the 4-D CONV tensor
            is viewed as its 2-D GEMM matrix (paper Fig. 1).
      bm/bn/bk: VMEM tile sizes.  ``bn`` should be a multiple of the lane
            width (128 on real TPU); ``bm``/``bk`` multiples of 8.  In
            interpret mode any positive size runs, which lets the hypothesis
            tests sweep odd shapes.

    Returns:
      (M, N) result in f32.
    """
    if x.ndim != 2 or w.ndim != 2 or mask.ndim != 2:
        raise ValueError("block_sparse_matmul expects 2-D operands")
    if x.shape[1] != w.shape[0] or w.shape != mask.shape:
        raise ValueError(
            f"shape mismatch: x={x.shape} w={w.shape} mask={mask.shape}"
        )
    m_dim, k_dim = x.shape
    _, n_dim = w.shape

    xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
    wp = _pad_to(_pad_to(w, bk, 0), bn, 1)
    mp = _pad_to(_pad_to(mask, bk, 0), bn, 1)
    mp_, kp = xp.shape
    _, np_ = wp.shape

    grid = (mp_ // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        _bsmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp_, np_), jnp.float32),
        interpret=True,
    )(xp, wp, mp)
    return out[:m_dim, :n_dim]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def block_sparse_matmul_ad(
    x: jax.Array, w: jax.Array, mask: jax.Array, bm: int = 32, bn: int = 32, bk: int = 32
) -> jax.Array:
    """Differentiable wrapper: Pallas forward + analytic pure-jnp backward.

    ``pallas_call`` carries no automatic VJP rule, so the L2 train-step
    attaches the closed-form masked-matmul gradients here; the forward pass
    (the hot path) still lowers through the Pallas kernel, and pytest pins
    the backward against ``jax.grad`` of the ref oracle.
    """
    return block_sparse_matmul(x, w, mask, bm=bm, bn=bn, bk=bk)


def _bsmm_fwd(x, w, mask, bm, bn, bk):
    return block_sparse_matmul(x, w, mask, bm=bm, bn=bn, bk=bk), (x, w, mask)


def _bsmm_bwd(bm, bn, bk, res, g):
    x, w, mask = res
    wm = (w * mask).astype(jnp.float32)
    gx = jnp.dot(g, wm.T).astype(x.dtype)
    gw = (jnp.dot(x.astype(jnp.float32).T, g) * mask).astype(w.dtype)
    # mask is a constant {0,1} structure — no gradient flows to it.
    return gx, gw, jnp.zeros_like(mask)


block_sparse_matmul_ad.defvjp(_bsmm_fwd, _bsmm_bwd)


def masked_matmul_unblocked(x: jax.Array, w: jax.Array, mask: jax.Array) -> jax.Array:
    """Single-tile Pallas variant (whole operands in one VMEM block).

    Used for small FC layers where tiling overhead dominates; also a second
    implementation to cross-check the blocked schedule.
    """

    def kernel(x_ref, w_ref, m_ref, o_ref):
        o_ref[...] = jnp.dot(
            x_ref[...].astype(jnp.float32),
            (w_ref[...] * m_ref[...]).astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )

    m_dim, _ = x.shape
    _, n_dim = w.shape
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((m_dim, n_dim), jnp.float32),
        interpret=True,
    )(x, w, mask)
