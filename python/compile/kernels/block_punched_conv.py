"""Layer-1 Pallas kernel: block-punched convolution.

Block-punched pruning (paper §4.1.2) partitions a CONV weight tensor
(F, C, KH, KW) into blocks along the (filter, input-channel) dims and prunes
the *same* intra-kernel positions for every kernel in a block.  In GEMM view
(im2col) that is exactly a structured mask on the (C*KH*KW, F) weight
matrix, so the conv lowers to patches-extraction + the block-sparse matmul
kernel — the punched mask keeps whole (c, kh, kw) rows alive per filter
block, which is why the VMEM tiles stay dense-multiplicable on the MXU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .block_sparse_matmul import block_sparse_matmul, block_sparse_matmul_ad

__all__ = ["block_punched_conv", "im2col", "conv_mask_to_gemm"]


def im2col(x: jax.Array, kh: int, kw: int, stride: int, padding: str) -> jax.Array:
    """Extract conv patches: (N, C, H, W) -> (N*OH*OW, C*KH*KW).

    Feature ordering of the output columns is (C, KH, KW) flattened with C
    slowest — matching ``w.reshape(F, C*KH*KW)`` for weights in (F, C, KH,
    KW) layout.
    """
    n = x.shape[0]
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding=padding,
    )  # (N, C*KH*KW, OH, OW)
    ckk = patches.shape[1]
    oh, ow = patches.shape[2], patches.shape[3]
    return patches.transpose(0, 2, 3, 1).reshape(n * oh * ow, ckk), (oh, ow)


def conv_mask_to_gemm(mask4: jax.Array) -> jax.Array:
    """(F, C, KH, KW) mask -> (C*KH*KW, F) GEMM mask."""
    f = mask4.shape[0]
    return mask4.reshape(f, -1).T


@functools.partial(
    jax.jit, static_argnames=("stride", "padding", "bm", "bn", "bk", "ad")
)
def block_punched_conv(
    x: jax.Array,
    w: jax.Array,
    mask: jax.Array,
    *,
    stride: int = 1,
    padding: str = "SAME",
    bm: int = 32,
    bn: int = 32,
    bk: int = 32,
    ad: bool = False,
) -> jax.Array:
    """2-D convolution with a block-punched pruning mask.

    Args:
      x:    (N, C, H, W) input.
      w:    (F, C, KH, KW) weights.
      mask: (F, C, KH, KW) {0,1} punched mask (same intra-kernel positions
            zeroed for all kernels within each (filter, channel) block).

    Returns:
      (N, F, OH, OW) output in f32.
    """
    n = x.shape[0]
    f, _, kh, kw = w.shape
    cols, (oh, ow) = im2col(x, kh, kw, stride, padding)
    wmat = w.reshape(f, -1).T  # (C*KH*KW, F)
    mmat = conv_mask_to_gemm(mask)
    if ad:
        out = block_sparse_matmul_ad(cols, wmat, mmat, bm, bn, bk)
    else:
        out = block_sparse_matmul(cols, wmat, mmat, bm=bm, bn=bn, bk=bk)
    return out.reshape(n, oh, ow, f).transpose(0, 3, 1, 2)
