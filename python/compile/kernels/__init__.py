"""Layer-1 Pallas kernels (build-time only; lowered into HLO by aot.py)."""

from .block_sparse_matmul import (
    block_sparse_matmul,
    block_sparse_matmul_ad,
    masked_matmul_unblocked,
)
from .block_punched_conv import block_punched_conv, conv_mask_to_gemm, im2col

__all__ = [
    "block_sparse_matmul",
    "block_sparse_matmul_ad",
    "masked_matmul_unblocked",
    "block_punched_conv",
    "conv_mask_to_gemm",
    "im2col",
]
