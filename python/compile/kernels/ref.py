"""Pure-jnp correctness oracles for the Pallas kernels.

No Pallas, no tiling — the simplest possible statement of each computation.
Every kernel test asserts allclose against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "masked_matmul_ref",
    "conv2d_ref",
    "block_punched_conv_ref",
    "group_norms_blocked_ref",
]


def masked_matmul_ref(x: jax.Array, w: jax.Array, mask: jax.Array) -> jax.Array:
    """x @ (w * mask) in f32."""
    return jnp.dot(
        x.astype(jnp.float32),
        (w * mask).astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def conv2d_ref(
    x: jax.Array, w: jax.Array, *, stride: int = 1, padding: str = "SAME"
) -> jax.Array:
    """Plain NCHW conv via lax.conv_general_dilated."""
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def block_punched_conv_ref(
    x: jax.Array,
    w: jax.Array,
    mask: jax.Array,
    *,
    stride: int = 1,
    padding: str = "SAME",
) -> jax.Array:
    """Masked conv = dense conv with pre-masked weights."""
    return conv2d_ref(x, w * mask, stride=stride, padding=padding)


def group_norms_blocked_ref(w: jax.Array, bp: int, bq: int) -> jax.Array:
    """Per-block squared Frobenius norms of a (P, Q) matrix.

    Blocks are (bp, bq) tiles; P % bp == 0 and Q % bq == 0 is required.
    Returns (P//bp, Q//bq) of sum-of-squares — the group statistic used by
    the reweighted algorithm's alpha update (paper Eq. 2-4 denominators).
    """
    p, q = w.shape
    assert p % bp == 0 and q % bq == 0
    blocks = w.reshape(p // bp, bp, q // bq, bq)
    return jnp.sum(blocks.astype(jnp.float32) ** 2, axis=(1, 3))
