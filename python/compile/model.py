"""Layer-2 JAX model: the paper's pruning pipeline at proxy scale.

A small CIFAR-shaped CNN (3 conv + 2 FC, ~0.17M weights) whose forward pass
calls the Layer-1 Pallas kernels (block-punched conv, block-sparse matmul)
and whose train step implements SGD on cross-entropy plus the paper's
reweighted group-Lasso penalty (Eq. 1-4):

    minimize  f(W, b; D) + lambda * sum_i R(alpha_i, W_i)

with R expressed element-wise: the Rust coordinator broadcasts the per-group
alpha (1 / (||group||_F^2 + eps)) to weight shape, so the penalty inside the
graph is simply sum(alpha * (w * mask)^2).  This keeps the HLO interface a
flat list of arrays and leaves the *group structure* — which is exactly the
per-layer pruning-scheme decision this paper is about — on the Rust side.

Everything here is build-time: aot.py lowers `forward`, `train_step`, and
the standalone kernel once to HLO text, and the Rust runtime executes the
artifacts over PJRT.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .kernels import block_punched_conv, block_sparse_matmul_ad
from .kernels.ref import conv2d_ref

# ---------------------------------------------------------------------------
# Architecture spec (kept in sync with rust/src/train/proxy.rs via the
# manifest emitted by aot.py).
# ---------------------------------------------------------------------------

IMG = 32          # input spatial size
IN_CH = 3         # input channels
NUM_CLASSES = 10
BATCH = 8

# (name, kind, shape) — weights then bias, in execution order.
PARAM_SPECS: List[Tuple[str, str, Tuple[int, ...]]] = [
    ("conv1_w", "conv", (16, IN_CH, 3, 3)),
    ("conv1_b", "bias", (16,)),
    ("conv2_w", "conv", (32, 16, 3, 3)),
    ("conv2_b", "bias", (32,)),
    ("conv3_w", "conv", (64, 32, 3, 3)),
    ("conv3_b", "bias", (64,)),
    ("fc1_w", "fc", (64 * 4 * 4, 128)),
    ("fc1_b", "bias", (128,)),
    ("fc2_w", "fc", (128, NUM_CLASSES)),
    ("fc2_b", "bias", (NUM_CLASSES,)),
]

# Indices (into the params list) of the prunable weight tensors, in order.
WEIGHT_IDX = [0, 2, 4, 6, 8]
WEIGHT_NAMES = ["conv1_w", "conv2_w", "conv3_w", "fc1_w", "fc2_w"]


def init_params(key: jax.Array) -> List[jax.Array]:
    """He-style init matching the Rust-side initializer (for tests only —
    the runtime passes params in from Rust)."""
    params = []
    for name, kind, shape in PARAM_SPECS:
        key, sub = jax.random.split(key)
        if kind == "bias":
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = int(jnp.prod(jnp.array(shape[1:]))) if kind == "conv" else shape[0]
            std = (2.0 / fan_in) ** 0.5
            params.append(jax.random.normal(sub, shape, jnp.float32) * std)
    return params


def _avg_pool2(x: jax.Array) -> jax.Array:
    """2x2 average pool, NCHW, spatial dims divisible by 2."""
    n, c, h, w = x.shape
    return x.reshape(n, c, h // 2, 2, w // 2, 2).mean(axis=(3, 5))


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def forward(
    params: Sequence[jax.Array],
    masks: Sequence[jax.Array],
    x: jax.Array,
    *,
    use_kernels: bool = True,
    ad: bool = False,
) -> jax.Array:
    """Masked forward pass; returns (B, NUM_CLASSES) logits.

    use_kernels=True routes convs/FCs through the Pallas kernels (the
    artifact path); False uses the pure-jnp reference ops (used by pytest to
    pin the two paths together and by grad-checks).
    """
    c1w, c1b, c2w, c2b, c3w, c3b, f1w, f1b, f2w, f2b = params
    c1m, c2m, c3m, f1m, f2m = masks

    def conv(x_, w_, m_, b_):
        if use_kernels:
            y = block_punched_conv(x_, w_, m_, stride=1, padding="SAME", ad=ad)
        else:
            y = conv2d_ref(x_, w_ * m_, stride=1, padding="SAME")
        return jax.nn.relu(y + b_[None, :, None, None])

    def fc(x_, w_, m_, b_):
        if use_kernels:
            y = block_sparse_matmul_ad(x_, w_, m_) if ad else _bsmm(x_, w_, m_)
        else:
            y = jnp.dot(x_, w_ * m_)
        return y + b_[None, :]

    h = _avg_pool2(conv(x, c1w, c1m, c1b))          # (B, 16, 16, 16)
    h = _avg_pool2(conv(h, c2w, c2m, c2b))          # (B, 32, 8, 8)
    h = _avg_pool2(conv(h, c3w, c3m, c3b))          # (B, 64, 4, 4)
    h = h.reshape(h.shape[0], -1)                   # (B, 1024)
    h = jax.nn.relu(fc(h, f1w, f1m, f1b))           # (B, 128)
    return fc(h, f2w, f2m, f2b)                     # (B, 10)


def _bsmm(x, w, m):
    from .kernels import block_sparse_matmul

    return block_sparse_matmul(x, w, m)


# ---------------------------------------------------------------------------
# Loss / train step
# ---------------------------------------------------------------------------


def loss_fn(
    params: Sequence[jax.Array],
    masks: Sequence[jax.Array],
    alphas: Sequence[jax.Array],
    x: jax.Array,
    y: jax.Array,
    lam: jax.Array,
    *,
    use_kernels: bool = True,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Cross-entropy + reweighted group-Lasso penalty.

    alphas are weight-shaped (per-group values broadcast by the caller), so
    the Eq. 2-4 regularizer collapses to sum(alpha * (w*mask)^2).
    """
    logits = forward(params, masks, x, use_kernels=use_kernels, ad=use_kernels)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ce = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))
    penalty = jnp.asarray(0.0, jnp.float32)
    for wi, (mi, ai) in zip(WEIGHT_IDX, zip(masks, alphas)):
        wm = params[wi] * mi
        penalty = penalty + jnp.sum(ai * wm * wm)
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return ce + lam * penalty, (ce, acc)


def train_step(
    params: Sequence[jax.Array],
    masks: Sequence[jax.Array],
    alphas: Sequence[jax.Array],
    x: jax.Array,
    y: jax.Array,
    lr: jax.Array,
    lam: jax.Array,
    *,
    use_kernels: bool = True,
) -> Tuple[List[jax.Array], jax.Array, jax.Array]:
    """One SGD step; masked weights are re-zeroed after the update so pruned
    structure survives retraining (the paper's masked-retrain phase)."""
    grad_fn = jax.grad(
        lambda p: loss_fn(p, masks, alphas, x, y, lam, use_kernels=use_kernels),
        has_aux=True,
    )
    grads, (ce, acc) = grad_fn(list(params))
    new_params = [p - lr * g for p, g in zip(params, grads)]
    for wi, mi in zip(WEIGHT_IDX, masks):
        new_params[wi] = new_params[wi] * mi
    return new_params, ce, acc


def group_norms(params: Sequence[jax.Array]) -> List[jax.Array]:
    """Element-wise squared weights for every prunable tensor.

    The Rust side reduces these over its chosen group structure (blocks,
    rows, columns, punched positions) to drive the alpha update — emitting
    w^2 rather than per-group sums keeps the artifact agnostic to the
    pruning-scheme mapping, which is the whole point of the paper.
    """
    return [params[i] * params[i] for i in WEIGHT_IDX]
