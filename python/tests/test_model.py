"""Layer-2 model tests: kernel path == ref path, train-step semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    params = model.init_params(key)
    masks = [
        jnp.ones(dict((n, s) for n, _, s in model.PARAM_SPECS)[w], jnp.float32)
        for w in model.WEIGHT_NAMES
    ]
    x = jax.random.normal(jax.random.fold_in(key, 7), (model.BATCH, 3, 32, 32))
    y = jax.random.randint(jax.random.fold_in(key, 8), (model.BATCH,), 0, 10)
    return params, masks, x, y


def _sparse_masks(masks, key, density=0.6):
    out = []
    for i, m in enumerate(masks):
        k = jax.random.fold_in(key, i)
        out.append((jax.random.uniform(k, m.shape) < density).astype(jnp.float32))
    return out


class TestForward:
    def test_shapes(self, setup):
        params, masks, x, _ = setup
        logits = model.forward(params, masks, x, use_kernels=False)
        assert logits.shape == (model.BATCH, model.NUM_CLASSES)

    def test_kernel_path_matches_ref_path(self, setup):
        params, masks, x, _ = setup
        masks = _sparse_masks(masks, jax.random.PRNGKey(3))
        a = model.forward(params, masks, x, use_kernels=True)
        b = model.forward(params, masks, x, use_kernels=False)
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)

    def test_mask_actually_prunes(self, setup):
        params, masks, x, _ = setup
        zero_masks = [jnp.zeros_like(m) for m in masks]
        logits = model.forward(params, zero_masks, x, use_kernels=False)
        # with all weights masked, logits are the (zero) biases
        np.testing.assert_allclose(logits, jnp.zeros_like(logits), atol=1e-6)


class TestTrainStep:
    def test_loss_decreases(self, setup):
        params, masks, x, y = setup
        alphas = [jnp.zeros_like(m) for m in masks]
        lr = jnp.float32(0.05)
        lam = jnp.float32(0.0)
        p = list(params)
        first = None
        for i in range(5):
            p, ce, acc = model.train_step(
                p, masks, alphas, x, y, lr, lam, use_kernels=False
            )
            if first is None:
                first = float(ce)
        assert float(ce) < first

    def test_masks_preserved_after_step(self, setup):
        params, masks, x, y = setup
        masks = _sparse_masks(masks, jax.random.PRNGKey(5))
        alphas = [jnp.zeros_like(m) for m in masks]
        p, _, _ = model.train_step(
            params, masks, alphas, x, y, jnp.float32(0.1), jnp.float32(0.0),
            use_kernels=False,
        )
        for wi, m in zip(model.WEIGHT_IDX, masks):
            np.testing.assert_allclose(p[wi] * (1 - m), jnp.zeros_like(m), atol=0)

    def test_penalty_shrinks_weights(self, setup):
        """With a huge reweighted penalty the weights must shrink toward
        zero faster than without — the mechanism behind Eq. 1-4."""
        params, masks, x, y = setup
        alphas = [jnp.ones_like(m) for m in masks]
        lr = jnp.float32(0.1)
        p_reg, _, _ = model.train_step(
            params, masks, alphas, x, y, lr, jnp.float32(1.0), use_kernels=False
        )
        p_noreg, _, _ = model.train_step(
            params, masks, alphas, x, y, lr, jnp.float32(0.0), use_kernels=False
        )
        wi = model.WEIGHT_IDX[0]
        assert float(jnp.sum(p_reg[wi] ** 2)) < float(jnp.sum(p_noreg[wi] ** 2))

    def test_kernel_train_step_matches_ref(self, setup):
        params, masks, x, y = setup
        masks = _sparse_masks(masks, jax.random.PRNGKey(9))
        alphas = [jnp.full_like(m, 0.01) for m in masks]
        lr, lam = jnp.float32(0.01), jnp.float32(0.001)
        pk, cek, _ = model.train_step(params, masks, alphas, x, y, lr, lam, use_kernels=True)
        pr, cer, _ = model.train_step(params, masks, alphas, x, y, lr, lam, use_kernels=False)
        np.testing.assert_allclose(float(cek), float(cer), rtol=1e-3)
        for a, b in zip(pk, pr):
            np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-4)


class TestGroupNorms:
    def test_shapes_and_values(self, setup):
        params, _, _, _ = setup
        sq = model.group_norms(params)
        assert len(sq) == len(model.WEIGHT_IDX)
        for s, wi in zip(sq, model.WEIGHT_IDX):
            np.testing.assert_allclose(s, params[wi] ** 2, rtol=1e-6)
