"""Kernel-vs-ref correctness: the CORE numeric signal for Layer 1.

Every Pallas kernel is pinned against the pure-jnp oracle in ref.py, with
hypothesis sweeping shapes, tile sizes, and mask densities.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    block_punched_conv,
    block_sparse_matmul,
    conv_mask_to_gemm,
    im2col,
    masked_matmul_unblocked,
)
from compile.kernels.block_sparse_matmul import block_sparse_matmul_ad
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)


def _rand(key, shape):
    return jax.random.normal(key, shape, jnp.float32)


def _mask(key, shape, density):
    return (jax.random.uniform(key, shape) < density).astype(jnp.float32)


# ---------------------------------------------------------------------------
# block_sparse_matmul
# ---------------------------------------------------------------------------


class TestBlockSparseMatmul:
    def test_dense_mask_equals_matmul(self):
        k = jax.random.PRNGKey(0)
        x = _rand(k, (64, 96))
        w = _rand(jax.random.fold_in(k, 1), (96, 32))
        m = jnp.ones_like(w)
        out = block_sparse_matmul(x, w, m)
        np.testing.assert_allclose(out, x @ w, rtol=1e-5, atol=1e-5)

    def test_zero_mask_is_zero(self):
        k = jax.random.PRNGKey(1)
        x = _rand(k, (32, 32))
        w = _rand(jax.random.fold_in(k, 1), (32, 32))
        out = block_sparse_matmul(x, w, jnp.zeros_like(w))
        np.testing.assert_allclose(out, jnp.zeros((32, 32)), atol=0)

    @pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (16, 32, 8), (32, 32, 32), (64, 16, 32)])
    def test_tile_sizes(self, bm, bn, bk):
        k = jax.random.PRNGKey(2)
        x = _rand(k, (48, 80))
        w = _rand(jax.random.fold_in(k, 1), (80, 56))
        m = _mask(jax.random.fold_in(k, 2), (80, 56), 0.5)
        out = block_sparse_matmul(x, w, m, bm=bm, bn=bn, bk=bk)
        np.testing.assert_allclose(out, ref.masked_matmul_ref(x, w, m), rtol=1e-4, atol=1e-4)

    @settings(max_examples=25, deadline=None)
    @given(
        m=st.integers(1, 70),
        k=st.integers(1, 70),
        n=st.integers(1, 70),
        density=st.floats(0.0, 1.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes(self, m, k, n, density, seed):
        key = jax.random.PRNGKey(seed)
        x = _rand(key, (m, k))
        w = _rand(jax.random.fold_in(key, 1), (k, n))
        msk = _mask(jax.random.fold_in(key, 2), (k, n), density)
        out = block_sparse_matmul(x, w, msk, bm=16, bn=16, bk=16)
        np.testing.assert_allclose(
            out, ref.masked_matmul_ref(x, w, msk), rtol=1e-4, atol=1e-4
        )

    def test_unblocked_matches_blocked(self):
        k = jax.random.PRNGKey(3)
        x = _rand(k, (24, 40))
        w = _rand(jax.random.fold_in(k, 1), (40, 24))
        m = _mask(jax.random.fold_in(k, 2), (40, 24), 0.3)
        a = block_sparse_matmul(x, w, m, bm=8, bn=8, bk=8)
        b = masked_matmul_unblocked(x, w, m)
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)

    def test_shape_errors(self):
        x = jnp.ones((4, 5))
        w = jnp.ones((6, 4))
        with pytest.raises(ValueError):
            block_sparse_matmul(x, w, jnp.ones_like(w))
        with pytest.raises(ValueError):
            block_sparse_matmul(x, jnp.ones((5, 4)), jnp.ones((4, 5)))


class TestBlockSparseMatmulAD:
    def test_forward_matches(self):
        k = jax.random.PRNGKey(4)
        x = _rand(k, (16, 32))
        w = _rand(jax.random.fold_in(k, 1), (32, 8))
        m = _mask(jax.random.fold_in(k, 2), (32, 8), 0.5)
        np.testing.assert_allclose(
            block_sparse_matmul_ad(x, w, m),
            ref.masked_matmul_ref(x, w, m),
            rtol=1e-4,
            atol=1e-4,
        )

    def test_grads_match_ref(self):
        k = jax.random.PRNGKey(5)
        x = _rand(k, (8, 16))
        w = _rand(jax.random.fold_in(k, 1), (16, 4))
        m = _mask(jax.random.fold_in(k, 2), (16, 4), 0.6)

        def loss_kernel(x_, w_):
            return jnp.sum(block_sparse_matmul_ad(x_, w_, m) ** 2)

        def loss_ref(x_, w_):
            return jnp.sum(ref.masked_matmul_ref(x_, w_, m) ** 2)

        gx_k, gw_k = jax.grad(loss_kernel, argnums=(0, 1))(x, w)
        gx_r, gw_r = jax.grad(loss_ref, argnums=(0, 1))(x, w)
        np.testing.assert_allclose(gx_k, gx_r, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(gw_k, gw_r, rtol=1e-4, atol=1e-4)

    def test_masked_weight_grad_is_zero(self):
        k = jax.random.PRNGKey(6)
        x = _rand(k, (8, 12))
        w = _rand(jax.random.fold_in(k, 1), (12, 6))
        m = _mask(jax.random.fold_in(k, 2), (12, 6), 0.4)
        gw = jax.grad(lambda w_: jnp.sum(block_sparse_matmul_ad(x, w_, m)))(w)
        np.testing.assert_allclose(gw * (1 - m), jnp.zeros_like(w), atol=0)


# ---------------------------------------------------------------------------
# im2col / block_punched_conv
# ---------------------------------------------------------------------------


class TestIm2col:
    @pytest.mark.parametrize("stride", [1, 2])
    @pytest.mark.parametrize("pad", ["SAME", "VALID"])
    def test_im2col_matmul_equals_conv(self, stride, pad):
        k = jax.random.PRNGKey(7)
        x = _rand(k, (2, 3, 8, 8))
        w = _rand(jax.random.fold_in(k, 1), (5, 3, 3, 3))
        cols, (oh, ow) = im2col(x, 3, 3, stride, pad)
        y = (cols @ w.reshape(5, -1).T).reshape(2, oh, ow, 5).transpose(0, 3, 1, 2)
        np.testing.assert_allclose(
            y, ref.conv2d_ref(x, w, stride=stride, padding=pad), rtol=1e-4, atol=1e-4
        )


class TestBlockPunchedConv:
    @pytest.mark.parametrize("kh", [1, 3, 5])
    def test_kernel_sizes(self, kh):
        k = jax.random.PRNGKey(8)
        x = _rand(k, (2, 4, 10, 10))
        w = _rand(jax.random.fold_in(k, 1), (6, 4, kh, kh))
        m = _mask(jax.random.fold_in(k, 2), w.shape, 0.5)
        out = block_punched_conv(x, w, m, bm=16, bn=16, bk=16)
        np.testing.assert_allclose(
            out, ref.block_punched_conv_ref(x, w, m), rtol=1e-4, atol=1e-4
        )

    def test_punched_mask_structure(self):
        """A true block-punched mask (same intra-kernel positions across a
        block of kernels) runs through the same path."""
        k = jax.random.PRNGKey(9)
        f, c, kh, kw = 8, 4, 3, 3
        x = _rand(k, (1, c, 6, 6))
        w = _rand(jax.random.fold_in(k, 1), (f, c, kh, kw))
        # punch positions (0,0) and (1,2) for the whole (f, c) block
        m = jnp.ones((f, c, kh, kw))
        m = m.at[:, :, 0, 0].set(0.0).at[:, :, 1, 2].set(0.0)
        out = block_punched_conv(x, w, m)
        np.testing.assert_allclose(
            out, ref.block_punched_conv_ref(x, w, m), rtol=1e-4, atol=1e-4
        )

    @settings(max_examples=10, deadline=None)
    @given(
        n=st.integers(1, 3),
        c=st.integers(1, 8),
        f=st.integers(1, 8),
        hw=st.integers(4, 12),
        stride=st.sampled_from([1, 2]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_conv(self, n, c, f, hw, stride, seed):
        key = jax.random.PRNGKey(seed)
        x = _rand(key, (n, c, hw, hw))
        w = _rand(jax.random.fold_in(key, 1), (f, c, 3, 3))
        m = _mask(jax.random.fold_in(key, 2), w.shape, 0.5)
        out = block_punched_conv(x, w, m, stride=stride, bm=16, bn=16, bk=16)
        np.testing.assert_allclose(
            out,
            ref.block_punched_conv_ref(x, w, m, stride=stride),
            rtol=1e-4,
            atol=1e-4,
        )

    def test_gemm_mask_roundtrip(self):
        m4 = (jax.random.uniform(jax.random.PRNGKey(10), (6, 4, 3, 3)) < 0.5).astype(
            jnp.float32
        )
        g = conv_mask_to_gemm(m4)
        assert g.shape == (4 * 9, 6)
        np.testing.assert_allclose(g.T.reshape(6, 4, 3, 3), m4, atol=0)


# ---------------------------------------------------------------------------
# group norms oracle
# ---------------------------------------------------------------------------


class TestGroupNorms:
    def test_blocked_norms(self):
        w = jnp.arange(16.0).reshape(4, 4)
        n = ref.group_norms_blocked_ref(w, 2, 2)
        assert n.shape == (2, 2)
        expect = np.array(
            [
                [0 + 1 + 16 + 25, 4 + 9 + 36 + 49],
                [64 + 81 + 144 + 169, 100 + 121 + 196 + 225],
            ],
            dtype=np.float32,
        )
        np.testing.assert_allclose(n, expect)

    def test_total_is_frobenius(self):
        k = jax.random.PRNGKey(11)
        w = _rand(k, (8, 12))
        n = ref.group_norms_blocked_ref(w, 4, 4)
        np.testing.assert_allclose(jnp.sum(n), jnp.sum(w * w), rtol=1e-5)
