//! Search-based vs rule-based mapping (paper §6.3.4): the RL search is the
//! close-to-optimal upper bound; the rule-based method should get within a
//! whisker of it while being training-free.
//!
//! ```sh
//! cargo run --release --example search_vs_rule
//! ```

use prunemap::latmodel::LatencyModel;
use prunemap::mapping::{self, map_rule_based, map_search_based, RuleConfig, SearchConfig};
use prunemap::models::{zoo, Dataset};
use prunemap::report::{sparkline, Table};
use prunemap::simulator::DeviceProfile;

fn main() {
    let dev = DeviceProfile::s10();
    let lat = LatencyModel::build(&dev);
    let mut t = Table::new(
        "Search-based vs rule-based mapping",
        &["Model", "Dataset", "Method", "Compr.", "Acc drop%", "Latency(ms)", "Wall(s)"],
    );

    for model in [
        zoo::resnet50(Dataset::Cifar10),
        zoo::resnet50(Dataset::ImageNet),
        zoo::mobilenet_v2(Dataset::ImageNet),
    ] {
        // rule-based: milliseconds
        let t0 = std::time::Instant::now();
        let rule = map_rule_based(&model, &lat, &RuleConfig::default());
        let rule_wall = t0.elapsed().as_secs_f64();
        let re = mapping::evaluate(&model, &rule, &dev);

        // search-based: seconds (the paper needed GPU-days; our fast proxy
        // reward makes the same policy-gradient loop cheap)
        let t0 = std::time::Instant::now();
        let (search, _, trace) = map_search_based(&model, &dev, &SearchConfig::default());
        let search_wall = t0.elapsed().as_secs_f64();
        let se = mapping::evaluate(&model, &search, &dev);

        println!(
            "{} ({:?}) search reward trace: {}",
            model.name,
            model.dataset,
            sparkline(&trace.iter().map(|&x| x as f64).collect::<Vec<_>>())
        );

        for (name, e, wall) in [("Rule", re, rule_wall), ("Search", se, search_wall)] {
            t.row(vec![
                model.name.clone(),
                format!("{:?}", model.dataset),
                name.into(),
                format!("{:.2}x", e.compression),
                format!("{:+.2}", e.acc_drop * 100.0),
                format!("{:.2}", e.latency_ms),
                format!("{wall:.2}"),
            ]);
        }
    }
    t.print();
    println!("\nPaper's conclusion to verify: search-based only slightly better; rule-based is training-free and practical.");
}
