//! Multi-model serving front door, end to end over the wire protocol:
//! one process compiles **two** different zoo models into a
//! [`ModelRegistry`], opens a [`Server`] with per-model micro-batchers,
//! serves concurrent remote clients over TCP line-JSON frames — and
//! proves the outputs are **bit-identical** to per-model solo
//! [`Session::infer`] runs, that priority/deadline admission produces
//! typed errors, and that an unknown model is a routing error, not a
//! crash.  Everything is fixed-seed; the assertions make this the CI
//! smoke for the serving stack.
//!
//! ```sh
//! cargo run --release --example multi_model_serve
//! ```

use std::net::TcpListener;
use std::sync::Arc;
use std::time::Duration;

use prunemap::serve::{
    wire, InferRequest, ModelRegistry, PreparedModel, Priority, ServeError, Server, Session,
};

fn mk_input(len: usize, tag: usize) -> Vec<f32> {
    (0..len).map(|j| (((tag * 7 + j) % 23) as f32) * 0.1 - 1.0).collect()
}

fn main() -> prunemap::Result<()> {
    // 1. compile each model once (fixed seeds -> deterministic weights)
    //    and register both under routing names in one shared registry
    let models: Vec<(&str, PreparedModel)> = vec![
        (
            "mobilenetv1",
            PreparedModel::builder()
                .model("mobilenetv1")
                .dataset("cifar10")
                .method("rule")
                .seed(11)
                .build()?,
        ),
        ("proxy", PreparedModel::builder().model("proxy").method("rule").seed(5).build()?),
    ];
    let registry = ModelRegistry::new();
    for (name, prepared) in &models {
        registry.insert(*name, prepared.clone());
        println!(
            "registered '{name}': {} ({}-mapped, seed {}, input {})",
            prepared.name(),
            prepared.method(),
            prepared.seed(),
            prepared.input_len()
        );
    }

    // 2. ground truth: each request served alone by its own single-model
    //    session (the PR-4 layer the front door must match bit for bit)
    let nreq = 6usize;
    let solo: Vec<Vec<Vec<f32>>> = models
        .iter()
        .map(|(_, prepared)| {
            let session = Session::builder(prepared.clone()).build();
            (0..nreq)
                .map(|tag| session.infer(mk_input(prepared.input_len(), tag)).unwrap())
                .collect()
        })
        .collect();

    // 3. open the front door on an ephemeral TCP port
    let server = Arc::new(Server::builder(registry.clone()).max_batch(16).build());
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let acceptor = {
        let server = Arc::clone(&server);
        std::thread::spawn(move || wire::serve_tcp(&server, listener, Some(2), 8))
    };
    println!("\nfront door listening on {addr} [{}]", registry.names().join(", "));

    // 4. two remote clients, each pipelining interleaved requests to BOTH
    //    models over one connection — the per-model batchers untangle them
    let checks: Vec<std::thread::JoinHandle<std::io::Result<usize>>> = (0..2)
        .map(|_| {
            let models: Vec<(String, usize)> = models
                .iter()
                .map(|(name, p)| (name.to_string(), p.input_len()))
                .collect();
            let solo = solo.clone();
            std::thread::spawn(move || -> std::io::Result<usize> {
                let mut client = wire::Client::connect(addr)?;
                let mut ids = Vec::new();
                for tag in 0..nreq {
                    for (m, (name, len)) in models.iter().enumerate() {
                        let mut req = InferRequest::new(name.clone(), mk_input(*len, tag));
                        if tag % 2 == 0 {
                            req = req.priority(Priority::High);
                        }
                        ids.push((m, tag, client.send(&req)?));
                    }
                }
                let mut matched = 0usize;
                for (m, tag, id) in ids {
                    let output = client.wait(id)?.expect("served output");
                    assert_eq!(
                        output, solo[m][tag],
                        "wire output for model {m} tag {tag} must be bit-identical to solo"
                    );
                    matched += 1;
                }
                // typed admission errors over the same connection:
                let ghost = client.infer(&InferRequest::new("ghost", vec![0.0; 4]))?;
                assert!(
                    matches!(ghost, Err(ServeError::UnknownModel(_))),
                    "unknown model must be a typed routing error, got {ghost:?}"
                );
                let (name, len) = &models[0];
                let late = InferRequest::new(name.clone(), mk_input(*len, 0));
                let late = client.infer(&late.deadline(Duration::ZERO))?;
                assert!(
                    matches!(late, Err(ServeError::DeadlineExpired { .. })),
                    "an already-expired deadline must be rejected, got {late:?}"
                );
                Ok(matched)
            })
        })
        .collect();
    let mut matched = 0usize;
    for check in checks {
        matched += check.join().expect("client thread")?;
    }
    acceptor.join().expect("acceptor thread")?;

    // 5. the known-logit smoke CI greps: first logits of each model for
    //    request 0, identical across solo, in-process, and wire serving
    for (m, (name, _)) in models.iter().enumerate() {
        println!("logit[0] of '{name}' request 0: {:.6}", solo[m][0][0]);
    }
    println!(
        "\nOK: {matched} wire requests across {} models bit-identical to solo sessions",
        models.len()
    );
    for (model, st) in server.stats() {
        println!(
            "  {model}: {} requests in {} runs (high/normal {}/{}, {} expired)",
            st.requests, st.runs, st.served_by_priority[0], st.served_by_priority[1], st.expired
        );
    }
    Ok(())
}
