//! End-to-end native inference through the serving API: map a pruned zoo
//! CNN once, seal it into a `PreparedModel`, and serve concurrent
//! requests through a micro-batching `Session`.
//!
//! ```sh
//! cargo run --release --example e2e_infer [-- --threads N --batch N]
//! ```
//!
//! Prints the per-layer scheme mapping with measured per-step latency,
//! demonstrates submit/wait coalescing (with the determinism guarantee:
//! a request's output is bit-identical whether it ran alone or rode a
//! coalesced batch), and writes a measured-vs-modeled calibration record
//! to `target/measured_vs_modeled.json`.

use std::time::Duration;

use prunemap::serve::{PreparedModel, Session, Ticket};
use prunemap::simulator::{measured_vs_modeled_network, DeviceProfile};
use prunemap::util::cli::Args;

fn main() -> prunemap::Result<()> {
    let args = Args::from_env();
    let threads = args.engine_threads()?;

    // 1. compile once: pick a zoo CNN, map the best-suited scheme per
    //    layer (training-free rule-based method), synthesize masked
    //    weights, and lower the fused plan — one sealed artifact
    let prepared = PreparedModel::builder()
        .model("mobilenetv1")
        .dataset("cifar10")
        .device("s10")
        .method("rule")
        .seed(7)
        .build()?;
    let net = prepared.net();
    println!(
        "{}: {} prunable layers -> {} steps, {} arena slots, {} retained weights\n",
        prepared.name(),
        net.layers.len(),
        net.steps.len(),
        net.num_slots,
        net.total_nnz()
    );

    // 2. serve many: the session owns the engine pool, per-worker arena,
    //    and request admission
    let session = Session::builder(prepared.clone())
        .threads(threads)
        .max_batch(16)
        .max_wait(Duration::from_millis(5))
        .build();

    // 3. warmed diagnostic run: per-layer scheme + measured latency
    let batch = args.batch_size(1)?;
    let (c, h, w) = prepared.input_shape();
    let input: Vec<f32> = (0..batch * c * h * w)
        .map(|i| ((i % 13) as f32) * 0.3 - 1.8)
        .collect();
    let (out, timings) = session.run_timed(&input, batch)?;
    println!("{:<14} {:>14} {:>6} {:>8} {:>10}", "layer", "scheme", "comp", "backend", "ms");
    let summaries: std::collections::HashMap<String, _> = net
        .summaries()
        .into_iter()
        .map(|s| (s.name.clone(), s))
        .collect();
    let mut total = 0.0;
    for t in &timings {
        total += t.ms;
        if let Some(s) = summaries.get(&t.name) {
            println!(
                "{:<14} {:>14} {:>5.1}x {:>8} {:>9.3}ms",
                s.name, s.scheme, s.compression, s.backend, t.ms
            );
        }
    }
    println!("(+ glue steps) total {total:.3}ms | output {} logits/sample", out.len() / batch);

    // 4. concurrent serving: submit a burst of single-sample requests and
    //    let the micro-batcher coalesce them into lane-aligned batches
    let sample = prepared.input_len();
    let mk_input = |tag: usize| -> Vec<f32> {
        (0..sample).map(|j| (((tag + j) % 13) as f32) * 0.3 - 1.8).collect()
    };
    let expect: Vec<Vec<f32>> = (0..24).map(|tag| session.infer(mk_input(tag)).unwrap()).collect();
    let tickets: Vec<Ticket> = (0..24).map(|tag| session.submit(mk_input(tag)).unwrap()).collect();
    for (tag, t) in tickets.into_iter().enumerate() {
        let y = t.wait()?;
        assert_eq!(y, expect[tag], "coalesced output must be bit-identical to solo runs");
    }
    let st = session.stats();
    println!(
        "\nserved {} requests in {} runs (max coalesced {}, {} padded lanes) — outputs bit-identical to solo runs",
        st.requests, st.runs, st.max_coalesced, st.padded_lanes
    );

    // 5. batch scaling + calibration record for BENCH trajectories
    let dev = DeviceProfile::s10();
    for b in [1usize, 4, 16] {
        let cmp = measured_vs_modeled_network(
            prepared.model(),
            prepared.assigns(),
            &dev,
            net,
            b,
            threads,
            3,
        )?;
        println!(
            "batch {b:>2}: measured {:.3}ms | modeled {:.3}ms (batch-1 mobile) | ratio {:.2}",
            cmp.measured_ms,
            cmp.modeled_ms,
            cmp.ratio()
        );
        if b == 1 {
            let path = "target/measured_vs_modeled.json";
            std::fs::create_dir_all("target").ok();
            std::fs::write(path, cmp.to_json().pretty())?;
            println!("          wrote {path}");
        }
    }
    Ok(())
}
