//! End-to-end native inference: map a pruned zoo CNN and run every layer
//! through the graph executor on the sparse engine.
//!
//! ```sh
//! cargo run --release --example e2e_infer [-- --threads N --batch N]
//! ```
//!
//! Prints the per-layer scheme mapping with measured per-step latency at
//! several batch sizes, verifies the executor's determinism guarantee
//! (bit-for-bit across thread counts), and writes a measured-vs-modeled
//! calibration record to `target/measured_vs_modeled.json`.

use prunemap::accuracy::Assignment;
use prunemap::latmodel::LatencyModel;
use prunemap::mapping::{map_rule_based, RuleConfig};
use prunemap::models::{zoo, Dataset};
use prunemap::runtime::{CompiledNet, GraphExecutor, KernelChoice};
use prunemap::simulator::{measured_vs_modeled_network, DeviceProfile};
use prunemap::util::cli::Args;

fn main() -> prunemap::Result<()> {
    let args = Args::from_env();
    let threads = args.engine_threads()?;

    // 1. pick a zoo CNN and map the best-suited scheme per layer
    //    (training-free rule-based method)
    let dev = DeviceProfile::s10();
    let model = zoo::mobilenet_v1(Dataset::Cifar10);
    let lat = LatencyModel::build(&dev);
    let assigns: Vec<Assignment> = map_rule_based(&model, &lat, &RuleConfig::default());

    // 2. lower the fused plan once: masks, BCS/CSR conversion, im2col
    //    shapes, arena slots — reused by every run below
    let net = CompiledNet::compile(&model, &assigns, 7, KernelChoice::Auto)?;
    println!(
        "{}: {} prunable layers -> {} steps, {} arena slots, {} retained weights\n",
        model.name,
        net.layers.len(),
        net.steps.len(),
        net.num_slots,
        net.total_nnz()
    );

    // 3. run end to end and report per-layer scheme + measured latency
    let exec = GraphExecutor::new(threads);
    let (c, h, w) = net.input_shape;
    let batch = args.batch_size(1)?;
    let input: Vec<f32> = (0..batch * c * h * w)
        .map(|i| ((i % 13) as f32) * 0.3 - 1.8)
        .collect();
    let _warmup = exec.run(&net, &input, batch)?;
    let (out, timings) = exec.run_timed(&net, &input, batch)?;
    println!("{:<14} {:>14} {:>6} {:>8} {:>10}", "layer", "scheme", "comp", "backend", "ms");
    let summaries: std::collections::HashMap<String, _> = net
        .summaries()
        .into_iter()
        .map(|s| (s.name.clone(), s))
        .collect();
    let mut total = 0.0;
    for t in &timings {
        total += t.ms;
        if let Some(s) = summaries.get(&t.name) {
            println!(
                "{:<14} {:>14} {:>5.1}x {:>8} {:>9.3}ms",
                s.name, s.scheme, s.compression, s.backend, t.ms
            );
        }
    }
    println!("(+ glue steps) total {total:.3}ms | output {} logits/sample", out.len() / batch);

    // 4. determinism: N threads and 1 thread agree bit-for-bit
    let serial = GraphExecutor::serial().run(&net, &input, batch)?;
    assert_eq!(serial, out, "threaded output must be bit-for-bit serial");
    println!("determinism: {} threads == serial, bit-for-bit", exec.threads());

    // 5. batch scaling + calibration record for BENCH trajectories
    for b in [1usize, 4, 16] {
        let cmp = measured_vs_modeled_network(&model, &assigns, &dev, &net, b, threads, 3)?;
        println!(
            "batch {b:>2}: measured {:.3}ms | modeled {:.3}ms (batch-1 mobile) | ratio {:.2}",
            cmp.measured_ms,
            cmp.modeled_ms,
            cmp.ratio()
        );
        if b == 1 {
            let path = "target/measured_vs_modeled.json";
            std::fs::create_dir_all("target").ok();
            std::fs::write(path, cmp.to_json().pretty())?;
            println!("          wrote {path}");
        }
    }
    Ok(())
}
