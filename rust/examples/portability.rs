//! Portability across devices (paper §6.3.5, Tables 6-7): build a latency
//! model per platform, re-run the rule-based mapping, and check that the
//! method transfers (same accuracy, faster phones get faster latency).
//!
//! ```sh
//! cargo run --release --example portability
//! ```

use prunemap::experiments::{table6, table7};

fn main() {
    table6().print();
    table7().print();
    println!("\nExpected shape (paper Table 7): compression and accuracy stable across devices; latency improves S10 -> S20 -> S21.");
}
