//! Quickstart: map the best-suited pruning scheme onto ResNet-50/ImageNet
//! with the training-free rule-based method, report the win, then seal a
//! servable model and answer requests through the session API.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use prunemap::experiments::describe_mapping;
use prunemap::latmodel::LatencyModel;
use prunemap::mapping::{self, map_rule_based, RuleConfig};
use prunemap::models::{zoo, Dataset};
use prunemap::serve::{PreparedModel, Session};
use prunemap::simulator::DeviceProfile;

fn main() -> prunemap::Result<()> {
    // 1. pick the target device and build (or load) its offline latency
    //    model — once per device, reusable for every DNN
    let dev = DeviceProfile::s10();
    let lat = LatencyModel::build(&dev);
    println!("latency model: {} settings for {}", lat.len(), lat.device);

    // 2. pick any DNN from the zoo (or define your own via the DSL)
    let model = zoo::resnet50(Dataset::ImageNet);

    // 3. map — training-free, milliseconds
    let assigns = map_rule_based(&model, &lat, &RuleConfig::default());
    describe_mapping(&model, &assigns).print();

    // 4. evaluate end to end on the device cost model
    let e = mapping::evaluate(&model, &assigns, &dev);
    let dense = mapping::dense_latency_ms(&model, &dev);
    println!(
        "\n{}: {:.2}x compression, {:+.2}% acc drop, {:.2}ms vs {:.2}ms dense ({:.2}x speedup)",
        model.name,
        e.compression,
        e.acc_drop * 100.0,
        e.latency_ms,
        dense,
        dense / e.latency_ms
    );

    // 5. serve it: seal (spec, mapping, weights, compiled net) into one
    //    artifact and answer requests through the micro-batching session.
    //    A smaller CIFAR net keeps the demo snappy; the lifecycle is
    //    identical for any zoo model.
    let prepared = PreparedModel::builder()
        .model("mobilenetv1")
        .dataset("cifar10")
        .method("rule")
        .build()?;
    let session = Session::builder(prepared.clone()).build();
    let tickets: Vec<_> = (0..8)
        .map(|tag| {
            let input = vec![0.1 * tag as f32; prepared.input_len()];
            session.submit(input).expect("submit")
        })
        .collect();
    for t in tickets {
        assert_eq!(t.wait()?.len(), prepared.output_len());
    }
    let st = session.stats();
    println!(
        "\nserved {} requests in {} coalesced runs through {} ({}-mapped)",
        st.requests,
        st.runs,
        prepared.name(),
        prepared.method()
    );
    Ok(())
}
