//! End-to-end validation (DESIGN.md §5): the paper's full pipeline at
//! laptop scale, with ALL THREE LAYERS composing:
//!
//!   Rust coordinator  →  AOT HLO artifacts (JAX model + Pallas kernels)
//!                     →  PJRT CPU execution
//!
//! Trains the proxy CNN on a synthetic CIFAR-like dataset, runs
//! reweighted-regularized epochs with host-side alpha updates, one-shot
//! prunes under the rule-based mapping, masked-retrains, and reports the
//! loss curve, achieved compression, accuracy, and simulated S10 latency.
//! The run is recorded in EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts && RUSTFLAGS="--cfg pjrt" cargo run --release --example e2e_train_prune
//! ```
//!
//! Needs the vendored `xla` bindings (see src/runtime/pjrt.rs); without
//! `--cfg pjrt` this example only prints how to enable it.

#[cfg(not(pjrt))]
fn main() {
    eprintln!(
        "e2e_train_prune needs the PJRT runtime: vendor the `xla` crate and rerun with \
         RUSTFLAGS=\"--cfg pjrt\" (see src/runtime/pjrt.rs)"
    );
}

#[cfg(pjrt)]
fn main() -> anyhow::Result<()> {
    use prunemap::coordinator::{run_pipeline, PipelineConfig};
    use prunemap::experiments::describe_mapping;
    use prunemap::latmodel::LatencyModel;
    use prunemap::mapping::{map_rule_based, RuleConfig};
    use prunemap::models::zoo;
    use prunemap::report::sparkline;
    use prunemap::runtime::Runtime;
    use prunemap::simulator::DeviceProfile;

    let rt = Runtime::open(Runtime::default_dir())?;
    println!("PJRT platform: {}", rt.platform());

    let dev = DeviceProfile::s10();
    let model = zoo::proxy_cnn();
    let lat = LatencyModel::build(&dev);
    let assigns = map_rule_based(&model, &lat, &RuleConfig::default());
    describe_mapping(&model, &assigns).print();

    let cfg = PipelineConfig::default();
    println!(
        "\npipeline: {} pretrain + {}x{} reweighted + prune + {} retrain steps",
        cfg.pretrain_steps, cfg.reg_epochs, cfg.steps_per_epoch, cfg.retrain_steps
    );
    let t0 = std::time::Instant::now();
    let rep = run_pipeline(&rt, &model, &assigns, &dev, &cfg)?;
    let wall = t0.elapsed();

    // loss curve, downsampled for the terminal
    let curve: Vec<f64> = rep.loss_curve.iter().map(|&x| x as f64).collect();
    println!("\nloss curve ({} steps): {}", curve.len(), sparkline(&curve));
    let chunks = 10.max(curve.len() / 10);
    for (i, c) in curve.chunks(chunks).enumerate() {
        let mean: f64 = c.iter().sum::<f64>() / c.len() as f64;
        println!(
            "  steps {:>4}-{:<4}  mean CE {:.4}",
            i * chunks,
            i * chunks + c.len() - 1,
            mean
        );
    }

    println!(
        "\naccuracy: pretrained {:.3} | after prune {:.3} | after masked retrain {:.3}",
        rep.acc_pretrained, rep.acc_after_prune, rep.acc_after_retrain
    );
    println!(
        "per-layer achieved compression: {:?}",
        rep.layer_compressions.iter().map(|c| format!("{c:.1}x")).collect::<Vec<_>>()
    );
    println!("overall compression {:.2}x", rep.overall_compression);
    println!(
        "simulated S10 latency: dense {:.3}ms -> pruned {:.3}ms ({:.2}x speedup)",
        rep.dense_latency_ms, rep.pruned_latency_ms, rep.speedup()
    );
    println!("wall clock: {:.1}s", wall.as_secs_f64());

    // validation gates: the run must demonstrate learning + recovery
    assert!(
        rep.loss_curve.first().unwrap() > rep.loss_curve.last().unwrap(),
        "loss did not decrease"
    );
    assert!(rep.acc_pretrained > 0.5, "pretraining failed to learn");
    assert!(
        rep.acc_after_retrain >= rep.acc_after_prune - 0.02,
        "retraining failed to recover"
    );
    assert!(rep.overall_compression > 2.0, "compression too weak");
    assert!(rep.speedup() > 1.0, "no simulated speedup");
    println!("\ne2e OK");
    Ok(())
}
