//! The advisory performance lint end to end: clean artifacts produce
//! advice at most (never Warning-or-worse), and each perf/calib rule has
//! a corruption path that plants exactly one smell and asserts the
//! expected rule id fires at Advice severity with its structured
//! suggestion.  Also locks the JSONL `suggestion` round trip and the
//! calibration record's re-pricing of the other rules — the read side of
//! the `prunemap profile` loop.

use prunemap::accuracy::Assignment;
use prunemap::analysis::{self, CalibrationRecord, LintConfig, Rule, Severity};
use prunemap::compiler::fusion::FusedKernel;
use prunemap::compiler::{fuse, Graph};
use prunemap::mapping::MappingMethod;
use prunemap::models::{zoo, Dataset, LayerSpec, ModelSpec};
use prunemap::pruning::Scheme;
use prunemap::runtime::NetWeights;
use prunemap::serve::PreparedModel;
use prunemap::simulator::DeviceProfile;
use prunemap::tensor::Tensor;
use prunemap::util::json::Value;

fn dev() -> DeviceProfile {
    DeviceProfile::by_name("s10").unwrap()
}

fn lint_synthesized(
    model: &ModelSpec,
    assigns: &[Assignment],
    calibration: Option<&CalibrationRecord>,
) -> analysis::Report {
    let weights = NetWeights::synthesize(model, assigns, 7).unwrap();
    analysis::lint_model(model, assigns, &weights, &dev(), &LintConfig::default(), calibration)
}

fn assert_advises(report: &analysis::Report, rule: Rule) {
    let hits = report.by_rule(rule);
    assert!(!hits.is_empty(), "expected {} to fire:\n{}", rule.id(), report.render());
    assert!(
        hits.iter().all(|d| d.severity == Severity::Advice),
        "{} must be Advice severity:\n{}",
        rule.id(),
        report.render()
    );
}

fn one_layer_model(layer: LayerSpec) -> ModelSpec {
    ModelSpec { name: "lint-fixture".into(), dataset: Dataset::Cifar10, layers: vec![layer] }
}

// ---- golden path ------------------------------------------------------

#[test]
fn clean_zoo_lint_is_advice_only() {
    let d = dev();
    let rule = MappingMethod::parse("rule", 0, 0).unwrap();
    let models = [
        zoo::proxy_cnn(),
        zoo::mobilenet_v1_scaled(Dataset::Cifar10, 0.25),
        zoo::mobilenet_v2_scaled(Dataset::Cifar10, 0.25),
        zoo::resnet18(Dataset::Cifar10),
    ];
    for model in &models {
        let assigns = rule.assign(model, &d);
        let report = lint_synthesized(model, &assigns, None);
        assert_eq!(report.error_count(), 0, "{}:\n{}", model.name, report.render());
        assert_eq!(report.warning_count(), 0, "{}:\n{}", model.name, report.render());
        assert!(
            report.diagnostics.iter().all(|x| x.severity == Severity::Advice),
            "{}: lint must emit advice only:\n{}",
            model.name,
            report.render()
        );
    }
}

#[test]
fn prepared_model_lint_reports_advice_only() {
    let p = PreparedModel::builder()
        .model("proxy")
        .device("s10")
        .mapping(MappingMethod::parse("rule", 0, 0).unwrap())
        .build()
        .unwrap();
    let report = p.lint(&dev(), &LintConfig::default(), None);
    assert_eq!(report.error_count(), 0, "{}", report.render());
    assert_eq!(report.warning_count(), 0, "{}", report.render());
}

// ---- per-rule corruption paths ----------------------------------------

#[test]
fn misaligned_block_fires_lane_rule() {
    let model = one_layer_model(LayerSpec::conv("conv1", 3, 16, 16, 8, 1));
    let assigns = vec![Assignment {
        scheme: Scheme::BlockPunched { bf: 4, bc: 4 },
        compression: 2.0,
    }];
    let report = lint_synthesized(&model, &assigns, None);
    assert_advises(&report, Rule::LaneMisalignedBlock);
    let d = &report.by_rule(Rule::LaneMisalignedBlock)[0];
    assert_eq!(d.site, "conv1");
    let s = d.suggestion.as_ref().expect("structured suggestion");
    assert_eq!(s.get("kind").unwrap().as_str().unwrap(), "align-block");
    assert_eq!(s.get("lane").unwrap().as_usize().unwrap(), 8);
    // a lane-aligned block candidate tiles 16x16, so an alternative with
    // its predicted speedup must be attached
    assert!(s.get("suggested_scheme").is_ok(), "{}", s.pretty());
    assert!(s.get("predicted_speedup").unwrap().as_f64().unwrap() > 0.0);
}

#[test]
fn forced_worse_scheme_fires_mismatch_with_speedup() {
    // unstructured CSR on a regular conv: the cost model prices the
    // index arithmetic + divergence well above a block or structured
    // scheme at the same compression
    let model = one_layer_model(LayerSpec::conv("conv1", 3, 32, 32, 16, 1));
    let assigns = vec![Assignment { scheme: Scheme::Unstructured, compression: 8.0 }];
    let report = lint_synthesized(&model, &assigns, None);
    assert_advises(&report, Rule::SchemeKernelMismatch);
    let d = &report.by_rule(Rule::SchemeKernelMismatch)[0];
    let s = d.suggestion.as_ref().expect("structured suggestion");
    assert_eq!(s.get("kind").unwrap().as_str().unwrap(), "remap-scheme");
    assert_eq!(
        s.get("current").unwrap().get("backend").unwrap().as_str().unwrap(),
        "csr"
    );
    let speedup = s.get("predicted_speedup").unwrap().as_f64().unwrap();
    assert!(speedup > 1.0, "speedup {speedup}");
    let suggested = s.get("suggested").unwrap();
    assert!(!suggested.get("scheme").unwrap().as_str().unwrap().is_empty());
    assert!(
        suggested.get("predicted_ms").unwrap().as_f64().unwrap()
            < s.get("current").unwrap().get("predicted_ms").unwrap().as_f64().unwrap()
    );
}

#[test]
fn unfused_epilogue_fires_missed_fusion() {
    let model = zoo::proxy_cnn();
    let assigns: Vec<Assignment> = model
        .layers
        .iter()
        .map(|_| Assignment { scheme: Scheme::None, compression: 1.0 })
        .collect();
    let weights = NetWeights::synthesize(&model, &assigns, 7).unwrap();
    let graph = Graph::from_model(&model);
    let mut plan = fuse(&graph);
    // evict one fused epilogue node into its own standalone kernel: the
    // canonical plan would have fused it, so lint must flag the miss
    let k = plan
        .kernels
        .iter_mut()
        .find(|k| !k.epilogue.is_empty())
        .expect("proxy has fused epilogues");
    let evicted = k.epilogue.pop().unwrap();
    plan.kernels.push(FusedKernel { anchor: evicted, epilogue: vec![] });
    let report = analysis::lint(
        &model,
        &assigns,
        &graph,
        &plan,
        &weights,
        &dev(),
        &LintConfig::default(),
        None,
    );
    assert_advises(&report, Rule::MissedFusion);
    let d = &report.by_rule(Rule::MissedFusion)[0];
    let s = d.suggestion.as_ref().expect("structured suggestion");
    assert_eq!(s.get("kind").unwrap().as_str().unwrap(), "fuse-epilogue");
    assert!(!s.get("anchor").unwrap().as_str().unwrap().is_empty());
    // the canonical plan stays clean
    let clean = analysis::lint_model(
        &model,
        &assigns,
        &weights,
        &dev(),
        &LintConfig::default(),
        None,
    );
    assert!(clean.by_rule(Rule::MissedFusion).is_empty(), "{}", clean.render());
}

#[test]
fn lopsided_model_fires_dominant_layer() {
    let model = ModelSpec {
        name: "lopsided".into(),
        dataset: Dataset::Cifar10,
        layers: vec![
            LayerSpec::conv("big", 3, 3, 64, 32, 1),
            LayerSpec::conv("tiny", 1, 64, 8, 4, 1),
        ],
    };
    let assigns = vec![
        Assignment { scheme: Scheme::Unstructured, compression: 4.0 },
        Assignment { scheme: Scheme::Unstructured, compression: 4.0 },
    ];
    let report = lint_synthesized(&model, &assigns, None);
    assert_advises(&report, Rule::DominantLayer);
    let d = &report.by_rule(Rule::DominantLayer)[0];
    assert_eq!(d.site, "big");
    let s = d.suggestion.as_ref().expect("structured suggestion");
    assert!(s.get("share").unwrap().as_f64().unwrap() > 0.5);
}

#[test]
fn skewed_rows_fire_load_imbalance() {
    let model = one_layer_model(LayerSpec::fc("fc1", 64, 64));
    let assigns = vec![Assignment { scheme: Scheme::Unstructured, compression: 4.0 }];
    let mut weights = NetWeights::synthesize(&model, &assigns, 7).unwrap();
    // plant a pathological nnz distribution: output unit 0 keeps a fully
    // dense row while every other unit keeps a single weight — no row
    // reordering can stride-split that evenly
    let mut w = Tensor::zeros(&[64, 64]);
    for i in 0..64 {
        w.set2(i, 0, 1.0);
    }
    for j in 1..64 {
        w.set2(0, j, 1.0);
    }
    weights.layers[0].weight = w;
    let report = analysis::lint_model(
        &model,
        &assigns,
        &weights,
        &dev(),
        &LintConfig::default(),
        None,
    );
    assert_advises(&report, Rule::LoadImbalance);
    let s = report.by_rule(Rule::LoadImbalance)[0]
        .suggestion
        .as_ref()
        .expect("structured suggestion");
    assert!(s.get("imbalance").unwrap().as_f64().unwrap() > 1.25);
}

// ---- calibration ------------------------------------------------------

fn three_layer_model() -> (ModelSpec, Vec<Assignment>) {
    let model = ModelSpec {
        name: "triplet".into(),
        dataset: Dataset::Cifar10,
        layers: vec![
            LayerSpec::conv("c1", 3, 16, 16, 8, 1),
            LayerSpec::conv("c2", 3, 16, 16, 8, 1),
            LayerSpec::conv("c3", 3, 16, 16, 8, 1),
        ],
    };
    let assigns = model
        .layers
        .iter()
        .map(|_| Assignment { scheme: Scheme::BlockPunched { bf: 8, bc: 16 }, compression: 2.0 })
        .collect();
    (model, assigns)
}

fn divergent_record() -> CalibrationRecord {
    // layers c1/c2 measured on-model, c3 measured 10x the shared ratio:
    // the exact file `prunemap profile --json-out` writes
    let json = r#"{"format":"prunemap.calibration.v1","model":"triplet","threads":2,
        "batch":8,"reps":3,"layers":[
        {"name":"c1","modeled_ms":1.0,"measured_ms":1.0,"ratio":1.0},
        {"name":"c2","modeled_ms":1.0,"measured_ms":1.0,"ratio":1.0},
        {"name":"c3","modeled_ms":1.0,"measured_ms":10.0,"ratio":10.0}]}"#;
    CalibrationRecord::from_json(&Value::parse(json).unwrap()).unwrap()
}

#[test]
fn divergent_calibration_flags_layer_and_reprices_other_rules() {
    let (model, assigns) = three_layer_model();

    // without calibration: three identical layers, no divergence and no
    // dominant layer
    let baseline = lint_synthesized(&model, &assigns, None);
    assert!(baseline.by_rule(Rule::CalibrationDivergence).is_empty());
    assert!(baseline.by_rule(Rule::DominantLayer).is_empty(), "{}", baseline.render());

    // with the divergent record: c3 is flagged, and the measured ratios
    // re-price the latency pass — c3 now dominates the network
    let record = divergent_record();
    let report = lint_synthesized(&model, &assigns, Some(&record));
    assert_advises(&report, Rule::CalibrationDivergence);
    let flagged = report.by_rule(Rule::CalibrationDivergence);
    assert_eq!(flagged.len(), 1, "{}", report.render());
    assert_eq!(flagged[0].site, "c3");
    let s = flagged[0].suggestion.as_ref().expect("structured suggestion");
    assert_eq!(s.get("kind").unwrap().as_str().unwrap(), "recalibrate");
    assert!((s.get("relative").unwrap().as_f64().unwrap() - 10.0).abs() < 1e-6);

    assert_advises(&report, Rule::DominantLayer);
    assert_eq!(report.by_rule(Rule::DominantLayer)[0].site, "c3");
}

// ---- serialization ----------------------------------------------------

#[test]
fn suggestion_field_round_trips_jsonl() {
    let model = one_layer_model(LayerSpec::conv("conv1", 3, 32, 32, 16, 1));
    let assigns = vec![Assignment { scheme: Scheme::Unstructured, compression: 8.0 }];
    let report = lint_synthesized(&model, &assigns, None);
    let jsonl = report.to_jsonl();
    let mismatch_line = jsonl
        .lines()
        .find(|l| l.contains("scheme-kernel-mismatch"))
        .expect("mismatch diagnostic in jsonl");
    let v = Value::parse(mismatch_line).unwrap();
    assert_eq!(v.get("severity").unwrap().as_str().unwrap(), "advice");
    assert_eq!(v.get("family").unwrap().as_str().unwrap(), "perf");
    let s = v.get("suggestion").unwrap();
    assert_eq!(s.get("kind").unwrap().as_str().unwrap(), "remap-scheme");
    assert!(s.get("predicted_speedup").unwrap().as_f64().unwrap() > 1.0);
    // parse -> compact -> parse is stable (BTreeMap ordering)
    let reparsed = Value::parse(&v.compact()).unwrap();
    assert_eq!(
        reparsed.get("suggestion").unwrap().compact(),
        s.compact(),
        "suggestion must survive a serialize/parse round trip"
    );
    // diagnostics without a suggestion (everything `check` emits) omit
    // the key entirely rather than writing null
    let fc = one_layer_model(LayerSpec::fc("fc1", 32, 10));
    let bad = vec![Assignment { scheme: Scheme::Pattern, compression: 2.0 }];
    let checked = analysis::check_assignments(&fc, &bad);
    assert!(checked.error_count() > 0, "fixture must produce a diagnostic");
    for line in checked.to_jsonl().lines() {
        assert!(
            Value::parse(line).unwrap().opt("suggestion").is_none(),
            "check diagnostics must not carry a suggestion: {line}"
        );
    }
}
