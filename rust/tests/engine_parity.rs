//! Parity tests for the batched multi-threaded sparse execution engine:
//! `spmm` with 1 and N threads (persistent pool) must match column-by-
//! column serial `spmv` **bit-for-bit** per backend, the SIMD batch lanes
//! must match the scalar reference loop, and both must hold across the
//! pruned-layout families and the edge cases that stress `row_cols`'
//! binary search (0 rows, empty rows, all-dense, single occurrence-run).
//! Also here: the graph executor's size-classed buffer arena (reuse must
//! never leak stale values, and a warm arena must stop allocating).

use prunemap::accuracy::Assignment;
use prunemap::models::zoo;
use prunemap::pruning::{prune, PatternLibrary, Scheme};
use prunemap::rng::Rng;
use prunemap::runtime::{Arena, CompiledNet, GraphExecutor, KernelChoice};
use prunemap::sparse::{
    pack_columns, unpack_column, Bcs, Csr, DenseKernel, Engine, SparseKernel,
};
use prunemap::tensor::Tensor;
use prunemap::util::cli::env_threads;
use prunemap::util::prop::{dim, for_cases};

/// All three backends over the same dense matrix.
fn backends(t: &Tensor) -> Vec<Box<dyn SparseKernel>> {
    vec![
        Box::new(DenseKernel::from_tensor(t)),
        Box::new(Csr::from_dense(t)),
        Box::new(Bcs::from_dense(t)),
    ]
}

/// Assert `spmm` (serial, 1-thread engine, N-thread engine) equals the
/// backend's own column-by-column serial `spmv`, bit for bit.
fn assert_spmm_parity(t: &Tensor, batch: usize, seed: u64) {
    let (rows, cols) = (t.shape()[0], t.shape()[1]);
    let mut rng = Rng::new(seed);
    let columns: Vec<Vec<f32>> = (0..batch)
        .map(|_| (0..cols).map(|_| rng.normal()).collect())
        .collect();
    let x = pack_columns(&columns);
    for kernel in backends(t) {
        // column-by-column serial spmv: the reference
        let reference: Vec<Vec<f32>> =
            columns.iter().map(|c| kernel.spmv_exec(c)).collect();
        let serial = kernel.spmm(&x, batch);
        let scalar = kernel.spmm_scalar(&x, batch);
        let one = Engine::new(1).spmm(&*kernel, &x, batch);
        let many = Engine::new(7).spmm(&*kernel, &x, batch);
        assert_eq!(serial, scalar, "{}: SIMD lanes != scalar reference", kernel.label());
        assert_eq!(serial, one, "{}: 1-thread engine != serial spmm", kernel.label());
        assert_eq!(serial, many, "{}: 7-thread engine != serial spmm", kernel.label());
        assert_eq!(serial.len(), rows * batch);
        for (b, want) in reference.iter().enumerate() {
            assert_eq!(
                &unpack_column(&serial, batch, b),
                want,
                "{}: spmm column {b} != serial spmv",
                kernel.label()
            );
        }
    }
}

fn random_sparse(rows: usize, cols: usize, density: f32, seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    let mut t = Tensor::zeros(&[rows, cols]);
    for r in 0..rows {
        for c in 0..cols {
            if rng.bernoulli(density) {
                t.set2(r, c, rng.normal());
            }
        }
    }
    t
}

#[test]
fn parity_unstructured_random() {
    for (rows, cols, batch) in [(33, 17, 1), (64, 48, 5), (10, 80, 32)] {
        let t = random_sparse(rows, cols, 0.25, rows as u64);
        assert_spmm_parity(&t, batch, 0xE0 + rows as u64);
    }
}

#[test]
fn parity_block_pruned() {
    let lib = PatternLibrary::default8();
    let mut rng = Rng::new(1);
    let w = Tensor::he_normal(&[96, 64], 64, &mut rng);
    let r = prune(&w, &Scheme::Block { bp: 8, bq: 8 }, 4.0, &lib);
    let t = w.hadamard(&r.mask);
    for batch in [1, 2, 33] {
        assert_spmm_parity(&t, batch, 0xE1);
    }
}

#[test]
fn parity_pattern_pruned_gemm_view() {
    let lib = PatternLibrary::default8();
    let mut rng = Rng::new(2);
    let w = Tensor::he_normal(&[16, 16, 3, 3], 16 * 9, &mut rng);
    let r = prune(&w, &Scheme::Pattern, 3.0, &lib);
    let t = w.hadamard(&r.mask).conv_to_gemm();
    assert_spmm_parity(&t, 4, 0xE2);
}

#[test]
fn parity_zero_rows() {
    let t = Tensor::zeros(&[0, 13]);
    assert_spmm_parity(&t, 3, 0xE3);
    for kernel in backends(&t) {
        assert!(kernel.work_units().is_empty(), "{}", kernel.label());
        assert!(Engine::new(4).spmm(&*kernel, &[1.0; 26], 2).is_empty());
    }
}

#[test]
fn parity_empty_rows_interleaved() {
    // all-zero rows between populated ones: BCS gets empty column lists
    // and run boundaries exactly where row_cols' binary search is touchy
    let mut t = Tensor::zeros(&[12, 6]);
    for r in [1usize, 2, 7, 11] {
        for c in 0..6 {
            if (r + c) % 2 == 0 {
                t.set2(r, c, (r * 6 + c) as f32 * 0.1 - 1.0);
            }
        }
    }
    assert_spmm_parity(&t, 5, 0xE4);
    let bcs = Bcs::from_dense(&t);
    assert_eq!(bcs.row_cols(0), &[] as &[u32]);
    assert!(!bcs.row_cols(11).is_empty());
}

#[test]
fn parity_all_dense() {
    // uniform in [0.5, 1.5): provably no exact zeros, so BCS degenerates
    // to one full-width run per distinct row pattern
    let mut rng = Rng::new(3);
    let t = Tensor::uniform(&[24, 24], 0.5, 1.5, &mut rng);
    assert_eq!(t.nnz(), 24 * 24);
    let bcs = Bcs::from_dense(&t);
    assert_eq!(bcs.n_lists(), 1, "identical all-dense patterns should share one run");
    assert_spmm_parity(&t, 6, 0xE5);
}

#[test]
fn parity_single_run() {
    // every row shares one column pattern -> a single occurrence-run;
    // the engine must split it and still match bit-for-bit
    let mut t = Tensor::zeros(&[200, 32]);
    for r in 0..200 {
        for c in [0usize, 5, 9, 31] {
            t.set2(r, c, 1.0 + (r * 32 + c) as f32 * 1e-3);
        }
    }
    let bcs = Bcs::from_dense(&t);
    assert_eq!(bcs.n_lists(), 1, "expected a single occurrence-run");
    assert_spmm_parity(&t, 9, 0xE6);
}

#[test]
fn parity_single_row_and_single_col() {
    assert_spmm_parity(&random_sparse(1, 40, 0.5, 7), 3, 0xE7);
    assert_spmm_parity(&random_sparse(40, 1, 0.5, 8), 3, 0xE8);
}

#[test]
fn threaded_engine_beats_nothing_but_is_deterministic_across_repeats() {
    // repeated threaded runs are identical (no atomics, no reduction
    // reordering anywhere in the dispatch), and the persistent pool is
    // reused across all of them
    let t = random_sparse(128, 96, 0.15, 9);
    let bcs = Bcs::from_dense(&t);
    let mut rng = Rng::new(10);
    let x: Vec<f32> = (0..96 * 16).map(|_| rng.normal()).collect();
    let eng = Engine::new(env_threads(8));
    let first = eng.spmm(&bcs, &x, 16);
    for _ in 0..5 {
        assert_eq!(first, eng.spmm(&bcs, &x, 16));
    }
}

#[test]
fn lane_width_parity_batches_around_the_lane() {
    // batch widths straddling the 8-wide lane (1, 7, 8, 9, 33): spmm ==
    // column-by-column spmv and SIMD == scalar, per backend, bit for bit
    let lib = PatternLibrary::default8();
    let mut rng = Rng::new(21);
    let w = Tensor::he_normal(&[72, 56], 56, &mut rng);
    let r = prune(&w, &Scheme::Block { bp: 8, bq: 8 }, 3.0, &lib);
    let t = w.hadamard(&r.mask);
    for batch in [1usize, 7, 8, 9, 33] {
        assert_spmm_parity(&t, batch, 0xF0 + batch as u64);
    }
}

#[test]
fn persistent_pool_parity_at_random_thread_counts() {
    // one engine per random thread count, several products through the
    // same pool (different shapes and batches), always == serial
    for_cases(10, 0xF1, |rng| {
        let threads = dim(rng, 1, 16);
        let eng = Engine::new(threads);
        for _ in 0..3 {
            let rows = dim(rng, 1, 80);
            let cols = dim(rng, 1, 50);
            let batch = dim(rng, 1, 12);
            let t = {
                let mut m = Tensor::zeros(&[rows, cols]);
                for r in 0..rows {
                    for c in 0..cols {
                        if rng.bernoulli(0.3) {
                            m.set2(r, c, rng.normal());
                        }
                    }
                }
                m
            };
            let bcs = Bcs::from_dense(&t);
            let x: Vec<f32> = (0..cols * batch).map(|_| rng.normal()).collect();
            assert_eq!(
                eng.spmm(&bcs, &x, batch),
                bcs.spmm(&x, batch),
                "threads={threads} rows={rows} cols={cols} batch={batch}"
            );
        }
    });
}

fn zoo_net() -> CompiledNet {
    let m = zoo::proxy_cnn();
    let assigns: Vec<Assignment> = m
        .layers
        .iter()
        .map(|_| Assignment { scheme: Scheme::Unstructured, compression: 2.0 })
        .collect();
    CompiledNet::compile(&m, &assigns, 99, KernelChoice::Auto).unwrap()
}

#[test]
fn arena_reuse_never_leaks_stale_values() {
    // run A poisons the arena's free lists with its activations; run B on
    // a different input through the same arena must match a fresh-arena
    // run bit for bit — a reused size-class buffer must never leak one
    // layer's (or one run's) values into a later output
    let net = zoo_net();
    let exec = GraphExecutor::new(env_threads(4));
    let mut rng = Rng::new(30);
    let a: Vec<f32> = (0..3 * 32 * 32).map(|_| rng.normal()).collect();
    let b: Vec<f32> = (0..3 * 32 * 32).map(|_| rng.normal()).collect();
    let fresh_b = exec.run(&net, &b, 1).unwrap();
    let mut arena = Arena::new();
    let _warm = exec.run_with_arena(&net, &a, 1, &mut arena).unwrap();
    let reused_b = exec.run_with_arena(&net, &b, 1, &mut arena).unwrap();
    assert_eq!(reused_b, fresh_b, "arena reuse changed the output");
}

#[test]
fn warm_arena_runs_allocation_free() {
    // the regression for the ROADMAP arena drop: after one warm-up run the
    // size-class free lists serve every take, so the arena-level
    // allocation counter stays at zero for later runs
    let net = zoo_net();
    let exec = GraphExecutor::new(env_threads(2));
    let input = vec![0.5f32; 3 * 32 * 32];
    let mut arena = Arena::new();
    let y1 = exec.run_with_arena(&net, &input, 1, &mut arena).unwrap();
    assert!(arena.stats().allocs > 0);
    for run in 0..3 {
        arena.reset_stats();
        let y = exec.run_with_arena(&net, &input, 1, &mut arena).unwrap();
        assert_eq!(y, y1);
        let s = arena.stats();
        assert_eq!(s.allocs, 0, "run {run} allocated through the arena: {s:?}");
        assert!(s.reuses > 0, "run {run} never touched the free lists");
    }
}
