//! Runtime integration.
//!
//! The native engine tests always run: they pin the same masked-GEMM
//! semantics the AOT artifacts expose, executed through the batched
//! multi-threaded sparse engine.  The PJRT tests (same assertions against
//! the real artifacts) compile only under `--cfg pjrt` and skip when
//! `make artifacts` has not been run.

use prunemap::rng::Rng;
use prunemap::runtime::{KernelChoice, NativeEngine, SparseLayer};
use prunemap::sparse::pack_columns;
use prunemap::tensor::Tensor;

#[test]
fn native_block_matmul_matches_host_math() {
    // x = ones, w = identity-ish pattern, mask = checkerboard on rows —
    // the exact case the block_matmul artifact test pins
    let (m, k, n) = (4, 16, 12);
    let x = vec![1.0f32; m * k];
    let mut w = Tensor::zeros(&[k, n]);
    for i in 0..k.min(n) {
        w.set2(i, i, 2.0);
    }
    let mask_data: Vec<f32> = (0..k * n).map(|i| ((i / n) % 2) as f32).collect();
    let mask = Tensor::from_vec(&[k, n], mask_data);

    let y = NativeEngine::new(4).block_matmul(&x, m, &w, &mask);
    assert_eq!(y.len(), m * n);
    // host reference: y[i,j] = sum_k x[i,k] * w[k,j] * mask[k,j]
    for i in 0..m {
        for j in 0..n {
            let expect: f32 = (0..k).map(|kk| w.at2(kk, j) * mask.at2(kk, j)).sum();
            assert!(
                (y[i * n + j] - expect).abs() < 1e-4,
                "({i},{j}): got {} want {expect}",
                y[i * n + j]
            );
        }
    }
}

#[test]
fn native_block_matmul_random_matches_dense_reference() {
    let mut rng = Rng::new(0xF00D);
    let (m, k, n) = (7, 20, 15);
    let x: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
    let w = Tensor::he_normal(&[k, n], k, &mut rng);
    let mask_data: Vec<f32> = (0..k * n).map(|_| rng.bernoulli(0.3) as u8 as f32).collect();
    let mask = Tensor::from_vec(&[k, n], mask_data);
    let y = NativeEngine::new(3).block_matmul(&x, m, &w, &mask);
    let wm = w.hadamard(&mask);
    for i in 0..m {
        for j in 0..n {
            let expect: f32 = (0..k).map(|kk| x[i * k + kk] * wm.at2(kk, j)).sum();
            assert!(
                (y[i * n + j] - expect).abs() < 1e-4,
                "({i},{j}): got {} want {expect}",
                y[i * n + j]
            );
        }
    }
}

#[test]
fn native_linear_respects_masks() {
    // zero mask -> zero output, the `forward_artifact_respects_masks`
    // analogue on the native path
    let mut rng = Rng::new(42);
    let w = Tensor::he_normal(&[32, 24], 24, &mut rng);
    let zero = SparseLayer::from_masked(&w.hadamard(&Tensor::zeros(&[32, 24])), KernelChoice::Auto);
    assert_eq!(zero.nnz(), 0);
    let eng = NativeEngine::new(2);
    let cols: Vec<Vec<f32>> = (0..5)
        .map(|_| (0..24).map(|_| rng.normal()).collect())
        .collect();
    let x = pack_columns(&cols);
    let y = eng.linear(&zero, &x, 5);
    assert!(y.iter().all(|&v| v == 0.0), "masked-out layer produced non-zeros");

    let live = SparseLayer::from_masked(&w, KernelChoice::Auto);
    let y2 = eng.linear(&live, &x, 5);
    assert!(y2.iter().any(|&v| v.abs() > 1e-3));
}

#[cfg(pjrt)]
mod pjrt {
    //! Requires `make artifacts`; skips (with a notice) when the artifacts
    //! directory is absent so `cargo test` stays usable on a fresh
    //! checkout.

    use prunemap::runtime::{HostValue, Runtime};

    fn runtime() -> Option<Runtime> {
        let dir = Runtime::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return None;
        }
        Some(Runtime::open(dir).expect("open runtime"))
    }

    #[test]
    fn block_matmul_artifact_matches_host_math() {
        let Some(rt) = runtime() else { return };
        let exe = rt.load("block_matmul").expect("load block_matmul");
        let sig = exe.signature().clone();
        let (m, k, n) = (sig.m.unwrap(), sig.k.unwrap(), sig.n.unwrap());

        // x = ones, w = identity-ish pattern, mask = checkerboard on rows
        let x = vec![1.0f32; m * k];
        let mut w = vec![0.0f32; k * n];
        for i in 0..k.min(n) {
            w[i * n + i] = 2.0;
        }
        let mask: Vec<f32> = (0..k * n).map(|i| ((i / n) % 2) as f32).collect();

        let out = exe
            .run(&[
                HostValue::f32(&[m, k], x),
                HostValue::f32(&[k, n], w.clone()),
                HostValue::f32(&[k, n], mask.clone()),
            ])
            .expect("execute");
        assert_eq!(out.len(), 1);
        let y = &out[0];
        assert_eq!(y.len(), m * n);

        // host reference: y[i,j] = sum_k x[i,k] * w[k,j] * mask[k,j]
        for j in 0..n.min(8) {
            let expect: f32 = (0..k).map(|kk| w[kk * n + j] * mask[kk * n + j]).sum();
            assert!(
                (y[j] - expect).abs() < 1e-4,
                "col {j}: got {} want {expect}",
                y[j]
            );
        }
    }

    #[test]
    fn group_norms_artifact_squares_weights() {
        let Some(rt) = runtime() else { return };
        let exe = rt.load("group_norms").expect("load group_norms");
        let manifest = rt.manifest();
        let mut inputs = Vec::new();
        for wname in &manifest.weight_names {
            let shape = manifest.param_shape(wname).unwrap().to_vec();
            let nelem: usize = shape.iter().product();
            inputs.push(HostValue::f32(
                &shape,
                (0..nelem).map(|i| (i % 5) as f32 - 2.0).collect(),
            ));
        }
        let out = exe.run(&inputs).expect("execute");
        assert_eq!(out.len(), manifest.weight_names.len());
        // first output must be elementwise square of the first weight tensor
        let w0 = inputs[0].as_f32().unwrap();
        for (a, b) in out[0].iter().zip(w0.iter()) {
            assert!((a - b * b).abs() < 1e-5);
        }
    }

    #[test]
    fn forward_artifact_runs_and_is_finite() {
        let Some(rt) = runtime() else { return };
        let exe = rt.load("forward").expect("load forward");
        let m = rt.manifest();
        let mut inputs = Vec::new();
        let mut rng = prunemap::rng::Rng::new(0xF00D);
        for p in &m.params {
            let n: usize = p.shape.iter().product();
            let scale = if p.kind == "bias" { 0.0 } else { 0.05 };
            inputs.push(HostValue::f32(
                &p.shape,
                (0..n).map(|_| rng.normal() * scale).collect(),
            ));
        }
        for wname in &m.weight_names {
            let shape = m.param_shape(wname).unwrap().to_vec();
            let n: usize = shape.iter().product();
            inputs.push(HostValue::f32(&shape, vec![1.0; n]));
        }
        let xn = m.batch * m.in_ch * m.img * m.img;
        inputs.push(HostValue::f32(
            &[m.batch, m.in_ch, m.img, m.img],
            (0..xn).map(|_| rng.normal()).collect(),
        ));
        let out = exe.run(&inputs).expect("execute forward");
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].len(), m.batch * m.num_classes);
        assert!(out[0].iter().all(|v| v.is_finite()));
    }
}
