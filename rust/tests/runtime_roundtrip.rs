//! Integration: load AOT artifacts and execute them over PJRT.
//!
//! Requires `make artifacts` to have run; tests skip (with a notice) when
//! the artifacts directory is absent so `cargo test` stays usable on a
//! fresh checkout.

use prunemap::runtime::{HostValue, Runtime};

fn runtime() -> Option<Runtime> {
    let dir = Runtime::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::open(dir).expect("open runtime"))
}

#[test]
fn block_matmul_artifact_matches_host_math() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("block_matmul").expect("load block_matmul");
    let sig = exe.signature().clone();
    let (m, k, n) = (sig.m.unwrap(), sig.k.unwrap(), sig.n.unwrap());

    // x = ones, w = identity-ish pattern, mask = checkerboard on rows
    let x = vec![1.0f32; m * k];
    let mut w = vec![0.0f32; k * n];
    for i in 0..k.min(n) {
        w[i * n + i] = 2.0;
    }
    let mask: Vec<f32> = (0..k * n).map(|i| ((i / n) % 2) as f32).collect();

    let out = exe
        .run(&[
            HostValue::f32(&[m, k], x),
            HostValue::f32(&[k, n], w.clone()),
            HostValue::f32(&[k, n], mask.clone()),
        ])
        .expect("execute");
    assert_eq!(out.len(), 1);
    let y = &out[0];
    assert_eq!(y.len(), m * n);

    // host reference: y[i,j] = sum_k x[i,k] * w[k,j] * mask[k,j]
    for j in 0..n.min(8) {
        let expect: f32 = (0..k).map(|kk| w[kk * n + j] * mask[kk * n + j]).sum();
        assert!(
            (y[j] - expect).abs() < 1e-4,
            "col {j}: got {} want {expect}",
            y[j]
        );
    }
}

#[test]
fn group_norms_artifact_squares_weights() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("group_norms").expect("load group_norms");
    let manifest = rt.manifest();
    let mut inputs = Vec::new();
    for wname in &manifest.weight_names {
        let shape = manifest.param_shape(wname).unwrap().to_vec();
        let nelem: usize = shape.iter().product();
        inputs.push(HostValue::f32(
            &shape,
            (0..nelem).map(|i| (i % 5) as f32 - 2.0).collect(),
        ));
    }
    let out = exe.run(&inputs).expect("execute");
    assert_eq!(out.len(), manifest.weight_names.len());
    // first output must be elementwise square of the first weight tensor
    let w0 = inputs[0].as_f32().unwrap();
    for (a, b) in out[0].iter().zip(w0.iter()) {
        assert!((a - b * b).abs() < 1e-5);
    }
}

#[test]
fn forward_artifact_runs_and_is_finite() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("forward").expect("load forward");
    let m = rt.manifest();
    let mut inputs = Vec::new();
    let mut rng = prunemap::rng::Rng::new(0xF00D);
    for p in &m.params {
        let n: usize = p.shape.iter().product();
        let scale = if p.kind == "bias" { 0.0 } else { 0.05 };
        inputs.push(HostValue::f32(
            &p.shape,
            (0..n).map(|_| rng.normal() * scale).collect(),
        ));
    }
    for wname in &m.weight_names {
        let shape = m.param_shape(wname).unwrap().to_vec();
        let n: usize = shape.iter().product();
        inputs.push(HostValue::f32(&shape, vec![1.0; n]));
    }
    let xn = m.batch * m.in_ch * m.img * m.img;
    inputs.push(HostValue::f32(
        &[m.batch, m.in_ch, m.img, m.img],
        (0..xn).map(|_| rng.normal()).collect(),
    ));
    let out = exe.run(&inputs).expect("execute forward");
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].len(), m.batch * m.num_classes);
    assert!(out[0].iter().all(|v| v.is_finite()));
}
