//! Cross-module property tests (in-tree prop harness; proptest is
//! unavailable offline).  Each property runs dozens of seeded random cases
//! and reports the failing seed.

use prunemap::compiler::dsl;
use prunemap::compiler::ir::Graph;
use prunemap::models::{zoo, Dataset, LayerSpec};
use prunemap::pruning::{prune, PatternLibrary, Scheme};
use prunemap::reweighted;
use prunemap::rng::Rng;
use prunemap::runtime::graph::im2col::{im2col, Im2colPanels};
use prunemap::simulator::{layer_latency_ms, DeviceProfile, ExecConfig};
use prunemap::sparse::{
    load_balance, permute_rows, reorder_rows, row_nnz_counts, unpack_column, Bcs, Csr,
    DenseKernel, Engine, SparseKernel,
};
use prunemap::tensor::Tensor;
use prunemap::util::prop::{dim, for_cases};

fn random_sparse(rng: &mut Rng, rows: usize, cols: usize, density: f32) -> Tensor {
    let mut t = Tensor::zeros(&[rows, cols]);
    for r in 0..rows {
        for c in 0..cols {
            if rng.bernoulli(density) {
                t.set2(r, c, rng.normal());
            }
        }
    }
    t
}

#[test]
fn prop_bcs_roundtrip_any_matrix() {
    for_cases(40, 0xB1, |rng| {
        let rows = dim(rng, 1, 40);
        let cols = dim(rng, 1, 40);
        let density = rng.f32();
        let t = random_sparse(rng, rows, cols, density);
        let b = Bcs::from_dense(&t);
        assert_eq!(b.to_dense(), t);
        assert_eq!(b.nnz(), t.nnz());
    });
}

#[test]
fn prop_bcs_spmv_equals_csr_spmv() {
    for_cases(30, 0xB2, |rng| {
        let rows = dim(rng, 1, 30);
        let cols = dim(rng, 1, 30);
        let t = random_sparse(rng, rows, cols, 0.4);
        let x: Vec<f32> = (0..cols).map(|_| rng.normal()).collect();
        let yb = Bcs::from_dense(&t).spmv(&x);
        let yc = Csr::from_dense(&t).spmv(&x);
        for (a, b) in yb.iter().zip(&yc) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    });
}

#[test]
fn prop_bcs_roundtrip_and_spmv_parity_on_pruned_masks() {
    // the satellite property set on the paper's three mask families:
    // exact BCS roundtrip, and BCS == CSR == dense matvec within 1e-5
    let lib = PatternLibrary::default8();
    for_cases(12, 0xB9, |rng| {
        let f = 4 * dim(rng, 1, 8);
        let c = 4 * dim(rng, 1, 8);
        let w = Tensor::he_normal(&[f, c, 3, 3], c * 9, &mut rng.fork(1));
        let comp = 2.0 + rng.f32() * 6.0;
        for scheme in [
            Scheme::Unstructured,
            Scheme::Pattern,
            Scheme::BlockPunched { bf: 4, bc: 4 },
        ] {
            let r = prune(&w, &scheme, comp, &lib);
            let t = w.hadamard(&r.mask).conv_to_gemm();
            let b = Bcs::from_dense(&t);
            assert_eq!(b.to_dense(), t, "{scheme:?}: BCS roundtrip");
            assert_eq!(b.nnz(), t.nnz(), "{scheme:?}");
            let csr = Csr::from_dense(&t);
            let x: Vec<f32> = (0..f).map(|_| rng.normal()).collect();
            let yb = b.spmv(&x);
            let yc = csr.spmv(&x);
            let yd = t.matvec(&x);
            for i in 0..yb.len() {
                assert!((yb[i] - yc[i]).abs() < 1e-5, "{scheme:?} bcs/csr row {i}");
                assert!((yb[i] - yd[i]).abs() < 1e-5, "{scheme:?} bcs/dense row {i}");
            }
        }
    });
}

#[test]
fn prop_bcs_index_bytes_beat_csr_on_block_pruned() {
    // the paper's pipeline (punched mask -> GEMM view -> row reorder):
    // BCS's whole reason to exist is a smaller non-value index
    let lib = PatternLibrary::default8();
    for_cases(10, 0xBA, |rng| {
        let f = 8 * dim(rng, 2, 7);
        let c = 8 * dim(rng, 2, 7);
        let w = Tensor::he_normal(&[f, c, 3, 3], c * 9, &mut rng.fork(2));
        let comp = 3.0 + rng.f32() * 5.0;
        let r = prune(&w, &Scheme::BlockPunched { bf: 8, bc: 8 }, comp, &lib);
        let gemm = w.hadamard(&r.mask).conv_to_gemm();
        let t = permute_rows(&gemm, &reorder_rows(&gemm));
        let b = Bcs::from_dense(&t);
        let csr = Csr::from_dense(&t);
        assert!(
            b.index_bytes() <= csr.index_bytes(),
            "{f}x{c} @ {comp:.1}x: BCS index {}B > CSR index {}B",
            b.index_bytes(),
            csr.index_bytes()
        );
    });
}

#[test]
fn prop_engine_spmm_equals_serial_spmv_any_thread_count() {
    for_cases(15, 0xBB, |rng| {
        let rows = dim(rng, 1, 60);
        let cols = dim(rng, 1, 40);
        let t = random_sparse(rng, rows, cols, rng.f32() * 0.6);
        let bcs = Bcs::from_dense(&t);
        let batch = dim(rng, 1, 6);
        let x: Vec<f32> = (0..cols * batch).map(|_| rng.normal()).collect();
        let threads = dim(rng, 1, 9);
        let y = Engine::new(threads).spmm(&bcs, &x, batch);
        for b in 0..batch {
            let col: Vec<f32> = (0..cols).map(|c| x[c * batch + b]).collect();
            let serial = bcs.spmv(&col);
            for r in 0..rows {
                assert_eq!(
                    y[r * batch + b],
                    serial[r],
                    "rows={rows} cols={cols} batch={batch} threads={threads} (r={r}, b={b})"
                );
            }
        }
    });
}

#[test]
fn prop_fused_tile_im2col_equals_materialized() {
    // fused tile-order im2col == materialized im2col for random shapes,
    // strides, and SAME padding, incl. depthwise block-diagonal kernels —
    // bit for bit, across backends, thread counts, and tile widths
    for_cases(12, 0xBC, |rng| {
        let c = dim(rng, 1, 5);
        let h = dim(rng, 3, 9);
        let w = dim(rng, 3, 9);
        let batch = dim(rng, 1, 4);
        let k = if rng.bernoulli(0.7) { 3 } else { 1 };
        let stride = if rng.bernoulli(0.5) { 1 } else { 2 };
        let act: Vec<f32> = (0..c * batch * h * w).map(|_| rng.normal()).collect();
        let mut x = Vec::new();
        let (oh, ow) = im2col(&act, c, h, w, batch, k, k, stride, &mut x);
        let src = Im2colPanels::new(&act, c, h, w, batch, k, k, stride);
        assert_eq!(src.out_hw(), (oh, ow));
        // standard conv kernel [f, c*k*k] or depthwise block-diagonal
        // [c, c*k*k] over the same panels
        let depthwise = rng.bernoulli(0.4);
        let a = if depthwise {
            let mut t = Tensor::zeros(&[c, c * k * k]);
            for ci in 0..c {
                for p in 0..k * k {
                    if rng.bernoulli(0.7) {
                        t.set2(ci, ci * k * k + p, rng.normal());
                    }
                }
            }
            t
        } else {
            let f = dim(rng, 1, 6);
            random_sparse(rng, f, c * k * k, 0.5)
        };
        let total = batch * oh * ow;
        for kernel in [
            Box::new(Bcs::from_dense(&a)) as Box<dyn SparseKernel>,
            Box::new(Csr::from_dense(&a)),
            Box::new(DenseKernel::from_tensor(&a)),
        ] {
            let want = kernel.spmm(&x, total);
            for (threads, tile) in [(1usize, 8usize), (3, 8), (3, 64)] {
                let eng = Engine::new(threads).with_tile_cols(tile);
                assert_eq!(
                    eng.spmm_fused(&*kernel, &src),
                    want,
                    "{} dw={depthwise} {c}x{h}x{w} b={batch} k={k} s={stride}",
                    kernel.label()
                );
            }
        }
    });
}

#[test]
fn prop_lane_width_parity_across_backends() {
    // spmm at batch widths straddling the 8-wide lane (1, 7, 8, 9, 33)
    // agrees column-by-column with spmv, and the SIMD lanes agree with
    // the scalar reference — dense, CSR, and BCS alike
    for_cases(8, 0xBD, |rng| {
        let rows = dim(rng, 1, 50);
        let cols = dim(rng, 1, 40);
        let t = random_sparse(rng, rows, cols, rng.f32() * 0.7);
        for kernel in [
            Box::new(Bcs::from_dense(&t)) as Box<dyn SparseKernel>,
            Box::new(Csr::from_dense(&t)),
            Box::new(DenseKernel::from_tensor(&t)),
        ] {
            for batch in [1usize, 7, 8, 9, 33] {
                let x: Vec<f32> = (0..cols * batch).map(|_| rng.normal()).collect();
                let y = Engine::new(dim(rng, 1, 8)).spmm(&*kernel, &x, batch);
                assert_eq!(y, kernel.spmm_scalar(&x, batch), "{} b={batch}", kernel.label());
                for b in 0..batch {
                    let col: Vec<f32> = (0..cols).map(|c| x[c * batch + b]).collect();
                    assert_eq!(
                        unpack_column(&y, batch, b),
                        kernel.spmv_exec(&col),
                        "{} batch={batch} column={b}",
                        kernel.label()
                    );
                }
            }
        }
    });
}

#[test]
fn prop_reorder_is_permutation_and_helps() {
    for_cases(30, 0xB3, |rng| {
        let rows = dim(rng, 2, 50);
        let cols = dim(rng, 2, 50);
        let density = rng.f32() * 0.8;
        let t = random_sparse(rng, rows, cols, density);
        let order = reorder_rows(&t);
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..rows).collect::<Vec<_>>());
        let nnz = row_nnz_counts(&t);
        let before = load_balance(&nnz, &(0..rows).collect::<Vec<_>>(), 4);
        let after = load_balance(&nnz, &order, 4);
        // sorted order minimizes adjacent nnz transitions (branch count)...
        assert!(after.pattern_switches <= before.pattern_switches);
        // ...and may not materially worsen thread balance (random identity
        // orders are occasionally near-perfect already, so allow slack)
        assert!(
            after.imbalance <= before.imbalance.max(1.0) * 1.15 + 1e-5,
            "imbalance {} -> {}",
            before.imbalance,
            after.imbalance
        );
        // permuted matrix round-trips through BCS
        let p = permute_rows(&t, &order);
        assert_eq!(Bcs::from_dense(&p).to_dense(), p);
    });
}

#[test]
fn prop_masks_are_binary_and_meet_compression() {
    let lib = PatternLibrary::default8();
    for_cases(25, 0xB4, |rng| {
        let f = dim(rng, 2, 24);
        let c = dim(rng, 2, 24);
        let w = Tensor::he_normal(&[f, c, 3, 3], c * 9, &mut rng.fork(1));
        let comp = 2.0 + rng.f32() * 10.0;
        let schemes = [
            Scheme::Unstructured,
            Scheme::StructuredRow,
            Scheme::BlockPunched { bf: 4, bc: 4 },
            Scheme::Pattern,
        ];
        for s in schemes {
            let r = prune(&w, &s, comp, &lib);
            // binary
            assert!(r.mask.data().iter().all(|&v| v == 0.0 || v == 1.0));
            // monotone: at least roughly the target survives (group
            // granularity can overshoot, never undershoot below 1 group)
            assert!(r.kept >= 1);
            assert!(r.kept <= r.total);
            if matches!(s, Scheme::Unstructured) {
                assert!((r.compression() - comp).abs() / comp < 0.25, "{s:?} {comp} {}", r.compression());
            }
        }
    });
}

#[test]
fn prop_masking_is_idempotent() {
    let lib = PatternLibrary::default8();
    for_cases(20, 0xB5, |rng| {
        let p = dim(rng, 4, 40);
        let q = dim(rng, 4, 40);
        let w = Tensor::he_normal(&[p, q], q, &mut rng.fork(2));
        let r = prune(&w, &Scheme::Block { bp: 4, bq: 4 }, 4.0, &lib);
        let once = w.hadamard(&r.mask);
        let twice = once.hadamard(&r.mask);
        assert_eq!(once, twice);
        // pruning the masked tensor again with the same scheme keeps mask
        let r2 = prune(&once, &Scheme::Block { bp: 4, bq: 4 }, 4.0, &lib);
        let thrice = once.hadamard(&r2.mask);
        assert_eq!(thrice.nnz(), once.hadamard(&r2.mask).nnz());
    });
}

#[test]
fn prop_reweighted_alpha_positive_and_inverse() {
    for_cases(20, 0xB6, |rng| {
        let f = dim(rng, 2, 12);
        let c = dim(rng, 2, 12);
        let w = Tensor::he_normal(&[f, c, 3, 3], c * 9, &mut rng.fork(3));
        for s in [
            Scheme::StructuredRow,
            Scheme::BlockPunched { bf: 2, bc: 2 },
            Scheme::Pattern,
        ] {
            let a = reweighted::alphas(&w, &s, reweighted::EPS);
            assert!(a.data().iter().all(|&v| v > 0.0), "{s:?}: nonpositive alpha");
            // penalty equals sum over groups of ||g||^2/(||g||^2+eps) <= #groups
            let pen = reweighted::penalty(&w, &a);
            let n_groups = reweighted::group_sq_norms(&w, &s).len() as f32;
            assert!(pen <= n_groups + 1e-3, "{s:?}: pen {pen} > {n_groups}");
        }
    });
}

#[test]
fn prop_latency_monotone_in_compression() {
    let dev = DeviceProfile::s10();
    for_cases(25, 0xB7, |rng| {
        let ch = [32, 64, 128, 256][rng.below(4)];
        let hw = [7, 14, 28, 56][rng.below(4)];
        let k = [1, 3, 5][rng.below(3)];
        let layer = LayerSpec::conv("l", k, ch, ch, hw, 1);
        let scheme = Scheme::BlockPunched { bf: 8, bc: 16 };
        let c1 = 1.5 + rng.f32() * 4.0;
        let c2 = c1 * (1.5 + rng.f32());
        let l1 = layer_latency_ms(&layer, &ExecConfig::new(scheme, c1, &dev), &dev);
        let l2 = layer_latency_ms(&layer, &ExecConfig::new(scheme, c2, &dev), &dev);
        assert!(l2 <= l1 + 1e-9, "higher compression slower: {l1} -> {l2}");
    });
}

#[test]
fn prop_dsl_roundtrip_random_chains() {
    for_cases(25, 0xB8, |rng| {
        // random conv/fc chain
        let mut text = String::from("input x 1 3 32 32\n");
        let mut prev = "x".to_string();
        let mut ch = 3usize;
        let n = dim(rng, 1, 6);
        for i in 0..n {
            let name = format!("l{i}");
            if rng.bernoulli(0.7) {
                let out = [8, 16, 32][rng.below(3)];
                let k = [1, 3, 5][rng.below(3)];
                text.push_str(&format!(
                    "conv {name} {prev} k={k} in={ch} out={out} hw=32 stride=1\n"
                ));
                ch = out;
            } else {
                text.push_str(&format!("relu {name} {prev}\n"));
            }
            prev = name;
        }
        text.push_str(&format!("output {prev}\n"));
        let g = dsl::parse(&text).unwrap();
        let printed = dsl::print(&g);
        let g2 = dsl::parse(&printed).unwrap();
        assert!(dsl::graphs_equal(&g, &g2), "\n{text}\n--\n{printed}");
    });
}

#[test]
fn prop_model_graph_fusion_one_kernel_per_layer() {
    // for pure chains (our zoo graphs), fusion must land exactly one
    // kernel per prunable layer
    for m in [
        zoo::vgg16(Dataset::Cifar10),
        zoo::resnet50(Dataset::ImageNet),
        zoo::mobilenet_v1(Dataset::ImageNet),
        zoo::yolov4(),
    ] {
        let g = Graph::from_model(&m);
        let plan = prunemap::compiler::fuse(&g);
        assert_eq!(plan.kernel_count(), m.layers.len(), "{}", m.name);
    }
}
