//! The static analyzer end to end: clean artifacts pass, and each rule
//! family has a negative path that corrupts exactly one invariant and
//! asserts the expected rule id fires at Error severity.  Also proves the
//! sealing gate: `PreparedModel::from_parts` and
//! `ModelRegistry::load_recipe` refuse Error-carrying artifacts with a
//! typed `ServeError::ArtifactRejected`.

use prunemap::accuracy::Assignment;
use prunemap::analysis::{self, Rule, Severity};
use prunemap::compiler::fusion::FusedKernel;
use prunemap::compiler::{fuse, Graph};
use prunemap::mapping::MappingMethod;
use prunemap::models::{zoo, Dataset, LayerSpec, ModelSpec};
use prunemap::pruning::Scheme;
use prunemap::runtime::graph::StepOp;
use prunemap::runtime::{CompiledNet, KernelChoice, NetWeights};
use prunemap::serve::{ModelRegistry, PreparedModel, ServeError};
use prunemap::simulator::DeviceProfile;

fn dense_assigns(model: &ModelSpec) -> Vec<Assignment> {
    model
        .layers
        .iter()
        .map(|_| Assignment { scheme: Scheme::None, compression: 1.0 })
        .collect()
}

fn compiled(model: &ModelSpec, assigns: &[Assignment]) -> (NetWeights, CompiledNet) {
    CompiledNet::compile_with_weights(model, assigns, 7, KernelChoice::Auto).unwrap()
}

/// First program step that is a GEMM.
fn gemm_step(net: &CompiledNet) -> usize {
    net.steps
        .iter()
        .position(|s| matches!(s.op, StepOp::Gemm { .. }))
        .expect("no GEMM step")
}

fn assert_fires(report: &analysis::Report, rule: Rule) {
    let hits = report.by_rule(rule);
    assert!(!hits.is_empty(), "expected {} to fire:\n{}", rule.id(), report.render());
    assert!(
        hits.iter().all(|d| d.severity == Severity::Error),
        "{} must be Error severity:\n{}",
        rule.id(),
        report.render()
    );
    assert!(report.has_errors());
}

// ---- clean artifacts --------------------------------------------------

#[test]
fn clean_mapped_zoo_models_pass() {
    let dev = DeviceProfile::by_name("s10").unwrap();
    let rule = MappingMethod::parse("rule", 0, 0).unwrap();
    let models = [
        zoo::proxy_cnn(),
        zoo::mobilenet_v1_scaled(Dataset::Cifar10, 0.25),
        zoo::mobilenet_v2_scaled(Dataset::Cifar10, 0.25),
        zoo::resnet18(Dataset::Cifar10),
    ];
    for model in &models {
        let assigns = rule.assign(model, &dev);
        let (weights, net) = compiled(model, &assigns);
        let report = analysis::check_model(model, &assigns, &weights, &net);
        assert!(
            !report.has_errors(),
            "rule-mapped {} must pass clean:\n{}",
            model.name,
            report.render()
        );
    }
}

#[test]
fn clean_searched_proxy_passes() {
    let dev = DeviceProfile::by_name("s10").unwrap();
    let search = MappingMethod::parse("search", 4, 0xC0FFEE).unwrap();
    let model = zoo::proxy_cnn();
    let assigns = search.assign(&model, &dev);
    let (weights, net) = compiled(&model, &assigns);
    let report = analysis::check_model(&model, &assigns, &weights, &net);
    assert!(!report.has_errors(), "{}", report.render());
}

#[test]
fn sealed_artifact_check_reports_no_errors() {
    let p = PreparedModel::builder()
        .model("proxy")
        .assignments(
            zoo::proxy_cnn()
                .layers
                .iter()
                .map(|l| {
                    if l.is_3x3_conv() {
                        Assignment { scheme: Scheme::BlockPunched { bf: 4, bc: 4 }, compression: 2.0 }
                    } else {
                        Assignment { scheme: Scheme::Block { bp: 8, bq: 2 }, compression: 2.0 }
                    }
                })
                .collect(),
        )
        .build()
        .unwrap();
    let report = p.check();
    assert!(!report.has_errors(), "{}", report.render());
}

// ---- shape family -----------------------------------------------------

#[test]
fn corrupted_step_shape_fires_shape_mismatch() {
    let model = zoo::proxy_cnn();
    let assigns = dense_assigns(&model);
    let (weights, mut net) = compiled(&model, &assigns);
    let g = gemm_step(&net);
    net.steps[g].out_shape.0 += 1;
    let report = analysis::check_model(&model, &assigns, &weights, &net);
    assert_fires(&report, Rule::ShapeMismatch);
}

#[test]
fn rewired_gemm_layer_fires_gemm_dims() {
    let model = zoo::proxy_cnn();
    let assigns = dense_assigns(&model);
    let (weights, mut net) = compiled(&model, &assigns);
    let g = gemm_step(&net);
    // point the first GEMM at a different layer's sparse weights: its
    // dims no longer match, and two layers end up mis-driven
    if let StepOp::Gemm { layer, .. } = &mut net.steps[g].op {
        *layer = (*layer + 1) % net.layers.len();
    }
    let report = analysis::check_model(&model, &assigns, &weights, &net);
    assert_fires(&report, Rule::GemmDims);
}

#[test]
fn wrong_head_width_fires_output_classes() {
    // a 7-way head on a 10-class dataset
    let model = ModelSpec {
        name: "BadHead".into(),
        dataset: Dataset::Cifar10,
        layers: vec![LayerSpec::fc("head", 64, 7)],
    };
    let assigns = dense_assigns(&model);
    let (weights, net) = compiled(&model, &assigns);
    let report = analysis::check_model(&model, &assigns, &weights, &net);
    assert_fires(&report, Rule::OutputClasses);
}

// ---- liveness family --------------------------------------------------

#[test]
fn out_of_range_slot_fires_slot_range() {
    let model = zoo::proxy_cnn();
    let assigns = dense_assigns(&model);
    let (weights, mut net) = compiled(&model, &assigns);
    let g = gemm_step(&net);
    net.steps[g].src = 999;
    let report = analysis::check_model(&model, &assigns, &weights, &net);
    assert_fires(&report, Rule::SlotRange);
}

#[test]
fn aliased_gemm_dst_fires_gemm_aliasing() {
    let model = zoo::proxy_cnn();
    let assigns = dense_assigns(&model);
    let (weights, mut net) = compiled(&model, &assigns);
    let g = gemm_step(&net);
    net.steps[g].dst = net.steps[g].src;
    let report = analysis::check_model(&model, &assigns, &weights, &net);
    assert_fires(&report, Rule::GemmAliasing);
}

#[test]
fn unwritten_slot_read_fires_read_before_write() {
    let model = zoo::proxy_cnn();
    let assigns = dense_assigns(&model);
    let (weights, mut net) = compiled(&model, &assigns);
    // a fresh, in-range slot nothing ever writes
    net.num_slots += 1;
    let g = gemm_step(&net);
    net.steps[g].src = net.num_slots - 1;
    let report = analysis::check_model(&model, &assigns, &weights, &net);
    assert_fires(&report, Rule::ReadBeforeWrite);
}

#[test]
fn unwritten_output_slot_fires_output_slot() {
    let model = zoo::proxy_cnn();
    let assigns = dense_assigns(&model);
    let (weights, mut net) = compiled(&model, &assigns);
    net.num_slots += 1;
    net.output_slot = net.num_slots - 1;
    let report = analysis::check_model(&model, &assigns, &weights, &net);
    assert_fires(&report, Rule::OutputSlot);
}

// ---- scheme family ----------------------------------------------------

#[test]
fn inapplicable_scheme_fires_scheme_legality() {
    let model = zoo::proxy_cnn();
    // pattern pruning cannot live on FC layers
    let assigns: Vec<Assignment> = model
        .layers
        .iter()
        .map(|_| Assignment { scheme: Scheme::Pattern, compression: 2.0 })
        .collect();
    let report = analysis::check_assignments(&model, &assigns);
    assert_fires(&report, Rule::SchemeLegality);

    // assignment count mismatch is the same family
    let short = analysis::check_assignments(&model, &[]);
    assert_fires(&short, Rule::SchemeLegality);
}

#[test]
fn corrupted_mask_fires_mask_structure() {
    let model = zoo::proxy_cnn();
    let mut assigns = dense_assigns(&model);
    assigns[0] = Assignment { scheme: Scheme::StructuredRow, compression: 2.0 };
    let (mut weights, net) = compiled(&model, &assigns);

    // un-prune one element of a pruned filter: the row is now partial
    let w = &mut weights.layers[0].weight;
    let zero_at = w.data().iter().position(|v| *v == 0.0).expect("mask has zeros");
    w.data_mut()[zero_at] = 1.0;
    let report = analysis::check_model(&model, &assigns, &weights, &net);
    assert_fires(&report, Rule::MaskStructure);

    // an entirely pruned layer is also structural corruption
    for v in weights.layers[0].weight.data_mut() {
        *v = 0.0;
    }
    let report = analysis::check_model(&model, &assigns, &weights, &net);
    assert_fires(&report, Rule::MaskStructure);
}

#[test]
fn compression_drift_warns_but_never_gates() {
    let model = zoo::proxy_cnn();
    let assigns = dense_assigns(&model);
    let (mut weights, net) = compiled(&model, &assigns);
    // a dense layer claiming 64x compression is implausible provenance
    weights.layers[0].compression = 64.0;
    let report = analysis::check_model(&model, &assigns, &weights, &net);
    let hits = report.by_rule(Rule::CompressionDrift);
    assert!(!hits.is_empty(), "{}", report.render());
    assert!(hits.iter().all(|d| d.severity == Severity::Warning));
    assert!(!report.has_errors(), "drift must not gate:\n{}", report.render());
}

// ---- plan family ------------------------------------------------------

#[test]
fn corrupted_plan_fires_plan_rules() {
    let model = zoo::proxy_cnn();
    let assigns = dense_assigns(&model);
    let graph = Graph::from_model(&model);
    let plan = fuse(&graph);
    let (weights, net) = compiled(&model, &assigns);

    // anchor the Input node
    let mut bad = plan.clone();
    bad.kernels.push(FusedKernel { anchor: 0, epilogue: vec![] });
    let report = analysis::check(&model, &assigns, &graph, &bad, &weights, &net);
    assert_fires(&report, Rule::PlanAnchor);

    // fuse a non-elementwise (layer) node into another kernel's epilogue
    let mut bad = plan.clone();
    let victim = bad.kernels[0].anchor;
    bad.kernels.last_mut().unwrap().epilogue.push(victim);
    let report = analysis::check(&model, &assigns, &graph, &bad, &weights, &net);
    assert_fires(&report, Rule::PlanEpilogue);
}

#[test]
fn disordered_graph_fires_plan_topo() {
    let model = zoo::proxy_cnn();
    let assigns = dense_assigns(&model);
    let mut graph = Graph::from_model(&model);
    let plan = fuse(&graph);
    let (weights, net) = compiled(&model, &assigns);
    graph.nodes.swap(0, 1);
    let report = analysis::check(&model, &assigns, &graph, &plan, &weights, &net);
    assert_fires(&report, Rule::PlanTopo);
}

// ---- gating -----------------------------------------------------------

fn bad_head_recipe_json() -> String {
    r#"{
  "format": "prunemap.prepared.v1",
  "model": {
    "name": "BadHead",
    "dataset": "cifar10",
    "layers": [
      {"name": "head", "kind": "fc", "kh": 1, "kw": 1,
       "in_ch": 64, "out_ch": 7, "in_hw": 1, "stride": 1}
    ]
  },
  "assignments": [{"scheme": {"kind": "none"}, "compression": 1.0}],
  "seed": "7",
  "kernel": "auto",
  "method": "explicit"
}"#
    .to_string()
}

#[test]
fn sealing_refuses_error_carrying_artifacts() {
    let model = ModelSpec {
        name: "BadHead".into(),
        dataset: Dataset::Cifar10,
        layers: vec![LayerSpec::fc("head", 64, 7)],
    };
    let assigns = dense_assigns(&model);
    let err = PreparedModel::from_parts(model, assigns, 7, KernelChoice::Auto, "explicit")
        .expect_err("sealing must refuse a wrong-width head");
    let serve = err
        .downcast_ref::<ServeError>()
        .expect("typed ServeError through the anyhow chain");
    assert_eq!(serve.kind(), "artifact_rejected");
    match serve {
        ServeError::ArtifactRejected { model, errors } => {
            assert_eq!(model, "BadHead");
            assert!(*errors >= 1);
        }
        other => panic!("wrong variant: {other:?}"),
    }
    // the context carries the rendered diagnostics with the rule id
    let rendered = format!("{err:#}");
    assert!(rendered.contains("output-classes"), "{rendered}");
}

#[test]
fn recipe_load_refuses_error_carrying_artifacts() {
    let dir = std::env::temp_dir();
    let path = dir.join(format!("prunemap_bad_head_{}.json", std::process::id()));
    std::fs::write(&path, bad_head_recipe_json()).unwrap();

    let err = PreparedModel::load(&path).expect_err("load must refuse");
    assert_eq!(
        err.downcast_ref::<ServeError>().map(ServeError::kind),
        Some("artifact_rejected")
    );

    let registry = ModelRegistry::new();
    let err = registry.load_recipe("bad", &path).expect_err("registry must refuse");
    assert_eq!(
        err.downcast_ref::<ServeError>().map(ServeError::kind),
        Some("artifact_rejected")
    );
    assert!(registry.get("bad").is_none(), "refused artifact must not be registered");

    let _ = std::fs::remove_file(&path);

    // the same recipe parses fine without the gate — that is how
    // `prunemap check --load` diagnoses it
    let v = prunemap::util::json::Value::parse(&bad_head_recipe_json()).unwrap();
    let (model, assigns, seed, choice, _) = PreparedModel::recipe_from_json(&v).unwrap();
    let (weights, net) =
        CompiledNet::compile_with_weights(&model, &assigns, seed, choice).unwrap();
    let report = analysis::check_model(&model, &assigns, &weights, &net);
    assert_fires(&report, Rule::OutputClasses);
}
