//! End-to-end integration.
//!
//! The native-path tests always run: mapping → mask generation → GEMM
//! view → batched multi-threaded sparse execution, asserting numerical
//! parity with dense references and thread-count invariance.  The live
//! PJRT pipeline tests (train-step semantics, penalty agreement, full
//! pipeline) compile only under `--cfg pjrt` and skip gracefully when
//! artifacts are absent.

use prunemap::latmodel::LatencyModel;
use prunemap::mapping::{self, map_rule_based, RuleConfig};
use prunemap::models::zoo;
use prunemap::pruning::{prune, PatternLibrary, Scheme};
use prunemap::rng::Rng;
use prunemap::runtime::{KernelChoice, NativeEngine, SparseLayer};
use prunemap::simulator::DeviceProfile;
use prunemap::sparse::{pack_columns, permute_rows, reorder_rows};
use prunemap::tensor::Tensor;

#[test]
fn native_pipeline_mapped_layers_execute_with_parity() {
    // rule-map the proxy CNN, generate real masks at the mapped rates,
    // and execute every layer's GEMM view on the engine
    let dev = DeviceProfile::s10();
    let model = zoo::proxy_cnn();
    let lat = LatencyModel::build(&dev);
    let assigns = map_rule_based(&model, &lat, &RuleConfig::default());
    let lib = PatternLibrary::default8();
    let mut rng = Rng::new(0xA11);
    let eng_serial = NativeEngine::serial();
    let eng_threads = NativeEngine::new(4);

    let mut total = 0usize;
    let mut kept = 0usize;
    for (layer, a) in model.layers.iter().zip(&assigns) {
        // realistic weight tensor in the layer's natural layout
        let shape: Vec<usize> = if layer.kh > 1 || layer.kind != prunemap::models::LayerKind::Fc {
            vec![layer.out_ch, layer.in_ch, layer.kh, layer.kw]
        } else {
            vec![layer.out_ch, layer.in_ch]
        };
        let fan: usize = shape[1..].iter().product();
        let w = Tensor::he_normal(&shape, fan.max(1), &mut rng);
        let r = prune(&w, &a.scheme, a.compression, &lib);
        let masked = w.hadamard(&r.mask);
        total += r.total;
        kept += r.kept;

        let gemm = if masked.ndim() == 4 {
            masked.conv_to_gemm()
        } else {
            masked.clone()
        };
        let reordered = permute_rows(&gemm, &reorder_rows(&gemm));
        let sl = SparseLayer::from_masked(&reordered, KernelChoice::Auto);
        let (rows, cols) = sl.dims();
        assert_eq!(sl.nnz(), reordered.nnz(), "{}", layer.name);

        let batch = 6;
        let x: Vec<f32> = (0..cols * batch).map(|_| rng.normal() * 0.2).collect();
        let y_serial = eng_serial.linear(&sl, &x, batch);
        let y_threaded = eng_threads.linear(&sl, &x, batch);
        assert_eq!(y_serial, y_threaded, "{}: thread-count parity", layer.name);
        let y_dense = reordered.matmul_cols(&x, batch);
        assert_eq!(y_serial.len(), rows * batch);
        for i in 0..y_serial.len() {
            assert!(
                (y_serial[i] - y_dense[i]).abs() < 1e-4,
                "{}: engine vs dense at {i}: {} vs {}",
                layer.name,
                y_serial[i],
                y_dense[i]
            );
        }
    }
    // the mapped masks actually compress the model
    let achieved = total as f32 / kept.max(1) as f32;
    assert!(achieved > 1.5, "overall mask compression {achieved}x");
    // and the mapped configuration is predicted faster than dense
    let e = mapping::evaluate(&model, &assigns, &dev);
    assert!(e.latency_ms < mapping::dense_latency_ms(&model, &dev));
}

#[test]
fn native_mlp_chain_forward_is_thread_invariant() {
    // a small pruned MLP executed end to end: x -> fc1+relu -> fc2+relu
    // -> logits, threaded result bit-identical to serial
    let lib = PatternLibrary::default8();
    let mut rng = Rng::new(0xB22);
    let dims = [(48usize, 64usize), (32, 48), (10, 32)];
    let layers: Vec<SparseLayer> = dims
        .iter()
        .map(|&(out, inp)| {
            let w = Tensor::he_normal(&[out, inp], inp, &mut rng);
            let r = prune(&w, &Scheme::Block { bp: 8, bq: 8 }, 3.0, &lib);
            SparseLayer::from_masked(&w.hadamard(&r.mask), KernelChoice::Auto)
        })
        .collect();

    let batch = 16;
    let cols: Vec<Vec<f32>> = (0..batch)
        .map(|_| (0..64).map(|_| rng.normal()).collect())
        .collect();
    let x0 = pack_columns(&cols);

    let forward = |eng: &NativeEngine| -> Vec<f32> {
        let h1 = eng.linear_relu(&layers[0], &x0, batch);
        let h2 = eng.linear_relu(&layers[1], &h1, batch);
        eng.linear(&layers[2], &h2, batch)
    };
    let serial = forward(&NativeEngine::serial());
    assert_eq!(serial.len(), 10 * batch);
    assert!(serial.iter().any(|&v| v != 0.0));
    for threads in [2, 4, 8] {
        assert_eq!(serial, forward(&NativeEngine::new(threads)), "threads={threads}");
    }
}

#[test]
fn native_engine_speedup_is_measurable_on_large_spmm() {
    // not a benchmark (CI boxes are noisy) — just assert the threaded
    // dispatch actually distributes work instead of serializing it
    use prunemap::sparse::{Bcs, Engine};
    let lib = PatternLibrary::default8();
    let mut rng = Rng::new(0xC33);
    let w = Tensor::he_normal(&[512, 512], 512, &mut rng);
    let r = prune(&w, &Scheme::Block { bp: 8, bq: 8 }, 8.0, &lib);
    let bcs = Bcs::from_dense(&w.hadamard(&r.mask));
    let eng = Engine::new(4);
    let costs = eng.worker_costs(&bcs);
    assert!(costs.len() >= 2, "dispatch degenerated to one worker");
    let balance = eng.predicted_balance(&bcs);
    assert!(
        balance.imbalance < 2.0,
        "stride dispatch badly imbalanced: {}",
        balance.imbalance
    );
}

#[cfg(pjrt)]
mod pjrt {
    //! The live PJRT path; skips when artifacts are absent.

    use prunemap::accuracy::Assignment;
    use prunemap::coordinator::{run_pipeline, PipelineConfig};
    use prunemap::latmodel::LatencyModel;
    use prunemap::mapping::{map_rule_based, RuleConfig};
    use prunemap::models::zoo;
    use prunemap::pruning::Scheme;
    use prunemap::rng::Rng;
    use prunemap::runtime::Runtime;
    use prunemap::simulator::DeviceProfile;
    use prunemap::train::{SynthDataset, TrainDriver};

    fn runtime() -> Option<Runtime> {
        let dir = Runtime::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return None;
        }
        Some(Runtime::open(dir).expect("open runtime"))
    }

    #[test]
    fn train_step_reduces_loss() {
        let Some(rt) = runtime() else { return };
        let mut d = TrainDriver::new(&rt, 7).unwrap();
        let ds = SynthDataset::cifar_like(7);
        let mut rng = Rng::new(8);
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..30 {
            let (x, y) = ds.batch(d.batch_size(), &mut rng);
            let s = d.step(&x, &y, 0.05, 0.0).unwrap();
            if first.is_none() {
                first = Some(s.ce);
            }
            last = s.ce;
        }
        assert!(last < first.unwrap(), "loss {first:?} -> {last}");
    }

    #[test]
    fn masks_survive_pjrt_training() {
        let Some(rt) = runtime() else { return };
        let mut d = TrainDriver::new(&rt, 9).unwrap();
        let model = zoo::proxy_cnn();
        let assigns: Vec<Assignment> = model
            .layers
            .iter()
            .map(|l| Assignment {
                scheme: if l.kind == prunemap::models::LayerKind::Fc {
                    Scheme::Block { bp: 8, bq: 2 }
                } else {
                    Scheme::BlockPunched { bf: 4, bc: 4 }
                },
                compression: 4.0,
            })
            .collect();
        let lib = prunemap::pruning::PatternLibrary::default8();
        d.prune_with(&assigns, &lib).unwrap();
        let masks: Vec<_> = d.masks.clone();
        let ds = SynthDataset::cifar_like(9);
        let mut rng = Rng::new(10);
        for _ in 0..5 {
            let (x, y) = ds.batch(d.batch_size(), &mut rng);
            d.step(&x, &y, 0.05, 0.0).unwrap();
        }
        // every masked weight must still be zero after PJRT updates
        for (w, m) in d.weights().iter().zip(&masks) {
            for (v, mk) in w.data().iter().zip(m.data()) {
                if *mk == 0.0 {
                    assert_eq!(*v, 0.0, "pruned weight resurrected");
                }
            }
        }
    }

    #[test]
    fn reweighted_penalty_matches_in_graph_loss_shift() {
        // CE reported by the artifact excludes the penalty term, but the
        // penalty influences gradients: with a huge alpha the weights shrink.
        let Some(rt) = runtime() else { return };
        let model = zoo::proxy_cnn();
        let assigns: Vec<Assignment> = model
            .layers
            .iter()
            .map(|l| Assignment {
                scheme: if l.kind == prunemap::models::LayerKind::Fc {
                    Scheme::StructuredRow
                } else {
                    Scheme::BlockPunched { bf: 4, bc: 4 }
                },
                compression: 1.0,
            })
            .collect();
        // identical training with and without the penalty; the regularized
        // run must end with smaller weight norms (paper Eq. 1's lambda term)
        let run = |lam: f32| -> f32 {
            let mut d = TrainDriver::new(&rt, 11).unwrap();
            d.update_alphas(&assigns);
            let ds = SynthDataset::cifar_like(11);
            let mut rng = Rng::new(12);
            for _ in 0..12 {
                let (x, y) = ds.batch(d.batch_size(), &mut rng);
                d.step(&x, &y, 0.01, lam).unwrap();
                d.update_alphas(&assigns);
            }
            d.weights().iter().map(|w| w.sq_norm()).sum()
        };
        let with_penalty = run(0.02);
        let without = run(0.0);
        assert!(
            with_penalty < without,
            "reweighted penalty failed to shrink weights: {with_penalty} !< {without}"
        );
    }

    #[test]
    fn short_pipeline_end_to_end() {
        let Some(rt) = runtime() else { return };
        let dev = DeviceProfile::s10();
        let model = zoo::proxy_cnn();
        let lat = LatencyModel::build(&dev);
        let assigns = map_rule_based(&model, &lat, &RuleConfig::default());
        let cfg = PipelineConfig {
            pretrain_steps: 40,
            reg_epochs: 2,
            steps_per_epoch: 10,
            retrain_steps: 30,
            ..Default::default()
        };
        let rep = run_pipeline(&rt, &model, &assigns, &dev, &cfg).unwrap();
        assert_eq!(
            rep.loss_curve.len(),
            cfg.pretrain_steps + cfg.reg_epochs * cfg.steps_per_epoch + cfg.retrain_steps
        );
        assert!(rep.overall_compression > 1.5, "{}", rep.overall_compression);
        assert!(rep.speedup() > 1.0);
        // learning happened
        let head: f32 = rep.loss_curve[..10].iter().sum::<f32>() / 10.0;
        let tail: f32 =
            rep.loss_curve[rep.loss_curve.len() - 10..].iter().sum::<f32>() / 10.0;
        assert!(tail < head, "loss {head} -> {tail}");
    }

    #[test]
    fn forward_artifact_respects_masks() {
        let Some(rt) = runtime() else { return };
        let mut d = TrainDriver::new(&rt, 13).unwrap();
        let ds = SynthDataset::cifar_like(13);
        let mut rng = Rng::new(14);
        let (x, _) = ds.batch(d.batch_size(), &mut rng);
        let before = d.forward(&x).unwrap();
        // zero all masks -> logits collapse to biases (zeros)
        let zero_masks: Vec<_> = d
            .masks
            .iter()
            .map(|m| prunemap::tensor::Tensor::zeros(m.shape()))
            .collect();
        d.set_masks(zero_masks).unwrap();
        let after = d.forward(&x).unwrap();
        assert!(before.iter().any(|v| v.abs() > 1e-3));
        assert!(after.iter().all(|v| v.abs() < 1e-5), "masked forward non-zero");
    }
}
