//! End-to-end integration over the live PJRT path: train-step semantics,
//! penalty agreement with the host-side reweighted module, and a short
//! full pipeline.  Skips gracefully when artifacts are absent.

use prunemap::accuracy::Assignment;
use prunemap::coordinator::{run_pipeline, PipelineConfig};
use prunemap::latmodel::LatencyModel;
use prunemap::mapping::{map_rule_based, RuleConfig};
use prunemap::models::zoo;
use prunemap::pruning::Scheme;
use prunemap::rng::Rng;
use prunemap::runtime::Runtime;
use prunemap::simulator::DeviceProfile;
use prunemap::train::{SynthDataset, TrainDriver};

fn runtime() -> Option<Runtime> {
    let dir = Runtime::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::open(dir).expect("open runtime"))
}

#[test]
fn train_step_reduces_loss() {
    let Some(rt) = runtime() else { return };
    let mut d = TrainDriver::new(&rt, 7).unwrap();
    let ds = SynthDataset::cifar_like(7);
    let mut rng = Rng::new(8);
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..30 {
        let (x, y) = ds.batch(d.batch_size(), &mut rng);
        let s = d.step(&x, &y, 0.05, 0.0).unwrap();
        if first.is_none() {
            first = Some(s.ce);
        }
        last = s.ce;
    }
    assert!(last < first.unwrap(), "loss {first:?} -> {last}");
}

#[test]
fn masks_survive_pjrt_training() {
    let Some(rt) = runtime() else { return };
    let mut d = TrainDriver::new(&rt, 9).unwrap();
    let model = zoo::proxy_cnn();
    let assigns: Vec<Assignment> = model
        .layers
        .iter()
        .map(|l| Assignment {
            scheme: if l.kind == prunemap::models::LayerKind::Fc {
                Scheme::Block { bp: 8, bq: 8 }
            } else {
                Scheme::BlockPunched { bf: 4, bc: 4 }
            },
            compression: 4.0,
        })
        .collect();
    let lib = prunemap::pruning::PatternLibrary::default8();
    d.prune_with(&assigns, &lib).unwrap();
    let masks: Vec<_> = d.masks.clone();
    let ds = SynthDataset::cifar_like(9);
    let mut rng = Rng::new(10);
    for _ in 0..5 {
        let (x, y) = ds.batch(d.batch_size(), &mut rng);
        d.step(&x, &y, 0.05, 0.0).unwrap();
    }
    // every masked weight must still be zero after PJRT updates
    for (w, m) in d.weights().iter().zip(&masks) {
        for (v, mk) in w.data().iter().zip(m.data()) {
            if *mk == 0.0 {
                assert_eq!(*v, 0.0, "pruned weight resurrected");
            }
        }
    }
}

#[test]
fn reweighted_penalty_matches_in_graph_loss_shift() {
    // CE reported by the artifact excludes the penalty term, but the
    // penalty influences gradients: with a huge alpha the weights shrink.
    let Some(rt) = runtime() else { return };
    let model = zoo::proxy_cnn();
    let assigns: Vec<Assignment> = model
        .layers
        .iter()
        .map(|l| Assignment {
            scheme: if l.kind == prunemap::models::LayerKind::Fc {
                Scheme::StructuredRow
            } else {
                Scheme::BlockPunched { bf: 4, bc: 4 }
            },
            compression: 1.0,
        })
        .collect();
    // identical training with and without the penalty; the regularized run
    // must end with smaller weight norms (paper Eq. 1's lambda term)
    let run = |lam: f32| -> f32 {
        let mut d = TrainDriver::new(&rt, 11).unwrap();
        d.update_alphas(&assigns);
        let ds = SynthDataset::cifar_like(11);
        let mut rng = Rng::new(12);
        for _ in 0..12 {
            let (x, y) = ds.batch(d.batch_size(), &mut rng);
            d.step(&x, &y, 0.01, lam).unwrap();
            d.update_alphas(&assigns);
        }
        d.weights().iter().map(|w| w.sq_norm()).sum()
    };
    let with_penalty = run(0.02);
    let without = run(0.0);
    assert!(
        with_penalty < without,
        "reweighted penalty failed to shrink weights: {with_penalty} !< {without}"
    );
}

#[test]
fn short_pipeline_end_to_end() {
    let Some(rt) = runtime() else { return };
    let dev = DeviceProfile::s10();
    let model = zoo::proxy_cnn();
    let lat = LatencyModel::build(&dev);
    let assigns = map_rule_based(&model, &lat, &RuleConfig::default());
    let cfg = PipelineConfig {
        pretrain_steps: 40,
        reg_epochs: 2,
        steps_per_epoch: 10,
        retrain_steps: 30,
        ..Default::default()
    };
    let rep = run_pipeline(&rt, &model, &assigns, &dev, &cfg).unwrap();
    assert_eq!(
        rep.loss_curve.len(),
        cfg.pretrain_steps + cfg.reg_epochs * cfg.steps_per_epoch + cfg.retrain_steps
    );
    assert!(rep.overall_compression > 1.5, "{}", rep.overall_compression);
    assert!(rep.speedup() > 1.0);
    // learning happened
    let head: f32 = rep.loss_curve[..10].iter().sum::<f32>() / 10.0;
    let tail: f32 =
        rep.loss_curve[rep.loss_curve.len() - 10..].iter().sum::<f32>() / 10.0;
    assert!(tail < head, "loss {head} -> {tail}");
}

#[test]
fn forward_artifact_respects_masks() {
    let Some(rt) = runtime() else { return };
    let mut d = TrainDriver::new(&rt, 13).unwrap();
    let ds = SynthDataset::cifar_like(13);
    let mut rng = Rng::new(14);
    let (x, _) = ds.batch(d.batch_size(), &mut rng);
    let before = d.forward(&x).unwrap();
    // zero all masks -> logits collapse to biases (zeros)
    let zero_masks: Vec<_> = d
        .masks
        .iter()
        .map(|m| prunemap::tensor::Tensor::zeros(m.shape()))
        .collect();
    d.set_masks(zero_masks).unwrap();
    let after = d.forward(&x).unwrap();
    assert!(before.iter().any(|v| v.abs() > 1e-3));
    assert!(after.iter().all(|v| v.abs() < 1e-5), "masked forward non-zero");
}
