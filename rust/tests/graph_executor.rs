//! Graph-executor correctness: im2col-lowered convolution against naive
//! direct references on random shapes/masks (incl. stride 2 and depthwise),
//! whole-zoo-network determinism across thread counts and batch widths,
//! and fused-vs-unfused epilogue equivalence.

use prunemap::accuracy::Assignment;
use prunemap::compiler::{fuse, Graph, Op};
use prunemap::compiler::fusion::{FusedKernel, FusionPlan};
use prunemap::models::{zoo, Dataset, LayerKind, LayerSpec, ModelSpec};
use prunemap::pruning::Scheme;
use prunemap::runtime::graph::im2col::{direct_conv, direct_dwconv};
use prunemap::runtime::graph::{CompiledNet, GraphExecutor, NetWeights};
use prunemap::runtime::KernelChoice;
use prunemap::rng::Rng;
use prunemap::util::cli::env_threads;
use prunemap::util::prop::{dim, for_cases};

/// Build input -> single layer -> output (no BN/ReLU) so the executor's
/// output is directly comparable to a naive convolution.
fn single_layer_net(
    spec: &LayerSpec,
    scheme: Scheme,
    compression: f32,
    seed: u64,
) -> (CompiledNet, NetWeights) {
    let model = ModelSpec {
        name: "single".into(),
        dataset: Dataset::Synthetic,
        layers: vec![spec.clone()],
    };
    let assigns = vec![Assignment { scheme, compression }];
    let weights = NetWeights::synthesize(&model, &assigns, seed).unwrap();
    let mut g = Graph::default();
    let i = g.add(
        "in",
        Op::Input { shape: vec![1, spec.in_ch, spec.in_hw, spec.in_hw] },
        vec![],
    );
    let l = g.add(&spec.name, Op::Layer { layer: spec.clone() }, vec![i]);
    g.add("out", Op::Output, vec![l]);
    let plan = fuse(&g);
    let net = CompiledNet::lower(&g, &plan, &weights, KernelChoice::Auto, "single").unwrap();
    (net, weights)
}

fn rand_input(n: usize, rng: &mut Rng) -> Vec<f32> {
    (0..n).map(|_| rng.normal()).collect()
}

fn assert_close(got: &[f32], want: &[f32], tol: f32, ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            (g - w).abs() <= tol * (1.0 + w.abs()),
            "{ctx}: element {i}: got {g}, want {w}"
        );
    }
}

#[test]
fn conv_matches_direct_reference_on_random_shapes() {
    for_cases(10, 0xC0A1, |rng| {
        let c = dim(rng, 1, 5);
        let f = dim(rng, 1, 7);
        let hw = dim(rng, 4, 9);
        let k = if rng.bernoulli(0.7) { 3 } else { 1 };
        let stride = if rng.bernoulli(0.5) { 1 } else { 2 };
        let batch = dim(rng, 1, 3);
        let spec = LayerSpec::conv("c", k, c, f, hw, stride);
        let scheme = if rng.bernoulli(0.5) {
            Scheme::Unstructured
        } else {
            // block dims must tile the random weight dims (Scheme::applicable)
            let bf = if f % 2 == 0 { 2 } else { 1 };
            let bc = if c % 2 == 0 { 2 } else { 1 };
            Scheme::BlockPunched { bf, bc }
        };
        let seed = rng.next_u64();
        let (net, weights) = single_layer_net(&spec, scheme, 2.0, seed);
        let input = rand_input(batch * c * hw * hw, rng);
        let want = direct_conv(&input, batch, c, hw, hw, &weights.layers[0].weight, stride);
        for threads in [1usize, 4] {
            let got = GraphExecutor::new(threads).run(&net, &input, batch).unwrap();
            assert_close(
                &got,
                &want,
                1e-4,
                &format!("conv c={c} f={f} hw={hw} k={k} s={stride} b={batch} t={threads}"),
            );
        }
    });
}

#[test]
fn depthwise_matches_direct_reference() {
    for_cases(8, 0xD0A2, |rng| {
        let c = dim(rng, 1, 6);
        let hw = dim(rng, 4, 8);
        let stride = if rng.bernoulli(0.5) { 1 } else { 2 };
        let batch = dim(rng, 1, 3);
        let spec = LayerSpec::dwconv("dw", 3, c, hw, stride);
        let scheme = if rng.bernoulli(0.5) {
            Scheme::None
        } else {
            // bf must tile the random channel count (Scheme::applicable)
            Scheme::BlockPunched { bf: if c % 2 == 0 { 2 } else { 1 }, bc: 1 }
        };
        let seed = rng.next_u64();
        let (net, weights) = single_layer_net(&spec, scheme, 1.5, seed);
        let input = rand_input(batch * c * hw * hw, rng);
        let want = direct_dwconv(&input, batch, c, hw, hw, &weights.layers[0].weight, stride);
        for threads in [1usize, 4] {
            let got = GraphExecutor::new(threads).run(&net, &input, batch).unwrap();
            assert_close(
                &got,
                &want,
                1e-4,
                &format!("dw c={c} hw={hw} s={stride} b={batch} t={threads}"),
            );
        }
    });
}

#[test]
fn stride2_odd_input_pins_same_padding() {
    // 7x7 input, 3x3 stride-2: out 4x4, leading pad 1 — pinned against the
    // naive reference so the SAME convention can never silently drift
    let spec = LayerSpec::conv("c", 3, 2, 3, 7, 2);
    let (net, weights) = single_layer_net(&spec, Scheme::Unstructured, 2.0, 99);
    let mut rng = Rng::new(100);
    let input = rand_input(2 * 2 * 7 * 7, &mut rng);
    let want = direct_conv(&input, 2, 2, 7, 7, &weights.layers[0].weight, 2);
    let got = GraphExecutor::serial().run(&net, &input, 2).unwrap();
    assert_eq!(got.len(), 2 * 3 * 4 * 4);
    assert_close(&got, &want, 1e-4, "stride2 odd");
}

fn zoo_assigns(model: &ModelSpec) -> Vec<Assignment> {
    model
        .layers
        .iter()
        .map(|l| match l.kind {
            LayerKind::Conv if l.is_3x3_conv() => {
                Assignment { scheme: Scheme::Pattern, compression: 2.25 }
            }
            LayerKind::Conv => {
                Assignment { scheme: Scheme::BlockPunched { bf: 4, bc: 4 }, compression: 3.0 }
            }
            LayerKind::DepthwiseConv => Assignment::dense(),
            LayerKind::Fc => {
                Assignment { scheme: Scheme::Block { bp: 8, bq: 2 }, compression: 2.0 }
            }
        })
        .collect()
}

#[test]
fn zoo_cnn_is_bit_for_bit_deterministic_across_threads_and_batches() {
    // the acceptance case: a zoo CNN end to end through GraphExecutor
    let model = zoo::mobilenet_v1_scaled(Dataset::Cifar10, 0.25);
    let assigns = zoo_assigns(&model);
    let net = CompiledNet::compile(&model, &assigns, 1234, KernelChoice::Auto).unwrap();
    let (c, h, w) = net.input_shape;
    assert_eq!((c, h, w), (3, 32, 32));

    let mut rng = Rng::new(7);
    let sample: Vec<f32> = rand_input(c * h * w, &mut rng);
    let out1 = GraphExecutor::serial().run(&net, &sample, 1).unwrap();
    assert_eq!(out1.len(), 10, "CIFAR-10 logits");
    assert!(out1.iter().all(|v| v.is_finite()));

    // 1 vs N threads: identical bits
    for threads in [2usize, 4, 8] {
        let out_t = GraphExecutor::new(threads).run(&net, &sample, 1).unwrap();
        assert_eq!(out1, out_t, "threads={threads}");
    }

    // batch widths: sample 0 of a batch-3 run == the batch-1 run, and a
    // repeated sample produces identical rows
    let mut batch3 = sample.clone();
    let other: Vec<f32> = rand_input(2 * c * h * w, &mut rng);
    batch3.extend_from_slice(&other);
    let out3 = GraphExecutor::new(4).run(&net, &batch3, 3).unwrap();
    assert_eq!(out3.len(), 30);
    assert_eq!(&out3[..10], &out1[..], "sample 0 must not depend on batch width");

    let mut twice = sample.clone();
    twice.extend_from_slice(&sample);
    let out2 = GraphExecutor::new(4).run(&net, &twice, 2).unwrap();
    assert_eq!(&out2[..10], &out2[10..], "identical samples, identical logits");
}

#[test]
fn fused_epilogues_match_standalone_passes_bit_for_bit() {
    let model = zoo::proxy_cnn();
    let assigns = zoo_assigns(&model);
    let weights = NetWeights::synthesize(&model, &assigns, 77).unwrap();
    let g = Graph::from_model(&model);

    let fused_plan = fuse(&g);
    let unfused_plan = FusionPlan {
        kernels: g
            .nodes
            .iter()
            .filter(|n| !matches!(n.op, Op::Input { .. } | Op::Output))
            .map(|n| FusedKernel { anchor: n.id, epilogue: vec![] })
            .collect(),
    };
    assert!(unfused_plan.kernel_count() > fused_plan.kernel_count());

    let fused =
        CompiledNet::lower(&g, &fused_plan, &weights, KernelChoice::Auto, "fused").unwrap();
    let unfused =
        CompiledNet::lower(&g, &unfused_plan, &weights, KernelChoice::Auto, "unfused").unwrap();
    assert!(fused.steps.len() < unfused.steps.len());

    let mut rng = Rng::new(8);
    let batch = 2;
    let input = rand_input(batch * 3 * 32 * 32, &mut rng);
    let a = GraphExecutor::new(3).run(&fused, &input, batch).unwrap();
    let b = GraphExecutor::new(3).run(&unfused, &input, batch).unwrap();
    assert_eq!(a, b, "fusion must not change results");
}

#[test]
fn residual_add_fuses_and_matches_standalone() {
    // input -> convA -> convB -> add(convB, convA): convB single-consumer,
    // so the add fuses into convB's kernel
    let spec_a = LayerSpec::conv("convA", 3, 2, 4, 6, 1);
    let spec_b = LayerSpec::conv("convB", 3, 4, 4, 6, 1);
    let model = ModelSpec {
        name: "res".into(),
        dataset: Dataset::Synthetic,
        layers: vec![spec_a.clone(), spec_b.clone()],
    };
    let assigns = vec![
        Assignment { scheme: Scheme::Unstructured, compression: 1.5 },
        Assignment { scheme: Scheme::Unstructured, compression: 1.5 },
    ];
    let weights = NetWeights::synthesize(&model, &assigns, 5).unwrap();

    let mut g = Graph::default();
    let i = g.add("in", Op::Input { shape: vec![1, 2, 6, 6] }, vec![]);
    let a = g.add("convA", Op::Layer { layer: spec_a }, vec![i]);
    let b = g.add("convB", Op::Layer { layer: spec_b }, vec![a]);
    let add = g.add("res_add", Op::Add, vec![b, a]);
    g.add("out", Op::Output, vec![add]);

    let plan = fuse(&g);
    assert!(plan.is_fused_away(add), "add should fuse into convB");
    let fused = CompiledNet::lower(&g, &plan, &weights, KernelChoice::Auto, "res").unwrap();

    let unfused_plan = FusionPlan {
        kernels: vec![
            FusedKernel { anchor: a, epilogue: vec![] },
            FusedKernel { anchor: b, epilogue: vec![] },
            FusedKernel { anchor: add, epilogue: vec![] },
        ],
    };
    let unfused =
        CompiledNet::lower(&g, &unfused_plan, &weights, KernelChoice::Auto, "res_u").unwrap();

    let mut rng = Rng::new(6);
    let input = rand_input(2 * 6 * 6, &mut rng);
    let ya = GraphExecutor::new(2).run(&fused, &input, 1).unwrap();
    let yb = GraphExecutor::serial().run(&unfused, &input, 1).unwrap();
    assert_eq!(ya, yb);
    assert_eq!(ya.len(), 4 * 6 * 6);
}

#[test]
fn fused_im2col_matches_materialized_on_a_zoo_cnn() {
    // whole-network acceptance for the fused rewrite: fused tile-order
    // im2col == materialized X, bit for bit, across thread counts, tile
    // widths, and batch widths that are not lane multiples
    let model = zoo::mobilenet_v1_scaled(Dataset::Cifar10, 0.25);
    let assigns = zoo_assigns(&model);
    let net = CompiledNet::compile(&model, &assigns, 555, KernelChoice::Auto).unwrap();
    let (c, h, w) = net.input_shape;
    let mut rng = Rng::new(556);
    for batch in [1usize, 3] {
        let input = rand_input(batch * c * h * w, &mut rng);
        let want = GraphExecutor::serial().materialized().run(&net, &input, batch).unwrap();
        for threads in [1usize, env_threads(4)] {
            for tile in [8usize, 64, 256] {
                let exec = GraphExecutor::new(threads).with_tile_cols(tile);
                let got = exec.run(&net, &input, batch).unwrap();
                assert_eq!(got, want, "batch={batch} threads={threads} tile={tile}");
            }
        }
    }
}

#[test]
fn vgg_style_glue_flattens_and_pools() {
    // proxy CNN shrinks 32 -> 16 -> 8 between conv stages and flattens
    // 64x4x4 into fc1 — the executor must insert the implicit glue
    let model = zoo::proxy_cnn();
    let assigns = zoo_assigns(&model);
    let net = CompiledNet::compile(&model, &assigns, 21, KernelChoice::Auto).unwrap();
    let mut rng = Rng::new(22);
    let input = rand_input(3 * 32 * 32, &mut rng);
    let y = GraphExecutor::new(2).run(&net, &input, 1).unwrap();
    assert_eq!(y.len(), 10);
    let y2 = GraphExecutor::serial().run(&net, &input, 1).unwrap();
    assert_eq!(y, y2);
}
