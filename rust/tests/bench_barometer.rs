//! Acceptance suite for the benchmark barometer (`prunemap bench`):
//!
//! * the harness runs a definition file end to end in its default
//!   child-process-per-measurement mode, prints normalized `RECORD`
//!   lines, and writes a loadable `--json-out` record set;
//! * `--update-checksums` pins observed output checksums into the
//!   definition file, after which `--check --strict` passes — and a
//!   corrupted pin makes `--check` fail loudly (every benchmark is also
//!   a correctness test);
//! * `bench cmp` exits zero on a clean pair, nonzero on an injected
//!   regression beyond the noise threshold, and zero again under
//!   `--report-only`;
//! * `bench rank` orders engine variants of one workload.
//!
//! Reporter classification details (win / regression / within-noise /
//! one-sided / drift) are unit-tested in `src/bench/cmp.rs`; this suite
//! drives the real binary.

use std::path::PathBuf;
use std::process::{Command, Output};

use prunemap::bench::RecordSet;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_prunemap"))
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("prunemap_barometer_{}_{name}", std::process::id()))
}

fn stdout(out: &Output) -> String {
    String::from_utf8_lossy(&out.stdout).into_owned()
}

fn stderr(out: &Output) -> String {
    String::from_utf8_lossy(&out.stderr).into_owned()
}

/// A two-variant spmm workload, small enough for debug-mode children.
const TINY_DEFS: &str = r#"{
  "format": "prunemap.benchdefs.v1",
  "benchmarks": [
    {"name": "it/spmm64/b4", "engine": "scalar", "kind": "spmm",
     "rows": 64, "cols": 64, "scheme": "block4x4", "compression": 4.0,
     "batch": 4, "threads": 1, "seed": 1, "warmup": 1, "samples": 2,
     "checksum": null},
    {"name": "it/spmm64/b4", "engine": "simd", "kind": "spmm",
     "rows": 64, "cols": 64, "scheme": "block4x4", "compression": 4.0,
     "batch": 4, "threads": 1, "seed": 1, "warmup": 1, "samples": 2,
     "checksum": null}
  ]
}"#;

#[test]
fn harness_runs_defs_in_child_processes_and_writes_records() {
    let defs = tmp("run_defs.json");
    let out_path = tmp("run_records.json");
    std::fs::write(&defs, TINY_DEFS).unwrap();
    let out = bin()
        .arg("bench")
        .arg("--defs")
        .arg(&defs)
        .arg("--json-out")
        .arg(&out_path)
        .output()
        .expect("run prunemap bench");
    assert!(out.status.success(), "bench run failed:\n{}{}", stdout(&out), stderr(&out));
    let text = stdout(&out);
    let record_lines: Vec<&str> =
        text.lines().filter(|l| l.starts_with("RECORD ")).collect();
    assert_eq!(record_lines.len(), 2, "one RECORD line per definition:\n{text}");

    let set = RecordSet::load(&out_path).expect("load --json-out records");
    assert_eq!(set.records.len(), 2);
    let scalar = set.find("it/spmm64/b4::scalar").expect("scalar record");
    let simd = set.find("it/spmm64/b4::simd").expect("simd record");
    assert!(scalar.mean_ns > 0.0 && simd.mean_ns > 0.0);
    assert_eq!(scalar.iters, 2);
    assert_eq!(
        scalar.checksum, simd.checksum,
        "engine variants of one workload must be bit-identical"
    );
    assert_eq!(scalar.checksum.len(), 16);
    let _ = std::fs::remove_file(&defs);
    let _ = std::fs::remove_file(&out_path);
}

#[test]
fn check_pins_verifies_and_fails_on_a_corrupted_pin() {
    let defs = tmp("check_defs.json");
    std::fs::write(&defs, TINY_DEFS).unwrap();

    // strict check over unpinned definitions fails (nothing to verify)
    let unpinned = bin()
        .args(["bench", "--defs"])
        .arg(&defs)
        .args(["--check", "--strict"])
        .output()
        .unwrap();
    assert!(!unpinned.status.success(), "--strict must fail on unpinned defs");

    // pin the observed checksums into the file
    let pin = bin()
        .args(["bench", "--defs"])
        .arg(&defs)
        .arg("--update-checksums")
        .output()
        .unwrap();
    assert!(pin.status.success(), "pinning failed:\n{}{}", stdout(&pin), stderr(&pin));
    assert!(stdout(&pin).contains("pinned it/spmm64/b4::scalar"), "{}", stdout(&pin));
    let pinned_text = std::fs::read_to_string(&defs).unwrap();
    assert!(!pinned_text.contains("null"), "checksums pinned in-place:\n{pinned_text}");

    // now a strict check passes
    let check = bin()
        .args(["bench", "--defs"])
        .arg(&defs)
        .args(["--check", "--strict"])
        .output()
        .unwrap();
    assert!(check.status.success(), "check failed:\n{}{}", stdout(&check), stderr(&check));
    assert!(stdout(&check).contains("2 checked, 0 mismatched, 0 unpinned"));

    // corrupt the pins -> the checksum test fails loudly
    let scalar_sum = RecordSetProbe::checksum_in(&pinned_text);
    let corrupted = pinned_text.replace(&scalar_sum, "0000000000000000");
    std::fs::write(&defs, corrupted).unwrap();
    let bad = bin().args(["bench", "--defs"]).arg(&defs).arg("--check").output().unwrap();
    assert!(!bad.status.success(), "a wrong pin must fail --check");
    assert!(stdout(&bad).contains("MISMATCH"), "{}", stdout(&bad));
    let _ = std::fs::remove_file(&defs);
}

/// Pull the pinned 16-hex-digit checksum out of a definition file.
struct RecordSetProbe;
impl RecordSetProbe {
    fn checksum_in(text: &str) -> String {
        text.split("\"checksum\": \"")
            .nth(1)
            .and_then(|rest| rest.split('"').next())
            .expect("a pinned checksum in the defs file")
            .to_string()
    }
}

fn record(name: &str, engine: &str, mean: f64, checksum: &str) -> String {
    format!(
        r#"{{"name": "{name}", "engine": "{engine}", "config": null, "iters": 5,
            "mean_ns": {mean}, "stddev_ns": 1.0, "min_ns": {mean},
            "checksum": "{checksum}", "rev": "test"}}"#
    )
}

fn record_set(records: &[String]) -> String {
    format!(
        r#"{{"format": "prunemap.benchrecords.v1", "records": [{}]}}"#,
        records.join(",")
    )
}

#[test]
fn cmp_exits_nonzero_on_regression_and_zero_in_report_only() {
    let base_path = tmp("cmp_base.json");
    let cont_path = tmp("cmp_cont.json");
    std::fs::write(
        &base_path,
        record_set(&[record("a", "simd", 1000.0, "c1"), record("b", "simd", 1000.0, "c2")]),
    )
    .unwrap();

    // clean pair: a 2x win and a within-noise wobble -> exit 0
    std::fs::write(
        &cont_path,
        record_set(&[record("a", "simd", 500.0, "c1"), record("b", "simd", 1050.0, "c2")]),
    )
    .unwrap();
    let clean = bin().args(["bench", "cmp"]).arg(&base_path).arg(&cont_path).output().unwrap();
    assert!(clean.status.success(), "clean cmp failed:\n{}{}", stdout(&clean), stderr(&clean));
    assert!(stdout(&clean).contains("2.00x"), "{}", stdout(&clean));
    assert!(stdout(&clean).contains("0 regressed"), "{}", stdout(&clean));

    // injected regression beyond the 10% noise threshold -> nonzero exit
    std::fs::write(
        &cont_path,
        record_set(&[record("a", "simd", 1300.0, "c1"), record("b", "simd", 1000.0, "c2")]),
    )
    .unwrap();
    let reg = bin().args(["bench", "cmp"]).arg(&base_path).arg(&cont_path).output().unwrap();
    assert!(!reg.status.success(), "a regression must exit nonzero:\n{}", stdout(&reg));
    assert!(stdout(&reg).contains("REGRESSED"), "{}", stdout(&reg));

    // same pair in report-only mode -> exit 0, regression still printed
    let report = bin()
        .args(["bench", "cmp"])
        .arg(&base_path)
        .arg(&cont_path)
        .arg("--report-only")
        .output()
        .unwrap();
    assert!(report.status.success(), "--report-only must never fail the build");
    assert!(stdout(&report).contains("REGRESSED"), "{}", stdout(&report));

    // a wider threshold waves the same slowdown through
    let wide = bin()
        .args(["bench", "cmp"])
        .arg(&base_path)
        .arg(&cont_path)
        .args(["--threshold", "0.5"])
        .output()
        .unwrap();
    assert!(wide.status.success(), "30% slower is within a 50% threshold");
    let _ = std::fs::remove_file(&base_path);
    let _ = std::fs::remove_file(&cont_path);
}

#[test]
fn rank_orders_engine_variants_within_one_record_set() {
    let path = tmp("rank.json");
    std::fs::write(
        &path,
        record_set(&[
            record("w", "scalar", 4000.0, "c"),
            record("w", "simd", 1000.0, "c"),
        ]),
    )
    .unwrap();
    let out = bin().args(["bench", "rank"]).arg(&path).output().unwrap();
    assert!(out.status.success(), "rank failed:\n{}{}", stdout(&out), stderr(&out));
    let text = stdout(&out);
    let simd = text.find("simd").expect("simd row");
    let scalar = text.find("scalar").expect("scalar row");
    assert!(simd < scalar, "fastest variant first:\n{text}");
    assert!(text.contains("4.00x"), "{text}");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn checked_in_baseline_records_load_and_pair_with_defs() {
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let set = RecordSet::load(manifest.join("benches/records/baseline.json"))
        .expect("checked-in baseline must parse");
    assert!(set.records.len() >= 10);
    let defs = prunemap::bench::load_defs(manifest.join("benches/defs"))
        .expect("checked-in defs must parse");
    for def in &defs {
        assert!(
            set.find(&def.id()).is_some(),
            "definition '{}' has no baseline record to cmp against",
            def.id()
        );
    }
}
