//! Acceptance suite for the compile-once/serve-many session API
//! (`prunemap::serve`):
//!
//! * concurrent `submit` from many threads returns outputs **bit-identical**
//!   to serial `Session::infer` (and to a solo low-level `GraphExecutor`
//!   run) at every thread/tile/fused combination;
//! * the micro-batcher coalesces to **lane-aligned** batch sizes —
//!   observable via `SessionStats` — and never exceeds the max-batch cap;
//! * a `PreparedModel` save -> load -> infer round trip reproduces
//!   identical logits.

use std::time::Duration;

use prunemap::accuracy::Assignment;
use prunemap::models::zoo;
use prunemap::pruning::Scheme;
use prunemap::runtime::GraphExecutor;
use prunemap::serve::{PreparedModel, Session, Ticket};
use prunemap::sparse::LANE;
use prunemap::util::cli::env_threads;

/// A small pruned proxy artifact (explicit assignments: no latency-model
/// build on the test path).
fn prepared_proxy(seed: u64) -> PreparedModel {
    let model = zoo::proxy_cnn();
    let assigns: Vec<Assignment> = model
        .layers
        .iter()
        .map(|l| {
            if l.is_3x3_conv() {
                Assignment { scheme: Scheme::BlockPunched { bf: 4, bc: 4 }, compression: 2.5 }
            } else {
                Assignment { scheme: Scheme::Block { bp: 8, bq: 2 }, compression: 2.0 }
            }
        })
        .collect();
    PreparedModel::builder()
        .model("proxy")
        .assignments(assigns)
        .seed(seed)
        .build()
        .expect("prepare proxy")
}

fn sample_input(len: usize, tag: usize) -> Vec<f32> {
    (0..len).map(|j| (((tag * 7 + j) % 23) as f32) * 0.1 - 1.0).collect()
}

#[test]
fn concurrent_submits_match_serial_infer_everywhere() {
    let prepared = prepared_proxy(42);
    let len = prepared.input_len();
    let nreq = 12usize;
    // anchor: the low-level executor running each sample alone
    let solo: Vec<Vec<f32>> = (0..nreq)
        .map(|tag| {
            GraphExecutor::serial()
                .run(prepared.net(), &sample_input(len, tag), 1)
                .unwrap()
        })
        .collect();
    for threads in [1usize, env_threads(4)] {
        for tile in [8usize, 256] {
            for fused in [true, false] {
                let session = Session::builder(prepared.clone())
                    .threads(threads)
                    .tile_cols(tile)
                    .fused(fused)
                    .max_batch(16)
                    .max_wait(Duration::from_millis(5))
                    .build();
                // serial: one request per infer call
                let serial: Vec<Vec<f32>> = (0..nreq)
                    .map(|tag| session.infer(sample_input(len, tag)).unwrap())
                    .collect();
                assert_eq!(
                    serial, solo,
                    "serial infer vs solo executor (threads={threads} tile={tile} fused={fused})"
                );
                // concurrent: every request from its own thread
                let concurrent: Vec<Vec<f32>> = std::thread::scope(|scope| {
                    let handles: Vec<_> = (0..nreq)
                        .map(|tag| {
                            let session = &session;
                            scope.spawn(move || session.infer(sample_input(len, tag)).unwrap())
                        })
                        .collect();
                    handles.into_iter().map(|h| h.join().unwrap()).collect()
                });
                assert_eq!(
                    concurrent, serial,
                    "concurrent submit vs serial (threads={threads} tile={tile} fused={fused})"
                );
            }
        }
    }
}

#[test]
fn micro_batcher_coalesces_lane_aligned_and_respects_max_batch() {
    let prepared = prepared_proxy(7);
    let len = prepared.input_len();
    let session = Session::builder(prepared)
        .threads(env_threads(2))
        .max_batch(16)
        .max_wait(Duration::from_secs(2))
        .build();
    assert_eq!(session.max_batch(), 16);

    // phase 1: exactly max-batch requests submitted up front -> the
    // batcher waits for a full batch and serves all 16 in one run
    // (inputs pre-built so the submission burst is as tight as possible)
    let inputs: Vec<Vec<f32>> = (0..16).map(|tag| sample_input(len, tag)).collect();
    let tickets: Vec<Ticket> = inputs.into_iter().map(|i| session.submit(i).unwrap()).collect();
    for t in tickets {
        t.wait().unwrap();
    }
    let st = session.stats();
    assert_eq!(st.requests, 16);
    assert_eq!(st.runs, 1, "a full batch must coalesce into one run: {st:?}");
    assert_eq!(st.max_coalesced, 16);
    assert_eq!(st.padded_lanes, 0);
    assert_eq!(st.batch_runs.get(&16), Some(&1));

    // phase 2: a burst larger than max-batch never exceeds the cap, and
    // every executed batch stays lane-aligned
    let tickets: Vec<Ticket> =
        (0..48).map(|tag| session.submit(sample_input(len, tag)).unwrap()).collect();
    for t in tickets {
        t.wait().unwrap();
    }
    let st = session.stats();
    assert_eq!(st.requests, 64);
    assert!(st.runs >= 4, "48 extra requests at cap 16 need >= 3 more runs: {st:?}");
    let mut accounted = 0usize;
    for (&batch, &runs) in &st.batch_runs {
        assert_eq!(batch % LANE, 0, "executed batch {batch} is not lane-aligned");
        assert!(batch <= session.max_batch(), "batch {batch} exceeds the cap");
        accounted += batch * runs;
    }
    assert_eq!(
        accounted,
        st.requests + st.padded_lanes,
        "stats must account for every executed lane: {st:?}"
    );
}

#[test]
fn under_full_batches_are_padded_to_the_lane() {
    let prepared = prepared_proxy(9);
    let len = prepared.input_len();
    let session = Session::builder(prepared)
        .threads(1)
        .max_batch(32)
        .max_wait(Duration::from_millis(20))
        .build();
    // 5 requests can never fill a lane-aligned batch exactly, however the
    // batcher splits them
    let tickets: Vec<Ticket> =
        (0..5).map(|tag| session.submit(sample_input(len, tag)).unwrap()).collect();
    for t in tickets {
        t.wait().unwrap();
    }
    let st = session.stats();
    assert_eq!(st.requests, 5);
    assert!(st.padded_lanes > 0, "5 requests require padding: {st:?}");
    for &batch in st.batch_runs.keys() {
        assert_eq!(batch % LANE, 0, "executed batch {batch} is not lane-aligned");
    }
}

#[test]
fn save_load_roundtrips_to_identical_logits() {
    let prepared = prepared_proxy(0xFEED_5EED_0123_4567);
    let path = std::env::temp_dir().join(format!(
        "prunemap_prepared_{}_{:?}.json",
        std::process::id(),
        std::thread::current().id()
    ));
    prepared.save(&path).unwrap();
    let loaded = PreparedModel::load(&path).unwrap();
    let _ = std::fs::remove_file(&path);

    assert_eq!(loaded.seed(), prepared.seed());
    assert_eq!(loaded.model().layers, prepared.model().layers);
    let len = prepared.input_len();
    // low-level parity: identical logits from the recompiled artifact
    let exec = GraphExecutor::serial();
    for tag in 0..4 {
        let input = sample_input(len, tag);
        let a = exec.run(prepared.net(), &input, 1).unwrap();
        let b = exec.run(loaded.net(), &input, 1).unwrap();
        assert_eq!(a, b, "request {tag}");
    }
    // serving parity: a session over the loaded artifact answers
    // identically too
    let sa = Session::builder(prepared).threads(env_threads(2)).build();
    let sb = Session::builder(loaded).threads(env_threads(2)).build();
    for tag in 0..4 {
        assert_eq!(
            sa.infer(sample_input(len, tag)).unwrap(),
            sb.infer(sample_input(len, tag)).unwrap(),
            "request {tag}"
        );
    }
}

#[test]
fn load_rejects_malformed_artifacts() {
    let dir = std::env::temp_dir();
    let missing = dir.join("prunemap_no_such_artifact.json");
    assert!(PreparedModel::load(&missing).is_err());
    let garbage = dir.join(format!("prunemap_garbage_{}.json", std::process::id()));
    std::fs::write(&garbage, "{\"format\": \"wrong\"").unwrap();
    assert!(PreparedModel::load(&garbage).is_err());
    std::fs::write(&garbage, "{\"format\": \"wrong\"}").unwrap();
    assert!(PreparedModel::load(&garbage).is_err());
    let _ = std::fs::remove_file(&garbage);
}

#[test]
fn submit_rejects_wrong_sample_length() {
    let prepared = prepared_proxy(3);
    let session = Session::builder(prepared.clone()).threads(1).build();
    assert!(session.submit(vec![0.0; 7]).is_err());
    assert!(session.submit(Vec::new()).is_err());
    // and a well-formed request still succeeds afterwards
    let y = session.infer(vec![0.5; prepared.input_len()]).unwrap();
    assert_eq!(y.len(), prepared.output_len());
}
