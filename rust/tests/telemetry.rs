//! Acceptance suite for the telemetry layer
//! (`prunemap::telemetry::{metrics, export, trace}`):
//!
//! * the metrics endpoint serves a valid Prometheus text exposition
//!   document over live TCP covering every per-model and wire-layer
//!   family the exporter promises ([`MODEL_FAMILIES`] /
//!   [`WIRE_FAMILIES`]);
//! * in-band `stats` / `metrics` admin frames on the wire protocol
//!   return the same counters over the same connection the inference
//!   frames ride;
//! * overload sheds (session-layer `max_queue` and wire-layer pool
//!   sheds) surface as nonzero counters in the exposition document;
//! * a traced server records queue/batch/run/op spans that dump as
//!   loadable Chrome trace-event JSON;
//! * `prunemap profile` (the real binary) emits the per-layer time
//!   table, a reparseable calibration record, and a trace dump.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use prunemap::accuracy::Assignment;
use prunemap::models::zoo;
use prunemap::serve::{wire, InferRequest, ModelRegistry, PreparedModel, ServeError, Server};
use prunemap::telemetry::{
    self, parse_exposition, TraceRing, MODEL_FAMILIES, WIRE_FAMILIES,
};
use prunemap::util::cli::env_threads;
use prunemap::util::json::Value;

/// The proxy CNN sealed dense — the cheapest real artifact for
/// debug-mode test runs.
fn proxy_registry() -> ModelRegistry {
    let spec = zoo::proxy_cnn();
    let assigns: Vec<Assignment> = spec.layers.iter().map(|_| Assignment::dense()).collect();
    let prepared = PreparedModel::builder()
        .model_spec(spec)
        .assignments(assigns)
        .seed(9)
        .build()
        .expect("prepare proxy model");
    let registry = ModelRegistry::new();
    registry.insert("proxy", prepared);
    registry
}

fn sample(len: usize, tag: usize) -> Vec<f32> {
    (0..len).map(|j| (((tag * 7 + j) % 13) as f32) * 0.2 - 1.1).collect()
}

#[test]
fn metrics_endpoint_serves_prometheus_text_over_live_tcp() {
    let registry = proxy_registry();
    let server = Arc::new(Server::builder(registry.clone()).threads(env_threads(1)).build());
    let n = registry.get("proxy").unwrap().input_len();
    // traffic first so every per-model family has samples to scrape:
    // one normal-lane and one high-lane request
    server.infer(InferRequest::new("proxy", sample(n, 0))).unwrap();
    server.infer(InferRequest::new("proxy", sample(n, 1)).high()).unwrap();

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let exporter = {
        let server = Arc::clone(&server);
        std::thread::spawn(move || {
            telemetry::serve_text(listener, Some(1), move || server.metrics_text())
        })
    };
    let mut sock = TcpStream::connect(addr).unwrap();
    write!(sock, "GET /metrics HTTP/1.1\r\nHost: test\r\nConnection: close\r\n\r\n").unwrap();
    let mut response = String::new();
    sock.read_to_string(&mut response).unwrap();
    exporter.join().expect("exporter thread").expect("scrape loop");

    let (head, body) = response.split_once("\r\n\r\n").expect("an HTTP head/body split");
    assert!(head.starts_with("HTTP/1.1 200 OK"), "{head}");
    assert!(head.contains("text/plain; version=0.0.4"), "{head}");

    let families = parse_exposition(body).expect("scrape body must parse as exposition text");
    for name in MODEL_FAMILIES.iter().chain(WIRE_FAMILIES.iter()) {
        assert!(families.contains_key(*name), "family '{name}' missing from scrape:\n{body}");
    }

    // the request counter splits by priority lane under the model label
    let requests = &families["prunemap_requests_total"];
    for lane in ["high", "normal"] {
        let s = requests
            .samples
            .iter()
            .find(|s| s.label("model") == Some("proxy") && s.label("priority") == Some(lane))
            .unwrap_or_else(|| panic!("no {lane}-lane sample:\n{body}"));
        assert_eq!(s.value, 1.0, "{lane} lane served exactly one request");
    }
    // the wait histogram is cumulative: the +Inf bucket and _count both
    // account for every request
    let wait = &families["prunemap_queue_wait_seconds"];
    assert_eq!(wait.kind, "histogram");
    let inf = wait
        .samples
        .iter()
        .find(|s| s.name.ends_with("_bucket") && s.label("le") == Some("+Inf"))
        .expect("+Inf bucket");
    assert_eq!(inf.value, 2.0);
    let count =
        wait.samples.iter().find(|s| s.name.ends_with("_count")).expect("_count sample");
    assert_eq!(count.value, 2.0);
}

#[test]
fn wire_admin_frames_fetch_stats_and_metrics_over_tcp() {
    let registry = proxy_registry();
    let server = Arc::new(Server::builder(registry.clone()).threads(env_threads(1)).build());
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let acceptor = {
        let server = Arc::clone(&server);
        std::thread::spawn(move || wire::serve_tcp(&server, listener, Some(1), 4))
    };
    let n = registry.get("proxy").unwrap().input_len();
    let mut client = wire::Client::connect(addr).unwrap();
    let y = client.infer(&InferRequest::new("proxy", sample(n, 2))).unwrap().unwrap();
    assert!(!y.is_empty());

    // the stats frame carries the same SessionStats JSON Server::stats
    // snapshots in-process
    let stats = client.stats().unwrap();
    let proxy = stats.get("proxy").expect("per-model stats object");
    assert_eq!(proxy.get("requests").unwrap().as_u64().unwrap(), 1);
    assert_eq!(proxy.get("runs").unwrap().as_u64().unwrap(), 1);

    // the metrics frame carries the same exposition document the HTTP
    // endpoint serves — and it sees this very connection's counters
    let text = client.metrics_text().unwrap();
    let families = parse_exposition(&text).expect("wire metrics frame must parse");
    for name in WIRE_FAMILIES {
        assert!(families.contains_key(name), "family '{name}' missing:\n{text}");
    }
    assert_eq!(families["prunemap_wire_served_frames_total"].samples[0].value, 1.0);
    assert_eq!(families["prunemap_wire_active_connections"].samples[0].value, 1.0);

    drop(client);
    acceptor.join().expect("acceptor thread").unwrap();
    let snap = server.wire_counters().snapshot();
    assert_eq!(snap.connections, 1);
    assert_eq!(snap.active, 0, "closed connection must release the active gauge");
    assert_eq!(snap.frames, 3, "one infer + two admin frames");
    assert_eq!(snap.served, 1);
    assert_eq!(snap.admin, 2);
    assert_eq!(snap.malformed, 0);
}

#[test]
fn overload_sheds_surface_in_the_prometheus_exposition() {
    let registry = proxy_registry();
    let server = Server::builder(registry.clone())
        .threads(1)
        .max_batch(8)
        .max_wait(Duration::from_secs(30))
        .max_queue(2)
        .build();
    let n = registry.get("proxy").unwrap().input_len();
    // two admitted requests park in the long hold window at the queue's
    // high-water mark; the third is shed with a typed overloaded error
    let parked: Vec<_> = (0..2)
        .map(|tag| server.submit(InferRequest::new("proxy", sample(n, tag))).unwrap())
        .collect();
    let shed = server.submit(InferRequest::new("proxy", sample(n, 2))).map(|_| ());
    assert!(
        matches!(shed, Err(ServeError::Overloaded { retry_after_ms }) if retry_after_ms >= 1),
        "the third submit must shed with a retry-after budget, got {shed:?}"
    );
    // exercise the wire-layer shed path's counters the way serve_tcp does
    let wire_counters = server.wire_counters();
    wire_counters.shed_conns.fetch_add(1, Ordering::Relaxed);
    wire_counters.record_error("overloaded");

    let text = server.metrics_text();
    let families = parse_exposition(&text).expect("exposition with sheds must parse");
    let model_shed = families["prunemap_shed_overload_total"]
        .samples
        .iter()
        .find(|s| s.label("model") == Some("proxy"))
        .unwrap_or_else(|| panic!("no per-model shed sample:\n{text}"));
    assert_eq!(model_shed.value, 1.0, "one session-layer shed");
    assert_eq!(families["prunemap_wire_shed_total"].samples[0].value, 1.0);
    let overloaded_kind = families["prunemap_wire_error_frames_total"]
        .samples
        .iter()
        .find(|s| s.label("kind") == Some("overloaded"))
        .unwrap_or_else(|| panic!("no overloaded error-kind sample:\n{text}"));
    assert_eq!(overloaded_kind.value, 1.0);
    // the parked requests were admitted, not lost: close drains them
    drop(server);
    for t in parked {
        assert!(t.wait().is_ok(), "admitted requests must drain on close");
    }
}

#[test]
fn traced_server_emits_loadable_chrome_trace_json() {
    let registry = proxy_registry();
    let ring = TraceRing::new(4096);
    let server = Server::builder(registry.clone())
        .threads(env_threads(1))
        .trace(Arc::clone(&ring))
        .build();
    let n = registry.get("proxy").unwrap().input_len();
    for tag in 0..3 {
        server.infer(InferRequest::new("proxy", sample(n, tag))).unwrap();
    }
    let spans = ring.snapshot();
    assert!(!spans.is_empty(), "a traced server must record spans");
    assert_eq!(ring.dropped(), 0, "4096 slots must hold three proxy runs");

    let text = telemetry::chrome_trace_json(&spans).pretty();
    let doc = Value::parse(&text).expect("chrome trace output must reparse");
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(
        events.len() >= spans.len(),
        "queue spans expand to b/e pairs, everything else maps 1:1"
    );
    for ev in events {
        let ph = ev.get("ph").unwrap().as_str().unwrap();
        assert!(matches!(ph, "X" | "b" | "e"), "unexpected phase '{ph}'");
        assert!(ev.get("ts").unwrap().as_f64().is_ok(), "every event carries a timestamp");
    }
    assert_eq!(doc.get("displayTimeUnit").unwrap().as_str().unwrap(), "ms");
}

#[test]
fn profile_subcommand_writes_calibration_and_trace_files() {
    let pid = std::process::id();
    let cal_path = std::env::temp_dir().join(format!("prunemap_profile_cal_{pid}.json"));
    let trace_path = std::env::temp_dir().join(format!("prunemap_profile_trace_{pid}.json"));
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_prunemap"))
        .args(["profile", "--model", "proxy", "--reps", "2", "--warmup", "1", "--threads", "1"])
        .arg("--json-out")
        .arg(&cal_path)
        .arg("--trace-out")
        .arg(&trace_path)
        .output()
        .expect("run prunemap profile");
    assert!(
        out.status.success(),
        "profile failed:\n{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("mean ms"), "per-layer table header:\n{text}");
    assert!(text.contains("measured-vs-modeled"), "calibration section:\n{text}");

    let cal = Value::parse(&std::fs::read_to_string(&cal_path).unwrap())
        .expect("calibration record must parse");
    assert_eq!(cal.get("format").unwrap().as_str().unwrap(), "prunemap.calibration.v1");
    assert_eq!(cal.get("reps").unwrap().as_u64().unwrap(), 2);
    let layers = cal.get("layers").unwrap().as_arr().unwrap();
    assert!(!layers.is_empty(), "calibration must join at least one layer");
    for l in layers {
        assert!(l.get("measured_ms").unwrap().as_f64().unwrap() >= 0.0);
        assert!(l.get("modeled_ms").unwrap().as_f64().unwrap() > 0.0);
    }

    let trace = Value::parse(&std::fs::read_to_string(&trace_path).unwrap())
        .expect("trace dump must parse");
    assert!(!trace.get("traceEvents").unwrap().as_arr().unwrap().is_empty());
    let _ = std::fs::remove_file(&cal_path);
    let _ = std::fs::remove_file(&trace_path);
}
