//! Golden-output regression: a tiny deterministic CNN with hand-written
//! **integer** weights whose end-to-end logits are checked in below.  All
//! intermediate values are small integers, which f32 represents exactly
//! and adds associatively, so these constants are immune to accumulation
//! re-ordering and must match **bit-for-bit on every execution path**:
//! scalar or SIMD lanes, serial or persistent-pool threaded, fused
//! tile-order or materialized im2col, any tile width, any backend
//! (dense/CSR/BCS/auto), any batch width.
//!
//! For float weights the executor's numerics are pinned structurally
//! rather than by constants: the accumulation order is defined **in one
//! place** — ascending non-zero order per output element, the order of
//! the serial scalar `spmv` (`SparseKernel::run_rows_scalar`) — and the
//! parity suites (`engine_parity.rs`, `properties.rs`) assert every other
//! path reproduces it exactly.  If that order ever changes, this file and
//! those suites are the single spot to re-pin.
//!
//! Network: 1×4×4 input → conv 3×3 SAME (1→2 ch, Sobel-x + Laplacian
//! filters) → ReLU → implicit 2×2 max pool → flatten → FC 8→3.
//! Reference values computed independently (exact integer arithmetic).

use prunemap::compiler::{fuse, Graph, Op};
use prunemap::models::LayerSpec;
use prunemap::pruning::Scheme;
use prunemap::runtime::graph::{CompiledNet, MaskedLayer, NetWeights};
use prunemap::runtime::{GraphExecutor, KernelChoice};
use prunemap::tensor::Tensor;
use prunemap::util::cli::env_threads;

/// Sample 0: pixels 0..16 row-major.  Sample 1: 15 - pixel index.
fn inputs() -> (Vec<f32>, Vec<f32>) {
    let s0: Vec<f32> = (0..16).map(|p| p as f32).collect();
    let s1: Vec<f32> = (0..16).map(|p| (15 - p) as f32).collect();
    (s0, s1)
}

/// The checked-in golden logits (exact integers; see module docs).
const GOLDEN_S0: [f32; 3] = [-10.0, 53.0, 120.0];
const GOLDEN_S1: [f32; 3] = [18.0, 61.0, 64.0];

fn golden_net(choice: KernelChoice) -> CompiledNet {
    let conv_spec = LayerSpec::conv("conv1", 3, 1, 2, 4, 1);
    let fc_spec = LayerSpec::fc("fc1", 8, 3);

    // (F=2, C=1, 3, 3): Sobel-x and Laplacian — both carry zeros, so the
    // sparse backends get real work
    #[rustfmt::skip]
    let conv_w = Tensor::from_vec(&[2, 1, 3, 3], vec![
        1.0, 0.0, -1.0,  2.0, 0.0, -2.0,  1.0, 0.0, -1.0,
        0.0, 1.0,  0.0,  1.0, -4.0, 1.0,  0.0, 1.0,  0.0,
    ]);
    // (in=8, out=3)
    #[rustfmt::skip]
    let fc_w = Tensor::from_vec(&[8, 3], vec![
         1.0,  0.0, -1.0,
         0.0,  2.0,  0.0,
         1.0, -1.0,  0.0,
         0.0,  0.0,  3.0,
        -2.0,  1.0,  0.0,
         0.0,  0.0,  0.0,
         1.0,  1.0,  1.0,
         0.0, -1.0,  2.0,
    ]);

    let weights = NetWeights {
        layers: vec![
            MaskedLayer {
                spec: conv_spec.clone(),
                weight: conv_w,
                scheme: Scheme::None,
                compression: 1.0,
            },
            MaskedLayer {
                spec: fc_spec.clone(),
                weight: fc_w,
                scheme: Scheme::None,
                compression: 1.0,
            },
        ],
        bn: Default::default(),
    };

    let mut g = Graph::default();
    let i = g.add("in", Op::Input { shape: vec![1, 1, 4, 4] }, vec![]);
    let c = g.add("conv1", Op::Layer { layer: conv_spec }, vec![i]);
    let r = g.add("relu1", Op::Relu, vec![c]);
    let f = g.add("fc1", Op::Layer { layer: fc_spec }, vec![r]);
    g.add("out", Op::Output, vec![f]);
    let plan = fuse(&g);
    CompiledNet::lower(&g, &plan, &weights, choice, "golden").unwrap()
}

fn assert_golden(y: &[f32], want: &[&[f32; 3]], ctx: &str) {
    let flat: Vec<f32> = want.iter().flat_map(|w| w.iter().copied()).collect();
    assert_eq!(y, flat.as_slice(), "{ctx}");
}

#[test]
fn golden_logits_every_backend_and_path() {
    let (s0, s1) = inputs();
    let mut both = s0.clone();
    both.extend_from_slice(&s1);
    for choice in [KernelChoice::Dense, KernelChoice::Csr, KernelChoice::Bcs, KernelChoice::Auto] {
        let net = golden_net(choice);
        let execs: Vec<(&str, GraphExecutor)> = vec![
            ("serial_fused", GraphExecutor::serial()),
            ("serial_tile8", GraphExecutor::serial().with_tile_cols(8)),
            ("serial_materialized", GraphExecutor::serial().materialized()),
            ("threaded_fused", GraphExecutor::new(env_threads(3))),
            ("threaded_materialized", GraphExecutor::new(env_threads(3)).materialized()),
        ];
        for (name, exec) in &execs {
            let ctx = format!("{choice:?}/{name}");
            let y0 = exec.run(&net, &s0, 1).unwrap();
            assert_golden(&y0, &[&GOLDEN_S0], &format!("{ctx} sample0"));
            let y1 = exec.run(&net, &s1, 1).unwrap();
            assert_golden(&y1, &[&GOLDEN_S1], &format!("{ctx} sample1"));
            let yb = exec.run(&net, &both, 2).unwrap();
            assert_golden(&yb, &[&GOLDEN_S0, &GOLDEN_S1], &format!("{ctx} batch2"));
        }
    }
}

#[test]
fn golden_net_uses_the_expected_lowering() {
    // the golden only means something if the program actually exercises
    // the conv + glue + fc pipeline it was computed for
    let net = golden_net(KernelChoice::Bcs);
    assert_eq!(net.layers.len(), 2);
    assert_eq!(net.input_shape, (1, 4, 4));
    assert_eq!(net.output_shape, (3, 1, 1));
    // conv GEMM is [2, 9] with 6 + 5 retained taps, fc is [3, 8] with 13
    assert_eq!(net.layers[0].sparse.dims(), (2, 9));
    assert_eq!(net.layers[0].sparse.nnz(), 11);
    assert_eq!(net.layers[1].sparse.dims(), (3, 8));
    assert_eq!(net.layers[1].sparse.nnz(), 13);
}
