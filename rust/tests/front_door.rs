//! Acceptance suite for the multi-model serving front door
//! (`prunemap::serve::{ModelRegistry, Server, wire}`):
//!
//! * routing: an unknown model is a typed [`ServeError::UnknownModel`],
//!   never a panic;
//! * a two-model registry serving interleaved concurrent clients returns
//!   outputs **bit-identical** to per-model solo `Session::infer` runs;
//! * under a saturated batcher, high-priority requests ride earlier runs
//!   than normal-priority ones (observed through `Ticket::wait_detail`);
//! * an expired deadline is rejected with
//!   [`ServeError::DeadlineExpired`] instead of being served late;
//! * the wire protocol round-trips encode -> decode -> serve -> decode
//!   over real TCP, including malformed-frame error frames, and preserves
//!   bit identity;
//! * overload is bounded and typed: submits past the session's
//!   `max_queue` high-water mark come back as `overloaded` frames with a
//!   retry-after budget, a pipeliner that outruns the reply writer is
//!   blocked by the bounded pending channel instead of growing memory,
//!   accepts past the connection pool are shed with one `overloaded`
//!   frame, and writer death unparks a reader blocked mid-line.

use std::io::{self, BufRead, BufReader, Cursor, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use prunemap::accuracy::Assignment;
use prunemap::models::{zoo, Dataset, ModelSpec};
use prunemap::serve::{
    wire, InferRequest, ModelRegistry, PreparedModel, ServeError, Server, Session,
};
use prunemap::util::cli::env_threads;

fn dense_prepared(spec: ModelSpec, seed: u64) -> PreparedModel {
    let assigns: Vec<Assignment> = spec.layers.iter().map(|_| Assignment::dense()).collect();
    PreparedModel::builder()
        .model_spec(spec)
        .assignments(assigns)
        .seed(seed)
        .build()
        .expect("prepare model")
}

/// Two genuinely different zoo architectures, cheap enough for debug-mode
/// test runs: the proxy CNN and a width-0.25 MobileNet-V1.
fn two_model_registry() -> ModelRegistry {
    let registry = ModelRegistry::new();
    registry.insert("alpha", dense_prepared(zoo::proxy_cnn(), 21));
    registry.insert(
        "beta",
        dense_prepared(zoo::mobilenet_v1_scaled(Dataset::Cifar10, 0.25), 22),
    );
    registry
}

fn sample(len: usize, tag: usize) -> Vec<f32> {
    (0..len).map(|j| (((tag * 7 + j) % 23) as f32) * 0.1 - 1.0).collect()
}

/// A solo single-model session's answers — the PR-4 layer the front door
/// must match bit for bit.
fn solo_answers(prepared: &PreparedModel, nreq: usize) -> Vec<Vec<f32>> {
    let session = Session::builder(prepared.clone()).threads(1).build();
    (0..nreq).map(|tag| session.infer(sample(prepared.input_len(), tag)).unwrap()).collect()
}

#[test]
fn unknown_model_is_a_typed_routing_error() {
    let server = Server::builder(two_model_registry()).threads(1).build();
    match server.infer(InferRequest::new("gamma", vec![0.0; 16])) {
        Err(ServeError::UnknownModel(name)) => assert_eq!(name, "gamma"),
        other => panic!("expected UnknownModel, got {other:?}"),
    }
    assert!(server.stats().is_empty(), "failed routing must not spin up sessions");
}

#[test]
fn interleaved_clients_on_two_models_match_solo_sessions() {
    let registry = two_model_registry();
    let server = Server::builder(registry.clone())
        .threads(env_threads(2))
        .max_batch(16)
        .max_wait(Duration::from_millis(5))
        .build();
    let nreq = 6usize;
    let clients = 3usize;
    let truth: Vec<(String, PreparedModel, Vec<Vec<f32>>)> = ["alpha", "beta"]
        .into_iter()
        .map(|name| {
            let prepared = registry.get(name).unwrap();
            let answers = solo_answers(&prepared, nreq);
            (name.to_string(), prepared, answers)
        })
        .collect();
    std::thread::scope(|scope| {
        for _ in 0..clients {
            let (server, truth) = (&server, &truth);
            scope.spawn(move || {
                // pipeline every (model, tag) pair interleaved across both
                // models, then check the answers against the solo truths
                let tickets: Vec<_> = (0..nreq)
                    .flat_map(|tag| {
                        truth.iter().map(move |(name, prepared, _)| {
                            let input = sample(prepared.input_len(), tag);
                            (name.clone(), tag, input)
                        })
                    })
                    .map(|(name, tag, input)| {
                        (tag, server.submit(InferRequest::new(name, input)).unwrap())
                    })
                    .collect();
                for (i, (tag, ticket)) in tickets.into_iter().enumerate() {
                    let (name, _, answers) = &truth[i % 2];
                    assert_eq!(
                        ticket.wait().unwrap(),
                        answers[tag],
                        "front-door output for model '{name}' tag {tag} diverged from solo"
                    );
                }
            });
        }
    });
    let stats = server.stats();
    assert_eq!(stats["alpha"].requests, clients * nreq);
    assert_eq!(stats["beta"].requests, clients * nreq);
    for st in stats.values() {
        assert!(st.queue_depth_hwm >= 1);
        assert_eq!(st.wait_buckets.iter().sum::<usize>(), clients * nreq);
        let occupancy: usize = st.batch_occupancy.iter().map(|(occ, runs)| occ * runs).sum();
        assert_eq!(occupancy, clients * nreq, "occupancy must account for every request");
    }
}

#[test]
fn high_priority_rides_earlier_runs_under_saturation() {
    let registry = two_model_registry();
    let server = Server::builder(registry.clone())
        .threads(1)
        .workers(1)
        .max_batch(8)
        .max_wait(Duration::ZERO)
        .build();
    let n = registry.get("alpha").unwrap().input_len();
    // a plug request occupies the single batcher worker so the burst
    // below queues up behind it; every high-priority request is submitted
    // before every normal one, so whatever the interleaving, no normal
    // request may be served by an earlier run than any high request
    let plug = server.submit(InferRequest::new("alpha", sample(n, 99))).unwrap();
    let high: Vec<_> = (0..8)
        .map(|tag| server.submit(InferRequest::new("alpha", sample(n, tag)).high()).unwrap())
        .collect();
    let normal: Vec<_> = (0..8)
        .map(|tag| server.submit(InferRequest::new("alpha", sample(n, tag))).unwrap())
        .collect();
    plug.wait().unwrap();
    let high_runs: Vec<u64> = high.into_iter().map(|t| t.wait_detail().unwrap().run).collect();
    let normal_runs: Vec<u64> = normal.into_iter().map(|t| t.wait_detail().unwrap().run).collect();
    assert!(
        high_runs.iter().max() <= normal_runs.iter().min(),
        "a normal-priority request was batched before a high-priority one: high {high_runs:?} vs normal {normal_runs:?}"
    );
    let stats = server.stats();
    let st = &stats["alpha"];
    assert_eq!(st.served_by_priority, [8, 9], "8 high + (plug + 8) normal");
    assert!(st.runs >= 3, "17 requests at cap 8 need >= 3 runs: {st:?}");
    assert!(st.batch_runs.keys().all(|&b| b <= 8), "cap exceeded: {st:?}");
}

#[test]
fn expired_deadline_is_rejected_not_served_late() {
    let registry = two_model_registry();
    let server = Server::builder(registry.clone()).threads(1).build();
    let prepared = registry.get("alpha").unwrap();
    let n = prepared.input_len();
    // a deadline equal to the submit instant has always passed by
    // assembly time
    let late = InferRequest::new("alpha", sample(n, 0)).high().deadline(Duration::ZERO);
    match server.infer(late) {
        Err(ServeError::DeadlineExpired { .. }) => {}
        other => panic!("expected DeadlineExpired, got {other:?}"),
    }
    // a generous deadline is served normally, bit-identical to solo
    let ok = InferRequest::new("alpha", sample(n, 0)).deadline(Duration::from_secs(30));
    assert_eq!(server.infer(ok).unwrap(), solo_answers(&prepared, 1)[0]);
    let stats = server.stats();
    let st = &stats["alpha"];
    assert_eq!((st.expired, st.requests), (1, 1));
}

#[test]
fn evicted_model_is_unknown_on_the_wire_not_stale() {
    // Regression: evicting a model through the shared registry handle
    // after its session was lazily cached must surface as a typed
    // UnknownModel over the wire — never a stale answer from the cached
    // session — and must actually drop that session.
    let registry = two_model_registry();
    let server = Arc::new(Server::builder(registry.clone()).threads(1).build());
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let acceptor = {
        let server = Arc::clone(&server);
        std::thread::spawn(move || wire::serve_tcp(&server, listener, Some(1), 4))
    };
    let alpha = registry.get("alpha").unwrap();
    let n = alpha.input_len();
    let mut client = wire::Client::connect(addr).unwrap();
    // first request builds and caches alpha's session
    let ok = client.infer(&InferRequest::new("alpha", sample(n, 0))).unwrap();
    assert_eq!(ok.unwrap(), solo_answers(&alpha, 1)[0]);
    assert!(server.stats().contains_key("alpha"), "session cached after first request");
    // evict through the registry handle the server shares
    assert!(registry.evict("alpha").is_some());
    match client.infer(&InferRequest::new("alpha", sample(n, 0))).unwrap() {
        Err(ServeError::UnknownModel(name)) => assert_eq!(name, "alpha"),
        other => panic!("expected UnknownModel after evict, got {other:?}"),
    }
    assert!(
        !server.stats().contains_key("alpha"),
        "the evicted model's cached session must be dropped, not kept warm"
    );
    // the untouched model still serves on the same connection
    let beta = registry.get("beta").unwrap();
    let yb = client.infer(&InferRequest::new("beta", sample(beta.input_len(), 1))).unwrap();
    assert_eq!(yb.unwrap(), solo_answers(&beta, 2)[1]);
    drop(client);
    acceptor.join().expect("acceptor").unwrap();
}

#[test]
fn wire_tcp_round_trip_including_malformed_frames() {
    let registry = two_model_registry();
    let server = Arc::new(Server::builder(registry.clone()).threads(env_threads(2)).build());
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let acceptor = {
        let server = Arc::clone(&server);
        std::thread::spawn(move || wire::serve_tcp(&server, listener, Some(2), 4))
    };
    let alpha = registry.get("alpha").unwrap();
    let beta = registry.get("beta").unwrap();

    // connection 1: the typed client, both models pipelined on one
    // socket, replies claimed out of submission order (exercises the
    // stash), plus a typed admission error over the wire
    {
        let mut client = wire::Client::connect(addr).unwrap();
        let ida =
            client.send(&InferRequest::new("alpha", sample(alpha.input_len(), 1)).high()).unwrap();
        let idb = client.send(&InferRequest::new("beta", sample(beta.input_len(), 2))).unwrap();
        let yb = client.wait(idb).unwrap().unwrap();
        let ya = client.wait(ida).unwrap().unwrap();
        assert_eq!(ya, solo_answers(&alpha, 2)[1], "alpha over the wire diverged from solo");
        assert_eq!(yb, solo_answers(&beta, 3)[2], "beta over the wire diverged from solo");
        let bad = client.infer(&InferRequest::new("alpha", vec![0.0; 3])).unwrap();
        assert!(
            matches!(bad, Err(ServeError::BadInput { .. })),
            "wrong payload length must come back as bad_input, got {bad:?}"
        );
    }

    // connection 2: a raw socket sends a malformed line then a valid
    // frame; the server answers an id-less malformed error frame, keeps
    // the connection up, and still serves the valid frame
    {
        let mut raw = TcpStream::connect(addr).unwrap();
        let beta_req = InferRequest::new("beta", sample(beta.input_len(), 3));
        let frame = wire::encode_request(7, &beta_req);
        write!(raw, "this is not json\n{frame}\n").unwrap();
        raw.shutdown(std::net::Shutdown::Write).unwrap();
        let reader = BufReader::new(raw);
        let lines: Vec<String> = reader.lines().map(|l| l.unwrap()).collect();
        assert_eq!(lines.len(), 2, "one error frame + one output frame: {lines:?}");
        match wire::decode_response(&lines[0]).unwrap() {
            wire::ResponseFrame::Error { id: None, error: ServeError::Malformed(_) } => {}
            other => panic!("expected id-less malformed error frame, got {other:?}"),
        }
        match wire::decode_response(&lines[1]).unwrap() {
            wire::ResponseFrame::Output { id: 7, output } => {
                assert_eq!(output, solo_answers(&beta, 4)[3], "wire output diverged from solo")
            }
            other => panic!("expected output frame for id 7, got {other:?}"),
        }
    }
    acceptor.join().expect("acceptor").unwrap();
}

/// A writer whose `write` parks until the gate opens — the "slow reply
/// consumer" half of the backpressure test.
struct GateWriter {
    gate: Arc<(Mutex<bool>, Condvar)>,
}

impl Write for GateWriter {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        let (lock, cv) = &*self.gate;
        let mut open = lock.lock().unwrap();
        while !*open {
            open = cv.wait(open).unwrap();
        }
        Ok(buf.len())
    }
    fn flush(&mut self) -> io::Result<()> {
        Ok(())
    }
}

#[test]
fn pipelining_past_the_bounded_channel_blocks_the_reader_not_memory() {
    let server = Server::builder(ModelRegistry::new()).threads(1).build();
    // unknown-model requests resolve instantly to error replies, so the
    // only thing pacing the connection is the (gated-shut) writer
    let total = wire::PENDING_REPLY_CAP * 3;
    let mut lines = String::new();
    for id in 0..total {
        lines
            .push_str(&wire::encode_request(id as u64 + 1, &InferRequest::new("ghost", vec![0.5])));
        lines.push('\n');
    }
    let gate = Arc::new((Mutex::new(false), Condvar::new()));
    let stats = std::thread::scope(|scope| {
        let handle = {
            let writer = GateWriter { gate: Arc::clone(&gate) };
            let server = &server;
            let lines = lines.as_bytes();
            scope.spawn(move || wire::serve_connection(server, Cursor::new(lines), writer))
        };
        // with the writer parked, the reader must stall at the channel
        // bound: one reply stuck in `write`, PENDING_REPLY_CAP buffered,
        // one more blocked in `send` (its frame already counted)
        let bound = (wire::PENDING_REPLY_CAP + 2) as u64;
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            let frames = server.wire_counters().snapshot().frames;
            assert!(frames <= bound, "reader ran past the bounded channel: {frames} > {bound}");
            if frames == bound || Instant::now() >= deadline {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        // it is a stall, not a pause: the count holds at the bound
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(server.wire_counters().snapshot().frames, bound, "pending replies kept growing");
        let (lock, cv) = &*gate;
        *lock.lock().unwrap() = true;
        cv.notify_all();
        handle.join().expect("serve_connection thread")
    })
    .expect("serve_connection");
    assert_eq!(stats.errors, total, "every pipelined frame is answered once the writer drains");
    assert_eq!(server.wire_counters().snapshot().frames, total as u64);
}

/// A reader that yields one frame, then parks until the shutdown hook
/// releases it — standing in for a TCP read half blocked in `read_line`
/// whose peer will never send another byte.
struct ParkingReader {
    line: Option<Vec<u8>>,
    gate: Arc<(Mutex<bool>, Condvar)>,
}

impl Read for ParkingReader {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        if let Some(line) = self.line.take() {
            buf[..line.len()].copy_from_slice(&line);
            return Ok(line.len());
        }
        let (lock, cv) = &*self.gate;
        let mut released = lock.lock().unwrap();
        while !*released {
            released = cv.wait(released).unwrap();
        }
        Ok(0)
    }
}

/// The read-half kill switch the writer fires on death: releases the
/// parked reader, as `TcpStream::shutdown(Shutdown::Read)` would.
struct GateShutdown {
    gate: Arc<(Mutex<bool>, Condvar)>,
}

impl wire::ReadShutdown for GateShutdown {
    fn shutdown_read(&self) {
        let (lock, cv) = &*self.gate;
        *lock.lock().unwrap() = true;
        cv.notify_all();
    }
}

/// A writer whose peer is gone: every write fails.
struct DeadWriter;

impl Write for DeadWriter {
    fn write(&mut self, _buf: &[u8]) -> io::Result<usize> {
        Err(io::Error::new(io::ErrorKind::BrokenPipe, "peer closed"))
    }
    fn flush(&mut self) -> io::Result<()> {
        Ok(())
    }
}

#[test]
fn writer_death_unparks_a_reader_blocked_mid_line() {
    let server = Server::builder(ModelRegistry::new()).threads(1).build();
    let gate = Arc::new((Mutex::new(false), Condvar::new()));
    let line = format!("{}\n", wire::encode_request(1, &InferRequest::new("ghost", vec![0.5])));
    let reader =
        BufReader::new(ParkingReader { line: Some(line.into_bytes()), gate: Arc::clone(&gate) });
    let hook = GateShutdown { gate: Arc::clone(&gate) };
    let started = Instant::now();
    // without the hook this call parks forever: the reader waits for a
    // line that will never come while the writer's error goes unreported
    let result = wire::serve_connection_with(&server, reader, DeadWriter, &hook);
    assert!(result.is_err(), "the writer's BrokenPipe must surface, got {result:?}");
    assert!(
        started.elapsed() < Duration::from_secs(10),
        "reader stayed parked long after writer death"
    );
    assert!(*gate.0.lock().unwrap(), "writer death must fire the read-half shutdown hook");
}

#[test]
fn queue_hwm_shed_is_a_typed_overloaded_frame_on_the_wire() {
    let registry = two_model_registry();
    let server = Server::builder(registry.clone())
        .threads(1)
        .max_batch(8)
        .max_wait(Duration::from_secs(30))
        .max_queue(2)
        .build();
    let alpha = registry.get("alpha").unwrap();
    let n = alpha.input_len();
    // park two requests in the long hold window so the queue sits at its
    // high-water mark while the wire frame below arrives
    let parked: Vec<_> = (0..2)
        .map(|tag| server.submit(InferRequest::new("alpha", sample(n, tag))).unwrap())
        .collect();
    let frame = format!("{}\n", wire::encode_request(9, &InferRequest::new("alpha", sample(n, 2))));
    let mut replies: Vec<u8> = Vec::new();
    let stats =
        wire::serve_connection(&server, Cursor::new(frame.as_bytes()), &mut replies).unwrap();
    assert_eq!((stats.served, stats.errors), (0, 1));
    let text = String::from_utf8(replies).unwrap();
    match wire::decode_response(text.trim()).unwrap() {
        wire::ResponseFrame::Error {
            id: Some(9),
            error: ServeError::Overloaded { retry_after_ms },
        } => {
            assert!(retry_after_ms >= 1, "drain estimate must not invite an instant retry");
        }
        other => panic!("expected an overloaded frame for id 9, got {other:?}"),
    }
    assert_eq!(server.stats()["alpha"].shed_overload, 1);
    assert_eq!(server.wire_counters().snapshot().errors, 1);
    // closing the server drains the admitted requests; only the shed one
    // was refused
    drop(server);
    for t in parked {
        assert_eq!(t.wait().expect("parked requests drain on close").len(), 10);
    }
}

#[test]
fn accepts_past_the_pool_bound_are_shed_with_one_overloaded_frame() {
    let registry = two_model_registry();
    let server = Arc::new(Server::builder(registry.clone()).threads(1).build());
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let acceptor = {
        let server = Arc::clone(&server);
        std::thread::spawn(move || wire::serve_tcp(&server, listener, Some(2), 1))
    };
    // connection 1 is served; a completed round trip proves it was
    // accepted (and counted against the pool) before connection 2 dials
    let alpha = registry.get("alpha").unwrap();
    let mut held = wire::Client::connect(addr).unwrap();
    let y = held.infer(&InferRequest::new("alpha", sample(alpha.input_len(), 0))).unwrap();
    assert_eq!(y.unwrap(), solo_answers(&alpha, 1)[0]);
    // connection 2 is past the bound: one id-less overloaded frame, then EOF
    let shed = TcpStream::connect(addr).unwrap();
    let mut lines = BufReader::new(shed).lines();
    let frame = lines.next().expect("one frame before close").unwrap();
    match wire::decode_response(&frame).unwrap() {
        wire::ResponseFrame::Error {
            id: None,
            error: ServeError::Overloaded { retry_after_ms },
        } => {
            assert_eq!(retry_after_ms, wire::SHED_RETRY_MS, "retry-after survives the wire");
        }
        other => panic!("expected an id-less overloaded frame, got {other:?}"),
    }
    assert!(lines.next().is_none(), "a shed connection is closed after its one frame");
    drop(held);
    acceptor.join().expect("acceptor").unwrap();
    let w = server.wire_counters().snapshot();
    assert_eq!(w.shed_conns, 1);
    assert_eq!(w.connections, 1, "shed connections never reach the serving layer");
    assert_eq!(w.conn_setup_failed, 0);
}
