//! Execution runtimes.
//!
//! Two request paths share this module's host-side types:
//!
//! * [`native`] — the default: the batched multi-threaded sparse execution
//!   engine ([`crate::sparse::Engine`]) running BCS/CSR kernels directly on
//!   the host.  Always compiled, no external dependencies; this is the
//!   crate's real hot path and the surface future perf PRs target.
//!   [`graph`] builds on it: whole pruned CNNs (im2col conv + fused
//!   epilogues) lowered from the compiler's fusion plan and executed
//!   end to end.
//! * [`pjrt`] — the PJRT bridge that loads AOT artifacts (HLO text emitted
//!   by python/compile/aot.py) and executes them through the `xla`
//!   bindings.  Compiled only under `--cfg pjrt` (`RUSTFLAGS="--cfg
//!   pjrt"`) because the `xla` crate must be vendored; see that module's
//!   docs for the artifact workflow.
//!
//! [`HostValue`] is the typed host-side tensor both paths accept, and
//! [`Manifest`] describes artifact signatures.

mod manifest;

pub mod graph;
pub mod native;
#[cfg(pjrt)]
pub mod pjrt;

pub use graph::{Arena, ArenaStats, CompiledNet, GraphExecutor, NetWeights};
pub use manifest::{ArtifactSig, Manifest, ParamSpec};
pub use native::{KernelChoice, NativeEngine, SparseLayer};
#[cfg(pjrt)]
pub use pjrt::{Executable, Runtime};

/// A typed host-side value crossing a runtime boundary.
#[derive(Debug, Clone)]
pub enum HostValue {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostValue {
    pub fn scalar_f32(v: f32) -> Self {
        HostValue::F32 { shape: vec![], data: vec![v] }
    }

    pub fn f32(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostValue::F32 { shape: shape.to_vec(), data }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostValue::I32 { shape: shape.to_vec(), data }
    }

    /// Flat f32 view (errors on I32).
    pub fn as_f32(&self) -> crate::Result<&[f32]> {
        match self {
            HostValue::F32 { data, .. } => Ok(data),
            HostValue::I32 { .. } => Err(anyhow::anyhow!("expected f32 value, got i32")),
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostValue::F32 { shape, .. } | HostValue::I32 { shape, .. } => shape,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_value_shape_check() {
        let v = HostValue::f32(&[2, 2], vec![1.0; 4]);
        assert_eq!(v.shape(), &[2, 2]);
        assert!(v.as_f32().is_ok());
        let i = HostValue::i32(&[2], vec![1, 2]);
        assert!(i.as_f32().is_err());
    }

    #[test]
    #[should_panic]
    fn host_value_len_mismatch_panics() {
        HostValue::f32(&[3], vec![0.0; 2]);
    }
}
