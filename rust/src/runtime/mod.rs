//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! This is the only bridge between the Rust coordinator and the Layer-1/2
//! compute: `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`.  Artifacts are produced once by
//! `make artifacts` (python/compile/aot.py) together with `manifest.json`
//! describing each artifact's input/output signature; Python never runs at
//! request time.
//!
//! HLO **text** is the interchange format: jax >= 0.5 serializes protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects, while the text
//! parser reassigns ids (see /opt/xla-example/README.md).

mod manifest;

pub use manifest::{ArtifactSig, Manifest, ParamSpec};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

/// Convert an `xla::Error` into an `anyhow` report.
fn xerr(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e:?}")
}

/// A typed host-side value crossing the PJRT boundary.
#[derive(Debug, Clone)]
pub enum HostValue {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostValue {
    pub fn scalar_f32(v: f32) -> Self {
        HostValue::F32 { shape: vec![], data: vec![v] }
    }

    pub fn f32(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostValue::F32 { shape: shape.to_vec(), data }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostValue::I32 { shape: shape.to_vec(), data }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            HostValue::F32 { shape, data } => {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims).map_err(xerr)?
            }
            HostValue::I32 { shape, data } => {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims).map_err(xerr)?
            }
        };
        Ok(lit)
    }

    /// Flat f32 view (errors on I32).
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostValue::F32 { data, .. } => Ok(data),
            HostValue::I32 { .. } => Err(anyhow!("expected f32 value, got i32")),
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostValue::F32 { shape, .. } | HostValue::I32 { shape, .. } => shape,
        }
    }
}

/// A compiled artifact ready to execute.
pub struct Executable {
    name: String,
    exe: xla::PjRtLoadedExecutable,
    sig: ArtifactSig,
}

impl Executable {
    /// Execute with host values; returns the flattened output tuple as f32
    /// vectors (all our artifact outputs are f32).
    pub fn run(&self, inputs: &[HostValue]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.sig.inputs.len() {
            return Err(anyhow!(
                "artifact '{}' expects {} inputs, got {}",
                self.name,
                self.sig.inputs.len(),
                inputs.len()
            ));
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|v| v.to_literal())
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals).map_err(xerr)?;
        let tuple = result[0][0].to_literal_sync().map_err(xerr)?;
        let parts = tuple.to_tuple().map_err(xerr)?;
        if parts.len() != self.sig.outputs.len() {
            return Err(anyhow!(
                "artifact '{}' returned {} outputs, manifest says {}",
                self.name,
                parts.len(),
                self.sig.outputs.len()
            ));
        }
        parts
            .into_iter()
            .map(|l| l.to_vec::<f32>().map_err(xerr))
            .collect()
    }

    pub fn signature(&self) -> &ArtifactSig {
        &self.sig
    }

    pub fn name(&self) -> &str {
        &self.name
    }
}

/// The PJRT runtime: one CPU client + the artifact manifest + a compile
/// cache so each artifact is compiled exactly once per process.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
}

impl Runtime {
    /// Open the artifacts directory (default `artifacts/`); reads
    /// `manifest.json` and creates the PJRT CPU client.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let manifest = Manifest::parse(&text).context("parsing manifest.json")?;
        let client = xla::PjRtClient::cpu().map_err(xerr)?;
        Ok(Self { client, dir, manifest, cache: Mutex::new(HashMap::new()) })
    }

    /// Locate `artifacts/` relative to the crate root (env override:
    /// `PRUNEMAP_ARTIFACTS`).
    pub fn default_dir() -> PathBuf {
        if let Ok(p) = std::env::var("PRUNEMAP_ARTIFACTS") {
            return PathBuf::from(p);
        }
        let mut d = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
        d.push("artifacts");
        d
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (compile-once, cached) an artifact by manifest key, e.g.
    /// `"train_step"`.
    pub fn load(&self, key: &str) -> Result<std::sync::Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(key) {
            return Ok(e.clone());
        }
        let sig = self
            .manifest
            .artifacts
            .get(key)
            .ok_or_else(|| anyhow!("unknown artifact '{key}'"))?
            .clone();
        let path = self.dir.join(&sig.file);
        let proto = xla::HloModuleProto::from_text_file(&path).map_err(xerr)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(xerr)?;
        let executable =
            std::sync::Arc::new(Executable { name: key.to_string(), exe, sig });
        self.cache
            .lock()
            .unwrap()
            .insert(key.to_string(), executable.clone());
        Ok(executable)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_value_shape_check() {
        let v = HostValue::f32(&[2, 2], vec![1.0; 4]);
        assert_eq!(v.shape(), &[2, 2]);
        assert!(v.as_f32().is_ok());
        let i = HostValue::i32(&[2], vec![1, 2]);
        assert!(i.as_f32().is_err());
    }

    #[test]
    #[should_panic]
    fn host_value_len_mismatch_panics() {
        HostValue::f32(&[3], vec![0.0; 2]);
    }
}
