//! Operator kernels for the non-prunable graph nodes.
//!
//! All kernels operate on the engine-native activation layout
//! `[C, batch, H*W]` (see [`super::im2col`]).  The elementwise ones
//! (batch-norm, ReLU, residual add) double as **fused epilogues**: when the
//! fusion plan attaches them to a conv/FC kernel they run in-place on the
//! GEMM output before it is stored, in exactly the order the standalone
//! steps would have applied them — so a fused program is bit-for-bit
//! identical to its unfused counterpart.

use crate::rng::Rng;

/// Inference-time batch-norm folded to a per-channel affine:
/// `y = scale[c] * x + shift[c]` with
/// `scale = gamma / sqrt(var + eps)`, `shift = beta - scale * mean`.
#[derive(Debug, Clone, PartialEq)]
pub struct BnParams {
    pub scale: Vec<f32>,
    pub shift: Vec<f32>,
}

impl BnParams {
    /// Identity normalization (scale 1, shift 0).
    pub fn identity(channels: usize) -> BnParams {
        BnParams { scale: vec![1.0; channels], shift: vec![0.0; channels] }
    }

    /// Deterministic synthetic statistics (positive scales near 1, small
    /// shifts) — stand-ins for trained parameters in tests and benches.
    pub fn synth(channels: usize, rng: &mut Rng) -> BnParams {
        BnParams {
            scale: (0..channels).map(|_| rng.range_f32(0.6, 1.4)).collect(),
            shift: (0..channels).map(|_| rng.range_f32(-0.2, 0.2)).collect(),
        }
    }

    pub fn channels(&self) -> usize {
        self.scale.len()
    }

    /// Apply in place to `[C, cols]` data (`cols = batch * H*W`).
    pub fn apply(&self, y: &mut [f32], cols: usize) {
        assert_eq!(y.len(), self.scale.len() * cols, "bn shape mismatch");
        for (c, row) in y.chunks_mut(cols.max(1)).enumerate() {
            let (s, t) = (self.scale[c], self.shift[c]);
            for v in row {
                *v = s * *v + t;
            }
        }
    }
}

/// An elementwise op fused into a GEMM kernel's epilogue.
#[derive(Debug, Clone)]
pub enum EpiOp {
    BatchNorm(BnParams),
    Relu,
    /// Residual add of another activation (arena slot id, same shape).
    Add { slot: usize },
}

/// ReLU in place.
pub fn relu(y: &mut [f32]) {
    for v in y {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// Elementwise `y += other` (residual add).
pub fn add_assign(y: &mut [f32], other: &[f32]) {
    assert_eq!(y.len(), other.len(), "residual shapes differ");
    for (a, b) in y.iter_mut().zip(other) {
        *a += b;
    }
}

/// 2x2 max pool, stride 2, ceil semantics (odd trailing rows/cols pool over
/// the in-image taps only).  `src` is `[C, batch, H*W]`; writes
/// `[C, batch, OH*OW]` into `out` (cleared first).  Returns `(oh, ow)`.
pub fn max_pool2x2(
    src: &[f32],
    c: usize,
    batch: usize,
    h: usize,
    w: usize,
    out: &mut Vec<f32>,
) -> (usize, usize) {
    assert_eq!(src.len(), c * batch * h * w);
    let (oh, ow) = (h.div_ceil(2), w.div_ceil(2));
    out.clear();
    out.resize(c * batch * oh * ow, 0.0);
    for ci in 0..c {
        for b in 0..batch {
            let plane = &src[(ci * batch + b) * h * w..(ci * batch + b + 1) * h * w];
            let dst = &mut out[(ci * batch + b) * oh * ow..(ci * batch + b + 1) * oh * ow];
            for ohi in 0..oh {
                for owi in 0..ow {
                    let mut m = f32::NEG_INFINITY;
                    for dh in 0..2 {
                        let ih = ohi * 2 + dh;
                        if ih >= h {
                            continue;
                        }
                        for dw in 0..2 {
                            let iw = owi * 2 + dw;
                            if iw >= w {
                                continue;
                            }
                            m = m.max(plane[ih * w + iw]);
                        }
                    }
                    dst[ohi * ow + owi] = m;
                }
            }
        }
    }
    (oh, ow)
}

/// Global average pool: `[C, batch, H*W]` -> `[C, batch, 1]`.
pub fn global_avg_pool(src: &[f32], c: usize, batch: usize, hw: usize, out: &mut Vec<f32>) {
    assert_eq!(src.len(), c * batch * hw);
    assert!(hw > 0);
    out.clear();
    out.resize(c * batch, 0.0);
    for (i, o) in out.iter_mut().enumerate() {
        let plane = &src[i * hw..(i + 1) * hw];
        *o = plane.iter().sum::<f32>() / hw as f32;
    }
}

/// Flatten `[C, batch, H*W]` into FC input layout `[C*H*W, batch, 1]` —
/// feature index `c*H*W + p` in CHW order, matching how the zoo specs count
/// FC input features.
pub fn flatten(src: &[f32], c: usize, batch: usize, hw: usize, out: &mut Vec<f32>) {
    assert_eq!(src.len(), c * batch * hw);
    out.clear();
    out.resize(c * hw * batch, 0.0);
    for ci in 0..c {
        for b in 0..batch {
            for p in 0..hw {
                out[(ci * hw + p) * batch + b] = src[(ci * batch + b) * hw + p];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bn_applies_per_channel_affine() {
        let bn = BnParams { scale: vec![2.0, -1.0], shift: vec![1.0, 0.5] };
        let mut y = vec![1.0, 2.0, 3.0, 4.0]; // [2 channels, 2 cols]
        bn.apply(&mut y, 2);
        assert_eq!(y, vec![3.0, 5.0, -2.5, -3.5]);
    }

    #[test]
    fn relu_and_add() {
        let mut y = vec![-1.0, 2.0, -0.5];
        relu(&mut y);
        assert_eq!(y, vec![0.0, 2.0, 0.0]);
        add_assign(&mut y, &[1.0, 1.0, 1.0]);
        assert_eq!(y, vec![1.0, 3.0, 1.0]);
    }

    #[test]
    fn max_pool_even_and_odd() {
        // 1 channel, 1 sample, 3x3 plane
        let src: Vec<f32> = (0..9).map(|i| i as f32).collect();
        let mut out = Vec::new();
        let (oh, ow) = max_pool2x2(&src, 1, 1, 3, 3, &mut out);
        assert_eq!((oh, ow), (2, 2));
        assert_eq!(out, vec![4.0, 5.0, 7.0, 8.0]);
    }

    #[test]
    fn global_avg_pool_means() {
        let src = vec![1.0, 3.0, 2.0, 4.0]; // [2 planes of 2]
        let mut out = Vec::new();
        global_avg_pool(&src, 2, 1, 2, &mut out);
        assert_eq!(out, vec![2.0, 3.0]);
    }

    #[test]
    fn flatten_orders_chw_per_sample() {
        // C=2, batch=2, HW=2: act[(c*2 + b)*2 + p]
        let src = vec![
            0.0, 1.0, // c0 b0
            10.0, 11.0, // c0 b1
            2.0, 3.0, // c1 b0
            12.0, 13.0, // c1 b1
        ];
        let mut out = Vec::new();
        flatten(&src, 2, 2, 2, &mut out);
        // feature f = c*2+p, layout [f, batch]
        assert_eq!(out, vec![0.0, 10.0, 1.0, 11.0, 2.0, 12.0, 3.0, 13.0]);
    }

    #[test]
    fn synth_bn_is_deterministic_and_positive_scale() {
        let a = BnParams::synth(8, &mut Rng::new(7));
        let b = BnParams::synth(8, &mut Rng::new(7));
        assert_eq!(a, b);
        assert!(a.scale.iter().all(|s| *s > 0.0));
    }
}
