//! im2col lowering: convolution as the batched GEMM the sparse engine runs.
//!
//! Activations flow through the executor in the **engine-native layout**
//! `[C, batch, H*W]` (channel-major, then sample, then spatial position) —
//! exactly the `[rows, B]` matrix [`crate::sparse::Engine::spmm`] produces
//! when the GEMM batch dimension is `batch * out_positions`.  Keeping every
//! step in this layout means a conv's output feeds the next layer's im2col
//! with no transposes, and per-column accumulation order is independent of
//! both thread count and batch width (the executor's bit-for-bit guarantee).
//!
//! Padding is SAME with `out = ceil(in / stride)`, mirroring
//! [`crate::models::LayerSpec::out_hw`] so lowered shapes agree with the
//! spec-level accounting the mapping methods use.
//!
//! The naive direct convolutions at the bottom are the *references* the
//! property tests compare the lowered path against — deliberately the
//! dumbest possible loops over NCHW.

use crate::sparse::PanelSource;
use crate::tensor::Tensor;

/// SAME-padding geometry for one spatial axis: `(out_size, leading_pad)`.
///
/// `pad_total = (out - 1) * stride + k - in` split TF-style (smaller half
/// leading).
pub fn same_geometry(in_sz: usize, k: usize, stride: usize) -> (usize, usize) {
    assert!(in_sz > 0 && k > 0 && stride > 0);
    let out = in_sz.div_ceil(stride);
    let pad_total = ((out - 1) * stride + k).saturating_sub(in_sz);
    (out, pad_total / 2)
}

/// Repack a batched NCHW tensor `[batch, C, H*W]` into the engine-native
/// activation layout `[C, batch, H*W]`.
pub fn nchw_to_act(x: &[f32], batch: usize, c: usize, hw: usize) -> Vec<f32> {
    let mut act = Vec::new();
    nchw_to_act_into(x, batch, c, hw, &mut act);
    act
}

/// [`nchw_to_act`] into a caller-owned buffer (cleared and resized here),
/// so an arena-recycled buffer can hold the input activation.
pub fn nchw_to_act_into(x: &[f32], batch: usize, c: usize, hw: usize, act: &mut Vec<f32>) {
    assert_eq!(x.len(), batch * c * hw, "input must be [batch, C, H*W]");
    act.clear();
    act.resize(x.len(), 0.0);
    for b in 0..batch {
        for ci in 0..c {
            let src = &x[(b * c + ci) * hw..(b * c + ci + 1) * hw];
            act[(ci * batch + b) * hw..(ci * batch + b + 1) * hw].copy_from_slice(src);
        }
    }
}

/// Inverse of [`nchw_to_act`]: engine layout back to `[batch, C, H*W]`.
pub fn act_to_nchw(act: &[f32], batch: usize, c: usize, hw: usize) -> Vec<f32> {
    assert_eq!(act.len(), batch * c * hw, "activation must be [C, batch, H*W]");
    let mut x = vec![0.0f32; act.len()];
    for ci in 0..c {
        for b in 0..batch {
            let src = &act[(ci * batch + b) * hw..(ci * batch + b + 1) * hw];
            x[(b * c + ci) * hw..(b * c + ci + 1) * hw].copy_from_slice(src);
        }
    }
    x
}

/// Expand `[C, batch, H*W]` activations into im2col columns
/// `X = [C*KH*KW, batch * out_positions]`, the `[cols, batch]` right-hand
/// side [`crate::sparse::Engine::spmm`] consumes.
///
/// Column `b * npos + oh*OW + ow` holds the receptive field of output
/// position `(oh, ow)` of sample `b`; row `(c*KH + kh)*KW + kw` matches
/// [`Tensor::conv_to_gemm`]'s row layout, so a layer's transposed GEMM-view
/// weights `[F, C*KH*KW]` multiply these columns directly.  Out-of-image
/// taps stay zero (SAME padding).
///
/// Writes into `x` (cleared and zero-filled first, so the caller can reuse
/// one scratch buffer across layers); returns `(out_h, out_w)`.
pub fn im2col(
    act: &[f32],
    c: usize,
    h: usize,
    w: usize,
    batch: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    x: &mut Vec<f32>,
) -> (usize, usize) {
    assert_eq!(act.len(), c * batch * h * w, "activation must be [C, batch, H*W]");
    let (oh, pad_h) = same_geometry(h, kh, stride);
    let (ow, pad_w) = same_geometry(w, kw, stride);
    let npos = oh * ow;
    let cols = batch * npos;
    x.clear();
    x.resize(c * kh * kw * cols, 0.0);
    for ci in 0..c {
        for khi in 0..kh {
            for kwi in 0..kw {
                let r = (ci * kh + khi) * kw + kwi;
                let xrow = &mut x[r * cols..(r + 1) * cols];
                for b in 0..batch {
                    let src = &act[(ci * batch + b) * h * w..(ci * batch + b + 1) * h * w];
                    let dst = &mut xrow[b * npos..(b + 1) * npos];
                    for ohi in 0..oh {
                        let ih = (ohi * stride + khi) as isize - pad_h as isize;
                        if ih < 0 || ih >= h as isize {
                            continue;
                        }
                        let irow = &src[ih as usize * w..(ih as usize + 1) * w];
                        let orow = &mut dst[ohi * ow..(ohi + 1) * ow];
                        for (owi, o) in orow.iter_mut().enumerate() {
                            let iw = (owi * stride + kwi) as isize - pad_w as isize;
                            if iw >= 0 && iw < w as isize {
                                *o = irow[iw as usize];
                            }
                        }
                    }
                }
            }
        }
    }
    (oh, ow)
}

/// Tile-order im2col producer: the [`PanelSource`] the fused spmm
/// consumes.  Where [`im2col`] materializes the whole
/// `X = [C*KH*KW, batch * out_positions]` matrix up front, this yields
/// `[C*KH*KW, tile]` column panels on demand — each generated directly in
/// the `[cols, batch]` order [`crate::sparse::Engine::spmm_fused`] reads
/// them, so a convolution's full `X` never exists and its activations are
/// expanded straight into cache-resident tiles.
///
/// Column and row indexing are identical to [`im2col`] (column
/// `b * npos + oh*OW + ow`, row `(c*KH + kh)*KW + kw`, SAME padding taps
/// zero), which the property suite pins by reassembling panels into the
/// materialized matrix.
pub struct Im2colPanels<'a> {
    act: &'a [f32],
    c: usize,
    h: usize,
    w: usize,
    batch: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    oh: usize,
    ow: usize,
    pad_h: usize,
    pad_w: usize,
}

impl<'a> Im2colPanels<'a> {
    /// Wrap `[C, batch, H*W]` activations for on-demand expansion.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        act: &'a [f32],
        c: usize,
        h: usize,
        w: usize,
        batch: usize,
        kh: usize,
        kw: usize,
        stride: usize,
    ) -> Im2colPanels<'a> {
        assert_eq!(act.len(), c * batch * h * w, "activation must be [C, batch, H*W]");
        let (oh, pad_h) = same_geometry(h, kh, stride);
        let (ow, pad_w) = same_geometry(w, kw, stride);
        Im2colPanels { act, c, h, w, batch, kh, kw, stride, oh, ow, pad_h, pad_w }
    }

    /// Output spatial size `(OH, OW)` (same geometry as [`im2col`]).
    pub fn out_hw(&self) -> (usize, usize) {
        (self.oh, self.ow)
    }
}

impl PanelSource for Im2colPanels<'_> {
    fn num_cols(&self) -> usize {
        self.batch * self.oh * self.ow
    }

    fn k_rows(&self) -> usize {
        self.c * self.kh * self.kw
    }

    fn fill(&self, j0: usize, width: usize, panel: &mut [f32]) {
        debug_assert!(j0 + width <= self.num_cols());
        debug_assert_eq!(panel.len(), self.k_rows() * width);
        let npos = self.oh * self.ow;
        for ci in 0..self.c {
            for khi in 0..self.kh {
                for kwi in 0..self.kw {
                    let r = (ci * self.kh + khi) * self.kw + kwi;
                    let prow = &mut panel[r * width..(r + 1) * width];
                    // walk the tile as (sample, output-row) segments so the
                    // div/mod geometry is resolved once per segment and each
                    // segment streams one input row
                    let mut jj = 0;
                    while jj < width {
                        let j = j0 + jj;
                        let b = j / npos;
                        let p = j % npos;
                        let ohi = p / self.ow;
                        let owi0 = p % self.ow;
                        let seg = (self.ow - owi0).min(width - jj);
                        let dst = &mut prow[jj..jj + seg];
                        let ih = (ohi * self.stride + khi) as isize - self.pad_h as isize;
                        if ih < 0 || ih >= self.h as isize {
                            dst.fill(0.0);
                        } else {
                            let plane = (ci * self.batch + b) * self.h * self.w;
                            let row0 = plane + ih as usize * self.w;
                            let irow = &self.act[row0..row0 + self.w];
                            for (d, owi) in dst.iter_mut().zip(owi0..) {
                                let iw = (owi * self.stride + kwi) as isize - self.pad_w as isize;
                                *d = if iw >= 0 && iw < self.w as isize {
                                    irow[iw as usize]
                                } else {
                                    0.0
                                };
                            }
                        }
                        jj += seg;
                    }
                }
            }
        }
    }
}

/// Naive direct convolution over NCHW input (reference for property tests).
///
/// `input` is `[batch, C, H, W]`, `weight` is 4-D `(F, C, KH, KW)` (already
/// masked); returns `[batch, F, OH, OW]` with the same SAME-padding
/// geometry as [`im2col`].
pub fn direct_conv(
    input: &[f32],
    batch: usize,
    c: usize,
    h: usize,
    w: usize,
    weight: &Tensor,
    stride: usize,
) -> Vec<f32> {
    assert_eq!(weight.ndim(), 4);
    let (f, wc, kh, kw) = (
        weight.shape()[0],
        weight.shape()[1],
        weight.shape()[2],
        weight.shape()[3],
    );
    assert_eq!(wc, c, "weight channels must match input channels");
    assert_eq!(input.len(), batch * c * h * w);
    let (oh, pad_h) = same_geometry(h, kh, stride);
    let (ow, pad_w) = same_geometry(w, kw, stride);
    let mut out = vec![0.0f32; batch * f * oh * ow];
    for b in 0..batch {
        for fi in 0..f {
            for ohi in 0..oh {
                for owi in 0..ow {
                    let mut acc = 0.0f32;
                    for ci in 0..c {
                        for khi in 0..kh {
                            let ih = (ohi * stride + khi) as isize - pad_h as isize;
                            if ih < 0 || ih >= h as isize {
                                continue;
                            }
                            for kwi in 0..kw {
                                let iw = (owi * stride + kwi) as isize - pad_w as isize;
                                if iw < 0 || iw >= w as isize {
                                    continue;
                                }
                                acc += weight.at4(fi, ci, khi, kwi)
                                    * input[((b * c + ci) * h + ih as usize) * w + iw as usize];
                            }
                        }
                    }
                    out[((b * f + fi) * oh + ohi) * ow + owi] = acc;
                }
            }
        }
    }
    out
}

/// Naive depthwise convolution (reference): `weight` is `(C, 1, KH, KW)`,
/// one filter per input channel; returns `[batch, C, OH, OW]`.
pub fn direct_dwconv(
    input: &[f32],
    batch: usize,
    c: usize,
    h: usize,
    w: usize,
    weight: &Tensor,
    stride: usize,
) -> Vec<f32> {
    assert_eq!(weight.ndim(), 4);
    assert_eq!(weight.shape()[0], c, "depthwise weight must have C filters");
    assert_eq!(weight.shape()[1], 1, "depthwise weight must have 1 channel per filter");
    let (kh, kw) = (weight.shape()[2], weight.shape()[3]);
    assert_eq!(input.len(), batch * c * h * w);
    let (oh, pad_h) = same_geometry(h, kh, stride);
    let (ow, pad_w) = same_geometry(w, kw, stride);
    let mut out = vec![0.0f32; batch * c * oh * ow];
    for b in 0..batch {
        for ci in 0..c {
            for ohi in 0..oh {
                for owi in 0..ow {
                    let mut acc = 0.0f32;
                    for khi in 0..kh {
                        let ih = (ohi * stride + khi) as isize - pad_h as isize;
                        if ih < 0 || ih >= h as isize {
                            continue;
                        }
                        for kwi in 0..kw {
                            let iw = (owi * stride + kwi) as isize - pad_w as isize;
                            if iw < 0 || iw >= w as isize {
                                continue;
                            }
                            acc += weight.at4(ci, 0, khi, kwi)
                                * input[((b * c + ci) * h + ih as usize) * w + iw as usize];
                        }
                    }
                    out[((b * c + ci) * oh + ohi) * ow + owi] = acc;
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn same_geometry_matches_spec_out_hw() {
        // k=3 s=1: pad 1 each side, size preserved
        assert_eq!(same_geometry(32, 3, 1), (32, 1));
        // k=3 s=2 even input: out = in/2, leading pad 0 (TF SAME)
        assert_eq!(same_geometry(32, 3, 2), (16, 0));
        // k=3 s=2 odd input
        assert_eq!(same_geometry(7, 3, 2), (4, 1));
        // k=1: no padding ever
        assert_eq!(same_geometry(9, 1, 1), (9, 0));
        assert_eq!(same_geometry(9, 1, 2), (5, 0));
        // k=7 s=2 ImageNet stem
        assert_eq!(same_geometry(224, 7, 2), (112, 2));
    }

    #[test]
    fn nchw_roundtrip() {
        let mut rng = Rng::new(1);
        let (batch, c, hw) = (3, 4, 6);
        let x: Vec<f32> = (0..batch * c * hw).map(|_| rng.normal()).collect();
        let act = nchw_to_act(&x, batch, c, hw);
        // channel 2 of sample 1 lands at [(2*batch + 1) * hw ..]
        assert_eq!(act[(2 * batch + 1) * hw], x[(c + 2) * hw]);
        assert_eq!(act_to_nchw(&act, batch, c, hw), x);
    }

    #[test]
    fn im2col_1x1_is_a_permutation_of_the_input() {
        let mut rng = Rng::new(2);
        let (c, h, w, batch) = (3, 4, 4, 2);
        let act: Vec<f32> = (0..c * batch * h * w).map(|_| rng.normal()).collect();
        let mut x = Vec::new();
        let (oh, ow) = im2col(&act, c, h, w, batch, 1, 1, 1, &mut x);
        assert_eq!((oh, ow), (h, w));
        let npos = h * w;
        for ci in 0..c {
            for b in 0..batch {
                for p in 0..npos {
                    assert_eq!(
                        x[ci * batch * npos + b * npos + p],
                        act[(ci * batch + b) * npos + p]
                    );
                }
            }
        }
    }

    #[test]
    fn im2col_padding_taps_are_zero() {
        // all-ones single-channel input, 3x3 stride 1: corner columns have
        // exactly 4 in-image taps
        let (c, h, w, batch) = (1, 3, 3, 1);
        let act = vec![1.0f32; c * h * w];
        let mut x = Vec::new();
        let (oh, ow) = im2col(&act, c, h, w, batch, 3, 3, 1, &mut x);
        assert_eq!((oh, ow), (3, 3));
        let cols = oh * ow;
        let col_sum = |j: usize| (0..9).map(|r| x[r * cols + j]).sum::<f32>();
        assert_eq!(col_sum(0), 4.0); // top-left corner
        assert_eq!(col_sum(4), 9.0); // center
        assert_eq!(col_sum(8), 4.0); // bottom-right corner
    }

    #[test]
    fn panels_reassemble_into_materialized_im2col() {
        let mut rng = Rng::new(5);
        for (c, h, w, batch, kh, kw, stride) in
            [(3, 5, 4, 2, 3, 3, 1), (2, 7, 7, 1, 3, 3, 2), (1, 4, 4, 3, 1, 1, 1)]
        {
            let act: Vec<f32> = (0..c * batch * h * w).map(|_| rng.normal()).collect();
            let mut x = Vec::new();
            let (oh, ow) = im2col(&act, c, h, w, batch, kh, kw, stride, &mut x);
            let src = Im2colPanels::new(&act, c, h, w, batch, kh, kw, stride);
            assert_eq!(src.out_hw(), (oh, ow));
            let total = src.num_cols();
            let k = src.k_rows();
            for tile in [1usize, 3, 8, total.max(1)] {
                let mut rebuilt = vec![f32::NAN; k * total];
                let mut panel = Vec::new();
                let mut j0 = 0;
                while j0 < total {
                    let width = (total - j0).min(tile);
                    panel.clear();
                    panel.resize(k * width, 0.0);
                    src.fill(j0, width, &mut panel);
                    for r in 0..k {
                        for jj in 0..width {
                            rebuilt[r * total + j0 + jj] = panel[r * width + jj];
                        }
                    }
                    j0 += width;
                }
                assert_eq!(rebuilt, x, "{c}x{h}x{w} b={batch} k={kh} s={stride} tile={tile}");
            }
        }
    }

    #[test]
    fn direct_conv_identity_kernel_passes_input_through() {
        let mut rng = Rng::new(3);
        let (batch, c, h, w) = (2, 2, 5, 5);
        let input: Vec<f32> = (0..batch * c * h * w).map(|_| rng.normal()).collect();
        // 1x1 identity mixing: F == C, w[f,c] = delta(f,c)
        let mut wt = Tensor::zeros(&[c, c, 1, 1]);
        for i in 0..c {
            wt.set4(i, i, 0, 0, 1.0);
        }
        let out = direct_conv(&input, batch, c, h, w, &wt, 1);
        assert_eq!(out, input);
    }
}
