//! Native CNN graph executor: whole pruned networks on the sparse engine.
//!
//! Where [`super::native`] executes isolated masked-GEMM views, this
//! subsystem runs **every layer of a [`crate::models::ModelSpec`]** natively
//! on [`crate::sparse::Engine`]:
//!
//! * [`lower`] turns a fused compiler plan ([`crate::compiler::fuse`]) into
//!   a [`CompiledNet`] — compressed weights converted once into
//!   [`SparseLayer`](super::SparseLayer)s, convs lowered through
//!   [`im2col`] (stride + SAME padding; depthwise as a block-diagonal
//!   per-channel GEMM; FC passthrough), elementwise nodes either fused as
//!   epilogues or kept as standalone [`ops`] steps, and intermediate
//!   activations assigned to a small arena of slots by DAG liveness;
//! * [`GraphExecutor`] runs the program over NCHW batched input.  Convs go
//!   through the **fused tile-order im2col** path by default
//!   ([`im2col::Im2colPanels`] + [`crate::sparse::Engine::spmm_fused`]):
//!   activation tiles are expanded on demand instead of materializing the
//!   full `X` matrix per layer (`GraphExecutor::materialized` keeps the
//!   old path as the bench baseline);
//! * [`Arena`] recycles activation buffers by **size class**: a slot's
//!   previous buffer goes to a free list instead of being dropped when a
//!   step's output replaces it, and `run_with_arena` carries the arena
//!   across runs so steady-state inference stops allocating.
//!
//! **Determinism:** every GEMM column is accumulated in a fixed non-zero
//! order by the engine and all other kernels are elementwise, so the output
//! is bit-for-bit identical across thread counts, batch widths, tile
//! widths, and the fused/materialized im2col paths — the same guarantee
//! the underlying engine makes, lifted to whole networks.
//!
//! **Layering:** this is the *low-level* execution API — explicit batches,
//! per-step timings, caller-owned arenas.  For serving (compile once,
//! admit concurrent single-sample requests, dynamic micro-batching) build
//! a [`crate::serve::Session`] over a [`crate::serve::PreparedModel`]
//! instead; it drives this executor underneath and inherits the
//! determinism guarantee per request.

pub mod im2col;
pub mod lower;
pub mod ops;

pub use lower::{
    CompiledNet, GemmKind, LayerExec, LayerSummary, MaskedLayer, NetWeights, Step, StepOp,
};
pub use ops::{BnParams, EpiOp};

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::sparse::Engine;
use crate::telemetry::trace::{self, TraceRing};
use crate::telemetry::Span;

use self::im2col::Im2colPanels;
use super::native::NativeEngine;

/// Wall-clock of one executed step (for per-layer latency reports).
#[derive(Debug, Clone)]
pub struct StepTiming {
    pub name: String,
    pub ms: f64,
}

/// Allocation counters of an [`Arena`] (diagnostics and regression tests:
/// a warm arena must serve a steady-state run entirely from free lists).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct ArenaStats {
    /// `take` calls that missed every free list and allocated fresh.
    pub allocs: usize,
    /// `take` calls served from a size-class free list.
    pub reuses: usize,
    /// Buffers returned to the free lists.
    pub released: usize,
}

/// Size-classed activation-buffer recycler.
///
/// Buffers are binned by power-of-two capacity class; `take` hands out a
/// **cleared** (length 0) buffer from the requested size's class so stale
/// contents can never be read, and every consumer `resize`s it before
/// writing.  This closes the ROADMAP buffer-arena item: a slot's `Vec` is
/// returned here instead of dropped when a GEMM output replaces it, and
/// [`GraphExecutor::run_with_arena`] carries the arena across runs so the
/// second and later inferences of a network allocate nothing at the arena
/// level.
#[derive(Debug, Default)]
pub struct Arena {
    free: BTreeMap<usize, Vec<Vec<f32>>>,
    stats: ArenaStats,
}

impl Arena {
    pub fn new() -> Arena {
        Arena::default()
    }

    /// Allocation counters since construction (or [`Arena::reset_stats`]).
    pub fn stats(&self) -> ArenaStats {
        self.stats
    }

    /// Zero the counters (the free lists are kept): per-run deltas.
    pub fn reset_stats(&mut self) {
        self.stats = ArenaStats::default();
    }

    fn class(len: usize) -> usize {
        len.next_power_of_two().max(1)
    }

    /// A cleared buffer whose size class covers `len`, reusing a freed
    /// buffer when one exists.  Fresh buffers are allocated at exactly
    /// their class capacity (≤ 2× overhead), so a recycled buffer can
    /// serve any request of its class without ever growing — which keeps
    /// classes stable and warm-arena runs allocation-free.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        let class = Self::class(len);
        match self.free.get_mut(&class).and_then(Vec::pop) {
            Some(mut v) => {
                self.stats.reuses += 1;
                v.clear();
                v
            }
            None => {
                self.stats.allocs += 1;
                Vec::with_capacity(class)
            }
        }
    }

    /// Return a buffer to its size-class free list (empty-capacity buffers
    /// are dropped — there is nothing to recycle).  Filed under the largest
    /// class the buffer can fully serve (capacity rounded **down** to a
    /// power of two), so a reused buffer never has to grow — the
    /// self-enforcing invariant behind allocation-free warm runs, even if
    /// a future consumer grows a taken buffer past its class.
    pub fn release(&mut self, v: Vec<f32>) {
        if v.capacity() == 0 {
            return;
        }
        self.stats.released += 1;
        let class = 1usize << (usize::BITS - 1 - v.capacity().leading_zeros());
        self.free.entry(class).or_default().push(v);
    }
}

/// Runs a [`CompiledNet`] on the threaded native engine — the low-level
/// layer underneath [`crate::serve::Session`].
#[derive(Debug, Clone)]
pub struct GraphExecutor {
    engine: NativeEngine,
    fused: bool,
    trace: Option<Arc<TraceRing>>,
}

impl GraphExecutor {
    pub fn new(threads: usize) -> GraphExecutor {
        GraphExecutor { engine: NativeEngine::new(threads), fused: true, trace: None }
    }

    pub fn serial() -> GraphExecutor {
        GraphExecutor { engine: NativeEngine::serial(), fused: true, trace: None }
    }

    pub fn with_engine(engine: NativeEngine) -> GraphExecutor {
        GraphExecutor { engine, fused: true, trace: None }
    }

    /// Run convs through the materialized-X im2col path instead of the
    /// fused tile-order producer — the baseline the
    /// `fused_vs_materialized_im2col` benches compare against.
    pub fn materialized(mut self) -> GraphExecutor {
        self.fused = false;
        self
    }

    /// Override the fused-im2col tile width (GEMM columns per panel).
    pub fn with_tile_cols(mut self, tile: usize) -> GraphExecutor {
        self.engine = self.engine.with_tile_cols(tile);
        self
    }

    /// Record trace spans into `ring` on every run: one `run` span per
    /// invocation, a `step` span per lowered graph step (parented to
    /// the run), and `op` spans for the im2col / spmm / epilogue work
    /// inside each GEMM step.  Without a ring attached the hot path
    /// only ever takes an untaken `None` branch.
    pub fn with_trace(mut self, ring: Arc<TraceRing>) -> GraphExecutor {
        self.trace = Some(ring);
        self
    }

    /// The attached span ring, if any.
    pub fn trace_ring(&self) -> Option<&Arc<TraceRing>> {
        self.trace.as_ref()
    }

    pub fn threads(&self) -> usize {
        self.engine.threads()
    }

    /// Whether convs use the fused tile-order im2col path.
    pub fn is_fused(&self) -> bool {
        self.fused
    }

    /// Run one batched inference.  `input` is NCHW `[batch, C, H, W]`
    /// row-major; the result is `[batch, out_features]` (NCHW-flattened
    /// per sample for spatial outputs).
    pub fn run(&self, net: &CompiledNet, input: &[f32], batch: usize) -> Result<Vec<f32>> {
        let mut arena = Arena::new();
        self.run_with_arena(net, input, batch, &mut arena)
    }

    /// [`GraphExecutor::run`] against a caller-owned [`Arena`]: carry it
    /// across runs and every activation buffer after the first run comes
    /// off a size-class free list instead of the allocator.
    pub fn run_with_arena(
        &self,
        net: &CompiledNet,
        input: &[f32],
        batch: usize,
        arena: &mut Arena,
    ) -> Result<Vec<f32>> {
        let mut sink = Vec::new();
        self.run_inner(net, input, batch, false, &mut sink, arena)
    }

    /// [`GraphExecutor::run`] plus per-step wall-clock timings.
    pub fn run_timed(
        &self,
        net: &CompiledNet,
        input: &[f32],
        batch: usize,
    ) -> Result<(Vec<f32>, Vec<StepTiming>)> {
        let mut arena = Arena::new();
        self.run_timed_with_arena(net, input, batch, &mut arena)
    }

    /// [`GraphExecutor::run_timed`] against a caller-owned [`Arena`], so a
    /// warmed-up arena makes the per-step timings measure the steady-state
    /// (allocation-free) path.
    pub fn run_timed_with_arena(
        &self,
        net: &CompiledNet,
        input: &[f32],
        batch: usize,
        arena: &mut Arena,
    ) -> Result<(Vec<f32>, Vec<StepTiming>)> {
        let mut timings = Vec::with_capacity(net.steps.len());
        let y = self.run_inner(net, input, batch, true, &mut timings, arena)?;
        Ok((y, timings))
    }

    fn run_inner(
        &self,
        net: &CompiledNet,
        input: &[f32],
        batch: usize,
        timed: bool,
        timings: &mut Vec<StepTiming>,
        arena: &mut Arena,
    ) -> Result<Vec<f32>> {
        if batch == 0 {
            bail!("batch must be >= 1");
        }
        let (ic, ih, iw) = net.input_shape;
        if input.len() != batch * ic * ih * iw {
            bail!(
                "input must be [batch={batch}, {ic}, {ih}, {iw}] = {} elements, got {}",
                batch * ic * ih * iw,
                input.len()
            );
        }
        // arena slots: every destination buffer is taken from (and every
        // replaced buffer released to) the size-class free lists, so a
        // run's allocation profile is bounded by the liveness-derived slot
        // count — and with a warm arena it is zero
        let mut slots: Vec<Vec<f32>> = (0..net.num_slots).map(|_| Vec::new()).collect();
        let mut inp = arena.take(input.len());
        im2col::nchw_to_act_into(input, batch, ic, ih * iw, &mut inp);
        slots[net.input_slot] = inp;

        // span ids are reserved before the work they cover so children
        // can name their parent while it is still open
        let tr = self.trace.as_deref();
        let run_span = tr.map(|r| (r.next_id(), trace::now_ns()));
        let engine = self.engine.engine();
        for step in &net.steps {
            let t0 = std::time::Instant::now();
            let step_span = tr.map(|r| (r.next_id(), trace::now_ns()));
            let (c, h, w) = step.in_shape;
            // the allocator guarantees dst != src (and dst != any residual
            // slot), so replacing dst's buffer never aliases a read; the
            // previous buffer goes back to the free list instead of being
            // dropped (the ROADMAP arena fix)
            debug_assert_ne!(step.src, step.dst, "step '{}'", step.name);
            arena.release(std::mem::take(&mut slots[step.dst]));
            let (oc, oh, ow) = step.out_shape;
            let mut out = arena.take(oc * oh * ow * batch);
            match &step.op {
                StepOp::Gemm { layer, epilogue } => {
                    let lay = &net.layers[*layer];
                    let gemm_trace = tr.zip(step_span.map(|(id, _)| id));
                    run_gemm(
                        engine,
                        lay,
                        &slots[step.src],
                        (c, h, w),
                        batch,
                        self.fused,
                        gemm_trace,
                        arena,
                        &mut out,
                    )?;
                    let cols = batch * oh * ow;
                    debug_assert_eq!(out.len(), oc * cols);
                    let epi_start = tr.map(|_| trace::now_ns());
                    for e in epilogue {
                        match e {
                            EpiOp::BatchNorm(p) => p.apply(&mut out, cols),
                            EpiOp::Relu => ops::relu(&mut out),
                            EpiOp::Add { slot } => ops::add_assign(&mut out, &slots[*slot]),
                        }
                    }
                    if let (Some((r, parent)), Some(t)) = (gemm_trace, epi_start) {
                        if !epilogue.is_empty() {
                            let name = format!("{}/epilogue", step.name);
                            r.record(Span::until_now(name, trace::CAT_OP, t).parent(parent));
                        }
                    }
                }
                StepOp::BatchNorm(p) => {
                    copy_into(&mut out, &slots[step.src]);
                    p.apply(&mut out, batch * h * w);
                }
                StepOp::Relu => {
                    copy_into(&mut out, &slots[step.src]);
                    ops::relu(&mut out);
                }
                StepOp::Add { other } => {
                    copy_into(&mut out, &slots[step.src]);
                    ops::add_assign(&mut out, &slots[*other]);
                }
                StepOp::MaxPool2x2 => {
                    ops::max_pool2x2(&slots[step.src], c, batch, h, w, &mut out);
                }
                StepOp::GlobalAvgPool => {
                    ops::global_avg_pool(&slots[step.src], c, batch, h * w, &mut out);
                }
                StepOp::Flatten => {
                    ops::flatten(&slots[step.src], c, batch, h * w, &mut out);
                }
            }
            debug_assert_eq!(out.len(), oc * oh * ow * batch, "step '{}'", step.name);
            slots[step.dst] = out;
            if let (Some(r), Some((id, start))) = (tr, step_span) {
                let mut span = Span::until_now(step.name.clone(), trace::CAT_STEP, start);
                span.id = id;
                span.parent = run_span.map_or(0, |(rid, _)| rid);
                r.record(span);
            }
            if timed {
                timings.push(StepTiming {
                    name: step.name.clone(),
                    ms: t0.elapsed().as_secs_f64() * 1e3,
                });
            }
        }

        let (oc, oh, ow) = net.output_shape;
        let y = im2col::act_to_nchw(&slots[net.output_slot], batch, oc, oh * ow);
        for s in slots {
            arena.release(s);
        }
        if let (Some(r), Some((id, start))) = (tr, run_span) {
            let mut span = Span::until_now(format!("net[b{batch}]"), trace::CAT_RUN, start);
            span.id = id;
            r.record(span);
        }
        Ok(y)
    }
}

/// Reuse `out`'s allocation for a copy of `src` (elementwise steps write a
/// fresh buffer without reallocating the arena slot).
fn copy_into(out: &mut Vec<f32>, src: &[f32]) {
    out.clear();
    out.extend_from_slice(src);
}

/// Execute one prunable layer's GEMM over the engine, into `y`.  `tr`
/// carries the span ring plus the enclosing step span's id; when set,
/// the im2col / spmm halves record their own `op` spans.
#[allow(clippy::too_many_arguments)]
fn run_gemm(
    engine: &Engine,
    lay: &LayerExec,
    act: &[f32],
    in_shape: (usize, usize, usize),
    batch: usize,
    fused: bool,
    tr: Option<(&TraceRing, u64)>,
    arena: &mut Arena,
    y: &mut Vec<f32>,
) -> Result<()> {
    let op_span = |start: Option<u64>, suffix: &str| {
        if let (Some((r, parent)), Some(t)) = (tr, start) {
            let name = format!("{}/{suffix}", lay.name);
            r.record(Span::until_now(name, trace::CAT_OP, t).parent(parent));
        }
    };
    let (c, h, w) = in_shape;
    match lay.kind {
        GemmKind::Conv | GemmKind::Depthwise => {
            let (kh, kw, stride) = (lay.spec.kh, lay.spec.kw, lay.spec.stride);
            if fused {
                // tile-order im2col fused into the spmm consumer: the
                // materialized X never exists
                let t0 = tr.map(|_| trace::now_ns());
                let src = Im2colPanels::new(act, c, h, w, batch, kh, kw, stride);
                engine.spmm_fused_into(lay.sparse.kernel(), &src, y);
                op_span(t0, "spmm_fused");
            } else {
                // materialized baseline: X lives in an arena-recycled
                // scratch for exactly this GEMM
                let ohw = lay.spec.out_hw();
                let t0 = tr.map(|_| trace::now_ns());
                let mut scratch = arena.take(c * kh * kw * batch * ohw * ohw);
                let (oh, ow) = im2col::im2col(act, c, h, w, batch, kh, kw, stride, &mut scratch);
                op_span(t0, "im2col");
                let t1 = tr.map(|_| trace::now_ns());
                engine.spmm_into(lay.sparse.kernel(), &scratch, batch * oh * ow, y);
                op_span(t1, "spmm");
                arena.release(scratch);
            }
        }
        GemmKind::Fc => {
            // glue guarantees [in, batch, 1] activation == [in, batch] GEMM rhs
            if act.len() != lay.spec.in_ch * batch {
                bail!(
                    "fc '{}' expects {} x batch inputs, got {}",
                    lay.name,
                    lay.spec.in_ch,
                    act.len()
                );
            }
            let t0 = tr.map(|_| trace::now_ns());
            engine.spmm_into(lay.sparse.kernel(), act, batch, y);
            op_span(t0, "spmm");
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accuracy::Assignment;
    use crate::models::zoo;
    use crate::pruning::Scheme;
    use crate::runtime::KernelChoice;

    #[test]
    fn proxy_runs_end_to_end() {
        let m = zoo::proxy_cnn();
        let assigns: Vec<Assignment> = m
            .layers
            .iter()
            .map(|l| {
                if l.is_3x3_conv() {
                    Assignment { scheme: Scheme::Pattern, compression: 2.25 }
                } else {
                    Assignment { scheme: Scheme::Block { bp: 8, bq: 2 }, compression: 2.0 }
                }
            })
            .collect();
        let net = CompiledNet::compile(&m, &assigns, 42, KernelChoice::Auto).unwrap();
        let batch = 2;
        let n = batch * 3 * 32 * 32;
        let input: Vec<f32> = (0..n).map(|i| ((i % 23) as f32) * 0.1 - 1.0).collect();
        let y = GraphExecutor::new(2).run(&net, &input, batch).unwrap();
        assert_eq!(y.len(), batch * 10);
        assert!(y.iter().all(|v| v.is_finite()));
        // fused and materialized paths are bit-for-bit identical, at any
        // tile width
        let ym = GraphExecutor::new(2).materialized().run(&net, &input, batch).unwrap();
        assert_eq!(y, ym);
        let yt = GraphExecutor::new(2).with_tile_cols(8).run(&net, &input, batch).unwrap();
        assert_eq!(y, yt);
        // wrong input length is a hard error
        assert!(GraphExecutor::serial().run(&net, &input[..n - 1], batch).is_err());
    }

    #[test]
    fn timed_run_reports_every_step() {
        let m = zoo::proxy_cnn();
        let assigns: Vec<Assignment> = m.layers.iter().map(|_| Assignment::dense()).collect();
        let net = CompiledNet::compile(&m, &assigns, 1, KernelChoice::Dense).unwrap();
        let input = vec![0.5f32; 3 * 32 * 32];
        let (y, t) = GraphExecutor::serial().run_timed(&net, &input, 1).unwrap();
        assert_eq!(y.len(), 10);
        assert_eq!(t.len(), net.steps.len());
        assert!(t.iter().all(|s| s.ms >= 0.0));
    }

    #[test]
    fn warm_arena_serves_second_run_without_allocating() {
        let m = zoo::proxy_cnn();
        let assigns: Vec<Assignment> = m.layers.iter().map(|_| Assignment::dense()).collect();
        let net = CompiledNet::compile(&m, &assigns, 3, KernelChoice::Auto).unwrap();
        let input = vec![0.25f32; 3 * 32 * 32];
        let exec = GraphExecutor::serial();
        let mut arena = Arena::new();
        let y1 = exec.run_with_arena(&net, &input, 1, &mut arena).unwrap();
        assert!(arena.stats().allocs > 0, "cold arena must allocate");
        arena.reset_stats();
        let y2 = exec.run_with_arena(&net, &input, 1, &mut arena).unwrap();
        assert_eq!(y1, y2, "arena reuse must not change results");
        let s = arena.stats();
        assert_eq!(s.allocs, 0, "warm arena still allocated: {s:?}");
        assert!(s.reuses > 0);
    }

    #[test]
    fn traced_run_records_nested_spans_without_changing_outputs() {
        let m = zoo::proxy_cnn();
        let assigns: Vec<Assignment> = m.layers.iter().map(|_| Assignment::dense()).collect();
        let net = CompiledNet::compile(&m, &assigns, 9, KernelChoice::Auto).unwrap();
        let input: Vec<f32> = (0..3 * 32 * 32).map(|i| ((i % 11) as f32) * 0.2 - 1.0).collect();
        let plain = GraphExecutor::serial().run(&net, &input, 1).unwrap();

        let ring = TraceRing::new(1024);
        let exec = GraphExecutor::serial().with_trace(Arc::clone(&ring));
        let traced = exec.run(&net, &input, 1).unwrap();
        assert_eq!(plain, traced, "tracing must not perturb the computation");

        let spans = ring.snapshot();
        let runs: Vec<_> = spans.iter().filter(|s| s.cat == trace::CAT_RUN).collect();
        let steps: Vec<_> = spans.iter().filter(|s| s.cat == trace::CAT_STEP).collect();
        let ops: Vec<_> = spans.iter().filter(|s| s.cat == trace::CAT_OP).collect();
        assert_eq!(runs.len(), 1);
        assert_eq!(steps.len(), net.steps.len(), "one step span per lowered step");
        assert!(!ops.is_empty(), "GEMM steps record im2col/spmm/epilogue sub-spans");
        let run_id = runs[0].id;
        let run_end = runs[0].start_ns + runs[0].dur_ns;
        for s in &steps {
            assert_eq!(s.parent, run_id, "step '{}' parents to the run span", s.name);
            assert!(s.start_ns >= runs[0].start_ns && s.start_ns + s.dur_ns <= run_end);
        }
        let step_ids: Vec<u64> = steps.iter().map(|s| s.id).collect();
        for o in &ops {
            assert!(step_ids.contains(&o.parent), "op '{}' parents to a step", o.name);
            assert!(o.name.contains('/'), "op names are layer/kind: {}", o.name);
        }
        // the fused conv path names its span accordingly
        assert!(ops.iter().any(|o| o.name.ends_with("/spmm_fused")), "{ops:?}");

        // a second run on the same ring appends another full span set
        exec.run(&net, &input, 1).unwrap();
        let again = ring.snapshot();
        assert_eq!(
            again.iter().filter(|s| s.cat == trace::CAT_STEP).count(),
            2 * net.steps.len()
        );
    }

    #[test]
    fn arena_take_is_cleared_and_classed() {
        let mut a = Arena::new();
        let mut v = a.take(100);
        v.resize(100, f32::NAN); // poison
        a.release(v);
        let v2 = a.take(100);
        assert!(v2.is_empty(), "reused buffers are handed out cleared");
        assert!(v2.capacity() >= 100);
        assert_eq!(a.stats(), ArenaStats { allocs: 1, reuses: 1, released: 1 });
        // zero-capacity buffers are not worth recycling
        a.release(Vec::new());
        assert_eq!(a.stats().released, 1);
    }
}
