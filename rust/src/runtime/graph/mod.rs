//! Native CNN graph executor: whole pruned networks on the sparse engine.
//!
//! Where [`super::native`] executes isolated masked-GEMM views, this
//! subsystem runs **every layer of a [`crate::models::ModelSpec`]** natively
//! on [`crate::sparse::Engine`]:
//!
//! * [`lower`] turns a fused compiler plan ([`crate::compiler::fuse`]) into
//!   a [`CompiledNet`] — compressed weights converted once into
//!   [`SparseLayer`](super::SparseLayer)s, convs lowered through
//!   [`im2col`] (stride + SAME padding; depthwise as a block-diagonal
//!   per-channel GEMM; FC passthrough), elementwise nodes either fused as
//!   epilogues or kept as standalone [`ops`] steps, and intermediate
//!   activations assigned to a small arena of slots by DAG liveness;
//! * [`GraphExecutor`] runs the program over NCHW batched input.
//!
//! **Determinism:** every GEMM column is accumulated in a fixed non-zero
//! order by the engine and all other kernels are elementwise, so the output
//! is bit-for-bit identical across thread counts *and* batch widths — the
//! same guarantee the underlying engine makes, lifted to whole networks.

pub mod im2col;
pub mod lower;
pub mod ops;

pub use lower::{
    CompiledNet, GemmKind, LayerExec, LayerSummary, MaskedLayer, NetWeights, Step, StepOp,
};
pub use ops::{BnParams, EpiOp};

use anyhow::{bail, Result};

use crate::sparse::Engine;

use super::native::NativeEngine;

/// Wall-clock of one executed step (for per-layer latency reports).
#[derive(Debug, Clone)]
pub struct StepTiming {
    pub name: String,
    pub ms: f64,
}

/// Runs a [`CompiledNet`] on the threaded native engine.
#[derive(Debug, Clone, Copy)]
pub struct GraphExecutor {
    engine: NativeEngine,
}

impl GraphExecutor {
    pub fn new(threads: usize) -> GraphExecutor {
        GraphExecutor { engine: NativeEngine::new(threads) }
    }

    pub fn serial() -> GraphExecutor {
        GraphExecutor { engine: NativeEngine::serial() }
    }

    pub fn with_engine(engine: NativeEngine) -> GraphExecutor {
        GraphExecutor { engine }
    }

    pub fn threads(&self) -> usize {
        self.engine.threads()
    }

    /// Run one batched inference.  `input` is NCHW `[batch, C, H, W]`
    /// row-major; the result is `[batch, out_features]` (NCHW-flattened
    /// per sample for spatial outputs).
    pub fn run(&self, net: &CompiledNet, input: &[f32], batch: usize) -> Result<Vec<f32>> {
        let mut sink = Vec::new();
        self.run_inner(net, input, batch, false, &mut sink)
    }

    /// [`GraphExecutor::run`] plus per-step wall-clock timings.
    pub fn run_timed(
        &self,
        net: &CompiledNet,
        input: &[f32],
        batch: usize,
    ) -> Result<(Vec<f32>, Vec<StepTiming>)> {
        let mut timings = Vec::with_capacity(net.steps.len());
        let y = self.run_inner(net, input, batch, true, &mut timings)?;
        Ok((y, timings))
    }

    fn run_inner(
        &self,
        net: &CompiledNet,
        input: &[f32],
        batch: usize,
        timed: bool,
        timings: &mut Vec<StepTiming>,
    ) -> Result<Vec<f32>> {
        if batch == 0 {
            bail!("batch must be >= 1");
        }
        let (ic, ih, iw) = net.input_shape;
        if input.len() != batch * ic * ih * iw {
            bail!(
                "input must be [batch={batch}, {ic}, {ih}, {iw}] = {} elements, got {}",
                batch * ic * ih * iw,
                input.len()
            );
        }
        // arena: slot buffers keep their allocation across steps (and the
        // im2col scratch across layers), so a run's allocation profile is
        // bounded by the liveness-derived slot count, not network depth
        let mut slots: Vec<Vec<f32>> = (0..net.num_slots).map(|_| Vec::new()).collect();
        let mut scratch: Vec<f32> = Vec::new();
        slots[net.input_slot] = im2col::nchw_to_act(input, batch, ic, ih * iw);

        let engine = self.engine.engine();
        for step in &net.steps {
            let t0 = std::time::Instant::now();
            let (c, h, w) = step.in_shape;
            // the allocator guarantees dst != src (and dst != any residual
            // slot), so taking dst's buffer out never aliases a read
            debug_assert_ne!(step.src, step.dst, "step '{}'", step.name);
            let mut out = std::mem::take(&mut slots[step.dst]);
            match &step.op {
                StepOp::Gemm { layer, epilogue } => {
                    let lay = &net.layers[*layer];
                    let mut y =
                        run_gemm(engine, lay, &slots[step.src], (c, h, w), batch, &mut scratch)?;
                    let (oc, oh, ow) = step.out_shape;
                    let cols = batch * oh * ow;
                    debug_assert_eq!(y.len(), oc * cols);
                    for e in epilogue {
                        match e {
                            EpiOp::BatchNorm(p) => p.apply(&mut y, cols),
                            EpiOp::Relu => ops::relu(&mut y),
                            EpiOp::Add { slot } => ops::add_assign(&mut y, &slots[*slot]),
                        }
                    }
                    out = y;
                }
                StepOp::BatchNorm(p) => {
                    copy_into(&mut out, &slots[step.src]);
                    p.apply(&mut out, batch * h * w);
                }
                StepOp::Relu => {
                    copy_into(&mut out, &slots[step.src]);
                    ops::relu(&mut out);
                }
                StepOp::Add { other } => {
                    copy_into(&mut out, &slots[step.src]);
                    ops::add_assign(&mut out, &slots[*other]);
                }
                StepOp::MaxPool2x2 => {
                    ops::max_pool2x2(&slots[step.src], c, batch, h, w, &mut out);
                }
                StepOp::GlobalAvgPool => {
                    ops::global_avg_pool(&slots[step.src], c, batch, h * w, &mut out);
                }
                StepOp::Flatten => {
                    ops::flatten(&slots[step.src], c, batch, h * w, &mut out);
                }
            }
            let (oc, oh, ow) = step.out_shape;
            debug_assert_eq!(out.len(), oc * oh * ow * batch, "step '{}'", step.name);
            slots[step.dst] = out;
            if timed {
                timings.push(StepTiming {
                    name: step.name.clone(),
                    ms: t0.elapsed().as_secs_f64() * 1e3,
                });
            }
        }

        let (oc, oh, ow) = net.output_shape;
        Ok(im2col::act_to_nchw(&slots[net.output_slot], batch, oc, oh * ow))
    }
}

/// Reuse `out`'s allocation for a copy of `src` (elementwise steps write a
/// fresh buffer without reallocating the arena slot).
fn copy_into(out: &mut Vec<f32>, src: &[f32]) {
    out.clear();
    out.extend_from_slice(src);
}

/// Execute one prunable layer's GEMM over the engine.
fn run_gemm(
    engine: &Engine,
    lay: &LayerExec,
    act: &[f32],
    in_shape: (usize, usize, usize),
    batch: usize,
    scratch: &mut Vec<f32>,
) -> Result<Vec<f32>> {
    let (c, h, w) = in_shape;
    match lay.kind {
        GemmKind::Conv | GemmKind::Depthwise => {
            let (oh, ow) = im2col::im2col(
                act,
                c,
                h,
                w,
                batch,
                lay.spec.kh,
                lay.spec.kw,
                lay.spec.stride,
                scratch,
            );
            Ok(engine.spmm(lay.sparse.kernel(), scratch, batch * oh * ow))
        }
        GemmKind::Fc => {
            // glue guarantees [in, batch, 1] activation == [in, batch] GEMM rhs
            if act.len() != lay.spec.in_ch * batch {
                bail!(
                    "fc '{}' expects {} x batch inputs, got {}",
                    lay.name,
                    lay.spec.in_ch,
                    act.len()
                );
            }
            Ok(engine.spmm(lay.sparse.kernel(), act, batch))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accuracy::Assignment;
    use crate::models::zoo;
    use crate::pruning::Scheme;
    use crate::runtime::KernelChoice;

    #[test]
    fn proxy_runs_end_to_end() {
        let m = zoo::proxy_cnn();
        let assigns: Vec<Assignment> = m
            .layers
            .iter()
            .map(|l| {
                if l.is_3x3_conv() {
                    Assignment { scheme: Scheme::Pattern, compression: 2.25 }
                } else {
                    Assignment { scheme: Scheme::Block { bp: 8, bq: 8 }, compression: 2.0 }
                }
            })
            .collect();
        let net = CompiledNet::compile(&m, &assigns, 42, KernelChoice::Auto).unwrap();
        let batch = 2;
        let n = batch * 3 * 32 * 32;
        let input: Vec<f32> = (0..n).map(|i| ((i % 23) as f32) * 0.1 - 1.0).collect();
        let y = GraphExecutor::new(2).run(&net, &input, batch).unwrap();
        assert_eq!(y.len(), batch * 10);
        assert!(y.iter().all(|v| v.is_finite()));
        // wrong input length is a hard error
        assert!(GraphExecutor::serial().run(&net, &input[..n - 1], batch).is_err());
    }

    #[test]
    fn timed_run_reports_every_step() {
        let m = zoo::proxy_cnn();
        let assigns: Vec<Assignment> = m.layers.iter().map(|_| Assignment::dense()).collect();
        let net = CompiledNet::compile(&m, &assigns, 1, KernelChoice::Dense).unwrap();
        let input = vec![0.5f32; 3 * 32 * 32];
        let (y, t) = GraphExecutor::serial().run_timed(&net, &input, 1).unwrap();
        assert_eq!(y.len(), 10);
        assert_eq!(t.len(), net.steps.len());
        assert!(t.iter().all(|s| s.ms >= 0.0));
    }
}
