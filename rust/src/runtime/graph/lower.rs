//! Lowering: fused compiler plan -> executable program.
//!
//! [`CompiledNet::lower`] walks a [`Graph`]'s [`FusionPlan`] in topological
//! order and emits a flat list of [`Step`]s:
//!
//! * every `Op::Layer` becomes one [`StepOp::Gemm`] over a prebuilt
//!   [`SparseLayer`] (compressed weights are converted **once** here and
//!   reused across every run) — standard convs via im2col, depthwise convs
//!   as a block-diagonal per-channel GEMM over the same im2col columns, FC
//!   as a passthrough;
//! * elementwise nodes the plan fused into a layer ride along as
//!   [`EpiOp`]s; unfused ones become standalone steps;
//! * **glue steps** (2x2 max pool / global average pool / flatten) are
//!   inserted wherever the zoo specs imply a spatial reduction between
//!   layers (`LayerSpec.in_hw` shrinking, FC consuming a conv map) — the
//!   same implicit-downsample reconciliation real CNN graphs carry as
//!   explicit pool nodes.
//!
//! Intermediate activations are assigned to **arena slots** by a linear
//! scan over buffer liveness: a step's destination reuses the slot of any
//! buffer whose last read has passed, so a deep chain like VGG-16 runs in a
//! handful of physical buffers regardless of depth.  At run time the
//! executor backs those slots with a size-classed buffer recycler
//! ([`super::Arena`]) and feeds conv GEMMs through the fused tile-order
//! im2col producer, so neither a step's replaced output buffer nor the
//! materialized im2col matrix is ever allocated per layer.

use std::collections::{BTreeMap, HashMap, HashSet};

use anyhow::{anyhow, bail, Result};

use crate::accuracy::Assignment;
use crate::compiler::fusion::FusionPlan;
use crate::compiler::ir::{Graph, Op};
use crate::models::{LayerKind, LayerSpec, ModelSpec};
use crate::pruning::{prune, PatternLibrary, Scheme};
use crate::rng::Rng;
use crate::runtime::native::{KernelChoice, SparseLayer};
use crate::tensor::Tensor;

use super::ops::{BnParams, EpiOp};

/// One masked (pruned) weight tensor in its natural layout: 4-D
/// `(F, C, KH, KW)` for conv, 4-D `(C, 1, KH, KW)` for depthwise, 2-D
/// `(in, out)` for FC.
#[derive(Debug, Clone)]
pub struct MaskedLayer {
    pub spec: LayerSpec,
    pub weight: Tensor,
    pub scheme: Scheme,
    pub compression: f32,
}

/// Weights + batch-norm statistics for a whole network.
#[derive(Debug, Clone)]
pub struct NetWeights {
    pub layers: Vec<MaskedLayer>,
    /// Per-BN-node parameters keyed by node name (`"{layer}_bn"` in the
    /// canonical inference graph); missing entries fall back to identity.
    pub bn: BTreeMap<String, BnParams>,
}

impl NetWeights {
    /// Deterministically synthesize masked weights for `model` under the
    /// per-layer `assigns` (He-normal init, one-shot magnitude masks) plus
    /// synthetic BN statistics — the stand-in for a trained checkpoint.
    pub fn synthesize(model: &ModelSpec, assigns: &[Assignment], seed: u64) -> Result<NetWeights> {
        if model.layers.len() != assigns.len() {
            bail!(
                "{} layers but {} assignments for {}",
                model.layers.len(),
                assigns.len(),
                model.name
            );
        }
        let lib = PatternLibrary::default8();
        let mut rng = Rng::new(seed);
        let mut layers = Vec::with_capacity(model.layers.len());
        let mut bn = BTreeMap::new();
        for (spec, a) in model.layers.iter().zip(assigns) {
            if !a.scheme.applicable(spec) {
                bail!("scheme {} not applicable to layer '{}'", a.scheme.label(), spec.name);
            }
            let shape: Vec<usize> = match spec.kind {
                LayerKind::Conv => vec![spec.out_ch, spec.in_ch, spec.kh, spec.kw],
                LayerKind::DepthwiseConv => vec![spec.out_ch, 1, spec.kh, spec.kw],
                LayerKind::Fc => vec![spec.in_ch, spec.out_ch],
            };
            let fan_in = match spec.kind {
                LayerKind::Conv => spec.in_ch * spec.kh * spec.kw,
                LayerKind::DepthwiseConv => spec.kh * spec.kw,
                LayerKind::Fc => spec.in_ch,
            };
            let mut lrng = rng.fork(layers.len() as u64);
            let w = Tensor::he_normal(&shape, fan_in, &mut lrng);
            let r = prune(&w, &a.scheme, a.compression, &lib);
            layers.push(MaskedLayer {
                spec: spec.clone(),
                weight: w.hadamard(&r.mask),
                scheme: a.scheme,
                compression: a.compression,
            });
            if spec.kind != LayerKind::Fc {
                bn.insert(format!("{}_bn", spec.name), BnParams::synth(spec.out_ch, &mut lrng));
            }
        }
        Ok(NetWeights { layers, bn })
    }
}

/// How a prunable layer's GEMM consumes its input.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GemmKind {
    /// im2col + `[F, C*KH*KW]` sparse weights.
    Conv,
    /// im2col + block-diagonal `[C, C*KH*KW]` per-channel weights.
    Depthwise,
    /// `[out, in]` sparse weights over `[in, batch]` input.
    Fc,
}

/// One executable prunable layer: compressed weights converted once at
/// lowering, shared by every subsequent run.
pub struct LayerExec {
    pub name: String,
    pub spec: LayerSpec,
    pub kind: GemmKind,
    pub sparse: SparseLayer,
    pub scheme: Scheme,
    pub compression: f32,
}

/// Program step kinds.
pub enum StepOp {
    /// Sparse GEMM of `layers[layer]` plus fused epilogue ops.
    Gemm { layer: usize, epilogue: Vec<EpiOp> },
    /// Standalone batch-norm.
    BatchNorm(BnParams),
    /// Standalone ReLU.
    Relu,
    /// Standalone residual add (`dst = src + slots[other]`).
    Add { other: usize },
    /// 2x2 max pool, stride 2.
    MaxPool2x2,
    /// Global average pool to 1x1.
    GlobalAvgPool,
    /// CHW flatten into FC feature order.
    Flatten,
}

/// One step of the lowered program.  `src`/`dst` (and `Add.other` /
/// `EpiOp::Add.slot`) are arena slot ids; shapes are per-sample `(C, H, W)`.
pub struct Step {
    pub name: String,
    pub op: StepOp,
    pub src: usize,
    pub dst: usize,
    pub in_shape: (usize, usize, usize),
    pub out_shape: (usize, usize, usize),
}

/// A lowered, executable network: run it with
/// [`GraphExecutor`](super::GraphExecutor).
pub struct CompiledNet {
    pub name: String,
    pub steps: Vec<Step>,
    pub layers: Vec<LayerExec>,
    /// Per-sample input shape `(C, H, W)`.
    pub input_shape: (usize, usize, usize),
    /// Per-sample output shape `(C, H, W)` — the shape of the buffer the
    /// graph's Output node consumes (not necessarily the last step's).
    pub output_shape: (usize, usize, usize),
    /// Physical arena slots the program needs.
    pub num_slots: usize,
    pub input_slot: usize,
    pub output_slot: usize,
}

/// Per-layer summary for reports (scheme, backend, sparsity).
#[derive(Debug, Clone)]
pub struct LayerSummary {
    pub name: String,
    pub scheme: String,
    pub compression: f32,
    pub backend: &'static str,
    pub rows: usize,
    pub cols: usize,
    pub nnz: usize,
}

impl CompiledNet {
    /// One-call path: canonical inference graph + fusion + synthesized
    /// weights + lowering.
    pub fn compile(
        model: &ModelSpec,
        assigns: &[Assignment],
        seed: u64,
        choice: KernelChoice,
    ) -> Result<CompiledNet> {
        Ok(Self::compile_with_weights(model, assigns, seed, choice)?.1)
    }

    /// [`CompiledNet::compile`] that also hands back the synthesized
    /// weights — the single definition of the graph -> fusion ->
    /// synthesize -> lower pipeline, shared with
    /// [`crate::serve::PreparedModel`], which seals both into its
    /// artifact.
    pub fn compile_with_weights(
        model: &ModelSpec,
        assigns: &[Assignment],
        seed: u64,
        choice: KernelChoice,
    ) -> Result<(NetWeights, CompiledNet)> {
        let graph = Graph::from_model(model);
        let plan = crate::compiler::fuse(&graph);
        let weights = NetWeights::synthesize(model, assigns, seed)?;
        let net = Self::lower(&graph, &plan, &weights, choice, &model.name)?;
        Ok((weights, net))
    }

    /// Lower a fused plan over explicit weights.
    pub fn lower(
        graph: &Graph,
        plan: &FusionPlan,
        weights: &NetWeights,
        choice: KernelChoice,
        name: &str,
    ) -> Result<CompiledNet> {
        graph.topo_check()?;
        let mut b = Lowerer::new(graph, plan, weights, choice);
        let out_buf = b.build()?;
        b.finish(name, out_buf)
    }

    /// Per-sample output element count.
    pub fn output_len(&self) -> usize {
        let (c, h, w) = self.output_shape;
        c * h * w
    }

    /// Retained non-zeros across all prunable layers.
    pub fn total_nnz(&self) -> usize {
        self.layers.iter().map(|l| l.sparse.nnz()).sum()
    }

    /// Per-layer scheme/backend summary in execution order.
    pub fn summaries(&self) -> Vec<LayerSummary> {
        self.layers
            .iter()
            .map(|l| {
                let (rows, cols) = l.sparse.dims();
                LayerSummary {
                    name: l.name.clone(),
                    scheme: l.scheme.label(),
                    compression: l.compression,
                    backend: l.sparse.backend(),
                    rows,
                    cols,
                    nnz: l.sparse.nnz(),
                }
            })
            .collect()
    }
}

/// Build state: steps over *virtual* buffer ids, later renamed to arena
/// slots by liveness.
struct Lowerer<'a> {
    graph: &'a Graph,
    plan: &'a FusionPlan,
    weights: &'a NetWeights,
    choice: KernelChoice,
    steps: Vec<Step>,
    layers: Vec<LayerExec>,
    /// node id -> virtual buffer holding its output
    node_buf: HashMap<usize, usize>,
    /// virtual buffer id -> per-sample shape
    shapes: Vec<(usize, usize, usize)>,
    input_shape: (usize, usize, usize),
    /// graph layer-node id -> index into `weights.layers`
    layer_idx: HashMap<usize, usize>,
}

impl<'a> Lowerer<'a> {
    fn new(
        graph: &'a Graph,
        plan: &'a FusionPlan,
        weights: &'a NetWeights,
        choice: KernelChoice,
    ) -> Lowerer<'a> {
        let layer_idx = graph
            .layer_nodes()
            .iter()
            .enumerate()
            .map(|(i, n)| (n.id, i))
            .collect();
        Lowerer {
            graph,
            plan,
            weights,
            choice,
            steps: Vec::new(),
            layers: Vec::new(),
            node_buf: HashMap::new(),
            shapes: Vec::new(),
            input_shape: (0, 0, 0),
            layer_idx,
        }
    }

    fn new_buf(&mut self, shape: (usize, usize, usize)) -> usize {
        self.shapes.push(shape);
        self.shapes.len() - 1
    }

    fn emit(&mut self, name: String, op: StepOp, src: usize, shape: (usize, usize, usize)) -> usize {
        let in_shape = self.shapes[src];
        let dst = self.new_buf(shape);
        self.steps.push(Step { name, op, src, dst, in_shape, out_shape: shape });
        dst
    }

    /// Emit all steps; returns the virtual buffer holding the graph output.
    fn build(&mut self) -> Result<usize> {
        let graph = self.graph;
        let plan = self.plan;
        // graph input
        let input = graph
            .nodes
            .iter()
            .find(|n| matches!(n.op, Op::Input { .. }))
            .ok_or_else(|| anyhow!("graph has no input node"))?;
        let Op::Input { shape } = &input.op else { unreachable!() };
        if shape.len() != 4 {
            bail!("input shape must be NCHW, got {shape:?}");
        }
        self.input_shape = (shape[1], shape[2], shape[3]);
        let buf = self.new_buf(self.input_shape);
        self.node_buf.insert(input.id, buf);

        // fusion kernels are emitted in anchor (= topological) order; a
        // corrupt plan referencing nodes the graph doesn't have is an
        // error, not an index panic
        for kernel in &plan.kernels {
            let anchor = graph
                .nodes
                .get(kernel.anchor)
                .ok_or_else(|| anyhow!("fusion plan anchors unknown node {}", kernel.anchor))?;
            match &anchor.op {
                Op::Layer { layer } => self.lower_layer(kernel.anchor, layer, &kernel.epilogue)?,
                Op::BatchNorm | Op::Relu | Op::Add | Op::Pool => {
                    self.lower_standalone(kernel.anchor)?
                }
                Op::Input { .. } | Op::Output => {
                    bail!("fusion plan anchored at a non-compute node '{}'", anchor.name)
                }
            }
        }

        // resolve the output buffer
        let out_node = graph.nodes.iter().find(|n| matches!(n.op, Op::Output));
        match out_node {
            Some(n) => {
                let src = *n
                    .inputs
                    .first()
                    .ok_or_else(|| anyhow!("output node has no input"))?;
                self.node_buf
                    .get(&src)
                    .copied()
                    .ok_or_else(|| anyhow!("output depends on unlowered node {src}"))
            }
            None => self
                .steps
                .last()
                .map(|s| s.dst)
                .ok_or_else(|| anyhow!("empty program")),
        }
    }

    /// Input-side glue: pool/flatten until the activation matches what the
    /// layer spec expects.
    fn glue(&mut self, mut buf: usize, spec: &LayerSpec) -> Result<usize> {
        match spec.kind {
            LayerKind::Conv | LayerKind::DepthwiseConv => {
                let (c, mut h, mut w) = self.shapes[buf];
                if c != spec.in_ch {
                    bail!(
                        "layer '{}' expects {} input channels, got {c}",
                        spec.name,
                        spec.in_ch
                    );
                }
                while h > spec.in_hw {
                    let shape = (c, h.div_ceil(2), w.div_ceil(2));
                    buf = self.emit(
                        format!("{}_pre_pool", spec.name),
                        StepOp::MaxPool2x2,
                        buf,
                        shape,
                    );
                    (h, w) = (shape.1, shape.2);
                }
                if h != spec.in_hw || w != spec.in_hw {
                    bail!(
                        "layer '{}' expects {}x{} input, got {h}x{w}",
                        spec.name,
                        spec.in_hw,
                        spec.in_hw
                    );
                }
                Ok(buf)
            }
            LayerKind::Fc => {
                loop {
                    let (c, h, w) = self.shapes[buf];
                    if c * h * w == spec.in_ch {
                        if h * w > 1 {
                            buf = self.emit(
                                format!("{}_flatten", spec.name),
                                StepOp::Flatten,
                                buf,
                                (c * h * w, 1, 1),
                            );
                        }
                        return Ok(buf);
                    }
                    if c == spec.in_ch {
                        // 1x1 handled above; >1x1 global-average pools
                        buf = self.emit(
                            format!("{}_gap", spec.name),
                            StepOp::GlobalAvgPool,
                            buf,
                            (c, 1, 1),
                        );
                        return Ok(buf);
                    }
                    if h <= 1 && w <= 1 {
                        bail!(
                            "layer '{}' expects {} input features, got {c}x{h}x{w}",
                            spec.name,
                            spec.in_ch
                        );
                    }
                    buf = self.emit(
                        format!("{}_pre_pool", spec.name),
                        StepOp::MaxPool2x2,
                        buf,
                        (c, h.div_ceil(2), w.div_ceil(2)),
                    );
                }
            }
        }
    }

    fn lower_layer(&mut self, node: usize, spec: &LayerSpec, epilogue: &[usize]) -> Result<()> {
        let graph = self.graph;
        let weights = self.weights;
        let n = &graph.nodes[node];
        let src_node = *n
            .inputs
            .first()
            .ok_or_else(|| anyhow!("layer '{}' has no input", spec.name))?;
        let src = *self
            .node_buf
            .get(&src_node)
            .ok_or_else(|| anyhow!("layer '{}' input not lowered", spec.name))?;
        let src = self.glue(src, spec)?;

        let li = *self
            .layer_idx
            .get(&node)
            .ok_or_else(|| anyhow!("no weight index for layer node {node}"))?;
        let masked = weights
            .layers
            .get(li)
            .ok_or_else(|| anyhow!("no weights for layer '{}' (index {li})", spec.name))?;
        if masked.spec.name != spec.name {
            bail!(
                "weight order mismatch: graph layer '{}' vs weights '{}'",
                spec.name,
                masked.spec.name
            );
        }
        let (kind, a) = lower_weight(masked)?;
        let sparse = SparseLayer::from_masked(&a, self.choice);
        self.layers.push(LayerExec {
            name: spec.name.clone(),
            spec: spec.clone(),
            kind,
            sparse,
            scheme: masked.scheme,
            compression: masked.compression,
        });

        // fused epilogue ops, in plan order
        let chain: HashSet<usize> =
            std::iter::once(node).chain(epilogue.iter().copied()).collect();
        let mut epi = Vec::with_capacity(epilogue.len());
        for &e in epilogue {
            let en = graph
                .nodes
                .get(e)
                .ok_or_else(|| anyhow!("fusion plan fuses unknown node {e} into '{}'", spec.name))?;
            match en.op {
                Op::BatchNorm => {
                    let p = self
                        .weights
                        .bn
                        .get(&en.name)
                        .cloned()
                        .unwrap_or_else(|| BnParams::identity(spec.out_ch));
                    if p.channels() != spec.out_ch {
                        bail!(
                            "bn '{}' has {} channels, layer '{}' outputs {}",
                            en.name,
                            p.channels(),
                            spec.name,
                            spec.out_ch
                        );
                    }
                    epi.push(EpiOp::BatchNorm(p));
                }
                Op::Relu => epi.push(EpiOp::Relu),
                Op::Add => {
                    let other = *en
                        .inputs
                        .iter()
                        .find(|i| !chain.contains(*i))
                        .ok_or_else(|| anyhow!("fused add '{}' has no residual input", en.name))?;
                    let slot = *self
                        .node_buf
                        .get(&other)
                        .ok_or_else(|| anyhow!("residual input of '{}' not lowered", en.name))?;
                    let out_shape = match spec.kind {
                        LayerKind::Fc => (spec.out_ch, 1, 1),
                        _ => (spec.out_ch, spec.out_hw(), spec.out_hw()),
                    };
                    if self.shapes[slot] != out_shape {
                        bail!(
                            "fused add '{}' shape mismatch: {:?} vs {:?}",
                            en.name,
                            self.shapes[slot],
                            out_shape
                        );
                    }
                    epi.push(EpiOp::Add { slot });
                }
                _ => bail!("non-elementwise node '{}' in epilogue", en.name),
            }
        }

        let out_shape = match spec.kind {
            LayerKind::Fc => (spec.out_ch, 1, 1),
            _ => (spec.out_ch, spec.out_hw(), spec.out_hw()),
        };
        let dst = self.emit(
            spec.name.clone(),
            StepOp::Gemm { layer: self.layers.len() - 1, epilogue: epi },
            src,
            out_shape,
        );
        self.node_buf.insert(node, dst);
        for &e in epilogue {
            self.node_buf.insert(e, dst);
        }
        Ok(())
    }

    fn lower_standalone(&mut self, node: usize) -> Result<()> {
        let graph = self.graph;
        let n = &graph.nodes[node];
        let src_node = *n
            .inputs
            .first()
            .ok_or_else(|| anyhow!("node '{}' has no input", n.name))?;
        let src = *self
            .node_buf
            .get(&src_node)
            .ok_or_else(|| anyhow!("node '{}' input not lowered", n.name))?;
        let (c, h, w) = self.shapes[src];
        let dst = match n.op {
            Op::BatchNorm => {
                let p = self
                    .weights
                    .bn
                    .get(&n.name)
                    .cloned()
                    .unwrap_or_else(|| BnParams::identity(c));
                if p.channels() != c {
                    bail!("bn '{}' has {} channels, input has {c}", n.name, p.channels());
                }
                self.emit(n.name.clone(), StepOp::BatchNorm(p), src, (c, h, w))
            }
            Op::Relu => self.emit(n.name.clone(), StepOp::Relu, src, (c, h, w)),
            Op::Add => {
                let other_node = *n
                    .inputs
                    .get(1)
                    .ok_or_else(|| anyhow!("add '{}' needs two inputs", n.name))?;
                let other = *self
                    .node_buf
                    .get(&other_node)
                    .ok_or_else(|| anyhow!("add '{}' input not lowered", n.name))?;
                if self.shapes[other] != (c, h, w) {
                    bail!(
                        "add '{}' shape mismatch: {:?} vs {:?}",
                        n.name,
                        self.shapes[other],
                        (c, h, w)
                    );
                }
                self.emit(n.name.clone(), StepOp::Add { other }, src, (c, h, w))
            }
            Op::Pool => self.emit(
                n.name.clone(),
                StepOp::MaxPool2x2,
                src,
                (c, h.div_ceil(2), w.div_ceil(2)),
            ),
            _ => bail!("unexpected standalone op '{}'", n.name),
        };
        self.node_buf.insert(node, dst);
        Ok(())
    }

    /// Rename virtual buffers to physical arena slots by liveness (linear
    /// scan: a destination takes any slot whose buffer's last read has
    /// passed).
    fn finish(mut self, name: &str, out_buf: usize) -> Result<CompiledNet> {
        let nbufs = self.shapes.len();

        // last step index reading each virtual buffer
        let mut last_read = vec![0usize; nbufs];
        for (i, s) in self.steps.iter().enumerate() {
            let mut reads = vec![s.src];
            match &s.op {
                StepOp::Add { other } => reads.push(*other),
                StepOp::Gemm { epilogue, .. } => {
                    for e in epilogue {
                        if let EpiOp::Add { slot } = e {
                            reads.push(*slot);
                        }
                    }
                }
                _ => {}
            }
            for r in reads {
                last_read[r] = i;
            }
        }
        last_read[out_buf] = usize::MAX; // never freed

        let mut phys = vec![usize::MAX; nbufs];
        let mut free: Vec<usize> = Vec::new();
        let mut num_slots = 0usize;
        let mut take = |free: &mut Vec<usize>| {
            free.pop().unwrap_or_else(|| {
                num_slots += 1;
                num_slots - 1
            })
        };
        phys[0] = take(&mut free); // input buffer, defined before step 0
        for i in 0..self.steps.len() {
            let dst = self.steps[i].dst;
            phys[dst] = take(&mut free);
            // free buffers whose last read was this step
            for (vb, &lr) in last_read.iter().enumerate() {
                if lr == i && phys[vb] != usize::MAX && vb != out_buf && vb != dst {
                    free.push(phys[vb]);
                }
            }
            free.sort_unstable(); // deterministic reuse order
        }

        // rewrite slot ids
        let remap = |v: usize| phys[v];
        for s in &mut self.steps {
            s.src = remap(s.src);
            s.dst = remap(s.dst);
            match &mut s.op {
                StepOp::Add { other } => *other = remap(*other),
                StepOp::Gemm { epilogue, .. } => {
                    for e in epilogue {
                        if let EpiOp::Add { slot } = e {
                            *slot = remap(*slot);
                        }
                    }
                }
                _ => {}
            }
        }

        Ok(CompiledNet {
            name: name.to_string(),
            output_shape: self.shapes[out_buf],
            steps: self.steps,
            layers: self.layers,
            input_shape: self.input_shape,
            num_slots,
            input_slot: phys[0],
            output_slot: phys[out_buf],
        })
    }
}

/// Turn a masked weight into the 2-D operator matrix the engine executes.
fn lower_weight(masked: &MaskedLayer) -> Result<(GemmKind, Tensor)> {
    let w = &masked.weight;
    match masked.spec.kind {
        LayerKind::Conv => {
            if w.ndim() != 4 {
                bail!("conv weight for '{}' must be 4-D", masked.spec.name);
            }
            // (F, C, KH, KW) -> [C*KH*KW, F] -> [F, C*KH*KW]
            Ok((GemmKind::Conv, w.conv_to_gemm().transpose2()))
        }
        LayerKind::DepthwiseConv => {
            if w.ndim() != 4 || w.shape()[1] != 1 {
                bail!("depthwise weight for '{}' must be (C, 1, KH, KW)", masked.spec.name);
            }
            let (c, kh, kw) = (w.shape()[0], w.shape()[2], w.shape()[3]);
            // block-diagonal [C, C*KH*KW]: row c covers its own channel's
            // im2col rows only — depthwise as per-channel blocked GEMM
            let kk = kh * kw;
            let mut a = Tensor::zeros(&[c, c * kk]);
            for ci in 0..c {
                for p in 0..kk {
                    a.set2(ci, ci * kk + p, w.at4(ci, 0, p / kw, p % kw));
                }
            }
            Ok((GemmKind::Depthwise, a))
        }
        LayerKind::Fc => {
            if w.ndim() != 2 {
                bail!("fc weight for '{}' must be 2-D (in, out)", masked.spec.name);
            }
            // (in, out) -> [out, in]
            Ok((GemmKind::Fc, w.transpose2()))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    fn simple_assigns(model: &ModelSpec) -> Vec<Assignment> {
        model
            .layers
            .iter()
            .map(|l| match l.kind {
                LayerKind::Conv if l.is_3x3_conv() => Assignment {
                    scheme: Scheme::BlockPunched { bf: 4, bc: 4 },
                    compression: 3.0,
                },
                LayerKind::Conv => Assignment {
                    scheme: Scheme::BlockPunched { bf: 4, bc: 4 },
                    compression: 2.0,
                },
                LayerKind::DepthwiseConv => Assignment::dense(),
                LayerKind::Fc => {
                    // bq=2 tiles the 10-class heads ([in, out] layout)
                    Assignment { scheme: Scheme::Block { bp: 8, bq: 2 }, compression: 2.0 }
                }
            })
            .collect()
    }

    #[test]
    fn proxy_lowering_inserts_glue_and_reuses_slots() {
        let m = zoo::proxy_cnn();
        let net =
            CompiledNet::compile(&m, &simple_assigns(&m), 1, KernelChoice::Auto).unwrap();
        // proxy: conv1(32) -> conv2(16) -> conv3(8) -> fc1(1024=64*4*4) -> fc2
        // needs pools before conv2/conv3, a pool + flatten before fc1
        let pools = net
            .steps
            .iter()
            .filter(|s| matches!(s.op, StepOp::MaxPool2x2))
            .count();
        assert_eq!(pools, 3, "expected implicit pools at 32->16->8->4");
        assert!(net.steps.iter().any(|s| matches!(s.op, StepOp::Flatten)));
        assert_eq!(net.layers.len(), 5);
        assert_eq!(net.output_len(), 10);
        // liveness keeps the arena tiny: a straight chain needs ~2-3 slots,
        // never one per step
        assert!(net.num_slots <= 3, "arena uses {} slots", net.num_slots);
        assert!(net.num_slots < net.steps.len());
    }

    #[test]
    fn mobilenet_gets_global_avg_pool_before_fc() {
        let m = zoo::mobilenet_v1_scaled(crate::models::Dataset::Cifar10, 0.25);
        let net =
            CompiledNet::compile(&m, &simple_assigns(&m), 2, KernelChoice::Auto).unwrap();
        assert!(net.steps.iter().any(|s| matches!(s.op, StepOp::GlobalAvgPool)));
        // one Gemm per prunable layer, depthwise lowered as Depthwise
        let dw = net
            .layers
            .iter()
            .filter(|l| l.kind == GemmKind::Depthwise)
            .count();
        assert_eq!(dw, 13);
    }

    #[test]
    fn depthwise_lowering_is_block_diagonal() {
        let spec = LayerSpec::dwconv("dw", 3, 4, 8, 1);
        let mut rng = Rng::new(3);
        let w = Tensor::he_normal(&[4, 1, 3, 3], 9, &mut rng);
        let masked = MaskedLayer {
            spec,
            weight: w.clone(),
            scheme: Scheme::None,
            compression: 1.0,
        };
        let (kind, a) = lower_weight(&masked).unwrap();
        assert_eq!(kind, GemmKind::Depthwise);
        assert_eq!(a.shape(), &[4, 36]);
        for c in 0..4 {
            for col in 0..36 {
                let expect = if (c * 9..(c + 1) * 9).contains(&col) {
                    w.at4(c, 0, (col - c * 9) / 3, (col - c * 9) % 3)
                } else {
                    0.0
                };
                assert_eq!(a.at2(c, col), expect);
            }
        }
    }

    #[test]
    fn channel_mismatch_is_an_error() {
        let m = ModelSpec {
            name: "bad".into(),
            dataset: crate::models::Dataset::Synthetic,
            layers: vec![
                LayerSpec::conv("c1", 3, 3, 8, 8, 1),
                LayerSpec::conv("c2", 3, 16, 8, 8, 1), // 16 != 8
            ],
        };
        let assigns = vec![Assignment::dense(), Assignment::dense()];
        let err = CompiledNet::compile(&m, &assigns, 1, KernelChoice::Auto);
        assert!(err.is_err());
    }

    #[test]
    fn synthesize_rejects_inapplicable_scheme() {
        let m = zoo::proxy_cnn();
        let mut assigns = simple_assigns(&m);
        assigns[0] = Assignment { scheme: Scheme::Block { bp: 4, bq: 4 }, compression: 2.0 };
        assert!(NetWeights::synthesize(&m, &assigns, 1).is_err());
    }
}
