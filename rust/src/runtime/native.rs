//! Native sparse runtime: the default execution path.
//!
//! Where the PJRT runtime executes AOT-compiled HLO artifacts, this runtime
//! executes the same masked-GEMM semantics directly through the batched
//! multi-threaded sparse engine ([`crate::sparse::Engine`]).  It is always
//! available (no vendored dependencies), deterministic at any thread
//! count, and is the measured counterpart the simulator's cost model is
//! compared against (`simulator::cost::measured_vs_modeled`).

use crate::sparse::{Bcs, Csr, DenseKernel, Engine, SparseKernel};
use crate::tensor::Tensor;

/// Storage format selection for a [`SparseLayer`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelChoice {
    /// Dense reference (zeros included) — baseline and fallback.
    Dense,
    /// Compressed sparse row — irregular sparsity.
    Csr,
    /// Blocked compressed storage — block/pattern-pruned layouts.
    Bcs,
    /// Pick BCS when its index overhead beats CSR's, else CSR (dense when
    /// nearly nothing is pruned).
    Auto,
}

impl KernelChoice {
    /// CLI/serialization name; inverse of [`KernelChoice::by_name`].
    pub fn name(&self) -> &'static str {
        match self {
            KernelChoice::Dense => "dense",
            KernelChoice::Csr => "csr",
            KernelChoice::Bcs => "bcs",
            KernelChoice::Auto => "auto",
        }
    }

    /// Look a kernel choice up by its CLI name (case-insensitive); `None`
    /// for unknown names.
    pub fn by_name(name: &str) -> Option<KernelChoice> {
        Some(match name.to_ascii_lowercase().as_str() {
            "dense" => KernelChoice::Dense,
            "csr" => KernelChoice::Csr,
            "bcs" => KernelChoice::Bcs,
            "auto" => KernelChoice::Auto,
            _ => return None,
        })
    }
}

/// One executable masked weight matrix (the GEMM view of a pruned layer).
pub struct SparseLayer {
    kernel: Box<dyn SparseKernel + Send>,
    rows: usize,
    cols: usize,
}

impl SparseLayer {
    /// Build from an already-masked 2-D weight (zeros = pruned).
    pub fn from_masked(w: &Tensor, choice: KernelChoice) -> SparseLayer {
        assert_eq!(w.ndim(), 2);
        let (rows, cols) = (w.shape()[0], w.shape()[1]);
        let kernel: Box<dyn SparseKernel + Send> = match choice {
            KernelChoice::Dense => Box::new(DenseKernel::from_tensor(w)),
            KernelChoice::Csr => Box::new(Csr::from_dense(w)),
            KernelChoice::Bcs => Box::new(Bcs::from_dense(w)),
            KernelChoice::Auto => {
                let total = w.len().max(1);
                if w.nnz() * 10 >= total * 9 {
                    Box::new(DenseKernel::from_tensor(w))
                } else {
                    let bcs = Bcs::from_dense(w);
                    let csr = Csr::from_dense(w);
                    if bcs.index_bytes() <= csr.index_bytes() {
                        Box::new(bcs)
                    } else {
                        Box::new(csr)
                    }
                }
            }
        };
        SparseLayer { kernel, rows, cols }
    }

    pub fn dims(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    pub fn nnz(&self) -> usize {
        self.kernel.nnz()
    }

    /// Which backend [`KernelChoice::Auto`] landed on.
    pub fn backend(&self) -> &'static str {
        self.kernel.label()
    }

    pub fn kernel(&self) -> &(dyn SparseKernel + Send) {
        &*self.kernel
    }
}

/// The native runtime: a threaded sparse engine (with its persistent
/// worker pool) plus the masked-GEMM entry points the PJRT artifacts
/// expose.  Cloning shares the pool.
#[derive(Debug, Clone)]
pub struct NativeEngine {
    engine: Engine,
}

impl NativeEngine {
    pub fn new(threads: usize) -> NativeEngine {
        NativeEngine { engine: Engine::new(threads) }
    }

    pub fn serial() -> NativeEngine {
        NativeEngine { engine: Engine::serial() }
    }

    /// One worker per available core.
    pub fn max_parallel() -> NativeEngine {
        NativeEngine { engine: Engine::max_parallel() }
    }

    /// Override the fused-im2col tile width (see
    /// [`Engine::with_tile_cols`]).
    pub fn with_tile_cols(mut self, tile: usize) -> NativeEngine {
        self.engine = self.engine.with_tile_cols(tile);
        self
    }

    pub fn threads(&self) -> usize {
        self.engine.threads()
    }

    pub fn tile_cols(&self) -> usize {
        self.engine.tile_cols()
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Native counterpart of the `block_matmul` AOT artifact:
    /// `y[m, n] = x[m, k] · (w ⊙ mask)[k, n]`, the masked weight executed
    /// as a BCS kernel.
    ///
    /// The engine computes `Yᵀ = (w ⊙ mask)ᵀ · Xᵀ` with the `m` activation
    /// rows as the batch dimension, which is exactly the layout the
    /// compiler's im2col GEMM view produces.
    ///
    /// Masking, transposition, and BCS conversion run on every call —
    /// this mirrors the artifact's one-shot signature for parity tests.
    /// For repeated inference build a [`SparseLayer`] once and call
    /// [`NativeEngine::linear`], which amortizes the conversion the way
    /// the PJRT runtime's compile cache does.
    pub fn block_matmul(&self, x: &[f32], m: usize, w: &Tensor, mask: &Tensor) -> Vec<f32> {
        assert_eq!(w.ndim(), 2);
        assert_eq!(w.shape(), mask.shape());
        let (k, n) = (w.shape()[0], w.shape()[1]);
        assert_eq!(x.len(), m * k, "x must be [m, k] row-major");
        let wm_t = w.hadamard(mask).transpose2(); // [n, k]
        let kernel = Bcs::from_dense(&wm_t);
        // x [m, k] -> X [k, m] ("[cols, batch]" with batch = m)
        let mut xt = vec![0.0f32; k * m];
        for i in 0..m {
            for kk in 0..k {
                xt[kk * m + i] = x[i * k + kk];
            }
        }
        let yt = self.engine.spmm(&kernel, &xt, m); // [n, m]
        let mut y = vec![0.0f32; m * n];
        for j in 0..n {
            for i in 0..m {
                y[i * n + j] = yt[j * m + i];
            }
        }
        y
    }

    /// Batched linear layer: `Y = W · X` with `X` `[cols, batch]`
    /// row-major, `Y` `[rows, batch]`.
    pub fn linear(&self, layer: &SparseLayer, x: &[f32], batch: usize) -> Vec<f32> {
        self.engine.spmm(layer.kernel(), x, batch)
    }

    /// Linear + ReLU, the fused epilogue the compiler emits for hidden
    /// layers.
    pub fn linear_relu(&self, layer: &SparseLayer, x: &[f32], batch: usize) -> Vec<f32> {
        let mut y = self.linear(layer, x, batch);
        for v in &mut y {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::{prune, PatternLibrary, Scheme};
    use crate::rng::Rng;

    #[test]
    fn block_matmul_matches_host_math() {
        // the same checkerboard case the PJRT artifact test pins
        let (m, k, n) = (6, 12, 9);
        let x = vec![1.0f32; m * k];
        let mut w = Tensor::zeros(&[k, n]);
        for i in 0..k.min(n) {
            w.set2(i, i, 2.0);
        }
        let mask_data: Vec<f32> = (0..k * n).map(|i| ((i / n) % 2) as f32).collect();
        let mask = Tensor::from_vec(&[k, n], mask_data);
        let y = NativeEngine::new(3).block_matmul(&x, m, &w, &mask);
        assert_eq!(y.len(), m * n);
        for j in 0..n {
            let expect: f32 = (0..k).map(|kk| w.at2(kk, j) * mask.at2(kk, j)).sum();
            assert!((y[j] - expect).abs() < 1e-4, "col {j}: got {} want {expect}", y[j]);
        }
    }

    #[test]
    fn block_matmul_thread_count_invariant() {
        let mut rng = Rng::new(11);
        let (m, k, n) = (8, 24, 16);
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let w = Tensor::he_normal(&[k, n], k, &mut rng);
        let mask_data: Vec<f32> =
            (0..k * n).map(|_| rng.bernoulli(0.4) as u8 as f32).collect();
        let mask = Tensor::from_vec(&[k, n], mask_data);
        let serial = NativeEngine::serial().block_matmul(&x, m, &w, &mask);
        let threaded = NativeEngine::new(8).block_matmul(&x, m, &w, &mask);
        assert_eq!(serial, threaded);
    }

    #[test]
    fn auto_choice_prefers_bcs_on_reordered_block_punched() {
        // the paper's pipeline: punched mask -> GEMM view -> row reorder
        // groups identical column patterns, which is where BCS's compact
        // index wins over CSR
        use crate::sparse::{permute_rows, reorder_rows};
        let mut rng = Rng::new(12);
        let w = Tensor::he_normal(&[64, 64, 3, 3], 64 * 9, &mut rng);
        let r = prune(
            &w,
            &Scheme::BlockPunched { bf: 8, bc: 8 },
            4.0,
            &PatternLibrary::default8(),
        );
        let gemm = w.hadamard(&r.mask).conv_to_gemm();
        let masked = permute_rows(&gemm, &reorder_rows(&gemm));
        let layer = SparseLayer::from_masked(&masked, KernelChoice::Auto);
        assert_eq!(layer.backend(), "bcs");
        assert_eq!(layer.dims(), (64 * 9, 64));
        assert_eq!(layer.nnz(), masked.nnz());
        // near-dense input falls back to the dense kernel
        let dense = Tensor::he_normal(&[32, 32], 32, &mut rng);
        let dense_layer = SparseLayer::from_masked(&dense, KernelChoice::Auto);
        assert_eq!(dense_layer.backend(), "dense");
    }

    #[test]
    fn kernel_choice_names_roundtrip() {
        for c in [KernelChoice::Dense, KernelChoice::Csr, KernelChoice::Bcs, KernelChoice::Auto] {
            assert_eq!(KernelChoice::by_name(c.name()), Some(c));
        }
        assert_eq!(KernelChoice::by_name("AUTO"), Some(KernelChoice::Auto));
        assert_eq!(KernelChoice::by_name("coo"), None);
    }

    #[test]
    fn linear_relu_clamps() {
        let w = Tensor::from_vec(&[2, 2], vec![1.0, 0.0, -1.0, 0.0]);
        let layer = SparseLayer::from_masked(&w, KernelChoice::Csr);
        let eng = NativeEngine::serial();
        let y = eng.linear(&layer, &[3.0, 2.0], 1);
        assert_eq!(y, vec![3.0, -3.0]);
        let yr = eng.linear_relu(&layer, &[3.0, 2.0], 1);
        assert_eq!(yr, vec![3.0, 0.0]);
    }
}
