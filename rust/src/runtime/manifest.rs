//! Typed view of `artifacts/manifest.json` (emitted by python/compile/aot.py).

use std::collections::HashMap;

use anyhow::Result;

use crate::util::json::Value;

/// A named parameter tensor of the proxy model.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    /// "conv" | "fc" | "bias"
    pub kind: String,
    pub shape: Vec<usize>,
}

/// Input/output signature of one AOT artifact.
#[derive(Debug, Clone)]
pub struct ArtifactSig {
    pub file: String,
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
    pub m: Option<usize>,
    pub k: Option<usize>,
    pub n: Option<usize>,
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub batch: usize,
    pub img: usize,
    pub in_ch: usize,
    pub num_classes: usize,
    pub params: Vec<ParamSpec>,
    pub weight_idx: Vec<usize>,
    pub weight_names: Vec<String>,
    pub artifacts: HashMap<String, ArtifactSig>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Value::parse(text)?;
        let params = v
            .get("params")?
            .as_arr()?
            .iter()
            .map(|p| {
                Ok(ParamSpec {
                    name: p.get("name")?.as_str()?.to_string(),
                    kind: p.get("kind")?.as_str()?.to_string(),
                    shape: p.get("shape")?.usize_vec()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let mut artifacts = HashMap::new();
        for (name, a) in v.get("artifacts")?.as_obj()? {
            artifacts.insert(
                name.clone(),
                ArtifactSig {
                    file: a.get("file")?.as_str()?.to_string(),
                    inputs: a.get("inputs")?.str_vec()?,
                    outputs: a.get("outputs")?.str_vec()?,
                    m: a.opt("m").map(|x| x.as_usize()).transpose()?,
                    k: a.opt("k").map(|x| x.as_usize()).transpose()?,
                    n: a.opt("n").map(|x| x.as_usize()).transpose()?,
                },
            );
        }
        Ok(Manifest {
            batch: v.get("batch")?.as_usize()?,
            img: v.get("img")?.as_usize()?,
            in_ch: v.get("in_ch")?.as_usize()?,
            num_classes: v.get("num_classes")?.as_usize()?,
            params,
            weight_idx: v.get("weight_idx")?.usize_vec()?,
            weight_names: v.get("weight_names")?.str_vec()?,
            artifacts,
        })
    }

    /// Shape of a parameter by name.
    pub fn param_shape(&self, name: &str) -> Option<&[usize]> {
        self.params
            .iter()
            .find(|p| p.name == name)
            .map(|p| p.shape.as_slice())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_manifest() {
        let json = r#"{
            "batch": 8, "img": 32, "in_ch": 3, "num_classes": 10,
            "params": [{"name": "w", "kind": "fc", "shape": [4, 2], "dtype": "f32"}],
            "weight_idx": [0],
            "weight_names": ["w"],
            "artifacts": {"fwd": {"file": "f.hlo.txt", "inputs": ["w"], "outputs": ["y"]}},
            "weights": [{"name": "w", "shape": [4, 2], "dtype": "f32"}]
        }"#;
        let m = Manifest::parse(json).unwrap();
        assert_eq!(m.param_shape("w"), Some(&[4usize, 2][..]));
        assert!(m.artifacts.contains_key("fwd"));
        assert_eq!(m.artifacts["fwd"].m, None);
        assert_eq!(m.batch, 8);
    }

    #[test]
    fn missing_key_errors() {
        assert!(Manifest::parse(r#"{"batch": 8}"#).is_err());
    }

    #[test]
    fn parses_param_and_artifact_fields() {
        let json = r#"{
            "batch": 4, "img": 28, "in_ch": 1, "num_classes": 7,
            "params": [
                {"name": "conv1_w", "kind": "conv", "shape": [16, 1, 3, 3]},
                {"name": "fc_w", "kind": "fc", "shape": [784, 7]},
                {"name": "fc_b", "kind": "bias", "shape": [7]}
            ],
            "weight_idx": [0, 1],
            "weight_names": ["conv1_w", "fc_w"],
            "artifacts": {
                "block_matmul": {
                    "file": "block_matmul.hlo.txt",
                    "inputs": ["x", "w", "mask"],
                    "outputs": ["y"],
                    "m": 256, "k": 512, "n": 512
                },
                "fwd": {"file": "fwd.hlo.txt", "inputs": ["x"], "outputs": ["logits"]}
            }
        }"#;
        let m = Manifest::parse(json).unwrap();
        assert_eq!((m.batch, m.img, m.in_ch, m.num_classes), (4, 28, 1, 7));

        // ParamSpec: name/kind/shape survive, lookup by name works
        assert_eq!(m.params.len(), 3);
        assert_eq!(m.params[0].kind, "conv");
        assert_eq!(m.params[0].shape, vec![16, 1, 3, 3]);
        assert_eq!(m.params[2].kind, "bias");
        assert_eq!(m.param_shape("fc_w"), Some(&[784usize, 7][..]));
        assert_eq!(m.param_shape("nope"), None);
        assert_eq!(m.weight_idx, vec![0, 1]);
        assert_eq!(m.weight_names, vec!["conv1_w".to_string(), "fc_w".to_string()]);

        // ArtifactSig: file/inputs/outputs plus the optional GEMM dims
        let bm = &m.artifacts["block_matmul"];
        assert_eq!(bm.file, "block_matmul.hlo.txt");
        assert_eq!(bm.inputs, vec!["x".to_string(), "w".to_string(), "mask".to_string()]);
        assert_eq!(bm.outputs, vec!["y".to_string()]);
        assert_eq!((bm.m, bm.k, bm.n), (Some(256), Some(512), Some(512)));
        let fwd = &m.artifacts["fwd"];
        assert_eq!((fwd.m, fwd.k, fwd.n), (None, None, None));
    }

    #[test]
    fn malformed_manifests_error() {
        // truncated document
        assert!(Manifest::parse(r#"{"batch": 8, "img": 32"#).is_err());
        // params must be an array of objects with string names
        assert!(Manifest::parse(
            r#"{
                "batch": 1, "img": 8, "in_ch": 1, "num_classes": 2,
                "params": {"name": "w"},
                "weight_idx": [], "weight_names": [], "artifacts": {}
            }"#
        )
        .is_err());
        // shapes must be non-negative integers
        assert!(Manifest::parse(
            r#"{
                "batch": 1, "img": 8, "in_ch": 1, "num_classes": 2,
                "params": [{"name": "w", "kind": "fc", "shape": [4, -2]}],
                "weight_idx": [], "weight_names": [], "artifacts": {}
            }"#
        )
        .is_err());
        // artifacts must be an object of signatures with inputs/outputs
        assert!(Manifest::parse(
            r#"{
                "batch": 1, "img": 8, "in_ch": 1, "num_classes": 2,
                "params": [], "weight_idx": [], "weight_names": [],
                "artifacts": {"fwd": {"file": "f.hlo.txt", "inputs": ["x"]}}
            }"#
        )
        .is_err());
        // a non-integral batch is rejected by the usize accessor
        assert!(Manifest::parse(
            r#"{
                "batch": 1.5, "img": 8, "in_ch": 1, "num_classes": 2,
                "params": [], "weight_idx": [], "weight_names": [], "artifacts": {}
            }"#
        )
        .is_err());
    }
}
