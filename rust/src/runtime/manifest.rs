//! Typed view of `artifacts/manifest.json` (emitted by python/compile/aot.py).

use std::collections::HashMap;

use anyhow::Result;

use crate::util::json::Value;

/// A named parameter tensor of the proxy model.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    /// "conv" | "fc" | "bias"
    pub kind: String,
    pub shape: Vec<usize>,
}

/// Input/output signature of one AOT artifact.
#[derive(Debug, Clone)]
pub struct ArtifactSig {
    pub file: String,
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
    pub m: Option<usize>,
    pub k: Option<usize>,
    pub n: Option<usize>,
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub batch: usize,
    pub img: usize,
    pub in_ch: usize,
    pub num_classes: usize,
    pub params: Vec<ParamSpec>,
    pub weight_idx: Vec<usize>,
    pub weight_names: Vec<String>,
    pub artifacts: HashMap<String, ArtifactSig>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Value::parse(text)?;
        let params = v
            .get("params")?
            .as_arr()?
            .iter()
            .map(|p| {
                Ok(ParamSpec {
                    name: p.get("name")?.as_str()?.to_string(),
                    kind: p.get("kind")?.as_str()?.to_string(),
                    shape: p.get("shape")?.usize_vec()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let mut artifacts = HashMap::new();
        for (name, a) in v.get("artifacts")?.as_obj()? {
            artifacts.insert(
                name.clone(),
                ArtifactSig {
                    file: a.get("file")?.as_str()?.to_string(),
                    inputs: a.get("inputs")?.str_vec()?,
                    outputs: a.get("outputs")?.str_vec()?,
                    m: a.opt("m").map(|x| x.as_usize()).transpose()?,
                    k: a.opt("k").map(|x| x.as_usize()).transpose()?,
                    n: a.opt("n").map(|x| x.as_usize()).transpose()?,
                },
            );
        }
        Ok(Manifest {
            batch: v.get("batch")?.as_usize()?,
            img: v.get("img")?.as_usize()?,
            in_ch: v.get("in_ch")?.as_usize()?,
            num_classes: v.get("num_classes")?.as_usize()?,
            params,
            weight_idx: v.get("weight_idx")?.usize_vec()?,
            weight_names: v.get("weight_names")?.str_vec()?,
            artifacts,
        })
    }

    /// Shape of a parameter by name.
    pub fn param_shape(&self, name: &str) -> Option<&[usize]> {
        self.params
            .iter()
            .find(|p| p.name == name)
            .map(|p| p.shape.as_slice())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_manifest() {
        let json = r#"{
            "batch": 8, "img": 32, "in_ch": 3, "num_classes": 10,
            "params": [{"name": "w", "kind": "fc", "shape": [4, 2], "dtype": "f32"}],
            "weight_idx": [0],
            "weight_names": ["w"],
            "artifacts": {"fwd": {"file": "f.hlo.txt", "inputs": ["w"], "outputs": ["y"]}},
            "weights": [{"name": "w", "shape": [4, 2], "dtype": "f32"}]
        }"#;
        let m = Manifest::parse(json).unwrap();
        assert_eq!(m.param_shape("w"), Some(&[4usize, 2][..]));
        assert!(m.artifacts.contains_key("fwd"));
        assert_eq!(m.artifacts["fwd"].m, None);
        assert_eq!(m.batch, 8);
    }

    #[test]
    fn missing_key_errors() {
        assert!(Manifest::parse(r#"{"batch": 8}"#).is_err());
    }
}
