//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! This is the bridge between the Rust coordinator and the Layer-1/2
//! compute: `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`.  Artifacts are produced once by
//! `make artifacts` (python/compile/aot.py) together with `manifest.json`
//! describing each artifact's input/output signature; Python never runs at
//! request time.
//!
//! HLO **text** is the interchange format: jax >= 0.5 serializes protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects, while the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! Compiled only under `--cfg pjrt`: the `xla` bindings are not on
//! crates.io and must be vendored as a path dependency first, e.g.
//!
//! ```toml
//! [dependencies]
//! xla = { path = "../vendor/xla-rs" }
//! ```
//!
//! then `RUSTFLAGS="--cfg pjrt" cargo build --release`.  The default build
//! uses [`super::native`] instead.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

use super::manifest::{ArtifactSig, Manifest};
use super::HostValue;

/// Convert an `xla::Error` into an `anyhow` report.
fn xerr(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e:?}")
}

/// Lower a host value to an XLA literal.
fn to_literal(v: &HostValue) -> Result<xla::Literal> {
    let lit = match v {
        HostValue::F32 { shape, data } => {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            xla::Literal::vec1(data).reshape(&dims).map_err(xerr)?
        }
        HostValue::I32 { shape, data } => {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            xla::Literal::vec1(data).reshape(&dims).map_err(xerr)?
        }
    };
    Ok(lit)
}

/// A compiled artifact ready to execute.
pub struct Executable {
    name: String,
    exe: xla::PjRtLoadedExecutable,
    sig: ArtifactSig,
}

impl Executable {
    /// Execute with host values; returns the flattened output tuple as f32
    /// vectors (all our artifact outputs are f32).
    pub fn run(&self, inputs: &[HostValue]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.sig.inputs.len() {
            return Err(anyhow!(
                "artifact '{}' expects {} inputs, got {}",
                self.name,
                self.sig.inputs.len(),
                inputs.len()
            ));
        }
        let literals: Vec<xla::Literal> =
            inputs.iter().map(to_literal).collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals).map_err(xerr)?;
        let tuple = result[0][0].to_literal_sync().map_err(xerr)?;
        let parts = tuple.to_tuple().map_err(xerr)?;
        if parts.len() != self.sig.outputs.len() {
            return Err(anyhow!(
                "artifact '{}' returned {} outputs, manifest says {}",
                self.name,
                parts.len(),
                self.sig.outputs.len()
            ));
        }
        parts
            .into_iter()
            .map(|l| l.to_vec::<f32>().map_err(xerr))
            .collect()
    }

    pub fn signature(&self) -> &ArtifactSig {
        &self.sig
    }

    pub fn name(&self) -> &str {
        &self.name
    }
}

/// The PJRT runtime: one CPU client + the artifact manifest + a compile
/// cache so each artifact is compiled exactly once per process.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
}

impl Runtime {
    /// Open the artifacts directory (default `artifacts/`); reads
    /// `manifest.json` and creates the PJRT CPU client.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let manifest = Manifest::parse(&text).context("parsing manifest.json")?;
        let client = xla::PjRtClient::cpu().map_err(xerr)?;
        Ok(Self { client, dir, manifest, cache: Mutex::new(HashMap::new()) })
    }

    /// Locate `artifacts/` relative to the crate root (env override:
    /// `PRUNEMAP_ARTIFACTS`).
    pub fn default_dir() -> PathBuf {
        if let Ok(p) = std::env::var("PRUNEMAP_ARTIFACTS") {
            return PathBuf::from(p);
        }
        let mut d = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
        d.push("artifacts");
        d
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (compile-once, cached) an artifact by manifest key, e.g.
    /// `"train_step"`.
    pub fn load(&self, key: &str) -> Result<std::sync::Arc<Executable>> {
        if let Some(e) = crate::util::recover(self.cache.lock()).get(key) {
            return Ok(e.clone());
        }
        let sig = self
            .manifest
            .artifacts
            .get(key)
            .ok_or_else(|| anyhow!("unknown artifact '{key}'"))?
            .clone();
        let path = self.dir.join(&sig.file);
        let proto = xla::HloModuleProto::from_text_file(&path).map_err(xerr)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(xerr)?;
        let executable =
            std::sync::Arc::new(Executable { name: key.to_string(), exe, sig });
        crate::util::recover(self.cache.lock())
            .insert(key.to_string(), executable.clone());
        Ok(executable)
    }
}
