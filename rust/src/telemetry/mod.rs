//! Observability for the serving stack: metrics out, trace spans down.
//!
//! Two halves, deliberately decoupled from the code they observe:
//!
//! * **Metrics** ([`metrics`], [`export`]) — the per-model
//!   [`SessionStats`](crate::serve::SessionStats) counters that already
//!   exist, plus lock-free wire-layer counters ([`WireCounters`]),
//!   rendered in Prometheus text exposition format.  The document is
//!   reachable three ways: in-process via
//!   [`Server::metrics_text`](crate::serve::Server::metrics_text), over
//!   the line-JSON wire protocol as a `metrics` admin frame, and over a
//!   dedicated scrape listener (`prunemap serve --metrics ADDR`, backed
//!   by [`serve_text`]).
//! * **Traces** ([`trace`]) — a bounded always-on span ring
//!   ([`TraceRing`]) that the session workers and the graph executor
//!   feed: queue-wait, batch assembly, whole runs, each lowered graph
//!   step, and the im2col/spmm/epilogue sub-ops inside it.  Snapshots
//!   export as Chrome trace-event JSON (`--trace-out`, `prunemap
//!   profile`), and the per-layer means feed
//!   [`simulator::cost`](crate::simulator::cost) calibration records.
//!
//! Everything here is pay-for-what-you-attach: with no ring attached
//! the executor's hot path takes an untaken `None` branch, and the
//! metrics renderers only run when something asks for the document.

pub mod export;
pub mod metrics;
pub mod trace;

pub use export::{render_server_metrics, render_session_stats, MODEL_FAMILIES, WIRE_FAMILIES};
pub use metrics::{parse_exposition, serve_text, PromWriter, WireCounters, WireSnapshot};
pub use trace::{chrome_trace_json, Span, TraceRing};
