//! Render serving-stack state for humans and scrapers.
//!
//! [`render_server_metrics`] turns the per-model
//! [`SessionStats`](crate::serve::SessionStats) snapshots plus the wire
//! [`WireSnapshot`] into one Prometheus text document — the existing
//! session counters are *re-exported* through here, never duplicated
//! into a second accounting path.  [`render_session_stats`] is the one
//! text renderer for a session's counters, shared by the `serve` CLI
//! summary and anything else that wants the human-readable block (it
//! used to live as a private formatter in `main.rs`).

use std::collections::BTreeMap;

use crate::serve::session::{wait_bucket_labels, SessionStats, WAIT_BUCKET_BOUNDS_US};
use crate::telemetry::metrics::{PromWriter, WireSnapshot, WIRE_ERROR_KINDS};

/// Names of the per-model metric families [`render_server_metrics`]
/// always emits — CI and tests assert against this list rather than
/// re-typing family names.
pub const MODEL_FAMILIES: [&str; 10] = [
    "prunemap_requests_total",
    "prunemap_runs_total",
    "prunemap_padded_lanes_total",
    "prunemap_expired_total",
    "prunemap_shed_overload_total",
    "prunemap_queue_depth_hwm",
    "prunemap_max_coalesced",
    "prunemap_queue_wait_seconds",
    "prunemap_batch_width_runs_total",
    "prunemap_batch_occupancy_runs_total",
];

/// Names of the wire-layer families [`render_server_metrics`] always
/// emits.
pub const WIRE_FAMILIES: [&str; 10] = [
    "prunemap_wire_connections_total",
    "prunemap_wire_active_connections",
    "prunemap_wire_frames_total",
    "prunemap_wire_served_frames_total",
    "prunemap_wire_error_frames_total",
    "prunemap_wire_admin_frames_total",
    "prunemap_wire_malformed_lines_total",
    "prunemap_wire_shed_total",
    "prunemap_wire_conn_setup_failed_total",
    "prunemap_wire_accept_retries_total",
];

/// Render every registered model's session counters plus the wire-layer
/// counters as one Prometheus text exposition document.
pub fn render_server_metrics(
    stats: &BTreeMap<String, SessionStats>,
    wire: &WireSnapshot,
) -> String {
    let mut w = PromWriter::new();

    w.family(
        "prunemap_requests_total",
        "counter",
        "Requests served, by model and priority lane.",
    );
    for (model, st) in stats {
        for (lane, &n) in ["high", "normal"].iter().zip(st.served_by_priority.iter()) {
            w.sample(
                "prunemap_requests_total",
                &[("model", model), ("priority", lane)],
                n as f64,
            );
        }
    }

    w.family("prunemap_runs_total", "counter", "Executor batch runs, by model.");
    w.family(
        "prunemap_padded_lanes_total",
        "counter",
        "Batch lanes padded to reach lane alignment, by model.",
    );
    w.family(
        "prunemap_expired_total",
        "counter",
        "Requests rejected by deadline admission, by model.",
    );
    w.family(
        "prunemap_shed_overload_total",
        "counter",
        "Submits shed at the queue-depth high-water mark, by model.",
    );
    w.family(
        "prunemap_queue_depth_hwm",
        "gauge",
        "High-water mark of the submit queue depth, by model.",
    );
    w.family(
        "prunemap_max_coalesced",
        "gauge",
        "Largest number of requests coalesced into one run, by model.",
    );
    for (model, st) in stats {
        let labels = [("model", model.as_str())];
        w.sample("prunemap_runs_total", &labels, st.runs as f64);
        w.sample("prunemap_padded_lanes_total", &labels, st.padded_lanes as f64);
        w.sample("prunemap_expired_total", &labels, st.expired as f64);
        w.sample("prunemap_shed_overload_total", &labels, st.shed_overload as f64);
        w.sample("prunemap_queue_depth_hwm", &labels, st.queue_depth_hwm as f64);
        w.sample("prunemap_max_coalesced", &labels, st.max_coalesced as f64);
    }

    w.family(
        "prunemap_queue_wait_seconds",
        "histogram",
        "Wait between request submit and batch assembly, by model.",
    );
    for (model, st) in stats {
        let mut cumulative = 0usize;
        for (&bound_us, &n) in WAIT_BUCKET_BOUNDS_US.iter().zip(st.wait_buckets.iter()) {
            cumulative += n;
            let le = (bound_us as f64 / 1e6).to_string();
            w.sample(
                "prunemap_queue_wait_seconds_bucket",
                &[("model", model), ("le", &le)],
                cumulative as f64,
            );
        }
        let total: usize = st.wait_buckets.iter().sum();
        w.sample(
            "prunemap_queue_wait_seconds_bucket",
            &[("model", model), ("le", "+Inf")],
            total as f64,
        );
        w.sample("prunemap_queue_wait_seconds_count", &[("model", model)], total as f64);
        w.sample(
            "prunemap_queue_wait_seconds_sum",
            &[("model", model)],
            st.wait_total_us as f64 / 1e6,
        );
    }

    w.family(
        "prunemap_batch_width_runs_total",
        "counter",
        "Runs by executed (lane-aligned) batch width.",
    );
    for (model, st) in stats {
        for (batch, runs) in &st.batch_runs {
            let width = batch.to_string();
            w.sample(
                "prunemap_batch_width_runs_total",
                &[("model", model), ("width", &width)],
                *runs as f64,
            );
        }
    }

    w.family(
        "prunemap_batch_occupancy_runs_total",
        "counter",
        "Runs by real request count before lane padding.",
    );
    for (model, st) in stats {
        for (occupancy, runs) in &st.batch_occupancy {
            let occ = occupancy.to_string();
            w.sample(
                "prunemap_batch_occupancy_runs_total",
                &[("model", model), ("occupancy", &occ)],
                *runs as f64,
            );
        }
    }

    w.family(
        "prunemap_wire_connections_total",
        "counter",
        "Wire connections accepted since startup.",
    );
    w.sample("prunemap_wire_connections_total", &[], wire.connections as f64);
    w.family("prunemap_wire_active_connections", "gauge", "Wire connections currently open.");
    w.sample("prunemap_wire_active_connections", &[], wire.active as f64);
    w.family("prunemap_wire_frames_total", "counter", "Non-blank request lines read.");
    w.sample("prunemap_wire_frames_total", &[], wire.frames as f64);
    w.family(
        "prunemap_wire_served_frames_total",
        "counter",
        "Successful inference replies written.",
    );
    w.sample("prunemap_wire_served_frames_total", &[], wire.served as f64);
    w.family(
        "prunemap_wire_error_frames_total",
        "counter",
        "Error replies written, by stable error kind.",
    );
    for (kind, &n) in WIRE_ERROR_KINDS.iter().zip(wire.error_kinds.iter()) {
        w.sample("prunemap_wire_error_frames_total", &[("kind", kind)], n as f64);
    }
    w.family(
        "prunemap_wire_admin_frames_total",
        "counter",
        "Admin (stats/metrics) replies written.",
    );
    w.sample("prunemap_wire_admin_frames_total", &[], wire.admin as f64);
    w.family(
        "prunemap_wire_malformed_lines_total",
        "counter",
        "Request lines that failed frame decoding.",
    );
    w.sample("prunemap_wire_malformed_lines_total", &[], wire.malformed as f64);
    w.family(
        "prunemap_wire_shed_total",
        "counter",
        "Connections shed at accept time because the pool was full.",
    );
    w.sample("prunemap_wire_shed_total", &[], wire.shed_conns as f64);
    w.family(
        "prunemap_wire_conn_setup_failed_total",
        "counter",
        "Accepted connections dropped because setup failed.",
    );
    w.sample("prunemap_wire_conn_setup_failed_total", &[], wire.conn_setup_failed as f64);
    w.family(
        "prunemap_wire_accept_retries_total",
        "counter",
        "Transient accept failures retried with backoff.",
    );
    w.sample("prunemap_wire_accept_retries_total", &[], wire.accept_retries as f64);

    w.finish()
}

/// One model's admission counters as the human-readable block the
/// `serve` CLI prints: throughput shape, queue pressure, and wait-time
/// distribution.
pub fn render_session_stats(model: &str, st: &SessionStats) -> String {
    let mut out = format!(
        "model {model}: {} request(s) in {} run(s) | max coalesced {} | {:.2} requests/run | {} padded lanes | queue depth hwm {} | high/normal {}/{} | {} expired | {} shed\n",
        st.requests,
        st.runs,
        st.max_coalesced,
        st.requests as f64 / st.runs.max(1) as f64,
        st.padded_lanes,
        st.queue_depth_hwm,
        st.served_by_priority[0],
        st.served_by_priority[1],
        st.expired,
        st.shed_overload
    );
    for (batch, runs) in &st.batch_runs {
        out.push_str(&format!("  executed batch {batch:>4}: {runs} run(s)\n"));
    }
    for (occupancy, runs) in &st.batch_occupancy {
        out.push_str(&format!("  occupancy {occupancy:>4}: {runs} run(s)\n"));
    }
    let waits: Vec<String> = wait_bucket_labels()
        .iter()
        .zip(st.wait_buckets.iter())
        .filter(|(_, &n)| n > 0)
        .map(|(label, n)| format!("{label}={n}"))
        .collect();
    if !waits.is_empty() {
        out.push_str(&format!("  wait: {}\n", waits.join(" ")));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::metrics::parse_exposition;

    fn sample_stats() -> SessionStats {
        SessionStats {
            requests: 7,
            runs: 3,
            padded_lanes: 5,
            max_coalesced: 4,
            batch_runs: [(8, 3)].into_iter().collect(),
            batch_occupancy: [(1, 1), (2, 1), (4, 1)].into_iter().collect(),
            queue_depth_hwm: 4,
            wait_buckets: [3, 2, 1, 1, 0],
            wait_total_us: 12_500,
            served_by_priority: [2, 5],
            expired: 1,
            shed_overload: 2,
        }
    }

    #[test]
    fn exported_metrics_parse_and_cover_every_family() {
        let stats: BTreeMap<String, SessionStats> =
            [("proxy".to_string(), sample_stats())].into_iter().collect();
        let mut wire = WireSnapshot { connections: 2, frames: 9, served: 7, ..Default::default() };
        wire.error_kinds[1] = 2;
        wire.errors = 2;
        let text = render_server_metrics(&stats, &wire);
        let fams = parse_exposition(&text).expect("exporter output is valid exposition text");
        for name in MODEL_FAMILIES.iter().chain(WIRE_FAMILIES.iter()) {
            let fam = fams.get(*name).unwrap_or_else(|| panic!("family '{name}' missing"));
            assert!(!fam.help.is_empty() && !fam.kind.is_empty(), "family '{name}' headers");
            assert!(!fam.samples.is_empty(), "family '{name}' has no samples");
        }
        assert_eq!(fams.len(), MODEL_FAMILIES.len() + WIRE_FAMILIES.len(), "no stray families");
    }

    #[test]
    fn wait_histogram_buckets_are_cumulative_with_inf_equal_to_count() {
        let stats: BTreeMap<String, SessionStats> =
            [("proxy".to_string(), sample_stats())].into_iter().collect();
        let text = render_server_metrics(&stats, &WireSnapshot::default());
        let fams = parse_exposition(&text).unwrap();
        let hist = &fams["prunemap_queue_wait_seconds"];
        let bucket = |le: &str| -> f64 {
            hist.samples
                .iter()
                .find(|s| s.name.ends_with("_bucket") && s.label("le") == Some(le))
                .unwrap_or_else(|| panic!("bucket le={le}"))
                .value
        };
        // wait_buckets [3,2,1,1,0] -> cumulative 3,5,6,7 and +Inf = 7
        assert_eq!(bucket("0.0001"), 3.0);
        assert_eq!(bucket("0.001"), 5.0);
        assert_eq!(bucket("0.01"), 6.0);
        assert_eq!(bucket("0.1"), 7.0);
        assert_eq!(bucket("+Inf"), 7.0);
        let count =
            hist.samples.iter().find(|s| s.name.ends_with("_count")).expect("count sample");
        assert_eq!(count.value, 7.0);
        let sum = hist.samples.iter().find(|s| s.name.ends_with("_sum")).expect("sum sample");
        assert!((sum.value - 0.0125).abs() < 1e-12, "sum from wait_total_us, got {}", sum.value);
    }

    #[test]
    fn priority_lanes_export_per_model_request_counters() {
        let stats: BTreeMap<String, SessionStats> =
            [("a".to_string(), sample_stats()), ("b".to_string(), SessionStats::default())]
                .into_iter()
                .collect();
        let text = render_server_metrics(&stats, &WireSnapshot::default());
        let fams = parse_exposition(&text).unwrap();
        let reqs = &fams["prunemap_requests_total"];
        assert_eq!(reqs.samples.len(), 4, "2 models x 2 lanes");
        let high_a = reqs
            .samples
            .iter()
            .find(|s| s.label("model") == Some("a") && s.label("priority") == Some("high"))
            .unwrap();
        assert_eq!(high_a.value, 2.0);
    }

    #[test]
    fn session_stats_text_block_names_every_counter() {
        let text = render_session_stats("proxy", &sample_stats());
        assert!(text.starts_with("model proxy: 7 request(s) in 3 run(s)"), "{text}");
        assert!(text.contains("1 expired | 2 shed"), "{text}");
        assert!(text.contains("executed batch    8: 3 run(s)"), "{text}");
        assert!(text.contains("occupancy    2: 1 run(s)"), "{text}");
        assert!(text.contains("wait: <100µs=3 <1ms=2 <10ms=1 <100ms=1"), "{text}");
        assert!(text.ends_with('\n'));
        // an idle session renders just the header line
        let idle = render_session_stats("idle", &SessionStats::default());
        assert_eq!(idle.lines().count(), 1, "{idle}");
    }
}
