//! Cheap always-on span recorder: nanosecond intervals in a bounded
//! ring buffer, dumpable as Chrome trace-event JSON.
//!
//! A [`Span`] is one closed interval of work — queue wait, batch
//! assembly, a whole network run, one lowered graph step, or a
//! sub-operation inside a step (im2col, spmm, epilogue).  Spans are
//! timestamped against a process-wide epoch so intervals recorded on
//! different threads land on one timeline, and carry a `parent` id so
//! consumers can rebuild the run → step → op hierarchy without relying
//! on time containment alone.
//!
//! [`TraceRing`] is the bounded recorder: when full it drops the
//! *oldest* spans (and counts them), so an always-attached ring costs a
//! mutex push per span and a fixed amount of memory no matter how long
//! the process serves.  [`chrome_trace_json`] renders a snapshot in the
//! Chrome `chrome://tracing` / Perfetto trace-event format: worker-side
//! spans as complete (`"X"`) events nested by time on their thread
//! track, queue waits — which overlap arbitrarily — as async
//! (`"b"`/`"e"`) pairs.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::Value;

/// Whole network run (one `GraphExecutor` invocation).
pub const CAT_RUN: &str = "run";
/// One lowered graph step (gemm layer, pool, flatten, ...).
pub const CAT_STEP: &str = "step";
/// A sub-operation inside a step: im2col, spmm, epilogue.
pub const CAT_OP: &str = "op";
/// Micro-batch assembly inside a serving session worker.
pub const CAT_BATCH: &str = "batch";
/// Per-request queue wait between submit and batch assembly.
pub const CAT_QUEUE: &str = "queue";

static EPOCH: OnceLock<Instant> = OnceLock::new();

fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

/// Nanoseconds since the process trace epoch (pinned the first time any
/// telemetry clock is touched).
pub fn now_ns() -> u64 {
    Instant::now().saturating_duration_since(epoch()).as_nanos() as u64
}

/// Convert an [`Instant`] captured elsewhere (e.g. a request's submit
/// time) to epoch nanoseconds.  Saturates to 0 for instants that
/// predate the epoch.
pub fn ns_since_epoch(t: Instant) -> u64 {
    t.saturating_duration_since(epoch()).as_nanos() as u64
}

static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
}

/// Small dense id for the current thread — `std::thread::ThreadId` has
/// no stable integer form, and trace viewers want compact track ids.
pub fn current_tid() -> u64 {
    TID.with(|t| *t)
}

/// One recorded interval of work.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Span {
    /// Human-readable label, e.g. a layer name or `"conv1/spmm"`.
    pub name: String,
    /// One of the `CAT_*` constants.
    pub cat: &'static str,
    /// Start, nanoseconds since the process trace epoch.
    pub start_ns: u64,
    /// Duration in nanoseconds.
    pub dur_ns: u64,
    /// Ring-assigned span id; 0 until [`TraceRing::record`] assigns one.
    pub id: u64,
    /// Id of the enclosing span, 0 for roots.
    pub parent: u64,
    /// Dense thread id from [`current_tid`].
    pub tid: u64,
}

impl Span {
    /// A span with an explicit duration on the current thread's track.
    pub fn new(name: impl Into<String>, cat: &'static str, start_ns: u64, dur_ns: u64) -> Span {
        Span { name: name.into(), cat, start_ns, dur_ns, id: 0, parent: 0, tid: current_tid() }
    }

    /// A span closing now: duration is `now_ns() - start_ns`.
    pub fn until_now(name: impl Into<String>, cat: &'static str, start_ns: u64) -> Span {
        let dur = now_ns().saturating_sub(start_ns);
        Span::new(name, cat, start_ns, dur)
    }

    /// Attach the enclosing span's id.
    pub fn parent(mut self, parent: u64) -> Span {
        self.parent = parent;
        self
    }

    /// Override the thread track (queue waits belong to no worker).
    pub fn tid(mut self, tid: u64) -> Span {
        self.tid = tid;
        self
    }
}

/// Bounded, thread-safe span ring: a fixed-capacity recorder that drops
/// the oldest spans when full and counts what it dropped.
#[derive(Debug)]
pub struct TraceRing {
    cap: usize,
    next_id: AtomicU64,
    dropped: AtomicU64,
    spans: Mutex<VecDeque<Span>>,
}

impl TraceRing {
    /// A new ring holding at most `cap` spans, shared via `Arc` so one
    /// ring can collect from a server, its sessions, and the executor.
    pub fn new(cap: usize) -> Arc<TraceRing> {
        let _ = epoch(); // pin the epoch before any span arithmetic
        Arc::new(TraceRing {
            cap: cap.max(1),
            next_id: AtomicU64::new(1),
            dropped: AtomicU64::new(0),
            spans: Mutex::new(VecDeque::new()),
        })
    }

    /// Reserve a span id up front (so children can name their parent
    /// before the parent span itself is recorded).
    pub fn next_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Record a span, assigning an id if the span has none; returns the
    /// span's id.  Evicts the oldest span when the ring is full.
    pub fn record(&self, mut span: Span) -> u64 {
        if span.id == 0 {
            span.id = self.next_id();
        }
        let id = span.id;
        let mut q = crate::util::recover(self.spans.lock());
        if q.len() >= self.cap {
            q.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        q.push_back(span);
        id
    }

    /// Copy out the current contents, ordered by start time.
    pub fn snapshot(&self) -> Vec<Span> {
        let mut out: Vec<Span> =
            crate::util::recover(self.spans.lock()).iter().cloned().collect();
        out.sort_by_key(|s| (s.start_ns, s.id));
        out
    }

    pub fn len(&self) -> usize {
        crate::util::recover(self.spans.lock()).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Spans evicted so far because the ring was full.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Discard all recorded spans (ids keep counting up).
    pub fn clear(&self) {
        crate::util::recover(self.spans.lock()).clear();
    }
}

/// Render spans as a Chrome trace-event JSON document (loadable in
/// `chrome://tracing` or Perfetto).  Timestamps and durations are in
/// microseconds per the format; `args` carries the span/parent ids so
/// the recorded hierarchy survives the export.
pub fn chrome_trace_json(spans: &[Span]) -> Value {
    let mut events = Vec::with_capacity(spans.len());
    for s in spans {
        let ts = s.start_ns as f64 / 1e3;
        let dur = s.dur_ns as f64 / 1e3;
        let args = Value::obj(vec![
            ("span", Value::num(s.id as f64)),
            ("parent", Value::num(s.parent as f64)),
        ]);
        if s.cat == CAT_QUEUE {
            // queue waits overlap arbitrarily on one logical track;
            // async begin/end pairs keyed by span id render cleanly
            // where overlapping "X" events on one tid would not
            for (ph, t) in [("b", ts), ("e", ts + dur)] {
                events.push(Value::obj(vec![
                    ("name", Value::str(&*s.name)),
                    ("cat", Value::str(s.cat)),
                    ("ph", Value::str(ph)),
                    ("id", Value::num(s.id as f64)),
                    ("ts", Value::num(t)),
                    ("pid", Value::num(1.0)),
                    ("tid", Value::num(s.tid as f64)),
                    ("args", args.clone()),
                ]));
            }
        } else {
            events.push(Value::obj(vec![
                ("name", Value::str(&*s.name)),
                ("cat", Value::str(s.cat)),
                ("ph", Value::str("X")),
                ("ts", Value::num(ts)),
                ("dur", Value::num(dur)),
                ("pid", Value::num(1.0)),
                ("tid", Value::num(s.tid as f64)),
                ("args", args),
            ]));
        }
    }
    Value::obj(vec![
        ("traceEvents", Value::arr(events)),
        ("displayTimeUnit", Value::str("ms")),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_bounds_capacity_and_counts_drops() {
        let ring = TraceRing::new(3);
        for i in 0..5 {
            ring.record(Span::new(format!("s{i}"), CAT_OP, i * 10, 5));
        }
        assert_eq!(ring.len(), 3);
        assert_eq!(ring.capacity(), 3);
        assert_eq!(ring.dropped(), 2);
        let names: Vec<String> = ring.snapshot().into_iter().map(|s| s.name).collect();
        assert_eq!(names, ["s2", "s3", "s4"], "oldest spans evicted first");
        ring.clear();
        assert!(ring.is_empty());
        assert_eq!(ring.dropped(), 2, "clear() does not forget the drop count");
    }

    #[test]
    fn record_assigns_monotonic_ids_and_keeps_explicit_ones() {
        let ring = TraceRing::new(8);
        let a = ring.record(Span::new("a", CAT_OP, 0, 1));
        let b = ring.record(Span::new("b", CAT_OP, 1, 1));
        assert!(b > a, "auto ids are monotonic");
        let reserved = ring.next_id();
        let mut s = Span::new("c", CAT_STEP, 2, 1);
        s.id = reserved;
        assert_eq!(ring.record(s), reserved, "pre-reserved ids survive record()");
    }

    #[test]
    fn until_now_measures_a_nonnegative_interval() {
        let t0 = now_ns();
        let s = Span::until_now("x", CAT_RUN, t0);
        assert_eq!(s.start_ns, t0);
        assert_eq!(s.tid, current_tid());
        // an instant captured after the epoch maps monotonically
        let i = std::time::Instant::now();
        assert!(ns_since_epoch(i) >= t0);
    }

    #[test]
    fn chrome_export_emits_x_events_and_async_pairs() {
        let spans = vec![
            Span { id: 1, parent: 0, ..Span::new("net", CAT_RUN, 1_000, 10_000) },
            Span { id: 2, parent: 1, ..Span::new("conv1", CAT_STEP, 1_500, 4_000) },
            Span { id: 3, parent: 0, tid: 0, ..Span::new("queue_wait", CAT_QUEUE, 500, 2_000) },
        ];
        let doc = chrome_trace_json(&spans);
        // round-trip through the serializer to prove the document loads
        let back = Value::parse(&doc.compact()).expect("chrome trace JSON parses");
        let events = back.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 4, "2 X events + 1 b/e pair");
        let phases: Vec<String> = events
            .iter()
            .map(|e| e.get("ph").unwrap().as_str().unwrap().to_string())
            .collect();
        assert_eq!(phases.iter().filter(|p| *p == "X").count(), 2);
        assert!(phases.contains(&"b".to_string()) && phases.contains(&"e".to_string()));
        for e in events {
            assert!(e.get("name").is_ok() && e.get("ts").is_ok() && e.get("pid").is_ok());
            if e.get("ph").unwrap().as_str().unwrap() == "X" {
                assert!(e.get("dur").unwrap().as_f64().unwrap() >= 0.0);
            }
        }
        // timestamps are microseconds: 1_000 ns -> 1.0 µs
        let net = events
            .iter()
            .find(|e| e.get("name").unwrap().as_str().unwrap() == "net")
            .unwrap();
        assert!((net.get("ts").unwrap().as_f64().unwrap() - 1.0).abs() < 1e-9);
        assert!((net.get("dur").unwrap().as_f64().unwrap() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn distinct_threads_get_distinct_tids() {
        let here = current_tid();
        let there = std::thread::spawn(current_tid).join().unwrap();
        assert_ne!(here, there);
        assert_eq!(here, current_tid(), "tid is stable within a thread");
    }
}
