//! Prometheus text exposition: a small writer, a strict parser (used by
//! tests to prove the exporter's output is well-formed), lock-free wire
//! counters, and a minimal TCP scrape endpoint.
//!
//! The exposition format is the stable text form Prometheus scrapes:
//! one `# HELP` and `# TYPE` line per metric family followed by its
//! samples, label values quoted with `\\`/`\"`/`\n` escapes, histogram
//! families expanded into cumulative `_bucket{le=...}` samples plus
//! `_sum` and `_count`.  [`PromWriter`] emits it; [`parse_exposition`]
//! validates it; [`serve_text`] answers `GET /metrics` scrapes with
//! whatever a render closure produces, so the endpoint stays decoupled
//! from the serving stack that feeds it.

use std::collections::BTreeMap;
use std::io::{self, BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use anyhow::{anyhow, bail, ensure, Result};

/// Incremental writer for the Prometheus text exposition format.
#[derive(Debug, Default)]
pub struct PromWriter {
    out: String,
}

impl PromWriter {
    pub fn new() -> PromWriter {
        PromWriter::default()
    }

    /// Start a metric family: one `# HELP` + `# TYPE` header pair.
    /// `kind` is `counter`, `gauge`, or `histogram`.
    pub fn family(&mut self, name: &str, kind: &str, help: &str) {
        self.out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
    }

    /// One sample line.  `name` may carry a `_bucket`/`_sum`/`_count`
    /// suffix for histogram families; labels are escaped here.
    pub fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        self.out.push_str(name);
        if !labels.is_empty() {
            self.out.push('{');
            for (i, (k, v)) in labels.iter().enumerate() {
                if i > 0 {
                    self.out.push(',');
                }
                self.out.push_str(&format!("{k}=\"{}\"", escape_label(v)));
            }
            self.out.push('}');
        }
        self.out.push(' ');
        self.out.push_str(&format_value(value));
        self.out.push('\n');
    }

    pub fn finish(self) -> String {
        self.out
    }
}

/// Escape a label value per the exposition format: backslash, quote,
/// and newline.
fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Sample values print as integers when they are integral (counters),
/// as `+Inf`/`-Inf`/`NaN` for the non-finite cases the format names.
fn format_value(v: f64) -> String {
    if v == f64::INFINITY {
        "+Inf".to_string()
    } else if v == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else if v.is_nan() {
        "NaN".to_string()
    } else if v.fract() == 0.0 && v.abs() < 9e15 {
        (v as i64).to_string()
    } else {
        v.to_string()
    }
}

/// One parsed sample line.
#[derive(Debug, Clone, PartialEq)]
pub struct Sample {
    /// Full sample name (may carry a histogram suffix).
    pub name: String,
    /// Label pairs in source order, values unescaped.
    pub labels: Vec<(String, String)>,
    pub value: f64,
}

impl Sample {
    /// Look up a label value by key.
    pub fn label(&self, key: &str) -> Option<&str> {
        self.labels.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }
}

/// One parsed metric family: its declared type, help text, and samples.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Family {
    pub kind: String,
    pub help: String,
    pub samples: Vec<Sample>,
}

/// Parse a text exposition document, enforcing that every family has
/// both `# HELP` and `# TYPE` lines and every sample belongs to a
/// declared family (histogram `_bucket`/`_sum`/`_count` suffixes
/// resolve to their base family).
pub fn parse_exposition(text: &str) -> Result<BTreeMap<String, Family>> {
    let mut fams: BTreeMap<String, Family> = BTreeMap::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.trim_end();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let (name, help) = rest.split_once(' ').unwrap_or((rest, ""));
            fams.entry(name.to_string()).or_default().help = help.to_string();
        } else if let Some(rest) = line.strip_prefix("# TYPE ") {
            let (name, kind) =
                rest.split_once(' ').ok_or_else(|| anyhow!("line {}: bare # TYPE", ln + 1))?;
            ensure!(
                matches!(kind, "counter" | "gauge" | "histogram" | "summary" | "untyped"),
                "line {}: unknown metric type '{kind}'",
                ln + 1
            );
            fams.entry(name.to_string()).or_default().kind = kind.to_string();
        } else if line.starts_with('#') {
            continue; // free-form comment
        } else {
            let sample = parse_sample(line).map_err(|e| anyhow!("line {}: {e}", ln + 1))?;
            let family = resolve_family(&sample.name, &fams).ok_or_else(|| {
                anyhow!("line {}: sample '{}' has no declared family", ln + 1, sample.name)
            })?;
            fams.get_mut(&family).expect("resolved family exists").samples.push(sample);
        }
    }
    for (name, f) in &fams {
        ensure!(!f.kind.is_empty(), "family '{name}' has no # TYPE line");
        ensure!(!f.help.is_empty(), "family '{name}' has no # HELP line");
    }
    Ok(fams)
}

/// Map a sample name onto its declared family, resolving histogram
/// suffixes against families declared as histograms.
fn resolve_family(name: &str, fams: &BTreeMap<String, Family>) -> Option<String> {
    if fams.contains_key(name) {
        return Some(name.to_string());
    }
    for suffix in ["_bucket", "_sum", "_count"] {
        if let Some(base) = name.strip_suffix(suffix) {
            if fams.get(base).is_some_and(|f| f.kind == "histogram") {
                return Some(base.to_string());
            }
        }
    }
    None
}

fn parse_sample(line: &str) -> Result<Sample> {
    let brace = line.find('{');
    let space = line.find(' ');
    let (name, labels, value_str) = match (brace, space) {
        (Some(b), _) if space.is_none_or(|s| b < s) => {
            let (labels, after) = parse_labels(&line[b + 1..])?;
            (&line[..b], labels, after)
        }
        (_, Some(s)) => (&line[..s], Vec::new(), &line[s + 1..]),
        _ => bail!("sample line '{line}' has no value"),
    };
    let name_ok = name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':');
    ensure!(!name.is_empty() && name_ok, "bad metric name '{name}'");
    Ok(Sample { name: name.to_string(), labels, value: parse_value(value_str.trim())? })
}

/// Parse `key="value",...}` starting just past the opening brace;
/// returns the labels and the remainder after the closing brace.
fn parse_labels(s: &str) -> Result<(Vec<(String, String)>, &str)> {
    let mut out = Vec::new();
    let mut rest = s.trim_start();
    loop {
        if let Some(after) = rest.strip_prefix('}') {
            return Ok((out, after));
        }
        let eq = rest.find('=').ok_or_else(|| anyhow!("label without '=' in '{{{s}'"))?;
        let key = rest[..eq].trim().to_string();
        ensure!(!key.is_empty(), "empty label name in '{{{s}'");
        let after_eq = rest[eq + 1..].trim_start();
        let inner = after_eq
            .strip_prefix('"')
            .ok_or_else(|| anyhow!("label value must be double-quoted in '{{{s}'"))?;
        let mut val = String::new();
        let mut end = None;
        let mut esc = false;
        for (i, c) in inner.char_indices() {
            if esc {
                val.push(if c == 'n' { '\n' } else { c });
                esc = false;
            } else if c == '\\' {
                esc = true;
            } else if c == '"' {
                end = Some(i);
                break;
            } else {
                val.push(c);
            }
        }
        let end = end.ok_or_else(|| anyhow!("unterminated label value in '{{{s}'"))?;
        out.push((key, val));
        rest = inner[end + 1..].trim_start();
        rest = rest.strip_prefix(',').unwrap_or(rest).trim_start();
    }
}

fn parse_value(s: &str) -> Result<f64> {
    match s {
        "+Inf" | "Inf" => Ok(f64::INFINITY),
        "-Inf" => Ok(f64::NEG_INFINITY),
        "NaN" => Ok(f64::NAN),
        _ => s.parse().map_err(|_| anyhow!("bad sample value '{s}'")),
    }
}

/// Stable wire error-kind tags, mirroring `ServeError::kind()`, plus a
/// catch-all slot so an unknown tag never panics the counter path.
pub const WIRE_ERROR_KINDS: [&str; 9] = [
    "unknown_model",
    "bad_input",
    "deadline_expired",
    "overloaded",
    "closed",
    "execution",
    "malformed",
    "artifact_rejected",
    "other",
];

/// Lock-free counters for the line-JSON wire layer, shared across all
/// connections of one [`crate::serve::Server`].
#[derive(Debug, Default)]
pub struct WireCounters {
    /// Connections accepted since startup.
    pub connections: AtomicU64,
    /// Connections currently open.
    pub active: AtomicU64,
    /// Non-blank request lines read.
    pub frames: AtomicU64,
    /// Successful inference replies written.
    pub served: AtomicU64,
    /// Error replies written (any kind).
    pub errors: AtomicU64,
    /// Admin (`stats`/`metrics`) replies written.
    pub admin: AtomicU64,
    /// Lines that failed frame decoding.
    pub malformed: AtomicU64,
    /// Connections shed at accept time because the pool was at
    /// `max_active` (each got one `overloaded` frame and was closed).
    pub shed_conns: AtomicU64,
    /// Accepted connections dropped because setup failed
    /// (`try_clone` / thread spawn), so they are never invisible.
    pub conn_setup_failed: AtomicU64,
    /// Transient `accept` failures retried instead of tearing the
    /// listener down.
    pub accept_retries: AtomicU64,
    error_kinds: [AtomicU64; 9],
}

impl WireCounters {
    /// Count one error reply, bucketing by its stable kind tag.
    pub fn record_error(&self, kind: &str) {
        self.errors.fetch_add(1, Ordering::Relaxed);
        let slot = WIRE_ERROR_KINDS
            .iter()
            .position(|k| *k == kind)
            .unwrap_or(WIRE_ERROR_KINDS.len() - 1);
        self.error_kinds[slot].fetch_add(1, Ordering::Relaxed);
    }

    /// A consistent-enough copy for rendering (individual loads are
    /// relaxed; exact cross-counter consistency is not needed for
    /// monotonic counters).
    pub fn snapshot(&self) -> WireSnapshot {
        let mut error_kinds = [0u64; 9];
        for (slot, counter) in error_kinds.iter_mut().zip(&self.error_kinds) {
            *slot = counter.load(Ordering::Relaxed);
        }
        WireSnapshot {
            connections: self.connections.load(Ordering::Relaxed),
            active: self.active.load(Ordering::Relaxed),
            frames: self.frames.load(Ordering::Relaxed),
            served: self.served.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            admin: self.admin.load(Ordering::Relaxed),
            malformed: self.malformed.load(Ordering::Relaxed),
            shed_conns: self.shed_conns.load(Ordering::Relaxed),
            conn_setup_failed: self.conn_setup_failed.load(Ordering::Relaxed),
            accept_retries: self.accept_retries.load(Ordering::Relaxed),
            error_kinds,
        }
    }
}

/// Point-in-time copy of [`WireCounters`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WireSnapshot {
    pub connections: u64,
    pub active: u64,
    pub frames: u64,
    pub served: u64,
    pub errors: u64,
    pub admin: u64,
    pub malformed: u64,
    pub shed_conns: u64,
    pub conn_setup_failed: u64,
    pub accept_retries: u64,
    /// Indexed like [`WIRE_ERROR_KINDS`].
    pub error_kinds: [u64; 9],
}

/// Answer scrapes on `listener` forever (or for `max_conns` accepts),
/// rendering a fresh document per request.  Speaks just enough HTTP for
/// Prometheus and `curl`: read the request head, answer `200 OK` with
/// `text/plain`.  Per-connection failures never take the endpoint down.
pub fn serve_text<F>(listener: TcpListener, max_conns: Option<usize>, render: F) -> io::Result<()>
where
    F: Fn() -> String,
{
    if max_conns == Some(0) {
        return Ok(());
    }
    let mut accepted = 0usize;
    for conn in listener.incoming() {
        if let Ok(stream) = conn {
            let _ = answer_scrape(stream, &render);
        }
        accepted += 1;
        if Some(accepted) == max_conns {
            break;
        }
    }
    Ok(())
}

fn answer_scrape<F: Fn() -> String>(stream: TcpStream, render: &F) -> io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(5)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    // consume the request line + headers up to the blank separator; the
    // path is ignored (every path serves the one document)
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 || line.trim().is_empty() {
            break;
        }
    }
    let body = render();
    let mut stream = stream;
    write!(
        stream,
        "HTTP/1.1 200 OK\r\nContent-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writer_output_roundtrips_through_the_parser() {
        let mut w = PromWriter::new();
        w.family("acme_requests_total", "counter", "Requests accepted.");
        w.sample("acme_requests_total", &[("model", "mobilenetv1"), ("priority", "high")], 3.0);
        w.sample("acme_requests_total", &[("model", "proxy"), ("priority", "normal")], 41.0);
        w.family("acme_wait_seconds", "histogram", "Queue wait.");
        w.sample("acme_wait_seconds_bucket", &[("le", "0.001")], 2.0);
        w.sample("acme_wait_seconds_bucket", &[("le", "+Inf")], 5.0);
        w.sample("acme_wait_seconds_count", &[], 5.0);
        w.sample("acme_wait_seconds_sum", &[], 0.0123);
        let text = w.finish();
        let fams = parse_exposition(&text).expect("writer output parses");
        assert_eq!(fams.len(), 2);
        let reqs = &fams["acme_requests_total"];
        assert_eq!(reqs.kind, "counter");
        assert_eq!(reqs.help, "Requests accepted.");
        assert_eq!(reqs.samples.len(), 2);
        assert_eq!(reqs.samples[0].label("model"), Some("mobilenetv1"));
        assert_eq!(reqs.samples[1].value, 41.0);
        let wait = &fams["acme_wait_seconds"];
        assert_eq!(wait.kind, "histogram");
        assert_eq!(wait.samples.len(), 4, "suffixed samples fold into the base family");
        let inf = wait
            .samples
            .iter()
            .find(|s| s.label("le") == Some("+Inf"))
            .expect("+Inf bucket");
        assert_eq!(inf.value, 5.0);
    }

    #[test]
    fn label_values_escape_and_unescape() {
        let mut w = PromWriter::new();
        w.family("x_total", "counter", "Escaping.");
        w.sample("x_total", &[("name", "a\"b\\c\nd")], 1.0);
        let text = w.finish();
        assert!(text.contains(r#"name="a\"b\\c\nd""#), "{text}");
        let fams = parse_exposition(&text).unwrap();
        assert_eq!(fams["x_total"].samples[0].label("name"), Some("a\"b\\c\nd"));
    }

    #[test]
    fn values_format_as_integers_infinities_and_floats() {
        assert_eq!(format_value(5.0), "5");
        assert_eq!(format_value(0.25), "0.25");
        assert_eq!(format_value(f64::INFINITY), "+Inf");
        assert_eq!(format_value(f64::NEG_INFINITY), "-Inf");
        assert_eq!(format_value(f64::NAN), "NaN");
        assert_eq!(parse_value("+Inf").unwrap(), f64::INFINITY);
    }

    #[test]
    fn parser_rejects_undeclared_and_headerless_families() {
        let orphan = "stray_total 3\n";
        assert!(parse_exposition(orphan).is_err(), "sample without TYPE/HELP must fail");
        let no_help = "# TYPE t_total counter\nt_total 1\n";
        assert!(parse_exposition(no_help).is_err(), "family without HELP must fail");
        let bad_value = "# HELP t_total h\n# TYPE t_total counter\nt_total abc\n";
        assert!(parse_exposition(bad_value).is_err());
        let bad_kind = "# HELP t_total h\n# TYPE t_total widget\n";
        assert!(parse_exposition(bad_kind).is_err());
    }

    #[test]
    fn wire_counters_bucket_error_kinds_with_a_catch_all() {
        let c = WireCounters::default();
        c.connections.fetch_add(2, Ordering::Relaxed);
        c.shed_conns.fetch_add(1, Ordering::Relaxed);
        c.conn_setup_failed.fetch_add(1, Ordering::Relaxed);
        c.accept_retries.fetch_add(3, Ordering::Relaxed);
        c.record_error("bad_input");
        c.record_error("bad_input");
        c.record_error("overloaded");
        c.record_error("not_a_real_kind");
        let s = c.snapshot();
        assert_eq!(s.connections, 2);
        assert_eq!(s.errors, 4);
        assert_eq!(s.shed_conns, 1);
        assert_eq!(s.conn_setup_failed, 1);
        assert_eq!(s.accept_retries, 3);
        let bad = WIRE_ERROR_KINDS.iter().position(|k| *k == "bad_input").unwrap();
        assert_eq!(s.error_kinds[bad], 2);
        let shed = WIRE_ERROR_KINDS.iter().position(|k| *k == "overloaded").unwrap();
        assert_eq!(s.error_kinds[shed], 1, "shed connections bucket under 'overloaded'");
        assert_eq!(s.error_kinds[WIRE_ERROR_KINDS.len() - 1], 1, "unknown kinds → other");
    }

    #[test]
    fn scrape_endpoint_answers_http_with_the_rendered_body() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server =
            std::thread::spawn(move || serve_text(listener, Some(1), || "m_total 7\n".to_string()));
        let mut conn = TcpStream::connect(addr).unwrap();
        write!(conn, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut reply = String::new();
        use std::io::Read;
        conn.read_to_string(&mut reply).unwrap();
        server.join().unwrap().unwrap();
        assert!(reply.starts_with("HTTP/1.1 200 OK\r\n"), "{reply}");
        assert!(reply.contains("Content-Type: text/plain; version=0.0.4"), "{reply}");
        assert!(reply.ends_with("m_total 7\n"), "{reply}");
    }
}
