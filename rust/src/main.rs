//! `prunemap` launcher: regenerate any paper table/figure, build latency
//! models, map pruning schemes onto zoo models, and run the live PJRT
//! pipeline.
//!
//! ```text
//! prunemap <command> [--device s10|s20|s21] [options] [--flags]
//!
//! Commands:
//!   fig3 | fig5 | fig7 | fig9 | fig10a | fig10b
//!   table1 | table2 | table3 | table4 | table5 | table6 | table7
//!   all                    every table and figure in order
//!   latmodel --out F       build + save the device latency model
//!   map --model M --dataset D --method rule|search
//!   infer --model M --dataset D [--threads N] [--batch N] [--tile N]
//!         [--materialized] [--json-out F]
//!                          native end-to-end inference through the graph
//!                          executor: per-layer scheme + measured latency
//!   e2e [--steps N]        live pipeline on the proxy CNN (needs artifacts)
//! ```

use anyhow::{anyhow, Result};

use prunemap::accuracy::Assignment;
#[cfg(pjrt)]
use prunemap::coordinator::{run_pipeline, PipelineConfig};
use prunemap::experiments as exp;
use prunemap::latmodel::LatencyModel;
use prunemap::mapping::{self, map_rule_based, map_search_based, RuleConfig, SearchConfig};
use prunemap::models::{zoo, Dataset, ModelSpec};
#[cfg(pjrt)]
use prunemap::runtime::Runtime;
use prunemap::runtime::{CompiledNet, GraphExecutor, KernelChoice};
use prunemap::simulator::{measured_vs_modeled_network, DeviceProfile};
use prunemap::util::cli::Args;

fn model_by_name(name: &str, ds: Dataset) -> Result<ModelSpec> {
    Ok(match name.to_ascii_lowercase().as_str() {
        "vgg16" => zoo::vgg16(ds),
        "resnet18" => zoo::resnet18(ds),
        "resnet50" => zoo::resnet50(ds),
        "mobilenetv1" => zoo::mobilenet_v1(ds),
        "mobilenetv2" => zoo::mobilenet_v2(ds),
        "yolov4" => zoo::yolov4(),
        "proxy" => zoo::proxy_cnn(),
        other => return Err(anyhow!("unknown model '{other}'")),
    })
}

fn dataset_by_name(name: &str) -> Result<Dataset> {
    Ok(match name.to_ascii_lowercase().as_str() {
        "cifar10" => Dataset::Cifar10,
        "cifar100" => Dataset::Cifar100,
        "imagenet" => Dataset::ImageNet,
        "coco" => Dataset::Coco,
        "synthetic" => Dataset::Synthetic,
        other => return Err(anyhow!("unknown dataset '{other}'")),
    })
}

fn device(args: &Args) -> Result<DeviceProfile> {
    let name = args.get_or("device", "s10");
    DeviceProfile::by_name(name).ok_or_else(|| anyhow!("unknown device '{name}'"))
}

fn cmd_map(args: &Args) -> Result<()> {
    let dev = device(args)?;
    let ds = dataset_by_name(args.get_or("dataset", "imagenet"))?;
    let model = model_by_name(args.get_or("model", "resnet50"), ds)?;
    let method = args.get_or("method", "rule");
    let assigns: Vec<Assignment> = match method {
        "rule" => {
            let lat = LatencyModel::build(&dev);
            map_rule_based(&model, &lat, &RuleConfig::default())
        }
        "search" => {
            let cfg = SearchConfig {
                iterations: args.get_usize("iterations", 60)?,
                seed: args.get_u64("seed", 0xC0FFEE)?,
                ..Default::default()
            };
            map_search_based(&model, &dev, &cfg).0
        }
        other => return Err(anyhow!("unknown method '{other}' (rule|search)")),
    };
    exp::describe_mapping(&model, &assigns).print();
    let e = mapping::evaluate(&model, &assigns, &dev);
    let dense = mapping::dense_latency_ms(&model, &dev);
    println!(
        "\ncompression {:.2}x | acc drop {:+.2}% | latency {:.2}ms (dense {:.2}ms, {:.2}x speedup) | MACs {:.2}G",
        e.compression,
        e.acc_drop * 100.0,
        e.latency_ms,
        dense,
        dense / e.latency_ms,
        e.macs / 1e9
    );
    Ok(())
}

/// Map a zoo model, synthesize masked weights, and run it end to end on
/// the native graph executor — per-layer scheme + measured latency, plus a
/// measured-vs-modeled calibration JSON record.
fn cmd_infer(args: &Args) -> Result<()> {
    let dev = device(args)?;
    let ds = dataset_by_name(args.get_or("dataset", "cifar10"))?;
    let model = model_by_name(args.get_or("model", "mobilenetv1"), ds)?;
    let threads = args.engine_threads()?;
    let batch = args.batch_size(1)?;
    let seed = args.get_u64("seed", 7)?;
    let reps = args.get_usize("reps", 3)?;
    let assigns: Vec<Assignment> = match args.get_or("method", "rule") {
        "rule" => {
            let lat = LatencyModel::build(&dev);
            map_rule_based(&model, &lat, &RuleConfig::default())
        }
        "search" => {
            let cfg = SearchConfig {
                iterations: args.get_usize("iterations", 30)?,
                seed: args.get_u64("search-seed", 0xC0FFEE)?,
                ..Default::default()
            };
            map_search_based(&model, &dev, &cfg).0
        }
        other => return Err(anyhow!("unknown method '{other}' (rule|search)")),
    };

    let net = CompiledNet::compile(&model, &assigns, seed, KernelChoice::Auto)?;
    let tile = args.tile_cols(prunemap::sparse::DEFAULT_TILE_COLS)?;
    let mut exec = GraphExecutor::new(threads).with_tile_cols(tile);
    if args.materialized() {
        exec = exec.materialized();
    }
    let (c, h, w) = net.input_shape;
    let input: Vec<f32> = (0..batch * c * h * w)
        .map(|i| ((i % 17) as f32) * 0.25 - 2.0)
        .collect();
    // warm the buffer arena so the per-layer timings measure the
    // steady-state path, same as the calibration record
    let mut arena = prunemap::runtime::Arena::new();
    let _warmup = exec.run_with_arena(&net, &input, batch, &mut arena)?;
    let (_, timings) = exec.run_timed_with_arena(&net, &input, batch, &mut arena)?;

    println!(
        "{} ({} layers, {} steps) | input {c}x{h}x{w} | batch {batch} | {threads} threads | {} im2col\n",
        model.name,
        net.layers.len(),
        net.steps.len(),
        if exec.is_fused() { "fused" } else { "materialized" }
    );
    println!(
        "{:<16} {:>14} {:>6} {:>8} {:>12} {:>10}",
        "layer", "scheme", "comp", "backend", "nnz", "ms"
    );
    let summaries: std::collections::HashMap<String, prunemap::runtime::graph::LayerSummary> =
        net.summaries().into_iter().map(|s| (s.name.clone(), s)).collect();
    let mut total_ms = 0.0;
    for t in &timings {
        total_ms += t.ms;
        match summaries.get(&t.name) {
            Some(s) => println!(
                "{:<16} {:>14} {:>5.1}x {:>8} {:>12} {:>9.3}ms",
                s.name, s.scheme, s.compression, s.backend, s.nnz, t.ms
            ),
            None => println!(
                "{:<16} {:>14} {:>6} {:>8} {:>12} {:>9.3}ms",
                t.name, "-", "-", "-", "-", t.ms
            ),
        }
    }
    println!("\ntotal {total_ms:.3}ms measured (host, whole batch)");

    let cmp = measured_vs_modeled_network(&model, &assigns, &dev, &net, batch, threads, reps)?;
    println!("measured-vs-modeled: {}", cmp.to_json().compact());
    if let Some(path) = args.get("json-out") {
        std::fs::write(path, cmp.to_json().pretty())?;
        println!("wrote calibration record to {path}");
    }
    Ok(())
}

#[cfg(pjrt)]
fn cmd_e2e(args: &Args) -> Result<()> {
    let rt = Runtime::open(Runtime::default_dir())?;
    println!("PJRT platform: {}", rt.platform());
    let dev = device(args)?;
    let model = zoo::proxy_cnn();
    let lat = LatencyModel::build(&dev);
    let assigns = map_rule_based(&model, &lat, &RuleConfig::default());
    exp::describe_mapping(&model, &assigns).print();
    let cfg = PipelineConfig {
        pretrain_steps: args.get_usize("steps", 150)?,
        ..Default::default()
    };
    let rep = run_pipeline(&rt, &model, &assigns, &dev, &cfg)?;
    println!(
        "\nacc: pretrained {:.3} -> pruned {:.3} -> retrained {:.3}",
        rep.acc_pretrained, rep.acc_after_prune, rep.acc_after_retrain
    );
    println!(
        "compression {:.2}x | latency {:.3}ms -> {:.3}ms ({:.2}x)",
        rep.overall_compression,
        rep.dense_latency_ms,
        rep.pruned_latency_ms,
        rep.speedup()
    );
    println!(
        "loss curve: {}",
        prunemap::report::sparkline(
            &rep.loss_curve.iter().map(|&x| x as f64).collect::<Vec<_>>()
        )
    );
    Ok(())
}

fn run() -> Result<()> {
    let args = Args::from_env();
    let cmd = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("help");
    let dev = device(&args)?;
    match cmd {
        "fig3" => exp::fig3().print(),
        "fig5" => exp::fig5(&dev).print(),
        "fig7" => exp::fig7().iter().for_each(|f| f.print()),
        "fig9" => exp::fig9(&dev).iter().for_each(|f| f.print()),
        "fig10a" => exp::fig10a(&dev).print(),
        "fig10b" => exp::fig10b(&dev).print(),
        "table1" => exp::table1().print(),
        "table2" => exp::table2(&dev).print(),
        "table3" => exp::table3().print(),
        "table4" => exp::table4(&dev, args.flag("quick")).print(),
        "table5" => exp::table5(&dev).print(),
        "table6" => exp::table6().print(),
        "table7" => exp::table7().print(),
        "ablation" => exp::ablation(&dev).print(),
        "all" => {
            exp::fig3().print();
            exp::fig5(&dev).print();
            exp::fig7().iter().for_each(|f| f.print());
            exp::fig9(&dev).iter().for_each(|f| f.print());
            exp::fig10a(&dev).print();
            exp::fig10b(&dev).print();
            exp::table1().print();
            exp::table2(&dev).print();
            exp::table3().print();
            exp::table4(&dev, true).print();
            exp::table5(&dev).print();
            exp::table6().print();
            exp::table7().print();
            exp::ablation(&dev).print();
        }
        "latmodel" => {
            let out = args.get_or("out", "latmodel.json");
            let m = LatencyModel::build(&dev);
            m.save(out)?;
            println!("saved {} settings for {} to {out}", m.len(), m.device);
        }
        "map" => cmd_map(&args)?,
        "infer" => cmd_infer(&args)?,
        #[cfg(pjrt)]
        "e2e" => cmd_e2e(&args)?,
        #[cfg(not(pjrt))]
        "e2e" => {
            return Err(anyhow!(
                "the e2e pipeline needs the PJRT runtime: vendor the `xla` crate and rebuild with RUSTFLAGS=\"--cfg pjrt\" (see src/runtime/pjrt.rs)"
            ));
        }
        _ => {
            println!(
                "usage: prunemap <fig3|fig5|fig7|fig9|fig10a|fig10b|table1..table7|all|latmodel|map|infer|e2e> [--device s10|s20|s21] [--threads N] [--batch N] [--tile N] [--materialized]"
            );
        }
    }
    Ok(())
}

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
