//! `prunemap` launcher: regenerate any paper table/figure, build latency
//! models, map pruning schemes onto zoo models, and serve inference
//! through the compile-once/serve-many session API.
//!
//! ```text
//! prunemap <command> [--device s10|s20|s21] [options] [--flags]
//!
//! Commands:
//!   fig3 | fig5 | fig7 | fig9 | fig10a | fig10b
//!   table1 | table2 | table3 | table4 | table5 | table6 | table7
//!   all                    every table and figure in order
//!   latmodel --out F       build + save the device latency model
//!   map --model M --dataset D --method rule|search
//!   infer --model M --dataset D [--threads N] [--batch N] [--tile N]
//!         [--materialized] [--json-out F]
//!                          native end-to-end inference through the graph
//!                          executor: per-layer scheme + measured latency
//!   serve --requests N [--clients N] [--max-batch N] [--max-wait-ms F]
//!         [--workers N] [--save F | --load F]
//!                          compile once, serve N concurrent requests
//!                          through the micro-batching session API
//!   e2e [--steps N]        live pipeline on the proxy CNN (needs artifacts)
//! ```

use std::time::Instant;

use anyhow::{anyhow, Result};

use prunemap::experiments as exp;
use prunemap::latmodel::LatencyModel;
use prunemap::mapping::{self, MappingMethod};
use prunemap::models::{zoo, Dataset, ModelSpec};
#[cfg(pjrt)]
use prunemap::runtime::Runtime;
use prunemap::serve::{PreparedModel, Session, Ticket};
use prunemap::simulator::{measured_vs_modeled_network, DeviceProfile};
use prunemap::util::cli::Args;

fn model_by_name(name: &str, ds: Dataset) -> Result<ModelSpec> {
    zoo::by_name(name, ds).ok_or_else(|| anyhow!("unknown model '{name}'"))
}

fn dataset_by_name(name: &str) -> Result<Dataset> {
    Dataset::by_name(name).ok_or_else(|| anyhow!("unknown dataset '{name}'"))
}

fn device(args: &Args) -> Result<DeviceProfile> {
    let name = args.get_or("device", "s10");
    DeviceProfile::by_name(name).ok_or_else(|| anyhow!("unknown device '{name}'"))
}

fn cmd_map(args: &Args) -> Result<()> {
    let dev = device(args)?;
    let ds = dataset_by_name(args.get_or("dataset", "imagenet"))?;
    let model = model_by_name(args.get_or("model", "resnet50"), ds)?;
    let method = MappingMethod::from_args(args, 60, args.get_u64("seed", 0xC0FFEE)?)?;
    let assigns = method.assign(&model, &dev);
    exp::describe_mapping(&model, &assigns).print();
    let e = mapping::evaluate(&model, &assigns, &dev);
    let dense = mapping::dense_latency_ms(&model, &dev);
    // degenerate modeled latencies must not print as "infx speedup"
    let speedup = if e.latency_ms > 1e-12 {
        format!("{:.2}x", dense / e.latency_ms)
    } else {
        "n/a".to_string()
    };
    println!(
        "\ncompression {:.2}x | acc drop {:+.2}% | latency {:.2}ms (dense {:.2}ms, {speedup} speedup) | MACs {:.2}G",
        e.compression,
        e.acc_drop * 100.0,
        e.latency_ms,
        dense,
        e.macs / 1e9
    );
    Ok(())
}

/// Build a [`PreparedModel`] from the shared CLI surface (`--model`,
/// `--dataset`, `--device`, `--method`/`--iterations`/`--search-seed`,
/// `--seed`) — the one resolution path `infer` and `serve` share.
fn prepared_from_args(args: &Args) -> Result<PreparedModel> {
    let method = MappingMethod::from_args(args, 30, args.get_u64("search-seed", 0xC0FFEE)?)?;
    PreparedModel::builder()
        .model(args.get_or("model", "mobilenetv1"))
        .dataset(args.get_or("dataset", "cifar10"))
        .device(args.get_or("device", "s10"))
        .mapping(method)
        .seed(args.get_u64("seed", 7)?)
        .build()
}

/// Map a zoo model, seal it into a [`PreparedModel`], and run it end to
/// end through a serving [`Session`] — per-layer scheme + measured
/// latency, plus a measured-vs-modeled calibration JSON record.
fn cmd_infer(args: &Args) -> Result<()> {
    let dev = device(args)?;
    let threads = args.engine_threads()?;
    let batch = args.batch_size(1)?;
    let reps = args.get_usize("reps", 3)?;
    let prepared = prepared_from_args(args)?;
    let session = Session::builder(prepared.clone())
        .threads(threads)
        .tile_cols(args.tile_cols(prunemap::sparse::DEFAULT_TILE_COLS)?)
        .fused(!args.materialized())
        .build();

    let (c, h, w) = prepared.input_shape();
    let input: Vec<f32> = (0..batch * c * h * w)
        .map(|i| ((i % 17) as f32) * 0.25 - 2.0)
        .collect();
    // warmed diagnostic run (bypasses the micro-batcher): per-layer
    // timings measure the steady-state allocation-free path
    let (_, timings) = session.run_timed(&input, batch)?;

    let net = prepared.net();
    println!(
        "{} ({} layers, {} steps) | input {c}x{h}x{w} | batch {batch} | {threads} threads | {} im2col\n",
        prepared.name(),
        net.layers.len(),
        net.steps.len(),
        if session.is_fused() { "fused" } else { "materialized" }
    );
    println!(
        "{:<16} {:>14} {:>6} {:>8} {:>12} {:>10}",
        "layer", "scheme", "comp", "backend", "nnz", "ms"
    );
    let summaries: std::collections::HashMap<String, prunemap::runtime::graph::LayerSummary> =
        net.summaries().into_iter().map(|s| (s.name.clone(), s)).collect();
    let mut total_ms = 0.0;
    for t in &timings {
        total_ms += t.ms;
        match summaries.get(&t.name) {
            Some(s) => println!(
                "{:<16} {:>14} {:>5.1}x {:>8} {:>12} {:>9.3}ms",
                s.name, s.scheme, s.compression, s.backend, s.nnz, t.ms
            ),
            None => println!(
                "{:<16} {:>14} {:>6} {:>8} {:>12} {:>9.3}ms",
                t.name, "-", "-", "-", "-", t.ms
            ),
        }
    }
    println!("\ntotal {total_ms:.3}ms measured (host, whole batch)");

    let cmp = measured_vs_modeled_network(
        prepared.model(),
        prepared.assigns(),
        &dev,
        net,
        batch,
        threads,
        reps,
    )?;
    println!("measured-vs-modeled: {}", cmp.to_json().compact());
    if let Some(path) = args.get("json-out") {
        std::fs::write(path, cmp.to_json().pretty())?;
        println!("wrote calibration record to {path}");
    }
    Ok(())
}

/// Compile once, then serve a burst of concurrent requests through the
/// micro-batching [`Session`]: the serving-throughput counterpart of
/// `infer`'s single diagnostic run.
fn cmd_serve(args: &Args) -> Result<()> {
    let threads = args.engine_threads()?;
    let requests = args.get_usize("requests", 64)?.max(1);
    let clients = args.get_usize("clients", 8)?.max(1);
    let prepared = match args.get("load") {
        Some(path) => {
            let p = PreparedModel::load(path)?;
            println!("loaded prepared artifact from {path}");
            p
        }
        None => prepared_from_args(args)?,
    };
    if let Some(path) = args.get("save") {
        prepared.save(path)?;
        println!("saved prepared artifact to {path}");
    }
    let session = Session::builder(prepared.clone())
        .threads(threads)
        .tile_cols(args.tile_cols(prunemap::sparse::DEFAULT_TILE_COLS)?)
        .fused(!args.materialized())
        .max_batch(args.max_batch(32)?)
        .max_wait(args.max_wait(2.0)?)
        .workers(args.get_usize("workers", 1)?)
        .build();
    println!(
        "{} ({}-mapped, seed {}) | {} engine threads | max batch {} | max wait {:?} | {} worker(s)",
        prepared.name(),
        prepared.method(),
        prepared.seed(),
        session.threads(),
        session.max_batch(),
        session.max_wait(),
        session.workers()
    );

    let sample = prepared.input_len();
    let per_client = requests.div_ceil(clients);
    let total = per_client * clients;
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for client in 0..clients {
            let session = &session;
            scope.spawn(move || {
                // each client keeps a small submission pipeline open so
                // concurrent requests exist for the batcher to coalesce
                let mut pending: Vec<Ticket> = Vec::new();
                for r in 0..per_client {
                    let tag = client * per_client + r;
                    let input: Vec<f32> = (0..sample)
                        .map(|j| (((tag + j) % 17) as f32) * 0.25 - 2.0)
                        .collect();
                    pending.push(session.submit(input).expect("submit"));
                    if pending.len() >= 4 {
                        pending.remove(0).wait().expect("serve request");
                    }
                }
                for t in pending {
                    t.wait().expect("serve request");
                }
            });
        }
    });
    let elapsed = t0.elapsed();
    let st = session.stats();
    println!(
        "\nserved {total} requests from {clients} client(s) in {:.1}ms -> {:.0} req/s",
        elapsed.as_secs_f64() * 1e3,
        total as f64 / elapsed.as_secs_f64().max(1e-9)
    );
    println!(
        "{} runs | max coalesced {} | {:.2} requests/run | {} padded lanes",
        st.runs,
        st.max_coalesced,
        st.requests as f64 / st.runs.max(1) as f64,
        st.padded_lanes
    );
    for (batch, runs) in &st.batch_runs {
        println!("  batch {batch:>4}: {runs} run(s)");
    }
    Ok(())
}

#[cfg(pjrt)]
fn cmd_e2e(args: &Args) -> Result<()> {
    let rt = Runtime::open(Runtime::default_dir())?;
    println!("PJRT platform: {}", rt.platform());
    let dev = device(args)?;
    let model = zoo::proxy_cnn();
    let method = MappingMethod::from_args(args, 30, args.get_u64("search-seed", 0xC0FFEE)?)?;
    let assigns = method.assign(&model, &dev);
    exp::describe_mapping(&model, &assigns).print();
    let cfg = prunemap::coordinator::PipelineConfig {
        pretrain_steps: args.get_usize("steps", 150)?,
        ..Default::default()
    };
    let rep = prunemap::coordinator::run_pipeline(&rt, &model, &assigns, &dev, &cfg)?;
    println!(
        "\nacc: pretrained {:.3} -> pruned {:.3} -> retrained {:.3}",
        rep.acc_pretrained, rep.acc_after_prune, rep.acc_after_retrain
    );
    println!(
        "compression {:.2}x | latency {:.3}ms -> {:.3}ms ({:.2}x)",
        rep.overall_compression,
        rep.dense_latency_ms,
        rep.pruned_latency_ms,
        rep.speedup()
    );
    println!(
        "loss curve: {}",
        prunemap::report::sparkline(
            &rep.loss_curve.iter().map(|&x| x as f64).collect::<Vec<_>>()
        )
    );
    Ok(())
}

fn run() -> Result<()> {
    let args = Args::from_env();
    let cmd = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("help");
    let dev = device(&args)?;
    match cmd {
        "fig3" => exp::fig3().print(),
        "fig5" => exp::fig5(&dev).print(),
        "fig7" => exp::fig7().iter().for_each(|f| f.print()),
        "fig9" => exp::fig9(&dev).iter().for_each(|f| f.print()),
        "fig10a" => exp::fig10a(&dev).print(),
        "fig10b" => exp::fig10b(&dev).print(),
        "table1" => exp::table1().print(),
        "table2" => exp::table2(&dev).print(),
        "table3" => exp::table3().print(),
        "table4" => exp::table4(&dev, args.flag("quick")).print(),
        "table5" => exp::table5(&dev).print(),
        "table6" => exp::table6().print(),
        "table7" => exp::table7().print(),
        "ablation" => exp::ablation(&dev).print(),
        "all" => {
            exp::fig3().print();
            exp::fig5(&dev).print();
            exp::fig7().iter().for_each(|f| f.print());
            exp::fig9(&dev).iter().for_each(|f| f.print());
            exp::fig10a(&dev).print();
            exp::fig10b(&dev).print();
            exp::table1().print();
            exp::table2(&dev).print();
            exp::table3().print();
            exp::table4(&dev, true).print();
            exp::table5(&dev).print();
            exp::table6().print();
            exp::table7().print();
            exp::ablation(&dev).print();
        }
        "latmodel" => {
            let out = args.get_or("out", "latmodel.json");
            let m = LatencyModel::build(&dev);
            m.save(out)?;
            println!("saved {} settings for {} to {out}", m.len(), m.device);
        }
        "map" => cmd_map(&args)?,
        "infer" => cmd_infer(&args)?,
        "serve" => cmd_serve(&args)?,
        #[cfg(pjrt)]
        "e2e" => cmd_e2e(&args)?,
        #[cfg(not(pjrt))]
        "e2e" => {
            return Err(anyhow!(
                "the e2e pipeline needs the PJRT runtime: vendor the `xla` crate and rebuild with RUSTFLAGS=\"--cfg pjrt\" (see src/runtime/pjrt.rs)"
            ));
        }
        _ => {
            println!(
                "usage: prunemap <fig3|fig5|fig7|fig9|fig10a|fig10b|table1..table7|all|latmodel|map|infer|serve|e2e> [--device s10|s20|s21] [--threads N] [--batch N] [--tile N] [--materialized] [--max-batch N] [--max-wait-ms F]"
            );
        }
    }
    Ok(())
}

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
