//! `prunemap` launcher: regenerate any paper table/figure, build latency
//! models, map pruning schemes onto zoo models, and serve inference
//! through the compile-once/serve-many session API.
//!
//! ```text
//! prunemap <command> [--device s10|s20|s21] [options] [--flags]
//!
//! Commands:
//!   fig3 | fig5 | fig7 | fig9 | fig10a | fig10b
//!   table1 | table2 | table3 | table4 | table5 | table6 | table7
//!   all                    every table and figure in order
//!   latmodel --out F       build + save the device latency model
//!   map --model M --dataset D --method rule|search
//!   check [--model M --dataset D --method rule|search | --load F]
//!         [--seed N] [--json-out F]
//!                          static analyzer over the compiled artifact:
//!                          shape/dataflow, arena liveness/aliasing,
//!                          scheme legality + mask structure, and plan
//!                          hygiene, each finding tagged with a stable
//!                          rule id (see README "Static analysis").
//!                          --load parses a saved recipe (bypassing the
//!                          sealing gate so corrupt artifacts can be
//!                          diagnosed); --json-out writes line-JSON
//!                          diagnostics plus a per-severity summary
//!                          object.  Exits nonzero on any error-severity
//!                          finding (--deny-warnings: on warnings too).
//!   lint [--model M --dataset D --method rule|search | --load F]
//!        [--calibration F] [--threshold F] [--seed N] [--json-out F]
//!                          advisory performance lint over the same
//!                          artifact: price the sealed mapping with the
//!                          cost model and report lane-misaligned
//!                          blocks, scheme/kernel mismatches (with
//!                          predicted-speedup suggestions), stride-split
//!                          load imbalance, missed fusion, dominant-layer
//!                          concentration, and — with a `prunemap
//!                          profile --json-out` record via --calibration
//!                          — measured/modeled divergence, re-pricing
//!                          every rule with the measured ratios.
//!                          Advice never gates (exit 0); --threshold
//!                          sets the minimum predicted speedup before a
//!                          scheme mismatch is reported (default 1.10).
//!   infer --model M --dataset D [--threads N] [--batch N] [--tile N]
//!         [--materialized] [--json-out F]
//!                          native end-to-end inference through the graph
//!                          executor: per-layer scheme + measured latency
//!   profile --model M [--reps N] [--warmup N] [--batch N] [--threads N]
//!           [--json-out F] [--trace-out F]
//!                          run N traced inferences, aggregate the
//!                          recorded spans into a per-layer time table,
//!                          and emit the trace-fed calibration record
//!                          (plus a Chrome trace-event JSON dump)
//!   serve [--models M1,M2 | --model M] [--listen ADDR|stdio] [--conns N]
//!         [--requests N] [--clients N] [--deadline-ms F] [--max-batch N]
//!         [--max-wait-ms F] [--max-queue N] [--max-conns N] [--workers N]
//!         [--save F | --load [name=]F] [--metrics ADDR] [--trace-out F]
//!                          multi-model serving front door: compile each
//!                          model once, route typed requests by name with
//!                          priority lanes + deadline admission.  With
//!                          --listen, speak the line-JSON wire protocol
//!                          over TCP or stdio; otherwise run an in-process
//!                          burst of --requests from --clients threads.
//!                          --metrics serves the Prometheus exposition
//!                          document to HTTP scrapers; --trace-out dumps
//!                          every recorded span as Chrome trace JSON when
//!                          serving ends.  Serve diagnostics go to stderr
//!                          (stdout belongs to the wire in stdio mode).
//!   bench [--defs PATH] [--only SUBSTR] [--samples N] [--warmup N]
//!         [--json-out F] [--no-fork] [--check] [--strict]
//!         [--update-checksums]
//!                          the benchmark barometer: run the checked-in
//!                          definitions under benches/defs/ (one child
//!                          process per measurement), print normalized
//!                          RECORD lines, and verify each definition's
//!                          pinned output checksum.  --check verifies
//!                          checksums without timing; --update-checksums
//!                          pins observed values back into the files.
//!   bench cmp BASE.json CONT.json [--threshold F] [--report-only]
//!                          diff two record sets: per-benchmark speedup
//!                          ratios, nonzero exit on a regression beyond
//!                          the noise threshold or a checksum drift
//!   bench rank SET.json    rank engine variants per workload
//!   e2e [--steps N]        live pipeline on the proxy CNN (needs artifacts)
//! ```

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use prunemap::accuracy::Assignment;
use prunemap::bench::{self, runner, CheckOutcome, RecordSet, RecordSink};
use prunemap::experiments as exp;
use prunemap::latmodel::LatencyModel;
use prunemap::mapping::{self, MappingMethod};
use prunemap::models::{zoo, Dataset, ModelSpec};
#[cfg(pjrt)]
use prunemap::runtime::Runtime;
use prunemap::analysis::{self, Diagnostic, Rule, Severity};
use prunemap::runtime::{Arena, CompiledNet, GraphExecutor, KernelChoice};
use prunemap::serve::{
    wire, InferRequest, ModelRegistry, PreparedModel, Priority, ServeError, Server, Session, Ticket,
};
use prunemap::simulator::{measured_vs_modeled_network, DeviceProfile, PerLayerCalibration};
use prunemap::telemetry::{self, trace, TraceRing};
use prunemap::util::cli::Args;
use prunemap::util::json::Value;

fn model_by_name(name: &str, ds: Dataset) -> Result<ModelSpec> {
    zoo::by_name(name, ds).ok_or_else(|| anyhow!("unknown model '{name}'"))
}

fn dataset_by_name(name: &str) -> Result<Dataset> {
    Dataset::by_name(name).ok_or_else(|| anyhow!("unknown dataset '{name}'"))
}

fn device(args: &Args) -> Result<DeviceProfile> {
    let name = args.get_or("device", "s10");
    DeviceProfile::by_name(name).ok_or_else(|| anyhow!("unknown device '{name}'"))
}

fn cmd_map(args: &Args) -> Result<()> {
    let dev = device(args)?;
    let ds = dataset_by_name(args.get_or("dataset", "imagenet"))?;
    let model = model_by_name(args.get_or("model", "resnet50"), ds)?;
    let method = MappingMethod::from_args(args, 60, args.get_u64("seed", 0xC0FFEE)?)?;
    let assigns = method.assign(&model, &dev);
    exp::describe_mapping(&model, &assigns).print();
    let e = mapping::evaluate(&model, &assigns, &dev);
    let dense = mapping::dense_latency_ms(&model, &dev);
    // degenerate modeled latencies must not print as "infx speedup"
    let speedup = if e.latency_ms > 1e-12 {
        format!("{:.2}x", dense / e.latency_ms)
    } else {
        "n/a".to_string()
    };
    println!(
        "\ncompression {:.2}x | acc drop {:+.2}% | latency {:.2}ms (dense {:.2}ms, {speedup} speedup) | MACs {:.2}G",
        e.compression,
        e.acc_drop * 100.0,
        e.latency_ms,
        dense,
        e.macs / 1e9
    );
    Ok(())
}

/// Resolve the artifact both analyzers operate on: map a zoo model, or
/// parse a saved recipe with `--load` (bypassing the sealing gate so
/// corrupt artifacts can be diagnosed).
fn resolve_artifact(
    args: &Args,
) -> Result<(ModelSpec, Vec<Assignment>, u64, KernelChoice, String)> {
    if let Some(path) = args.get("load") {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read artifact from {path}"))?;
        let (model, assigns, seed, choice, method) =
            PreparedModel::recipe_from_json(&Value::parse(&text)?)?;
        Ok((model, assigns, seed, choice, format!("{path} (method {method})")))
    } else {
        let dev = device(args)?;
        let ds = dataset_by_name(args.get_or("dataset", "cifar10"))?;
        let model = model_by_name(args.get_or("model", "proxy"), ds)?;
        let method = MappingMethod::from_args(args, 30, args.get_u64("search-seed", 0xC0FFEE)?)?;
        let assigns = method.assign(&model, &dev);
        let origin = format!("method {}", method.label());
        Ok((model, assigns, args.get_u64("seed", 7)?, KernelChoice::Auto, origin))
    }
}

/// Write a report's line-JSON diagnostics plus the trailing per-severity
/// summary object to `--json-out`, when requested.
fn write_json_out(args: &Args, report: &analysis::Report) -> Result<()> {
    if let Some(path) = args.get("json-out") {
        let mut out = report.to_jsonl();
        out.push_str(&report.summary_json().compact());
        out.push('\n');
        std::fs::write(path, out).with_context(|| format!("write diagnostics to {path}"))?;
        eprintln!("wrote {} diagnostic(s) to {path}", report.diagnostics.len());
    }
    Ok(())
}

/// Statically verify an artifact: compile it, run every analysis pass,
/// and render the diagnostics.  Exits nonzero iff any Error-severity
/// rule fired — or any Warning too under `--deny-warnings`.
fn cmd_check(args: &Args) -> Result<()> {
    let (model, assigns, seed, choice, origin) = resolve_artifact(args)?;
    println!(
        "check {} / {} ({} layers, {origin})",
        model.name,
        model.dataset.name(),
        model.layers.len()
    );

    // pre-compile legality first: an illegal mapping must come out as
    // diagnostics, not as a synthesis bail
    let mut report = analysis::check_assignments(&model, &assigns);
    if !report.has_errors() {
        match CompiledNet::compile_with_weights(&model, &assigns, seed, choice) {
            Ok((weights, net)) => {
                report = analysis::check_model(&model, &assigns, &weights, &net);
            }
            Err(e) => report.diagnostics.push(Diagnostic {
                rule: Rule::CompileFailed,
                severity: Severity::Error,
                site: model.name.clone(),
                message: format!("{e:#}"),
                suggestion: None,
            }),
        }
    }

    print!("{}", report.render());
    write_json_out(args, &report)?;
    if report.has_errors() {
        return Err(anyhow!(
            "{} error-severity diagnostic(s) for {}",
            report.error_count(),
            model.name
        ));
    }
    if args.flag("deny-warnings") && report.warning_count() > 0 {
        return Err(anyhow!(
            "{} warning-severity diagnostic(s) for {} (--deny-warnings)",
            report.warning_count(),
            model.name
        ));
    }
    Ok(())
}

/// Advisory performance lint over an artifact: price the sealed mapping
/// with the cost model — re-priced by a `--calibration` record when one
/// is given — and render clippy-style advice with structured
/// suggestions.  Advice never gates: the exit code is nonzero only when
/// the artifact cannot be compiled at all.
fn cmd_lint(args: &Args) -> Result<()> {
    let dev = device(args)?;
    let (model, assigns, seed, choice, origin) = resolve_artifact(args)?;
    println!(
        "lint {} / {} ({} layers, {origin})",
        model.name,
        model.dataset.name(),
        model.layers.len()
    );

    let defaults = analysis::LintConfig::default();
    let lint_cfg = analysis::LintConfig {
        speedup_threshold: args.get_f32("threshold", defaults.speedup_threshold as f32)? as f64,
        ..defaults
    };
    let calibration = match args.get("calibration") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("read calibration record from {path}"))?;
            let record = analysis::CalibrationRecord::from_json(&Value::parse(&text)?)?;
            if record.model != model.name {
                return Err(anyhow!(
                    "calibration record is for '{}', artifact is '{}'",
                    record.model,
                    model.name
                ));
            }
            eprintln!(
                "re-pricing with {} measured layer(s) from {path} (median ratio {:.2})",
                record.layers.len(),
                record.median_ratio()
            );
            Some(record)
        }
        None => None,
    };

    let mut report = analysis::check_assignments(&model, &assigns);
    if !report.has_errors() {
        match CompiledNet::compile_with_weights(&model, &assigns, seed, choice) {
            Ok((weights, _net)) => {
                report = analysis::lint_model(
                    &model,
                    &assigns,
                    &weights,
                    &dev,
                    &lint_cfg,
                    calibration.as_ref(),
                );
            }
            Err(e) => report.diagnostics.push(Diagnostic {
                rule: Rule::CompileFailed,
                severity: Severity::Error,
                site: model.name.clone(),
                message: format!("{e:#}"),
                suggestion: None,
            }),
        }
    }

    print!("{}", report.render());
    write_json_out(args, &report)?;
    if report.has_errors() {
        return Err(anyhow!(
            "{} error-severity diagnostic(s) for {}",
            report.error_count(),
            model.name
        ));
    }
    Ok(())
}

/// Build a [`PreparedModel`] for one zoo name from the shared CLI surface
/// (`--dataset`, `--device`, `--method`/`--iterations`/`--search-seed`,
/// `--seed`) — the one resolution path `infer` and `serve` share.
fn prepared_named(args: &Args, model: &str) -> Result<PreparedModel> {
    let method = MappingMethod::from_args(args, 30, args.get_u64("search-seed", 0xC0FFEE)?)?;
    PreparedModel::builder()
        .model(model)
        .dataset(args.get_or("dataset", "cifar10"))
        .device(args.get_or("device", "s10"))
        .mapping(method)
        .seed(args.get_u64("seed", 7)?)
        .build()
}

/// `infer`'s single-model resolution: `--model` (default mobilenetv1).
fn prepared_from_args(args: &Args) -> Result<PreparedModel> {
    prepared_named(args, args.get_or("model", "mobilenetv1"))
}

/// Map a zoo model, seal it into a [`PreparedModel`], and run it end to
/// end through a serving [`Session`] — per-layer scheme + measured
/// latency, plus a measured-vs-modeled calibration JSON record.
fn cmd_infer(args: &Args) -> Result<()> {
    let dev = device(args)?;
    let threads = args.engine_threads()?;
    let batch = args.batch_size(1)?;
    let reps = args.get_usize("reps", 3)?;
    let prepared = prepared_from_args(args)?;
    let session = Session::builder(prepared.clone())
        .threads(threads)
        .tile_cols(args.tile_cols(prunemap::sparse::DEFAULT_TILE_COLS)?)
        .fused(!args.materialized())
        .build();

    let (c, h, w) = prepared.input_shape();
    let input: Vec<f32> = (0..batch * c * h * w)
        .map(|i| ((i % 17) as f32) * 0.25 - 2.0)
        .collect();
    // warmed diagnostic run (bypasses the micro-batcher): per-layer
    // timings measure the steady-state allocation-free path
    let (_, timings) = session.run_timed(&input, batch)?;

    let net = prepared.net();
    println!(
        "{} ({} layers, {} steps) | input {c}x{h}x{w} | batch {batch} | {threads} threads | {} im2col\n",
        prepared.name(),
        net.layers.len(),
        net.steps.len(),
        if session.is_fused() { "fused" } else { "materialized" }
    );
    println!(
        "{:<16} {:>14} {:>6} {:>8} {:>12} {:>10}",
        "layer", "scheme", "comp", "backend", "nnz", "ms"
    );
    let summaries: std::collections::HashMap<String, prunemap::runtime::graph::LayerSummary> =
        net.summaries().into_iter().map(|s| (s.name.clone(), s)).collect();
    let mut total_ms = 0.0;
    for t in &timings {
        total_ms += t.ms;
        match summaries.get(&t.name) {
            Some(s) => println!(
                "{:<16} {:>14} {:>5.1}x {:>8} {:>12} {:>9.3}ms",
                s.name, s.scheme, s.compression, s.backend, s.nnz, t.ms
            ),
            None => println!(
                "{:<16} {:>14} {:>6} {:>8} {:>12} {:>9.3}ms",
                t.name, "-", "-", "-", "-", t.ms
            ),
        }
    }
    println!("\ntotal {total_ms:.3}ms measured (host, whole batch)");

    let cmp = measured_vs_modeled_network(
        prepared.model(),
        prepared.assigns(),
        &dev,
        net,
        batch,
        threads,
        reps,
    )?;
    println!("measured-vs-modeled: {}", cmp.to_json().compact());
    if let Some(path) = args.get("json-out") {
        std::fs::write(path, cmp.to_json().pretty())?;
        println!("wrote calibration record to {path}");
    }
    Ok(())
}

/// `prunemap profile`: run `--reps` traced inferences through the graph
/// executor, aggregate the recorded step spans into a per-layer time
/// table, and join the measured means against the analytic cost model —
/// the trace-fed calibration record [`PerLayerCalibration`] feeds
/// `simulator::cost` tuning.  `--trace-out` additionally dumps every
/// span as Chrome trace-event JSON (load it in `chrome://tracing` or
/// Perfetto).
fn cmd_profile(args: &Args) -> Result<()> {
    let dev = device(args)?;
    let threads = args.engine_threads()?;
    let batch = args.batch_size(1)?;
    let reps = args.get_usize("reps", 10)?.max(1);
    let warmup = args.get_usize("warmup", 1)?;
    let prepared = prepared_from_args(args)?;
    let net = prepared.net();

    // sized so a full profile run never evicts: every step can emit a
    // step span plus up to three op spans, and each run adds a root +
    // batch-assembly slack
    let ring = TraceRing::new(reps * (net.steps.len() * 4 + 2) + 16);
    let mut executor = GraphExecutor::new(threads)
        .with_tile_cols(args.tile_cols(prunemap::sparse::DEFAULT_TILE_COLS)?)
        .with_trace(Arc::clone(&ring));
    if args.materialized() {
        executor = executor.materialized();
    }

    let (c, h, w) = prepared.input_shape();
    let input: Vec<f32> = (0..batch * c * h * w)
        .map(|i| ((i % 17) as f32) * 0.25 - 2.0)
        .collect();
    let mut arena = Arena::new();
    for _ in 0..warmup {
        executor.run_with_arena(net, &input, batch, &mut arena)?;
    }
    ring.clear();
    let t0 = Instant::now();
    for _ in 0..reps {
        executor.run_with_arena(net, &input, batch, &mut arena)?;
    }
    let elapsed = t0.elapsed();
    let spans = ring.snapshot();

    // aggregate step spans by name in first-seen (execution) order; the
    // mean over reps is the per-layer measurement the table and the
    // calibration record share
    let mut order: Vec<String> = Vec::new();
    let mut total_ns: std::collections::HashMap<String, u64> = std::collections::HashMap::new();
    for s in spans.iter().filter(|s| s.cat == trace::CAT_STEP) {
        if !total_ns.contains_key(&s.name) {
            order.push(s.name.clone());
        }
        *total_ns.entry(s.name.clone()).or_insert(0) += s.dur_ns;
    }
    let measured: Vec<(String, f64)> = order
        .iter()
        .map(|name| (name.clone(), total_ns[name] as f64 / 1e6 / reps as f64))
        .collect();

    println!(
        "{} ({} layers, {} steps) | input {c}x{h}x{w} | batch {batch} | {threads} threads | {reps} rep(s) | {} im2col\n",
        prepared.name(),
        net.layers.len(),
        net.steps.len(),
        if args.materialized() { "materialized" } else { "fused" }
    );
    println!(
        "{:<16} {:>14} {:>6} {:>8} {:>12} {:>10}",
        "layer", "scheme", "comp", "backend", "nnz", "mean ms"
    );
    let summaries: std::collections::HashMap<String, prunemap::runtime::graph::LayerSummary> =
        net.summaries().into_iter().map(|s| (s.name.clone(), s)).collect();
    let mut total_ms = 0.0;
    for (name, ms) in &measured {
        total_ms += *ms;
        match summaries.get(name) {
            Some(s) => println!(
                "{:<16} {:>14} {:>5.1}x {:>8} {:>12} {:>9.3}ms",
                s.name, s.scheme, s.compression, s.backend, s.nnz, ms
            ),
            None => println!(
                "{:<16} {:>14} {:>6} {:>8} {:>12} {:>9.3}ms",
                name, "-", "-", "-", "-", ms
            ),
        }
    }
    println!(
        "\ntotal {total_ms:.3}ms mean per run | {:.1}ms wall over {reps} rep(s) | {} span(s) recorded, {} dropped",
        elapsed.as_secs_f64() * 1e3,
        spans.len(),
        ring.dropped()
    );

    let cal = PerLayerCalibration::new(
        prepared.model(),
        prepared.assigns(),
        &dev,
        &measured,
        threads,
        batch,
        reps,
    )?;
    println!("\nper-layer measured-vs-modeled ({}):", dev.name);
    for l in &cal.layers {
        println!(
            "  {:<16} modeled {:>8.3}ms  measured {:>8.3}ms  ratio {:>5.2}x",
            l.name,
            l.modeled_ms,
            l.measured_ms,
            l.ratio()
        );
    }
    if let Some(path) = args.get("json-out") {
        std::fs::write(path, cal.to_json().pretty())
            .with_context(|| format!("write calibration record to {path}"))?;
        println!("wrote calibration record to {path}");
    }
    if let Some(path) = args.trace_out() {
        std::fs::write(path, telemetry::chrome_trace_json(&spans).pretty())
            .with_context(|| format!("write trace to {path}"))?;
        println!("wrote {} trace span(s) to {path}", spans.len());
    }
    Ok(())
}

/// Build the serving registry from the CLI: either one `--load
/// [name=]recipe.json` artifact (registered under `name`, defaulting to
/// the lowercased spec name), or every `--models`/`--model` zoo name,
/// each sealed with the shared dataset/device/method/seed surface.
fn registry_from_args(args: &Args) -> Result<ModelRegistry> {
    let registry = ModelRegistry::new();
    if let Some(spec) = args.get("load") {
        let (name, path) = match spec.split_once('=') {
            Some((name, path)) => (Some(name.to_string()), path),
            None => (None, spec),
        };
        let prepared = PreparedModel::load(path)?;
        let name = name.unwrap_or_else(|| prepared.name().to_lowercase());
        eprintln!("loaded prepared artifact from {path} as '{name}'");
        registry.insert(name, prepared);
    } else {
        for name in args.models("mobilenetv1") {
            let prepared =
                prepared_named(args, &name).with_context(|| format!("prepare model '{name}'"))?;
            registry.insert(name, prepared);
        }
    }
    Ok(registry)
}

/// Multi-model serving front door: seal every requested model into the
/// registry, open a [`Server`] routing typed requests across them, then
/// either speak the wire protocol (`--listen ADDR|stdio`) or drive an
/// in-process concurrent burst.  All diagnostics go to stderr — in stdio
/// wire mode stdout carries reply frames and nothing else.
fn cmd_serve(args: &Args) -> Result<()> {
    let threads = args.engine_threads()?;
    let registry = registry_from_args(args)?;
    if let Some(path) = args.get("save") {
        let names = registry.names();
        let [name] = names.as_slice() else {
            return Err(anyhow!(
                "--save needs exactly one model to serialize, got {names:?}"
            ));
        };
        registry.get(name).expect("registered above").save(path)?;
        eprintln!("saved prepared artifact to {path}");
    }
    let max_batch = args.max_batch(32)?;
    let max_wait = args.max_wait(2.0)?;
    let max_queue = args.max_queue(prunemap::serve::DEFAULT_MAX_QUEUE)?;
    let workers = args.get_usize("workers", 1)?;
    // the ring exists only when someone will read it (--trace-out), so
    // the default serve path stays allocation- and lock-free on spans
    let ring = args.trace_out().map(|_| TraceRing::new(65_536));
    let mut builder = Server::builder(registry.clone())
        .threads(threads)
        .tile_cols(args.tile_cols(prunemap::sparse::DEFAULT_TILE_COLS)?)
        .fused(!args.materialized())
        .max_batch(max_batch)
        .max_wait(max_wait)
        .max_queue(max_queue)
        .workers(workers);
    if let Some(ring) = &ring {
        builder = builder.trace(Arc::clone(ring));
    }
    let server = Arc::new(builder.build());
    eprintln!(
        "front door: [{}] | {threads} engine threads | max batch {max_batch} | max wait {max_wait:?} | max queue {max_queue} | {workers} worker(s) per model",
        registry.names().join(", ")
    );
    if let Some(addr) = args.metrics_addr() {
        let listener = std::net::TcpListener::bind(addr)
            .with_context(|| format!("bind metrics listener on {addr}"))?;
        eprintln!("metrics on http://{}/metrics", listener.local_addr()?);
        let scraped = Arc::clone(&server);
        // the scrape loop runs until the process exits; each GET renders
        // a fresh snapshot of every session's counters
        std::thread::Builder::new()
            .name("prunemap-metrics".into())
            .spawn(move || {
                if let Err(e) = telemetry::serve_text(listener, None, move || scraped.metrics_text())
                {
                    eprintln!("metrics listener failed: {e}");
                }
            })
            .context("spawn metrics listener thread")?;
    }

    match args.listen() {
        Some("stdio") => {
            let stdin = std::io::stdin();
            // Stdout (not StdoutLock) because the reply writer runs on its
            // own thread; frames are flushed per line either way
            let stats = wire::serve_connection(&server, stdin.lock(), std::io::stdout())?;
            eprintln!(
                "stdio connection closed: {} served, {} error frame(s)",
                stats.served, stats.errors
            );
        }
        Some(addr) => {
            let listener = std::net::TcpListener::bind(addr)
                .with_context(|| format!("bind wire listener on {addr}"))?;
            eprintln!("listening on {}", listener.local_addr()?);
            let conns = args.get_usize("conns", 0)?;
            let max_active = args.max_conns(256)?;
            wire::serve_tcp(&server, listener, (conns > 0).then_some(conns), max_active)?;
        }
        None => serve_burst(args, &server)?,
    }
    for (model, st) in server.stats() {
        print_session_stats(&model, &st);
    }
    if let (Some(path), Some(ring)) = (args.trace_out(), &ring) {
        std::fs::write(path, telemetry::chrome_trace_json(&ring.snapshot()).pretty())
            .with_context(|| format!("write trace to {path}"))?;
        eprintln!("wrote {} trace span(s) to {path} ({} dropped)", ring.len(), ring.dropped());
    }
    Ok(())
}

/// The in-process load generator behind plain `prunemap serve`:
/// `--clients` threads pipeline `--requests` typed submissions round-robin
/// across the registered models (every fourth request rides the high lane;
/// `--deadline-ms` arms deadline admission).  Ticket failures are
/// propagated as errors naming the request index — except deadline
/// rejections, which the burst counts as the admission working as
/// configured.
fn serve_burst(args: &Args, server: &Server) -> Result<()> {
    let requests = args.get_usize("requests", 64)?.max(1);
    let clients = args.get_usize("clients", 8)?.max(1);
    let deadline = args.deadline_ms()?;
    let models: Vec<(String, usize)> = server
        .registry()
        .names()
        .into_iter()
        .map(|name| {
            let len = server.registry().get(&name).expect("registered").input_len();
            (name, len)
        })
        .collect();
    let per_client = requests.div_ceil(clients);
    let total = per_client * clients;
    let expired = AtomicUsize::new(0);
    let t0 = Instant::now();
    std::thread::scope(|scope| -> Result<()> {
        let handles: Vec<_> = (0..clients)
            .map(|client| {
                let (server, models, expired) = (&server, &models, &expired);
                scope.spawn(move || -> Result<()> {
                    let finish = |(tag, ticket): (usize, Ticket)| -> Result<()> {
                        match ticket.wait() {
                            Ok(_) => Ok(()),
                            Err(ServeError::DeadlineExpired { .. }) => {
                                expired.fetch_add(1, Ordering::Relaxed);
                                Ok(())
                            }
                            Err(e) => {
                                Err(anyhow!(e).context(format!("serve request {tag} failed")))
                            }
                        }
                    };
                    // each client keeps a small submission pipeline open
                    // so concurrent requests exist for the per-model
                    // batchers to coalesce
                    let mut pending: Vec<(usize, Ticket)> = Vec::new();
                    for r in 0..per_client {
                        let tag = client * per_client + r;
                        let (model, sample) = &models[tag % models.len()];
                        let input: Vec<f32> = (0..*sample)
                            .map(|j| (((tag + j) % 17) as f32) * 0.25 - 2.0)
                            .collect();
                        let mut req = InferRequest::new(model.clone(), input);
                        if tag % 4 == 0 {
                            req = req.priority(Priority::High);
                        }
                        if let Some(d) = deadline {
                            req = req.deadline(d);
                        }
                        let ticket = server
                            .submit(req)
                            .map_err(|e| anyhow!(e).context(format!("submit request {tag}")))?;
                        pending.push((tag, ticket));
                        if pending.len() >= 4 {
                            finish(pending.remove(0))?;
                        }
                    }
                    pending.into_iter().try_for_each(finish)
                })
            })
            .collect();
        for handle in handles {
            handle.join().map_err(|_| anyhow!("serve client panicked"))??;
        }
        Ok(())
    })?;
    let elapsed = t0.elapsed();
    let expired = expired.load(Ordering::Relaxed);
    eprintln!(
        "\nserved {} of {total} requests from {clients} client(s) across {} model(s) in {:.1}ms -> {:.0} req/s ({expired} deadline-expired)",
        total - expired,
        models.len(),
        elapsed.as_secs_f64() * 1e3,
        total as f64 / elapsed.as_secs_f64().max(1e-9)
    );
    Ok(())
}

/// Print one model's admission counters (the `Server::stats` snapshot):
/// throughput shape, queue pressure, and wait-time distribution.  The
/// text itself is rendered by [`telemetry::render_session_stats`] — the
/// same renderer the exporter tests pin — so CLI output and exporter
/// cannot drift apart.
fn print_session_stats(model: &str, st: &prunemap::serve::SessionStats) {
    eprint!("{}", telemetry::render_session_stats(model, st));
}

/// `prunemap bench ...`: the barometer front end.  Sub-commands `cmp`
/// and `rank` are reporters over record files; everything else runs the
/// definition set.
fn cmd_bench(args: &Args) -> Result<()> {
    match args.positional.get(1).map(String::as_str) {
        Some("cmp") => cmd_bench_cmp(args),
        Some("rank") => {
            let path = args.positional.get(2).ok_or_else(|| {
                anyhow!("usage: prunemap bench rank <records.json>")
            })?;
            print!("{}", bench::rank(&RecordSet::load(path)?));
            Ok(())
        }
        _ => cmd_bench_run(args),
    }
}

/// `prunemap bench cmp BASE CONT`: pair the two record sets, print the
/// per-benchmark table, and fail on regressions/drift unless
/// `--report-only`.
fn cmd_bench_cmp(args: &Args) -> Result<()> {
    let usage = "usage: prunemap bench cmp <baseline.json> <contender.json> [--threshold F] [--report-only]";
    let base = args.positional.get(2).ok_or_else(|| anyhow!(usage))?;
    let cont = args.positional.get(3).ok_or_else(|| anyhow!(usage))?;
    let threshold = f64::from(args.get_f32("threshold", bench::NOISE_THRESHOLD as f32)?);
    let report = bench::compare(&RecordSet::load(base)?, &RecordSet::load(cont)?, threshold);
    print!("{}", report.render());
    if report.failed() && !args.flag("report-only") {
        return Err(anyhow!(
            "{} benchmark(s) regressed beyond the {:.0}% noise threshold, {} checksum drift(s)",
            report.regressions(),
            threshold * 100.0,
            report.drifted()
        ));
    }
    Ok(())
}

/// The measurement / `--check` path over a definition set.
fn cmd_bench_run(args: &Args) -> Result<()> {
    let defs_path = args.get_or("defs", "benches/defs");
    let mut defs = bench::load_defs(defs_path)?;
    let child = args.flag("child");
    if let Some(filter) = args.get("only") {
        // the child re-exec names one exact id; interactive use filters
        // by substring
        if child {
            defs.retain(|d| d.id() == filter);
        } else {
            defs.retain(|d| d.id().contains(filter));
        }
        if defs.is_empty() {
            return Err(anyhow!("--only '{filter}' matched no definition in {defs_path}"));
        }
    }
    let samples = args.get_opt_usize("samples")?;
    let warmup = args.get_opt_usize("warmup")?;

    if args.flag("check") || args.flag("update-checksums") {
        let report = runner::check_defs(&defs)?;
        print!("{}", report.render());
        if args.flag("update-checksums") {
            for (id, source, outcome) in &report.rows {
                let actual = match outcome {
                    CheckOutcome::Matched => continue,
                    CheckOutcome::Mismatched { actual, .. } => actual,
                    CheckOutcome::Unpinned { actual } => actual,
                };
                let source = source
                    .as_ref()
                    .ok_or_else(|| anyhow!("'{id}' has no source file to pin into"))?;
                if prunemap::bench::defs::pin_checksum(source, id, actual)? {
                    println!("pinned {id} = {actual} in {}", source.display());
                }
            }
            return Ok(());
        }
        if report.failed(args.flag("strict")) {
            return Err(anyhow!(
                "{} checksum mismatch(es), {} unpinned definition(s)",
                report.mismatched(),
                report.unpinned()
            ));
        }
        return Ok(());
    }

    // measurement run: by default one child process per definition so no
    // benchmark warms pools or caches for the next; --no-fork (and the
    // child itself) measures in-process
    let mut sink = RecordSink::new(args.get("json-out").map(std::path::PathBuf::from));
    let mut drifted = Vec::new();
    for def in &defs {
        let m = if child || args.flag("no-fork") {
            runner::measure(def, samples, warmup)?
        } else {
            runner::measure_in_child(def, samples, warmup)?
        };
        println!("RECORD {}", m.to_json().compact());
        if !child {
            println!(
                "{:<48} mean {:>12.0}ns  stddev {:>10.0}ns  min {:>12.0}ns  ({} iters)",
                m.id(),
                m.mean_ns,
                m.stddev_ns,
                m.min_ns,
                m.iters
            );
        }
        if let Some(expected) = &def.checksum {
            if *expected != m.checksum {
                drifted.push(format!("{}: pinned {expected}, observed {}", def.id(), m.checksum));
            }
        }
        sink.push(m)?;
    }
    if let Some(path) = args.get("json-out") {
        if !child {
            println!("wrote {} record(s) to {path}", sink.records().len());
        }
    }
    if !drifted.is_empty() {
        return Err(anyhow!("output checksum drift:\n  {}", drifted.join("\n  ")));
    }
    Ok(())
}

#[cfg(pjrt)]
fn cmd_e2e(args: &Args) -> Result<()> {
    let rt = Runtime::open(Runtime::default_dir())?;
    println!("PJRT platform: {}", rt.platform());
    let dev = device(args)?;
    let model = zoo::proxy_cnn();
    let method = MappingMethod::from_args(args, 30, args.get_u64("search-seed", 0xC0FFEE)?)?;
    let assigns = method.assign(&model, &dev);
    exp::describe_mapping(&model, &assigns).print();
    let cfg = prunemap::coordinator::PipelineConfig {
        pretrain_steps: args.get_usize("steps", 150)?,
        ..Default::default()
    };
    let rep = prunemap::coordinator::run_pipeline(&rt, &model, &assigns, &dev, &cfg)?;
    println!(
        "\nacc: pretrained {:.3} -> pruned {:.3} -> retrained {:.3}",
        rep.acc_pretrained, rep.acc_after_prune, rep.acc_after_retrain
    );
    println!(
        "compression {:.2}x | latency {:.3}ms -> {:.3}ms ({:.2}x)",
        rep.overall_compression,
        rep.dense_latency_ms,
        rep.pruned_latency_ms,
        rep.speedup()
    );
    println!(
        "loss curve: {}",
        prunemap::report::sparkline(
            &rep.loss_curve.iter().map(|&x| x as f64).collect::<Vec<_>>()
        )
    );
    Ok(())
}

fn run() -> Result<()> {
    let args = Args::from_env();
    let cmd = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("help");
    let dev = device(&args)?;
    match cmd {
        "fig3" => exp::fig3().print(),
        "fig5" => exp::fig5(&dev).print(),
        "fig7" => exp::fig7().iter().for_each(|f| f.print()),
        "fig9" => exp::fig9(&dev).iter().for_each(|f| f.print()),
        "fig10a" => exp::fig10a(&dev).print(),
        "fig10b" => exp::fig10b(&dev).print(),
        "table1" => exp::table1().print(),
        "table2" => exp::table2(&dev).print(),
        "table3" => exp::table3().print(),
        "table4" => exp::table4(&dev, args.flag("quick")).print(),
        "table5" => exp::table5(&dev).print(),
        "table6" => exp::table6().print(),
        "table7" => exp::table7().print(),
        "ablation" => exp::ablation(&dev).print(),
        "all" => {
            exp::fig3().print();
            exp::fig5(&dev).print();
            exp::fig7().iter().for_each(|f| f.print());
            exp::fig9(&dev).iter().for_each(|f| f.print());
            exp::fig10a(&dev).print();
            exp::fig10b(&dev).print();
            exp::table1().print();
            exp::table2(&dev).print();
            exp::table3().print();
            exp::table4(&dev, true).print();
            exp::table5(&dev).print();
            exp::table6().print();
            exp::table7().print();
            exp::ablation(&dev).print();
        }
        "latmodel" => {
            let out = args.get_or("out", "latmodel.json");
            let m = LatencyModel::build(&dev);
            m.save(out)?;
            println!("saved {} settings for {} to {out}", m.len(), m.device);
        }
        "map" => cmd_map(&args)?,
        "check" => cmd_check(&args)?,
        "lint" => cmd_lint(&args)?,
        "infer" => cmd_infer(&args)?,
        "profile" => cmd_profile(&args)?,
        "serve" => cmd_serve(&args)?,
        "bench" => cmd_bench(&args)?,
        #[cfg(pjrt)]
        "e2e" => cmd_e2e(&args)?,
        #[cfg(not(pjrt))]
        "e2e" => {
            return Err(anyhow!(
                "the e2e pipeline needs the PJRT runtime: vendor the `xla` crate and rebuild with RUSTFLAGS=\"--cfg pjrt\" (see src/runtime/pjrt.rs)"
            ));
        }
        _ => {
            println!(
                "usage: prunemap <fig3|fig5|fig7|fig9|fig10a|fig10b|table1..table7|all|latmodel|map|check|lint|infer|profile|serve|bench|e2e> [--device s10|s20|s21] [--threads N] [--batch N] [--tile N] [--materialized] [--models M1,M2] [--listen ADDR|stdio] [--max-batch N] [--max-wait-ms F] [--max-queue N] [--max-conns N] [--deadline-ms F] [--metrics ADDR] [--trace-out F]"
            );
        }
    }
    Ok(())
}

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
