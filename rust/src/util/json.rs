//! Minimal JSON parser/serializer (offline environment: no serde_json).
//!
//! Supports the full JSON grammar minus exotic number forms; used for
//! `artifacts/manifest.json`, the latency-model store, and experiment
//! reports.  Deliberately simple: recursive descent, `Value` tree, no
//! zero-copy tricks — these files are kilobytes.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn parse(s: &str) -> Result<Value> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Value> {
        match self {
            Value::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (looking up '{key}')"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    /// Unsigned 64-bit integer.  Accepts an integral number within f64's
    /// exactly-representable range, or a decimal string — the encoding
    /// writers should use for values (e.g. RNG seeds) that may exceed
    /// 2^53, since every JSON number passes through f64.
    pub fn as_u64(&self) -> Result<u64> {
        match self {
            Value::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= 9_007_199_254_740_992.0 => {
                Ok(*n as u64)
            }
            Value::Str(s) => s
                .parse()
                .map_err(|_| anyhow!("not a u64 string: '{s}'")),
            _ => bail!("not a u64: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a usize: {n}");
        }
        Ok(n as usize)
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    pub fn str_vec(&self) -> Result<Vec<String>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_str().map(str::to_string))
            .collect()
    }

    // ---- builders --------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr(vals: Vec<Value>) -> Value {
        Value::Arr(vals)
    }

    pub fn num(n: f64) -> Value {
        Value::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    /// Serialize compactly.
    pub fn compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, depth: usize, pretty: bool) {
        let pad = |out: &mut String, d: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..d {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if !n.is_finite() {
                    // JSON has no inf/NaN literal; `null` keeps the
                    // document parseable for downstream readers
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    x.write(out, depth + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, depth);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    x.write(out, depth + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, depth);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.compact())
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, found '{}'", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Value::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let chunk = std::str::from_utf8(&self.b[start..start + len])?;
                        s.push_str(chunk);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Value::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number '{s}': {e}"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-12", "3.5", "\"hi\""] {
            let v = Value::parse(s).unwrap();
            assert_eq!(Value::parse(&v.compact()).unwrap(), v);
        }
    }

    #[test]
    fn parses_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str().unwrap(),
            "c"
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("12 34").is_err());
        assert!(Value::parse("").is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Value::Str("a\"b\\c\nd\té".into());
        let round = Value::parse(&v.compact()).unwrap();
        assert_eq!(round, v);
    }

    #[test]
    fn unicode_escape() {
        let v = Value::parse(r#""é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é");
    }

    #[test]
    fn pretty_parses_back() {
        let v = Value::obj(vec![
            ("x", Value::num(1.0)),
            ("y", Value::arr(vec![Value::str("a"), Value::Bool(true)])),
        ]);
        assert_eq!(Value::parse(&v.pretty()).unwrap(), v);
    }

    #[test]
    fn bool_and_u64_accessors() {
        assert!(Value::parse("true").unwrap().as_bool().unwrap());
        assert!(Value::parse("1").unwrap().as_bool().is_err());
        assert_eq!(Value::parse("12").unwrap().as_u64().unwrap(), 12);
        // strings round-trip the full u64 range, which f64 cannot
        let big = u64::MAX;
        let v = Value::str(big.to_string());
        assert_eq!(Value::parse(&v.compact()).unwrap().as_u64().unwrap(), big);
        assert!(Value::parse("-1").unwrap().as_u64().is_err());
        assert!(Value::parse("1.5").unwrap().as_u64().is_err());
        assert!(Value::parse("\"abc\"").unwrap().as_u64().is_err());
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        // JSON has no inf/NaN literal — a raw `{n}` would emit invalid
        // JSON that no parser (including this one) can read back
        for bad in [f64::INFINITY, f64::NEG_INFINITY, f64::NAN] {
            let v = Value::obj(vec![("speedup", Value::num(bad))]);
            let text = v.compact();
            assert_eq!(text, r#"{"speedup":null}"#);
            assert_eq!(Value::parse(&text).unwrap().get("speedup").unwrap(), &Value::Null);
        }
    }

    #[test]
    fn usize_vec_accessor() {
        let v = Value::parse("[1, 2, 3]").unwrap();
        assert_eq!(v.usize_vec().unwrap(), vec![1, 2, 3]);
        assert!(Value::parse("[1.5]").unwrap().usize_vec().is_err());
    }

    #[test]
    fn real_manifest_parses() {
        // Smoke over the actual artifact manifest if present.
        let p = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(s) = std::fs::read_to_string(p) {
            let v = Value::parse(&s).unwrap();
            assert!(v.get("artifacts").is_ok());
        }
    }
}
