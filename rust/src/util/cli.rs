//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args —
//! enough for the `prunemap` launcher and the examples.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit token stream (first token is NOT the program
    /// name; strip it before calling).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Args {
        let mut out = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer, got '{s}'")),
        }
    }

    pub fn get_f32(&self, name: &str, default: f32) -> Result<f32> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow!("--{name} expects a float, got '{s}'")),
        }
    }

    /// Optional integer override: `Some(n)` only when `--name N` was
    /// given (the bench harness distinguishes "use the definition's
    /// count" from "override it").
    pub fn get_opt_usize(&self, name: &str) -> Result<Option<usize>> {
        self.get(name)
            .map(|s| {
                s.parse()
                    .map_err(|_| anyhow!("--{name} expects an integer, got '{s}'"))
            })
            .transpose()
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer, got '{s}'")),
        }
    }

    /// Engine worker count from `--threads N` (default: one per available
    /// core) — the knob every native-engine entry point shares instead of
    /// hardcoding a thread count.
    pub fn engine_threads(&self) -> Result<usize> {
        let t = self.get_usize("threads", rayon::current_num_threads())?;
        Ok(t.max(1))
    }

    /// Batch size from `--batch N` (clamped to >= 1).
    pub fn batch_size(&self, default: usize) -> Result<usize> {
        let b = self.get_usize("batch", default)?;
        Ok(b.max(1))
    }

    /// Fused-im2col tile width from `--tile N` (GEMM columns per panel;
    /// the engine rounds it up to a multiple of the 8-wide SIMD lane).
    pub fn tile_cols(&self, default: usize) -> Result<usize> {
        let t = self.get_usize("tile", default)?;
        Ok(t.max(1))
    }

    /// `--materialized`: run convs through the materialized-X im2col path
    /// instead of the fused tile-order producer (the bench baseline).
    pub fn materialized(&self) -> bool {
        self.flag("materialized")
    }

    /// Serve-session coalescing cap from `--max-batch N` (requests per
    /// fused run; the session rounds it up to a SIMD-lane multiple).
    pub fn max_batch(&self, default: usize) -> Result<usize> {
        let b = self.get_usize("max-batch", default)?;
        Ok(b.max(1))
    }

    /// Serve-session admission window from `--max-wait-ms F`: how long the
    /// micro-batcher holds an under-full batch open for more requests.
    /// Clamped to [0, 60s] — `Duration::from_secs_f32` panics on values it
    /// cannot represent, and a multi-minute admission window is a typo.
    pub fn max_wait(&self, default_ms: f32) -> Result<std::time::Duration> {
        self.millis("max-wait-ms", Some(default_ms)).map(|d| d.unwrap_or_default())
    }

    /// Per-model queue-depth high-water mark from `--max-queue N`
    /// (clamped to >= 1): submits past it are shed with a typed
    /// `overloaded` rejection instead of queueing without bound.
    pub fn max_queue(&self, default: usize) -> Result<usize> {
        let q = self.get_usize("max-queue", default)?;
        Ok(q.max(1))
    }

    /// Concurrent-connection bound for the TCP front door from
    /// `--max-conns N` (clamped to >= 1): accepts past it are shed with
    /// a single `overloaded` error frame and closed.
    pub fn max_conns(&self, default: usize) -> Result<usize> {
        let c = self.get_usize("max-conns", default)?;
        Ok(c.max(1))
    }

    /// Optional request deadline from `--deadline-ms F` (`None` when the
    /// flag is absent): the serve burst's admission budget per request.
    pub fn deadline_ms(&self) -> Result<Option<std::time::Duration>> {
        self.millis("deadline-ms", None)
    }

    /// A millisecond duration option shared by the serve knobs, clamped to
    /// [0, 60s] like `max_wait` always was.
    fn millis(&self, name: &str, default_ms: Option<f32>) -> Result<Option<std::time::Duration>> {
        let ms = match (self.get(name), default_ms) {
            (None, None) => return Ok(None),
            (None, Some(d)) => d,
            (Some(_), _) => self.get_f32(name, 0.0)?,
        };
        if !ms.is_finite() {
            return Err(anyhow!("--{name} expects a finite value, got '{ms}'"));
        }
        Ok(Some(std::time::Duration::from_secs_f32(ms.clamp(0.0, 60_000.0) / 1e3)))
    }

    /// The model list for the multi-model serve front door: `--models
    /// a,b,c` (comma-separated registry names), falling back to `--model
    /// M`, falling back to `default`.  Always non-empty.
    pub fn models(&self, default: &str) -> Vec<String> {
        let names: Vec<String> = match self.get("models") {
            Some(list) => list
                .split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(str::to_string)
                .collect(),
            None => Vec::new(),
        };
        if names.is_empty() {
            vec![self.get_or("model", default).to_string()]
        } else {
            names
        }
    }

    /// Wire-protocol endpoint from `--listen ADDR` — a TCP bind address
    /// like `127.0.0.1:7077`, or the literal `stdio` to speak frames over
    /// stdin/stdout.  `None` keeps `serve` in in-process burst mode.
    pub fn listen(&self) -> Option<&str> {
        self.get("listen")
    }

    /// Metrics scrape endpoint from `--metrics ADDR` — a TCP bind address
    /// the Prometheus text exposition document is served on.  `None`
    /// leaves metrics reachable only in-band (`metrics` admin frames).
    pub fn metrics_addr(&self) -> Option<&str> {
        self.get("metrics")
    }

    /// Chrome trace output path from `--trace-out FILE`: attach a span
    /// ring and dump it as trace-event JSON on exit.  `None` disables
    /// tracing.
    pub fn trace_out(&self) -> Option<&str> {
        self.get("trace-out")
    }
}

/// Engine worker count for test binaries: `PRUNEMAP_TEST_THREADS` when
/// set (CI runs the tier-1 suite at 1 and 4 to catch pool-lifecycle
/// bugs), else `default`.
pub fn env_threads(default: usize) -> usize {
    std::env::var("PRUNEMAP_TEST_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
        .max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn parses_mixed() {
        // note: `--key value` greedily consumes the next non-dash token,
        // so bare flags go last (documented CLI convention)
        let a = Args::parse(toks("table4 pos2 --device s10 --beta=0.2 --verbose"));
        assert_eq!(a.positional, vec!["table4", "pos2"]);
        assert_eq!(a.get("device"), Some("s10"));
        assert_eq!(a.get("beta"), Some("0.2"));
        assert!(a.flag("verbose"));
    }

    #[test]
    fn trailing_flag() {
        let a = Args::parse(toks("cmd --fast"));
        assert!(a.flag("fast"));
        assert_eq!(a.get("fast"), None);
    }

    #[test]
    fn engine_knobs() {
        let a = Args::parse(toks("--threads 3 --batch 16 --tile 64 --materialized"));
        assert_eq!(a.engine_threads().unwrap(), 3);
        assert_eq!(a.batch_size(1).unwrap(), 16);
        assert_eq!(a.tile_cols(256).unwrap(), 64);
        assert!(a.materialized());
        assert!(!Args::parse(toks("")).materialized());
        assert_eq!(Args::parse(toks("--tile 0")).tile_cols(256).unwrap(), 1);
        let d = Args::parse(toks(""));
        assert!(d.engine_threads().unwrap() >= 1);
        assert_eq!(d.batch_size(4).unwrap(), 4);
        // zero clamps to 1 (a zero-thread engine is meaningless)
        let z = Args::parse(toks("--threads 0 --batch 0"));
        assert_eq!(z.engine_threads().unwrap(), 1);
        assert_eq!(z.batch_size(8).unwrap(), 1);
    }

    #[test]
    fn serve_knobs() {
        let a = Args::parse(toks("--max-batch 48 --max-wait-ms 2.5 --max-queue 64 --max-conns 9"));
        assert_eq!(a.max_batch(32).unwrap(), 48);
        assert_eq!(a.max_wait(1.0).unwrap(), std::time::Duration::from_micros(2500));
        assert_eq!(a.max_queue(1024).unwrap(), 64);
        assert_eq!(a.max_conns(256).unwrap(), 9);
        let d = Args::parse(toks(""));
        assert_eq!(d.max_batch(32).unwrap(), 32);
        assert_eq!(d.max_wait(2.0).unwrap(), std::time::Duration::from_millis(2));
        assert_eq!(d.max_queue(1024).unwrap(), 1024);
        assert_eq!(d.max_conns(256).unwrap(), 256);
        // zero overload bounds clamp to 1 (a zero-capacity server serves nothing)
        let zb = Args::parse(toks("--max-queue 0 --max-conns 0"));
        assert_eq!(zb.max_queue(1024).unwrap(), 1);
        assert_eq!(zb.max_conns(256).unwrap(), 1);
        assert!(Args::parse(toks("--max-queue abc")).max_queue(1024).is_err());
        // zero batch clamps to 1; negative wait clamps to zero
        let z = Args::parse(toks("--max-batch 0 --max-wait-ms -3"));
        assert_eq!(z.max_batch(32).unwrap(), 1);
        assert_eq!(z.max_wait(2.0).unwrap(), std::time::Duration::ZERO);
        // unrepresentable values error or clamp instead of panicking
        assert!(Args::parse(toks("--max-wait-ms inf")).max_wait(2.0).is_err());
        assert_eq!(
            Args::parse(toks("--max-wait-ms 1e30")).max_wait(2.0).unwrap(),
            std::time::Duration::from_secs(60)
        );
    }

    #[test]
    fn front_door_knobs() {
        let a = Args::parse(toks("--models vgg16,,mobilenetv1,proxy --deadline-ms 4"));
        assert_eq!(a.models("x"), vec!["vgg16", "mobilenetv1", "proxy"]);
        assert_eq!(
            a.deadline_ms().unwrap(),
            Some(std::time::Duration::from_millis(4))
        );
        assert_eq!(a.listen(), None);
        let single = Args::parse(toks("--model resnet18 --listen 127.0.0.1:7077"));
        assert_eq!(single.models("x"), vec!["resnet18"]);
        assert_eq!(single.listen(), Some("127.0.0.1:7077"));
        let obs = Args::parse(toks("--metrics 127.0.0.1:9090 --trace-out trace.json"));
        assert_eq!(obs.metrics_addr(), Some("127.0.0.1:9090"));
        assert_eq!(obs.trace_out(), Some("trace.json"));
        assert_eq!(single.metrics_addr(), None);
        assert_eq!(single.trace_out(), None);
        let defaults = Args::parse(toks(""));
        assert_eq!(defaults.models("mobilenetv1"), vec!["mobilenetv1"]);
        assert_eq!(defaults.deadline_ms().unwrap(), None);
        // a degenerate --models list falls back rather than serving nothing
        assert_eq!(Args::parse(toks("--models ,")).models("proxy"), vec!["proxy"]);
        assert!(Args::parse(toks("--deadline-ms nan")).deadline_ms().is_err());
    }

    #[test]
    fn typed_getters() {
        let a = Args::parse(toks("--n 12 --lr 0.5"));
        assert_eq!(a.get_usize("n", 0).unwrap(), 12);
        assert_eq!(a.get_f32("lr", 0.0).unwrap(), 0.5);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        let bad = Args::parse(toks("--n abc"));
        assert!(bad.get_usize("n", 0).is_err());
        assert_eq!(a.get_opt_usize("n").unwrap(), Some(12));
        assert_eq!(a.get_opt_usize("missing").unwrap(), None);
        assert!(bad.get_opt_usize("n").is_err());
    }
}
