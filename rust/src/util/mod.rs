//! In-tree substrates for the offline environment: JSON, CLI parsing,
//! micro-benchmark harness, and property-testing helpers.

pub mod bench;
pub mod cli;
pub mod json;
pub mod lock;
pub mod prop;

pub use lock::recover;
