//! Property-testing helper (proptest is unavailable offline).
//!
//! `for_cases(n, seed, |rng| ...)` runs a closure over `n` independently
//! seeded RNGs and reports the failing seed on panic, so failures are
//! reproducible with `check_case(seed, ...)`.

use crate::rng::Rng;

/// Run `body` for `n` pseudo-random cases; on panic, re-raise annotated
/// with the failing case seed.
pub fn for_cases<F: Fn(&mut Rng) + std::panic::RefUnwindSafe>(n: usize, seed: u64, body: F) {
    for case in 0..n {
        let case_seed = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(case as u64);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(case_seed);
            body(&mut rng);
        });
        if let Err(e) = result {
            eprintln!("property failed at case {case} (seed {case_seed})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Re-run a single failing case by its reported seed.
pub fn check_case<F: FnOnce(&mut Rng)>(case_seed: u64, body: F) {
    let mut rng = Rng::new(case_seed);
    body(&mut rng);
}

/// Random dimension convenience: uniform in [lo, hi].
pub fn dim(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    lo + rng.below(hi - lo + 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let counter = std::sync::atomic::AtomicUsize::new(0);
        for_cases(25, 1, |_rng| {
            counter.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        });
        assert_eq!(counter.load(std::sync::atomic::Ordering::SeqCst), 25);
    }

    #[test]
    fn dim_in_bounds() {
        for_cases(100, 2, |rng| {
            let d = dim(rng, 3, 9);
            assert!((3..=9).contains(&d));
        });
    }

    #[test]
    #[should_panic]
    fn propagates_failure() {
        for_cases(10, 3, |rng| {
            let _ = rng.f32();
            panic!("intentional");
        });
    }
}
