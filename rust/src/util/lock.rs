//! Poisoned-lock recovery.
//!
//! A thread that panics while holding a `std::sync::Mutex`/`RwLock`
//! poisons it; propagating that poison as a panic (`lock().unwrap()`)
//! turns one failed worker into a panic for every subsequent user of
//! the lock.  Everything this crate guards with locks — serving
//! counters and queues, trace ring buffers, executable caches — stays
//! structurally valid across a panic (worst case: one increment lost
//! or one cached entry dropped), so the right policy is to strip the
//! poison and keep going.
//!
//! Convention: production code never writes `lock().unwrap()`.  Call
//! `recover(mutex.lock())` instead.  CI enforces this with a grep gate
//! over `rust/src` (see `.github/workflows/ci.yml`); test code under
//! `rust/tests/` is exempt because a panic there should fail the test.

/// Recover a possibly-poisoned lock guard instead of propagating the
/// poison as a panic.
pub fn recover<G>(result: Result<G, std::sync::PoisonError<G>>) -> G {
    result.unwrap_or_else(std::sync::PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::recover;
    use std::sync::{Arc, Mutex};

    #[test]
    fn recover_strips_poison() {
        let m = Arc::new(Mutex::new(7usize));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(m.is_poisoned());
        assert_eq!(*recover(m.lock()), 7);
    }
}
