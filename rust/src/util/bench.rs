//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` runs each `[[bench]]` target's `main()`; this module gives
//! those targets warmup + repeated timing with median/mean/p95 reporting and
//! a black-box to defeat the optimizer.  Not statistics-grade, but stable
//! enough for the before/after deltas recorded in EXPERIMENTS.md §Perf.

use std::hint::black_box as bb;
use std::time::{Duration, Instant};

use crate::util::json::Value;

/// Re-exported optimizer barrier.
pub fn black_box<T>(x: T) -> T {
    bb(x)
}

/// Result of one timed benchmark.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl BenchStats {
    /// JSON record (`util::json`) so bench output can be tracked across
    /// PRs: `{"name", "iters", "min_ms", "median_ms", "mean_ms",
    /// "p95_ms"}`.
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("name", Value::str(self.name.clone())),
            ("iters", Value::num(self.iters as f64)),
            ("min_ms", Value::num(self.min.as_secs_f64() * 1e3)),
            ("median_ms", Value::num(self.median.as_secs_f64() * 1e3)),
            ("mean_ms", Value::num(self.mean.as_secs_f64() * 1e3)),
            ("p95_ms", Value::num(self.p95.as_secs_f64() * 1e3)),
        ])
    }

    pub fn report(&self) {
        println!(
            "{:<44} {:>10} {:>10} {:>10} {:>10}   ({} iters)",
            self.name,
            fmt_dur(self.min),
            fmt_dur(self.median),
            fmt_dur(self.mean),
            fmt_dur(self.p95),
            self.iters
        );
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.2}s", ns as f64 / 1e9)
    }
}

/// Print the column header once per bench binary.
pub fn header() {
    println!(
        "{:<44} {:>10} {:>10} {:>10} {:>10}",
        "benchmark", "min", "median", "mean", "p95"
    );
    println!("{}", "-".repeat(92));
}

/// Time `f`, auto-calibrating the iteration count to fill ~`budget`.
pub fn bench<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchStats {
    // warmup + calibration
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().max(Duration::from_nanos(50));
    let target_iters = (budget.as_nanos() / once.as_nanos()).clamp(5, 10_000) as usize;

    let mut samples = Vec::with_capacity(target_iters);
    for _ in 0..target_iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    samples.sort_unstable();
    let n = samples.len();
    let mean = samples.iter().sum::<Duration>() / n as u32;
    let stats = BenchStats {
        name: name.to_string(),
        iters: n,
        mean,
        median: samples[n / 2],
        p95: samples[(n * 95 / 100).min(n - 1)],
        min: samples[0],
    };
    stats.report();
    stats
}

/// Fixed-iteration variant for expensive end-to-end benches.
pub fn bench_n<F: FnMut()>(name: &str, iters: usize, mut f: F) -> BenchStats {
    f(); // warmup
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    samples.sort_unstable();
    let n = samples.len();
    let mean = samples.iter().sum::<Duration>() / n as u32;
    let stats = BenchStats {
        name: name.to_string(),
        iters: n,
        mean,
        median: samples[n / 2],
        p95: samples[(n * 95 / 100).min(n - 1)],
        min: samples[0],
    };
    stats.report();
    stats
}

/// Build a baseline-vs-contender comparison record and the median
/// speedup, without printing.  The speedup is `None` (and the JSON field
/// `null`) when either median is degenerate — a zero/sub-resolution
/// timing must not put `inf`/`NaN` into the BENCH record, which
/// downstream JSON parsers reject.
pub fn comparison_record(
    name: &str,
    baseline: &BenchStats,
    contender: &BenchStats,
) -> (Value, Option<f64>) {
    let (b, c) = (baseline.median.as_secs_f64(), contender.median.as_secs_f64());
    let speedup = if b > 0.0 && c > 0.0 { Some(b / c) } else { None };
    let rec = Value::obj(vec![
        ("bench", Value::str(name.to_string())),
        ("baseline", baseline.to_json()),
        ("contender", contender.to_json()),
        ("speedup", speedup.map_or(Value::Null, Value::num)),
    ]);
    (rec, speedup)
}

/// Print one machine-readable `BENCH {json}` comparison line — the record
/// BENCH trajectories grep out of bench logs across PRs — and return the
/// record plus the baseline/contender median speedup (see
/// [`comparison_record`] for the degenerate-timing `None`).
pub fn emit_comparison(
    name: &str,
    baseline: &BenchStats,
    contender: &BenchStats,
) -> (Value, Option<f64>) {
    let (rec, speedup) = comparison_record(name, baseline, contender);
    println!("BENCH {}", rec.compact());
    (rec, speedup)
}

/// Render a speedup for human-facing log lines: "4.00x", or "n/a" when
/// the ratio was degenerate.
pub fn fmt_speedup(speedup: Option<f64>) -> String {
    match speedup {
        Some(s) => format!("{s:.2}x"),
        None => "n/a".to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_ordered_stats() {
        let s = bench_n("noop", 50, || {
            black_box(1 + 1);
        });
        assert!(s.min <= s.median && s.median <= s.p95);
        assert_eq!(s.iters, 50);
    }

    #[test]
    fn json_record_and_speedup() {
        let mk = |name: &str, ms: u64| BenchStats {
            name: name.to_string(),
            iters: 3,
            mean: Duration::from_millis(ms),
            median: Duration::from_millis(ms),
            p95: Duration::from_millis(ms),
            min: Duration::from_millis(ms),
        };
        let base = mk("scalar", 40);
        let cont = mk("simd", 10);
        let (rec, speedup) = emit_comparison("spmm", &base, &cont);
        assert!((speedup.unwrap() - 4.0).abs() < 1e-9);
        assert_eq!(rec.get("bench").unwrap().as_str().unwrap(), "spmm");
        let j = base.to_json();
        assert_eq!(j.get("name").unwrap().as_str().unwrap(), "scalar");
        assert!((j.get("median_ms").unwrap().as_f64().unwrap() - 40.0).abs() < 1e-9);
    }

    #[test]
    fn degenerate_median_yields_null_speedup_and_valid_json() {
        let mk = |name: &str, ms: u64| BenchStats {
            name: name.to_string(),
            iters: 3,
            mean: Duration::from_millis(ms),
            median: Duration::from_millis(ms),
            p95: Duration::from_millis(ms),
            min: Duration::from_millis(ms),
        };
        // a zero-duration contender used to divide-by-~0 into an inf
        // speedup, which serialized as `inf` — not JSON
        let (rec, speedup) = comparison_record("degen", &mk("base", 40), &mk("cont", 0));
        assert_eq!(speedup, None);
        assert_eq!(rec.get("speedup").unwrap(), &Value::Null);
        let text = rec.compact();
        Value::parse(&text).expect("BENCH record must stay parseable JSON");
        assert_eq!(fmt_speedup(speedup), "n/a");
        assert_eq!(fmt_speedup(Some(4.0)), "4.00x");
        // zero baseline is equally degenerate
        let (_, s2) = comparison_record("degen2", &mk("base", 0), &mk("cont", 40));
        assert_eq!(s2, None);
    }

    #[test]
    fn calibration_bounds_iters() {
        let s = bench("tiny", Duration::from_millis(5), || {
            black_box((0..100).sum::<u64>());
        });
        assert!(s.iters >= 5 && s.iters <= 10_000);
    }
}
