//! Reweighted dynamic regularization (paper §4.2, Eq. 1-4).
//!
//! The reweighted group-Lasso method of Candes-Wakin-Boyd applied to the
//! paper's group structures: per-group penalties
//! `alpha_g = 1 / (||W_g||_F^2 + eps)` shrink already-small groups harder
//! and protect large (critical) ones, so the per-layer / per-block
//! compression rate emerges *automatically* — the key advantage over ADMM
//! (Table 1: Reweighted = High accuracy + Auto rate).
//!
//! The Rust side owns the *group structure* (which is exactly the pruning
//! scheme decision), computes alpha broadcast to weight shape, and feeds it
//! to the AOT train-step artifact whose in-graph penalty is
//! `sum(alpha * (w*mask)^2)`.  After training, [`auto_prune`] zeroes groups
//! whose norms the regularizer has driven below threshold.

use crate::pruning::{PruneResult, Scheme};
use crate::tensor::Tensor;

/// Numerical floor in the alpha update.
pub const EPS: f32 = 1e-3;

/// One group of the scheme's structure: member element indices (flat).
/// Visitor-based to avoid materializing index lists for large tensors.
fn for_each_group<F: FnMut(&[usize])>(w: &Tensor, scheme: &Scheme, mut f: F) {
    let s = w.shape().to_vec();
    let mut buf: Vec<usize> = Vec::new();
    match (scheme, w.ndim()) {
        (Scheme::None, _) => {}
        (Scheme::Unstructured, _) => {
            for i in 0..w.len() {
                buf.clear();
                buf.push(i);
                f(&buf);
            }
        }
        (Scheme::StructuredRow, 2) => {
            for r in 0..s[0] {
                buf.clear();
                buf.extend((0..s[1]).map(|c| r * s[1] + c));
                f(&buf);
            }
        }
        (Scheme::StructuredColumn, 2) => {
            for c in 0..s[1] {
                buf.clear();
                buf.extend((0..s[0]).map(|r| r * s[1] + c));
                f(&buf);
            }
        }
        (Scheme::StructuredRow, 4) => {
            let per = s[1] * s[2] * s[3];
            for fi in 0..s[0] {
                buf.clear();
                buf.extend(fi * per..(fi + 1) * per);
                f(&buf);
            }
        }
        (Scheme::StructuredColumn, 4) => {
            let kk = s[2] * s[3];
            for ci in 0..s[1] {
                buf.clear();
                for fi in 0..s[0] {
                    let base = (fi * s[1] + ci) * kk;
                    buf.extend(base..base + kk);
                }
                f(&buf);
            }
        }
        (Scheme::Pattern, 4) => {
            // reweighted granularity for pattern pruning = whole kernels
            // (the connectivity-pruning unit)
            let kk = s[2] * s[3];
            for fi in 0..s[0] {
                for ci in 0..s[1] {
                    let base = (fi * s[1] + ci) * kk;
                    buf.clear();
                    buf.extend(base..base + kk);
                    f(&buf);
                }
            }
        }
        (Scheme::Block { bp, bq }, 2) => {
            let (p, q) = (s[0], s[1]);
            let bp = (*bp).min(p).max(1);
            let bq = (*bq).min(q).max(1);
            for br in 0..p.div_ceil(bp) {
                for bc in 0..q.div_ceil(bq) {
                    let (r0, c0) = (br * bp, bc * bq);
                    let (r1, c1) = ((r0 + bp).min(p), (c0 + bq).min(q));
                    // row groups then column groups inside the block
                    for r in r0..r1 {
                        buf.clear();
                        buf.extend((c0..c1).map(|c| r * q + c));
                        f(&buf);
                    }
                    for c in c0..c1 {
                        buf.clear();
                        buf.extend((r0..r1).map(|r| r * q + c));
                        f(&buf);
                    }
                }
            }
        }
        (Scheme::BlockPunched { bf, bc }, 4) => {
            let (fdim, cdim, kh, kw) = (s[0], s[1], s[2], s[3]);
            let bf = (*bf).min(fdim).max(1);
            let bc = (*bc).min(cdim).max(1);
            for bfi in 0..fdim.div_ceil(bf) {
                for bci in 0..cdim.div_ceil(bc) {
                    let (f0, c0) = (bfi * bf, bci * bc);
                    let (f1, c1) = ((f0 + bf).min(fdim), (c0 + bc).min(cdim));
                    for m in 0..kh {
                        for n in 0..kw {
                            buf.clear();
                            for fi in f0..f1 {
                                for ci in c0..c1 {
                                    buf.push(((fi * cdim + ci) * kh + m) * kw + n);
                                }
                            }
                            f(&buf);
                        }
                    }
                }
            }
        }
        (sch, nd) => panic!("scheme {sch:?} incompatible with {nd}-D weight"),
    }
}

/// Per-group squared Frobenius norms under the scheme's structure.
pub fn group_sq_norms(w: &Tensor, scheme: &Scheme) -> Vec<f32> {
    let mut out = Vec::new();
    let data = w.data();
    for_each_group(w, scheme, |idx| {
        out.push(idx.iter().map(|&i| data[i] * data[i]).sum());
    });
    out
}

/// Reweighted alpha update (Eq. 2-4): alpha_g = 1 / (||W_g||^2 + eps),
/// broadcast to weight shape.  Elements covered by multiple groups
/// (block-based row+col) accumulate both penalties, matching the paper's
/// "solved simultaneously" formulation.
pub fn alphas(w: &Tensor, scheme: &Scheme, eps: f32) -> Tensor {
    let mut alpha = Tensor::zeros(w.shape());
    if matches!(scheme, Scheme::None) {
        return alpha;
    }
    let data = w.data();
    let mut sums: Vec<(Vec<usize>, f32)> = Vec::new();
    for_each_group(w, scheme, |idx| {
        let sq: f32 = idx.iter().map(|&i| data[i] * data[i]).sum();
        sums.push((idx.to_vec(), sq));
    });
    for (idx, sq) in sums {
        let a = 1.0 / (sq + eps);
        for i in idx {
            alpha.data_mut()[i] += a;
        }
    }
    alpha
}

/// The regularization penalty `sum(alpha * w^2)` — must match the in-graph
/// penalty of the AOT train-step (pinned by the integration tests).
pub fn penalty(w: &Tensor, alpha: &Tensor) -> f32 {
    assert_eq!(w.shape(), alpha.shape());
    w.data()
        .iter()
        .zip(alpha.data())
        .map(|(v, a)| a * v * v)
        .sum()
}

/// Automatic pruning after reweighted training: prune every group whose
/// mean-square magnitude fell below `tau` x the layer's mean group stat.
/// The compression rate is *discovered*, not specified — the property the
/// paper claims over ADMM.
pub fn auto_prune(w: &Tensor, scheme: &Scheme, tau: f32) -> PruneResult {
    if matches!(scheme, Scheme::None) {
        return PruneResult { mask: Tensor::ones(w.shape()), kept: w.len(), total: w.len() };
    }
    let data = w.data();
    let mut groups: Vec<(Vec<usize>, f32)> = Vec::new();
    for_each_group(w, scheme, |idx| {
        let mean_sq: f32 =
            idx.iter().map(|&i| data[i] * data[i]).sum::<f32>() / idx.len() as f32;
        groups.push((idx.to_vec(), mean_sq));
    });
    let mean: f32 =
        groups.iter().map(|(_, s)| *s).sum::<f32>() / groups.len().max(1) as f32;
    let thresh = tau * mean;
    let mut mask = Tensor::zeros(w.shape());
    for (idx, stat) in &groups {
        if *stat >= thresh {
            for &i in idx {
                mask.data_mut()[i] = 1.0;
            }
        }
    }
    // block-based: an element survives only if BOTH its row and col group
    // survive; the additive fill above marks it if EITHER does.  Fix by
    // intersecting: re-zero elements whose any covering group died.
    if let Scheme::Block { .. } = scheme {
        let mut dead = vec![false; w.len()];
        for (idx, stat) in &groups {
            if *stat < thresh {
                for &i in idx {
                    dead[i] = true;
                }
            }
        }
        for (i, d) in dead.iter().enumerate() {
            if *d {
                mask.data_mut()[i] = 0.0;
            }
        }
    }
    let kept = mask.nnz();
    PruneResult { mask, kept, total: w.len() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn rand_w(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        Tensor::he_normal(shape, 16, &mut rng)
    }

    #[test]
    fn alpha_inverse_to_group_norm() {
        let mut w = Tensor::zeros(&[4, 4]);
        // row 0 large, row 3 tiny
        for c in 0..4 {
            w.set2(0, c, 10.0);
            w.set2(3, c, 0.01);
        }
        let a = alphas(&w, &Scheme::StructuredRow, EPS);
        assert!(a.at2(3, 0) > a.at2(0, 0) * 100.0);
    }

    #[test]
    fn group_norm_totals_match_frobenius() {
        let w = rand_w(&[8, 8, 3, 3], 1);
        for scheme in [
            Scheme::StructuredRow,
            Scheme::StructuredColumn,
            Scheme::BlockPunched { bf: 4, bc: 4 },
            Scheme::Pattern,
            Scheme::Unstructured,
        ] {
            let total: f32 = group_sq_norms(&w, &scheme).iter().sum();
            assert!(
                (total - w.sq_norm()).abs() < 1e-3,
                "{scheme:?}: {total} vs {}",
                w.sq_norm()
            );
        }
    }

    #[test]
    fn block_groups_cover_each_element_twice() {
        // every element belongs to one row group and one column group
        let w = rand_w(&[16, 16], 2);
        let total: f32 = group_sq_norms(&w, &Scheme::Block { bp: 4, bq: 4 }).iter().sum();
        assert!((total - 2.0 * w.sq_norm()).abs() < 1e-3);
    }

    #[test]
    fn penalty_matches_manual_sum() {
        let w = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let a = Tensor::from_vec(&[2, 2], vec![0.5, 0.5, 1.0, 0.0]);
        assert!((penalty(&w, &a) - (0.5 + 2.0 + 9.0)).abs() < 1e-6);
    }

    #[test]
    fn auto_prune_discovers_planted_sparsity() {
        // plant: half the punched groups near zero
        let mut w = rand_w(&[8, 8, 3, 3], 3);
        let scheme = Scheme::BlockPunched { bf: 4, bc: 4 };
        // zero out positions (m,n) with m+n odd in the first block
        for fi in 0..4 {
            for ci in 0..4 {
                for m in 0..3 {
                    for n in 0..3 {
                        if (m + n) % 2 == 1 {
                            w.set4(fi, ci, m, n, 1e-4);
                        }
                    }
                }
            }
        }
        let r = auto_prune(&w, &scheme, 0.05);
        // the planted near-zero groups must be pruned
        for m in 0..3 {
            for n in 0..3 {
                if (m + n) % 2 == 1 {
                    assert_eq!(r.mask.at4(0, 0, m, n), 0.0, "({m},{n}) not pruned");
                }
            }
        }
        assert!(r.compression() > 1.0);
    }

    #[test]
    fn auto_prune_none_keeps_all() {
        let w = rand_w(&[4, 4], 4);
        let r = auto_prune(&w, &Scheme::None, 0.5);
        assert_eq!(r.kept, r.total);
    }

    #[test]
    fn block_auto_prune_intersects_row_col() {
        let mut w = Tensor::zeros(&[8, 8]);
        for r in 0..8 {
            for c in 0..8 {
                w.set2(r, c, 1.0);
            }
        }
        // kill row 0 of block (0,0)
        for c in 0..4 {
            w.set2(0, c, 1e-5);
        }
        let r = auto_prune(&w, &Scheme::Block { bp: 4, bq: 4 }, 0.1);
        for c in 0..4 {
            assert_eq!(r.mask.at2(0, c), 0.0);
        }
        // other rows of that block survive
        assert_eq!(r.mask.at2(1, 0), 1.0);
    }

    #[test]
    fn reweighted_shrink_simulation_converges_to_sparse() {
        // Simulate the training dynamic: w <- w * (1 - lr*lam*alpha) per
        // step (gradient of alpha*w^2), alpha re-derived each epoch.
        // Groups starting small must collapse; big groups must survive.
        let mut w = Tensor::zeros(&[8, 8]);
        let mut rng = Rng::new(5);
        for r in 0..8 {
            for c in 0..8 {
                let scale = if r < 4 { 1.0 } else { 0.05 };
                w.set2(r, c, rng.normal() * scale);
            }
        }
        let scheme = Scheme::StructuredRow;
        for _epoch in 0..30 {
            let a = alphas(&w, &scheme, EPS);
            for i in 0..w.len() {
                let shrink = 1.0 - (0.05 * a.data()[i]).min(0.9);
                w.data_mut()[i] *= shrink;
            }
        }
        let r = auto_prune(&w, &scheme, 0.1);
        // bottom rows (small init) pruned, top rows kept
        for c in 0..8 {
            assert_eq!(r.mask.at2(7, c), 0.0, "small group survived");
            assert_eq!(r.mask.at2(0, c), 1.0, "large group pruned");
        }
    }
}
