//! Genetic-algorithm auto-tuner (App. A.2).
//!
//! Per layer, searches tile/unroll parameters against the device cost
//! model, "starting parameter search after an initialization with an
//! arbitrary number of chromosomes".  Elitist GA: tournament selection,
//! single-point crossover over the (tile_m, tile_n, unroll) genome,
//! per-gene mutation.

use crate::models::LayerSpec;
use crate::rng::Rng;
use crate::simulator::{layer_latency_ms, DeviceProfile, ExecConfig, TileParams};

/// GA hyperparameters.
#[derive(Debug, Clone, Copy)]
pub struct GaConfig {
    pub population: usize,
    pub generations: usize,
    pub mutation_rate: f32,
    pub elite: usize,
}

impl Default for GaConfig {
    fn default() -> Self {
        GaConfig { population: 24, generations: 12, mutation_rate: 0.25, elite: 2 }
    }
}

const TILE_M: [usize; 4] = [4, 8, 16, 32];
const TILE_N: [usize; 5] = [16, 32, 64, 128, 256];
const UNROLL: [usize; 4] = [1, 2, 4, 8];

fn random_genome(rng: &mut Rng) -> TileParams {
    TileParams {
        tile_m: TILE_M[rng.below(TILE_M.len())],
        tile_n: TILE_N[rng.below(TILE_N.len())],
        unroll: UNROLL[rng.below(UNROLL.len())],
    }
}

fn mutate(t: &mut TileParams, rate: f32, rng: &mut Rng) {
    if rng.bernoulli(rate) {
        t.tile_m = TILE_M[rng.below(TILE_M.len())];
    }
    if rng.bernoulli(rate) {
        t.tile_n = TILE_N[rng.below(TILE_N.len())];
    }
    if rng.bernoulli(rate) {
        t.unroll = UNROLL[rng.below(UNROLL.len())];
    }
}

fn crossover(a: &TileParams, b: &TileParams, rng: &mut Rng) -> TileParams {
    match rng.below(3) {
        0 => TileParams { tile_m: a.tile_m, tile_n: b.tile_n, unroll: b.unroll },
        1 => TileParams { tile_m: a.tile_m, tile_n: a.tile_n, unroll: b.unroll },
        _ => TileParams { tile_m: b.tile_m, tile_n: a.tile_n, unroll: a.unroll },
    }
}

/// Tune one layer's tile parameters; returns (best tile, best latency ms).
pub fn tune_layer(
    layer: &LayerSpec,
    base: &ExecConfig,
    dev: &DeviceProfile,
    cfg: &GaConfig,
    rng: &mut Rng,
) -> (TileParams, f64) {
    let fitness = |t: &TileParams| -> f64 {
        let mut c = base.clone();
        c.tile = *t;
        layer_latency_ms(layer, &c, dev)
    };
    let mut pop: Vec<(TileParams, f64)> = (0..cfg.population)
        .map(|_| {
            let g = random_genome(rng);
            let f = fitness(&g);
            (g, f)
        })
        .collect();
    pop.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());

    for _gen in 0..cfg.generations {
        let mut next: Vec<(TileParams, f64)> = pop.iter().take(cfg.elite).cloned().collect();
        while next.len() < cfg.population {
            // tournament of 3
            let pick = |rng: &mut Rng, pop: &[(TileParams, f64)]| -> TileParams {
                let mut best = pop[rng.below(pop.len())];
                for _ in 0..2 {
                    let c = pop[rng.below(pop.len())];
                    if c.1 < best.1 {
                        best = c;
                    }
                }
                best.0
            };
            let a = pick(rng, &pop);
            let b = pick(rng, &pop);
            let mut child = crossover(&a, &b, rng);
            mutate(&mut child, cfg.mutation_rate, rng);
            let f = fitness(&child);
            next.push((child, f));
        }
        next.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        pop = next;
    }
    pop[0]
}

/// Tune every layer of a model; returns per-layer tiles + total latency.
pub fn tune_model(
    layers: &[LayerSpec],
    bases: &[ExecConfig],
    dev: &DeviceProfile,
    cfg: &GaConfig,
    seed: u64,
) -> (Vec<TileParams>, f64) {
    assert_eq!(layers.len(), bases.len());
    let mut rng = Rng::new(seed);
    let mut tiles = Vec::with_capacity(layers.len());
    let mut total = 0.0;
    for (layer, base) in layers.iter().zip(bases) {
        let (t, lat) = tune_layer(layer, base, dev, cfg, &mut rng);
        tiles.push(t);
        total += lat;
    }
    (tiles, total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::Scheme;

    #[test]
    fn tuned_no_worse_than_default() {
        let dev = DeviceProfile::s10();
        let layer = LayerSpec::conv("c", 3, 128, 128, 28, 1);
        let base = ExecConfig::new(Scheme::BlockPunched { bf: 8, bc: 16 }, 8.0, &dev);
        let default_lat = layer_latency_ms(&layer, &base, &dev);
        let mut rng = Rng::new(1);
        let (tile, tuned_lat) = tune_layer(&layer, &base, &dev, &GaConfig::default(), &mut rng);
        assert!(tuned_lat <= default_lat + 1e-9, "{tuned_lat} > {default_lat}");
        // the tuned tile should at least be lane-aligned
        assert_eq!(tile.tile_n % dev.simd_lanes, 0);
    }

    #[test]
    fn deterministic_for_seed() {
        let dev = DeviceProfile::s10();
        let layer = LayerSpec::conv("c", 1, 256, 256, 14, 1);
        let base = ExecConfig::new(Scheme::BlockPunched { bf: 16, bc: 32 }, 4.0, &dev);
        let ga = GaConfig::default();
        let mut r1 = Rng::new(7);
        let mut r2 = Rng::new(7);
        let a = tune_layer(&layer, &base, &dev, &ga, &mut r1);
        let b = tune_layer(&layer, &base, &dev, &ga, &mut r2);
        assert_eq!(a.0, b.0);
        assert_eq!(a.1, b.1);
    }

    #[test]
    fn tune_model_sums_layers() {
        let dev = DeviceProfile::s10();
        let layers = vec![
            LayerSpec::conv("a", 3, 64, 64, 56, 1),
            LayerSpec::fc("b", 1024, 256),
        ];
        let bases: Vec<ExecConfig> = layers
            .iter()
            .map(|_| ExecConfig::dense(&dev))
            .collect();
        let (tiles, total) = tune_model(&layers, &bases, &dev, &GaConfig::default(), 3);
        assert_eq!(tiles.len(), 2);
        assert!(total > 0.0);
    }
}
