//! Compiler stack (paper §4.3 + Appendix A): computation-graph IR, DSL
//! front-end, layer-fusion pass, GA auto-tuner, and schedule codegen.
//!
//! The pipeline mirrors the paper's: DSL ⇄ graph IR (with layer-wise BCS
//! pruning annotations) → fusion → tuning → a [`Schedule`] of kernel
//! launches that the mobile-SoC simulator "executes".  On the real system
//! codegen would emit OpenCL/C++; here the schedule *is* the executable —
//! the simulator prices exactly what generated code would do (dispatches,
//! tiles, sparse-format index work).

pub mod dsl;
pub mod fusion;
pub mod ir;
pub mod tuning;

pub use fusion::{fuse, FusionPlan};
pub use ir::{Graph, Node, Op, TopoError};
pub use tuning::{tune_layer, tune_model, GaConfig};

use crate::models::LayerSpec;
use crate::pruning::Scheme;
use crate::simulator::{layer_latency_ms, DeviceProfile, ExecConfig, TileParams};

/// One kernel launch in the compiled schedule.
#[derive(Debug, Clone)]
pub struct KernelLaunch {
    pub layer: LayerSpec,
    pub cfg: ExecConfig,
}

/// The compiled model: an ordered list of kernel launches.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub kernels: Vec<KernelLaunch>,
    pub device: String,
}

impl Schedule {
    /// Total latency on the device it was compiled for.
    pub fn latency_ms(&self, dev: &DeviceProfile) -> f64 {
        self.kernels
            .iter()
            .map(|k| layer_latency_ms(&k.layer, &k.cfg, dev))
            .sum()
    }
}

/// Compile a graph: fuse, annotate pruning configs, tune tiles, emit the
/// schedule.  `schemes` maps layer-node order to (scheme, compression);
/// layers without an entry run dense.
pub fn compile(
    graph: &Graph,
    schemes: &[(Scheme, f32)],
    dev: &DeviceProfile,
    tune: Option<&GaConfig>,
    seed: u64,
) -> Schedule {
    let plan = fuse(graph);
    let layer_nodes = graph.layer_nodes();
    let mut kernels = Vec::new();
    let mut rng = crate::rng::Rng::new(seed);
    for (i, node) in layer_nodes.iter().enumerate() {
        let Op::Layer { layer } = &node.op else { unreachable!() };
        let (scheme, compression) = schemes
            .get(i)
            .copied()
            .or(node.scheme.map(|(s, c)| (s, c)))
            .unwrap_or((Scheme::None, 1.0));
        let fused = plan
            .kernel_for_anchor(node.id)
            .map(|k| !k.epilogue.is_empty())
            .unwrap_or(false);
        let mut cfg = ExecConfig::new(scheme, compression, dev);
        cfg.fused = fused;
        if let Some(ga) = tune {
            let (tile, _) = tune_layer(layer, &cfg, dev, ga, &mut rng);
            cfg.tile = tile;
        } else {
            cfg.tile = TileParams::default_for(dev);
        }
        kernels.push(KernelLaunch { layer: layer.clone(), cfg });
    }
    Schedule { kernels, device: dev.name.to_string() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{zoo, Dataset};

    #[test]
    fn compile_dense_and_pruned() {
        let dev = DeviceProfile::s10();
        let m = zoo::proxy_cnn();
        let g = Graph::from_model(&m);
        let dense = compile(&g, &[], &dev, None, 0);
        assert_eq!(dense.kernels.len(), m.layers.len());
        let schemes: Vec<(Scheme, f32)> = m
            .layers
            .iter()
            .map(|_| (Scheme::BlockPunched { bf: 8, bc: 16 }, 8.0))
            .collect();
        let pruned = compile(&g, &schemes, &dev, None, 0);
        assert!(pruned.latency_ms(&dev) < dense.latency_ms(&dev));
    }

    #[test]
    fn tuning_improves_or_matches_schedule() {
        let dev = DeviceProfile::s10();
        let m = zoo::vgg16(Dataset::Cifar10);
        let g = Graph::from_model(&m);
        let schemes: Vec<(Scheme, f32)> = m
            .layers
            .iter()
            .map(|_| (Scheme::BlockPunched { bf: 16, bc: 32 }, 8.0))
            .collect();
        let untuned = compile(&g, &schemes, &dev, None, 1);
        let tuned = compile(&g, &schemes, &dev, Some(&GaConfig::default()), 1);
        assert!(tuned.latency_ms(&dev) <= untuned.latency_ms(&dev) + 1e-9);
    }

    #[test]
    fn fusion_flag_propagates() {
        let dev = DeviceProfile::s10();
        let m = zoo::proxy_cnn();
        let g = Graph::from_model(&m);
        let s = compile(&g, &[], &dev, None, 0);
        // conv kernels fused (bn+relu), fc1 fused (relu), fc2 not
        let fused_count = s.kernels.iter().filter(|k| k.cfg.fused).count();
        assert_eq!(fused_count, 4);
    }
}
