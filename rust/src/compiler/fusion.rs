//! Layer-fusion pass (App. A.1).
//!
//! Fuses elementwise epilogues (BN, ReLU, residual Add) into their producer
//! compute kernel, eliminating intermediate-tensor round-trips and kernel
//! launches.  Candidates are identified conservatively: an elementwise node
//! fuses into its producer iff the producer has exactly one consumer (the
//! paper's "only explore the opportunities specifically provided" + memory
//! cost metric — fusing a multi-consumer producer would recompute).

use std::collections::HashMap;

use super::ir::{Graph, Op};

/// A fused kernel: one anchor compute node + fused epilogue node ids.
#[derive(Debug, Clone)]
pub struct FusedKernel {
    /// The compute node (Layer) or standalone elementwise anchor.
    pub anchor: usize,
    /// Elementwise nodes folded into the anchor's kernel.
    pub epilogue: Vec<usize>,
}

/// Result of the pass.
#[derive(Debug, Clone)]
pub struct FusionPlan {
    pub kernels: Vec<FusedKernel>,
}

impl FusionPlan {
    pub fn kernel_count(&self) -> usize {
        self.kernels.len()
    }

    /// Was the given node fused into some anchor (i.e. not its own kernel)?
    pub fn is_fused_away(&self, node: usize) -> bool {
        self.kernels.iter().any(|k| k.epilogue.contains(&node))
    }

    /// The kernel anchored at a given layer node, if any.
    pub fn kernel_for_anchor(&self, anchor: usize) -> Option<&FusedKernel> {
        self.kernels.iter().find(|k| k.anchor == anchor)
    }
}

/// Run the fusion pass over a graph.
pub fn fuse(graph: &Graph) -> FusionPlan {
    let fanout = graph.fanout();
    // map: node -> anchor it fused into
    let mut fused_into: HashMap<usize, usize> = HashMap::new();
    let mut epilogues: HashMap<usize, Vec<usize>> = HashMap::new();

    for node in &graph.nodes {
        if !node.op.is_elementwise() {
            continue;
        }
        // single-input elementwise chains fuse upward; Add fuses into its
        // first producer when that producer is single-consumer
        let producer = match node.op {
            Op::Add => node.inputs.first().copied(),
            _ => node.inputs.first().copied(),
        };
        let Some(p) = producer else { continue };
        // resolve through already-fused producers to the anchor
        let anchor = *fused_into.get(&p).unwrap_or(&p);
        let anchor_node = &graph.nodes[anchor];
        let anchor_is_compute = matches!(anchor_node.op, Op::Layer { .. });
        let producer_single_consumer = fanout.get(&p).copied().unwrap_or(0) == 1;
        if anchor_is_compute && producer_single_consumer {
            fused_into.insert(node.id, anchor);
            epilogues.entry(anchor).or_default().push(node.id);
        }
    }

    let mut kernels = Vec::new();
    for node in &graph.nodes {
        if matches!(node.op, Op::Input { .. } | Op::Output) {
            continue;
        }
        if fused_into.contains_key(&node.id) {
            continue; // folded into an anchor
        }
        kernels.push(FusedKernel {
            anchor: node.id,
            epilogue: epilogues.remove(&node.id).unwrap_or_default(),
        });
    }
    FusionPlan { kernels }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{zoo, Dataset};
    use crate::compiler::ir::Graph;

    #[test]
    fn conv_bn_relu_fuses_to_one_kernel() {
        let g = Graph::from_model(&zoo::proxy_cnn());
        let plan = fuse(&g);
        // proxy: 3 conv (+bn+relu fused) + fc1 (+relu) + fc2 = 5 kernels
        assert_eq!(plan.kernel_count(), 5, "{:?}", plan.kernels);
        // each conv kernel carries 2 epilogue ops
        let conv_kernels: Vec<_> = plan
            .kernels
            .iter()
            .filter(|k| k.epilogue.len() == 2)
            .collect();
        assert_eq!(conv_kernels.len(), 3);
    }

    #[test]
    fn fusion_reduces_kernel_count_on_vgg() {
        let g = Graph::from_model(&zoo::vgg16(Dataset::Cifar10));
        let plan = fuse(&g);
        assert!(plan.kernel_count() < g.naive_kernel_count() / 2);
        // exactly one kernel per prunable layer
        assert_eq!(plan.kernel_count(), g.layer_nodes().len());
    }

    #[test]
    fn multi_consumer_producer_not_fused() {
        // build: input -> layer -> (relu, relu2) — layer has two consumers
        use crate::compiler::ir::Op;
        use crate::models::LayerSpec;
        let mut g = Graph::default();
        let input = g.add("in", Op::Input { shape: vec![1, 3, 8, 8] }, vec![]);
        let conv = g.add(
            "conv",
            Op::Layer { layer: LayerSpec::conv("conv", 3, 3, 8, 8, 1) },
            vec![input],
        );
        let r1 = g.add("relu1", Op::Relu, vec![conv]);
        let r2 = g.add("relu2", Op::Relu, vec![conv]);
        g.add("out", Op::Output, vec![r1.max(r2)]);
        let plan = fuse(&g);
        // conv cannot absorb either relu: 3 kernels
        assert_eq!(plan.kernel_count(), 3);
        assert!(!plan.is_fused_away(r1));
        assert!(!plan.is_fused_away(r2));
    }
}
