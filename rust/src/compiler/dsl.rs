//! High-level DSL for model definition (App. A.3).
//!
//! A line-oriented language equivalent to the computation graph — "DSL is
//! another type of high-level function used to simulate the data flow of
//! the DNN model, and they can be easily converted to each other":
//!
//! ```text
//! input x 1 3 32 32
//! conv c1 x k=3 in=3 out=16 hw=32 stride=1
//! bn b1 c1
//! relu r1 b1
//! dwconv d1 r1 k=3 ch=16 hw=32 stride=1
//! fc f1 r1 in=1024 out=10
//! add a1 r1 r2
//! pool p1 r1
//! output r1
//! ```
//!
//! `parse` builds a [`Graph`]; `print` emits DSL from a graph; the pair
//! round-trips (tested).

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

use super::ir::{Graph, Node, Op};
use crate::models::{LayerKind, LayerSpec};

fn kv_args(tokens: &[&str]) -> Result<HashMap<String, usize>> {
    let mut out = HashMap::new();
    for t in tokens {
        let (k, v) = t
            .split_once('=')
            .ok_or_else(|| anyhow!("expected key=value, got '{t}'"))?;
        out.insert(k.to_string(), v.parse::<usize>().map_err(|_| anyhow!("bad int '{v}'"))?);
    }
    Ok(out)
}

fn req(map: &HashMap<String, usize>, key: &str, line: &str) -> Result<usize> {
    map.get(key)
        .copied()
        .ok_or_else(|| anyhow!("missing '{key}=' in line: {line}"))
}

/// Parse DSL text into a graph.
pub fn parse(text: &str) -> Result<Graph> {
    let mut g = Graph::default();
    let mut names: HashMap<String, usize> = HashMap::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap().trim();
        if line.is_empty() {
            continue;
        }
        let toks: Vec<&str> = line.split_whitespace().collect();
        let op_kind = toks[0];
        let err = |m: &str| anyhow!("line {}: {m}: {line}", lineno + 1);
        let resolve = |names: &HashMap<String, usize>, n: &str| -> Result<usize> {
            names
                .get(n)
                .copied()
                .ok_or_else(|| anyhow!("line {}: unknown tensor '{n}'", lineno + 1))
        };
        match op_kind {
            "input" => {
                if toks.len() < 3 {
                    return Err(err("input needs a name and dims"));
                }
                let shape: Vec<usize> = toks[2..]
                    .iter()
                    .map(|t| t.parse().map_err(|_| anyhow!("bad dim '{t}'")))
                    .collect::<Result<_>>()?;
                let id = g.add(toks[1], Op::Input { shape }, vec![]);
                names.insert(toks[1].to_string(), id);
            }
            "conv" | "dwconv" => {
                if toks.len() < 3 {
                    return Err(err("conv needs name and input"));
                }
                let input = resolve(&names, toks[2])?;
                let args = kv_args(&toks[3..])?;
                let k = req(&args, "k", line)?;
                let hw = req(&args, "hw", line)?;
                let stride = args.get("stride").copied().unwrap_or(1);
                let layer = if op_kind == "dwconv" {
                    LayerSpec::dwconv(toks[1], k, req(&args, "ch", line)?, hw, stride)
                } else {
                    LayerSpec::conv(
                        toks[1],
                        k,
                        req(&args, "in", line)?,
                        req(&args, "out", line)?,
                        hw,
                        stride,
                    )
                };
                let id = g.add(toks[1], Op::Layer { layer }, vec![input]);
                names.insert(toks[1].to_string(), id);
            }
            "fc" => {
                if toks.len() < 3 {
                    return Err(err("fc needs name and input"));
                }
                let input = resolve(&names, toks[2])?;
                let args = kv_args(&toks[3..])?;
                let layer =
                    LayerSpec::fc(toks[1], req(&args, "in", line)?, req(&args, "out", line)?);
                let id = g.add(toks[1], Op::Layer { layer }, vec![input]);
                names.insert(toks[1].to_string(), id);
            }
            "bn" | "relu" | "pool" => {
                if toks.len() != 3 {
                    return Err(err("unary op needs name and input"));
                }
                let input = resolve(&names, toks[2])?;
                let op = match op_kind {
                    "bn" => Op::BatchNorm,
                    "relu" => Op::Relu,
                    _ => Op::Pool,
                };
                let id = g.add(toks[1], op, vec![input]);
                names.insert(toks[1].to_string(), id);
            }
            "add" => {
                if toks.len() != 4 {
                    return Err(err("add needs name and two inputs"));
                }
                let a = resolve(&names, toks[2])?;
                let b = resolve(&names, toks[3])?;
                let id = g.add(toks[1], Op::Add, vec![a, b]);
                names.insert(toks[1].to_string(), id);
            }
            "output" => {
                if toks.len() != 2 {
                    return Err(err("output needs one input"));
                }
                let input = resolve(&names, toks[1])?;
                g.add("output", Op::Output, vec![input]);
            }
            other => bail!("line {}: unknown op '{other}'", lineno + 1),
        }
    }
    g.topo_check()?;
    Ok(g)
}

/// Emit DSL text from a graph (inverse of [`parse`]).
pub fn print(g: &Graph) -> String {
    let mut out = String::new();
    let name_of = |id: usize| g.nodes[id].name.clone();
    for node in &g.nodes {
        match &node.op {
            Op::Input { shape } => {
                out.push_str(&format!(
                    "input {} {}\n",
                    node.name,
                    shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(" ")
                ));
            }
            Op::Layer { layer } => match layer.kind {
                LayerKind::Fc => out.push_str(&format!(
                    "fc {} {} in={} out={}\n",
                    node.name,
                    name_of(node.inputs[0]),
                    layer.in_ch,
                    layer.out_ch
                )),
                LayerKind::DepthwiseConv => out.push_str(&format!(
                    "dwconv {} {} k={} ch={} hw={} stride={}\n",
                    node.name,
                    name_of(node.inputs[0]),
                    layer.kh,
                    layer.in_ch,
                    layer.in_hw,
                    layer.stride
                )),
                LayerKind::Conv => out.push_str(&format!(
                    "conv {} {} k={} in={} out={} hw={} stride={}\n",
                    node.name,
                    name_of(node.inputs[0]),
                    layer.kh,
                    layer.in_ch,
                    layer.out_ch,
                    layer.in_hw,
                    layer.stride
                )),
            },
            Op::BatchNorm => out.push_str(&format!(
                "bn {} {}\n",
                node.name,
                name_of(node.inputs[0])
            )),
            Op::Relu => out.push_str(&format!(
                "relu {} {}\n",
                node.name,
                name_of(node.inputs[0])
            )),
            Op::Pool => out.push_str(&format!(
                "pool {} {}\n",
                node.name,
                name_of(node.inputs[0])
            )),
            Op::Add => out.push_str(&format!(
                "add {} {} {}\n",
                node.name,
                name_of(node.inputs[0]),
                name_of(node.inputs[1])
            )),
            Op::Output => {
                out.push_str(&format!("output {}\n", name_of(node.inputs[0])));
            }
        }
    }
    out
}

/// Node-level structural equality (op + wiring), for round-trip tests.
pub fn graphs_equal(a: &Graph, b: &Graph) -> bool {
    if a.nodes.len() != b.nodes.len() {
        return false;
    }
    a.nodes.iter().zip(&b.nodes).all(|(x, y): (&Node, &Node)| {
        x.inputs == y.inputs
            && match (&x.op, &y.op) {
                (Op::Input { shape: s1 }, Op::Input { shape: s2 }) => s1 == s2,
                (Op::Layer { layer: l1 }, Op::Layer { layer: l2 }) => {
                    l1.kind == l2.kind
                        && l1.kh == l2.kh
                        && l1.in_ch == l2.in_ch
                        && l1.out_ch == l2.out_ch
                        && l1.in_hw == l2.in_hw
                        && l1.stride == l2.stride
                }
                (o1, o2) => o1 == o2,
            }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    const SAMPLE: &str = r#"
# tiny residual net
input x 1 3 32 32
conv c1 x k=3 in=3 out=16 hw=32 stride=1
bn b1 c1
relu r1 b1
conv c2 r1 k=3 in=16 out=16 hw=32 stride=1
add a1 c2 r1
relu r2 a1
fc f1 r2 in=16384 out=10
output f1
"#;

    #[test]
    fn parses_sample() {
        let g = parse(SAMPLE).unwrap();
        assert_eq!(g.layer_nodes().len(), 3);
        g.topo_check().unwrap();
    }

    #[test]
    fn roundtrip_sample() {
        let g = parse(SAMPLE).unwrap();
        let text = print(&g);
        let g2 = parse(&text).unwrap();
        assert!(graphs_equal(&g, &g2), "\n{text}");
    }

    #[test]
    fn roundtrip_model_graphs() {
        for m in [zoo::proxy_cnn(), zoo::mobilenet_v2(crate::models::Dataset::Cifar10)] {
            let g = Graph::from_model(&m);
            let text = print(&g);
            let g2 = parse(&text).unwrap();
            assert!(graphs_equal(&g, &g2), "{}", m.name);
        }
    }

    #[test]
    fn errors_are_informative() {
        assert!(parse("conv c1 missing k=3").is_err());
        assert!(parse("input x 1 3 32 32\nconv c1 x k=3").is_err()); // missing in/out/hw
        assert!(parse("bogus y z").is_err());
        assert!(parse("input x 1\noutput nope").is_err());
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let g = parse("# hi\n\ninput x 1 3 8 8\noutput x\n").unwrap();
        assert_eq!(g.nodes.len(), 2);
    }
}
