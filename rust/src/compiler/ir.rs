//! Computation-graph IR (App. A.3: "a computational graph of a DNN model
//! can be represented by a directed acyclic graph; each node corresponds to
//! an operator").
//!
//! The IR is what the DSL parses into, what the fusion pass rewrites, and
//! what codegen lowers to a [`Schedule`] of kernel launches for the
//! simulator.  Each compute node carries a layer-wise annotation with the
//! BCS pruning information (scheme + compression), mirroring the paper's
//! "layerwise IR which contains BCS pruning information".

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use crate::models::{LayerSpec, ModelSpec};
use crate::pruning::Scheme;

/// Operator kinds.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// Graph input with NCHW-ish shape metadata.
    Input { shape: Vec<usize> },
    /// Convolution / FC referencing a prunable layer.
    Layer { layer: LayerSpec },
    /// Batch normalization (elementwise at inference).
    BatchNorm,
    /// ReLU (elementwise).
    Relu,
    /// Elementwise residual add (two inputs).
    Add,
    /// 2x2 pooling.
    Pool,
    /// Graph output.
    Output,
}

impl Op {
    /// Elementwise ops are fusion *epilogue* candidates.
    pub fn is_elementwise(&self) -> bool {
        matches!(self, Op::BatchNorm | Op::Relu | Op::Add)
    }
}

/// A node in the DAG.
#[derive(Debug, Clone)]
pub struct Node {
    pub id: usize,
    pub name: String,
    pub op: Op,
    pub inputs: Vec<usize>,
    /// Pruning annotation (None until the mapping method assigns one).
    pub scheme: Option<(Scheme, f32)>,
}

/// The computation graph.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    pub nodes: Vec<Node>,
}

/// Why [`Graph::topo_check`] rejected a graph: the node list is required
/// to be stored in topological order with `id == index`, so both defects
/// are structural corruption, not recoverable states.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TopoError {
    /// A node consumes a node at or after its own position (cycle,
    /// self-loop, or dangling input id).
    ForwardDependency { node: usize, name: String, input: usize },
    /// `nodes[index].id != index`: the id space is inconsistent.
    IdMismatch { index: usize, id: usize },
}

impl std::fmt::Display for TopoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TopoError::ForwardDependency { node, name, input } => {
                write!(f, "node {node} ('{name}') depends on later node {input}")
            }
            TopoError::IdMismatch { index, id } => {
                write!(f, "node at position {index} carries id {id}")
            }
        }
    }
}

impl std::error::Error for TopoError {}

impl Graph {
    pub fn add(&mut self, name: &str, op: Op, inputs: Vec<usize>) -> usize {
        let id = self.nodes.len();
        self.nodes.push(Node { id, name: name.to_string(), op, inputs, scheme: None });
        id
    }

    /// Build the canonical inference graph for a model spec: each conv is
    /// followed by BN + ReLU; FCs by ReLU (except the last).
    pub fn from_model(model: &ModelSpec) -> Graph {
        let mut g = Graph::default();
        let input_shape = vec![
            1,
            model.layers.first().map(|l| l.in_ch).unwrap_or(3),
            model.layers.first().map(|l| l.in_hw).unwrap_or(32),
            model.layers.first().map(|l| l.in_hw).unwrap_or(32),
        ];
        let mut prev = g.add("input", Op::Input { shape: input_shape }, vec![]);
        let n = model.layers.len();
        for (i, layer) in model.layers.iter().enumerate() {
            let lid = g.add(&layer.name, Op::Layer { layer: layer.clone() }, vec![prev]);
            let is_conv = layer.kind != crate::models::LayerKind::Fc;
            prev = lid;
            if is_conv {
                let bn = g.add(&format!("{}_bn", layer.name), Op::BatchNorm, vec![prev]);
                let relu = g.add(&format!("{}_relu", layer.name), Op::Relu, vec![bn]);
                prev = relu;
            } else if i + 1 < n {
                let relu = g.add(&format!("{}_relu", layer.name), Op::Relu, vec![prev]);
                prev = relu;
            }
        }
        g.add("output", Op::Output, vec![prev]);
        g
    }

    /// Number of compute-kernel launches if executed naively (one kernel
    /// per non-input/output node).
    pub fn naive_kernel_count(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| !matches!(n.op, Op::Input { .. } | Op::Output))
            .count()
    }

    /// Topological order (the graph is built in topo order; verify).
    /// Mandatory on every lowering path — [`crate::runtime::graph`]'s
    /// `CompiledNet::lower`/`compile` call it before trusting the node
    /// ids — and typed so callers can match on the exact defect instead
    /// of parsing a message.
    pub fn topo_check(&self) -> std::result::Result<(), TopoError> {
        for (idx, n) in self.nodes.iter().enumerate() {
            if n.id != idx {
                return Err(TopoError::IdMismatch { index: idx, id: n.id });
            }
            for &i in &n.inputs {
                // covers self-loops and dangling ids too: any input >= id
                // is either a forward edge or out of range
                if i >= n.id {
                    return Err(TopoError::ForwardDependency {
                        node: n.id,
                        name: n.name.clone(),
                        input: i,
                    });
                }
            }
        }
        Ok(())
    }

    /// Consumers count per node.
    pub fn fanout(&self) -> HashMap<usize, usize> {
        let mut out: HashMap<usize, usize> = HashMap::new();
        for n in &self.nodes {
            for &i in &n.inputs {
                *out.entry(i).or_default() += 1;
            }
        }
        out
    }

    /// Assign a pruning annotation to the layer node with the given name.
    pub fn annotate(&mut self, layer_name: &str, scheme: Scheme, compression: f32) -> Result<()> {
        let node = self
            .nodes
            .iter_mut()
            .find(|n| n.name == layer_name && matches!(n.op, Op::Layer { .. }))
            .ok_or_else(|| anyhow!("no layer node named '{layer_name}'"))?;
        node.scheme = Some((scheme, compression));
        Ok(())
    }

    /// All layer nodes in order.
    pub fn layer_nodes(&self) -> Vec<&Node> {
        self.nodes
            .iter()
            .filter(|n| matches!(n.op, Op::Layer { .. }))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{zoo, Dataset};

    #[test]
    fn from_model_structure() {
        let m = zoo::proxy_cnn();
        let g = Graph::from_model(&m);
        g.topo_check().unwrap();
        assert_eq!(g.layer_nodes().len(), m.layers.len());
        // conv layers get bn+relu, fc1 gets relu, fc2 (last) bare
        // nodes: input + 3*(conv+bn+relu) + (fc+relu) + fc + output
        assert_eq!(g.nodes.len(), 1 + 9 + 2 + 1 + 1);
    }

    #[test]
    fn annotate_layers() {
        let m = zoo::proxy_cnn();
        let mut g = Graph::from_model(&m);
        g.annotate("conv1", Scheme::BlockPunched { bf: 4, bc: 4 }, 4.0).unwrap();
        assert!(g.annotate("missing", Scheme::Unstructured, 2.0).is_err());
        let node = g.layer_nodes()[0];
        assert!(node.scheme.is_some());
    }

    #[test]
    fn topo_check_is_typed() {
        let mut g = Graph::from_model(&zoo::proxy_cnn());
        // forward edge: first layer node made to consume the output node
        let last = g.nodes.len() - 1;
        g.nodes[1].inputs = vec![last];
        assert_eq!(
            g.topo_check(),
            Err(TopoError::ForwardDependency {
                node: 1,
                name: g.nodes[1].name.clone(),
                input: last,
            })
        );
        let mut g = Graph::from_model(&zoo::proxy_cnn());
        g.nodes[2].id = 7;
        assert_eq!(g.topo_check(), Err(TopoError::IdMismatch { index: 2, id: 7 }));
        // the error is a real std::error::Error with a stable message
        let e: Box<dyn std::error::Error> =
            Box::new(TopoError::IdMismatch { index: 2, id: 7 });
        assert_eq!(e.to_string(), "node at position 2 carries id 7");
    }

    #[test]
    fn kernel_count_counts_compute_nodes() {
        let g = Graph::from_model(&zoo::vgg16(Dataset::Cifar10));
        assert!(g.naive_kernel_count() > 13 * 3);
        g.topo_check().unwrap();
    }
}
