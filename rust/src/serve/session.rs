//! [`Session`]: request admission + dynamic micro-batching over a
//! [`PreparedModel`].
//!
//! A session owns everything mutable about serving one model: the
//! [`GraphExecutor`]s (whose engines share one persistent rayon pool), a
//! per-worker [`Arena`] that makes steady-state runs allocation-free, and
//! the request queue.  Callers [`Session::submit`] one sample at a time
//! and get a [`Ticket`]; batcher workers coalesce whatever is queued into
//! a **lane-aligned** batch (a multiple of the engine's
//! [`LANE`](crate::sparse::LANE), padded with zero samples, never more
//! than the max-batch cap), hold an under-full batch open for at most the
//! max-wait window, run the network once, and scatter each request's
//! output back through its ticket.  Per-request outputs are bit-identical
//! to a solo run — the engine accumulates every output element in the
//! same order at any batch width, and padding lanes are never read back.
//!
//! Admission is priority- and deadline-aware ([`Session::submit_with`]):
//! the queue is two lanes, and every batch assembly drains the
//! [`Priority::High`] lane before the [`Priority::Normal`] lane, so under
//! saturation high-priority requests ride the earlier runs.  A request
//! whose deadline has passed when its batch is assembled is rejected with
//! [`ServeError::DeadlineExpired`] instead of silently served late; it
//! never occupies a batch slot.
//!
//! Queueing is **bounded**: once the queue holds
//! [`SessionBuilder::max_queue`] requests, further submits are shed with
//! [`ServeError::Overloaded`] carrying a drain-time `retry_after_ms`
//! estimate — overload is a typed, observable condition
//! ([`SessionStats::shed_overload`]), never unbounded memory growth.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::runtime::graph::StepTiming;
use crate::runtime::{Arena, GraphExecutor};
use crate::sparse::{align_to_lane, DEFAULT_TILE_COLS};
use crate::telemetry::trace::{self, TraceRing};
use crate::telemetry::Span;
use crate::util::json::Value;

use super::{recover, PreparedModel, Priority, ServeError};

/// What a batcher worker sends back per request (typed errors so one
/// failed run can fan out to every rider of the batch, and admission
/// rejections stay distinguishable from executor faults).
type Served = std::result::Result<Outcome, ServeError>;

/// One served request's output plus its admission trace — what
/// [`Ticket::wait_detail`] returns when the caller wants to observe *how*
/// a request was served, not just its logits.
#[derive(Debug, Clone, PartialEq)]
pub struct Outcome {
    /// The request's `[out_features]` output.
    pub output: Vec<f32>,
    /// 1-based sequence number of the executor run that served it
    /// (assigned under the stats lock, so with one batcher worker it is
    /// exactly the execution order).
    pub run: u64,
    /// Real requests coalesced into that run.
    pub coalesced: usize,
    /// Queue wait from submit to batch assembly (what the wait-time
    /// buckets aggregate).
    pub waited: Duration,
}

/// A pending request: one sample, its reply channel, and its admission
/// metadata.
struct Request {
    input: Vec<f32>,
    tx: mpsc::Sender<Served>,
    priority: Priority,
    deadline: Option<Instant>,
    submitted: Instant,
}

/// Upper bounds (exclusive, µs) of the first [`SessionStats::wait_buckets`]
/// entries; the last bucket is the overflow.
pub const WAIT_BUCKET_BOUNDS_US: [u64; 4] = [100, 1_000, 10_000, 100_000];

/// Human labels for the wait-time buckets, index-aligned with
/// [`SessionStats::wait_buckets`].
pub fn wait_bucket_labels() -> [&'static str; 5] {
    ["<100µs", "<1ms", "<10ms", "<100ms", "≥100ms"]
}

fn wait_bucket(wait: Duration) -> usize {
    let us = wait.as_micros().min(u128::from(u64::MAX)) as u64;
    WAIT_BUCKET_BOUNDS_US
        .iter()
        .position(|&bound| us < bound)
        .unwrap_or(WAIT_BUCKET_BOUNDS_US.len())
}

/// Admission counters, observable via [`Session::stats`] (and per model
/// via [`Server::stats`](super::Server::stats)).  The batch-runs histogram
/// keys are *executed* batch widths (real requests + padding lanes), so
/// lane alignment and the max-batch cap are directly testable; the
/// occupancy histogram keys are *real* requests per run, so coalescing
/// quality is too.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SessionStats {
    /// Requests served (not counting padding lanes).
    pub requests: usize,
    /// Executor runs dispatched.
    pub runs: usize,
    /// Zero-sample lanes added to align batches to the SIMD lane width.
    pub padded_lanes: usize,
    /// Largest number of real requests coalesced into one run.
    pub max_coalesced: usize,
    /// Executed batch width -> number of runs at that width.
    pub batch_runs: BTreeMap<usize, usize>,
    /// Real requests per run -> number of runs at that occupancy.
    pub batch_occupancy: BTreeMap<usize, usize>,
    /// Most requests ever queued at once (sampled at submit time).
    pub queue_depth_hwm: usize,
    /// Served requests by queue wait (submit -> batch assembly), bucketed
    /// by [`WAIT_BUCKET_BOUNDS_US`] with a final overflow bucket.
    pub wait_buckets: [usize; 5],
    /// Total queue wait across all served requests, microseconds — with
    /// the bucket counts this gives exporters a histogram `_sum`.
    pub wait_total_us: u64,
    /// Served requests per priority lane, indexed by `Priority::lane()`
    /// (0 = high, 1 = normal).
    pub served_by_priority: [usize; 2],
    /// Requests rejected because their deadline passed before assembly.
    pub expired: usize,
    /// Requests shed at submit because the queue was at its
    /// `max_queue` high-water mark (they were never queued).
    pub shed_overload: usize,
}

impl SessionStats {
    /// The counters as a JSON object — what the wire protocol's `stats`
    /// admin frame returns per model.  Histogram maps keep their integer
    /// keys as object keys; `served_by_priority` is keyed by lane name.
    pub fn to_json(&self) -> Value {
        let hist = |m: &BTreeMap<usize, usize>| {
            Value::Obj(m.iter().map(|(k, v)| (k.to_string(), Value::num(*v as f64))).collect())
        };
        let buckets = self.wait_buckets.iter().map(|&n| Value::num(n as f64)).collect();
        Value::obj(vec![
            ("requests", Value::num(self.requests as f64)),
            ("runs", Value::num(self.runs as f64)),
            ("padded_lanes", Value::num(self.padded_lanes as f64)),
            ("max_coalesced", Value::num(self.max_coalesced as f64)),
            ("batch_runs", hist(&self.batch_runs)),
            ("batch_occupancy", hist(&self.batch_occupancy)),
            ("queue_depth_hwm", Value::num(self.queue_depth_hwm as f64)),
            ("wait_buckets", Value::arr(buckets)),
            ("wait_total_us", Value::num(self.wait_total_us as f64)),
            (
                "served_by_priority",
                Value::obj(vec![
                    ("high", Value::num(self.served_by_priority[0] as f64)),
                    ("normal", Value::num(self.served_by_priority[1] as f64)),
                ]),
            ),
            ("expired", Value::num(self.expired as f64)),
            ("shed_overload", Value::num(self.shed_overload as f64)),
        ])
    }
}

/// The two admission lanes; index by [`Priority::lane`] (high first).
struct Queues {
    lanes: [VecDeque<Request>; 2],
}

impl Queues {
    fn len(&self) -> usize {
        self.lanes.iter().map(VecDeque::len).sum()
    }

    fn is_empty(&self) -> bool {
        self.lanes.iter().all(VecDeque::is_empty)
    }

    /// The earliest deadline among queued requests, if any carries one —
    /// what caps the batcher's hold-open window so coalescing never turns
    /// a servable request into a deadline rejection.
    fn earliest_deadline(&self) -> Option<Instant> {
        self.lanes.iter().flatten().filter_map(|r| r.deadline).min()
    }
}

/// Pull up to `max_batch` live requests out of the lanes — high lane
/// first, FIFO within a lane — dropping every already-expired request
/// encountered on the way (returned with how late it was, so the caller
/// can reject it without it ever occupying a batch slot).
fn assemble(
    lanes: &mut Queues,
    max_batch: usize,
    now: Instant,
) -> (Vec<Request>, Vec<(Request, Duration)>) {
    let mut batch = Vec::new();
    let mut expired = Vec::new();
    for lane in lanes.lanes.iter_mut() {
        while batch.len() < max_batch {
            let Some(r) = lane.pop_front() else { break };
            match r.deadline {
                Some(d) if now >= d => expired.push((r, now - d)),
                _ => batch.push(r),
            }
        }
    }
    (batch, expired)
}

struct Shared {
    queue: Mutex<Queues>,
    cv: Condvar,
    closed: AtomicBool,
    stats: Mutex<SessionStats>,
    max_batch: usize,
    max_wait: Duration,
    max_queue: usize,
    sample_len: usize,
    out_len: usize,
    trace: Option<Arc<TraceRing>>,
}

/// A handle to one submitted request; [`Ticket::wait`] blocks until its
/// batch has run (or its admission was rejected).
pub struct Ticket {
    rx: mpsc::Receiver<Served>,
}

impl Ticket {
    /// Block for this request's output (`[out_features]` for the sample).
    pub fn wait(self) -> Result<Vec<f32>, ServeError> {
        self.wait_detail().map(|outcome| outcome.output)
    }

    /// Block for the full [`Outcome`]: the output plus which run served
    /// the request, how many riders it shared the batch with, and how
    /// long it queued.
    pub fn wait_detail(self) -> Result<Outcome, ServeError> {
        match self.rx.recv() {
            Ok(served) => served,
            Err(_) => Err(ServeError::Closed),
        }
    }
}

/// Configuration for a [`Session`]; see the field setters.  Build with
/// [`Session::builder`] or [`PreparedModel::session`].
pub struct SessionBuilder {
    prepared: PreparedModel,
    threads: usize,
    tile_cols: usize,
    fused: bool,
    max_batch: usize,
    max_wait: Duration,
    max_queue: usize,
    workers: usize,
    trace: Option<Arc<TraceRing>>,
}

/// Default queue-depth high-water mark ([`SessionBuilder::max_queue`]):
/// deep enough that a well-provisioned session never sheds, small enough
/// that a runaway pipeliner cannot grow the queue without limit.
pub const DEFAULT_MAX_QUEUE: usize = 1024;

impl SessionBuilder {
    fn new(prepared: PreparedModel) -> SessionBuilder {
        SessionBuilder {
            prepared,
            threads: rayon::current_num_threads(),
            tile_cols: DEFAULT_TILE_COLS,
            fused: true,
            max_batch: 32,
            max_wait: Duration::from_millis(2),
            max_queue: DEFAULT_MAX_QUEUE,
            workers: 1,
            trace: None,
        }
    }

    /// Engine worker threads per executor run (the persistent pool is
    /// built once and shared by every run).  Default: one per core.
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Fused-im2col tile width (GEMM columns per panel).
    pub fn tile_cols(mut self, tile: usize) -> Self {
        self.tile_cols = tile.max(1);
        self
    }

    /// `false` routes convs through the materialized-X im2col baseline
    /// instead of the fused tile-order producer.  Default fused.
    pub fn fused(mut self, fused: bool) -> Self {
        self.fused = fused;
        self
    }

    /// Most requests one run may serve.  Rounded **up** to a lane multiple
    /// (minimum one lane block) so coalesced batches always align; the
    /// effective value is [`Session::max_batch`].  Default 32.
    pub fn max_batch(mut self, max_batch: usize) -> Self {
        self.max_batch = max_batch.max(1);
        self
    }

    /// How long the micro-batcher holds an under-full batch open for more
    /// requests — the tail-latency bound.  `Duration::ZERO` dispatches
    /// whatever is queued immediately.  Default 2ms.
    pub fn max_wait(mut self, max_wait: Duration) -> Self {
        self.max_wait = max_wait;
        self
    }

    /// Queue-depth high-water mark: a submit arriving while `max_queue`
    /// requests are already queued is shed with
    /// [`ServeError::Overloaded`] instead of queued — the bound that
    /// keeps overload a typed condition rather than unbounded memory
    /// growth.  Clamped to >= 1.  Default [`DEFAULT_MAX_QUEUE`].
    pub fn max_queue(mut self, max_queue: usize) -> Self {
        self.max_queue = max_queue.max(1);
        self
    }

    /// Batcher worker threads, each owning a persistent [`Arena`] (warm
    /// runs allocate nothing) and draining the shared queue.  Default 1.
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    /// Attach a shared [`TraceRing`]: the batcher workers record
    /// per-request queue-wait and batch-assembly spans into it, and the
    /// executors record run/step/op spans.  Default: no tracing.
    pub fn trace(mut self, ring: Arc<TraceRing>) -> Self {
        self.trace = Some(ring);
        self
    }

    /// Spawn the batcher workers and open the session for requests.
    pub fn build(self) -> Session {
        let exec = {
            let e = GraphExecutor::new(self.threads).with_tile_cols(self.tile_cols);
            let e = if self.fused { e } else { e.materialized() };
            match &self.trace {
                Some(ring) => e.with_trace(Arc::clone(ring)),
                None => e,
            }
        };
        let shared = Arc::new(Shared {
            queue: Mutex::new(Queues { lanes: [VecDeque::new(), VecDeque::new()] }),
            cv: Condvar::new(),
            closed: AtomicBool::new(false),
            stats: Mutex::new(SessionStats::default()),
            max_batch: align_to_lane(self.max_batch),
            max_wait: self.max_wait,
            max_queue: self.max_queue,
            sample_len: self.prepared.input_len(),
            out_len: self.prepared.output_len(),
            trace: self.trace,
        });
        let workers = (0..self.workers)
            .map(|i| {
                let exec = exec.clone();
                let prepared = self.prepared.clone();
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("prunemap-serve-{i}"))
                    .spawn(move || worker_loop(&exec, &prepared, &shared))
                    .expect("spawn serve worker")
            })
            .collect();
        Session { prepared: self.prepared, exec, shared, workers }
    }
}

/// A live serving endpoint over one [`PreparedModel`]; see the
/// [module docs](self).  Dropping the session serves every queued request,
/// then joins the workers.
pub struct Session {
    prepared: PreparedModel,
    exec: GraphExecutor,
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl Session {
    /// Start configuring a session over `prepared`.
    pub fn builder(prepared: PreparedModel) -> SessionBuilder {
        SessionBuilder::new(prepared)
    }

    /// The sealed artifact this session serves.
    pub fn prepared(&self) -> &PreparedModel {
        &self.prepared
    }

    /// Effective coalescing cap: the builder's `max_batch` rounded up to a
    /// lane multiple.  No executed batch ever exceeds this.
    pub fn max_batch(&self) -> usize {
        self.shared.max_batch
    }

    /// The micro-batcher's admission window.
    pub fn max_wait(&self) -> Duration {
        self.shared.max_wait
    }

    /// The queue-depth high-water mark; a submit past this is shed with
    /// [`ServeError::Overloaded`].
    pub fn max_queue(&self) -> usize {
        self.shared.max_queue
    }

    /// Engine worker threads per executor run.
    pub fn threads(&self) -> usize {
        self.exec.threads()
    }

    /// Whether convs run the fused tile-order im2col path.
    pub fn is_fused(&self) -> bool {
        self.exec.is_fused()
    }

    /// Batcher worker count.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// A snapshot of the admission counters.
    pub fn stats(&self) -> SessionStats {
        recover(self.shared.stats.lock()).clone()
    }

    /// The span ring this session records into, if one was attached.
    pub fn trace_ring(&self) -> Option<&Arc<TraceRing>> {
        self.shared.trace.as_ref()
    }

    /// Enqueue one sample (NCHW-flattened `[C*H*W]`) on the normal lane
    /// with no deadline and return a [`Ticket`] for its output.
    /// Concurrent submissions coalesce into lane-aligned batches; the call
    /// itself never blocks on execution.
    pub fn submit(&self, input: Vec<f32>) -> Result<Ticket, ServeError> {
        self.submit_with(input, Priority::Normal, None)
    }

    /// [`Session::submit`] with explicit admission metadata: the priority
    /// lane, and an optional deadline relative to now.  A request whose
    /// deadline passes before its batch is assembled is rejected with
    /// [`ServeError::DeadlineExpired`] through its ticket — it is never
    /// executed late.  A submit arriving while the queue already holds
    /// `max_queue` requests is shed immediately with
    /// [`ServeError::Overloaded`] — it never consumes queue memory.
    pub fn submit_with(
        &self,
        input: Vec<f32>,
        priority: Priority,
        deadline: Option<Duration>,
    ) -> Result<Ticket, ServeError> {
        if input.len() != self.shared.sample_len {
            return Err(ServeError::BadInput {
                expected: self.shared.sample_len,
                got: input.len(),
            });
        }
        let now = Instant::now();
        let (tx, rx) = mpsc::channel();
        let req = Request {
            input,
            tx,
            priority,
            // a budget too large for Instant arithmetic saturates to "no
            // deadline" instead of panicking mid-submit
            deadline: deadline.and_then(|d| now.checked_add(d)),
            submitted: now,
        };
        let depth = {
            let mut q = recover(self.shared.queue.lock());
            if q.len() >= self.shared.max_queue {
                // shed under the queue lock so the HWM check and the
                // admit race cannot interleave past the bound
                let retry_after_ms = self.retry_after_ms(q.len());
                drop(q);
                let mut st = recover(self.shared.stats.lock());
                st.shed_overload += 1;
                return Err(ServeError::Overloaded { retry_after_ms });
            }
            q.lanes[priority.lane()].push_back(req);
            q.len()
        };
        self.shared.cv.notify_all();
        {
            let mut st = recover(self.shared.stats.lock());
            st.queue_depth_hwm = st.queue_depth_hwm.max(depth);
        }
        Ok(Ticket { rx })
    }

    /// Drain-time estimate for a shed request: the backlog in batches
    /// times the admission window (the floor of how long each batch is
    /// held open), never reported as zero — "retry immediately" would
    /// invite the very stampede the shed exists to stop.
    fn retry_after_ms(&self, depth: usize) -> u64 {
        let batches = depth.div_ceil(self.shared.max_batch).max(1) as u64;
        let window_ms = (self.shared.max_wait.as_millis() as u64).max(1);
        window_ms.saturating_mul(batches)
    }

    /// Blocking convenience: [`Session::submit`] + [`Ticket::wait`].
    pub fn infer(&self, input: Vec<f32>) -> Result<Vec<f32>, ServeError> {
        self.submit(input)?.wait()
    }

    /// Diagnostic direct run (bypasses the micro-batcher): one warmed
    /// batched inference with per-step timings, as `prunemap infer`
    /// reports.  `input` is `[batch, C, H, W]` row-major.
    pub fn run_timed(
        &self,
        input: &[f32],
        batch: usize,
    ) -> anyhow::Result<(Vec<f32>, Vec<StepTiming>)> {
        let mut arena = Arena::new();
        let _warmup = self.exec.run_with_arena(self.prepared.net(), input, batch, &mut arena)?;
        self.exec.run_timed_with_arena(self.prepared.net(), input, batch, &mut arena)
    }
}

impl Drop for Session {
    fn drop(&mut self) {
        {
            // flip `closed` and notify while holding the queue mutex:
            // a worker between its `closed` check and `cv.wait` still
            // holds the lock, so the store+notify cannot slip into that
            // window and strand it (the classic lost wakeup)
            let _queue = recover(self.shared.queue.lock());
            self.shared.closed.store(true, Ordering::Release);
            self.shared.cv.notify_all();
        }
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }
}

/// One batcher worker: wait for requests, coalesce up to `max_batch`
/// within `max_wait` (high lane first), reject expired requests, pad the
/// batch to a lane multiple, run once, scatter.  On close the queue is
/// drained — pending tickets are served (or deadline-rejected), not
/// dropped.
fn worker_loop(exec: &GraphExecutor, prepared: &PreparedModel, shared: &Shared) {
    let net = prepared.net();
    let sample = shared.sample_len;
    let out_len = shared.out_len;
    let mut arena = Arena::new();
    let mut input: Vec<f32> = Vec::new();
    loop {
        let mut q = recover(shared.queue.lock());
        // phase 1: block until there is at least one request (or shutdown
        // with an empty queue)
        loop {
            if !q.is_empty() {
                break;
            }
            if shared.closed.load(Ordering::Acquire) {
                return;
            }
            q = recover(shared.cv.wait(q));
        }
        // phase 2: hold the batch open for up to `max_wait` hoping to fill
        // it to `max_batch` (skipped when closing: drain immediately).  If
        // any queued request's deadline falls inside the hold window,
        // dispatch immediately instead — a lone request whose budget is
        // shorter than `max_wait` must be served right away on an idle
        // server, not held open until its deadline has passed.
        let hold_start = shared.trace.as_ref().map(|_| trace::now_ns());
        let hold_until = Instant::now() + shared.max_wait;
        while q.len() < shared.max_batch && !shared.closed.load(Ordering::Acquire) {
            let now = Instant::now();
            if now >= hold_until || q.earliest_deadline().is_some_and(|d| d <= hold_until) {
                break;
            }
            let (guard, timeout) = recover(shared.cv.wait_timeout(q, hold_until - now));
            q = guard;
            if timeout.timed_out() {
                break;
            }
        }
        let assembled_at = Instant::now();
        let (reqs, rejected) = assemble(&mut q, shared.max_batch, assembled_at);
        drop(q);
        if !rejected.is_empty() {
            let mut st = recover(shared.stats.lock());
            st.expired += rejected.len();
        }
        for (r, missed_by) in rejected {
            let _ = r.tx.send(Err(ServeError::DeadlineExpired { missed_by }));
        }
        if reqs.is_empty() {
            // another worker drained the queue while we held the batch
            // open (or everything queued had expired); go back to waiting
            continue;
        }
        if let Some(ring) = shared.trace.as_deref() {
            for r in &reqs {
                let waited = assembled_at.saturating_duration_since(r.submitted);
                // queue waits overlap arbitrarily, so they live on a
                // synthetic track (tid 0) as async events in the export
                ring.record(
                    Span::new(
                        "queue_wait",
                        trace::CAT_QUEUE,
                        trace::ns_since_epoch(r.submitted),
                        waited.as_nanos().min(u128::from(u64::MAX)) as u64,
                    )
                    .tid(0),
                );
            }
            if let Some(t) = hold_start {
                let name = format!("assemble x{}", reqs.len());
                ring.record(Span::until_now(name, trace::CAT_BATCH, t));
            }
        }

        // pad to the lane-aligned width (<= max_batch, which is itself
        // lane-aligned); padding lanes are zero samples whose outputs are
        // never read
        let batch = align_to_lane(reqs.len());
        input.clear();
        input.resize(batch * sample, 0.0);
        for (i, r) in reqs.iter().enumerate() {
            input[i * sample..(i + 1) * sample].copy_from_slice(&r.input);
        }
        let result = exec.run_with_arena(net, &input, batch, &mut arena);
        let run = {
            let mut st = recover(shared.stats.lock());
            st.requests += reqs.len();
            st.runs += 1;
            st.padded_lanes += batch - reqs.len();
            st.max_coalesced = st.max_coalesced.max(reqs.len());
            *st.batch_runs.entry(batch).or_insert(0) += 1;
            *st.batch_occupancy.entry(reqs.len()).or_insert(0) += 1;
            for r in &reqs {
                st.served_by_priority[r.priority.lane()] += 1;
                let wait = assembled_at.saturating_duration_since(r.submitted);
                st.wait_buckets[wait_bucket(wait)] += 1;
                st.wait_total_us += wait.as_micros().min(u128::from(u64::MAX)) as u64;
            }
            st.runs as u64
        };
        match result {
            Ok(y) => {
                for (i, r) in reqs.iter().enumerate() {
                    let _ = r.tx.send(Ok(Outcome {
                        output: y[i * out_len..(i + 1) * out_len].to_vec(),
                        run,
                        coalesced: reqs.len(),
                        waited: assembled_at.saturating_duration_since(r.submitted),
                    }));
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for r in &reqs {
                    let _ = r.tx.send(Err(ServeError::Execution(msg.clone())));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accuracy::Assignment;

    fn proxy_prepared() -> PreparedModel {
        PreparedModel::builder()
            .model("proxy")
            .assignments(
                crate::models::zoo::proxy_cnn()
                    .layers
                    .iter()
                    .map(|_| Assignment::dense())
                    .collect(),
            )
            .seed(5)
            .build()
            .unwrap()
    }

    fn proxy_session(max_batch: usize, max_wait: Duration) -> Session {
        Session::builder(proxy_prepared())
            .threads(1)
            .max_batch(max_batch)
            .max_wait(max_wait)
            .build()
    }

    fn queued(reqs: Vec<Request>) -> Queues {
        let mut q = Queues { lanes: [VecDeque::new(), VecDeque::new()] };
        for r in reqs {
            q.lanes[r.priority.lane()].push_back(r);
        }
        q
    }

    fn request(tag: f32, priority: Priority, deadline: Option<Instant>) -> Request {
        // the receiver is dropped: these pure tests only inspect queues,
        // they never reply
        let (tx, _rx) = mpsc::channel();
        Request { input: vec![tag], tx, priority, deadline, submitted: Instant::now() }
    }

    #[test]
    fn assemble_drains_the_high_lane_first() {
        let now = Instant::now();
        let mut q = queued(vec![
            request(0.0, Priority::Normal, None),
            request(1.0, Priority::Normal, None),
            request(2.0, Priority::High, None),
            request(3.0, Priority::High, None),
        ]);
        let (batch, expired) = assemble(&mut q, 3, now);
        assert!(expired.is_empty());
        // both high requests first (FIFO within the lane), then the oldest
        // normal request; the cap leaves the last normal queued
        let tags: Vec<f32> = batch.iter().map(|r| r.input[0]).collect();
        assert_eq!(tags, vec![2.0, 3.0, 0.0]);
        assert_eq!(q.len(), 1);
        assert_eq!(q.lanes[Priority::Normal.lane()][0].input[0], 1.0);
    }

    #[test]
    fn assemble_rejects_expired_without_consuming_slots() {
        let now = Instant::now();
        let past = now - Duration::from_millis(5);
        let future = now + Duration::from_secs(60);
        let mut q = queued(vec![
            request(0.0, Priority::High, Some(past)),
            request(1.0, Priority::High, Some(future)),
            request(2.0, Priority::Normal, Some(past)),
            request(3.0, Priority::Normal, None),
        ]);
        let (batch, expired) = assemble(&mut q, 2, now);
        let tags: Vec<f32> = batch.iter().map(|r| r.input[0]).collect();
        assert_eq!(tags, vec![1.0, 3.0], "expired requests must not occupy batch slots");
        assert_eq!(expired.len(), 2);
        for (r, missed_by) in &expired {
            assert!(r.deadline.is_some());
            assert!(*missed_by >= Duration::from_millis(5), "missed_by {missed_by:?}");
        }
        assert!(q.is_empty());
    }

    #[test]
    fn wait_buckets_cover_all_durations() {
        assert_eq!(wait_bucket(Duration::ZERO), 0);
        assert_eq!(wait_bucket(Duration::from_micros(99)), 0);
        assert_eq!(wait_bucket(Duration::from_micros(100)), 1);
        assert_eq!(wait_bucket(Duration::from_millis(5)), 2);
        assert_eq!(wait_bucket(Duration::from_millis(50)), 3);
        assert_eq!(wait_bucket(Duration::from_secs(10)), 4);
        assert_eq!(wait_bucket_labels().len(), SessionStats::default().wait_buckets.len());
    }

    #[test]
    fn wait_bucket_boundaries_are_exclusive() {
        // each bound is an *exclusive* upper limit: a wait exactly at the
        // bound belongs to the next bucket, one microsecond under stays
        for (i, &bound) in WAIT_BUCKET_BOUNDS_US.iter().enumerate() {
            assert_eq!(wait_bucket(Duration::from_micros(bound - 1)), i, "just under {bound}us");
            assert_eq!(wait_bucket(Duration::from_micros(bound)), i + 1, "exactly {bound}us");
        }
    }

    #[test]
    fn stats_to_json_carries_every_counter() {
        let mut st = SessionStats {
            requests: 3,
            runs: 2,
            padded_lanes: 5,
            max_coalesced: 2,
            queue_depth_hwm: 4,
            wait_buckets: [1, 2, 0, 0, 0],
            wait_total_us: 750,
            served_by_priority: [1, 2],
            expired: 1,
            shed_overload: 2,
            ..SessionStats::default()
        };
        st.batch_runs.insert(8, 2);
        st.batch_occupancy.insert(1, 1);
        st.batch_occupancy.insert(2, 1);
        let j = st.to_json();
        // round-trip through the serializer: the admin frame sends text
        let j = Value::parse(&j.compact()).unwrap();
        assert_eq!(j.get("requests").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(j.get("wait_total_us").unwrap().as_f64().unwrap(), 750.0);
        assert_eq!(j.get("batch_runs").unwrap().get("8").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(j.get("batch_occupancy").unwrap().get("2").unwrap().as_f64().unwrap(), 1.0);
        let lanes = j.get("served_by_priority").unwrap();
        assert_eq!(lanes.get("high").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(lanes.get("normal").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(j.get("wait_buckets").unwrap().as_arr().unwrap().len(), 5);
        assert_eq!(j.get("expired").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(j.get("shed_overload").unwrap().as_f64().unwrap(), 2.0);
    }

    #[test]
    fn attached_trace_ring_records_queue_batch_and_run_spans() {
        let ring = TraceRing::new(1024);
        let s = Session::builder(proxy_prepared())
            .threads(1)
            .max_wait(Duration::ZERO)
            .trace(Arc::clone(&ring))
            .build();
        assert!(s.trace_ring().is_some());
        let y = s.infer(vec![0.1; s.prepared().input_len()]).unwrap();
        assert_eq!(y.len(), 10);
        let spans = ring.snapshot();
        let count = |c: &str| spans.iter().filter(|s| s.cat == c).count();
        assert_eq!(count(trace::CAT_QUEUE), 1, "one request, one queue-wait span");
        assert_eq!(count(trace::CAT_BATCH), 1, "one assembled batch");
        assert_eq!(count(trace::CAT_RUN), 1, "one executor run");
        assert!(count(trace::CAT_OP) > 0, "executor records per-op spans");
        let q = spans.iter().find(|s| s.cat == trace::CAT_QUEUE).unwrap();
        assert_eq!(q.name, "queue_wait");
        assert_eq!(q.tid, 0, "queue waits live on the synthetic track");
        let b = spans.iter().find(|s| s.cat == trace::CAT_BATCH).unwrap();
        assert_eq!(b.name, "assemble x1");
        let st = s.stats();
        assert_eq!(st.wait_buckets.iter().sum::<usize>(), 1);
    }

    #[test]
    fn submit_validates_sample_length() {
        let s = proxy_session(8, Duration::ZERO);
        match s.submit(vec![0.0; 5]) {
            Err(ServeError::BadInput { expected, got }) => {
                assert_eq!(expected, s.prepared().input_len());
                assert_eq!(got, 5);
            }
            other => panic!("expected BadInput, got {other:?}"),
        }
        let y = s.infer(vec![0.1; s.prepared().input_len()]).unwrap();
        assert_eq!(y.len(), 10);
    }

    #[test]
    fn max_batch_rounds_up_to_a_lane_multiple() {
        let s = proxy_session(1, Duration::ZERO);
        assert_eq!(s.max_batch(), crate::sparse::LANE);
        let s = proxy_session(20, Duration::ZERO);
        assert_eq!(s.max_batch(), 24);
    }

    #[test]
    fn drop_serves_pending_tickets() {
        let s = proxy_session(32, Duration::from_millis(200));
        let n = s.prepared().input_len();
        let tickets: Vec<Ticket> =
            (0..3).map(|i| s.submit(vec![0.01 * i as f32; n]).unwrap()).collect();
        drop(s);
        for t in tickets {
            let y = t.wait().expect("pending requests are drained on close");
            assert_eq!(y.len(), 10);
        }
    }

    #[test]
    fn short_deadline_dispatches_early_instead_of_expiring_in_the_hold_window() {
        // max_wait far longer than the request's budget: the batcher must
        // dispatch immediately rather than hold the batch open past the
        // deadline (the request is alone on an idle session)
        let s = proxy_session(32, Duration::from_secs(5));
        let n = s.prepared().input_len();
        let t = s
            .submit_with(vec![0.4; n], Priority::Normal, Some(Duration::from_millis(500)))
            .unwrap();
        let y = t.wait().expect("a servable short-deadline request must not be held to death");
        assert_eq!(y.len(), 10);
        assert_eq!(s.stats().expired, 0);
    }

    #[test]
    fn submits_past_the_queue_hwm_are_shed_with_retry_after() {
        // a long hold window keeps the first submits parked in the queue
        // while the batcher waits to fill its batch, so the depth check
        // is deterministic; closing the session drains them immediately
        let s = Session::builder(proxy_prepared())
            .threads(1)
            .max_batch(8)
            .max_wait(Duration::from_secs(30))
            .max_queue(2)
            .build();
        assert_eq!(s.max_queue(), 2);
        let n = s.prepared().input_len();
        let admitted: Vec<Ticket> =
            (0..2).map(|i| s.submit(vec![0.1 * i as f32; n]).unwrap()).collect();
        match s.submit(vec![0.9; n]) {
            Err(ServeError::Overloaded { retry_after_ms }) => {
                assert!(retry_after_ms >= 1, "retry-after must never invite an instant retry");
            }
            Err(other) => panic!("expected Overloaded at the HWM, got {other:?}"),
            Ok(_) => panic!("expected Overloaded at the HWM, got an admitted ticket"),
        }
        let st = s.stats();
        assert_eq!(st.shed_overload, 1);
        assert_eq!(st.queue_depth_hwm, 2, "the shed request never entered the queue");
        drop(s);
        for t in admitted {
            assert_eq!(t.wait().expect("admitted requests still serve").len(), 10);
        }
    }

    #[test]
    fn max_queue_clamps_to_at_least_one() {
        let s = Session::builder(proxy_prepared()).threads(1).max_queue(0).build();
        assert_eq!(s.max_queue(), 1);
        // default is the documented constant
        let d = proxy_session(8, Duration::ZERO);
        assert_eq!(d.max_queue(), DEFAULT_MAX_QUEUE);
    }

    #[test]
    fn expired_deadline_is_rejected_not_served() {
        let s = proxy_session(8, Duration::ZERO);
        let n = s.prepared().input_len();
        // a deadline equal to the submit instant has always passed by the
        // time the batch is assembled
        let t = s.submit_with(vec![0.2; n], Priority::High, Some(Duration::ZERO)).unwrap();
        match t.wait() {
            Err(ServeError::DeadlineExpired { .. }) => {}
            other => panic!("expected DeadlineExpired, got {other:?}"),
        }
        // a served request after the rejection still works, and the stats
        // account for both
        let y = s.infer(vec![0.3; n]).unwrap();
        assert_eq!(y.len(), 10);
        let st = s.stats();
        assert_eq!(st.expired, 1);
        assert_eq!(st.requests, 1);
        assert_eq!(st.served_by_priority, [0, 1]);
        assert_eq!(st.wait_buckets.iter().sum::<usize>(), 1);
        assert!(st.queue_depth_hwm >= 1);
    }
}
