//! [`Session`]: request admission + dynamic micro-batching over a
//! [`PreparedModel`].
//!
//! A session owns everything mutable about serving: the
//! [`GraphExecutor`]s (whose engines share one persistent rayon pool), a
//! per-worker [`Arena`] that makes steady-state runs allocation-free, and
//! the request queue.  Callers [`Session::submit`] one sample at a time
//! and get a [`Ticket`]; batcher workers coalesce whatever is queued into
//! a **lane-aligned** batch (a multiple of the engine's
//! [`LANE`](crate::sparse::LANE), padded with zero samples, never more
//! than the max-batch cap), hold an under-full batch open for at most the
//! max-wait window, run the network once, and scatter each request's
//! output back through its ticket.  Per-request outputs are bit-identical
//! to a solo run — the engine accumulates every output element in the
//! same order at any batch width, and padding lanes are never read back.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::runtime::graph::StepTiming;
use crate::runtime::{Arena, GraphExecutor};
use crate::sparse::{align_to_lane, DEFAULT_TILE_COLS};

use super::PreparedModel;

/// What a batcher worker sends back per request (errors as strings so one
/// failed run can fan out to every rider of the batch).
type Served = std::result::Result<Vec<f32>, String>;

/// A pending request: one sample plus its reply channel.
struct Request {
    input: Vec<f32>,
    tx: mpsc::Sender<Served>,
}

/// Admission counters, observable via [`Session::stats`].  The batch
/// histogram keys are *executed* batch widths (real requests + padding
/// lanes), so lane alignment and the max-batch cap are directly testable.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SessionStats {
    /// Requests served (not counting padding lanes).
    pub requests: usize,
    /// Executor runs dispatched.
    pub runs: usize,
    /// Zero-sample lanes added to align batches to the SIMD lane width.
    pub padded_lanes: usize,
    /// Largest number of real requests coalesced into one run.
    pub max_coalesced: usize,
    /// Executed batch width -> number of runs at that width.
    pub batch_runs: BTreeMap<usize, usize>,
}

struct Shared {
    queue: Mutex<VecDeque<Request>>,
    cv: Condvar,
    closed: AtomicBool,
    stats: Mutex<SessionStats>,
    max_batch: usize,
    max_wait: Duration,
    sample_len: usize,
    out_len: usize,
}

/// A handle to one submitted request; [`Ticket::wait`] blocks until its
/// batch has run.
pub struct Ticket {
    rx: mpsc::Receiver<Served>,
}

impl Ticket {
    /// Block for this request's output (`[out_features]` for the sample).
    pub fn wait(self) -> Result<Vec<f32>> {
        match self.rx.recv() {
            Ok(Ok(y)) => Ok(y),
            Ok(Err(msg)) => Err(anyhow!(msg)),
            Err(_) => Err(anyhow!("session shut down before the request was served")),
        }
    }
}

/// Configuration for a [`Session`]; see the field setters.  Build with
/// [`Session::builder`] or [`PreparedModel::session`].
pub struct SessionBuilder {
    prepared: PreparedModel,
    threads: usize,
    tile_cols: usize,
    fused: bool,
    max_batch: usize,
    max_wait: Duration,
    workers: usize,
}

impl SessionBuilder {
    fn new(prepared: PreparedModel) -> SessionBuilder {
        SessionBuilder {
            prepared,
            threads: rayon::current_num_threads(),
            tile_cols: DEFAULT_TILE_COLS,
            fused: true,
            max_batch: 32,
            max_wait: Duration::from_millis(2),
            workers: 1,
        }
    }

    /// Engine worker threads per executor run (the persistent pool is
    /// built once and shared by every run).  Default: one per core.
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Fused-im2col tile width (GEMM columns per panel).
    pub fn tile_cols(mut self, tile: usize) -> Self {
        self.tile_cols = tile.max(1);
        self
    }

    /// `false` routes convs through the materialized-X im2col baseline
    /// instead of the fused tile-order producer.  Default fused.
    pub fn fused(mut self, fused: bool) -> Self {
        self.fused = fused;
        self
    }

    /// Most requests one run may serve.  Rounded **up** to a lane multiple
    /// (minimum one lane block) so coalesced batches always align; the
    /// effective value is [`Session::max_batch`].  Default 32.
    pub fn max_batch(mut self, max_batch: usize) -> Self {
        self.max_batch = max_batch.max(1);
        self
    }

    /// How long the micro-batcher holds an under-full batch open for more
    /// requests — the tail-latency bound.  `Duration::ZERO` dispatches
    /// whatever is queued immediately.  Default 2ms.
    pub fn max_wait(mut self, max_wait: Duration) -> Self {
        self.max_wait = max_wait;
        self
    }

    /// Batcher worker threads, each owning a persistent [`Arena`] (warm
    /// runs allocate nothing) and draining the shared queue.  Default 1.
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    /// Spawn the batcher workers and open the session for requests.
    pub fn build(self) -> Session {
        let exec = {
            let e = GraphExecutor::new(self.threads).with_tile_cols(self.tile_cols);
            if self.fused {
                e
            } else {
                e.materialized()
            }
        };
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            closed: AtomicBool::new(false),
            stats: Mutex::new(SessionStats::default()),
            max_batch: align_to_lane(self.max_batch),
            max_wait: self.max_wait,
            sample_len: self.prepared.input_len(),
            out_len: self.prepared.output_len(),
        });
        let workers = (0..self.workers)
            .map(|i| {
                let exec = exec.clone();
                let prepared = self.prepared.clone();
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("prunemap-serve-{i}"))
                    .spawn(move || worker_loop(&exec, &prepared, &shared))
                    .expect("spawn serve worker")
            })
            .collect();
        Session { prepared: self.prepared, exec, shared, workers }
    }
}

/// A live serving endpoint over one [`PreparedModel`]; see the
/// [module docs](self).  Dropping the session serves every queued request,
/// then joins the workers.
pub struct Session {
    prepared: PreparedModel,
    exec: GraphExecutor,
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl Session {
    /// Start configuring a session over `prepared`.
    pub fn builder(prepared: PreparedModel) -> SessionBuilder {
        SessionBuilder::new(prepared)
    }

    /// The sealed artifact this session serves.
    pub fn prepared(&self) -> &PreparedModel {
        &self.prepared
    }

    /// Effective coalescing cap: the builder's `max_batch` rounded up to a
    /// lane multiple.  No executed batch ever exceeds this.
    pub fn max_batch(&self) -> usize {
        self.shared.max_batch
    }

    /// The micro-batcher's admission window.
    pub fn max_wait(&self) -> Duration {
        self.shared.max_wait
    }

    /// Engine worker threads per executor run.
    pub fn threads(&self) -> usize {
        self.exec.threads()
    }

    /// Whether convs run the fused tile-order im2col path.
    pub fn is_fused(&self) -> bool {
        self.exec.is_fused()
    }

    /// Batcher worker count.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// A snapshot of the admission counters.
    pub fn stats(&self) -> SessionStats {
        self.shared.stats.lock().unwrap().clone()
    }

    /// Enqueue one sample (NCHW-flattened `[C*H*W]`) and return a
    /// [`Ticket`] for its output.  Concurrent submissions coalesce into
    /// lane-aligned batches; the call itself never blocks on execution.
    pub fn submit(&self, input: Vec<f32>) -> Result<Ticket> {
        if input.len() != self.shared.sample_len {
            let (c, h, w) = self.prepared.input_shape();
            bail!(
                "input must be one [{c}, {h}, {w}] sample = {} elements, got {}",
                self.shared.sample_len,
                input.len()
            );
        }
        let (tx, rx) = mpsc::channel();
        self.shared.queue.lock().unwrap().push_back(Request { input, tx });
        self.shared.cv.notify_all();
        Ok(Ticket { rx })
    }

    /// Blocking convenience: [`Session::submit`] + [`Ticket::wait`].
    pub fn infer(&self, input: Vec<f32>) -> Result<Vec<f32>> {
        self.submit(input)?.wait()
    }

    /// Diagnostic direct run (bypasses the micro-batcher): one warmed
    /// batched inference with per-step timings, as `prunemap infer`
    /// reports.  `input` is `[batch, C, H, W]` row-major.
    pub fn run_timed(&self, input: &[f32], batch: usize) -> Result<(Vec<f32>, Vec<StepTiming>)> {
        let mut arena = Arena::new();
        let _warmup = self.exec.run_with_arena(self.prepared.net(), input, batch, &mut arena)?;
        self.exec.run_timed_with_arena(self.prepared.net(), input, batch, &mut arena)
    }
}

impl Drop for Session {
    fn drop(&mut self) {
        {
            // flip `closed` and notify while holding the queue mutex:
            // a worker between its `closed` check and `cv.wait` still
            // holds the lock, so the store+notify cannot slip into that
            // window and strand it (the classic lost wakeup)
            let _queue = self.shared.queue.lock().unwrap();
            self.shared.closed.store(true, Ordering::Release);
            self.shared.cv.notify_all();
        }
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }
}

/// One batcher worker: wait for requests, coalesce up to `max_batch`
/// within `max_wait`, pad the batch to a lane multiple, run once, scatter.
/// On close the queue is drained — pending tickets are served, not
/// dropped.
fn worker_loop(exec: &GraphExecutor, prepared: &PreparedModel, shared: &Shared) {
    let net = prepared.net();
    let sample = shared.sample_len;
    let out_len = shared.out_len;
    let mut arena = Arena::new();
    let mut input: Vec<f32> = Vec::new();
    loop {
        let mut q = shared.queue.lock().unwrap();
        // phase 1: block until there is at least one request (or shutdown
        // with an empty queue)
        loop {
            if !q.is_empty() {
                break;
            }
            if shared.closed.load(Ordering::Acquire) {
                return;
            }
            q = shared.cv.wait(q).unwrap();
        }
        // phase 2: hold the batch open for up to `max_wait` hoping to fill
        // it to `max_batch` (skipped when closing: drain immediately)
        let deadline = Instant::now() + shared.max_wait;
        while q.len() < shared.max_batch && !shared.closed.load(Ordering::Acquire) {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (guard, timeout) = shared.cv.wait_timeout(q, deadline - now).unwrap();
            q = guard;
            if timeout.timed_out() {
                break;
            }
        }
        let take = q.len().min(shared.max_batch);
        let reqs: Vec<Request> = q.drain(..take).collect();
        drop(q);
        if reqs.is_empty() {
            // another worker drained the queue while we held the batch
            // open; go back to waiting
            continue;
        }

        // pad to the lane-aligned width (<= max_batch, which is itself
        // lane-aligned); padding lanes are zero samples whose outputs are
        // never read
        let batch = align_to_lane(reqs.len());
        input.clear();
        input.resize(batch * sample, 0.0);
        for (i, r) in reqs.iter().enumerate() {
            input[i * sample..(i + 1) * sample].copy_from_slice(&r.input);
        }
        let result = exec.run_with_arena(net, &input, batch, &mut arena);
        {
            let mut st = shared.stats.lock().unwrap();
            st.requests += reqs.len();
            st.runs += 1;
            st.padded_lanes += batch - reqs.len();
            st.max_coalesced = st.max_coalesced.max(reqs.len());
            *st.batch_runs.entry(batch).or_insert(0) += 1;
        }
        match result {
            Ok(y) => {
                for (i, r) in reqs.iter().enumerate() {
                    let _ = r.tx.send(Ok(y[i * out_len..(i + 1) * out_len].to_vec()));
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for r in &reqs {
                    let _ = r.tx.send(Err(msg.clone()));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accuracy::Assignment;

    fn proxy_session(max_batch: usize, max_wait: Duration) -> Session {
        let prepared = PreparedModel::builder()
            .model("proxy")
            .assignments(
                crate::models::zoo::proxy_cnn()
                    .layers
                    .iter()
                    .map(|_| Assignment::dense())
                    .collect(),
            )
            .seed(5)
            .build()
            .unwrap();
        Session::builder(prepared)
            .threads(1)
            .max_batch(max_batch)
            .max_wait(max_wait)
            .build()
    }

    #[test]
    fn submit_validates_sample_length() {
        let s = proxy_session(8, Duration::ZERO);
        assert!(s.submit(vec![0.0; 5]).is_err());
        let y = s.infer(vec![0.1; s.prepared().input_len()]).unwrap();
        assert_eq!(y.len(), 10);
    }

    #[test]
    fn max_batch_rounds_up_to_a_lane_multiple() {
        let s = proxy_session(1, Duration::ZERO);
        assert_eq!(s.max_batch(), crate::sparse::LANE);
        let s = proxy_session(20, Duration::ZERO);
        assert_eq!(s.max_batch(), 24);
    }

    #[test]
    fn drop_serves_pending_tickets() {
        let s = proxy_session(32, Duration::from_millis(200));
        let n = s.prepared().input_len();
        let tickets: Vec<Ticket> =
            (0..3).map(|i| s.submit(vec![0.01 * i as f32; n]).unwrap()).collect();
        drop(s);
        for t in tickets {
            let y = t.wait().expect("pending requests are drained on close");
            assert_eq!(y.len(), 10);
        }
    }
}
