//! [`PreparedModel`]: the sealed, shareable inference artifact.
//!
//! Everything the serving layer needs to answer a request — the layer
//! spec, the per-layer pruning assignments, the synthesized masked
//! weights, and the lowered [`CompiledNet`] with its converted sparse
//! kernels — is built once here and frozen behind an `Arc`.  `Clone` is a
//! refcount bump, so sessions, workers, and benches all execute the same
//! kernels.  [`PreparedModel::save`]/[`PreparedModel::load`] persist the
//! *recipe* (spec + assignments + seed + kernel choice) through
//! [`crate::util::json`]; weights re-synthesize deterministically from the
//! seed on load, so a search-based mapping is computed once and served
//! repeatedly.

use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::accuracy::Assignment;
use crate::mapping::MappingMethod;
use crate::models::{zoo, Dataset, LayerKind, LayerSpec, ModelSpec};
use crate::pruning::Scheme;
use crate::runtime::{CompiledNet, KernelChoice, NetWeights};
use crate::simulator::DeviceProfile;
use crate::util::json::Value;

use super::session::{Session, SessionBuilder};

/// Artifact format tag written by [`PreparedModel::save`].
const FORMAT: &str = "prunemap.prepared.v1";

struct Inner {
    model: ModelSpec,
    assigns: Vec<Assignment>,
    seed: u64,
    choice: KernelChoice,
    weights: NetWeights,
    net: CompiledNet,
    /// Provenance label: `"rule"`, `"search"`, `"explicit"`, or `"loaded"`.
    method: String,
}

/// An immutable, cheaply-`Clone` compiled inference artifact: spec +
/// assignments + synthesized weights + lowered network, shared via `Arc`.
/// See the [module docs](super) for the serving lifecycle.
#[derive(Clone)]
pub struct PreparedModel {
    inner: Arc<Inner>,
}

impl PreparedModel {
    /// Start a fluent build: zoo model, dataset, mapping method, weight
    /// seed, kernel choice.
    pub fn builder() -> PreparedModelBuilder {
        PreparedModelBuilder::default()
    }

    /// Seal explicit parts into an artifact: synthesize masked weights
    /// from `seed` and lower the fused plan once.  `method` is a
    /// provenance label carried for reports.
    ///
    /// Sealing is gated by the static analyzer
    /// ([`crate::analysis::check_model`]): an artifact carrying any
    /// Error-severity diagnostic is refused with a
    /// [`ServeError::ArtifactRejected`](super::ServeError::ArtifactRejected)
    /// (downcastable through the anyhow chain) whose context carries the
    /// full diagnostic rendering.  Warnings never gate.
    pub fn from_parts(
        model: ModelSpec,
        assigns: Vec<Assignment>,
        seed: u64,
        choice: KernelChoice,
        method: &str,
    ) -> Result<PreparedModel> {
        let (weights, net) = CompiledNet::compile_with_weights(&model, &assigns, seed, choice)?;
        let report = crate::analysis::check_model(&model, &assigns, &weights, &net);
        if report.has_errors() {
            let err = super::ServeError::ArtifactRejected {
                model: model.name.clone(),
                errors: report.error_count(),
            };
            return Err(anyhow::Error::new(err).context(format!(
                "static analysis rejected '{}':\n{}",
                model.name,
                report.render()
            )));
        }
        Ok(PreparedModel {
            inner: Arc::new(Inner {
                model,
                assigns,
                seed,
                choice,
                weights,
                net,
                method: method.to_string(),
            }),
        })
    }

    pub fn model(&self) -> &ModelSpec {
        &self.inner.model
    }

    pub fn name(&self) -> &str {
        &self.inner.model.name
    }

    pub fn assigns(&self) -> &[Assignment] {
        &self.inner.assigns
    }

    pub fn weights(&self) -> &NetWeights {
        &self.inner.weights
    }

    /// The lowered network (converted sparse kernels, program steps) —
    /// hand this to a [`GraphExecutor`](crate::runtime::GraphExecutor)
    /// for low-level control, or build a [`Session`] for serving.
    pub fn net(&self) -> &CompiledNet {
        &self.inner.net
    }

    pub fn seed(&self) -> u64 {
        self.inner.seed
    }

    pub fn kernel_choice(&self) -> KernelChoice {
        self.inner.choice
    }

    /// Provenance of the assignments: `"rule"`, `"search"`, `"explicit"`,
    /// or `"loaded"`.
    pub fn method(&self) -> &str {
        &self.inner.method
    }

    /// Per-sample input shape `(C, H, W)`.
    pub fn input_shape(&self) -> (usize, usize, usize) {
        self.inner.net.input_shape
    }

    /// Per-sample input element count (one request's payload length).
    pub fn input_len(&self) -> usize {
        let (c, h, w) = self.inner.net.input_shape;
        c * h * w
    }

    /// Per-sample output element count.
    pub fn output_len(&self) -> usize {
        self.inner.net.output_len()
    }

    /// Re-run the static analyzer over this sealed artifact.  Sealing
    /// already refused Error-carrying artifacts, so this reports at most
    /// warnings — it exists so `prunemap check` and operators can render
    /// the full report for an artifact that passed.
    pub fn check(&self) -> crate::analysis::Report {
        crate::analysis::check_model(
            &self.inner.model,
            &self.inner.assigns,
            &self.inner.weights,
            &self.inner.net,
        )
    }

    /// Run the advisory performance lint over this sealed artifact: the
    /// same cost model that drove the mapping re-prices the result (with
    /// `calibration` ratios when a profile record is supplied) and
    /// reports Advice-severity findings.  Never gates — a sealed
    /// artifact is correct by construction; lint says whether it is
    /// *fast*.
    pub fn lint(
        &self,
        dev: &crate::simulator::DeviceProfile,
        cfg: &crate::analysis::LintConfig,
        calibration: Option<&crate::analysis::CalibrationRecord>,
    ) -> crate::analysis::Report {
        crate::analysis::lint_model(
            &self.inner.model,
            &self.inner.assigns,
            &self.inner.weights,
            dev,
            cfg,
            calibration,
        )
    }

    /// Start building a serving [`Session`] over this artifact.
    pub fn session(&self) -> SessionBuilder {
        Session::builder(self.clone())
    }

    /// Whether `self` and `other` are the same sealed artifact (the same
    /// `Arc`), not merely equal recipes — how the
    /// [`Server`](super::Server) detects that a registry name was rebound
    /// to a new artifact.
    pub fn same_artifact(&self, other: &PreparedModel) -> bool {
        Arc::ptr_eq(&self.inner, &other.inner)
    }

    /// The artifact recipe as a JSON value (see [`PreparedModel::save`]).
    pub fn to_json(&self) -> Value {
        let assigns = self
            .inner
            .assigns
            .iter()
            .map(assignment_to_json)
            .collect();
        Value::obj(vec![
            ("format", Value::str(FORMAT)),
            ("model", model_to_json(&self.inner.model)),
            ("assignments", Value::arr(assigns)),
            // string-encoded so the full u64 range survives JSON's f64
            ("seed", Value::str(self.inner.seed.to_string())),
            ("kernel", Value::str(self.inner.choice.name())),
            ("method", Value::str(self.inner.method.clone())),
        ])
    }

    /// Persist the recipe — spec, assignments, seed, kernel choice — as
    /// pretty JSON.  Weights are *not* stored: they re-synthesize
    /// bit-identically from the seed on [`PreparedModel::load`], so the
    /// round trip reproduces identical logits at a few kilobytes.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        std::fs::write(path, self.to_json().pretty())
            .with_context(|| format!("write prepared artifact to {}", path.display()))
    }

    /// Rebuild an artifact saved by [`PreparedModel::save`]: parse the
    /// recipe, re-synthesize weights, and re-lower the network.
    pub fn load(path: impl AsRef<Path>) -> Result<PreparedModel> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read prepared artifact from {}", path.display()))?;
        Self::from_json(&Value::parse(&text)?)
            .with_context(|| format!("parse prepared artifact {}", path.display()))
    }

    /// [`PreparedModel::load`] from an already-parsed JSON value.
    pub fn from_json(v: &Value) -> Result<PreparedModel> {
        let (model, assigns, seed, choice, method) = Self::recipe_from_json(v)?;
        Self::from_parts(model, assigns, seed, choice, &method)
    }

    /// Parse a saved recipe into its parts *without* sealing (and so
    /// without the static-analysis gate) — how `prunemap check --load`
    /// analyzes an artifact that sealing would refuse.
    pub fn recipe_from_json(
        v: &Value,
    ) -> Result<(ModelSpec, Vec<Assignment>, u64, KernelChoice, String)> {
        let format = v.get("format")?.as_str()?;
        if format != FORMAT {
            bail!("unsupported artifact format '{format}' (expected '{FORMAT}')");
        }
        let model = model_from_json(v.get("model")?)?;
        let assigns = v
            .get("assignments")?
            .as_arr()?
            .iter()
            .map(assignment_from_json)
            .collect::<Result<Vec<_>>>()?;
        let seed = v.get("seed")?.as_u64()?;
        let kernel = v.get("kernel")?.as_str()?;
        let choice = KernelChoice::by_name(kernel)
            .ok_or_else(|| anyhow!("unknown kernel choice '{kernel}'"))?;
        let method = match v.opt("method") {
            Some(m) => m.as_str()?.to_string(),
            None => "loaded".to_string(),
        };
        Ok((model, assigns, seed, choice, method))
    }
}

/// Fluent configuration for [`PreparedModel`]: pick a zoo model (or pass a
/// spec), a dataset, a mapping method (or explicit assignments), the
/// weight seed, and the sparse-format choice; `build()` runs the mapping
/// and seals the artifact.
pub struct PreparedModelBuilder {
    model_name: Option<String>,
    model_spec: Option<ModelSpec>,
    dataset: String,
    device: String,
    method: String,
    iterations: usize,
    search_seed: u64,
    mapping: Option<MappingMethod>,
    assignments: Option<Vec<Assignment>>,
    seed: u64,
    choice: KernelChoice,
}

impl Default for PreparedModelBuilder {
    fn default() -> Self {
        PreparedModelBuilder {
            model_name: None,
            model_spec: None,
            dataset: "cifar10".to_string(),
            device: "s10".to_string(),
            method: "rule".to_string(),
            iterations: 30,
            search_seed: 0xC0FFEE,
            mapping: None,
            assignments: None,
            seed: 7,
            choice: KernelChoice::Auto,
        }
    }
}

impl PreparedModelBuilder {
    /// Zoo model name (`vgg16`, `resnet18`, `resnet50`, `mobilenetv1`,
    /// `mobilenetv2`, `yolov4`, `proxy`).
    pub fn model(mut self, name: &str) -> Self {
        self.model_name = Some(name.to_string());
        self
    }

    /// Use an explicit [`ModelSpec`] instead of a zoo name.
    pub fn model_spec(mut self, spec: ModelSpec) -> Self {
        self.model_spec = Some(spec);
        self
    }

    /// Dataset name (`cifar10`, `cifar100`, `imagenet`, `coco`,
    /// `synthetic`); drives zoo variants and the mapping's difficulty
    /// dispatch.  Default `cifar10`.
    pub fn dataset(mut self, name: &str) -> Self {
        self.dataset = name.to_string();
        self
    }

    /// Device profile the mapping optimizes for (`s10` | `s20` | `s21`).
    /// Default `s10`.
    pub fn device(mut self, name: &str) -> Self {
        self.device = name.to_string();
        self
    }

    /// Mapping method name (`rule` | `search`).  Default `rule`.
    pub fn method(mut self, name: &str) -> Self {
        self.method = name.to_string();
        self
    }

    /// Search iterations (search method only).  Default 30.
    pub fn iterations(mut self, n: usize) -> Self {
        self.iterations = n;
        self
    }

    /// Search RNG seed (search method only).
    pub fn search_seed(mut self, seed: u64) -> Self {
        self.search_seed = seed;
        self
    }

    /// Use an already-resolved [`MappingMethod`] (overrides
    /// `method`/`iterations`/`search_seed`).
    pub fn mapping(mut self, method: MappingMethod) -> Self {
        self.mapping = Some(method);
        self
    }

    /// Skip mapping entirely and use these per-layer assignments.
    pub fn assignments(mut self, assigns: Vec<Assignment>) -> Self {
        self.assignments = Some(assigns);
        self
    }

    /// Weight-synthesis seed (the stand-in for a trained checkpoint).
    /// Default 7.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Sparse-format selection per layer.  Default
    /// [`KernelChoice::Auto`].
    pub fn kernel(mut self, choice: KernelChoice) -> Self {
        self.choice = choice;
        self
    }

    /// Resolve names, run the mapping (unless explicit assignments were
    /// given), synthesize weights, and lower the network.
    pub fn build(self) -> Result<PreparedModel> {
        let ds = Dataset::by_name(&self.dataset)
            .ok_or_else(|| anyhow!("unknown dataset '{}'", self.dataset))?;
        let model = match (self.model_spec, self.model_name) {
            (Some(spec), _) => spec,
            (None, Some(name)) => zoo::by_name(&name, ds)
                .ok_or_else(|| anyhow!("unknown model '{name}'"))?,
            (None, None) => {
                bail!("PreparedModel::builder() needs .model(name) or .model_spec(spec)")
            }
        };
        let (assigns, method) = match self.assignments {
            Some(a) => (a, "explicit".to_string()),
            None => {
                let dev = DeviceProfile::by_name(&self.device)
                    .ok_or_else(|| anyhow!("unknown device '{}'", self.device))?;
                let m = match self.mapping {
                    Some(m) => m,
                    None => MappingMethod::parse(&self.method, self.iterations, self.search_seed)?,
                };
                let label = m.label().to_string();
                (m.assign(&model, &dev), label)
            }
        };
        PreparedModel::from_parts(model, assigns, self.seed, self.choice, &method)
    }
}

// ---- JSON (de)serialization helpers ------------------------------------

fn kind_name(kind: LayerKind) -> &'static str {
    match kind {
        LayerKind::Conv => "conv",
        LayerKind::DepthwiseConv => "dwconv",
        LayerKind::Fc => "fc",
    }
}

fn kind_from_name(name: &str) -> Result<LayerKind> {
    Ok(match name {
        "conv" => LayerKind::Conv,
        "dwconv" => LayerKind::DepthwiseConv,
        "fc" => LayerKind::Fc,
        other => bail!("unknown layer kind '{other}'"),
    })
}

fn layer_to_json(l: &LayerSpec) -> Value {
    Value::obj(vec![
        ("name", Value::str(l.name.clone())),
        ("kind", Value::str(kind_name(l.kind))),
        ("kh", Value::num(l.kh as f64)),
        ("kw", Value::num(l.kw as f64)),
        ("in_ch", Value::num(l.in_ch as f64)),
        ("out_ch", Value::num(l.out_ch as f64)),
        ("in_hw", Value::num(l.in_hw as f64)),
        ("stride", Value::num(l.stride as f64)),
    ])
}

fn layer_from_json(v: &Value) -> Result<LayerSpec> {
    Ok(LayerSpec {
        name: v.get("name")?.as_str()?.to_string(),
        kind: kind_from_name(v.get("kind")?.as_str()?)?,
        kh: v.get("kh")?.as_usize()?,
        kw: v.get("kw")?.as_usize()?,
        in_ch: v.get("in_ch")?.as_usize()?,
        out_ch: v.get("out_ch")?.as_usize()?,
        in_hw: v.get("in_hw")?.as_usize()?,
        stride: v.get("stride")?.as_usize()?,
    })
}

fn model_to_json(m: &ModelSpec) -> Value {
    Value::obj(vec![
        ("name", Value::str(m.name.clone())),
        ("dataset", Value::str(m.dataset.name())),
        ("layers", Value::arr(m.layers.iter().map(layer_to_json).collect())),
    ])
}

fn model_from_json(v: &Value) -> Result<ModelSpec> {
    let ds = v.get("dataset")?.as_str()?;
    Ok(ModelSpec {
        name: v.get("name")?.as_str()?.to_string(),
        dataset: Dataset::by_name(ds).ok_or_else(|| anyhow!("unknown dataset '{ds}'"))?,
        layers: v
            .get("layers")?
            .as_arr()?
            .iter()
            .map(layer_from_json)
            .collect::<Result<Vec<_>>>()?,
    })
}

fn scheme_to_json(s: &Scheme) -> Value {
    match s {
        Scheme::None => Value::obj(vec![("kind", Value::str("none"))]),
        Scheme::Unstructured => Value::obj(vec![("kind", Value::str("unstructured"))]),
        Scheme::StructuredRow => Value::obj(vec![("kind", Value::str("structured-row"))]),
        Scheme::StructuredColumn => Value::obj(vec![("kind", Value::str("structured-col"))]),
        Scheme::Pattern => Value::obj(vec![("kind", Value::str("pattern"))]),
        Scheme::Block { bp, bq } => Value::obj(vec![
            ("kind", Value::str("block")),
            ("bp", Value::num(*bp as f64)),
            ("bq", Value::num(*bq as f64)),
        ]),
        Scheme::BlockPunched { bf, bc } => Value::obj(vec![
            ("kind", Value::str("punched")),
            ("bf", Value::num(*bf as f64)),
            ("bc", Value::num(*bc as f64)),
        ]),
    }
}

fn scheme_from_json(v: &Value) -> Result<Scheme> {
    Ok(match v.get("kind")?.as_str()? {
        "none" => Scheme::None,
        "unstructured" => Scheme::Unstructured,
        "structured-row" => Scheme::StructuredRow,
        "structured-col" => Scheme::StructuredColumn,
        "pattern" => Scheme::Pattern,
        "block" => Scheme::Block {
            bp: v.get("bp")?.as_usize()?,
            bq: v.get("bq")?.as_usize()?,
        },
        "punched" => Scheme::BlockPunched {
            bf: v.get("bf")?.as_usize()?,
            bc: v.get("bc")?.as_usize()?,
        },
        other => bail!("unknown scheme kind '{other}'"),
    })
}

fn assignment_to_json(a: &Assignment) -> Value {
    Value::obj(vec![
        ("scheme", scheme_to_json(&a.scheme)),
        ("compression", Value::num(f64::from(a.compression))),
    ])
}

fn assignment_from_json(v: &Value) -> Result<Assignment> {
    Ok(Assignment {
        scheme: scheme_from_json(v.get("scheme")?)?,
        compression: v.get("compression")?.as_f64()? as f32,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    fn proxy_assigns(model: &ModelSpec) -> Vec<Assignment> {
        model
            .layers
            .iter()
            .map(|l| {
                if l.is_3x3_conv() {
                    Assignment { scheme: Scheme::BlockPunched { bf: 4, bc: 4 }, compression: 2.0 }
                } else {
                    Assignment { scheme: Scheme::Block { bp: 8, bq: 2 }, compression: 2.0 }
                }
            })
            .collect()
    }

    #[test]
    fn builder_seals_a_runnable_artifact() {
        let m = zoo::proxy_cnn();
        let assigns = proxy_assigns(&m);
        let p = PreparedModel::builder()
            .model("proxy")
            .assignments(assigns)
            .seed(3)
            .build()
            .unwrap();
        assert_eq!(p.name(), "ProxyCNN");
        assert_eq!(p.method(), "explicit");
        assert_eq!(p.input_shape(), (3, 32, 32));
        assert_eq!(p.input_len(), 3 * 32 * 32);
        assert_eq!(p.output_len(), 10);
        assert_eq!(p.assigns().len(), m.layers.len());
        // clones share the same sealed artifact
        let q = p.clone();
        assert!(std::ptr::eq(p.net(), q.net()));
    }

    #[test]
    fn builder_rejects_unknowns() {
        assert!(PreparedModel::builder().build().is_err());
        assert!(PreparedModel::builder().model("alexnet").build().is_err());
        assert!(PreparedModel::builder().model("proxy").dataset("mnist").build().is_err());
        assert!(PreparedModel::builder().model("proxy").device("pixel").build().is_err());
        assert!(PreparedModel::builder().model("proxy").method("magic").build().is_err());
    }

    #[test]
    fn recipe_json_roundtrips() {
        let m = zoo::proxy_cnn();
        let assigns = proxy_assigns(&m);
        let p = PreparedModel::builder()
            .model("proxy")
            .assignments(assigns)
            .seed(0xDEAD_BEEF_DEAD_BEEF)
            .kernel(KernelChoice::Csr)
            .build()
            .unwrap();
        let v = Value::parse(&p.to_json().pretty()).unwrap();
        let q = PreparedModel::from_json(&v).unwrap();
        assert_eq!(q.seed(), 0xDEAD_BEEF_DEAD_BEEF);
        assert_eq!(q.kernel_choice(), KernelChoice::Csr);
        assert_eq!(q.model().layers, p.model().layers);
        for (a, b) in p.assigns().iter().zip(q.assigns()) {
            assert_eq!(a.scheme.label(), b.scheme.label());
            assert_eq!(a.compression, b.compression);
        }
        // identical weights — the determinism behind save/load parity
        for (a, b) in p.weights().layers.iter().zip(&q.weights().layers) {
            assert_eq!(a.weight.data(), b.weight.data(), "layer {}", a.spec.name);
        }
    }

    #[test]
    fn from_json_rejects_bad_artifacts() {
        let bad_format = Value::parse(r#"{"format": "prunemap.prepared.v9"}"#).unwrap();
        assert!(PreparedModel::from_json(&bad_format).is_err());
        assert!(PreparedModel::from_json(&Value::parse("{}").unwrap()).is_err());
    }
}
