//! [`Server`]: the multi-model serving front door.
//!
//! One process, many models: the server routes a typed [`InferRequest`]
//! to the [`Session`] of the model it names, creating that session
//! lazily (one micro-batcher per model, all sharing the server's session
//! knobs) from the [`ModelRegistry`].  Admission failures — unknown
//! model, wrong payload length, expired deadline, executor fault — all
//! surface as typed [`ServeError`]s, so callers (and the
//! [`wire`](super::wire) protocol) can tell a routing mistake from a
//! missed deadline without parsing strings.
//!
//! The per-model micro-batchers keep the session layer's guarantee: a
//! request's output is bit-identical whether it ran alone in a dedicated
//! process or rode a coalesced batch behind the front door
//! (`tests/front_door.rs` locks this across two models and interleaved
//! clients).

use std::collections::BTreeMap;
use std::sync::{Arc, RwLock};
use std::time::Duration;

use crate::sparse::DEFAULT_TILE_COLS;
use crate::telemetry::trace::TraceRing;
use crate::telemetry::{render_server_metrics, WireCounters};

use super::session::SessionStats;
use super::{recover, ModelRegistry, Priority, ServeError, Session, Ticket};

/// The typed request envelope the front door accepts: which model, one
/// input sample, and the admission metadata the batcher honors.
#[derive(Debug, Clone, PartialEq)]
pub struct InferRequest {
    /// Registry name of the model to route to.
    pub model: String,
    /// One NCHW-flattened `[C*H*W]` sample.
    pub input: Vec<f32>,
    /// Admission lane; [`Priority::High`] drains first under saturation.
    pub priority: Priority,
    /// Latest acceptable service start, relative to submission.  A
    /// request still queued past this budget is rejected with
    /// [`ServeError::DeadlineExpired`], never silently served late.
    pub deadline: Option<Duration>,
}

impl InferRequest {
    /// A normal-priority request with no deadline.
    pub fn new(model: impl Into<String>, input: Vec<f32>) -> InferRequest {
        InferRequest { model: model.into(), input, priority: Priority::Normal, deadline: None }
    }

    /// Set the admission lane.
    pub fn priority(mut self, priority: Priority) -> InferRequest {
        self.priority = priority;
        self
    }

    /// Shorthand for the high-priority lane.
    pub fn high(self) -> InferRequest {
        self.priority(Priority::High)
    }

    /// Set the service deadline (relative to submission).
    pub fn deadline(mut self, deadline: Duration) -> InferRequest {
        self.deadline = Some(deadline);
        self
    }
}

/// Session knobs every per-model session shares; see the
/// [`SessionBuilder`](super::SessionBuilder) setters with the same names.
struct SessionKnobs {
    threads: usize,
    tile_cols: usize,
    fused: bool,
    max_batch: usize,
    max_wait: Duration,
    max_queue: usize,
    workers: usize,
}

/// Configuration for a [`Server`]; build with [`Server::builder`].
pub struct ServerBuilder {
    registry: ModelRegistry,
    knobs: SessionKnobs,
    trace: Option<Arc<TraceRing>>,
}

impl ServerBuilder {
    fn new(registry: ModelRegistry) -> ServerBuilder {
        ServerBuilder {
            registry,
            knobs: SessionKnobs {
                threads: rayon::current_num_threads(),
                tile_cols: DEFAULT_TILE_COLS,
                fused: true,
                max_batch: 32,
                max_wait: Duration::from_millis(2),
                max_queue: super::DEFAULT_MAX_QUEUE,
                workers: 1,
            },
            trace: None,
        }
    }

    /// Engine worker threads per executor run, for every model's session.
    pub fn threads(mut self, threads: usize) -> Self {
        self.knobs.threads = threads.max(1);
        self
    }

    /// Fused-im2col tile width (GEMM columns per panel).
    pub fn tile_cols(mut self, tile: usize) -> Self {
        self.knobs.tile_cols = tile.max(1);
        self
    }

    /// `false` routes convs through the materialized-X im2col baseline.
    pub fn fused(mut self, fused: bool) -> Self {
        self.knobs.fused = fused;
        self
    }

    /// Per-model coalescing cap (rounded up to a lane multiple).
    pub fn max_batch(mut self, max_batch: usize) -> Self {
        self.knobs.max_batch = max_batch.max(1);
        self
    }

    /// Per-model micro-batcher admission window.
    pub fn max_wait(mut self, max_wait: Duration) -> Self {
        self.knobs.max_wait = max_wait;
        self
    }

    /// Per-model queue-depth high-water mark: submits past it are shed
    /// with [`ServeError::Overloaded`] instead of queued.
    pub fn max_queue(mut self, max_queue: usize) -> Self {
        self.knobs.max_queue = max_queue.max(1);
        self
    }

    /// Batcher workers per model.
    pub fn workers(mut self, workers: usize) -> Self {
        self.knobs.workers = workers.max(1);
        self
    }

    /// Attach one shared [`TraceRing`]: every session this server spins
    /// up records queue/batch/run/step/op spans into it.  Default: none.
    pub fn trace(mut self, ring: Arc<TraceRing>) -> Self {
        self.trace = Some(ring);
        self
    }

    /// Open the front door.  Sessions spin up lazily on each model's
    /// first request; nothing is compiled here.
    pub fn build(self) -> Server {
        Server {
            registry: self.registry,
            knobs: self.knobs,
            trace: self.trace,
            sessions: RwLock::new(BTreeMap::new()),
            wire: Arc::new(WireCounters::default()),
        }
    }
}

/// The process-level serving front door; see the [module docs](self).
pub struct Server {
    registry: ModelRegistry,
    knobs: SessionKnobs,
    trace: Option<Arc<TraceRing>>,
    sessions: RwLock<BTreeMap<String, Arc<Session>>>,
    wire: Arc<WireCounters>,
}

impl Server {
    /// Start configuring a server over `registry` (the registry is
    /// `Clone`-shared: models inserted after the server is built are
    /// routable immediately).
    pub fn builder(registry: ModelRegistry) -> ServerBuilder {
        ServerBuilder::new(registry)
    }

    /// A server over `registry` with default session knobs.
    pub fn new(registry: ModelRegistry) -> Server {
        Server::builder(registry).build()
    }

    /// The shared registry this server routes across.
    pub fn registry(&self) -> &ModelRegistry {
        &self.registry
    }

    /// The live session for `name`, creating it from the registry
    /// artifact on first use.  If the registry artifact was replaced
    /// since the session was built (`ModelRegistry::insert` over an
    /// existing name), the session is rebuilt around the new artifact —
    /// requests already queued on the old session still drain against
    /// the artifact they were admitted to.  If the registry no longer
    /// has the name at all (`ModelRegistry::evict` through the shared
    /// handle), the cached session is dropped here, not just routed
    /// around: otherwise its batcher workers idle forever and
    /// [`Server::stats`] keeps reporting a model the registry disowned.
    pub fn session(&self, name: &str) -> Result<Arc<Session>, ServeError> {
        {
            let Some(artifact) = self.registry.get(name) else {
                self.purge(name);
                return Err(ServeError::UnknownModel(name.to_string()));
            };
            if let Some(session) = recover(self.sessions.read()).get(name) {
                if session.prepared().same_artifact(&artifact) {
                    return Ok(Arc::clone(session));
                }
            }
        }
        let mut sessions = recover(self.sessions.write());
        // re-resolve the artifact under the write lock — the registry may
        // have been rebound or evicted since the fast path looked, and a
        // stale snapshot here would let a lagging thread overwrite a
        // newer session with one built from the old artifact
        let Some(artifact) = self.registry.get(name) else {
            let stale = sessions.remove(name);
            // release the map lock before the stale session can drop —
            // its drop drains the queue and joins workers
            drop(sessions);
            drop(stale);
            return Err(ServeError::UnknownModel(name.to_string()));
        };
        if let Some(session) = sessions.get(name) {
            if session.prepared().same_artifact(&artifact) {
                return Ok(Arc::clone(session));
            }
        }
        let mut builder = Session::builder(artifact)
            .threads(self.knobs.threads)
            .tile_cols(self.knobs.tile_cols)
            .fused(self.knobs.fused)
            .max_batch(self.knobs.max_batch)
            .max_wait(self.knobs.max_wait)
            .max_queue(self.knobs.max_queue)
            .workers(self.knobs.workers);
        if let Some(ring) = &self.trace {
            builder = builder.trace(Arc::clone(ring));
        }
        let session = Arc::new(builder.build());
        let replaced = sessions.insert(name.to_string(), Arc::clone(&session));
        // release the map lock before the replaced session can drop —
        // Session::drop drains its queue and joins workers, and doing
        // that under the write lock would stall routing for every model
        drop(sessions);
        drop(replaced);
        Ok(session)
    }

    /// Route `req` to its model's session and enqueue it; the [`Ticket`]
    /// resolves to the output or a typed admission/execution error.
    pub fn submit(&self, req: InferRequest) -> Result<Ticket, ServeError> {
        let session = self.session(&req.model)?;
        session.submit_with(req.input, req.priority, req.deadline)
    }

    /// Blocking convenience: [`Server::submit`] + [`Ticket::wait`].
    pub fn infer(&self, req: InferRequest) -> Result<Vec<f32>, ServeError> {
        self.submit(req)?.wait()
    }

    /// Drop any cached session for `name` whose registry entry is gone.
    /// The temporary write guard is released at the end of the `remove`
    /// statement; the session itself (queue drain + worker join) drops
    /// after it.
    fn purge(&self, name: &str) {
        let stale = recover(self.sessions.write()).remove(name);
        drop(stale);
    }

    /// Drop `name` everywhere: the registry entry and the live session
    /// (whose queued requests drain before its workers exit).  Returns
    /// whether anything was removed.  The registry entry goes first so a
    /// concurrent submit cannot re-resolve the name and resurrect a
    /// session in the gap.
    pub fn evict(&self, name: &str) -> bool {
        let had_model = self.registry.evict(name).is_some();
        // bind the removed session so it outlives (and thus drops after)
        // the statement's write guard: its drop drains the queue and
        // joins workers, which must not happen under the map lock
        let removed = recover(self.sessions.write()).remove(name);
        had_model || removed.is_some()
    }

    /// Admission counters per model, for every session spun up so far
    /// (a registered model nobody has routed to yet has no stats).
    pub fn stats(&self) -> BTreeMap<String, SessionStats> {
        recover(self.sessions.read())
            .iter()
            .map(|(name, session)| (name.clone(), session.stats()))
            .collect()
    }

    /// The wire-layer counters ([`wire`](super::wire) increments them
    /// per connection/frame; the exporter renders them).
    pub fn wire_counters(&self) -> &Arc<WireCounters> {
        &self.wire
    }

    /// The span ring shared by every session, if one was attached.
    pub fn trace_ring(&self) -> Option<&Arc<TraceRing>> {
        self.trace.as_ref()
    }

    /// The full Prometheus text exposition document for this server:
    /// every per-model family from [`Server::stats`] plus the wire-layer
    /// counters.  What the `metrics` admin frame and the `--metrics`
    /// scrape listener both serve.
    pub fn metrics_text(&self) -> String {
        render_server_metrics(&self.stats(), &self.wire.snapshot())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accuracy::Assignment;
    use crate::serve::PreparedModel;

    fn proxy(seed: u64) -> PreparedModel {
        PreparedModel::builder()
            .model("proxy")
            .assignments(
                crate::models::zoo::proxy_cnn()
                    .layers
                    .iter()
                    .map(|_| Assignment::dense())
                    .collect(),
            )
            .seed(seed)
            .build()
            .unwrap()
    }

    fn server_with(models: &[(&str, u64)]) -> Server {
        let registry = ModelRegistry::new();
        for &(name, seed) in models {
            registry.insert(name, proxy(seed));
        }
        Server::builder(registry).threads(1).build()
    }

    #[test]
    fn unknown_model_is_a_typed_error() {
        let server = server_with(&[("a", 1)]);
        match server.infer(InferRequest::new("b", vec![0.0; 3072])) {
            Err(ServeError::UnknownModel(name)) => assert_eq!(name, "b"),
            other => panic!("expected UnknownModel, got {other:?}"),
        }
        assert!(server.stats().is_empty(), "no session for a failed route");
    }

    #[test]
    fn routes_by_name_and_reports_stats_per_model() {
        let server = server_with(&[("a", 1), ("b", 2)]);
        let input = vec![0.25; 3072];
        let ya = server.infer(InferRequest::new("a", input.clone())).unwrap();
        let yb = server.infer(InferRequest::new("b", input.clone())).unwrap();
        // different seeds -> different weights -> different logits
        assert_ne!(ya, yb);
        let yb2 = server.infer(InferRequest::new("b", input)).unwrap();
        assert_eq!(yb, yb2, "same model + input must be deterministic");
        let stats = server.stats();
        assert_eq!(stats.len(), 2);
        assert_eq!(stats["a"].requests, 1);
        assert_eq!(stats["b"].requests, 2);
    }

    #[test]
    fn replacing_a_registry_artifact_rebuilds_the_session() {
        let server = server_with(&[("m", 1)]);
        let input = vec![0.5; 3072];
        let y1 = server.infer(InferRequest::new("m", input.clone())).unwrap();
        server.registry().insert("m", proxy(2));
        let y2 = server.infer(InferRequest::new("m", input)).unwrap();
        assert_ne!(y1, y2, "new artifact must actually serve");
        assert_eq!(server.stats()["m"].requests, 1, "fresh session, fresh stats");
    }

    #[test]
    fn evict_stops_routing() {
        let server = server_with(&[("m", 1)]);
        server.infer(InferRequest::new("m", vec![0.1; 3072])).unwrap();
        assert!(server.evict("m"));
        assert!(!server.evict("m"));
        assert!(matches!(
            server.infer(InferRequest::new("m", vec![0.1; 3072])),
            Err(ServeError::UnknownModel(_))
        ));
    }

    #[test]
    fn registry_evict_drops_the_cached_session() {
        // evicting through the SHARED registry handle (not Server::evict)
        // used to leave the lazily-built session cached forever: routing
        // already failed, but the session's workers idled on and stats()
        // kept reporting the evicted model
        let server = server_with(&[("m", 1)]);
        server.infer(InferRequest::new("m", vec![0.1; 3072])).unwrap();
        assert_eq!(server.stats().len(), 1, "session cached after first request");
        assert!(server.registry().evict("m").is_some());
        assert!(matches!(
            server.infer(InferRequest::new("m", vec![0.1; 3072])),
            Err(ServeError::UnknownModel(_))
        ));
        assert!(
            server.stats().is_empty(),
            "the cached session must be dropped once the registry disowns the name"
        );
    }

    #[test]
    fn metrics_text_renders_per_model_and_wire_families() {
        let server = server_with(&[("a", 1)]);
        server.infer(InferRequest::new("a", vec![0.25; 3072])).unwrap();
        server.wire_counters().connections.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let fams = crate::telemetry::parse_exposition(&server.metrics_text()).unwrap();
        let reqs = &fams["prunemap_requests_total"];
        assert!(reqs.samples.iter().any(|s| s.label("model") == Some("a")));
        assert_eq!(fams["prunemap_wire_connections_total"].samples[0].value, 1.0);
    }

    #[test]
    fn bad_input_and_deadline_flow_through_the_envelope() {
        let server = server_with(&[("m", 1)]);
        assert!(matches!(
            server.infer(InferRequest::new("m", vec![0.1; 5])),
            Err(ServeError::BadInput { expected: 3072, got: 5 })
        ));
        let req = InferRequest::new("m", vec![0.1; 3072]).high().deadline(Duration::ZERO);
        assert!(matches!(server.infer(req), Err(ServeError::DeadlineExpired { .. })));
    }
}
