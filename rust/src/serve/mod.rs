//! Compile-once / serve-many inference: the crate's front-door API.
//!
//! The serving stack is three layers, each public, each the documented
//! floor for the one above:
//!
//! ```text
//! Server            multi-model front door: ModelRegistry routing,
//!   |               typed InferRequest envelopes, priority lanes,
//!   |               deadline admission, per-model stats; spoken over
//!   |               the line-JSON wire protocol (serve::wire) by
//!   |               `prunemap serve --listen` (TCP or stdio)
//!   v
//! Session           one model's admission loop: persistent engine
//!   |               pool, per-worker arena, dynamic micro-batcher
//!   |               coalescing submits into lane-aligned batches
//!   v
//! GraphExecutor     the low-level executor: explicit batches,
//!                   per-step timings, arena control
//! ```
//!
//! * [`PreparedModel`] — `(ModelSpec, assignments, NetWeights,
//!   CompiledNet)` sealed into a single immutable artifact behind an
//!   `Arc`, so clones are cheap and every session/worker shares the same
//!   converted sparse kernels.  Built fluently via
//!   [`PreparedModel::builder`] (zoo model, dataset, mapping method, seed,
//!   kernel choice), and `save`/`load`-able as a JSON *recipe*
//!   ([`crate::util::json`]): the spec, per-layer assignments, and the
//!   weight seed round-trip, and weights are re-synthesized
//!   deterministically on load — a mapping computed once (e.g. by the RL
//!   search) is served forever without re-running search.
//! * [`Session`] — built from a `PreparedModel` via [`SessionBuilder`]
//!   (threads, tile width, fused/materialized im2col, max batch, max
//!   wait, worker count).  It owns the persistent
//!   [`Engine`](crate::sparse::Engine) pool and a per-worker
//!   [`Arena`](crate::runtime::Arena), and exposes
//!   [`Session::submit`]`(input) -> `[`Ticket`] plus the blocking
//!   [`Session::infer`] wrapper.  A **dynamic micro-batcher** coalesces
//!   concurrently submitted requests into lane-aligned batches (multiples
//!   of the engine's 8-wide [`LANE`](crate::sparse::LANE), latency bounded
//!   by the max-wait knob) before one fused executor run, then scatters
//!   per-request outputs.  Because every GEMM column accumulates in a
//!   fixed non-zero order and all other kernels are elementwise, a
//!   request's output is **bit-identical** whether it ran alone or rode a
//!   coalesced batch — the executor's determinism guarantee lifted to the
//!   serving layer (locked by `tests/serve_api.rs`).  Requests carry a
//!   [`Priority`] lane and an optional deadline
//!   ([`Session::submit_with`]): the batcher drains the high lane before
//!   the normal lane, and a request whose deadline has passed when its
//!   batch is assembled is rejected with
//!   [`ServeError::DeadlineExpired`] instead of silently served late.
//! * [`ModelRegistry`] + [`Server`] — the process-level front door.  The
//!   registry holds many named `PreparedModel` artifacts
//!   (insert / load-recipe / evict; `Clone` shares the same store); the
//!   server routes a typed [`InferRequest`]` { model, input, priority,
//!   deadline }` to that model's session (created lazily, one micro-batcher
//!   per model) and surfaces every admission failure as a typed
//!   [`ServeError`].  [`Server::stats`] exposes each model's
//!   [`SessionStats`].
//! * [`wire`] — the line-delimited JSON protocol over the `Server`:
//!   request / response / error frames tagged with caller-chosen ids,
//!   served over TCP or stdio by `prunemap serve --listen`, plus the
//!   [`wire::Client`] helper the examples and benches drive it with.
//!   In-band [`wire::AdminCmd`] frames (`stats` / `metrics`) let clients
//!   fetch per-model [`SessionStats`] and the Prometheus exposition
//!   document over the same connection; `prunemap serve --metrics ADDR`
//!   additionally serves the document to HTTP scrapers (see
//!   [`crate::telemetry`]).
//!
//! [`GraphExecutor`](crate::runtime::GraphExecutor) remains public as the
//! low-level layer underneath: reach for it when you need explicit
//! batches, per-step timings, or arena control; reach for [`Session`]
//! when you serve one model in-process; reach for [`Server`] when one
//! process serves several models or remote clients.

use std::fmt;
use std::time::Duration;

pub mod prepared;
pub mod registry;
pub mod server;
pub mod session;
pub mod wire;

pub use prepared::{PreparedModel, PreparedModelBuilder};
pub use registry::ModelRegistry;
pub use server::{InferRequest, Server, ServerBuilder};
pub use session::{
    wait_bucket_labels, Outcome, Session, SessionBuilder, SessionStats, Ticket,
    DEFAULT_MAX_QUEUE, WAIT_BUCKET_BOUNDS_US,
};

/// Admission lane for a request.  The micro-batcher always drains the
/// [`Priority::High`] lane before the [`Priority::Normal`] lane when it
/// assembles a batch, so under saturation high-priority requests ride the
/// earlier runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum Priority {
    /// Best-effort lane (the default).
    #[default]
    Normal,
    /// Drained first by every batch assembly.
    High,
}

impl Priority {
    /// Stable wire / display name (`"normal"` | `"high"`).
    pub fn name(self) -> &'static str {
        match self {
            Priority::Normal => "normal",
            Priority::High => "high",
        }
    }

    /// Parse a wire name; `None` for anything but `"normal"` / `"high"`.
    pub fn by_name(name: &str) -> Option<Priority> {
        match name {
            "normal" => Some(Priority::Normal),
            "high" => Some(Priority::High),
            _ => None,
        }
    }

    /// Queue-lane index: high = 0 (drained first), normal = 1.
    pub(crate) fn lane(self) -> usize {
        match self {
            Priority::High => 0,
            Priority::Normal => 1,
        }
    }
}

/// Why the serving layer refused or failed a request — every admission
/// outcome a caller can observe, as a typed error instead of a panic or a
/// stringly anyhow chain.  [`ServeError::kind`] is the stable tag the
/// [`wire`] protocol carries in error frames.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The request named a model the registry does not hold.
    UnknownModel(String),
    /// The input payload length does not match the model's sample length.
    BadInput { expected: usize, got: usize },
    /// The request's deadline had already passed when its batch was
    /// assembled (or when it was submitted); it was never executed.
    DeadlineExpired { missed_by: Duration },
    /// The request was shed because the session's queue was already at
    /// its high-water mark (`max_queue`), or the connection was shed
    /// because the wire layer's pool was full — it was never queued.
    /// `retry_after_ms` is a drain-time estimate the caller should back
    /// off for before retrying.
    Overloaded { retry_after_ms: u64 },
    /// The session/server shut down before the request was served.
    Closed,
    /// The executor failed the batch this request rode.
    Execution(String),
    /// A wire frame could not be decoded.
    Malformed(String),
    /// The artifact failed static analysis
    /// ([`crate::analysis::check_model`]) at sealing or recipe-load time:
    /// it carries `errors` Error-severity diagnostics and was refused
    /// before it could reach the registry or serve a request.
    ArtifactRejected { model: String, errors: usize },
}

impl ServeError {
    /// Stable machine-readable tag, used as the `kind` field of wire
    /// error frames.
    pub fn kind(&self) -> &'static str {
        match self {
            ServeError::UnknownModel(_) => "unknown_model",
            ServeError::BadInput { .. } => "bad_input",
            ServeError::DeadlineExpired { .. } => "deadline_expired",
            ServeError::Overloaded { .. } => "overloaded",
            ServeError::Closed => "closed",
            ServeError::Execution(_) => "execution",
            ServeError::Malformed(_) => "malformed",
            ServeError::ArtifactRejected { .. } => "artifact_rejected",
        }
    }

    /// Rebuild from a wire `(kind, message)` pair.  Structured fields
    /// (expected/got lengths, missed-by duration) do not survive the trip
    /// — the message keeps them human-readable — so unknown or structured
    /// kinds map to the closest variant.  The one exception is
    /// `overloaded`: its retry-after budget is the whole point of the
    /// rejection, so it is parsed back out of the message and the variant
    /// round-trips losslessly.
    pub fn from_wire(kind: &str, message: &str) -> ServeError {
        match kind {
            "unknown_model" => ServeError::UnknownModel(message.to_string()),
            "bad_input" => ServeError::BadInput { expected: 0, got: 0 },
            "deadline_expired" => ServeError::DeadlineExpired { missed_by: Duration::ZERO },
            "overloaded" => {
                ServeError::Overloaded { retry_after_ms: parse_retry_after(message) }
            }
            "closed" => ServeError::Closed,
            "malformed" => ServeError::Malformed(message.to_string()),
            "artifact_rejected" => {
                ServeError::ArtifactRejected { model: message.to_string(), errors: 0 }
            }
            _ => ServeError::Execution(message.to_string()),
        }
    }
}

/// Inverse of the `Overloaded` display format: the `N` out of
/// `... retry after Nms`, `0` (retry immediately at the caller's own
/// risk) when the message does not carry one.
fn parse_retry_after(message: &str) -> u64 {
    message
        .rsplit("retry after ")
        .next()
        .and_then(|tail| tail.split("ms").next())
        .and_then(|n| n.trim().parse().ok())
        .unwrap_or(0)
}

/// Recover a possibly-poisoned lock guard.  A batcher or client thread
/// that panicked while holding a serving lock poisons it; propagating
/// that poison as a panic would turn one failed worker into a panic for
/// every subsequent request on the lock.  The guarded state here is
/// counters and queues that stay structurally valid across a panic
/// (worst case: one increment lost), so the server degrades to serving
/// instead of cascading.  The implementation lives in [`crate::util::lock`]
/// so non-serve modules (telemetry, runtime caches) share the pattern.
pub(crate) use crate::util::recover;

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::UnknownModel(name) => write!(f, "unknown model '{name}'"),
            ServeError::BadInput { expected, got } => {
                write!(f, "input must be {expected} elements, got {got}")
            }
            ServeError::DeadlineExpired { missed_by } => {
                write!(f, "deadline expired {missed_by:?} before the batch was assembled")
            }
            ServeError::Overloaded { retry_after_ms } => {
                write!(f, "server overloaded: retry after {retry_after_ms}ms")
            }
            ServeError::Closed => write!(f, "session shut down before the request was served"),
            ServeError::Execution(msg) => write!(f, "execution failed: {msg}"),
            ServeError::Malformed(msg) => write!(f, "malformed frame: {msg}"),
            ServeError::ArtifactRejected { model, errors } => {
                write!(f, "artifact '{model}' rejected by static analysis: {errors} error(s)")
            }
        }
    }
}

impl std::error::Error for ServeError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn priority_names_roundtrip() {
        for p in [Priority::Normal, Priority::High] {
            assert_eq!(Priority::by_name(p.name()), Some(p));
        }
        assert_eq!(Priority::by_name("urgent"), None);
        assert_eq!(Priority::default(), Priority::Normal);
        assert!(Priority::High.lane() < Priority::Normal.lane());
    }

    #[test]
    fn serve_error_kinds_roundtrip() {
        let cases = [
            ServeError::UnknownModel("m".into()),
            ServeError::BadInput { expected: 4, got: 2 },
            ServeError::DeadlineExpired { missed_by: Duration::from_millis(3) },
            ServeError::Overloaded { retry_after_ms: 12 },
            ServeError::Closed,
            ServeError::Execution("boom".into()),
            ServeError::Malformed("not json".into()),
            ServeError::ArtifactRejected { model: "m".into(), errors: 2 },
        ];
        for e in &cases {
            let back = ServeError::from_wire(e.kind(), &e.to_string());
            assert_eq!(back.kind(), e.kind(), "{e}");
        }
        // unknown kinds degrade to Execution, not a panic
        assert_eq!(ServeError::from_wire("??", "m").kind(), "execution");
    }

    #[test]
    fn overloaded_retry_after_survives_the_wire() {
        // the retry budget is the point of the rejection, so unlike the
        // other structured fields it round-trips through the message
        let e = ServeError::Overloaded { retry_after_ms: 250 };
        assert_eq!(ServeError::from_wire(e.kind(), &e.to_string()), e);
        // a mangled message degrades to "retry now", never a parse panic
        assert_eq!(
            ServeError::from_wire("overloaded", "free-form text"),
            ServeError::Overloaded { retry_after_ms: 0 }
        );
        assert_eq!(parse_retry_after("server overloaded: retry after 7ms"), 7);
        assert_eq!(parse_retry_after("retry after soonms"), 0);
    }
}
