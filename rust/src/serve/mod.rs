//! Compile-once / serve-many inference: the crate's front-door API.
//!
//! The lower layers expose the pipeline as loose stages — run a mapping
//! method, [`NetWeights::synthesize`](crate::runtime::NetWeights::synthesize),
//! [`CompiledNet::compile`](crate::runtime::CompiledNet::compile), then
//! drive a [`GraphExecutor`](crate::runtime::GraphExecutor) with a
//! caller-chosen batch.  That is the right surface for benchmarks and
//! parity tests, but a serving process wants one object that owns the
//! compiled artifact and one that owns admission.  This module provides
//! both:
//!
//! * [`PreparedModel`] — `(ModelSpec, assignments, NetWeights,
//!   CompiledNet)` sealed into a single immutable artifact behind an
//!   `Arc`, so clones are cheap and every session/worker shares the same
//!   converted sparse kernels.  Built fluently via
//!   [`PreparedModel::builder`] (zoo model, dataset, mapping method, seed,
//!   kernel choice), and `save`/`load`-able as a JSON *recipe*
//!   ([`crate::util::json`]): the spec, per-layer assignments, and the
//!   weight seed round-trip, and weights are re-synthesized
//!   deterministically on load — a mapping computed once (e.g. by the RL
//!   search) is served forever without re-running search.
//! * [`Session`] — built from a `PreparedModel` via [`SessionBuilder`]
//!   (threads, tile width, fused/materialized im2col, max batch, max
//!   wait, worker count).  It owns the persistent
//!   [`Engine`](crate::sparse::Engine) pool and a per-worker
//!   [`Arena`](crate::runtime::Arena), and exposes
//!   [`Session::submit`]`(input) -> `[`Ticket`] plus the blocking
//!   [`Session::infer`] wrapper.  A **dynamic micro-batcher** coalesces
//!   concurrently submitted requests into lane-aligned batches (multiples
//!   of the engine's 8-wide [`LANE`](crate::sparse::LANE), latency bounded
//!   by the max-wait knob) before one fused executor run, then scatters
//!   per-request outputs.  Because every GEMM column accumulates in a
//!   fixed non-zero order and all other kernels are elementwise, a
//!   request's output is **bit-identical** whether it ran alone or rode a
//!   coalesced batch — the executor's determinism guarantee lifted to the
//!   serving layer (locked by `tests/serve_api.rs`).
//!
//! [`GraphExecutor`](crate::runtime::GraphExecutor) remains public as the
//! low-level layer underneath: reach for it when you need explicit
//! batches, per-step timings, or arena control; reach for this module when
//! you need a front door.

pub mod prepared;
pub mod session;

pub use prepared::{PreparedModel, PreparedModelBuilder};
pub use session::{Session, SessionBuilder, SessionStats, Ticket};
