//! [`ModelRegistry`]: the named store of sealed [`PreparedModel`]
//! artifacts a [`Server`](super::Server) routes requests across.
//!
//! The registry is deliberately dumb: a concurrent name -> artifact map.
//! Artifacts are `Arc`-shared ([`PreparedModel`] clones are refcount
//! bumps), so handing one to a session, a bench, and the registry costs
//! nothing, and evicting a name never invalidates in-flight requests — a
//! session serving the artifact keeps its own reference until it drops.
//! `Clone` on the registry itself shares the *store* (the server and the
//! CLI see the same models), not a snapshot.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, RwLock};

use anyhow::Result;

use super::{recover, PreparedModel};

/// A shared, concurrent map of model name -> sealed artifact.  See the
/// [module docs](self).
#[derive(Clone, Default)]
pub struct ModelRegistry {
    models: Arc<RwLock<BTreeMap<String, PreparedModel>>>,
}

impl ModelRegistry {
    /// An empty registry.
    pub fn new() -> ModelRegistry {
        ModelRegistry::default()
    }

    /// Register `prepared` under `name`, replacing any previous artifact
    /// with that name (returned, so callers can tell an insert from an
    /// update).  The serving name is the caller's routing key and need not
    /// match the zoo spec name — one process can hold `"resnet50-eu"` and
    /// `"resnet50-us"` variants of the same spec.
    pub fn insert(
        &self,
        name: impl Into<String>,
        prepared: PreparedModel,
    ) -> Option<PreparedModel> {
        recover(self.models.write()).insert(name.into(), prepared)
    }

    /// [`PreparedModel::load`] a saved recipe and register it under
    /// `name`: weights re-synthesize deterministically from the recipe
    /// seed, so a mapping computed once (e.g. by the RL search) is
    /// registered and served without re-running search.
    pub fn load_recipe(&self, name: impl Into<String>, path: impl AsRef<Path>) -> Result<()> {
        let prepared = PreparedModel::load(path)?;
        self.insert(name, prepared);
        Ok(())
    }

    /// Remove `name` from the registry; returns the artifact if it was
    /// held.  In-flight requests already routed keep serving — eviction
    /// only stops *new* routing.
    pub fn evict(&self, name: &str) -> Option<PreparedModel> {
        recover(self.models.write()).remove(name)
    }

    /// The artifact registered under `name` (a cheap `Arc` clone).
    pub fn get(&self, name: &str) -> Option<PreparedModel> {
        recover(self.models.read()).get(name).cloned()
    }

    /// Whether `name` is registered.
    pub fn contains(&self, name: &str) -> bool {
        recover(self.models.read()).contains_key(name)
    }

    /// Registered names, sorted (the map is ordered).
    pub fn names(&self) -> Vec<String> {
        recover(self.models.read()).keys().cloned().collect()
    }

    /// Number of registered models.
    pub fn len(&self) -> usize {
        recover(self.models.read()).len()
    }

    /// Whether the registry holds no models.
    pub fn is_empty(&self) -> bool {
        recover(self.models.read()).is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accuracy::Assignment;

    fn proxy(seed: u64) -> PreparedModel {
        PreparedModel::builder()
            .model("proxy")
            .assignments(
                crate::models::zoo::proxy_cnn()
                    .layers
                    .iter()
                    .map(|_| Assignment::dense())
                    .collect(),
            )
            .seed(seed)
            .build()
            .unwrap()
    }

    #[test]
    fn insert_get_evict_share_one_store() {
        let reg = ModelRegistry::new();
        assert!(reg.is_empty());
        assert!(reg.insert("a", proxy(1)).is_none());
        let alias = reg.clone();
        alias.insert("b", proxy(2));
        assert_eq!(reg.names(), vec!["a".to_string(), "b".to_string()]);
        assert_eq!(reg.len(), 2);
        assert!(reg.contains("b"));
        // get is the same sealed artifact, not a copy
        let got = reg.get("a").unwrap();
        assert!(std::ptr::eq(got.net(), reg.get("a").unwrap().net()));
        // replacing returns the old artifact; evicting removes it
        assert!(reg.insert("a", proxy(3)).is_some());
        assert_eq!(reg.get("a").unwrap().seed(), 3);
        assert!(reg.evict("a").is_some());
        assert!(reg.evict("a").is_none());
        assert!(!alias.contains("a"));
    }

    #[test]
    fn load_recipe_registers_a_saved_artifact() {
        let reg = ModelRegistry::new();
        let path = std::env::temp_dir().join(format!(
            "prunemap_registry_recipe_{}_{:?}.json",
            std::process::id(),
            std::thread::current().id()
        ));
        proxy(9).save(&path).unwrap();
        reg.load_recipe("served", &path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(reg.get("served").unwrap().seed(), 9);
        assert!(reg.load_recipe("nope", "/no/such/recipe.json").is_err());
    }
}
