//! Line-delimited JSON wire protocol over the [`Server`] front door.
//!
//! One frame per line, every frame a JSON object, every request tagged
//! with a caller-chosen `id` the reply echoes — so replies may be read
//! out of order and requests pipelined (which is exactly what lets the
//! per-model micro-batchers coalesce remote traffic):
//!
//! ```text
//! -> {"id":1,"model":"mobilenetv1","input":[0.1,...],"priority":"high","deadline_ms":5.0}
//! <- {"id":1,"output":[...]}
//! -> {"id":2,"model":"nope","input":[...]}
//! <- {"id":2,"error":{"kind":"unknown_model","message":"unknown model 'nope'"}}
//! ```
//!
//! `priority` (default `"normal"`) and `deadline_ms` (default none) are
//! optional.  A line that cannot be decoded is answered with a
//! `"malformed"` error frame — `id` echoed when it can be recovered,
//! `null` otherwise — and the connection stays up.  Blank lines are
//! ignored (netcat-friendly).
//!
//! Two **admin frames** ([`AdminCmd`]) share the connection with
//! inference traffic: `{"id":N,"admin":"stats"}` is answered with the
//! per-model session counters as JSON, and `{"id":N,"admin":"metrics"}`
//! with the Prometheus text exposition document in a `"metrics"` string
//! field.  Admin replies are rendered when the reply writer reaches
//! them, so a `stats` frame pipelined behind an inference observes that
//! inference in its counters.
//!
//! [`serve_connection`] drives one duplex byte stream (any
//! `BufRead` + `Write` pair: a TCP socket, stdio, or in-memory buffers in
//! tests); [`serve_tcp`] accepts connections and serves each on its own
//! thread, bounded by a `max_active` pool — excess accepts are shed with
//! a single `overloaded` error frame and closed; [`Client`] is the
//! matching caller side with pipelined [`Client::send`] /
//! [`Client::wait`].  `prunemap serve --listen <addr|stdio>` wires these
//! to the CLI.
//!
//! Overload is **bounded and typed** end to end: each connection's
//! pending-reply channel holds at most [`PENDING_REPLY_CAP`] replies
//! (a fast pipeliner blocks the reader, pushing backpressure into the
//! peer's TCP window), each model's session sheds submits past its
//! `max_queue` high-water mark with an `overloaded` error carrying
//! `retry_after_ms`, and a writer whose peer vanished kills its own
//! read half ([`ReadShutdown`]) so the connection thread exits instead
//! of parking in `read_line` forever.
//!
//! Numbers are carried as JSON numbers (shortest-roundtrip `f64`, which
//! `f32` payloads survive exactly), so a wire round trip preserves the
//! serving layer's bit-identity guarantee for finite values; NaN and
//! infinity are not representable in JSON and are rejected as malformed.
//! Ids ride the same number representation, so they must stay below
//! 2^53 (f64's exact-integer range) — [`Client`] assigns sequential ids
//! from 1 and can never reach the bound; hand-rolled callers using
//! hash-derived ids would see them silently rounded by any JSON stack.

use std::collections::BTreeMap;
use std::io::{self, BufRead, BufReader, Write};
use std::net::{Shutdown, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use crate::util::json::Value;

use super::session::SessionStats;
use super::{InferRequest, Priority, ServeError, Server, Ticket};

/// Wire deadlines above this are clamped (mirrors the CLI's `--max-wait-ms`
/// bound): `Duration::from_secs_f64` panics on values it cannot represent,
/// and a multi-minute service deadline is a typo.
const MAX_DEADLINE_MS: f64 = 60_000.0;

/// Depth of a connection's pending-reply channel.  A pipelining client
/// that outruns the reply writer fills it and then parks the connection
/// *reader* in `send`, which stops `read_line` draining the socket,
/// which fills the kernel receive buffer — backpressure all the way out
/// to the peer's TCP window instead of unbounded server-side queueing.
pub const PENDING_REPLY_CAP: usize = 128;

/// `retry_after_ms` hint carried by the `overloaded` frame a connection
/// shed at accept time (pool full) receives before the socket closes.
pub const SHED_RETRY_MS: u64 = 50;

/// Consecutive `accept` failures tolerated (with backoff) before
/// [`serve_tcp`] gives up and returns the error.  Transient failures —
/// EMFILE under fd pressure, ECONNABORTED races — clear the streak on
/// the next successful accept.
const ACCEPT_ERROR_LIMIT: u32 = 8;

/// Base backoff between accept retries; scaled by the failure streak.
const ACCEPT_RETRY_BACKOFF: Duration = Duration::from_millis(10);

/// A decoded request frame: the caller's id plus the typed envelope.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestFrame {
    pub id: u64,
    pub request: InferRequest,
}

/// A decoded reply frame: an output or a typed error (whose `id` is
/// `None` when the server could not recover the offending request's id).
#[derive(Debug, Clone, PartialEq)]
pub enum ResponseFrame {
    Output { id: u64, output: Vec<f32> },
    Error { id: Option<u64>, error: ServeError },
}

/// In-band admin commands: observability frames that ride the same
/// connection as inference traffic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdminCmd {
    /// Per-model [`SessionStats`] as a JSON object.
    Stats,
    /// The full Prometheus text exposition document.
    Metrics,
}

impl AdminCmd {
    /// The stable wire name (`"stats"` / `"metrics"`).
    pub fn name(self) -> &'static str {
        match self {
            AdminCmd::Stats => "stats",
            AdminCmd::Metrics => "metrics",
        }
    }

    /// Inverse of [`AdminCmd::name`].
    pub fn by_name(name: &str) -> Option<AdminCmd> {
        match name {
            "stats" => Some(AdminCmd::Stats),
            "metrics" => Some(AdminCmd::Metrics),
            _ => None,
        }
    }
}

/// Any decoded request-side frame: an inference request or an admin
/// command.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    Infer(RequestFrame),
    Admin { id: u64, cmd: AdminCmd },
}

fn malformed(e: anyhow::Error) -> ServeError {
    ServeError::Malformed(format!("{e:#}"))
}

fn f32s_to_json(xs: &[f32]) -> Value {
    Value::arr(xs.iter().map(|&x| Value::num(f64::from(x))).collect())
}

fn f32s_from_json(v: &Value) -> Result<Vec<f32>, ServeError> {
    v.as_arr()
        .map_err(malformed)?
        .iter()
        .map(|x| x.as_f64().map(|f| f as f32))
        .collect::<anyhow::Result<Vec<f32>>>()
        .map_err(malformed)
}

/// Encode one request frame (a single line, no trailing newline).
pub fn encode_request(id: u64, req: &InferRequest) -> String {
    let mut fields = vec![
        ("id", Value::num(id as f64)),
        ("model", Value::str(req.model.clone())),
        ("input", f32s_to_json(&req.input)),
        ("priority", Value::str(req.priority.name())),
    ];
    if let Some(d) = req.deadline {
        fields.push(("deadline_ms", Value::num(d.as_secs_f64() * 1e3)));
    }
    Value::obj(fields).compact()
}

/// Decode one request line; any structural problem is a
/// [`ServeError::Malformed`].
pub fn decode_request(line: &str) -> Result<RequestFrame, ServeError> {
    let v = Value::parse(line).map_err(malformed)?;
    let id = v.get("id").map_err(malformed)?.as_u64().map_err(malformed)?;
    // ids ride JSON numbers (f64) in replies; a string-encoded id above
    // 2^53 would be accepted here but corrupted on echo, so reject it
    if id > (1 << 53) {
        return Err(ServeError::Malformed(format!("id {id} exceeds 2^53")));
    }
    let model = v.get("model").map_err(malformed)?.as_str().map_err(malformed)?.to_string();
    let input = f32s_from_json(v.get("input").map_err(malformed)?)?;
    if input.iter().any(|x| !x.is_finite()) {
        return Err(ServeError::Malformed("non-finite input element".to_string()));
    }
    let priority = match v.opt("priority") {
        None => Priority::Normal,
        Some(p) => {
            let name = p.as_str().map_err(malformed)?;
            match Priority::by_name(name) {
                Some(priority) => priority,
                None => return Err(ServeError::Malformed(format!("unknown priority '{name}'"))),
            }
        }
    };
    let deadline = match v.opt("deadline_ms") {
        None => None,
        Some(d) => {
            let ms = d.as_f64().map_err(malformed)?;
            if !ms.is_finite() || ms < 0.0 {
                return Err(ServeError::Malformed(format!("bad deadline_ms {ms}")));
            }
            Some(Duration::from_secs_f64(ms.min(MAX_DEADLINE_MS) / 1e3))
        }
    };
    Ok(RequestFrame { id, request: InferRequest { model, input, priority, deadline } })
}

/// Decode one request-side line: an object with an `"admin"` key is an
/// admin frame, anything else must be an inference request.
pub fn decode_frame(line: &str) -> Result<Frame, ServeError> {
    let v = Value::parse(line).map_err(malformed)?;
    let Some(cmd) = v.opt("admin") else {
        return decode_request(line).map(Frame::Infer);
    };
    let id = v.get("id").map_err(malformed)?.as_u64().map_err(malformed)?;
    if id > (1 << 53) {
        return Err(ServeError::Malformed(format!("id {id} exceeds 2^53")));
    }
    let name = cmd.as_str().map_err(malformed)?;
    match AdminCmd::by_name(name) {
        Some(cmd) => Ok(Frame::Admin { id, cmd }),
        None => Err(ServeError::Malformed(format!("unknown admin command '{name}'"))),
    }
}

/// Encode one admin request frame.
pub fn encode_admin(id: u64, cmd: AdminCmd) -> String {
    Value::obj(vec![("id", Value::num(id as f64)), ("admin", Value::str(cmd.name()))]).compact()
}

/// Encode the reply to an [`AdminCmd::Stats`] frame: the per-model
/// counters keyed by registry name.
pub fn encode_stats(id: u64, stats: &BTreeMap<String, SessionStats>) -> String {
    let models = Value::Obj(stats.iter().map(|(name, st)| (name.clone(), st.to_json())).collect());
    Value::obj(vec![("id", Value::num(id as f64)), ("stats", models)]).compact()
}

/// Encode the reply to an [`AdminCmd::Metrics`] frame: the exposition
/// document as one JSON string (newlines escape cleanly).
pub fn encode_metrics(id: u64, text: &str) -> String {
    Value::obj(vec![("id", Value::num(id as f64)), ("metrics", Value::str(text))]).compact()
}

/// Encode one output frame.
pub fn encode_output(id: u64, output: &[f32]) -> String {
    Value::obj(vec![("id", Value::num(id as f64)), ("output", f32s_to_json(output))]).compact()
}

/// Encode one error frame (`id` is `null` when unrecoverable).
pub fn encode_error(id: Option<u64>, error: &ServeError) -> String {
    let id = match id {
        Some(id) => Value::num(id as f64),
        None => Value::Null,
    };
    Value::obj(vec![
        ("id", id),
        (
            "error",
            Value::obj(vec![
                ("kind", Value::str(error.kind())),
                ("message", Value::str(error.to_string())),
            ]),
        ),
    ])
    .compact()
}

/// Decode one reply line (output or error frame).
pub fn decode_response(line: &str) -> Result<ResponseFrame, ServeError> {
    let v = Value::parse(line).map_err(malformed)?;
    if let Some(err) = v.opt("error") {
        let id = match v.opt("id") {
            None | Some(Value::Null) => None,
            Some(x) => Some(x.as_u64().map_err(malformed)?),
        };
        let kind = err.get("kind").map_err(malformed)?.as_str().map_err(malformed)?;
        let message = err.get("message").map_err(malformed)?.as_str().map_err(malformed)?;
        return Ok(ResponseFrame::Error { id, error: ServeError::from_wire(kind, message) });
    }
    let id = v.get("id").map_err(malformed)?.as_u64().map_err(malformed)?;
    let output = f32s_from_json(v.get("output").map_err(malformed)?)?;
    Ok(ResponseFrame::Output { id, output })
}

/// Best-effort id recovery from a line that failed [`decode_request`], so
/// the error frame can still be correlated by the caller.
fn recover_id(line: &str) -> Option<u64> {
    Value::parse(line).ok().and_then(|v| v.opt("id").and_then(|x| x.as_u64().ok()))
}

/// What one connection did, as counted by the reply writer.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ConnStats {
    /// Output frames written.
    pub served: usize,
    /// Error frames written (admission rejections, executor faults, and
    /// malformed lines alike).
    pub errors: usize,
    /// Admin (`stats`/`metrics`) replies written.
    pub admin: usize,
}

/// A reply the writer thread still has to resolve and encode.  Admin
/// replies are rendered at *dequeue* time, not when the frame is read:
/// the writer has already resolved every earlier reply on the
/// connection, so a pipelined `stats` frame observes the inferences that
/// preceded it.
enum Pending {
    Ok(u64, Ticket),
    Err(Option<u64>, ServeError),
    Admin(u64, AdminCmd),
}

/// How the reply writer kills a connection's *read* half once its own
/// write half is dead.  Without this, a peer that closed its read side
/// but kept its write side open would park the connection thread in
/// `read_line` forever — replies have nowhere to go, but the reader
/// never learns that.  [`TcpStream`] shuts the socket's read half down;
/// streams with no read half to kill (stdio, in-memory test buffers) use
/// [`NoReadShutdown`] and rely on the dead-flag check between lines.
pub trait ReadShutdown: Sync {
    /// Best-effort: unblock the connection's parked reader.
    fn shutdown_read(&self);
}

/// No-op [`ReadShutdown`] for streams without a kickable read half.
pub struct NoReadShutdown;

impl ReadShutdown for NoReadShutdown {
    fn shutdown_read(&self) {}
}

impl ReadShutdown for TcpStream {
    fn shutdown_read(&self) {
        let _ = self.shutdown(Shutdown::Read);
    }
}

/// [`serve_connection_with`] without a read half to kill: writer death
/// is still detected, but only between lines ([`NoReadShutdown`]).
pub fn serve_connection<R: BufRead, W: Write + Send>(
    server: &Server,
    reader: R,
    writer: W,
) -> io::Result<ConnStats> {
    serve_connection_with(server, reader, writer, &NoReadShutdown)
}

/// Serve one duplex stream until the reader hits EOF (or the writer's
/// peer goes away): decode each line, submit it to the server, and write
/// the reply frame as soon as its ticket resolves.  Requests are
/// submitted as they arrive — not one-at-a-time — so pipelined frames
/// coalesce in the per-model micro-batchers exactly like in-process
/// submits; replies are written in request order (ids still echo, so
/// clients need not rely on that).
///
/// The pending-reply channel is **bounded** ([`PENDING_REPLY_CAP`]): a
/// pipeliner that outruns the writer parks the reader in `send` instead
/// of growing an unbounded queue, and the stalled reader propagates
/// backpressure to the peer's TCP window.  On writer death the writer
/// thread raises the dead flag *and* calls
/// [`ReadShutdown::shutdown_read`] on `read_shutdown`, so a reader
/// parked in `read_line` unblocks immediately instead of waiting for
/// the peer to send another line.
pub fn serve_connection_with<R: BufRead, W: Write + Send, S: ReadShutdown + ?Sized>(
    server: &Server,
    mut reader: R,
    writer: W,
    read_shutdown: &S,
) -> io::Result<ConnStats> {
    let wire = server.wire_counters();
    wire.connections.fetch_add(1, Ordering::Relaxed);
    wire.active.fetch_add(1, Ordering::Relaxed);
    let (tx, rx) = mpsc::sync_channel::<Pending>(PENDING_REPLY_CAP);
    let dead = AtomicBool::new(false);
    let dead_ref = &dead;
    let result = std::thread::scope(|scope| {
        let writer_handle = scope.spawn(move || -> io::Result<ConnStats> {
            let mut writer = writer;
            let mut stats = ConnStats::default();
            for pending in rx {
                let line = match pending {
                    Pending::Admin(id, cmd) => {
                        stats.admin += 1;
                        wire.admin.fetch_add(1, Ordering::Relaxed);
                        match cmd {
                            AdminCmd::Stats => encode_stats(id, &server.stats()),
                            AdminCmd::Metrics => encode_metrics(id, &server.metrics_text()),
                        }
                    }
                    Pending::Ok(id, ticket) => match ticket.wait() {
                        Ok(y) => {
                            stats.served += 1;
                            wire.served.fetch_add(1, Ordering::Relaxed);
                            encode_output(id, &y)
                        }
                        Err(e) => {
                            stats.errors += 1;
                            wire.record_error(e.kind());
                            encode_error(Some(id), &e)
                        }
                    },
                    Pending::Err(id, e) => {
                        stats.errors += 1;
                        wire.record_error(e.kind());
                        encode_error(id, &e)
                    }
                };
                if let Err(e) = writeln!(writer, "{line}").and_then(|()| writer.flush()) {
                    dead_ref.store(true, Ordering::Release);
                    read_shutdown.shutdown_read();
                    return Err(e);
                }
            }
            Ok(stats)
        });
        let mut line = String::new();
        let reader_result: io::Result<()> = loop {
            if dead.load(Ordering::Acquire) {
                break Ok(());
            }
            line.clear();
            match reader.read_line(&mut line) {
                Ok(0) => break Ok(()),
                Ok(_) => {}
                // a read failure after the writer killed our read half is
                // the shutdown itself, not a peer error
                Err(_) if dead.load(Ordering::Acquire) => break Ok(()),
                Err(e) => break Err(e),
            }
            let frame = line.trim();
            if frame.is_empty() {
                continue;
            }
            wire.frames.fetch_add(1, Ordering::Relaxed);
            let pending = match decode_frame(frame) {
                Ok(Frame::Admin { id, cmd }) => Pending::Admin(id, cmd),
                Ok(Frame::Infer(f)) => match server.submit(f.request) {
                    Ok(ticket) => Pending::Ok(f.id, ticket),
                    Err(e) => Pending::Err(Some(f.id), e),
                },
                Err(e) => {
                    wire.malformed.fetch_add(1, Ordering::Relaxed);
                    Pending::Err(recover_id(frame), e)
                }
            };
            // blocks when the channel is full: this is the backpressure
            if tx.send(pending).is_err() {
                break Ok(()); // writer bailed; its error is reported below
            }
        };
        drop(tx);
        let written = writer_handle
            .join()
            .map_err(|_| io::Error::other("wire writer thread panicked"))?;
        reader_result?;
        written
    });
    wire.active.fetch_sub(1, Ordering::Relaxed);
    result
}

/// Decrements the shared active-connection count when a connection
/// thread finishes (or its spawn fails), however it exits.
struct ActiveGuard {
    active: Arc<AtomicUsize>,
}

impl Drop for ActiveGuard {
    fn drop(&mut self) {
        self.active.fetch_sub(1, Ordering::AcqRel);
    }
}

/// Accept TCP connections and serve each on its own thread, at most
/// `max_active` of them live at once: an accept past that bound is
/// **shed** — answered with a single id-less `overloaded` error frame
/// (`retry_after_ms` = [`SHED_RETRY_MS`]) and closed — so the pool is
/// bounded instead of thread-per-connection-unbounded.  Transient
/// `accept` failures are retried with a short backoff (counted in
/// `accept_retries`) rather than tearing down the listener; only
/// [`ACCEPT_ERROR_LIMIT`] consecutive failures return the error.  A
/// connection whose setup fails (`try_clone` / thread spawn) is counted
/// in `conn_setup_failed`, never silently dropped.
///
/// `max_conns` bounds how many connections (served *or* shed) are
/// accepted before returning (joining the spawned threads) — `None`
/// serves forever.  Bind the listener yourself so `127.0.0.1:0` tests
/// can read the chosen port.
pub fn serve_tcp(
    server: &Arc<Server>,
    listener: TcpListener,
    max_conns: Option<usize>,
    max_active: usize,
) -> io::Result<()> {
    if max_conns == Some(0) {
        return Ok(());
    }
    let max_active = max_active.max(1);
    let wire = Arc::clone(server.wire_counters());
    let active = Arc::new(AtomicUsize::new(0));
    let mut accepted = 0usize;
    let mut error_streak = 0u32;
    let mut handles = Vec::new();
    for conn in listener.incoming() {
        let mut stream = match conn {
            Ok(stream) => {
                error_streak = 0;
                stream
            }
            Err(e) => {
                error_streak += 1;
                wire.accept_retries.fetch_add(1, Ordering::Relaxed);
                if error_streak >= ACCEPT_ERROR_LIMIT {
                    return Err(e);
                }
                std::thread::sleep(ACCEPT_RETRY_BACKOFF * error_streak);
                continue;
            }
        };
        accepted += 1;
        if active.load(Ordering::Acquire) >= max_active {
            wire.shed_conns.fetch_add(1, Ordering::Relaxed);
            wire.record_error("overloaded");
            let frame =
                encode_error(None, &ServeError::Overloaded { retry_after_ms: SHED_RETRY_MS });
            let _ = writeln!(stream, "{frame}").and_then(|()| stream.flush());
            drop(stream); // closes: one frame, then EOF
            if Some(accepted) == max_conns {
                break;
            }
            continue;
        }
        // count before the thread is live so the *next* accept already
        // sees this connection against the bound
        active.fetch_add(1, Ordering::AcqRel);
        let guard = ActiveGuard { active: Arc::clone(&active) };
        let server = Arc::clone(server);
        let spawned = std::thread::Builder::new()
            .name("prunemap-wire-conn".to_string())
            .spawn(move || {
                let _guard = guard;
                let reader = match stream.try_clone() {
                    Ok(read_half) => BufReader::new(read_half),
                    Err(_) => {
                        server.wire_counters().conn_setup_failed.fetch_add(1, Ordering::Relaxed);
                        return;
                    }
                };
                // the stream is both the reply writer and the read-half
                // kill switch for writer death
                let _ = serve_connection_with(&server, reader, &stream, &stream);
            });
        match spawned {
            Ok(handle) => {
                if max_conns.is_some() {
                    handles.push(handle);
                }
            }
            // the unspawned closure just dropped, releasing the guard
            Err(_) => {
                wire.conn_setup_failed.fetch_add(1, Ordering::Relaxed);
            }
        }
        if Some(accepted) == max_conns {
            break;
        }
    }
    for handle in handles {
        let _ = handle.join();
    }
    Ok(())
}

/// The caller side of the protocol over TCP: assigns ids, pipelines
/// requests ([`Client::send`]), and matches replies back by id
/// ([`Client::wait`] stashes out-of-order arrivals).  Used by the
/// `multi_model_serve` example and the `hotpaths` bench.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
    stashed: BTreeMap<u64, Result<Vec<f32>, ServeError>>,
}

impl Client {
    /// Connect to a `serve_tcp` endpoint.
    pub fn connect<A: ToSocketAddrs>(addr: A) -> io::Result<Client> {
        let writer = TcpStream::connect(addr)?;
        let reader = BufReader::new(writer.try_clone()?);
        Ok(Client { reader, writer, next_id: 1, stashed: BTreeMap::new() })
    }

    /// Write one request frame without waiting; returns the assigned id.
    pub fn send(&mut self, req: &InferRequest) -> io::Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        writeln!(self.writer, "{}", encode_request(id, req))?;
        self.writer.flush()?;
        Ok(id)
    }

    /// Read the next reply frame off the wire.
    pub fn recv(&mut self) -> io::Result<(Option<u64>, Result<Vec<f32>, ServeError>)> {
        let mut line = String::new();
        loop {
            line.clear();
            if self.reader.read_line(&mut line)? == 0 {
                return Err(io::ErrorKind::UnexpectedEof.into());
            }
            if line.trim().is_empty() {
                continue;
            }
            return match decode_response(line.trim()) {
                Ok(ResponseFrame::Output { id, output }) => Ok((Some(id), Ok(output))),
                Ok(ResponseFrame::Error { id, error }) => Ok((id, Err(error))),
                Err(e) => Err(io::Error::new(io::ErrorKind::InvalidData, e.to_string())),
            };
        }
    }

    /// Block for the reply to `id`, stashing any other replies that
    /// arrive first (they resolve later `wait` calls without re-reading
    /// the wire).
    pub fn wait(&mut self, id: u64) -> io::Result<Result<Vec<f32>, ServeError>> {
        if let Some(served) = self.stashed.remove(&id) {
            return Ok(served);
        }
        loop {
            let (got, served) = self.recv()?;
            let Some(got) = got else {
                // an id-less error frame means the peer could not even
                // attribute the failure; nothing further on this
                // connection can be matched reliably
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    served.err().map(|e| e.to_string()).unwrap_or_default(),
                ));
            };
            if got == id {
                return Ok(served);
            }
            self.stashed.insert(got, served);
        }
    }

    /// Blocking convenience: [`Client::send`] + [`Client::wait`].
    pub fn infer(&mut self, req: &InferRequest) -> io::Result<Result<Vec<f32>, ServeError>> {
        let id = self.send(req)?;
        self.wait(id)
    }

    /// Issue an admin frame and block for its reply object, stashing any
    /// inference replies that arrive first (they resolve later
    /// [`Client::wait`] calls without re-reading the wire).
    pub fn admin(&mut self, cmd: AdminCmd) -> io::Result<Value> {
        let id = self.next_id;
        self.next_id += 1;
        writeln!(self.writer, "{}", encode_admin(id, cmd))?;
        self.writer.flush()?;
        let mut line = String::new();
        loop {
            line.clear();
            if self.reader.read_line(&mut line)? == 0 {
                return Err(io::ErrorKind::UnexpectedEof.into());
            }
            let frame = line.trim();
            if frame.is_empty() {
                continue;
            }
            let v = Value::parse(frame).map_err(invalid_data)?;
            if v.opt("id").and_then(|x| x.as_u64().ok()) == Some(id) {
                return Ok(v);
            }
            match decode_response(frame) {
                Ok(ResponseFrame::Output { id, output }) => {
                    self.stashed.insert(id, Ok(output));
                }
                Ok(ResponseFrame::Error { id: Some(id), error }) => {
                    self.stashed.insert(id, Err(error));
                }
                _ => {
                    return Err(io::Error::new(
                        io::ErrorKind::InvalidData,
                        "unmatchable reply while waiting for an admin frame",
                    ));
                }
            }
        }
    }

    /// Fetch the per-model stats object (`{"<model>": {counters...}}`).
    pub fn stats(&mut self) -> io::Result<Value> {
        let v = self.admin(AdminCmd::Stats)?;
        v.get("stats").map(Value::clone).map_err(invalid_data)
    }

    /// Fetch the Prometheus text exposition document over the wire.
    pub fn metrics_text(&mut self) -> io::Result<String> {
        let v = self.admin(AdminCmd::Metrics)?;
        let text = v.get("metrics").and_then(Value::as_str).map_err(invalid_data)?;
        Ok(text.to_string())
    }
}

fn invalid_data(e: anyhow::Error) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, format!("{e:#}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accuracy::Assignment;
    use crate::serve::{ModelRegistry, PreparedModel};
    use std::io::Cursor;

    #[test]
    fn request_frames_roundtrip() {
        let req = InferRequest::new("m", vec![0.25, -1.5, 3.0])
            .high()
            .deadline(Duration::from_millis(5));
        let line = encode_request(7, &req);
        let back = decode_request(&line).unwrap();
        assert_eq!(back.id, 7);
        assert_eq!(back.request, req);
        // optional fields default
        let bare = decode_request(r#"{"id":1,"model":"m","input":[1]}"#).unwrap();
        assert_eq!(bare.request.priority, Priority::Normal);
        assert_eq!(bare.request.deadline, None);
    }

    #[test]
    fn response_frames_roundtrip() {
        let out = encode_output(3, &[0.5, -2.25]);
        assert_eq!(
            decode_response(&out).unwrap(),
            ResponseFrame::Output { id: 3, output: vec![0.5, -2.25] }
        );
        let err = encode_error(Some(4), &ServeError::UnknownModel("x".into()));
        match decode_response(&err).unwrap() {
            ResponseFrame::Error { id: Some(4), error } => {
                assert_eq!(error.kind(), "unknown_model")
            }
            other => panic!("bad decode: {other:?}"),
        }
        let anon = encode_error(None, &ServeError::Malformed("junk".into()));
        assert!(matches!(
            decode_response(&anon).unwrap(),
            ResponseFrame::Error { id: None, error: ServeError::Malformed(_) }
        ));
    }

    #[test]
    fn malformed_requests_are_typed_errors() {
        for bad in [
            "not json",
            "{}",
            r#"{"id":1}"#,
            r#"{"id":1,"model":"m"}"#,
            r#"{"id":1,"model":"m","input":"xs"}"#,
            r#"{"id":1,"model":"m","input":[1],"priority":"urgent"}"#,
            r#"{"id":1,"model":"m","input":[1],"deadline_ms":-2}"#,
            r#"{"id":-1,"model":"m","input":[1]}"#,
            // string-encoded ids above 2^53 would corrupt on echo
            r#"{"id":"18446744073709551615","model":"m","input":[1]}"#,
        ] {
            match decode_request(bad) {
                Err(ServeError::Malformed(_)) => {}
                other => panic!("'{bad}' should be malformed, got {other:?}"),
            }
        }
        assert_eq!(recover_id(r#"{"id":9,"model":3}"#), Some(9));
        assert_eq!(recover_id("not json"), None);
    }

    #[test]
    fn serve_connection_answers_frames_in_memory() {
        let registry = ModelRegistry::new();
        let prepared = PreparedModel::builder()
            .model("proxy")
            .assignments(
                crate::models::zoo::proxy_cnn()
                    .layers
                    .iter()
                    .map(|_| Assignment::dense())
                    .collect(),
            )
            .seed(5)
            .build()
            .unwrap();
        let n = prepared.input_len();
        registry.insert("proxy", prepared.clone());
        let server = Server::builder(registry).threads(1).build();

        let good = InferRequest::new("proxy", vec![0.1; n]);
        let unknown = InferRequest::new("ghost", vec![0.1; n]);
        let frames = format!(
            "{}\n\n{}\nnot json\n{}\n",
            encode_request(1, &good),
            encode_request(2, &unknown),
            encode_request(3, &good),
        );
        let mut replies: Vec<u8> = Vec::new();
        let stats =
            serve_connection(&server, Cursor::new(frames.as_bytes()), &mut replies).unwrap();
        assert_eq!(stats, ConnStats { served: 2, errors: 2, admin: 0 });

        let text = String::from_utf8(replies).unwrap();
        let decoded: Vec<ResponseFrame> =
            text.lines().map(|l| decode_response(l).unwrap()).collect();
        assert_eq!(decoded.len(), 4);
        // in-process truth for the same input
        let expect = prepared.session().threads(1).build().infer(vec![0.1; n]).unwrap();
        match &decoded[0] {
            ResponseFrame::Output { id: 1, output } => assert_eq!(output, &expect),
            other => panic!("frame 1: {other:?}"),
        }
        assert!(matches!(
            &decoded[1],
            ResponseFrame::Error { id: Some(2), error: ServeError::UnknownModel(_) }
        ));
        assert!(matches!(
            &decoded[2],
            ResponseFrame::Error { id: None, error: ServeError::Malformed(_) }
        ));
        match &decoded[3] {
            ResponseFrame::Output { id: 3, output } => assert_eq!(output, &expect),
            other => panic!("frame 3: {other:?}"),
        }
    }

    #[test]
    fn admin_frames_decode_and_roundtrip() {
        assert_eq!(
            decode_frame(r#"{"id":2,"admin":"stats"}"#).unwrap(),
            Frame::Admin { id: 2, cmd: AdminCmd::Stats }
        );
        assert_eq!(
            decode_frame(&encode_admin(9, AdminCmd::Metrics)).unwrap(),
            Frame::Admin { id: 9, cmd: AdminCmd::Metrics }
        );
        // an inference line still decodes as an inference frame
        let line = encode_request(1, &InferRequest::new("m", vec![0.5]));
        assert!(matches!(decode_frame(&line).unwrap(), Frame::Infer(f) if f.id == 1));
        // unknown commands and missing ids are malformed, not panics
        for bad in [r#"{"id":4,"admin":"reboot"}"#, r#"{"admin":"stats"}"#] {
            match decode_frame(bad) {
                Err(ServeError::Malformed(_)) => {}
                other => panic!("'{bad}' should be malformed, got {other:?}"),
            }
        }
        for (cmd, name) in [(AdminCmd::Stats, "stats"), (AdminCmd::Metrics, "metrics")] {
            assert_eq!(cmd.name(), name);
            assert_eq!(AdminCmd::by_name(name), Some(cmd));
        }
        assert_eq!(AdminCmd::by_name("reboot"), None);
    }

    #[test]
    fn admin_frames_share_the_connection_and_see_prior_replies() {
        let registry = ModelRegistry::new();
        let prepared = PreparedModel::builder()
            .model("proxy")
            .assignments(
                crate::models::zoo::proxy_cnn()
                    .layers
                    .iter()
                    .map(|_| Assignment::dense())
                    .collect(),
            )
            .seed(5)
            .build()
            .unwrap();
        let n = prepared.input_len();
        registry.insert("proxy", prepared);
        let server = Server::builder(registry).threads(1).build();

        let frames = format!(
            "{}\n{}\n{}\n{}\n",
            encode_request(1, &InferRequest::new("proxy", vec![0.1; n])),
            encode_admin(2, AdminCmd::Stats),
            encode_admin(3, AdminCmd::Metrics),
            r#"{"id":4,"admin":"reboot"}"#,
        );
        let mut replies: Vec<u8> = Vec::new();
        let stats =
            serve_connection(&server, Cursor::new(frames.as_bytes()), &mut replies).unwrap();
        assert_eq!(stats, ConnStats { served: 1, errors: 1, admin: 2 });

        let text = String::from_utf8(replies).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(matches!(
            decode_response(lines[0]).unwrap(),
            ResponseFrame::Output { id: 1, .. }
        ));
        // stats render at dequeue time, so the inference above is visible
        let stats_frame = Value::parse(lines[1]).unwrap();
        assert_eq!(stats_frame.get("id").unwrap().as_u64().unwrap(), 2);
        let proxy = stats_frame.get("stats").unwrap().get("proxy").unwrap();
        assert_eq!(proxy.get("requests").unwrap().as_f64().unwrap(), 1.0);
        // the metrics reply carries a parseable Prometheus document
        let metrics_frame = Value::parse(lines[2]).unwrap();
        assert_eq!(metrics_frame.get("id").unwrap().as_u64().unwrap(), 3);
        let doc = metrics_frame.get("metrics").unwrap().as_str().unwrap();
        let fams = crate::telemetry::parse_exposition(doc).unwrap();
        assert!(fams.contains_key("prunemap_requests_total"), "{doc}");
        assert!(fams.contains_key("prunemap_wire_frames_total"), "{doc}");
        // an unknown admin command is malformed with the id echoed
        assert!(matches!(
            decode_response(lines[3]).unwrap(),
            ResponseFrame::Error { id: Some(4), error: ServeError::Malformed(_) }
        ));
        // the shared wire counters saw the whole connection
        let w = server.wire_counters().snapshot();
        assert_eq!(w.connections, 1);
        assert_eq!(w.active, 0, "active connections settle back to zero");
        assert_eq!(w.frames, 4);
        assert_eq!(w.served, 1);
        assert_eq!(w.admin, 2);
        assert_eq!(w.malformed, 1);
        assert_eq!(w.errors, 1);
    }
}
