//! # prunemap
//!
//! A full-system reproduction of *"Automatic Mapping of the Best-Suited DNN
//! Pruning Schemes for Real-Time Mobile Acceleration"* (Gong, Yuan, et al.,
//! ACM TODAES 2021) as a three-layer Rust + JAX + Pallas stack.
//!
//! Layer 3 (this crate) is the paper's system contribution: the five pruning
//! regularities, the reweighted dynamic-regularization pruning algorithm,
//! the BCS sparse format + the batched multi-threaded sparse execution
//! engine that runs it ([`sparse::exec`]), compiler optimizations (fusion,
//! auto-tuning, DSL codegen), the mobile-SoC latency simulator that
//! substitutes for the paper's Samsung Galaxy test devices, the offline
//! latency model, and the two automatic pruning-scheme mapping methods
//! (rule-based and RL search-based).  The default request path is the
//! native engine ([`runtime::native`]); layers 1/2 (Pallas kernels + JAX
//! model) are AOT-lowered to HLO text and executed over PJRT when built
//! with `--cfg pjrt` — Python is never on the request path.
//!
//! [`serve`] is the deployment surface on top: a multi-model [`Server`]
//! front door (named-artifact registry, typed requests, priority lanes,
//! deadline admission, a line-JSON wire protocol over TCP/stdio) layered
//! over the compile-once/serve-many [`Session`] micro-batcher, with
//! outputs bit-identical to solo runs.
//!
//! Start at [`mapping`] for the paper's headline contribution, or run
//! `cargo run --release -- table4` to regenerate the paper's main table.
//!
//! [`Server`]: serve::Server
//! [`Session`]: serve::Session

pub mod accuracy;
pub mod analysis;
pub mod bench;
pub mod compiler;
pub mod coordinator;
pub mod experiments;
pub mod latmodel;
pub mod mapping;
pub mod models;
pub mod pruning;
pub mod report;
pub mod reweighted;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod simulator;
pub mod sparse;
pub mod telemetry;
pub mod tensor;
pub mod train;

pub mod util;

pub use anyhow::Result;
