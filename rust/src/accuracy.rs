//! Analytic accuracy model — the substitution for full CIFAR/ImageNet/COCO
//! training runs (DESIGN.md §2).
//!
//! Both mapping methods consume *accuracy deltas between pruning schemes*,
//! not absolute accuracies.  This model encodes the paper's empirically
//! established mechanisms, with constants calibrated against the paper's
//! own reported numbers (Tables 2-5, Figs. 5/7):
//!
//! * damage grows with pruned fraction, superlinearly near full sparsity
//!   (`sev(p) = -p ln(1-p)`);
//! * finer granularity hurts less: unstructured < block (growing with
//!   block size) < structured (Fig. 5);
//! * pattern-based pruning beats block-punched on *hard* datasets (its
//!   Gaussian/ELoG shapes aid feature extraction) and loses on *easy*
//!   ones where acceleration-friendlier blocks cost nothing (Fig. 7,
//!   Remark 1);
//! * depthwise layers are hypersensitive (Table 3);
//! * mild pruning *improves* easy-dataset accuracy (over-fitting
//!   mitigation), saturating with overall sparsity.
//!
//! The live counterpart — one-shot prune + masked retrain of the proxy CNN
//! through the AOT train-step — lives in [`crate::train`] and is exercised
//! by the end-to-end example.

use crate::models::{Dataset, LayerSpec, ModelSpec};
use crate::pruning::Scheme;

/// Per-layer pruning assignment: the output of a mapping method.
#[derive(Debug, Clone, Copy)]
pub struct Assignment {
    pub scheme: Scheme,
    pub compression: f32,
}

impl Assignment {
    pub fn dense() -> Assignment {
        Assignment { scheme: Scheme::None, compression: 1.0 }
    }
}

/// Dataset-level constants.
struct DatasetParams {
    /// Damage scale (fraction accuracy per unit damage).
    a: f32,
    /// Over-fitting-mitigation bonus ceiling.
    bonus: f32,
}

fn params(ds: Dataset) -> DatasetParams {
    match ds {
        Dataset::Cifar10 => DatasetParams { a: 0.004, bonus: 0.013 },
        Dataset::Cifar100 => DatasetParams { a: 0.009, bonus: 0.008 },
        Dataset::ImageNet => DatasetParams { a: 0.006, bonus: 0.002 },
        Dataset::Coco => DatasetParams { a: 0.040, bonus: 0.004 },
        Dataset::Synthetic => DatasetParams { a: 0.004, bonus: 0.010 },
    }
}

/// Severity of pruning fraction p: superlinear blow-up approaching 1.
fn sev(p: f32) -> f32 {
    let p = p.clamp(0.0, 0.995);
    -p * (1.0 - p).ln()
}

/// Granularity cost multiplier (lower = gentler on accuracy).
pub fn granularity(scheme: &Scheme, layer: &LayerSpec, ds: Dataset) -> f32 {
    // DW layers hold ~2% of weights but ~33% of activations and have no
    // cross-filter redundancy (one kernel per input channel, §5.2.4), so
    // their per-parameter damage is orders of magnitude higher — this is
    // what makes pruning them a bad deal (Table 3).
    let dw_mult = if layer.is_3x3_dw() { 120.0 } else { 1.0 };
    let base = match scheme {
        Scheme::None => 0.0,
        Scheme::Unstructured => 0.75,
        Scheme::Pattern => {
            if ds.is_hard() {
                0.95 // Gaussian/ELoG shapes help feature extraction
            } else {
                1.55
            }
        }
        Scheme::Block { bp, bq } => block_granularity((bp * bq) as f32),
        Scheme::BlockPunched { bf, bc } => block_granularity((bf * bc) as f32),
        Scheme::StructuredRow | Scheme::StructuredColumn => 2.60,
    };
    base * dw_mult
}

/// Block granularity grows slowly (log) with block area: 1x1 ≈
/// unstructured, whole-matrix ≈ structured.
fn block_granularity(elems: f32) -> f32 {
    let l = elems.max(1.0).log2();
    (0.78 + 0.062 * l).min(2.5)
}

/// Accuracy drop (fraction, e.g. 0.003 = 0.3%) of a pruned model.
/// Negative = improvement.  For COCO the unit is mAP fraction.
pub fn acc_drop(model: &ModelSpec, assigns: &[Assignment]) -> f32 {
    assert_eq!(model.layers.len(), assigns.len());
    let p = params(model.dataset);
    let total_params: f32 = model.total_params() as f32;
    let mut damage = 0.0;
    let mut pruned_weights = 0.0;
    for (layer, a) in model.layers.iter().zip(assigns) {
        if matches!(a.scheme, Scheme::None) || a.compression <= 1.0 {
            continue;
        }
        let frac_pruned = 1.0 - 1.0 / a.compression;
        let wfrac = layer.params() as f32 / total_params;
        damage += wfrac * granularity(&a.scheme, layer, model.dataset) * sev(frac_pruned);
        pruned_weights += wfrac * frac_pruned;
    }
    let bonus = p.bonus * (1.0 - (-4.0 * pruned_weights).exp());
    p.a * damage - bonus
}

/// Absolute accuracy after pruning (top-1 for classification, mAP for COCO).
pub fn accuracy(model: &ModelSpec, assigns: &[Assignment]) -> f32 {
    model.baseline_acc() - acc_drop(model, assigns)
}

/// Overall compression rate over *pruned-eligible* layers (the paper's
/// Table 4 convention: parameter reduction of CONV layers, or of the
/// whole model for YOLO's Table 2).
pub fn overall_compression(model: &ModelSpec, assigns: &[Assignment], conv_only: bool) -> f32 {
    let mut total = 0.0f64;
    let mut kept = 0.0f64;
    for (layer, a) in model.layers.iter().zip(assigns) {
        if conv_only && layer.kind == crate::models::LayerKind::Fc {
            continue;
        }
        let p = layer.params() as f64;
        total += p;
        kept += p / a.compression.max(1.0) as f64;
    }
    (total / kept.max(1.0)) as f32
}

/// Remaining MACs after pruning (Table 4/5 "MACs" column).
pub fn remaining_macs(model: &ModelSpec, assigns: &[Assignment]) -> f64 {
    model
        .layers
        .iter()
        .zip(assigns)
        .map(|(l, a)| l.macs() as f64 / a.compression.max(1.0) as f64)
        .sum()
}

/// Per-layer automatic compression under a damage budget — the spec-level
/// stand-in for what the reweighted regularization discovers during
/// training: easy datasets tolerate ~12x per layer, hard ones ~4-8x, and
/// gentler granularities earn higher rates at equal budget.
pub fn auto_compression(layer: &LayerSpec, scheme: &Scheme, ds: Dataset) -> f32 {
    if matches!(scheme, Scheme::None) {
        return 1.0;
    }
    let budget = match ds {
        Dataset::Cifar10 | Dataset::Synthetic => 0.013,
        Dataset::Cifar100 => 0.011,
        Dataset::ImageNet => 0.011,
        Dataset::Coco => 0.012,
    };
    let g = granularity(scheme, layer, ds) * params(ds).a;
    // tiny layers can't spare capacity: keep at least ~256 weights (the
    // paper's targets are multi-million-parameter layers; first convs and
    // classifier heads are barely pruned in practice)
    let size_cap = (layer.params() as f32 / 256.0).max(1.0);
    let grid = [16.0f32, 14.0, 12.0, 10.0, 8.0, 7.0, 6.0, 5.0, 4.0, 3.5, 3.0, 2.5, 2.0, 1.5];
    for &c in &grid {
        if c > size_cap {
            continue;
        }
        let p = 1.0 - 1.0 / c;
        if g * sev(p) <= budget {
            return c;
        }
    }
    1.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    fn assign_all(model: &ModelSpec, scheme: Scheme, c: f32) -> Vec<Assignment> {
        model
            .layers
            .iter()
            .map(|l| {
                if scheme.applicable(l) && !l.is_3x3_dw() {
                    Assignment { scheme, compression: c }
                } else {
                    Assignment::dense()
                }
            })
            .collect()
    }

    #[test]
    fn dense_model_has_zero_drop() {
        let m = zoo::resnet50(Dataset::Cifar10);
        let assigns: Vec<Assignment> = m.layers.iter().map(|_| Assignment::dense()).collect();
        assert_eq!(acc_drop(&m, &assigns), -0.0);
        assert!((accuracy(&m, &assigns) - m.baseline_acc()).abs() < 1e-6);
    }

    #[test]
    fn cifar_block_near_zero_drop_at_high_compression() {
        // Table 4: ResNet-50 CIFAR-10 block 11.51x -> +0.1% drop
        let m = zoo::resnet50(Dataset::Cifar10);
        let assigns = assign_all(&m, Scheme::BlockPunched { bf: 4, bc: 16 }, 11.5);
        let d = acc_drop(&m, &assigns) * 100.0;
        assert!((-0.6..0.8).contains(&d), "drop {d}%");
    }

    #[test]
    fn cifar_mild_pruning_improves() {
        // Table 4 PatDNN rows: low-compression pruning improves CIFAR acc
        let m = zoo::resnet50(Dataset::Cifar10);
        let mut assigns: Vec<Assignment> = m.layers.iter().map(|_| Assignment::dense()).collect();
        for (i, l) in m.layers.iter().enumerate() {
            if l.is_3x3_conv() {
                assigns[i] = Assignment { scheme: Scheme::Pattern, compression: 3.0 };
            }
        }
        let d = acc_drop(&m, &assigns) * 100.0;
        assert!(d < 0.0, "expected improvement, got {d}%");
    }

    #[test]
    fn imagenet_moderate_drop() {
        // Table 4: ResNet-50 ImageNet hybrid 4.4x -> ~0.1-0.3% drop
        let m = zoo::resnet50(Dataset::ImageNet);
        let assigns: Vec<Assignment> = m
            .layers
            .iter()
            .map(|l| {
                if l.is_3x3_conv() {
                    Assignment { scheme: Scheme::Pattern, compression: 8.0 }
                } else if l.kind == crate::models::LayerKind::Conv {
                    Assignment {
                        scheme: Scheme::BlockPunched { bf: 4, bc: 16 },
                        compression: 3.5,
                    }
                } else {
                    Assignment::dense()
                }
            })
            .collect();
        let d = acc_drop(&m, &assigns) * 100.0;
        assert!((-0.2..1.0).contains(&d), "drop {d}%");
    }

    #[test]
    fn fig7_pattern_vs_block_dataset_dependence() {
        // same 3x3-only pruning, both datasets
        for (ds, pattern_wins) in [(Dataset::ImageNet, true), (Dataset::Cifar10, false)] {
            let m = zoo::resnet18(ds);
            let mut pat: Vec<Assignment> =
                m.layers.iter().map(|_| Assignment::dense()).collect();
            let mut blk = pat.clone();
            for (i, l) in m.layers.iter().enumerate() {
                if l.is_3x3_conv() {
                    pat[i] = Assignment { scheme: Scheme::Pattern, compression: 6.0 };
                    blk[i] = Assignment {
                        scheme: Scheme::BlockPunched { bf: 4, bc: 16 },
                        compression: 6.0,
                    };
                }
            }
            let dp = acc_drop(&m, &pat);
            let db = acc_drop(&m, &blk);
            if pattern_wins {
                assert!(dp < db, "{ds:?}: pattern {dp} !< block {db}");
            } else {
                assert!(db <= dp, "{ds:?}: block {db} !<= pattern {dp}");
            }
        }
    }

    #[test]
    fn fig5_acc_decreases_with_block_size() {
        let m = zoo::resnet50(Dataset::ImageNet);
        let sizes = [(1, 1), (4, 4), (8, 16), (16, 32), (64, 128)];
        let drops: Vec<f32> = sizes
            .iter()
            .map(|&(a, b)| {
                acc_drop(&m, &assign_all(&m, Scheme::BlockPunched { bf: a, bc: b }, 6.0))
            })
            .collect();
        for w in drops.windows(2) {
            assert!(w[1] > w[0], "acc must fall with block size: {drops:?}");
        }
        // structured is the worst
        let st = acc_drop(&m, &assign_all(&m, Scheme::StructuredRow, 6.0));
        assert!(st > *drops.last().unwrap());
        // unstructured the best
        let un = acc_drop(&m, &assign_all(&m, Scheme::Unstructured, 6.0));
        assert!(un < drops[1]);
    }

    #[test]
    fn table2_yolo_orderings() {
        let m = zoo::yolov4();
        let st = acc_drop(&m, &assign_all(&m, Scheme::StructuredRow, 7.3)) * 100.0;
        let un = acc_drop(&m, &assign_all(&m, Scheme::Unstructured, 11.2)) * 100.0;
        let blk = acc_drop(&m, &assign_all(&m, Scheme::BlockPunched { bf: 4, bc: 16 }, 8.1)) * 100.0;
        // structured devastates mAP (paper: -17.9 points)
        assert!(st > 10.0, "structured drop {st}");
        // unstructured at higher compression stays mild (paper: -4.8)
        assert!((1.0..10.0).contains(&un), "unstructured drop {un}");
        // block lands between (paper: -6.0 at 8.1x)
        assert!(blk > un - 2.0 && blk < st, "block drop {blk}");
    }

    #[test]
    fn table3_dw_pruning_hurts() {
        let m = zoo::mobilenet_v2(Dataset::Cifar10);
        // baseline: 1x1 conv pruned only
        let base: Vec<Assignment> = m
            .layers
            .iter()
            .map(|l| {
                if l.kind == crate::models::LayerKind::Conv && l.kh == 1 {
                    Assignment {
                        scheme: Scheme::BlockPunched { bf: 4, bc: 16 },
                        compression: 7.2,
                    }
                } else {
                    Assignment::dense()
                }
            })
            .collect();
        // plus DW pruning at 2.22x
        let with_dw: Vec<Assignment> = m
            .layers
            .iter()
            .zip(&base)
            .map(|(l, a)| {
                if l.is_3x3_dw() {
                    Assignment {
                        scheme: Scheme::BlockPunched { bf: 4, bc: 16 },
                        compression: 2.22,
                    }
                } else {
                    *a
                }
            })
            .collect();
        let d0 = acc_drop(&m, &base) * 100.0;
        let d1 = acc_drop(&m, &with_dw) * 100.0;
        let extra = d1 - d0;
        // Table 3: -0.4 to -1.5% additional drop, tiny compression gain
        assert!((0.1..2.5).contains(&extra), "extra DW drop {extra}%");
        let c0 = overall_compression(&m, &base, false);
        let c1 = overall_compression(&m, &with_dw, false);
        assert!((c1 - c0) / c0 < 0.2, "DW pruning should barely move compression");
    }

    #[test]
    fn auto_compression_scales_with_dataset_and_granularity() {
        let conv1x1 = LayerSpec::conv("c", 1, 256, 256, 14, 1);
        let easy = auto_compression(&conv1x1, &Scheme::BlockPunched { bf: 4, bc: 16 }, Dataset::Cifar10);
        let hard = auto_compression(&conv1x1, &Scheme::BlockPunched { bf: 4, bc: 16 }, Dataset::ImageNet);
        assert!(easy > hard, "easy {easy} !> hard {hard}");
        assert!(easy >= 10.0, "easy {easy}");
        assert!((2.0..8.0).contains(&hard), "hard {hard}");
        // pattern earns a higher rate than coarse blocks on hard datasets
        let c3 = LayerSpec::conv("c", 3, 256, 256, 14, 1);
        let pat = auto_compression(&c3, &Scheme::Pattern, Dataset::ImageNet);
        let blk = auto_compression(&c3, &Scheme::BlockPunched { bf: 32, bc: 64 }, Dataset::ImageNet);
        assert!(pat > blk, "pattern {pat} !> big-block {blk}");
    }

    #[test]
    fn compression_accounting() {
        let m = zoo::proxy_cnn();
        let assigns: Vec<Assignment> = m
            .layers
            .iter()
            .map(|_| Assignment { scheme: Scheme::Unstructured, compression: 4.0 })
            .collect();
        let c = overall_compression(&m, &assigns, false);
        assert!((c - 4.0).abs() < 1e-3);
        let macs = remaining_macs(&m, &assigns);
        assert!((macs - m.total_macs() as f64 / 4.0).abs() < 1.0);
    }
}
