//! Offline latency model (paper §5.2.1).
//!
//! The rule-based mapping method never trains and never measures the target
//! DNN; it consults a table of layer-latency results built **once per
//! device** by timing test layers over a grid of settings — layer type,
//! feature size, channel count, pruning scheme, block size, compression.
//! The paper builds ~512 settings in ~30 minutes on a phone; we build ours
//! from the simulator in milliseconds, but the interface (build once, query
//! forever, JSON on disk) is the paper's.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::models::{LayerKind, LayerSpec};
use crate::pruning::Scheme;
use crate::simulator::{layer_latency_ms, DeviceProfile, ExecConfig};
use crate::util::json::Value;

/// Discretized layer template in the sweep.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LayerClass {
    Conv1x1,
    Conv3x3,
    Conv5x5,
    Conv7x7,
    Dw3x3,
    Fc,
}

impl LayerClass {
    pub fn of(layer: &LayerSpec) -> LayerClass {
        match layer.kind {
            LayerKind::Fc => LayerClass::Fc,
            LayerKind::DepthwiseConv => LayerClass::Dw3x3,
            LayerKind::Conv => match layer.kh {
                1 => LayerClass::Conv1x1,
                3 => LayerClass::Conv3x3,
                5 => LayerClass::Conv5x5,
                _ => LayerClass::Conv7x7,
            },
        }
    }

    fn tag(&self) -> &'static str {
        match self {
            LayerClass::Conv1x1 => "conv1x1",
            LayerClass::Conv3x3 => "conv3x3",
            LayerClass::Conv5x5 => "conv5x5",
            LayerClass::Conv7x7 => "conv7x7",
            LayerClass::Dw3x3 => "dw3x3",
            LayerClass::Fc => "fc",
        }
    }

    fn from_tag(s: &str) -> Option<LayerClass> {
        Some(match s {
            "conv1x1" => LayerClass::Conv1x1,
            "conv3x3" => LayerClass::Conv3x3,
            "conv5x5" => LayerClass::Conv5x5,
            "conv7x7" => LayerClass::Conv7x7,
            "dw3x3" => LayerClass::Dw3x3,
            "fc" => LayerClass::Fc,
            _ => return None,
        })
    }

    /// A representative test layer for the sweep.
    fn template(&self, feat: usize, ch: usize) -> LayerSpec {
        match self {
            LayerClass::Conv1x1 => LayerSpec::conv("t", 1, ch, ch, feat, 1),
            LayerClass::Conv3x3 => LayerSpec::conv("t", 3, ch, ch, feat, 1),
            LayerClass::Conv5x5 => LayerSpec::conv("t", 5, ch, ch, feat, 1),
            LayerClass::Conv7x7 => LayerSpec::conv("t", 7, ch, ch, feat, 1),
            LayerClass::Dw3x3 => LayerSpec::dwconv("t", 3, ch, feat, 1),
            LayerClass::Fc => LayerSpec::fc("t", feat * ch, ch),
        }
    }
}

/// Scheme discretization for table keys.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SchemeTag {
    Dense,
    Unstructured,
    Structured,
    Pattern,
    Block(usize, usize),
}

impl SchemeTag {
    pub fn of(scheme: &Scheme) -> SchemeTag {
        match scheme {
            Scheme::None => SchemeTag::Dense,
            Scheme::Unstructured => SchemeTag::Unstructured,
            Scheme::StructuredRow | Scheme::StructuredColumn => SchemeTag::Structured,
            Scheme::Pattern => SchemeTag::Pattern,
            Scheme::Block { bp, bq } => SchemeTag::Block(*bp, *bq),
            Scheme::BlockPunched { bf, bc } => SchemeTag::Block(*bf, *bc),
        }
    }

    fn to_scheme(self, class: LayerClass) -> Scheme {
        match self {
            SchemeTag::Dense => Scheme::None,
            SchemeTag::Unstructured => Scheme::Unstructured,
            SchemeTag::Structured => Scheme::StructuredRow,
            SchemeTag::Pattern => Scheme::Pattern,
            SchemeTag::Block(a, b) => {
                if class == LayerClass::Fc {
                    Scheme::Block { bp: a, bq: b }
                } else {
                    Scheme::BlockPunched { bf: a, bc: b }
                }
            }
        }
    }

    fn encode(&self) -> String {
        match self {
            SchemeTag::Dense => "dense".into(),
            SchemeTag::Unstructured => "unstructured".into(),
            SchemeTag::Structured => "structured".into(),
            SchemeTag::Pattern => "pattern".into(),
            SchemeTag::Block(a, b) => format!("block{a}x{b}"),
        }
    }

    fn decode(s: &str) -> Option<SchemeTag> {
        Some(match s {
            "dense" => SchemeTag::Dense,
            "unstructured" => SchemeTag::Unstructured,
            "structured" => SchemeTag::Structured,
            "pattern" => SchemeTag::Pattern,
            _ => {
                let rest = s.strip_prefix("block")?;
                let (a, b) = rest.split_once('x')?;
                SchemeTag::Block(a.parse().ok()?, b.parse().ok()?)
            }
        })
    }
}

/// One table key: (class, feature size, channels, scheme, compression*10).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SettingKey {
    pub class: LayerClass,
    pub feat: usize,
    pub ch: usize,
    pub scheme: SchemeTag,
    pub comp_x10: u32,
}

impl SettingKey {
    fn encode(&self) -> String {
        format!(
            "{}|{}|{}|{}|{}",
            self.class.tag(),
            self.feat,
            self.ch,
            self.scheme.encode(),
            self.comp_x10
        )
    }

    fn decode(s: &str) -> Option<SettingKey> {
        let parts: Vec<&str> = s.split('|').collect();
        if parts.len() != 5 {
            return None;
        }
        Some(SettingKey {
            class: LayerClass::from_tag(parts[0])?,
            feat: parts[1].parse().ok()?,
            ch: parts[2].parse().ok()?,
            scheme: SchemeTag::decode(parts[3])?,
            comp_x10: parts[4].parse().ok()?,
        })
    }
}

/// The sweep grids (the paper's "512 different layer settings" ballpark).
pub const FEAT_GRID: [usize; 4] = [7, 14, 28, 56];
pub const CH_GRID: [usize; 4] = [64, 128, 256, 512];
pub const COMP_GRID: [f32; 4] = [2.0, 4.0, 8.0, 16.0];

/// The offline latency table for one device.
#[derive(Debug, Clone)]
pub struct LatencyModel {
    pub device: String,
    entries: HashMap<SettingKey, f64>,
}

impl LatencyModel {
    /// Build by sweeping the setting grid on the simulator ("measuring the
    /// test models on the target device").
    pub fn build(dev: &DeviceProfile) -> LatencyModel {
        let mut entries = HashMap::new();
        let classes = [
            LayerClass::Conv1x1,
            LayerClass::Conv3x3,
            LayerClass::Conv5x5,
            LayerClass::Dw3x3,
            LayerClass::Fc,
        ];
        let mut schemes: Vec<SchemeTag> = vec![
            SchemeTag::Dense,
            SchemeTag::Unstructured,
            SchemeTag::Structured,
            SchemeTag::Pattern,
        ];
        for &(a, b) in Scheme::block_size_candidates() {
            schemes.push(SchemeTag::Block(a, b));
        }
        for class in classes {
            for &feat in &FEAT_GRID {
                for &ch in &CH_GRID {
                    let layer = class.template(feat, ch);
                    for &scheme in &schemes {
                        if scheme == SchemeTag::Pattern && class != LayerClass::Conv3x3 {
                            continue; // patterns are 3x3-only
                        }
                        for &comp in &COMP_GRID {
                            let s = scheme.to_scheme(class);
                            let comp_eff = if scheme == SchemeTag::Dense { 1.0 } else { comp };
                            let cfg = ExecConfig::new(s, comp_eff, dev);
                            let lat = layer_latency_ms(&layer, &cfg, dev);
                            entries.insert(
                                SettingKey {
                                    class,
                                    feat,
                                    ch,
                                    scheme,
                                    comp_x10: (comp * 10.0) as u32,
                                },
                                lat,
                            );
                        }
                    }
                }
            }
        }
        LatencyModel { device: dev.name.to_string(), entries }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    fn snap(grid: &[usize], v: usize) -> usize {
        *grid
            .iter()
            .min_by_key(|&&g| (g as i64 - v as i64).unsigned_abs())
            .unwrap()
    }

    fn snap_comp(c: f32) -> u32 {
        let best = COMP_GRID
            .iter()
            .min_by(|a, b| (**a - c).abs().partial_cmp(&(**b - c).abs()).unwrap())
            .unwrap();
        (*best * 10.0) as u32
    }

    /// Query latency for an arbitrary layer/scheme/compression: snaps to
    /// the nearest grid setting and rescales by the MAC ratio between the
    /// actual layer and the grid template (the paper normalizes latency by
    /// MACs for exactly this purpose).
    pub fn query(&self, layer: &LayerSpec, scheme: &Scheme, compression: f32) -> Option<f64> {
        let class = LayerClass::of(layer);
        let feat = Self::snap(&FEAT_GRID, layer.in_hw.max(1));
        let ch = Self::snap(&CH_GRID, layer.out_ch);
        let tag = SchemeTag::of(scheme);
        let key = SettingKey {
            class,
            feat,
            ch,
            scheme: tag,
            comp_x10: if tag == SchemeTag::Dense { 20 } else { Self::snap_comp(compression) },
        };
        let base = *self.entries.get(&key)?;
        let template = class.template(feat, ch);
        let scale = layer.macs() as f64 / template.macs().max(1) as f64;
        Some(base * scale)
    }

    /// MAC-normalized latency (ms per GMAC) — the §5.2.2 block-size
    /// selection metric.
    pub fn latency_per_gmac(
        &self,
        layer: &LayerSpec,
        scheme: &Scheme,
        compression: f32,
    ) -> Option<f64> {
        let lat = self.query(layer, scheme, compression)?;
        Some(lat / (layer.macs() as f64 / 1e9))
    }

    // --- persistence ------------------------------------------------------

    pub fn to_json(&self) -> Value {
        let mut obj = std::collections::BTreeMap::new();
        for (k, v) in &self.entries {
            obj.insert(k.encode(), Value::num(*v));
        }
        Value::obj(vec![
            ("device", Value::str(self.device.clone())),
            ("entries", Value::Obj(obj)),
        ])
    }

    pub fn from_json(v: &Value) -> Result<LatencyModel> {
        let device = v.get("device")?.as_str()?.to_string();
        let mut entries = HashMap::new();
        for (k, val) in v.get("entries")?.as_obj()? {
            let key = SettingKey::decode(k).ok_or_else(|| anyhow!("bad key '{k}'"))?;
            entries.insert(key, val.as_f64()?);
        }
        Ok(LatencyModel { device, entries })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path.as_ref(), self.to_json().pretty())
            .with_context(|| format!("writing {}", path.as_ref().display()))
    }

    pub fn load(path: impl AsRef<Path>) -> Result<LatencyModel> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::from_json(&Value::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_has_paper_scale_settings() {
        let m = LatencyModel::build(&DeviceProfile::s10());
        // paper mentions ~512 settings; our grid is denser
        assert!(m.len() >= 512, "only {} settings", m.len());
    }

    #[test]
    fn query_snaps_and_scales() {
        let m = LatencyModel::build(&DeviceProfile::s10());
        let layer = LayerSpec::conv("c", 3, 100, 120, 30, 1); // off-grid
        let lat = m
            .query(&layer, &Scheme::BlockPunched { bf: 8, bc: 16 }, 6.0)
            .unwrap();
        assert!(lat > 0.0 && lat.is_finite());
    }

    #[test]
    fn pattern_only_for_3x3() {
        let m = LatencyModel::build(&DeviceProfile::s10());
        let c1 = LayerSpec::conv("c", 1, 128, 128, 28, 1);
        assert!(m.query(&c1, &Scheme::Pattern, 4.0).is_none());
        let c3 = LayerSpec::conv("c", 3, 128, 128, 28, 1);
        assert!(m.query(&c3, &Scheme::Pattern, 4.0).is_some());
    }

    #[test]
    fn block_ordering_survives_tabulation() {
        let m = LatencyModel::build(&DeviceProfile::s10());
        let layer = LayerSpec::conv("c", 3, 128, 128, 28, 1);
        let small = m
            .query(&layer, &Scheme::BlockPunched { bf: 4, bc: 4 }, 8.0)
            .unwrap();
        let big = m
            .query(&layer, &Scheme::BlockPunched { bf: 16, bc: 32 }, 8.0)
            .unwrap();
        let structured = m.query(&layer, &Scheme::StructuredRow, 8.0).unwrap();
        assert!(structured < big && big < small);
    }

    #[test]
    fn json_roundtrip() {
        let m = LatencyModel::build(&DeviceProfile::s20());
        let v = m.to_json();
        let back = LatencyModel::from_json(&v).unwrap();
        assert_eq!(back.len(), m.len());
        assert_eq!(back.device, m.device);
        // spot-check an entry survives
        let layer = LayerSpec::conv("c", 3, 128, 128, 28, 1);
        let a = m.query(&layer, &Scheme::Unstructured, 4.0).unwrap();
        let b = back.query(&layer, &Scheme::Unstructured, 4.0).unwrap();
        assert!((a - b).abs() < 1e-9);
    }

    #[test]
    fn save_load_file() {
        let m = LatencyModel::build(&DeviceProfile::s10());
        let path = std::env::temp_dir().join("prunemap_latmodel_test.json");
        m.save(&path).unwrap();
        let back = LatencyModel::load(&path).unwrap();
        assert_eq!(back.len(), m.len());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn per_gmac_normalization() {
        let m = LatencyModel::build(&DeviceProfile::s10());
        let layer = LayerSpec::conv("c", 3, 256, 256, 28, 1);
        let per = m
            .latency_per_gmac(&layer, &Scheme::StructuredRow, 8.0)
            .unwrap();
        assert!(per > 0.0);
    }
}
