//! Regeneration of every table and figure in the paper's evaluation
//! (DESIGN.md §4 maps each to its modules).  Shared by the CLI
//! (`prunemap table4` etc.) and the benchmark harness.
//!
//! Absolute numbers come from our simulator/accuracy substitutions; the
//! *shape* — who wins, by what factor, where crossovers fall — is the
//! reproduction target (see EXPERIMENTS.md for paper-vs-measured).

use crate::accuracy::{
    acc_drop, accuracy, overall_compression, remaining_macs, Assignment,
};
use crate::latmodel::LatencyModel;
use crate::mapping::{self, map_rule_based, map_search_based, RuleConfig, SearchConfig};
use crate::models::{zoo, Dataset, LayerKind, ModelSpec};
use crate::pruning::Scheme;
use crate::report::{Figure, Table};
use crate::simulator::{layer_latency_ms, DeviceProfile, ExecConfig};

/// Assign one scheme to every layer it applies to (3x3-DW stays dense).
pub fn uniform_assign(model: &ModelSpec, scheme: Scheme, c: f32) -> Vec<Assignment> {
    model
        .layers
        .iter()
        .map(|l| {
            if scheme.applicable(l) && !l.is_3x3_dw() {
                Assignment { scheme, compression: c }
            } else {
                Assignment::dense()
            }
        })
        .collect()
}

/// Assign a scheme to 3x3 CONV layers only (the PatDNN restriction).
pub fn only_3x3_assign(model: &ModelSpec, scheme: Scheme, c: f32) -> Vec<Assignment> {
    model
        .layers
        .iter()
        .map(|l| {
            if l.is_3x3_conv() {
                Assignment { scheme, compression: c }
            } else {
                Assignment::dense()
            }
        })
        .collect()
}

/// PatDNN baseline: pattern-based pruning on 3x3 layers with a *manually
/// set* per-layer rate (ADMM), chosen to land the paper's overall
/// compression.  For MobileNetV2 the only 3x3s are depthwise, which is
/// exactly why PatDNN gets 1.01x there.
pub fn patdnn_assignments(model: &ModelSpec) -> Vec<Assignment> {
    // Table 4 reports compression over CONV-layer parameters, so the 3x3
    // share is computed over CONV params only (VGG-16's giant FCs would
    // otherwise hide its conv structure).
    let conv_params: usize = model
        .layers
        .iter()
        .filter(|l| l.kind != LayerKind::Fc)
        .map(|l| l.params())
        .sum();
    let three_params: usize = model
        .layers
        .iter()
        .filter(|l| l.is_3x3_conv())
        .map(|l| l.params())
        .sum();
    let f = three_params as f32 / conv_params.max(1) as f32;
    // per-layer pattern rate: 8x where 3x3 dominates (VGG/ResNet-18);
    // solve conv-overall 1.56x where it doesn't (ResNet-50); MobileNetV2
    // has no regular 3x3s at all — PatDNN can only nibble the DW layers.
    let c_layer = if f > 0.9 {
        8.0
    } else if f > 0.3 {
        let kept = 1.0 / 1.56;
        (f / (kept - (1.0 - f)).max(1e-3)).clamp(1.0, 16.0)
    } else {
        1.0
    };
    model
        .layers
        .iter()
        .map(|l| {
            if l.is_3x3_conv() && c_layer > 1.0 {
                Assignment { scheme: Scheme::Pattern, compression: c_layer }
            } else if l.is_3x3_dw() && f < 0.05 {
                Assignment { scheme: Scheme::Pattern, compression: 1.5 }
            } else {
                Assignment::dense()
            }
        })
        .collect()
}

// ---------------------------------------------------------------------
// Figures
// ---------------------------------------------------------------------

/// Fig. 3: parameter / MAC share of 3x3 CONV layers.
pub fn fig3() -> Figure {
    let models = [
        zoo::vgg16(Dataset::ImageNet),
        zoo::resnet18(Dataset::ImageNet),
        zoo::resnet50(Dataset::ImageNet),
        zoo::mobilenet_v2(Dataset::ImageNet),
    ];
    let mut f = Figure::new(
        "Fig. 3: share of 3x3 CONV layers (ImageNet models)",
        "network",
    );
    f.set_x(&models.iter().map(|m| m.name.clone()).collect::<Vec<_>>());
    f.add_series(
        "params_3x3_frac",
        models.iter().map(|m| m.frac_params_3x3() as f64).collect(),
    );
    f.add_series(
        "macs_3x3_frac",
        models.iter().map(|m| m.frac_macs_3x3() as f64).collect(),
    );
    f
}

/// Fig. 5: accuracy & latency vs block size (ResNet-50 / ImageNet).
pub fn fig5(dev: &DeviceProfile) -> Figure {
    let m = zoo::resnet50(Dataset::ImageNet);
    let sizes: Vec<(String, Option<(usize, usize)>)> = vec![
        ("1x1 (unstr.)".into(), Some((1, 1))),
        ("4x4".into(), Some((4, 4))),
        ("4x16".into(), Some((4, 16))),
        ("8x16".into(), Some((8, 16))),
        ("16x32".into(), Some((16, 32))),
        ("64x128".into(), Some((64, 128))),
        ("whole (struct.)".into(), None),
    ];
    let mut acc = Vec::new();
    let mut lat = Vec::new();
    for (_, b) in &sizes {
        let assigns = match b {
            Some((a, c)) => uniform_assign(&m, Scheme::BlockPunched { bf: *a, bc: *c }, 6.0),
            None => uniform_assign(&m, Scheme::StructuredRow, 6.0),
        };
        let e = mapping::evaluate(&m, &assigns, dev);
        acc.push((accuracy(&m, &assigns) * 100.0) as f64);
        lat.push(e.latency_ms);
    }
    let mut f = Figure::new(
        "Fig. 5: accuracy & latency vs block size (ResNet-50/ImageNet, 6x)",
        "block",
    );
    f.set_x(&sizes.iter().map(|(n, _)| n.clone()).collect::<Vec<_>>());
    f.add_series("top1_acc_%", acc);
    f.add_series("latency_ms", lat);
    f
}

/// Fig. 7: pattern vs block-punched accuracy across compression rates.
pub fn fig7() -> Vec<Figure> {
    let comps = [2.0f32, 4.0, 6.0, 8.0, 12.0, 16.0];
    let mut out = Vec::new();
    for (net, ds, tag) in [
        ("resnet18", Dataset::Cifar10, "(a) ResNet-18 / CIFAR-10"),
        ("vgg16", Dataset::Cifar10, "(b) VGG-16 / CIFAR-10"),
        ("resnet18", Dataset::ImageNet, "(c) ResNet-18 / ImageNet"),
        ("vgg16", Dataset::ImageNet, "(d) VGG-16 / ImageNet"),
    ] {
        let m = if net == "resnet18" { zoo::resnet18(ds) } else { zoo::vgg16(ds) };
        let mut pat = Vec::new();
        let mut blk = Vec::new();
        for &c in &comps {
            pat.push(
                (accuracy(&m, &only_3x3_assign(&m, Scheme::Pattern, c)) * 100.0) as f64,
            );
            blk.push(
                (accuracy(
                    &m,
                    &only_3x3_assign(&m, Scheme::BlockPunched { bf: 4, bc: 16 }, c),
                ) * 100.0) as f64,
            );
        }
        let mut f = Figure::new(&format!("Fig. 7{tag}: top-1 vs compression (3x3 only)"), "comp");
        f.set_x(&comps.iter().map(|c| format!("{c}x")).collect::<Vec<_>>());
        f.add_series("pattern", pat);
        f.add_series("block 4x16", blk);
        out.push(f);
    }
    out
}

/// Fig. 9: CONV latency vs block size for iso-MAC (feature, channel)
/// configurations; one figure per kernel size (1x1, 3x3).
pub fn fig9(dev: &DeviceProfile) -> Vec<Figure> {
    let configs = [(56usize, 64usize), (28, 128), (14, 256), (7, 512)];
    let blocks = [(4usize, 4usize), (4, 16), (8, 16), (16, 32), (32, 64), (64, 128)];
    let mut out = Vec::new();
    for k in [1usize, 3] {
        let mut f = Figure::new(
            &format!("Fig. 9: {k}x{k} CONV latency vs block size (8x compression)"),
            "block",
        );
        f.set_x(&blocks.iter().map(|(a, b)| format!("{a}x{b}")).collect::<Vec<_>>());
        for &(feat, ch) in &configs {
            let layer = crate::models::LayerSpec::conv("t", k, ch, ch, feat, 1);
            let ys: Vec<f64> = blocks
                .iter()
                .map(|&(a, b)| {
                    layer_latency_ms(
                        &layer,
                        &ExecConfig::new(Scheme::BlockPunched { bf: a, bc: b }, 8.0, dev),
                        dev,
                    )
                })
                .collect();
            f.add_series(&format!("{feat}x{feat}x{ch}"), ys);
        }
        out.push(f);
    }
    out
}

/// Fig. 10a: FC-layer latency vs block size (normalized to 1x1 blocks).
pub fn fig10a(dev: &DeviceProfile) -> Figure {
    let layers = zoo::fig10a_fc_layers();
    let blocks = [(1usize, 1usize), (4, 4), (8, 16), (16, 32), (64, 128), (128, 256)];
    let mut f = Figure::new(
        "Fig. 10a: FC latency vs block size, normalized to 1x1 (8x)",
        "block",
    );
    f.set_x(&blocks.iter().map(|(a, b)| format!("{a}x{b}")).collect::<Vec<_>>());
    for layer in &layers {
        let base = layer_latency_ms(
            layer,
            &ExecConfig::new(Scheme::Block { bp: 1, bq: 1 }, 8.0, dev),
            dev,
        );
        let ys: Vec<f64> = blocks
            .iter()
            .map(|&(a, b)| {
                layer_latency_ms(
                    layer,
                    &ExecConfig::new(Scheme::Block { bp: a, bq: b }, 8.0, dev),
                    dev,
                ) / base
            })
            .collect();
        f.add_series(&layer.name, ys);
    }
    f
}

/// Fig. 10b: pattern vs block-punched latency across compression
/// (3x3 CONV, 28x28 feature map, 128 channels).
pub fn fig10b(dev: &DeviceProfile) -> Figure {
    let layer = crate::models::LayerSpec::conv("t", 3, 128, 128, 28, 1);
    let comps = [4.0f32, 8.0, 12.0, 16.0];
    let mut f = Figure::new(
        "Fig. 10b: 3x3 CONV 28x28x128 latency: pattern vs block",
        "comp",
    );
    f.set_x(&comps.iter().map(|c| format!("{c}x")).collect::<Vec<_>>());
    let series: Vec<(&str, Scheme)> = vec![
        ("pattern", Scheme::Pattern),
        ("block 8x16", Scheme::BlockPunched { bf: 8, bc: 16 }),
        ("block 16x32", Scheme::BlockPunched { bf: 16, bc: 32 }),
    ];
    for (name, scheme) in series {
        let ys: Vec<f64> = comps
            .iter()
            .map(|&c| layer_latency_ms(&layer, &ExecConfig::new(scheme, c, dev), dev))
            .collect();
        f.add_series(name, ys);
    }
    f
}

// ---------------------------------------------------------------------
// Tables
// ---------------------------------------------------------------------

/// Table 1: pruning-algorithm characteristics (qualitative).
pub fn table1() -> Table {
    let mut t = Table::new(
        "Table 1: pruning algorithm comparison",
        &["Algorithm", "Accuracy", "Compression rate"],
    );
    t.row(vec!["GroupLasso".into(), "Low".into(), "Auto".into()]);
    t.row(vec!["ADMM".into(), "High".into(), "Manual".into()]);
    t.row(vec!["Reweighted (ours)".into(), "High".into(), "Auto".into()]);
    t
}

/// Table 2: YOLOv4 / COCO pruning-scheme comparison.
pub fn table2(dev: &DeviceProfile) -> Table {
    let m = zoo::yolov4();
    let dense_ms = mapping::dense_latency_ms(&m, dev);
    let mut t = Table::new(
        "Table 2: YOLOv4 on COCO",
        &["Scheme", "#Weights(M)", "Compr.", "mAP", "FPS"],
    );
    let fps = |ms: f64| 1000.0 / ms;
    t.row(vec![
        "Not Prune".into(),
        format!("{:.2}", m.total_params() as f64 / 1e6),
        "1.0x".into(),
        format!("{:.1}", m.baseline_acc() * 100.0),
        format!("{:.1}", fps(dense_ms)),
    ]);
    let mut add = |label: &str, assigns: Vec<Assignment>| {
        let e = mapping::evaluate(&m, &assigns, dev);
        let kept_m = m.total_params() as f64 / e.compression as f64 / 1e6;
        t.row(vec![
            label.into(),
            format!("{kept_m:.2}"),
            format!("{:.1}x", e.compression),
            format!("{:.1}", (m.baseline_acc() - e.acc_drop) * 100.0),
            format!("{:.1}", fps(e.latency_ms)),
        ]);
    };
    add("Structured", uniform_assign(&m, Scheme::StructuredRow, 7.3));
    add("Unstructured", uniform_assign(&m, Scheme::Unstructured, 11.2));
    add("Pattern (3x3 only)", only_3x3_assign(&m, Scheme::Pattern, 9.0 / 4.0));
    add(
        "Block (3x3 only)",
        only_3x3_assign(&m, Scheme::BlockPunched { bf: 4, bc: 16 }, 9.0 / 4.0),
    );
    add("Block (all)", uniform_assign(&m, Scheme::BlockPunched { bf: 8, bc: 16 }, 8.1));
    // hybrid: pattern on 3x3, block on everything else
    let hybrid: Vec<Assignment> = m
        .layers
        .iter()
        .map(|l| {
            if l.is_3x3_conv() {
                Assignment { scheme: Scheme::Pattern, compression: 8.5 }
            } else if l.kind != LayerKind::Fc {
                Assignment { scheme: Scheme::BlockPunched { bf: 8, bc: 16 }, compression: 8.5 }
            } else {
                Assignment::dense()
            }
        })
        .collect();
    add("Hybrid (ours)", hybrid);
    t
}

/// Table 3: pruning 3x3-DW layers of MobileNetV2 (CIFAR-10/100).
pub fn table3() -> Table {
    let mut t = Table::new(
        "Table 3: extra 2.22x pruning of 3x3-DW layers (MobileNetV2)",
        &["Dataset", "Base compr.", "With-DW compr.", "Extra acc drop: pattern", "block"],
    );
    for (ds, base_c) in [(Dataset::Cifar10, 7.19f32), (Dataset::Cifar100, 2.78)] {
        let m = zoo::mobilenet_v2(ds);
        let base: Vec<Assignment> = m
            .layers
            .iter()
            .map(|l| {
                if l.kind == LayerKind::Conv && l.kh == 1 {
                    Assignment {
                        scheme: Scheme::BlockPunched { bf: 4, bc: 16 },
                        compression: base_c,
                    }
                } else {
                    Assignment::dense()
                }
            })
            .collect();
        let with_dw = |scheme: Scheme| -> Vec<Assignment> {
            m.layers
                .iter()
                .zip(&base)
                .map(|(l, a)| {
                    if l.is_3x3_dw() {
                        Assignment { scheme, compression: 2.22 }
                    } else {
                        *a
                    }
                })
                .collect()
        };
        let d0 = acc_drop(&m, &base);
        let dp = acc_drop(&m, &with_dw(Scheme::Pattern)) - d0;
        let db = acc_drop(&m, &with_dw(Scheme::BlockPunched { bf: 4, bc: 16 })) - d0;
        let c0 = overall_compression(&m, &base, false);
        let c1 = overall_compression(&m, &with_dw(Scheme::Pattern), false);
        t.row(vec![
            format!("{ds:?}"),
            format!("{c0:.2}x"),
            format!("{c1:.2}x"),
            format!("-{:.2}%", dp * 100.0),
            format!("-{:.2}%", db * 100.0),
        ]);
    }
    t
}

/// One Table-4 block: a network on a dataset under the three methods.
pub fn table4_rows(
    t: &mut Table,
    model: &ModelSpec,
    lat: &LatencyModel,
    dev: &DeviceProfile,
    search_cfg: &SearchConfig,
) {
    let baseline = model.baseline_acc() * 100.0;
    let mut add = |method: &str, assigns: &[Assignment]| {
        let e = mapping::evaluate(model, assigns, dev);
        let schemes: std::collections::BTreeSet<String> = assigns
            .iter()
            .filter(|a| !matches!(a.scheme, Scheme::None))
            .map(|a| match a.scheme {
                Scheme::Pattern => "Pattern".to_string(),
                Scheme::Block { .. } | Scheme::BlockPunched { .. } => "Block".to_string(),
                Scheme::Unstructured => "Unstr".to_string(),
                _ => "Struct".to_string(),
            })
            .collect();
        let label = if schemes.len() > 1 {
            "Hybrid".to_string()
        } else {
            schemes.into_iter().next().unwrap_or_else(|| "None".into())
        };
        // Table 4 convention: compression over CONV-layer parameters
        let conv_c = overall_compression(model, assigns, true);
        t.row(vec![
            model.name.clone(),
            format!("{:?}", model.dataset),
            method.into(),
            label,
            format!("{baseline:.1}"),
            format!("{conv_c:.2}x"),
            format!("{:+.2}", e.acc_drop * 100.0),
            format!("{:.2}", e.latency_ms),
            format!("{:.2}G", e.macs / 1e9),
        ]);
    };
    add("PatDNN", &patdnn_assignments(model));
    add("Rule-based", &map_rule_based(model, lat, &RuleConfig::default()));
    let (search_assigns, _, _) = map_search_based(model, dev, search_cfg);
    add("Search-based", &search_assigns);
}

/// Table 4: the main comparison (3 nets x 2 datasets x 3 methods).
pub fn table4(dev: &DeviceProfile, quick: bool) -> Table {
    let lat = LatencyModel::build(dev);
    let search_cfg = if quick {
        SearchConfig { iterations: 25, samples: 4, ..Default::default() }
    } else {
        SearchConfig::default()
    };
    let mut t = Table::new(
        "Table 4: comparison with PatDNN",
        &[
            "Network", "Dataset", "Method", "Scheme", "Orig acc%", "Compr.", "Acc drop%",
            "Latency(ms)", "MACs",
        ],
    );
    for ds in [Dataset::Cifar10, Dataset::ImageNet] {
        for model in [zoo::resnet50(ds), zoo::vgg16(ds), zoo::mobilenet_v2(ds)] {
            table4_rows(&mut t, &model, &lat, dev, &search_cfg);
        }
    }
    t
}

/// Table 5: ImageNet MACs-level comparison against other compression work.
pub fn table5(dev: &DeviceProfile) -> Table {
    let lat = LatencyModel::build(dev);
    let mut t = Table::new(
        "Table 5: ImageNet MACs-level comparison",
        &["Group", "Model", "MACs(M)", "Top-1 acc%"],
    );
    // literature anchors (from the paper's table)
    for (g, name, macs, acc) in [
        ("300M", "MobileNetV2 1.0x", 300.0, 71.0),
        ("300M", "NetAdapt-MobileNetV1", 284.3, 69.1),
        ("300M", "ChamNet-B", 323.0, 73.8),
        ("200M", "MobileNetV2 0.75x", 209.0, 69.8),
        ("200M", "AMC-MobileNetV2", 211.0, 70.8),
        ("200M", "AutoSlim-MobileNetV2", 207.0, 73.0),
        ("200M", "MetaPruning-MobileNetV2", 217.0, 71.2),
        ("150M", "MobileNetV1 0.5x", 150.0, 63.3),
        ("150M", "AutoSlim-MobileNetV1", 150.0, 67.9),
    ] {
        t.row(vec![g.into(), name.into(), format!("{macs:.1}"), format!("{acc:.1}")]);
    }
    // ours: rule-based MobileNetV2, compression scaled to the MACs targets
    let m = zoo::mobilenet_v2(Dataset::ImageNet);
    let base = map_rule_based(&m, &lat, &RuleConfig::default());
    for (group, target_m) in [("200M", 203.0f64), ("150M", 177.0), ("150M", 151.0)] {
        let assigns = scale_to_macs(&m, &base, target_m * 1e6);
        let macs = remaining_macs(&m, &assigns) / 1e6;
        let acc = accuracy(&m, &assigns) * 100.0;
        t.row(vec![
            group.into(),
            "Ours (Rule-based)".into(),
            format!("{macs:.1}"),
            format!("{acc:.1}"),
        ]);
    }
    t
}

/// Scale a mapping's per-layer compression uniformly to hit a MACs target.
pub fn scale_to_macs(
    model: &ModelSpec,
    base: &[Assignment],
    target_macs: f64,
) -> Vec<Assignment> {
    let mut lo = 0.05f32;
    let mut hi = 4.0f32;
    let eval = |scale: f32| -> (Vec<Assignment>, f64) {
        let assigns: Vec<Assignment> = base
            .iter()
            .map(|a| {
                if matches!(a.scheme, Scheme::None) {
                    *a
                } else {
                    Assignment {
                        scheme: a.scheme,
                        compression: (a.compression * scale).max(1.0),
                    }
                }
            })
            .collect();
        let macs = remaining_macs(model, &assigns);
        (assigns, macs)
    };
    for _ in 0..40 {
        let mid = (lo + hi) / 2.0;
        let (_, macs) = eval(mid);
        if macs > target_macs {
            lo = mid; // need more compression
        } else {
            hi = mid;
        }
    }
    eval((lo + hi) / 2.0).0
}

/// Table 6: hardware specs of the portability platforms.
pub fn table6() -> Table {
    let mut t = Table::new(
        "Table 6: portability platforms",
        &["Model", "Peak GMAC/s", "Mem BW GB/s", "Dispatch ms"],
    );
    for d in DeviceProfile::all() {
        t.row(vec![
            d.name.into(),
            format!("{:.0}", d.peak_macs / 1e9),
            format!("{:.0}", d.mem_bw / 1e9),
            format!("{:.3}", d.dispatch_ms),
        ]);
    }
    t
}

/// Table 7: portability of the rule-based method across S10/S20/S21.
pub fn table7() -> Table {
    let mut t = Table::new(
        "Table 7: rule-based portability (VGG-16)",
        &["Dataset", "Platform", "Compr.", "MACs", "Top-1%", "Latency(ms)"],
    );
    for ds in [Dataset::Cifar10, Dataset::ImageNet] {
        let m = zoo::vgg16(ds);
        for dev in DeviceProfile::all() {
            let lat = LatencyModel::build(&dev);
            let assigns = map_rule_based(&m, &lat, &RuleConfig::default());
            let e = mapping::evaluate(&m, &assigns, &dev);
            t.row(vec![
                format!("{ds:?}"),
                dev.name.into(),
                format!("{:.2}x", e.compression),
                format!("{:.2}G", e.macs / 1e9),
                format!("{:.1}", (m.baseline_acc() - e.acc_drop) * 100.0),
                format!("{:.2}", e.latency_ms),
            ]);
        }
    }
    t
}

/// Ablation over the compiler optimizations (DESIGN.md §6): rule-mapped
/// ResNet-50/ImageNet with each optimization toggled off in turn.
pub fn ablation(dev: &DeviceProfile) -> Table {
    let lat = LatencyModel::build(dev);
    let m = zoo::resnet50(Dataset::ImageNet);
    let assigns = map_rule_based(&m, &lat, &RuleConfig::default());
    let mut t = Table::new(
        "Ablation: compiler optimizations (ResNet-50/ImageNet, rule-mapped)",
        &["Config", "Latency(ms)", "vs full"],
    );
    let latency_with = |fused: bool, reordered: bool, tuned: bool| -> f64 {
        let g = crate::compiler::Graph::from_model(&m);
        let schemes: Vec<(Scheme, f32)> =
            assigns.iter().map(|a| (a.scheme, a.compression)).collect();
        let ga = crate::compiler::GaConfig { population: 12, generations: 6, ..Default::default() };
        let mut sched =
            crate::compiler::compile(&g, &schemes, dev, tuned.then_some(&ga), 7);
        for k in &mut sched.kernels {
            if !fused {
                k.cfg.fused = false;
            }
            if !reordered {
                k.cfg.reordered = false;
            }
        }
        sched.latency_ms(dev)
    };
    let full = latency_with(true, true, true);
    for (name, f, r, tu) in [
        ("full (fusion+reorder+tuning)", true, true, true),
        ("no layer fusion", false, true, true),
        ("no row reordering", true, false, true),
        ("no GA auto-tuning", true, true, false),
        ("none", false, false, false),
    ] {
        let l = latency_with(f, r, tu);
        t.row(vec![name.into(), format!("{l:.2}"), format!("{:+.1}%", (l / full - 1.0) * 100.0)]);
    }
    t
}

/// Auto-compression preview for a model (what the reweighted stand-in
/// assigns per layer) — used by the quickstart example.
pub fn describe_mapping(model: &ModelSpec, assigns: &[Assignment]) -> Table {
    let mut t = Table::new(
        &format!("Mapping for {} ({:?})", model.name, model.dataset),
        &["Layer", "Type", "Scheme", "Compr."],
    );
    for (l, a) in model.layers.iter().zip(assigns) {
        let kind = match l.kind {
            LayerKind::Conv => format!("{}x{} conv", l.kh, l.kw),
            LayerKind::DepthwiseConv => format!("{}x{} dw", l.kh, l.kw),
            LayerKind::Fc => "fc".to_string(),
        };
        t.row(vec![
            l.name.clone(),
            kind,
            a.scheme.label(),
            format!("{:.1}x", a.compression),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_shapes() {
        let f = fig3();
        assert_eq!(f.x.len(), 4);
        // ResNet-18 is 3x3-heavy; MobileNetV2 is not
        let params = &f.series[0].1;
        assert!(params[1] > 0.9, "ResNet-18 {}", params[1]);
        assert!(params[3] < 0.1, "MobileNetV2 {}", params[3]);
    }

    #[test]
    fn fig5_tradeoff_shape() {
        let f = fig5(&DeviceProfile::s10());
        let acc = &f.series[0].1;
        let lat = &f.series[1].1;
        // accuracy monotonically falls, latency monotonically falls
        assert!(acc.first().unwrap() > acc.last().unwrap());
        assert!(lat.first().unwrap() > lat.last().unwrap());
    }

    #[test]
    fn fig7_remark1_shape() {
        let figs = fig7();
        assert_eq!(figs.len(), 4);
        // CIFAR subplots: block >= pattern at high compression
        for f in &figs[..2] {
            let pat = &f.series[0].1;
            let blk = &f.series[1].1;
            assert!(blk.last().unwrap() >= pat.last().unwrap(), "{}", f.title);
        }
        // ImageNet subplots: pattern > block
        for f in &figs[2..] {
            let pat = &f.series[0].1;
            let blk = &f.series[1].1;
            assert!(pat.last().unwrap() > blk.last().unwrap(), "{}", f.title);
        }
    }

    #[test]
    fn fig9_monotone_saturating() {
        let figs = fig9(&DeviceProfile::s10());
        for f in &figs {
            for (name, ys) in &f.series {
                for w in ys.windows(2) {
                    assert!(w[1] <= w[0] * 1.001, "{}/{name}: {ys:?}", f.title);
                }
            }
            // iso-MACs: small feature map slower than large at every block
            let first = &f.series[0].1;
            let last = &f.series.last().unwrap().1;
            assert!(last[0] > first[0], "{}", f.title);
        }
    }

    #[test]
    fn fig10a_normalized_start_at_one() {
        let f = fig10a(&DeviceProfile::s10());
        for (_, ys) in &f.series {
            assert!((ys[0] - 1.0).abs() < 1e-9);
            assert!(*ys.last().unwrap() < 1.0);
        }
    }

    #[test]
    fn fig10b_pattern_between_blocks() {
        let f = fig10b(&DeviceProfile::s10());
        let pat = &f.series[0].1;
        let b8 = &f.series[1].1;
        let b16 = &f.series[2].1;
        for i in 0..pat.len() {
            assert!(b16[i] <= b8[i], "16x32 must be fastest");
            let ratio = pat[i] / b8[i];
            assert!((0.5..2.0).contains(&ratio), "pattern/8x16 ratio {ratio}");
        }
    }

    #[test]
    fn table2_shape() {
        let t = table2(&DeviceProfile::s10());
        assert_eq!(t.rows.len(), 7);
        // structured mAP (row 1) far below unstructured (row 2)
        let map_of = |r: usize| t.rows[r][3].parse::<f64>().unwrap();
        let fps_of = |r: usize| t.rows[r][4].parse::<f64>().unwrap();
        assert!(map_of(1) + 5.0 < map_of(2), "structured {} vs unstructured {}", map_of(1), map_of(2));
        // hybrid (last row) is the fastest pruned variant and keeps mAP
        let hybrid_fps = fps_of(6);
        assert!(hybrid_fps > fps_of(2), "hybrid should beat unstructured FPS");
        assert!(map_of(6) > map_of(1) + 5.0);
        // dense is slowest
        assert!(fps_of(0) < hybrid_fps);
    }

    #[test]
    fn table3_shape() {
        let t = table3();
        assert_eq!(t.rows.len(), 2);
    }

    #[test]
    fn table5_ours_competitive() {
        let t = table5(&DeviceProfile::s10());
        // our 150M row should beat MobileNetV1-0.5x's 63.3% clearly
        let ours: Vec<&Vec<String>> =
            t.rows.iter().filter(|r| r[1].contains("Ours")).collect();
        assert_eq!(ours.len(), 3);
        for r in ours {
            let acc: f64 = r[3].parse().unwrap();
            assert!(acc > 65.0, "ours acc {acc}");
        }
    }

    #[test]
    fn ablation_full_is_fastest() {
        let t = ablation(&DeviceProfile::s10());
        let full: f64 = t.rows[0][1].parse().unwrap();
        for r in &t.rows[1..] {
            let l: f64 = r[1].parse().unwrap();
            assert!(l >= full - 1e-9, "{} faster than full: {l} < {full}", r[0]);
        }
        // disabling everything must cost meaningfully
        let none: f64 = t.rows.last().unwrap()[1].parse().unwrap();
        assert!(none > full * 1.05, "none {none} vs full {full}");
    }

    #[test]
    fn scale_to_macs_hits_target() {
        let dev = DeviceProfile::s10();
        let lat = LatencyModel::build(&dev);
        let m = zoo::mobilenet_v2(Dataset::ImageNet);
        let base = map_rule_based(&m, &lat, &RuleConfig::default());
        let scaled = scale_to_macs(&m, &base, 200e6);
        let macs = remaining_macs(&m, &scaled);
        assert!((macs - 200e6).abs() / 200e6 < 0.1, "macs {macs}");
    }
}
