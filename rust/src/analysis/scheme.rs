//! Scheme-legality and mask-consistency rules.
//!
//! Legality re-applies [`Scheme::applicable`] to every assignment (the
//! same predicate weight synthesis enforces, but reported as diagnostics
//! instead of a bail).  Mask consistency goes further: the *zero pattern*
//! of each synthesized weight must actually have the structure its scheme
//! declares — whole rows/columns for structured pruning, outer-product
//! blocks for block-based FC, a shared punched support per kernel block,
//! library patterns per kernel — and the declared compression must be in
//! the neighborhood of the measured `total/nnz`.  A weight is treated as
//! pruned iff it is exactly `0.0`: masks zero weights exactly, and the
//! He-normal init never produces exact zeros.

use crate::accuracy::Assignment;
use crate::models::{LayerKind, LayerSpec, ModelSpec};
use crate::pruning::{PatternLibrary, Scheme};
use crate::runtime::graph::{MaskedLayer, NetWeights};
use crate::tensor::Tensor;

use super::{Report, Rule};

/// Declared-vs-measured compression beyond this factor (either way) is
/// reported.  Group granularity legitimately lands block schemes up to
/// ~2x off target on small layers, so the tolerance is deliberately loose.
const DRIFT_FACTOR: f32 = 3.0;

pub(crate) fn check_legality(model: &ModelSpec, assigns: &[Assignment], report: &mut Report) {
    if model.layers.len() != assigns.len() {
        report.error(
            Rule::SchemeLegality,
            model.name.clone(),
            format!(
                "{} layers but {} assignments",
                model.layers.len(),
                assigns.len()
            ),
        );
        return;
    }
    for (spec, a) in model.layers.iter().zip(assigns) {
        if !a.scheme.applicable(spec) {
            report.error(
                Rule::SchemeLegality,
                spec.name.clone(),
                format!(
                    "scheme {} is not applicable to this {:?} layer ({}x{} in {} out {})",
                    a.scheme.label(),
                    spec.kind,
                    spec.kh,
                    spec.kw,
                    spec.in_ch,
                    spec.out_ch
                ),
            );
        }
    }
}

pub(crate) fn check_masks(model: &ModelSpec, weights: &NetWeights, report: &mut Report) {
    // count/order mismatches are the plan pass's findings; just align here
    for (spec, masked) in model.layers.iter().zip(&weights.layers) {
        check_layer(spec, masked, report);
    }
}

fn check_layer(spec: &LayerSpec, masked: &MaskedLayer, report: &mut Report) {
    let site = spec.name.clone();
    let w = &masked.weight;
    let expected_shape: Vec<usize> = match spec.kind {
        LayerKind::Conv => vec![spec.out_ch, spec.in_ch, spec.kh, spec.kw],
        LayerKind::DepthwiseConv => vec![spec.out_ch, 1, spec.kh, spec.kw],
        LayerKind::Fc => vec![spec.in_ch, spec.out_ch],
    };
    if w.shape() != expected_shape.as_slice() {
        report.error(
            Rule::MaskStructure,
            site,
            format!(
                "weight shape {:?} does not match the spec's {:?}",
                w.shape(),
                expected_shape
            ),
        );
        return;
    }

    let nnz = w.data().iter().filter(|v| **v != 0.0).count();
    if nnz == 0 {
        report.error(
            Rule::MaskStructure,
            site,
            "layer is entirely pruned (every weight is zero)",
        );
        return;
    }

    match masked.scheme {
        Scheme::None | Scheme::Unstructured => {}
        Scheme::StructuredRow => check_structured(w, true, &site, report),
        Scheme::StructuredColumn => check_structured(w, false, &site, report),
        Scheme::Pattern => check_pattern(w, &site, report),
        Scheme::Block { bp, bq } => check_block_fc(w, bp, bq, &site, report),
        Scheme::BlockPunched { bf, bc } => check_block_punched(w, bf, bc, &site, report),
    }

    // declared vs measured compression
    let declared = masked.compression.max(1.0);
    let measured = w.len() as f32 / nnz as f32;
    if measured > declared * DRIFT_FACTOR || measured * DRIFT_FACTOR < declared {
        report.warn(
            Rule::CompressionDrift,
            site,
            format!(
                "declared {declared:.2}x but measured {measured:.2}x ({nnz}/{} kept)",
                w.len()
            ),
        );
    }
}

/// Whole-row (dim 0 / filter) or whole-column (dim 1 / channel) pruning:
/// every group must be entirely zero or entirely nonzero.
fn check_structured(w: &Tensor, rows: bool, site: &str, report: &mut Report) {
    let s = w.shape();
    let groups = if rows { s[0] } else { s[1] };
    for g in 0..groups {
        let (mut zeros, mut nonzeros) = (0usize, 0usize);
        each_in_group(w, g, rows, |v| {
            if v == 0.0 {
                zeros += 1;
            } else {
                nonzeros += 1;
            }
        });
        if zeros > 0 && nonzeros > 0 {
            report.error(
                Rule::MaskStructure,
                site,
                format!(
                    "structured {} {g} is partially pruned ({nonzeros} kept, {zeros} zero)",
                    if rows { "row" } else { "column" }
                ),
            );
            return; // one witness per layer keeps reports readable
        }
    }
}

fn each_in_group(w: &Tensor, g: usize, rows: bool, mut f: impl FnMut(f32)) {
    let s = w.shape();
    match w.ndim() {
        2 => {
            if rows {
                for c in 0..s[1] {
                    f(w.at2(g, c));
                }
            } else {
                for r in 0..s[0] {
                    f(w.at2(r, g));
                }
            }
        }
        4 => {
            let (fdim, c, kh, kw) = (s[0], s[1], s[2], s[3]);
            if rows {
                for ci in 0..c {
                    for p in 0..kh * kw {
                        f(w.at4(g, ci, p / kw, p % kw));
                    }
                }
            } else {
                for fi in 0..fdim {
                    for p in 0..kh * kw {
                        f(w.at4(fi, g, p / kw, p % kw));
                    }
                }
            }
        }
        _ => {}
    }
}

/// Pattern pruning: every kernel is either fully pruned (connectivity) or
/// its nonzero support is covered by one of the library's 4-entry
/// patterns.
fn check_pattern(w: &Tensor, site: &str, report: &mut Report) {
    if w.ndim() != 4 || w.shape()[2] != 3 || w.shape()[3] != 3 {
        report.error(Rule::MaskStructure, site, "pattern scheme on a non-3x3 weight");
        return;
    }
    let lib = PatternLibrary::default8();
    let patterns = lib.patterns();
    let (f, c) = (w.shape()[0], w.shape()[1]);
    for fi in 0..f {
        for ci in 0..c {
            let mut support: u16 = 0;
            for p in 0..9 {
                if w.at4(fi, ci, p / 3, p % 3) != 0.0 {
                    support |= 1 << p;
                }
            }
            if support != 0 && !patterns.iter().any(|&pat| support & !pat == 0) {
                report.error(
                    Rule::MaskStructure,
                    site,
                    format!(
                        "kernel ({fi},{ci}) support {support:#011b} matches no library pattern"
                    ),
                );
                return;
            }
        }
    }
}

/// Block-based FC pruning: inside every (bp x bq) block the nonzero set
/// must be the outer product of a kept-row and a kept-column vector.
fn check_block_fc(w: &Tensor, bp: usize, bq: usize, site: &str, report: &mut Report) {
    if w.ndim() != 2 {
        report.error(Rule::MaskStructure, site, "block scheme on a non-2-D weight");
        return;
    }
    let (p, q) = (w.shape()[0], w.shape()[1]);
    // clamp exactly like the mask generator
    let bp = bp.min(p).max(1);
    let bq = bq.min(q).max(1);
    for r0 in (0..p).step_by(bp) {
        for c0 in (0..q).step_by(bq) {
            let r1 = (r0 + bp).min(p);
            let c1 = (c0 + bq).min(q);
            let row_any: Vec<bool> = (r0..r1)
                .map(|r| (c0..c1).any(|c| w.at2(r, c) != 0.0))
                .collect();
            let col_any: Vec<bool> = (c0..c1)
                .map(|c| (r0..r1).any(|r| w.at2(r, c) != 0.0))
                .collect();
            for r in r0..r1 {
                for c in c0..c1 {
                    let expect = row_any[r - r0] && col_any[c - c0];
                    if (w.at2(r, c) != 0.0) != expect {
                        report.error(
                            Rule::MaskStructure,
                            site,
                            format!(
                                "block ({},{}) is not outer-product structured at ({r},{c})",
                                r0 / bp,
                                c0 / bq
                            ),
                        );
                        return;
                    }
                }
            }
        }
    }
}

/// Block-punched pruning: inside every (bf x bc) kernel block, each kernel
/// position is either kept by every kernel or pruned by every kernel.
fn check_block_punched(w: &Tensor, bf: usize, bc: usize, site: &str, report: &mut Report) {
    if w.ndim() != 4 {
        report.error(Rule::MaskStructure, site, "punched scheme on a non-4-D weight");
        return;
    }
    let s = w.shape();
    let (f, c, kh, kw) = (s[0], s[1], s[2], s[3]);
    let bf = bf.min(f).max(1);
    let bc = bc.min(c).max(1);
    for f0 in (0..f).step_by(bf) {
        for c0 in (0..c).step_by(bc) {
            let f1 = (f0 + bf).min(f);
            let c1 = (c0 + bc).min(c);
            let block = (f1 - f0) * (c1 - c0);
            for p in 0..kh * kw {
                let kept = (f0..f1)
                    .flat_map(|fi| (c0..c1).map(move |ci| (fi, ci)))
                    .filter(|&(fi, ci)| w.at4(fi, ci, p / kw, p % kw) != 0.0)
                    .count();
                if kept != 0 && kept != block {
                    report.error(
                        Rule::MaskStructure,
                        site,
                        format!(
                            "kernel block ({},{}) position ({},{}) kept by {kept}/{block} kernels",
                            f0 / bf,
                            c0 / bc,
                            p / kw,
                            p % kw
                        ),
                    );
                    return;
                }
            }
        }
    }
}
