//! Static analysis over compiled artifacts: `prunemap check`.
//!
//! A compiled artifact is a five-part contract — `(ModelSpec, assignments,
//! Graph + FusionPlan, NetWeights, CompiledNet)` — and every part can be
//! corrupted independently: a hand-edited recipe, a buggy mapping method, a
//! plan rewrite that anchors a fused-away node, a lowering change that
//! aliases an arena slot.  This module re-derives what each part *implies*
//! and reports every disagreement as a [`Diagnostic`] with a stable
//! clippy-style rule id, instead of letting the executor chase garbage at
//! request time.
//!
//! Four rule families (see [`Rule`]):
//!
//! * **shape** — symbolic shape inference over every program step
//!   (im2col/SAME-padding arithmetic, depthwise block-diagonal dims,
//!   pool/flatten glue), plus output length vs. the dataset's class count;
//! * **liveness** — arena-slot dataflow: no step reads a slot before it is
//!   written or after its value was replaced, no GEMM writes over its own
//!   input panel, every slot id is in range;
//! * **scheme** — [`Scheme::applicable`] legality, mask *structure* (the
//!   zero pattern of each masked weight must actually have the declared
//!   regularity), and declared-vs-measured compression drift;
//! * **plan** — fusion-plan hygiene over the graph: topological order,
//!   anchors that exist and are compute nodes, no node fused twice,
//!   weights lining up one-to-one with the graph's layer nodes.
//!
//! Entry points: [`check_assignments`] (pre-compile legality),
//! [`check_model`] (the full post-compile pass
//! [`PreparedModel`](crate::serve::PreparedModel) sealing gates on), and
//! [`check`] (explicit graph + plan, for callers that built their own).
//! Reports render human-readably ([`Report::render`]) and as line-JSON
//! ([`Report::to_jsonl`]) for CI.

mod liveness;
mod plan;
mod scheme;
mod shape;

use std::fmt;

use crate::accuracy::Assignment;
use crate::compiler::{fuse, FusionPlan, Graph};
use crate::models::ModelSpec;
use crate::runtime::graph::NetWeights;
use crate::runtime::CompiledNet;
use crate::util::json::Value;

/// How bad a finding is.  `Error` findings gate sealing and serving
/// (`prunemap check` exits nonzero, [`crate::serve::PreparedModel`]
/// refuses to seal); `Warning` findings are reported but never gate.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Warning,
    Error,
}

impl Severity {
    /// Stable lowercase name (`"warning"` | `"error"`).
    pub fn name(self) -> &'static str {
        match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Every rule the analyzer can fire, with a stable kebab-case id (the
/// contract CI and the negative-path tests assert against) and a family
/// grouping the four analysis passes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Rule {
    // -- shape/dataflow -----------------------------------------------------
    /// A step's recorded in/out shape disagrees with the re-derived one.
    ShapeMismatch,
    /// A GEMM's sparse operator dims disagree with its layer spec
    /// (im2col rows, depthwise block-diagonal width, FC transpose), or a
    /// layer is lowered zero or multiple times.
    GemmDims,
    /// The compiled output length is not the dataset's class count.
    OutputClasses,
    // -- arena liveness/aliasing --------------------------------------------
    /// A step reads an arena slot no prior step (or the input) wrote.
    ReadBeforeWrite,
    /// A step reads a slot whose current value is not the one it expects
    /// (the slot was reused for a different buffer first).
    StaleRead,
    /// A slot id is outside `0..num_slots`.
    SlotRange,
    /// A GEMM's destination slot aliases its input panel or a fused
    /// residual operand.
    GemmAliasing,
    /// A step's output is replaced before anything reads it.
    DeadWrite,
    /// The declared output slot does not hold the output-shaped value at
    /// the end of the program.
    OutputSlot,
    // -- scheme legality + mask consistency ---------------------------------
    /// An assignment's scheme is not applicable to its layer
    /// ([`crate::pruning::Scheme::applicable`]).
    SchemeLegality,
    /// A masked weight's zero pattern violates its declared scheme
    /// structure (partial blocks, off-pattern kernels, an all-zero layer).
    MaskStructure,
    /// Declared compression is far from the measured `total/nnz`.
    CompressionDrift,
    // -- plan hygiene -------------------------------------------------------
    /// The graph is not in topological order / node ids are inconsistent.
    PlanTopo,
    /// A fusion kernel anchors a node that is missing, not a compute node,
    /// already fused into another kernel, or anchored twice.
    PlanAnchor,
    /// An epilogue entry is missing, non-elementwise, or fused twice.
    PlanEpilogue,
    /// Weights do not line up one-to-one with the graph's layer nodes, or
    /// a layer node is never covered by any kernel.
    PlanWeights,
    /// Lowering itself failed; the artifact cannot be compiled at all.
    CompileFailed,
}

impl Rule {
    /// Stable kebab-case rule id.
    pub fn id(self) -> &'static str {
        match self {
            Rule::ShapeMismatch => "shape-mismatch",
            Rule::GemmDims => "gemm-dims",
            Rule::OutputClasses => "output-classes",
            Rule::ReadBeforeWrite => "read-before-write",
            Rule::StaleRead => "stale-read",
            Rule::SlotRange => "slot-range",
            Rule::GemmAliasing => "gemm-aliasing",
            Rule::DeadWrite => "dead-write",
            Rule::OutputSlot => "output-slot",
            Rule::SchemeLegality => "scheme-legality",
            Rule::MaskStructure => "mask-structure",
            Rule::CompressionDrift => "compression-drift",
            Rule::PlanTopo => "plan-topo",
            Rule::PlanAnchor => "plan-anchor",
            Rule::PlanEpilogue => "plan-epilogue",
            Rule::PlanWeights => "plan-weights",
            Rule::CompileFailed => "compile-failed",
        }
    }

    /// Which analysis pass owns the rule
    /// (`"shape"` | `"liveness"` | `"scheme"` | `"plan"`).
    pub fn family(self) -> &'static str {
        match self {
            Rule::ShapeMismatch | Rule::GemmDims | Rule::OutputClasses => "shape",
            Rule::ReadBeforeWrite
            | Rule::StaleRead
            | Rule::SlotRange
            | Rule::GemmAliasing
            | Rule::DeadWrite
            | Rule::OutputSlot => "liveness",
            Rule::SchemeLegality | Rule::MaskStructure | Rule::CompressionDrift => "scheme",
            Rule::PlanTopo
            | Rule::PlanAnchor
            | Rule::PlanEpilogue
            | Rule::PlanWeights
            | Rule::CompileFailed => "plan",
        }
    }

    /// Every rule, for documentation and exhaustiveness tests.
    pub fn all() -> &'static [Rule] {
        &[
            Rule::ShapeMismatch,
            Rule::GemmDims,
            Rule::OutputClasses,
            Rule::ReadBeforeWrite,
            Rule::StaleRead,
            Rule::SlotRange,
            Rule::GemmAliasing,
            Rule::DeadWrite,
            Rule::OutputSlot,
            Rule::SchemeLegality,
            Rule::MaskStructure,
            Rule::CompressionDrift,
            Rule::PlanTopo,
            Rule::PlanAnchor,
            Rule::PlanEpilogue,
            Rule::PlanWeights,
            Rule::CompileFailed,
        ]
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.id())
    }
}

/// One finding: a rule firing at a site (a step, layer, node, or slot)
/// with a human-readable explanation.
#[derive(Debug, Clone, PartialEq)]
pub struct Diagnostic {
    pub rule: Rule,
    pub severity: Severity,
    /// Where it fired: a step/layer/node name or a slot id.
    pub site: String,
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}[{}]: {}: {}",
            self.severity,
            self.rule.id(),
            self.site,
            self.message
        )
    }
}

/// The outcome of an analysis pass: every diagnostic, in discovery order
/// (plan, scheme, shape, liveness).
#[derive(Debug, Clone, Default)]
pub struct Report {
    pub diagnostics: Vec<Diagnostic>,
}

impl Report {
    pub(crate) fn error(&mut self, rule: Rule, site: impl Into<String>, message: impl Into<String>) {
        self.diagnostics.push(Diagnostic {
            rule,
            severity: Severity::Error,
            site: site.into(),
            message: message.into(),
        });
    }

    pub(crate) fn warn(&mut self, rule: Rule, site: impl Into<String>, message: impl Into<String>) {
        self.diagnostics.push(Diagnostic {
            rule,
            severity: Severity::Warning,
            site: site.into(),
            message: message.into(),
        });
    }

    /// Whether any diagnostic gates (severity [`Severity::Error`]).
    pub fn has_errors(&self) -> bool {
        self.error_count() > 0
    }

    pub fn error_count(&self) -> usize {
        self.diagnostics
            .iter()
            .filter(|d| d.severity == Severity::Error)
            .count()
    }

    pub fn warning_count(&self) -> usize {
        self.diagnostics.len() - self.error_count()
    }

    /// Diagnostics that fired a specific rule.
    pub fn by_rule(&self, rule: Rule) -> Vec<&Diagnostic> {
        self.diagnostics.iter().filter(|d| d.rule == rule).collect()
    }

    /// Human-readable rendering: one line per diagnostic plus a summary
    /// line (always present, so "clean" is visible too).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for d in &self.diagnostics {
            out.push_str(&d.to_string());
            out.push('\n');
        }
        out.push_str(&format!(
            "check: {} error(s), {} warning(s)\n",
            self.error_count(),
            self.warning_count()
        ));
        out
    }

    /// Line-JSON rendering: one compact object per diagnostic
    /// (`rule`, `family`, `severity`, `site`, `message`), for CI and
    /// machine consumers.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for d in &self.diagnostics {
            let v = Value::obj(vec![
                ("rule", Value::str(d.rule.id())),
                ("family", Value::str(d.rule.family())),
                ("severity", Value::str(d.severity.name())),
                ("site", Value::str(d.site.clone())),
                ("message", Value::str(d.message.clone())),
            ]);
            out.push_str(&v.compact());
            out.push('\n');
        }
        out
    }
}

/// Pre-compile legality pass: assignment count and per-layer
/// [`Scheme::applicable`](crate::pruning::Scheme::applicable), without
/// weights.  This is what `prunemap check` runs *before* synthesis, so an
/// illegal mapping is reported as a diagnostic instead of a bail.
pub fn check_assignments(model: &ModelSpec, assigns: &[Assignment]) -> Report {
    let mut report = Report::default();
    scheme::check_legality(model, assigns, &mut report);
    report
}

/// The full analysis over an explicit graph + fusion plan.  Use this when
/// you built (or corrupted) the plan yourself; [`check_model`] is the
/// convenience over the canonical pipeline.
pub fn check(
    model: &ModelSpec,
    assigns: &[Assignment],
    graph: &Graph,
    plan: &FusionPlan,
    weights: &NetWeights,
    net: &CompiledNet,
) -> Report {
    let mut report = Report::default();
    plan::check_plan(graph, plan, weights, &mut report);
    scheme::check_legality(model, assigns, &mut report);
    scheme::check_masks(model, weights, &mut report);
    shape::check_shapes(model, net, &mut report);
    liveness::check_liveness(net, &mut report);
    report
}

/// The full analysis over the canonical pipeline: rebuilds the inference
/// graph and fusion plan from the spec (both are deterministic) and runs
/// every pass.  This is the gate
/// [`PreparedModel::from_parts`](crate::serve::PreparedModel::from_parts)
/// applies before sealing.
pub fn check_model(
    model: &ModelSpec,
    assigns: &[Assignment],
    weights: &NetWeights,
    net: &CompiledNet,
) -> Report {
    let graph = Graph::from_model(model);
    let plan = fuse(&graph);
    check(model, assigns, &graph, &plan, weights, net)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rule_ids_are_stable_and_unique() {
        let mut seen = std::collections::HashSet::new();
        for r in Rule::all() {
            assert!(seen.insert(r.id()), "duplicate rule id {}", r.id());
            assert!(
                r.id().chars().all(|c| c.is_ascii_lowercase() || c == '-'),
                "rule id '{}' is not kebab-case",
                r.id()
            );
            assert!(
                matches!(r.family(), "shape" | "liveness" | "scheme" | "plan"),
                "unknown family {}",
                r.family()
            );
        }
        assert_eq!(seen.len(), Rule::all().len());
    }

    #[test]
    fn report_renders_and_serializes() {
        let mut r = Report::default();
        assert!(!r.has_errors());
        assert!(r.render().contains("0 error(s), 0 warning(s)"));
        r.warn(Rule::CompressionDrift, "conv1", "declared 8.0x, measured 1.0x");
        r.error(Rule::ShapeMismatch, "conv2", "expected (8, 16, 16), recorded (8, 17, 16)");
        assert!(r.has_errors());
        assert_eq!(r.error_count(), 1);
        assert_eq!(r.warning_count(), 1);
        assert_eq!(r.by_rule(Rule::ShapeMismatch).len(), 1);
        let text = r.render();
        assert!(text.contains("error[shape-mismatch]: conv2:"), "{text}");
        assert!(text.contains("warning[compression-drift]: conv1:"), "{text}");
        // every jsonl line parses back with the stable fields
        for line in r.to_jsonl().lines() {
            let v = Value::parse(line).unwrap();
            assert!(Rule::all().iter().any(|r| r.id() == v.get("rule").unwrap().as_str().unwrap()));
            assert!(v.get("family").is_ok());
            assert!(matches!(
                v.get("severity").unwrap().as_str().unwrap(),
                "warning" | "error"
            ));
        }
        assert_eq!(r.to_jsonl().lines().count(), 2);
    }
}
