//! Static analysis over compiled artifacts: `prunemap check`.
//!
//! A compiled artifact is a five-part contract — `(ModelSpec, assignments,
//! Graph + FusionPlan, NetWeights, CompiledNet)` — and every part can be
//! corrupted independently: a hand-edited recipe, a buggy mapping method, a
//! plan rewrite that anchors a fused-away node, a lowering change that
//! aliases an arena slot.  This module re-derives what each part *implies*
//! and reports every disagreement as a [`Diagnostic`] with a stable
//! clippy-style rule id, instead of letting the executor chase garbage at
//! request time.
//!
//! Four rule families (see [`Rule`]):
//!
//! * **shape** — symbolic shape inference over every program step
//!   (im2col/SAME-padding arithmetic, depthwise block-diagonal dims,
//!   pool/flatten glue), plus output length vs. the dataset's class count;
//! * **liveness** — arena-slot dataflow: no step reads a slot before it is
//!   written or after its value was replaced, no GEMM writes over its own
//!   input panel, every slot id is in range;
//! * **scheme** — [`Scheme::applicable`] legality, mask *structure* (the
//!   zero pattern of each masked weight must actually have the declared
//!   regularity), and declared-vs-measured compression drift;
//! * **plan** — fusion-plan hygiene over the graph: topological order,
//!   anchors that exist and are compute nodes, no node fused twice,
//!   weights lining up one-to-one with the graph's layer nodes.
//!
//! A second, advisory analyzer family (`prunemap lint`) prices the same
//! artifact with [`crate::simulator::cost`] and reports *performance*
//! smells instead of correctness violations:
//!
//! * **perf** — lane-misaligned block sizes, scheme↔kernel mismatches
//!   (the cost model prefers a different backend, with the predicted
//!   speedup attached as a structured suggestion), stride-split load
//!   imbalance, missed fusion opportunities, and dominant-layer latency
//!   concentration;
//! * **calib** — measured-vs-modeled divergence against a
//!   [`PerLayerCalibration`](crate::simulator::PerLayerCalibration)
//!   record, whose ratios also re-price every other lint rule.
//!
//! Entry points: [`check_assignments`] (pre-compile legality),
//! [`check_model`] (the full post-compile pass
//! [`PreparedModel`](crate::serve::PreparedModel) sealing gates on),
//! [`check`] (explicit graph + plan, for callers that built their own),
//! and the advisory siblings [`lint_model`] / [`lint`].
//! Reports render human-readably ([`Report::render`]) and as line-JSON
//! ([`Report::to_jsonl`]) for CI.

pub mod calib;
mod liveness;
mod perf;
mod plan;
mod scheme;
mod shape;

pub use calib::CalibrationRecord;
pub use perf::LintConfig;

use std::fmt;

use crate::accuracy::Assignment;
use crate::compiler::{fuse, FusionPlan, Graph};
use crate::models::ModelSpec;
use crate::runtime::graph::NetWeights;
use crate::runtime::CompiledNet;
use crate::util::json::Value;

/// How bad a finding is.  `Error` findings gate sealing and serving
/// (`prunemap check` exits nonzero, [`crate::serve::PreparedModel`]
/// refuses to seal); `Warning` findings are reported but only gate under
/// `--deny-warnings`; `Advice` findings (the `prunemap lint` tier) never
/// gate — they are performance suggestions, not contract violations.
/// Variant order is the severity order: `Advice < Warning < Error`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Advice,
    Warning,
    Error,
}

impl Severity {
    /// Stable lowercase name (`"advice"` | `"warning"` | `"error"`).
    pub fn name(self) -> &'static str {
        match self {
            Severity::Advice => "advice",
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Every rule the analyzer can fire, with a stable kebab-case id (the
/// contract CI and the negative-path tests assert against) and a family
/// grouping the four analysis passes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Rule {
    // -- shape/dataflow -----------------------------------------------------
    /// A step's recorded in/out shape disagrees with the re-derived one.
    ShapeMismatch,
    /// A GEMM's sparse operator dims disagree with its layer spec
    /// (im2col rows, depthwise block-diagonal width, FC transpose), or a
    /// layer is lowered zero or multiple times.
    GemmDims,
    /// The compiled output length is not the dataset's class count.
    OutputClasses,
    // -- arena liveness/aliasing --------------------------------------------
    /// A step reads an arena slot no prior step (or the input) wrote.
    ReadBeforeWrite,
    /// A step reads a slot whose current value is not the one it expects
    /// (the slot was reused for a different buffer first).
    StaleRead,
    /// A slot id is outside `0..num_slots`.
    SlotRange,
    /// A GEMM's destination slot aliases its input panel or a fused
    /// residual operand.
    GemmAliasing,
    /// A step's output is replaced before anything reads it.
    DeadWrite,
    /// The declared output slot does not hold the output-shaped value at
    /// the end of the program.
    OutputSlot,
    // -- scheme legality + mask consistency ---------------------------------
    /// An assignment's scheme is not applicable to its layer
    /// ([`crate::pruning::Scheme::applicable`]).
    SchemeLegality,
    /// A masked weight's zero pattern violates its declared scheme
    /// structure (partial blocks, off-pattern kernels, an all-zero layer).
    MaskStructure,
    /// Declared compression is far from the measured `total/nnz`.
    CompressionDrift,
    // -- plan hygiene -------------------------------------------------------
    /// The graph is not in topological order / node ids are inconsistent.
    PlanTopo,
    /// A fusion kernel anchors a node that is missing, not a compute node,
    /// already fused into another kernel, or anchored twice.
    PlanAnchor,
    /// An epilogue entry is missing, non-elementwise, or fused twice.
    PlanEpilogue,
    /// Weights do not line up one-to-one with the graph's layer nodes, or
    /// a layer node is never covered by any kernel.
    PlanWeights,
    /// Lowering itself failed; the artifact cannot be compiled at all.
    CompileFailed,
    // -- performance lint (advisory) -----------------------------------------
    /// A block scheme's dims are not multiples of [`crate::sparse::LANE`],
    /// forcing padded SIMD lanes.
    LaneMisalignedBlock,
    /// The cost model prefers a different scheme/kernel backend than the
    /// one assigned; the suggestion carries the predicted speedup.
    SchemeKernelMismatch,
    /// `reorder::load_balance` predicts stride-split skew above the
    /// threshold for the layer's row-occupancy distribution.
    LoadImbalance,
    /// A GEMM is followed by a fusion-eligible BN/ReLU/Add the plan left
    /// unfused.
    MissedFusion,
    /// One layer is predicted to carry more than the threshold share of
    /// network latency.
    DominantLayer,
    /// A layer's measured/modeled ratio diverges from the rest of its
    /// calibration record beyond the accepted band.
    CalibrationDivergence,
}

impl Rule {
    /// Stable kebab-case rule id.
    pub fn id(self) -> &'static str {
        match self {
            Rule::ShapeMismatch => "shape-mismatch",
            Rule::GemmDims => "gemm-dims",
            Rule::OutputClasses => "output-classes",
            Rule::ReadBeforeWrite => "read-before-write",
            Rule::StaleRead => "stale-read",
            Rule::SlotRange => "slot-range",
            Rule::GemmAliasing => "gemm-aliasing",
            Rule::DeadWrite => "dead-write",
            Rule::OutputSlot => "output-slot",
            Rule::SchemeLegality => "scheme-legality",
            Rule::MaskStructure => "mask-structure",
            Rule::CompressionDrift => "compression-drift",
            Rule::PlanTopo => "plan-topo",
            Rule::PlanAnchor => "plan-anchor",
            Rule::PlanEpilogue => "plan-epilogue",
            Rule::PlanWeights => "plan-weights",
            Rule::CompileFailed => "compile-failed",
            Rule::LaneMisalignedBlock => "lane-misaligned-block",
            Rule::SchemeKernelMismatch => "scheme-kernel-mismatch",
            Rule::LoadImbalance => "load-imbalance",
            Rule::MissedFusion => "missed-fusion",
            Rule::DominantLayer => "dominant-layer",
            Rule::CalibrationDivergence => "calibration-divergence",
        }
    }

    /// Which analysis pass owns the rule (`"shape"` | `"liveness"` |
    /// `"scheme"` | `"plan"` | `"perf"` | `"calib"`).
    pub fn family(self) -> &'static str {
        match self {
            Rule::ShapeMismatch | Rule::GemmDims | Rule::OutputClasses => "shape",
            Rule::ReadBeforeWrite
            | Rule::StaleRead
            | Rule::SlotRange
            | Rule::GemmAliasing
            | Rule::DeadWrite
            | Rule::OutputSlot => "liveness",
            Rule::SchemeLegality | Rule::MaskStructure | Rule::CompressionDrift => "scheme",
            Rule::PlanTopo
            | Rule::PlanAnchor
            | Rule::PlanEpilogue
            | Rule::PlanWeights
            | Rule::CompileFailed => "plan",
            Rule::LaneMisalignedBlock
            | Rule::SchemeKernelMismatch
            | Rule::LoadImbalance
            | Rule::MissedFusion
            | Rule::DominantLayer => "perf",
            Rule::CalibrationDivergence => "calib",
        }
    }

    /// Every rule, for documentation and exhaustiveness tests.
    pub fn all() -> &'static [Rule] {
        &[
            Rule::ShapeMismatch,
            Rule::GemmDims,
            Rule::OutputClasses,
            Rule::ReadBeforeWrite,
            Rule::StaleRead,
            Rule::SlotRange,
            Rule::GemmAliasing,
            Rule::DeadWrite,
            Rule::OutputSlot,
            Rule::SchemeLegality,
            Rule::MaskStructure,
            Rule::CompressionDrift,
            Rule::PlanTopo,
            Rule::PlanAnchor,
            Rule::PlanEpilogue,
            Rule::PlanWeights,
            Rule::CompileFailed,
            Rule::LaneMisalignedBlock,
            Rule::SchemeKernelMismatch,
            Rule::LoadImbalance,
            Rule::MissedFusion,
            Rule::DominantLayer,
            Rule::CalibrationDivergence,
        ]
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.id())
    }
}

/// One finding: a rule firing at a site (a step, layer, node, or slot)
/// with a human-readable explanation.
#[derive(Debug, Clone, PartialEq)]
pub struct Diagnostic {
    pub rule: Rule,
    pub severity: Severity,
    /// Where it fired: a step/layer/node name or a slot id.
    pub site: String,
    pub message: String,
    /// Machine-readable remediation (lint rules): a JSON object such as
    /// `{"kind":"remap-scheme","suggested":{...},"predicted_speedup":1.8}`
    /// that tools can act on without parsing `message`.
    pub suggestion: Option<Value>,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}[{}]: {}: {}",
            self.severity,
            self.rule.id(),
            self.site,
            self.message
        )
    }
}

/// The outcome of an analysis pass: every diagnostic, in discovery order
/// (plan, scheme, shape, liveness).
#[derive(Debug, Clone, Default)]
pub struct Report {
    pub diagnostics: Vec<Diagnostic>,
}

impl Report {
    pub(crate) fn error(&mut self, rule: Rule, site: impl Into<String>, message: impl Into<String>) {
        self.diagnostics.push(Diagnostic {
            rule,
            severity: Severity::Error,
            site: site.into(),
            message: message.into(),
            suggestion: None,
        });
    }

    pub(crate) fn warn(&mut self, rule: Rule, site: impl Into<String>, message: impl Into<String>) {
        self.diagnostics.push(Diagnostic {
            rule,
            severity: Severity::Warning,
            site: site.into(),
            message: message.into(),
            suggestion: None,
        });
    }

    /// Push an advisory (lint-tier) diagnostic, optionally carrying a
    /// structured suggestion.
    pub(crate) fn advise(
        &mut self,
        rule: Rule,
        site: impl Into<String>,
        message: impl Into<String>,
        suggestion: Option<Value>,
    ) {
        self.diagnostics.push(Diagnostic {
            rule,
            severity: Severity::Advice,
            site: site.into(),
            message: message.into(),
            suggestion,
        });
    }

    /// Whether any diagnostic gates (severity [`Severity::Error`]).
    pub fn has_errors(&self) -> bool {
        self.error_count() > 0
    }

    pub fn error_count(&self) -> usize {
        self.diagnostics
            .iter()
            .filter(|d| d.severity == Severity::Error)
            .count()
    }

    pub fn warning_count(&self) -> usize {
        self.diagnostics
            .iter()
            .filter(|d| d.severity == Severity::Warning)
            .count()
    }

    pub fn advice_count(&self) -> usize {
        self.diagnostics
            .iter()
            .filter(|d| d.severity == Severity::Advice)
            .count()
    }

    /// Per-severity counts as a JSON object (`{"errors","warnings",
    /// "advice"}`), the summary object `--json-out` files end with.
    pub fn summary_json(&self) -> Value {
        Value::obj(vec![(
            "summary",
            Value::obj(vec![
                ("errors", Value::num(self.error_count() as f64)),
                ("warnings", Value::num(self.warning_count() as f64)),
                ("advice", Value::num(self.advice_count() as f64)),
            ]),
        )])
    }

    /// Diagnostics that fired a specific rule.
    pub fn by_rule(&self, rule: Rule) -> Vec<&Diagnostic> {
        self.diagnostics.iter().filter(|d| d.rule == rule).collect()
    }

    /// Human-readable rendering: one line per diagnostic plus a summary
    /// line (always present, so "clean" is visible too).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for d in &self.diagnostics {
            out.push_str(&d.to_string());
            out.push('\n');
        }
        out.push_str(&format!(
            "check: {} error(s), {} warning(s), {} advice\n",
            self.error_count(),
            self.warning_count(),
            self.advice_count()
        ));
        out
    }

    /// Line-JSON rendering: one compact object per diagnostic
    /// (`rule`, `family`, `severity`, `site`, `message`, and `suggestion`
    /// when the diagnostic carries one), for CI and machine consumers.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for d in &self.diagnostics {
            let mut fields = vec![
                ("rule", Value::str(d.rule.id())),
                ("family", Value::str(d.rule.family())),
                ("severity", Value::str(d.severity.name())),
                ("site", Value::str(d.site.clone())),
                ("message", Value::str(d.message.clone())),
            ];
            if let Some(s) = &d.suggestion {
                fields.push(("suggestion", s.clone()));
            }
            out.push_str(&Value::obj(fields).compact());
            out.push('\n');
        }
        out
    }
}

/// Pre-compile legality pass: assignment count and per-layer
/// [`Scheme::applicable`](crate::pruning::Scheme::applicable), without
/// weights.  This is what `prunemap check` runs *before* synthesis, so an
/// illegal mapping is reported as a diagnostic instead of a bail.
pub fn check_assignments(model: &ModelSpec, assigns: &[Assignment]) -> Report {
    let mut report = Report::default();
    scheme::check_legality(model, assigns, &mut report);
    report
}

/// The full analysis over an explicit graph + fusion plan.  Use this when
/// you built (or corrupted) the plan yourself; [`check_model`] is the
/// convenience over the canonical pipeline.
pub fn check(
    model: &ModelSpec,
    assigns: &[Assignment],
    graph: &Graph,
    plan: &FusionPlan,
    weights: &NetWeights,
    net: &CompiledNet,
) -> Report {
    let mut report = Report::default();
    plan::check_plan(graph, plan, weights, &mut report);
    scheme::check_legality(model, assigns, &mut report);
    scheme::check_masks(model, weights, &mut report);
    shape::check_shapes(model, net, &mut report);
    liveness::check_liveness(net, &mut report);
    report
}

/// The full analysis over the canonical pipeline: rebuilds the inference
/// graph and fusion plan from the spec (both are deterministic) and runs
/// every pass.  This is the gate
/// [`PreparedModel::from_parts`](crate::serve::PreparedModel::from_parts)
/// applies before sealing.
pub fn check_model(
    model: &ModelSpec,
    assigns: &[Assignment],
    weights: &NetWeights,
    net: &CompiledNet,
) -> Report {
    let graph = Graph::from_model(model);
    let plan = fuse(&graph);
    check(model, assigns, &graph, &plan, weights, net)
}

/// The advisory performance lint over an explicit graph + fusion plan.
/// Every diagnostic is [`Severity::Advice`]: the artifact is *correct*,
/// but the cost model (re-priced by `calibration` when given) thinks it
/// could be faster.  Use this when you built the plan yourself;
/// [`lint_model`] is the convenience over the canonical pipeline.
#[allow(clippy::too_many_arguments)]
pub fn lint(
    model: &ModelSpec,
    assigns: &[Assignment],
    graph: &Graph,
    plan: &FusionPlan,
    weights: &NetWeights,
    dev: &crate::simulator::DeviceProfile,
    cfg: &LintConfig,
    calibration: Option<&CalibrationRecord>,
) -> Report {
    let mut report = Report::default();
    if let Some(record) = calibration {
        calib::check_divergence(record, cfg, &mut report);
    }
    perf::lint_perf(model, assigns, graph, plan, weights, dev, cfg, calibration, &mut report);
    report
}

/// The advisory performance lint over the canonical pipeline: rebuilds
/// the inference graph and fusion plan from the spec and runs every lint
/// pass.  This is what `prunemap lint` and
/// [`PreparedModel::lint`](crate::serve::PreparedModel::lint) run.
pub fn lint_model(
    model: &ModelSpec,
    assigns: &[Assignment],
    weights: &NetWeights,
    dev: &crate::simulator::DeviceProfile,
    cfg: &LintConfig,
    calibration: Option<&CalibrationRecord>,
) -> Report {
    let graph = Graph::from_model(model);
    let plan = fuse(&graph);
    lint(model, assigns, &graph, &plan, weights, dev, cfg, calibration)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rule_ids_are_stable_and_unique() {
        let mut seen = std::collections::HashSet::new();
        for r in Rule::all() {
            assert!(seen.insert(r.id()), "duplicate rule id {}", r.id());
            assert!(
                r.id().chars().all(|c| c.is_ascii_lowercase() || c == '-'),
                "rule id '{}' is not kebab-case",
                r.id()
            );
            assert!(
                matches!(
                    r.family(),
                    "shape" | "liveness" | "scheme" | "plan" | "perf" | "calib"
                ),
                "unknown family {}",
                r.family()
            );
        }
        assert_eq!(seen.len(), Rule::all().len());
    }

    #[test]
    fn report_renders_and_serializes() {
        let mut r = Report::default();
        assert!(!r.has_errors());
        assert!(r.render().contains("0 error(s), 0 warning(s)"));
        r.warn(Rule::CompressionDrift, "conv1", "declared 8.0x, measured 1.0x");
        r.error(Rule::ShapeMismatch, "conv2", "expected (8, 16, 16), recorded (8, 17, 16)");
        r.advise(
            Rule::LaneMisalignedBlock,
            "conv3",
            "4x4 blocks misalign with 8-wide lanes",
            Some(Value::obj(vec![("kind", Value::str("align-block"))])),
        );
        assert!(r.has_errors());
        assert_eq!(r.error_count(), 1);
        assert_eq!(r.warning_count(), 1);
        assert_eq!(r.advice_count(), 1);
        assert_eq!(r.by_rule(Rule::ShapeMismatch).len(), 1);
        let text = r.render();
        assert!(text.contains("error[shape-mismatch]: conv2:"), "{text}");
        assert!(text.contains("warning[compression-drift]: conv1:"), "{text}");
        assert!(text.contains("advice[lane-misaligned-block]: conv3:"), "{text}");
        assert!(text.contains("1 error(s), 1 warning(s), 1 advice"), "{text}");
        // every jsonl line parses back with the stable fields
        for line in r.to_jsonl().lines() {
            let v = Value::parse(line).unwrap();
            assert!(Rule::all().iter().any(|r| r.id() == v.get("rule").unwrap().as_str().unwrap()));
            assert!(v.get("family").is_ok());
            assert!(matches!(
                v.get("severity").unwrap().as_str().unwrap(),
                "advice" | "warning" | "error"
            ));
        }
        assert_eq!(r.to_jsonl().lines().count(), 3);
        // the summary object counts per severity
        let s = r.summary_json();
        let s = s.get("summary").unwrap();
        assert_eq!(s.get("errors").unwrap().as_usize().unwrap(), 1);
        assert_eq!(s.get("warnings").unwrap().as_usize().unwrap(), 1);
        assert_eq!(s.get("advice").unwrap().as_usize().unwrap(), 1);
    }

    #[test]
    fn severity_order_keeps_advice_below_warning() {
        assert!(Severity::Advice < Severity::Warning);
        assert!(Severity::Warning < Severity::Error);
    }
}
