//! Plan-hygiene rules: the fusion plan and weights against the graph.
//!
//! The lowerer trusts the plan to walk the graph in topological order,
//! anchor every compute node exactly once, and fuse only elementwise
//! nodes; it trusts the weights to line up one-to-one with the graph's
//! layer nodes.  A plan that breaks any of these drops or double-executes
//! work silently — so every assumption is checked here first.

use std::collections::HashSet;

use crate::compiler::ir::{Graph, Op};
use crate::compiler::FusionPlan;
use crate::runtime::graph::NetWeights;

use super::{Report, Rule};

pub(crate) fn check_plan(
    graph: &Graph,
    plan: &FusionPlan,
    weights: &NetWeights,
    report: &mut Report,
) {
    if let Err(e) = graph.topo_check() {
        report.error(Rule::PlanTopo, "graph", e.to_string());
        // node ids are unreliable past a topo defect; bail on this pass
        return;
    }

    let mut anchored: HashSet<usize> = HashSet::new();
    let mut fused: HashSet<usize> = HashSet::new();
    for kernel in &plan.kernels {
        let site = graph
            .nodes
            .get(kernel.anchor)
            .map(|n| n.name.clone())
            .unwrap_or_else(|| format!("kernel@{}", kernel.anchor));
        let Some(anchor) = graph.nodes.get(kernel.anchor) else {
            report.error(
                Rule::PlanAnchor,
                site,
                format!("anchors node {} which the graph does not have", kernel.anchor),
            );
            continue;
        };
        if !anchored.insert(kernel.anchor) {
            report.error(Rule::PlanAnchor, &site, "anchored by more than one kernel");
        }
        if matches!(anchor.op, Op::Input { .. } | Op::Output) {
            report.error(Rule::PlanAnchor, &site, "anchors a non-compute node");
        }
        for &e in &kernel.epilogue {
            let Some(en) = graph.nodes.get(e) else {
                report.error(
                    Rule::PlanEpilogue,
                    &site,
                    format!("fuses node {e} which the graph does not have"),
                );
                continue;
            };
            if e == kernel.anchor {
                report.error(Rule::PlanEpilogue, &site, "fuses its own anchor");
            }
            if !en.op.is_elementwise() {
                report.error(
                    Rule::PlanEpilogue,
                    &site,
                    format!("fuses non-elementwise node '{}'", en.name),
                );
            }
            if !fused.insert(e) {
                report.error(
                    Rule::PlanEpilogue,
                    &site,
                    format!("node '{}' is fused into more than one kernel", en.name),
                );
            }
        }
    }
    // a kernel that is both an anchor and somebody's epilogue executes twice
    for &node in anchored.intersection(&fused) {
        report.error(
            Rule::PlanAnchor,
            graph.nodes[node].name.clone(),
            "anchors a kernel but is also fused into another kernel",
        );
    }
    // coverage: every compute node must be executed by exactly one kernel
    for n in &graph.nodes {
        let compute = !matches!(n.op, Op::Input { .. } | Op::Output);
        if compute && !anchored.contains(&n.id) && !fused.contains(&n.id) {
            report.error(
                Rule::PlanAnchor,
                n.name.clone(),
                "compute node covered by no kernel (silently dropped)",
            );
        }
    }

    // weights must mirror the graph's layer nodes one-to-one, in order
    let layer_nodes = graph.layer_nodes();
    if weights.layers.len() != layer_nodes.len() {
        report.error(
            Rule::PlanWeights,
            "weights",
            format!(
                "{} weight tensors for {} layer nodes",
                weights.layers.len(),
                layer_nodes.len()
            ),
        );
    } else {
        for (node, masked) in layer_nodes.iter().zip(&weights.layers) {
            if node.name != masked.spec.name {
                report.error(
                    Rule::PlanWeights,
                    node.name.clone(),
                    format!("weight order mismatch: weights carry '{}'", masked.spec.name),
                );
            }
        }
    }
    // bn statistics that no BatchNorm node will ever consume
    let bn_nodes: HashSet<&str> = graph
        .nodes
        .iter()
        .filter(|n| matches!(n.op, Op::BatchNorm))
        .map(|n| n.name.as_str())
        .collect();
    for key in weights.bn.keys() {
        if !bn_nodes.contains(key.as_str()) {
            report.warn(
                Rule::PlanWeights,
                key.clone(),
                "bn statistics for a node the graph does not have",
            );
        }
    }
}
