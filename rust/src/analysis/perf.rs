//! Advisory performance lint: the `perf` rule family.
//!
//! Where the correctness passes re-derive what an artifact *must* look
//! like, this pass re-derives what it *should have cost*: every rule
//! prices the sealed `(ModelSpec, assignments, FusionPlan, NetWeights)`
//! artifact with [`crate::simulator::cost`] and reports the places where
//! the mapping, block geometry, row distribution, or fusion plan leaves
//! predicted latency on the table.  Everything here is
//! [`Severity::Advice`](super::Severity::Advice): a finding means
//! "slower than it could be", never "wrong".
//!
//! When a [`CalibrationRecord`] is supplied, every latency in this pass
//! is re-priced with the record's per-layer measured/modeled ratios
//! (normalized by the record median, see [`super::calib`]), so the
//! advice reflects the machine that was actually profiled.

use crate::accuracy::Assignment;
use crate::compiler::{FusionPlan, Graph, Op};
use crate::mapping::{block_scheme, candidate_schemes};
use crate::models::ModelSpec;
use crate::pruning::Scheme;
use crate::runtime::graph::NetWeights;
use crate::simulator::{
    backend_for_scheme, calibrated_layer_latency_ms, rank_schemes, DeviceProfile, ExecConfig,
};
use crate::sparse::{reorder, LANE};
use crate::util::json::Value;

use super::{CalibrationRecord, Report, Rule};

/// Thresholds for the advisory rules.  Defaults are deliberately
/// conservative: lint over a well-mapped artifact should read as a short
/// list of genuine opportunities, not noise.
#[derive(Debug, Clone, Copy)]
pub struct LintConfig {
    /// Minimum predicted speedup (assigned ms / best candidate ms) before
    /// `scheme-kernel-mismatch` fires; the CLI's `--threshold`.
    pub speedup_threshold: f64,
    /// Stride-split max/mean worker load before `load-imbalance` fires.
    pub imbalance_threshold: f32,
    /// Share of network latency one layer may carry before
    /// `dominant-layer` fires.
    pub dominance_share: f64,
    /// Accepted band around the record's median measured/modeled ratio
    /// for `calibration-divergence`.
    pub divergence_band: f64,
}

impl Default for LintConfig {
    fn default() -> Self {
        LintConfig {
            speedup_threshold: 1.10,
            imbalance_threshold: 1.25,
            dominance_share: 0.50,
            divergence_band: 3.0,
        }
    }
}

/// Run every perf rule over the artifact.
#[allow(clippy::too_many_arguments)]
pub(crate) fn lint_perf(
    model: &ModelSpec,
    assigns: &[Assignment],
    graph: &Graph,
    plan: &FusionPlan,
    weights: &NetWeights,
    dev: &DeviceProfile,
    cfg: &LintConfig,
    calibration: Option<&CalibrationRecord>,
    report: &mut Report,
) {
    if model.layers.len() != assigns.len() {
        // the correctness analyzer owns this contract; nothing to price
        return;
    }
    let scale = |name: &str| calibration.map_or(1.0, |c| c.scale_for(name));

    // per-layer calibrated latency under the assigned configuration
    let assigned_ms: Vec<f64> = model
        .layers
        .iter()
        .zip(assigns)
        .map(|(l, a)| {
            let cfg = ExecConfig::new(a.scheme, a.compression, dev);
            calibrated_layer_latency_ms(l, &cfg, dev, scale(&l.name))
        })
        .collect();

    for ((layer, a), &current_ms) in model.layers.iter().zip(assigns).zip(&assigned_ms) {
        check_lane_alignment(layer, a, current_ms, dev, scale(&layer.name), report);
        check_scheme_ranking(layer, a, current_ms, dev, cfg, scale(&layer.name), report);
    }
    check_load_imbalance(weights, dev, cfg, report);
    check_missed_fusion(graph, plan, report);
    check_dominant_layer(model, assigns, &assigned_ms, dev, cfg, &scale, report);
}

/// `lane-misaligned-block`: block dims that are not multiples of the
/// SIMD lane width force partially-filled lanes on every surviving block.
fn check_lane_alignment(
    layer: &crate::models::LayerSpec,
    a: &Assignment,
    current_ms: f64,
    dev: &DeviceProfile,
    scale: f64,
    report: &mut Report,
) {
    let (p, q) = match a.scheme {
        Scheme::Block { bp, bq } => (bp, bq),
        Scheme::BlockPunched { bf, bc } => (bf, bc),
        _ => return,
    };
    if p % LANE == 0 && q % LANE == 0 {
        return;
    }
    // the best lane-aligned block candidate for this layer, if any tiles it
    let aligned: Vec<Scheme> = Scheme::block_size_candidates()
        .iter()
        .filter(|(x, y)| x % LANE == 0 && y % LANE == 0)
        .map(|&(x, y)| block_scheme(layer, x, y))
        .collect();
    let best = rank_schemes(layer, &aligned, a.compression, dev, scale)
        .into_iter()
        .next();
    let mut fields = vec![
        ("kind", Value::str("align-block")),
        ("lane", Value::num(LANE as f64)),
        ("block", Value::arr(vec![Value::num(p as f64), Value::num(q as f64)])),
    ];
    if let Some((s, ms)) = best {
        fields.push(("suggested_scheme", Value::str(s.label())));
        fields.push(("predicted_speedup", Value::num(current_ms / ms.max(1e-12))));
    }
    report.advise(
        Rule::LaneMisalignedBlock,
        layer.name.clone(),
        format!(
            "{p}x{q} block dims are not multiples of the {LANE}-wide SIMD lane: every \
             surviving block leaves lanes partially filled"
        ),
        Some(Value::obj(fields)),
    );
}

/// `scheme-kernel-mismatch`: re-rank every scheme either mapping method
/// could have assigned and flag the layer when the cost model predicts a
/// materially faster choice than the assigned one.
fn check_scheme_ranking(
    layer: &crate::models::LayerSpec,
    a: &Assignment,
    current_ms: f64,
    dev: &DeviceProfile,
    cfg: &LintConfig,
    scale: f64,
    report: &mut Report,
) {
    if matches!(a.scheme, Scheme::None) {
        // dense is a deliberate mapping decision (3x3 depthwise), not a smell
        return;
    }
    let ranked = rank_schemes(layer, &candidate_schemes(layer), a.compression, dev, scale);
    let Some(&(best, best_ms)) = ranked.first() else { return };
    if best == a.scheme {
        return;
    }
    let speedup = current_ms / best_ms.max(1e-12);
    if speedup < cfg.speedup_threshold {
        return;
    }
    report.advise(
        Rule::SchemeKernelMismatch,
        layer.name.clone(),
        format!(
            "cost model prefers {} on the {} backend over assigned {} on {}: \
             {:.4}ms vs {:.4}ms predicted ({speedup:.2}x)",
            best.label(),
            backend_for_scheme(&best),
            a.scheme.label(),
            backend_for_scheme(&a.scheme),
            best_ms,
            current_ms
        ),
        Some(Value::obj(vec![
            ("kind", Value::str("remap-scheme")),
            (
                "current",
                Value::obj(vec![
                    ("scheme", Value::str(a.scheme.label())),
                    ("backend", Value::str(backend_for_scheme(&a.scheme))),
                    ("predicted_ms", Value::num(current_ms)),
                ]),
            ),
            (
                "suggested",
                Value::obj(vec![
                    ("scheme", Value::str(best.label())),
                    ("backend", Value::str(backend_for_scheme(&best))),
                    ("predicted_ms", Value::num(best_ms)),
                ]),
            ),
            ("predicted_speedup", Value::num(speedup)),
        ])),
    );
}

/// `load-imbalance`: replay the executor's row view and stride split over
/// each masked weight and flag layers whose reordered row-occupancy
/// distribution still skews worker loads past the threshold.
fn check_load_imbalance(
    weights: &NetWeights,
    dev: &DeviceProfile,
    cfg: &LintConfig,
    report: &mut Report,
) {
    for masked in &weights.layers {
        if matches!(masked.scheme, Scheme::None) {
            continue; // dense rows are uniform by construction
        }
        // rows = output units, the executor's parallel axis
        let gemm = match masked.spec.kind {
            crate::models::LayerKind::Fc => masked.weight.transpose2(),
            _ => masked.weight.conv_to_gemm().transpose2(),
        };
        let row_nnz = reorder::row_nnz_counts(&gemm);
        let order = reorder::reorder_rows(&gemm);
        let lb = reorder::load_balance(&row_nnz, &order, dev.threads);
        if lb.imbalance <= cfg.imbalance_threshold {
            continue;
        }
        report.advise(
            Rule::LoadImbalance,
            masked.spec.name.clone(),
            format!(
                "stride split over {} workers leaves max/mean load at {:.2} even after \
                 row reordering (threshold {:.2}): the nnz distribution concentrates in \
                 few rows",
                dev.threads, lb.imbalance, cfg.imbalance_threshold
            ),
            Some(Value::obj(vec![
                ("kind", Value::str("rebalance")),
                ("imbalance", Value::num(lb.imbalance as f64)),
                ("threads", Value::num(dev.threads as f64)),
                ("pattern_switches", Value::num(lb.pattern_switches as f64)),
            ])),
        );
    }
}

/// `missed-fusion`: replay the fusion pass's eligibility predicate and
/// flag elementwise nodes the plan left standalone even though their
/// producer chain resolves to a single-consumer compute anchor.
fn check_missed_fusion(graph: &Graph, plan: &FusionPlan, report: &mut Report) {
    let fanout = graph.fanout();
    let mut fused_into = std::collections::HashMap::new();
    for k in &plan.kernels {
        for &e in &k.epilogue {
            fused_into.insert(e, k.anchor);
        }
    }
    for node in &graph.nodes {
        if !node.op.is_elementwise() || plan.is_fused_away(node.id) {
            continue;
        }
        let Some(&p) = node.inputs.first() else { continue };
        let anchor = *fused_into.get(&p).unwrap_or(&p);
        let Some(anchor_node) = graph.nodes.get(anchor) else { continue };
        let eligible = matches!(anchor_node.op, Op::Layer { .. })
            && fanout.get(&p).copied().unwrap_or(0) == 1;
        if !eligible {
            continue;
        }
        report.advise(
            Rule::MissedFusion,
            node.name.clone(),
            format!(
                "elementwise '{}' is fusion-eligible into compute kernel '{}' but the \
                 plan leaves it standalone, paying an extra dispatch and tensor round-trip",
                node.name, anchor_node.name
            ),
            Some(Value::obj(vec![
                ("kind", Value::str("fuse-epilogue")),
                ("node", Value::str(node.name.clone())),
                ("anchor", Value::str(anchor_node.name.clone())),
            ])),
        );
    }
}

/// `dominant-layer`: one layer predicted to carry more than the
/// threshold share of network latency — where the mapping search should
/// have concentrated its block-size budget.
fn check_dominant_layer(
    model: &ModelSpec,
    assigns: &[Assignment],
    assigned_ms: &[f64],
    dev: &DeviceProfile,
    cfg: &LintConfig,
    scale: &dyn Fn(&str) -> f64,
    report: &mut Report,
) {
    if model.layers.len() < 2 {
        return;
    }
    let total: f64 = assigned_ms.iter().sum();
    if total <= 0.0 {
        return;
    }
    let (idx, &ms) = assigned_ms
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .expect("non-empty");
    let share = ms / total;
    if share <= cfg.dominance_share {
        return;
    }
    let layer = &model.layers[idx];
    let a = &assigns[idx];
    let mut fields = vec![
        ("kind", Value::str("focus-search")),
        ("share", Value::num(share)),
        ("layer_ms", Value::num(ms)),
        ("total_ms", Value::num(total)),
    ];
    // attach the best alternative for the hot layer when one exists
    let ranked =
        rank_schemes(layer, &candidate_schemes(layer), a.compression, dev, scale(&layer.name));
    if let Some(&(best, best_ms)) = ranked.first() {
        if best != a.scheme && best_ms < ms {
            fields.push(("suggested_scheme", Value::str(best.label())));
            fields.push(("predicted_speedup", Value::num(ms / best_ms.max(1e-12))));
        }
    }
    report.advise(
        Rule::DominantLayer,
        layer.name.clone(),
        format!(
            "predicted to carry {:.0}% of network latency ({ms:.4}ms of {total:.4}ms, \
             threshold {:.0}%): spend the mapping budget here first",
            share * 100.0,
            cfg.dominance_share * 100.0
        ),
        Some(Value::obj(fields)),
    );
}
