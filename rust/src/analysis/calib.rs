//! Calibration-record consumption: the read side of the profile loop.
//!
//! `prunemap profile --json-out` serializes a
//! [`PerLayerCalibration`](crate::simulator::PerLayerCalibration) record
//! (`"format":"prunemap.calibration.v1"`).  This module parses that
//! record back, turns each layer's measured/modeled ratio into a
//! re-pricing scale for the cost model, and flags layers whose ratio
//! diverges from the rest of the record.
//!
//! Absolute ratios far from 1.0 are *expected* — the model prices a
//! mobile GPU while the trace measures a host CPU — so divergence is
//! judged relative to the record's own median ratio: a layer 3x above
//! (or below) the median is one the analytic model misprices relative
//! to its siblings, exactly where a measured-speedup claim should not
//! be trusted without a second look.

use crate::util::json::Value;

use super::{LintConfig, Report, Rule};

/// One parsed layer of a calibration record.
#[derive(Debug, Clone)]
pub struct CalibrationLayer {
    pub name: String,
    pub modeled_ms: f64,
    pub measured_ms: f64,
    /// measured / modeled.
    pub ratio: f64,
}

/// A parsed `prunemap.calibration.v1` record: the file handed to
/// `prunemap lint --calibration`.
#[derive(Debug, Clone)]
pub struct CalibrationRecord {
    pub model: String,
    pub layers: Vec<CalibrationLayer>,
}

impl CalibrationRecord {
    /// Parse a calibration JSON document (the exact shape
    /// [`PerLayerCalibration::to_json`](crate::simulator::PerLayerCalibration::to_json)
    /// writes).  Rejects unknown format tags and empty layer lists.
    pub fn from_json(v: &Value) -> crate::Result<CalibrationRecord> {
        let format = v.get("format")?.as_str()?;
        anyhow::ensure!(
            format == "prunemap.calibration.v1",
            "unsupported calibration format '{format}'"
        );
        let model = v.get("model")?.as_str()?.to_string();
        let mut layers = Vec::new();
        for l in v.get("layers")?.as_arr()? {
            let modeled_ms = l.get("modeled_ms")?.as_f64()?;
            let measured_ms = l.get("measured_ms")?.as_f64()?;
            let ratio = match l.opt("ratio") {
                Some(r) => r.as_f64()?,
                None => measured_ms / modeled_ms.max(1e-12),
            };
            layers.push(CalibrationLayer {
                name: l.get("name")?.as_str()?.to_string(),
                modeled_ms,
                measured_ms,
                ratio,
            });
        }
        anyhow::ensure!(!layers.is_empty(), "calibration record has no layers");
        Ok(CalibrationRecord { model, layers })
    }

    /// Median measured/modeled ratio across the record — the systematic
    /// model↔machine offset every layer shares.
    pub fn median_ratio(&self) -> f64 {
        let mut ratios: Vec<f64> = self.layers.iter().map(|l| l.ratio).collect();
        ratios.sort_by(f64::total_cmp);
        ratios[ratios.len() / 2]
    }

    /// The re-pricing scale for one layer: its ratio normalized by the
    /// record's median, so the shared mobile-GPU-vs-host offset cancels
    /// and only per-layer mispricing remains.  `1.0` for layers the
    /// record did not measure.
    pub fn scale_for(&self, layer: &str) -> f64 {
        match self.layers.iter().find(|l| l.name == layer) {
            Some(l) => l.ratio / self.median_ratio().max(1e-12),
            None => 1.0,
        }
    }
}

/// Flag every layer whose normalized ratio falls outside
/// `[1/band, band]` ([`LintConfig::divergence_band`]).
pub(crate) fn check_divergence(record: &CalibrationRecord, cfg: &LintConfig, report: &mut Report) {
    let median = record.median_ratio().max(1e-12);
    let band = cfg.divergence_band.max(1.0);
    for l in &record.layers {
        let rel = l.ratio / median;
        if rel > band || rel < 1.0 / band {
            let direction = if rel > 1.0 { "slower" } else { "faster" };
            report.advise(
                Rule::CalibrationDivergence,
                l.name.clone(),
                format!(
                    "measured/modeled ratio {:.2} is {rel:.2}x the record median {median:.2} \
                     ({:.3}ms measured vs {:.3}ms modeled): this layer runs {direction} than \
                     the model believes, outside the {band:.1}x band",
                    l.ratio, l.measured_ms, l.modeled_ms
                ),
                Some(Value::obj(vec![
                    ("kind", Value::str("recalibrate")),
                    ("ratio", Value::num(l.ratio)),
                    ("median_ratio", Value::num(median)),
                    ("relative", Value::num(rel)),
                    ("band", Value::num(band)),
                ])),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(ratios: &[f64]) -> CalibrationRecord {
        CalibrationRecord {
            model: "proxy".into(),
            layers: ratios
                .iter()
                .enumerate()
                .map(|(i, &r)| CalibrationLayer {
                    name: format!("l{i}"),
                    modeled_ms: 1.0,
                    measured_ms: r,
                    ratio: r,
                })
                .collect(),
        }
    }

    #[test]
    fn parse_round_trips_profile_output() {
        let json = r#"{"format":"prunemap.calibration.v1","model":"proxy","threads":2,
            "batch":8,"reps":3,"layers":[
            {"name":"conv1","modeled_ms":0.5,"measured_ms":2.0,"ratio":4.0},
            {"name":"conv2","modeled_ms":0.25,"measured_ms":1.0}]}"#;
        let rec = CalibrationRecord::from_json(&Value::parse(json).unwrap()).unwrap();
        assert_eq!(rec.model, "proxy");
        assert_eq!(rec.layers.len(), 2);
        assert!((rec.layers[1].ratio - 4.0).abs() < 1e-9, "ratio derived when absent");
    }

    #[test]
    fn bad_format_tag_rejected() {
        let json = r#"{"format":"prunemap.calibration.v2","model":"m","layers":[]}"#;
        assert!(CalibrationRecord::from_json(&Value::parse(json).unwrap()).is_err());
    }

    #[test]
    fn scale_normalizes_out_the_median() {
        let rec = record(&[4.0, 4.0, 4.0, 12.0]);
        // the shared 4x offset cancels; only the outlier re-prices
        assert!((rec.scale_for("l0") - 1.0).abs() < 1e-9);
        assert!((rec.scale_for("l3") - 3.0).abs() < 1e-9);
        assert!((rec.scale_for("missing") - 1.0).abs() < 1e-9);
    }

    #[test]
    fn divergence_flags_only_outliers() {
        let rec = record(&[4.0, 4.0, 4.0, 40.0]);
        let mut report = Report::default();
        check_divergence(&rec, &LintConfig::default(), &mut report);
        let fired = report.by_rule(Rule::CalibrationDivergence);
        assert_eq!(fired.len(), 1);
        assert_eq!(fired[0].site, "l3");
        let s = fired[0].suggestion.as_ref().unwrap();
        assert!((s.get("relative").unwrap().as_f64().unwrap() - 10.0).abs() < 1e-9);
    }
}
