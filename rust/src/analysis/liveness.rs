//! Arena-slot liveness and aliasing rules.
//!
//! The lowered program addresses a small arena of physical slots; the
//! lowerer's liveness analysis is what makes that safe.  This pass
//! re-executes the program symbolically — tracking, per slot, the shape of
//! the value it currently holds — and reports reads of never-written or
//! stale slots, out-of-range slot ids, and GEMM steps that alias their
//! destination with an input (the executor writes `dst` column-by-column
//! while still reading `src`, so `dst == src` corrupts the product).

use crate::runtime::graph::{CompiledNet, EpiOp, Step, StepOp};

use super::{Report, Rule};

type Shape = (usize, usize, usize);

/// What a slot currently holds.
#[derive(Clone, Copy)]
struct SlotState {
    shape: Shape,
    /// Set once anything reads the value; an overwrite of an unread value
    /// is a dead write.
    read: bool,
}

pub(crate) fn check_liveness(net: &CompiledNet, report: &mut Report) {
    let mut slots: Vec<Option<SlotState>> = vec![None; net.num_slots];

    if net.input_slot >= net.num_slots {
        report.error(
            Rule::SlotRange,
            net.name.clone(),
            format!("input slot {} out of range ({} slots)", net.input_slot, net.num_slots),
        );
        return;
    }
    slots[net.input_slot] = Some(SlotState { shape: net.input_shape, read: false });

    for step in &net.steps {
        check_step(step, &mut slots, net.num_slots, report);
    }

    if net.output_slot >= net.num_slots {
        report.error(
            Rule::OutputSlot,
            net.name.clone(),
            format!("output slot {} out of range ({} slots)", net.output_slot, net.num_slots),
        );
        return;
    }
    match slots[net.output_slot] {
        None => report.error(
            Rule::OutputSlot,
            net.name.clone(),
            format!("output slot {} is never written", net.output_slot),
        ),
        Some(s) if s.shape != net.output_shape => report.error(
            Rule::OutputSlot,
            net.name.clone(),
            format!(
                "output slot holds {:?} but the net promises {:?}",
                s.shape, net.output_shape
            ),
        ),
        Some(_) => {}
    }
}

fn check_step(step: &Step, slots: &mut [Option<SlotState>], num_slots: usize, report: &mut Report) {
    let site = step.name.clone();

    // collect every slot the step reads, with the shape each read expects
    // (a fused residual operand holds the GEMM's *output*-shaped value —
    // the lowerer enforces exactly that before fusing the add)
    let mut reads: Vec<(usize, Shape)> = vec![(step.src, step.in_shape)];
    if let StepOp::Add { other } = step.op {
        reads.push((other, step.in_shape));
    }
    if let StepOp::Gemm { epilogue, .. } = &step.op {
        for epi in epilogue {
            if let EpiOp::Add { slot } = epi {
                reads.push((*slot, step.out_shape));
            }
        }
    }
    for s in reads.iter().map(|r| r.0).chain(std::iter::once(step.dst)) {
        if s >= num_slots {
            report.error(
                Rule::SlotRange,
                site,
                format!("slot {s} out of range ({num_slots} slots)"),
            );
            return; // state is unknowable past a bad id; skip this step
        }
    }

    // every read must see a live value of the shape it expects
    for &(s, want) in &reads {
        match slots[s] {
            None => report.error(
                Rule::ReadBeforeWrite,
                &site,
                format!("reads slot {s} before anything wrote it"),
            ),
            Some(st) if st.shape != want => report.error(
                Rule::StaleRead,
                &site,
                format!("reads slot {s} holding {:?} but expects {:?}", st.shape, want),
            ),
            Some(_) => slots[s].as_mut().unwrap().read = true,
        }
    }

    // GEMM steps stream src (and any residual input) while writing dst
    if matches!(step.op, StepOp::Gemm { .. }) {
        if step.dst == step.src {
            report.error(
                Rule::GemmAliasing,
                &site,
                format!("GEMM writes slot {} while reading it as src", step.dst),
            );
        }
        if let StepOp::Gemm { epilogue, .. } = &step.op {
            for epi in epilogue {
                if let EpiOp::Add { slot } = epi {
                    if *slot == step.dst {
                        report.error(
                            Rule::GemmAliasing,
                            &site,
                            format!("fused residual add reads slot {slot} while the GEMM overwrites it"),
                        );
                    }
                }
            }
        }
    }

    // overwriting a value nobody ever read means the producing step was
    // wasted work (or the consumer reads the wrong slot)
    if step.dst != step.src {
        if let Some(prev) = slots[step.dst] {
            if !prev.read {
                report.warn(
                    Rule::DeadWrite,
                    &site,
                    format!("overwrites slot {} whose previous value was never read", step.dst),
                );
            }
        }
    }
    slots[step.dst] = Some(SlotState { shape: step.out_shape, read: false });
}
