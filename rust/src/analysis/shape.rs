//! Shape/dataflow rules over the lowered program.
//!
//! Every [`Step`] records the per-sample `(C, H, W)` shapes it expects to
//! read and promises to write.  This pass independently re-derives what
//! each op *must* produce from its input shape (and, for GEMMs, from the
//! layer spec and the sparse matrix dimensions) and reports any step whose
//! recorded shapes disagree — the static form of the runtime's
//! "gemm shape mismatch" panics.  It also checks that every prunable
//! layer is driven by exactly one GEMM step and that the net's output is
//! a class vector of the dataset's size.

use crate::models::{LayerKind, ModelSpec};
use crate::runtime::graph::{CompiledNet, EpiOp, GemmKind, Step, StepOp};

use super::{Report, Rule};

pub(crate) fn check_shapes(model: &ModelSpec, net: &CompiledNet, report: &mut Report) {
    let mut layer_refs = vec![0usize; net.layers.len()];
    for step in &net.steps {
        check_step(net, step, &mut layer_refs, report);
    }

    for (idx, &count) in layer_refs.iter().enumerate() {
        if count != 1 {
            report.error(
                Rule::GemmDims,
                net.layers[idx].name.clone(),
                format!("layer is driven by {count} GEMM steps (expected exactly 1)"),
            );
        }
    }

    if let Some(classes) = model.dataset.num_classes() {
        if net.output_len() != classes {
            report.error(
                Rule::OutputClasses,
                net.name.clone(),
                format!(
                    "output has {} elements but {} expects a {classes}-class vector",
                    net.output_len(),
                    model.dataset.name()
                ),
            );
        }
    }
}

fn check_step(net: &CompiledNet, step: &Step, layer_refs: &mut [usize], report: &mut Report) {
    let site = step.name.clone();
    let (c, h, w) = step.in_shape;
    let expected_out = match &step.op {
        StepOp::Gemm { layer, epilogue } => {
            let Some(le) = net.layers.get(*layer) else {
                report.error(
                    Rule::GemmDims,
                    site,
                    format!("references layer {layer} but the net has {}", net.layers.len()),
                );
                return;
            };
            layer_refs[*layer] += 1;
            let spec = &le.spec;
            let kind_ok = matches!(
                (le.kind, spec.kind),
                (GemmKind::Conv, LayerKind::Conv)
                    | (GemmKind::Depthwise, LayerKind::DepthwiseConv)
                    | (GemmKind::Fc, LayerKind::Fc)
            );
            if !kind_ok {
                report.error(
                    Rule::GemmDims,
                    &site,
                    format!("lowered as {:?} GEMM but the spec is {:?}", le.kind, spec.kind),
                );
            }
            if spec.kind == LayerKind::DepthwiseConv && spec.in_ch != spec.out_ch {
                report.error(
                    Rule::GemmDims,
                    &site,
                    format!("depthwise layer with in {} != out {}", spec.in_ch, spec.out_ch),
                );
            }
            let expected_in = match spec.kind {
                LayerKind::Fc => (spec.in_ch, 1, 1),
                _ => (spec.in_ch, spec.in_hw, spec.in_hw),
            };
            if step.in_shape != expected_in {
                report.error(
                    Rule::ShapeMismatch,
                    &site,
                    format!(
                        "consumes {:?} but layer '{}' expects {:?}",
                        step.in_shape, spec.name, expected_in
                    ),
                );
            }
            // sparse matrix dims the executor will multiply with
            let expected_dims = match le.kind {
                GemmKind::Conv => (spec.out_ch, spec.in_ch * spec.kh * spec.kw),
                GemmKind::Depthwise => (spec.out_ch, spec.out_ch * spec.kh * spec.kw),
                GemmKind::Fc => (spec.out_ch, spec.in_ch),
            };
            if le.sparse.dims() != expected_dims {
                report.error(
                    Rule::GemmDims,
                    &site,
                    format!(
                        "sparse weights are {:?} but the {:?} view needs {:?}",
                        le.sparse.dims(),
                        le.kind,
                        expected_dims
                    ),
                );
            }
            for epi in epilogue {
                if let EpiOp::BatchNorm(p) = epi {
                    if p.channels() != spec.out_ch {
                        report.error(
                            Rule::ShapeMismatch,
                            &site,
                            format!(
                                "fused bn has {} channels but the GEMM writes {}",
                                p.channels(),
                                spec.out_ch
                            ),
                        );
                    }
                }
            }
            match spec.kind {
                LayerKind::Fc => (spec.out_ch, 1, 1),
                _ => (spec.out_ch, spec.out_hw(), spec.out_hw()),
            }
        }
        StepOp::BatchNorm(p) => {
            if p.channels() != c {
                report.error(
                    Rule::ShapeMismatch,
                    &site,
                    format!("bn has {} channels but the input carries {c}", p.channels()),
                );
            }
            (c, h, w)
        }
        StepOp::Relu | StepOp::Add { .. } => (c, h, w),
        StepOp::MaxPool2x2 => (c, h.div_ceil(2), w.div_ceil(2)),
        StepOp::GlobalAvgPool => (c, 1, 1),
        StepOp::Flatten => (c * h * w, 1, 1),
    };
    if step.out_shape != expected_out {
        report.error(
            Rule::ShapeMismatch,
            site,
            format!(
                "records output {:?} but the op produces {:?} from {:?}",
                step.out_shape, expected_out, step.in_shape
            ),
        );
    }
}
