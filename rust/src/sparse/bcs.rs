//! Blocked Compressed Storage (paper §4.3, Fig. 4).
//!
//! CSR stores one explicit column index per non-zero.  Block-based /
//! block-punched pruning leaves *identical column patterns* across runs of
//! consecutive rows, so BCS hierarchically compresses the column index:
//!
//! * `weights`      — all non-zero values, row-major (as CSR);
//! * `row_offset`   — start of each row in `weights` (as CSR's row_ptr);
//! * `compact_cols` — deduplicated column-index lists;
//! * `col_stride`   — start/end of each *distinct* column list in
//!                    `compact_cols`;
//! * `occurrence`   — for each distinct list, the first row of the run of
//!                    consecutive rows sharing it (ends with `rows`).
//!
//! For a block-pruned matrix the number of distinct lists ≈ rows/bp, so the
//! index overhead collapses by ~bp× versus CSR.

use crate::tensor::Tensor;

use super::csr::Csr;
use super::exec::{SparseKernel, WorkUnit, LANE};

/// BCS matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Bcs {
    pub rows: usize,
    pub cols: usize,
    /// All non-zero values, row-major.
    pub weights: Vec<f32>,
    /// Start of each row in `weights`; len = rows + 1.
    pub row_offset: Vec<u32>,
    /// Deduplicated column-index streams.
    pub compact_cols: Vec<u32>,
    /// Start index in `compact_cols` of each distinct list; len = lists + 1.
    pub col_stride: Vec<u32>,
    /// First row of each run sharing a list; len = lists + 1 (ends = rows).
    pub occurrence: Vec<u32>,
}

impl Bcs {
    /// Build from dense, deduplicating identical column patterns over runs
    /// of consecutive rows.
    pub fn from_dense(t: &Tensor) -> Bcs {
        assert_eq!(t.ndim(), 2);
        let (rows, cols) = (t.shape()[0], t.shape()[1]);
        let mut weights = Vec::new();
        let mut row_offset = Vec::with_capacity(rows + 1);
        row_offset.push(0u32);

        let mut compact_cols: Vec<u32> = Vec::new();
        let mut col_stride: Vec<u32> = vec![0];
        let mut occurrence: Vec<u32> = Vec::new();

        // §Perf: single reusable pattern buffer compared in place against
        // the tail of compact_cols (no per-row Vec allocation)
        let data = t.data();
        let mut pattern: Vec<u32> = Vec::with_capacity(cols);
        for r in 0..rows {
            pattern.clear();
            for (c, v) in data[r * cols..(r + 1) * cols].iter().enumerate() {
                if *v != 0.0 {
                    weights.push(*v);
                    pattern.push(c as u32);
                }
            }
            row_offset.push(weights.len() as u32);
            let prev_start = col_stride[col_stride.len() - 1] as usize;
            let prev = &compact_cols[if col_stride.len() >= 2 {
                col_stride[col_stride.len() - 2] as usize
            } else {
                0
            }..prev_start];
            let same = !occurrence.is_empty() && prev == pattern.as_slice();
            if !same {
                occurrence.push(r as u32);
                compact_cols.extend_from_slice(&pattern);
                col_stride.push(compact_cols.len() as u32);
            }
        }
        occurrence.push(rows as u32);
        Bcs { rows, cols, weights, row_offset, compact_cols, col_stride, occurrence }
    }

    /// Number of distinct column lists.
    pub fn n_lists(&self) -> usize {
        self.col_stride.len().saturating_sub(1)
    }

    /// Column list for row `r` (binary search over occurrence runs).
    ///
    /// Out-of-range rows and malformed matrices (empty `occurrence`, as a
    /// hand-built 0-row BCS can produce) resolve to the empty list instead
    /// of panicking: `binary_search` returns `Err(0)` there, and the old
    /// `i - 1` underflowed.
    pub fn row_cols(&self, r: usize) -> &[u32] {
        debug_assert!(r < self.rows);
        // occurrence is sorted; find the run containing r (shared
        // `run_start` resolution: a start past `r` means no run covers it)
        let (li, start) = self.run_start(r, r + 1);
        if start > r || li >= self.n_lists() {
            return &[];
        }
        let s = self.col_stride[li] as usize;
        let e = self.col_stride[li + 1] as usize;
        &self.compact_cols[s..e]
    }

    /// Expand back to dense.
    pub fn to_dense(&self) -> Tensor {
        let mut t = Tensor::zeros(&[self.rows, self.cols]);
        for r in 0..self.rows {
            let cols = self.row_cols(r);
            let base = self.row_offset[r] as usize;
            for (k, &c) in cols.iter().enumerate() {
                t.set2(r, c as usize, self.weights[base + k]);
            }
        }
        t
    }

    pub fn nnz(&self) -> usize {
        self.weights.len()
    }

    /// Storage bytes: values + all index arrays.
    pub fn storage_bytes(&self) -> usize {
        self.weights.len() * 4
            + self.row_offset.len() * 4
            + self.compact_cols.len() * 4
            + self.col_stride.len() * 4
            + self.occurrence.len() * 4
    }

    /// Index (non-value) bytes only — the quantity BCS optimizes.
    pub fn index_bytes(&self) -> usize {
        self.storage_bytes() - self.weights.len() * 4
    }

    /// Resolve where execution of rows `[r0, r1)` starts: `(list index,
    /// first row)`.  One home for the occurrence binary search shared by
    /// the SIMD and scalar `run_rows` paths — `Err(0)` means `r0` precedes
    /// the first run (malformed occurrence, same contract as
    /// [`Bcs::row_cols`], whose old `i - 1` underflowed): those rows are
    /// empty, so execution starts at the first run (clamped to `r1`) and
    /// the zero-initialized output before it stays untouched.
    fn run_start(&self, r0: usize, r1: usize) -> (usize, usize) {
        match self.occurrence.binary_search(&(r0 as u32)) {
            Ok(i) => (i, r0),
            Err(0) => (0, self.occurrence.first().map_or(r1, |&o| (o as usize).min(r1))),
            Err(i) => (i - 1, r0),
        }
    }

    /// Sparse matrix-vector product.
    pub fn spmv(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        // iterate runs so the column list is resolved once per run — the
        // same access pattern the paper's generated code uses
        for li in 0..self.n_lists() {
            let r0 = self.occurrence[li] as usize;
            let r1 = self.occurrence[li + 1] as usize;
            let s = self.col_stride[li] as usize;
            let e = self.col_stride[li + 1] as usize;
            let cols = &self.compact_cols[s..e];
            for r in r0..r1 {
                let base = self.row_offset[r] as usize;
                let mut acc = 0.0;
                for (k, &c) in cols.iter().enumerate() {
                    acc += self.weights[base + k] * x[c as usize];
                }
                y[r] = acc;
            }
        }
        y
    }
}

impl SparseKernel for Bcs {
    fn dims(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    fn nnz(&self) -> usize {
        self.weights.len()
    }

    fn label(&self) -> &'static str {
        "bcs"
    }

    /// One unit per occurrence-run, so the engine resolves each compact
    /// column list exactly once per dispatch — the access pattern the
    /// paper's generated code uses.
    fn work_units(&self) -> Vec<WorkUnit> {
        (0..self.n_lists())
            .map(|li| {
                let r0 = self.occurrence[li] as usize;
                let r1 = self.occurrence[li + 1] as usize;
                WorkUnit {
                    r0,
                    r1,
                    cost: (self.row_offset[r1] - self.row_offset[r0]) as usize,
                }
            })
            .collect()
    }

    fn run_rows(&self, x: &[f32], batch: usize, r0: usize, r1: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), (r1 - r0) * batch);
        if r0 >= r1 {
            return;
        }
        // locate the run containing r0 (see `run_start` for the malformed-
        // occurrence contract), then walk runs covering [r0, r1)
        let (mut li, mut r) = self.run_start(r0, r1);
        let n_lists = self.n_lists();
        let full = batch - batch % LANE;
        while r < r1 && li < n_lists {
            let run_end = (self.occurrence[li + 1] as usize).min(r1);
            let s = self.col_stride[li] as usize;
            let e = self.col_stride[li + 1] as usize;
            let cols = &self.compact_cols[s..e];
            if cols.is_empty() {
                r = run_end;
                li += 1;
                continue;
            }
            // lane blocks outermost, rows of the occurrence-run inner: the
            // [len(cols), LANE] slab of X gathered for one block is reused
            // by every row sharing the column list — the access pattern
            // that makes the pruning schemes' block structure pay off.
            // Per-element accumulation stays ascending-k: bit-identical to
            // the scalar `spmv` order at every batch width, thread count,
            // and lane blocking.
            let mut b = 0;
            while b < full {
                for rr in r..run_end {
                    let base = self.row_offset[rr] as usize;
                    let mut acc = [0.0f32; LANE];
                    for (k, &c) in cols.iter().enumerate() {
                        let w = self.weights[base + k];
                        let xs = &x[c as usize * batch + b..c as usize * batch + b + LANE];
                        for (a, &xv) in acc.iter_mut().zip(xs) {
                            *a += w * xv;
                        }
                    }
                    let o0 = (rr - r0) * batch + b;
                    for (o, a) in out[o0..o0 + LANE].iter_mut().zip(&acc) {
                        *o += a;
                    }
                }
                b += LANE;
            }
            if b < batch {
                // scalar tail for the batch % LANE trailing columns
                for rr in r..run_end {
                    let base = self.row_offset[rr] as usize;
                    let orow = &mut out[(rr - r0) * batch..(rr - r0 + 1) * batch];
                    for bt in b..batch {
                        let mut acc = 0.0f32;
                        for (k, &c) in cols.iter().enumerate() {
                            acc += self.weights[base + k] * x[c as usize * batch + bt];
                        }
                        orow[bt] += acc;
                    }
                }
            }
            r = run_end;
            li += 1;
        }
    }

    fn run_rows_scalar(&self, x: &[f32], batch: usize, r0: usize, r1: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), (r1 - r0) * batch);
        if r0 >= r1 {
            return;
        }
        let (mut li, mut r) = self.run_start(r0, r1);
        let n_lists = self.n_lists();
        while r < r1 && li < n_lists {
            let run_end = (self.occurrence[li + 1] as usize).min(r1);
            let s = self.col_stride[li] as usize;
            let e = self.col_stride[li + 1] as usize;
            let cols = &self.compact_cols[s..e];
            while r < run_end {
                let base = self.row_offset[r] as usize;
                let orow = &mut out[(r - r0) * batch..(r - r0 + 1) * batch];
                // ascending-k accumulation, one batch element at a time
                for (k, &c) in cols.iter().enumerate() {
                    let w = self.weights[base + k];
                    let xrow = &x[c as usize * batch..(c as usize + 1) * batch];
                    for (o, &xv) in orow.iter_mut().zip(xrow) {
                        *o += w * xv;
                    }
                }
                r += 1;
            }
            li += 1;
        }
    }
}

/// Comparative storage report (used by the compression benches).
pub fn storage_comparison(t: &Tensor) -> (usize, usize, usize) {
    let dense_bytes = t.len() * 4;
    let csr = Csr::from_dense(t).storage_bytes();
    let bcs = Bcs::from_dense(t).storage_bytes();
    (dense_bytes, csr, bcs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::{prune, PatternLibrary, Scheme};
    use crate::rng::Rng;

    fn block_pruned(rows: usize, cols: usize, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let w = Tensor::he_normal(&[rows, cols], cols, &mut rng);
        let r = prune(&w, &Scheme::Block { bp: 8, bq: 8 }, 4.0, &PatternLibrary::default8());
        w.hadamard(&r.mask)
    }

    #[test]
    fn paper_fig4_example() {
        // the simplified example of Fig. 4: rows 0-1 share columns {0,3,6}
        #[rustfmt::skip]
        let t = Tensor::from_vec(&[4, 8], vec![
            1.0, 0.0, 0.0, 2.0, 0.0, 0.0, 3.0, 0.0,
            4.0, 0.0, 0.0, 5.0, 0.0, 0.0, 6.0, 0.0,
            0.0, 7.0, 0.0, 0.0, 8.0, 0.0, 0.0, 0.0,
            0.0, 9.0, 0.0, 0.0, 1.5, 0.0, 0.0, 0.0,
        ]);
        let b = Bcs::from_dense(&t);
        assert_eq!(b.n_lists(), 2, "two distinct column patterns");
        assert_eq!(b.row_cols(0), &[0, 3, 6]);
        assert_eq!(b.row_cols(1), &[0, 3, 6]);
        assert_eq!(b.row_cols(2), &[1, 4]);
        assert_eq!(b.occurrence, vec![0, 2, 4]);
        assert_eq!(b.to_dense(), t);
    }

    #[test]
    fn roundtrip_random() {
        let mut rng = Rng::new(1);
        for _ in 0..10 {
            let rows = 4 + rng.below(30);
            let cols = 4 + rng.below(30);
            let mut t = Tensor::zeros(&[rows, cols]);
            for r in 0..rows {
                for c in 0..cols {
                    if rng.bernoulli(0.3) {
                        t.set2(r, c, rng.normal());
                    }
                }
            }
            let b = Bcs::from_dense(&t);
            assert_eq!(b.to_dense(), t);
            assert_eq!(b.nnz(), t.nnz());
        }
    }

    #[test]
    fn bcs_beats_csr_on_reordered_block_punched() {
        // the paper's pipeline: block-punched mask -> GEMM view -> row
        // reorder (groups identical column patterns) -> BCS
        use crate::sparse::reorder::{permute_rows, reorder_rows};
        let mut rng = Rng::new(2);
        let w = Tensor::he_normal(&[64, 64, 3, 3], 64 * 9, &mut rng);
        let pr = prune(
            &w,
            &Scheme::BlockPunched { bf: 8, bc: 8 },
            4.0,
            &PatternLibrary::default8(),
        );
        let gemm = w.hadamard(&pr.mask).conv_to_gemm();
        let reordered = permute_rows(&gemm, &reorder_rows(&gemm));
        let b = Bcs::from_dense(&reordered);
        let c = Csr::from_dense(&reordered);
        assert!(
            b.storage_bytes() < c.storage_bytes(),
            "BCS ({}B) should beat CSR ({}B) on reordered block-punched weights",
            b.storage_bytes(),
            c.storage_bytes()
        );
        // index overhead specifically collapses
        assert!(b.index_bytes() * 2 < c.index_bytes());
        // far fewer distinct lists than rows
        assert!(b.n_lists() * 4 < b.rows, "lists={} rows={}", b.n_lists(), b.rows);
    }

    #[test]
    fn bcs_no_worse_than_csr_plus_eps_on_random() {
        // on unstructured sparsity every row pattern is distinct; BCS
        // degenerates to CSR + occurrence/stride overhead
        let mut rng = Rng::new(3);
        let mut t = Tensor::zeros(&[64, 64]);
        for r in 0..64 {
            for c in 0..64 {
                if rng.bernoulli(0.2) {
                    t.set2(r, c, rng.normal());
                }
            }
        }
        let (_, csr, bcs) = storage_comparison(&t);
        assert!(bcs as f32 <= csr as f32 * 1.2);
    }

    #[test]
    fn spmv_matches_csr() {
        let t = block_pruned(64, 48, 4);
        let b = Bcs::from_dense(&t);
        let c = Csr::from_dense(&t);
        let x: Vec<f32> = (0..48).map(|i| (i as f32).sin()).collect();
        let yb = b.spmv(&x);
        let yc = c.spmv(&x);
        for (a, e) in yb.iter().zip(yc.iter()) {
            assert!((a - e).abs() < 1e-4);
        }
    }

    #[test]
    fn row_cols_no_underflow_on_malformed_zero_row_matrix() {
        // regression: binary_search Err(0) used to hit `i - 1` and panic
        // on a hand-built BCS whose occurrence table is empty
        let malformed = Bcs {
            rows: 1,
            cols: 4,
            weights: vec![],
            row_offset: vec![0, 0],
            compact_cols: vec![],
            col_stride: vec![0],
            occurrence: vec![],
        };
        assert_eq!(malformed.row_cols(0), &[] as &[u32]);

        // a legitimate 0-row matrix round-trips and never panics
        let empty = Bcs::from_dense(&Tensor::zeros(&[0, 7]));
        assert_eq!(empty.rows, 0);
        assert_eq!(empty.n_lists(), 0);
        assert_eq!(empty.to_dense(), Tensor::zeros(&[0, 7]));

        // occurrence starting past row 0 (malformed) resolves empty too
        let shifted = Bcs {
            rows: 4,
            cols: 4,
            weights: vec![1.0],
            row_offset: vec![0, 0, 1, 1, 1],
            compact_cols: vec![2],
            col_stride: vec![0, 1],
            occurrence: vec![1, 4],
        };
        assert_eq!(shifted.row_cols(0), &[] as &[u32]);
        assert_eq!(shifted.row_cols(1), &[2]);

        // the execution path honors the same contract: rows before the
        // first run stay zero instead of borrowing run 0's column list
        let x = [0.0, 0.0, 5.0, 0.0];
        let mut out = vec![0.0f32; 2];
        shifted.run_rows(&x, 1, 0, 2, &mut out);
        assert_eq!(out, vec![0.0, 5.0]);
    }

    #[test]
    fn row_cols_run_resolution() {
        let t = block_pruned(32, 32, 5);
        let b = Bcs::from_dense(&t);
        for r in 0..32 {
            let expect: Vec<u32> = (0..32)
                .filter(|&c| t.at2(r, c) != 0.0)
                .map(|c| c as u32)
                .collect();
            assert_eq!(b.row_cols(r), expect.as_slice(), "row {r}");
        }
    }
}
