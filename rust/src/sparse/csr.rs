//! Compressed Sparse Row format — the baseline BCS is compared against.

use crate::tensor::Tensor;

use super::exec::{lane_row_indexed, SparseKernel, WorkUnit};

/// Standard CSR over a 2-D matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    pub values: Vec<f32>,
    pub col_idx: Vec<u32>,
    pub row_ptr: Vec<u32>,
}

impl Csr {
    /// Build from a dense 2-D tensor (explicit zeros dropped).
    pub fn from_dense(t: &Tensor) -> Csr {
        assert_eq!(t.ndim(), 2);
        let (rows, cols) = (t.shape()[0], t.shape()[1]);
        let mut values = Vec::new();
        let mut col_idx = Vec::new();
        let mut row_ptr = Vec::with_capacity(rows + 1);
        row_ptr.push(0u32);
        for r in 0..rows {
            for c in 0..cols {
                let v = t.at2(r, c);
                if v != 0.0 {
                    values.push(v);
                    col_idx.push(c as u32);
                }
            }
            row_ptr.push(values.len() as u32);
        }
        Csr { rows, cols, values, col_idx, row_ptr }
    }

    /// Expand back to dense.
    pub fn to_dense(&self) -> Tensor {
        let mut t = Tensor::zeros(&[self.rows, self.cols]);
        for r in 0..self.rows {
            for k in self.row_ptr[r]..self.row_ptr[r + 1] {
                t.set2(r, self.col_idx[k as usize] as usize, self.values[k as usize]);
            }
        }
        t
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Non-zeros in row `r`.
    pub fn row_nnz(&self, r: usize) -> usize {
        (self.row_ptr[r + 1] - self.row_ptr[r]) as usize
    }

    /// Storage footprint in bytes (f32 values + u32 indices/pointers).
    pub fn storage_bytes(&self) -> usize {
        self.values.len() * 4 + self.col_idx.len() * 4 + self.row_ptr.len() * 4
    }

    /// Index (non-value) bytes only — the quantity BCS competes on.
    pub fn index_bytes(&self) -> usize {
        self.col_idx.len() * 4 + self.row_ptr.len() * 4
    }

    /// Sparse matrix-vector product (reference for execution tests).
    pub fn spmv(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        for r in 0..self.rows {
            let mut acc = 0.0;
            for k in self.row_ptr[r]..self.row_ptr[r + 1] {
                acc += self.values[k as usize] * x[self.col_idx[k as usize] as usize];
            }
            y[r] = acc;
        }
        y
    }
}

impl SparseKernel for Csr {
    fn dims(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    fn nnz(&self) -> usize {
        self.values.len()
    }

    fn label(&self) -> &'static str {
        "csr"
    }

    /// One unit per row — CSR has no run structure to exploit, which is
    /// exactly the per-row load-balance picture `reorder::load_balance`
    /// models for irregular sparsity.
    fn work_units(&self) -> Vec<WorkUnit> {
        (0..self.rows)
            .map(|r| WorkUnit { r0: r, r1: r + 1, cost: self.row_nnz(r) })
            .collect()
    }

    fn run_rows(&self, x: &[f32], batch: usize, r0: usize, r1: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), (r1 - r0) * batch);
        for r in r0..r1 {
            let lo = self.row_ptr[r] as usize;
            let hi = self.row_ptr[r + 1] as usize;
            // ascending-k accumulation in [f32; LANE] register blocks:
            // bit-identical to the scalar spmv order
            lane_row_indexed(
                &self.values[lo..hi],
                &self.col_idx[lo..hi],
                x,
                batch,
                &mut out[(r - r0) * batch..(r - r0 + 1) * batch],
            );
        }
    }

    fn run_rows_scalar(&self, x: &[f32], batch: usize, r0: usize, r1: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), (r1 - r0) * batch);
        for r in r0..r1 {
            let orow = &mut out[(r - r0) * batch..(r - r0 + 1) * batch];
            // ascending-k accumulation, one batch element at a time
            for k in self.row_ptr[r] as usize..self.row_ptr[r + 1] as usize {
                let w = self.values[k];
                let c = self.col_idx[k] as usize;
                let xrow = &x[c * batch..(c + 1) * batch];
                for (o, &xv) in orow.iter_mut().zip(xrow) {
                    *o += w * xv;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn sparse_tensor(rows: usize, cols: usize, density: f32, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let mut t = Tensor::zeros(&[rows, cols]);
        for r in 0..rows {
            for c in 0..cols {
                if rng.bernoulli(density) {
                    t.set2(r, c, rng.normal());
                }
            }
        }
        t
    }

    #[test]
    fn roundtrip() {
        let t = sparse_tensor(17, 23, 0.3, 1);
        let csr = Csr::from_dense(&t);
        assert_eq!(csr.to_dense(), t);
        assert_eq!(csr.nnz(), t.nnz());
    }

    #[test]
    fn empty_matrix() {
        let t = Tensor::zeros(&[4, 4]);
        let csr = Csr::from_dense(&t);
        assert_eq!(csr.nnz(), 0);
        assert_eq!(csr.to_dense(), t);
    }

    #[test]
    fn spmv_matches_dense() {
        let t = sparse_tensor(8, 12, 0.4, 2);
        let csr = Csr::from_dense(&t);
        let x: Vec<f32> = (0..12).map(|i| i as f32 * 0.5 - 2.0).collect();
        let y = csr.spmv(&x);
        for r in 0..8 {
            let expect: f32 = (0..12).map(|c| t.at2(r, c) * x[c]).sum();
            assert!((y[r] - expect).abs() < 1e-4);
        }
    }

    #[test]
    fn storage_shrinks_with_sparsity() {
        let dense = sparse_tensor(64, 64, 0.9, 3);
        let sparse = sparse_tensor(64, 64, 0.1, 4);
        assert!(
            Csr::from_dense(&sparse).storage_bytes() < Csr::from_dense(&dense).storage_bytes()
        );
    }
}
