//! Batched multi-threaded sparse execution engine.
//!
//! The paper's speedups come from compiler-generated kernels that run the
//! BCS format over multi-threaded SIMD hardware; the seed repo only modeled
//! that execution in the simulator.  This module is the real code path:
//!
//! * [`SparseKernel`] — the execution contract: a sparse (or dense
//!   reference) matrix that can compute any row range of `Y = A · X` for a
//!   batched right-hand side (`X` is `[cols, batch]` row-major, one
//!   activation column per sample, exactly the GEMM view the compiler
//!   produces from im2col);
//! * backends — [`DenseKernel`] (reference), [`Csr`](super::Csr), and
//!   [`Bcs`](super::Bcs), the latter dispatching whole occurrence-runs so
//!   the compact column list is resolved once per run;
//! * **SIMD lanes** — every backend's `run_rows` vectorizes over the batch
//!   dimension in [`LANE`]-wide `[f32; 8]` accumulator blocks (portable
//!   code LLVM auto-vectorizes; no nightly features), with the pre-rewrite
//!   scalar loop kept as [`SparseKernel::run_rows_scalar`], the bit-for-bit
//!   reference the parity suite locks the lanes against;
//! * [`PanelSource`] — the fused right-hand-side contract: a producer
//!   (e.g. tile-order im2col) that writes `[cols, tile]` panels of `X` on
//!   demand so [`Engine::spmm_fused`] never needs the materialized matrix;
//! * [`Engine`] — threaded dispatch over a **persistent thread pool** owned
//!   by the engine (built once at construction, reused by every product
//!   instead of a fresh `rayon::scope` per spmm).  Work units (BCS
//!   occurrence-runs; rows for CSR/dense) are assigned to workers by the
//!   same **stride rule** `unit i → worker i % threads` that
//!   [`reorder`](super::reorder) models, so
//!   [`LoadBalance`](super::LoadBalance) statistics computed offline
//!   predict the real per-thread work of this engine.
//!
//! Determinism: a row's dot products are always accumulated in the same
//! element order regardless of thread count, batch size, lane blocking, or
//! panel fusion, so `Engine::spmm` with N threads is **bit-for-bit
//! identical** to the serial column-by-column `spmv` of the same backend.

use std::sync::Arc;

use crate::tensor::Tensor;

use super::reorder::{load_balance, stride_worker, LoadBalance};

/// Batch-lane width: `run_rows` processes the batch dimension in
/// `[f32; LANE]` register blocks (plus a scalar tail), the portable shape
/// LLVM lowers to 8-wide f32 SIMD.
pub const LANE: usize = 8;

/// Default fused-im2col tile width (GEMM columns per [`PanelSource`]
/// panel): wide enough to amortize streaming the weights once per panel,
/// small enough that a `[cols, tile]` panel stays cache-resident.  Always
/// a multiple of [`LANE`].
pub const DEFAULT_TILE_COLS: usize = 256;

/// Round `n` up to the next multiple of [`LANE`] (minimum one full lane
/// block): the shared alignment rule for fused-im2col tile widths and the
/// serve-layer micro-batcher's coalesced batch sizes, so the engine's
/// inner loops run whole `[f32; LANE]` register blocks with no scalar
/// tail.
pub fn align_to_lane(n: usize) -> usize {
    n.max(1).div_ceil(LANE) * LANE
}

/// A contiguous row range plus its cost (retained non-zeros), the unit of
/// thread dispatch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkUnit {
    /// First row (inclusive).
    pub r0: usize,
    /// Last row (exclusive).
    pub r1: usize,
    /// Work estimate: non-zeros in the range (MACs per batch column).
    pub cost: usize,
}

/// One output row over an index-compressed weight row:
/// `orow[b] += Σ_k w[k] · x[cols[k], b]`, the batch processed as full
/// `[f32; LANE]` register blocks plus a scalar tail.  Per-element
/// accumulation is ascending-`k`, identical to the scalar path.
#[inline]
pub(crate) fn lane_row_indexed(
    weights: &[f32],
    cols: &[u32],
    x: &[f32],
    batch: usize,
    orow: &mut [f32],
) {
    debug_assert_eq!(weights.len(), cols.len());
    debug_assert_eq!(orow.len(), batch);
    let full = batch - batch % LANE;
    let mut b = 0;
    while b < full {
        let mut acc = [0.0f32; LANE];
        for (&w, &c) in weights.iter().zip(cols) {
            let xs = &x[c as usize * batch + b..c as usize * batch + b + LANE];
            for (a, &xv) in acc.iter_mut().zip(xs) {
                *a += w * xv;
            }
        }
        for (o, a) in orow[b..b + LANE].iter_mut().zip(&acc) {
            *o += a;
        }
        b += LANE;
    }
    for bt in full..batch {
        let mut acc = 0.0f32;
        for (&w, &c) in weights.iter().zip(cols) {
            acc += w * x[c as usize * batch + bt];
        }
        orow[bt] += acc;
    }
}

/// Dense-row variant of [`lane_row_indexed`]: every column is touched,
/// zeros included (the reference semantics of [`DenseKernel`]).
#[inline]
pub(crate) fn lane_row_dense(wrow: &[f32], x: &[f32], batch: usize, orow: &mut [f32]) {
    debug_assert_eq!(orow.len(), batch);
    let full = batch - batch % LANE;
    let mut b = 0;
    while b < full {
        let mut acc = [0.0f32; LANE];
        for (c, &w) in wrow.iter().enumerate() {
            let xs = &x[c * batch + b..c * batch + b + LANE];
            for (a, &xv) in acc.iter_mut().zip(xs) {
                *a += w * xv;
            }
        }
        for (o, a) in orow[b..b + LANE].iter_mut().zip(&acc) {
            *o += a;
        }
        b += LANE;
    }
    for bt in full..batch {
        let mut acc = 0.0f32;
        for (c, &w) in wrow.iter().enumerate() {
            acc += w * x[c * batch + bt];
        }
        orow[bt] += acc;
    }
}

/// The execution contract every sparse backend implements.
///
/// `X` is `[cols, batch]` row-major (`x[c * batch + b]` is element `c` of
/// sample `b`); `Y` is `[rows, batch]`.  With `batch == 1` this degenerates
/// to SpMV.
pub trait SparseKernel: Sync {
    /// (rows, cols) of the operator.
    fn dims(&self) -> (usize, usize);

    /// Retained non-zeros.
    fn nnz(&self) -> usize;

    /// Short display name for benches and reports.
    fn label(&self) -> &'static str;

    /// Dispatchable work units covering `0..rows` exactly once, in row
    /// order.  BCS returns its occurrence-runs; CSR/dense return rows.
    fn work_units(&self) -> Vec<WorkUnit>;

    /// Compute rows `r0..r1` of `Y = A · X` into `out` (length
    /// `(r1 - r0) * batch`, **zero-initialized** by the caller, row-major
    /// relative to `r0`).  Implementations must accumulate each output
    /// element in ascending non-zero order so results are bit-identical
    /// across dispatch strategies, lane widths, and panel tilings.
    fn run_rows(&self, x: &[f32], batch: usize, r0: usize, r1: usize, out: &mut [f32]);

    /// The pre-SIMD scalar inner loop (one batch element at a time):
    /// the bit-for-bit reference `run_rows` is locked against by the
    /// parity suite, and the baseline of the `spmm_simd_vs_scalar` bench.
    fn run_rows_scalar(&self, x: &[f32], batch: usize, r0: usize, r1: usize, out: &mut [f32]);

    /// Serial batched product `Y = A · X`.
    fn spmm(&self, x: &[f32], batch: usize) -> Vec<f32> {
        let (rows, cols) = self.dims();
        assert_eq!(x.len(), cols * batch, "X must be [cols, batch] row-major");
        let mut y = vec![0.0f32; rows * batch];
        for u in self.work_units() {
            self.run_rows(x, batch, u.r0, u.r1, &mut y[u.r0 * batch..u.r1 * batch]);
        }
        y
    }

    /// Serial batched product through the scalar reference loop.
    fn spmm_scalar(&self, x: &[f32], batch: usize) -> Vec<f32> {
        let (rows, cols) = self.dims();
        assert_eq!(x.len(), cols * batch, "X must be [cols, batch] row-major");
        let mut y = vec![0.0f32; rows * batch];
        for u in self.work_units() {
            self.run_rows_scalar(x, batch, u.r0, u.r1, &mut y[u.r0 * batch..u.r1 * batch]);
        }
        y
    }

    /// Serial mat-vec (batch = 1 spmm).
    fn spmv_exec(&self, x: &[f32]) -> Vec<f32> {
        self.spmm(x, 1)
    }
}

/// A producer of right-hand-side panels for [`Engine::spmm_fused`]: the
/// fused-im2col contract.  `fill` writes GEMM columns `j0..j0 + width` as
/// a `[k_rows, width]` row-major panel — `X` restricted to one column
/// tile, generated directly in the order the spmm consumes it, so the full
/// `[k_rows, num_cols]` matrix never has to exist.
pub trait PanelSource: Sync {
    /// Total GEMM columns (the spmm batch dimension).
    fn num_cols(&self) -> usize;

    /// Panel rows; must equal the kernel's column count.
    fn k_rows(&self) -> usize;

    /// Write columns `j0..j0 + width` into `panel` (`[k_rows, width]`
    /// row-major, fully overwritten — no zero-init required).
    fn fill(&self, j0: usize, width: usize, panel: &mut [f32]);
}

/// A materialized `[k_rows, num_cols]` right-hand side exposed as a
/// [`PanelSource`] (reference producer for parity tests and benches).
pub struct SlicePanels<'a> {
    x: &'a [f32],
    k_rows: usize,
    num_cols: usize,
}

impl<'a> SlicePanels<'a> {
    pub fn new(x: &'a [f32], k_rows: usize, num_cols: usize) -> SlicePanels<'a> {
        assert_eq!(x.len(), k_rows * num_cols, "X must be [k_rows, num_cols]");
        SlicePanels { x, k_rows, num_cols }
    }
}

impl PanelSource for SlicePanels<'_> {
    fn num_cols(&self) -> usize {
        self.num_cols
    }

    fn k_rows(&self) -> usize {
        self.k_rows
    }

    fn fill(&self, j0: usize, width: usize, panel: &mut [f32]) {
        debug_assert!(j0 + width <= self.num_cols);
        debug_assert_eq!(panel.len(), self.k_rows * width);
        for r in 0..self.k_rows {
            let src = &self.x[r * self.num_cols + j0..r * self.num_cols + j0 + width];
            panel[r * width..(r + 1) * width].copy_from_slice(src);
        }
    }
}

/// Dense row-major reference backend: every element is touched, zeros
/// included — the baseline sparse backends are validated against.
#[derive(Debug, Clone)]
pub struct DenseKernel {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl DenseKernel {
    pub fn from_tensor(t: &Tensor) -> DenseKernel {
        assert_eq!(t.ndim(), 2);
        DenseKernel {
            rows: t.shape()[0],
            cols: t.shape()[1],
            data: t.data().to_vec(),
        }
    }
}

impl SparseKernel for DenseKernel {
    fn dims(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    fn nnz(&self) -> usize {
        self.data.iter().filter(|v| **v != 0.0).count()
    }

    fn label(&self) -> &'static str {
        "dense"
    }

    fn work_units(&self) -> Vec<WorkUnit> {
        (0..self.rows)
            .map(|r| WorkUnit { r0: r, r1: r + 1, cost: self.cols })
            .collect()
    }

    fn run_rows(&self, x: &[f32], batch: usize, r0: usize, r1: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), (r1 - r0) * batch);
        for r in r0..r1 {
            let row = &self.data[r * self.cols..(r + 1) * self.cols];
            lane_row_dense(row, x, batch, &mut out[(r - r0) * batch..(r - r0 + 1) * batch]);
        }
    }

    fn run_rows_scalar(&self, x: &[f32], batch: usize, r0: usize, r1: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), (r1 - r0) * batch);
        for r in r0..r1 {
            let row = &self.data[r * self.cols..(r + 1) * self.cols];
            let orow = &mut out[(r - r0) * batch..(r - r0 + 1) * batch];
            for (c, &w) in row.iter().enumerate() {
                let xrow = &x[c * batch..(c + 1) * batch];
                for (o, &xv) in orow.iter_mut().zip(xrow) {
                    *o += w * xv;
                }
            }
        }
    }
}

/// `y.as_mut_ptr()` smuggled across pool workers.  Sound because each
/// worker writes only disjoint spans (row ranges for `spmm`, column tiles
/// for `spmm_fused`) of the units it owns.
struct SyncPtr(*mut f32);

unsafe impl Send for SyncPtr {}
unsafe impl Sync for SyncPtr {}

/// Multi-threaded dispatcher over any [`SparseKernel`].
///
/// The engine owns a **persistent rayon thread pool**, built once at
/// construction and reused by every product (replacing the per-spmm
/// `rayon::scope` of earlier revisions, whose dispatch overhead dominated
/// small layers).  Unit `i` goes to worker `i % threads` — the stride
/// assignment [`reorder::load_balance`](super::reorder::load_balance)
/// models — so the offline [`LoadBalance`] report for a matrix is a
/// prediction of this engine's thread utilization (see
/// [`Engine::predicted_balance`]).
#[derive(Clone)]
pub struct Engine {
    threads: usize,
    tile_cols: usize,
    pool: Option<Arc<rayon::ThreadPool>>,
}

impl std::fmt::Debug for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Engine")
            .field("threads", &self.threads)
            .field("tile_cols", &self.tile_cols)
            .finish()
    }
}

impl Engine {
    pub fn new(threads: usize) -> Engine {
        let threads = threads.max(1);
        let pool = (threads > 1).then(|| {
            Arc::new(
                rayon::ThreadPoolBuilder::new()
                    .num_threads(threads)
                    .thread_name(|i| format!("prunemap-engine-{i}"))
                    .build()
                    .expect("spawn engine thread pool"),
            )
        });
        Engine { threads, tile_cols: DEFAULT_TILE_COLS, pool }
    }

    /// Single-threaded engine (identical output, no pool).
    pub fn serial() -> Engine {
        Engine::new(1)
    }

    /// One worker per available core.
    pub fn max_parallel() -> Engine {
        Engine::new(rayon::current_num_threads())
    }

    /// Override the fused-im2col tile width (GEMM columns per panel),
    /// rounded up to a multiple of [`LANE`] so full register blocks
    /// dominate.
    pub fn with_tile_cols(mut self, tile: usize) -> Engine {
        self.tile_cols = align_to_lane(tile);
        self
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn tile_cols(&self) -> usize {
        self.tile_cols
    }

    /// Dispatch units: the backend's work units, with oversized runs split
    /// so a single long occurrence-run (e.g. a uniform-pattern matrix)
    /// cannot serialize the whole product.  Splitting never changes
    /// results — rows are computed identically wherever they land.
    pub fn dispatch_units<K: SparseKernel + ?Sized>(&self, kernel: &K) -> Vec<WorkUnit> {
        let (rows, _) = kernel.dims();
        let units = kernel.work_units();
        if self.threads == 1 || rows == 0 {
            return units;
        }
        let max_rows = rows.div_ceil(self.threads * 8).max(1);
        let mut out = Vec::with_capacity(units.len());
        for u in units {
            let span = u.r1 - u.r0;
            if span <= max_rows {
                out.push(u);
                continue;
            }
            let mut r = u.r0;
            while r < u.r1 {
                let e = (r + max_rows).min(u.r1);
                out.push(WorkUnit { r0: r, r1: e, cost: u.cost * (e - r) / span });
                r = e;
            }
        }
        out
    }

    /// Batched product `Y = A · X` (`X` is `[cols, batch]` row-major).
    /// Bit-for-bit identical to the serial [`SparseKernel::spmm`] at any
    /// thread count.
    pub fn spmm<K: SparseKernel + ?Sized>(&self, kernel: &K, x: &[f32], batch: usize) -> Vec<f32> {
        let mut y = Vec::new();
        self.spmm_into(kernel, x, batch, &mut y);
        y
    }

    /// [`Engine::spmm`] into a caller-owned buffer (cleared and
    /// zero-resized here), so arena-recycled buffers are reused instead of
    /// a fresh `Vec` being allocated per product.
    pub fn spmm_into<K: SparseKernel + ?Sized>(
        &self,
        kernel: &K,
        x: &[f32],
        batch: usize,
        y: &mut Vec<f32>,
    ) {
        let (rows, cols) = kernel.dims();
        assert_eq!(x.len(), cols * batch, "X must be [cols, batch] row-major");
        y.clear();
        y.resize(rows * batch, 0.0);
        let units = self.dispatch_units(kernel);
        let workers = self.threads.min(units.len());
        let pool = match &self.pool {
            Some(pool) if workers > 1 => pool,
            _ => {
                for u in &units {
                    kernel.run_rows(x, batch, u.r0, u.r1, &mut y[u.r0 * batch..u.r1 * batch]);
                }
                return;
            }
        };
        let ptr = SyncPtr(y.as_mut_ptr());
        let units = &units;
        let ptr = &ptr;
        pool.broadcast(|ctx| {
            let w = ctx.index();
            if w >= workers {
                return;
            }
            // stride assignment: unit i -> worker i % workers
            for u in units.iter().skip(w).step_by(workers) {
                let len = (u.r1 - u.r0) * batch;
                // SAFETY: units cover disjoint row ranges and each unit is
                // visited by exactly one worker, so these slices never
                // alias; `y` outlives the (blocking) broadcast.
                let out = unsafe { std::slice::from_raw_parts_mut(ptr.0.add(u.r0 * batch), len) };
                kernel.run_rows(x, batch, u.r0, u.r1, out);
            }
        });
    }

    /// Fused batched product `Y = A · X` where `X`'s column tiles are
    /// generated on demand by `src` (e.g. tile-order im2col) instead of
    /// materialized up front.
    pub fn spmm_fused<K, P>(&self, kernel: &K, src: &P) -> Vec<f32>
    where
        K: SparseKernel + ?Sized,
        P: PanelSource + ?Sized,
    {
        let mut y = Vec::new();
        self.spmm_fused_into(kernel, src, &mut y);
        y
    }

    /// [`Engine::spmm_fused`] into a caller-owned buffer.  Each worker
    /// fills a `[cols, tile]` panel, runs the SIMD kernels over it at
    /// `batch = tile`, and scatters the `[rows, tile]` result into its
    /// disjoint column range of `Y`.  Per-element accumulation order is
    /// unchanged (ascending non-zeros), so the result is bit-for-bit
    /// identical to [`Engine::spmm`] over the materialized `X`, at any
    /// thread count and tile width.
    pub fn spmm_fused_into<K, P>(&self, kernel: &K, src: &P, y: &mut Vec<f32>)
    where
        K: SparseKernel + ?Sized,
        P: PanelSource + ?Sized,
    {
        let (rows, cols) = kernel.dims();
        assert_eq!(cols, src.k_rows(), "panel rows must match kernel cols");
        let total = src.num_cols();
        y.clear();
        y.resize(rows * total, 0.0);
        if rows == 0 || total == 0 {
            return;
        }
        let tile = self.tile_cols.max(LANE);
        let npanels = total.div_ceil(tile);
        let workers = self.threads.min(npanels);
        let pool = match &self.pool {
            Some(pool) if workers > 1 => pool,
            _ => {
                let mut panel = Vec::new();
                let mut outp = Vec::new();
                for i in 0..npanels {
                    let j0 = i * tile;
                    let width = (total - j0).min(tile);
                    panel_product(kernel, src, j0, width, &mut panel, &mut outp);
                    for r in 0..rows {
                        y[r * total + j0..r * total + j0 + width]
                            .copy_from_slice(&outp[r * width..(r + 1) * width]);
                    }
                }
                return;
            }
        };
        let ptr = SyncPtr(y.as_mut_ptr());
        let ptr = &ptr;
        pool.broadcast(|ctx| {
            let w = ctx.index();
            if w >= workers {
                return;
            }
            let mut panel = Vec::new();
            let mut outp = Vec::new();
            // stride assignment: panel i -> worker i % workers, the same
            // rule the row dispatch uses
            let mut i = w;
            while i < npanels {
                let j0 = i * tile;
                let width = (total - j0).min(tile);
                panel_product(kernel, src, j0, width, &mut panel, &mut outp);
                for r in 0..rows {
                    // SAFETY: panels cover disjoint column ranges and each
                    // panel is visited by exactly one worker, so these row
                    // segments never alias; `y` outlives the broadcast.
                    let dst = unsafe {
                        std::slice::from_raw_parts_mut(ptr.0.add(r * total + j0), width)
                    };
                    dst.copy_from_slice(&outp[r * width..(r + 1) * width]);
                }
                i += workers;
            }
        });
    }

    /// Mat-vec through the threaded dispatcher.
    pub fn spmv<K: SparseKernel + ?Sized>(&self, kernel: &K, x: &[f32]) -> Vec<f32> {
        self.spmm(kernel, x, 1)
    }

    /// The offline load-balance prediction for this engine's dispatch of
    /// `kernel`: stride-assigned unit costs, same model as
    /// [`reorder::load_balance`](super::reorder::load_balance).
    pub fn predicted_balance<K: SparseKernel + ?Sized>(&self, kernel: &K) -> LoadBalance {
        let units = self.dispatch_units(kernel);
        let costs: Vec<usize> = units.iter().map(|u| u.cost).collect();
        let order: Vec<usize> = (0..costs.len()).collect();
        load_balance(&costs, &order, self.threads)
    }

    /// Actual per-worker cost split of the dispatch (for tests asserting
    /// the prediction matches reality).
    pub fn worker_costs<K: SparseKernel + ?Sized>(&self, kernel: &K) -> Vec<usize> {
        let units = self.dispatch_units(kernel);
        let workers = self.threads.min(units.len()).max(1);
        let mut costs = vec![0usize; workers];
        for (i, u) in units.iter().enumerate() {
            costs[stride_worker(i, workers)] += u.cost;
        }
        costs
    }
}

/// Fill one `[cols, width]` panel from `src` and compute the kernel's full
/// `[rows, width]` product over it (scratch buffers reused by the caller
/// across panels).
fn panel_product<K, P>(
    kernel: &K,
    src: &P,
    j0: usize,
    width: usize,
    panel: &mut Vec<f32>,
    outp: &mut Vec<f32>,
) where
    K: SparseKernel + ?Sized,
    P: PanelSource + ?Sized,
{
    let (rows, cols) = kernel.dims();
    panel.clear();
    panel.resize(cols * width, 0.0);
    src.fill(j0, width, panel);
    outp.clear();
    outp.resize(rows * width, 0.0);
    kernel.run_rows(panel, width, 0, rows, outp);
}

/// Pack per-sample input vectors (each `cols` long) into the
/// `[cols, batch]` row-major layout [`SparseKernel::spmm`] consumes.
pub fn pack_columns(columns: &[Vec<f32>]) -> Vec<f32> {
    let batch = columns.len();
    if batch == 0 {
        return Vec::new();
    }
    let cols = columns[0].len();
    let mut x = vec![0.0f32; cols * batch];
    for (b, col) in columns.iter().enumerate() {
        assert_eq!(col.len(), cols, "ragged batch");
        for (c, &v) in col.iter().enumerate() {
            x[c * batch + b] = v;
        }
    }
    x
}

/// Extract output column `b` from a `[rows, batch]` result.
pub fn unpack_column(y: &[f32], batch: usize, b: usize) -> Vec<f32> {
    assert!(b < batch.max(1));
    y.iter().skip(b).step_by(batch.max(1)).copied().collect()
}

#[cfg(test)]
mod tests {
    use super::super::{Bcs, Csr};
    use super::*;
    use crate::pruning::{prune, PatternLibrary, Scheme};
    use crate::rng::Rng;

    fn block_pruned(rows: usize, cols: usize, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let w = Tensor::he_normal(&[rows, cols], cols, &mut rng);
        let r = prune(&w, &Scheme::Block { bp: 8, bq: 8 }, 4.0, &PatternLibrary::default8());
        w.hadamard(&r.mask)
    }

    #[test]
    fn backends_agree_with_dense_reference() {
        let t = block_pruned(64, 48, 1);
        let dense = DenseKernel::from_tensor(&t);
        let csr = Csr::from_dense(&t);
        let bcs = Bcs::from_dense(&t);
        let mut rng = Rng::new(2);
        let batch = 5;
        let x: Vec<f32> = (0..48 * batch).map(|_| rng.normal()).collect();
        let yd = dense.spmm(&x, batch);
        let yc = csr.spmm(&x, batch);
        let yb = bcs.spmm(&x, batch);
        assert_eq!(yd.len(), 64 * batch);
        for i in 0..yd.len() {
            assert!((yd[i] - yc[i]).abs() < 1e-4, "csr[{i}]");
            assert!((yd[i] - yb[i]).abs() < 1e-4, "bcs[{i}]");
        }
    }

    #[test]
    fn simd_lanes_match_scalar_reference() {
        // the lockdown: the lane rewrite is bit-identical to the scalar
        // loop at every batch width, lane-aligned or not
        let t = block_pruned(96, 64, 3);
        for kernel in [
            Box::new(Bcs::from_dense(&t)) as Box<dyn SparseKernel>,
            Box::new(Csr::from_dense(&t)),
            Box::new(DenseKernel::from_tensor(&t)),
        ] {
            let mut rng = Rng::new(4);
            for batch in [1usize, 7, 8, 9, 33] {
                let x: Vec<f32> = (0..64 * batch).map(|_| rng.normal()).collect();
                assert_eq!(
                    kernel.spmm(&x, batch),
                    kernel.spmm_scalar(&x, batch),
                    "{} batch={batch}",
                    kernel.label()
                );
            }
        }
    }

    #[test]
    fn threaded_bit_for_bit_serial() {
        let t = block_pruned(96, 64, 3);
        let bcs = Bcs::from_dense(&t);
        let mut rng = Rng::new(4);
        let batch = 7;
        let x: Vec<f32> = (0..64 * batch).map(|_| rng.normal()).collect();
        let serial = Engine::serial().spmm(&bcs, &x, batch);
        for threads in [2, 3, 4, 8, 33] {
            let y = Engine::new(threads).spmm(&bcs, &x, batch);
            assert_eq!(serial, y, "threads={threads}");
        }
    }

    #[test]
    fn persistent_pool_is_reused_across_products() {
        // one engine, many spmm calls: the pool survives and stays correct
        let t = block_pruned(64, 48, 9);
        let bcs = Bcs::from_dense(&t);
        let eng = Engine::new(4);
        let mut rng = Rng::new(10);
        for batch in [1usize, 3, 8, 12] {
            let x: Vec<f32> = (0..48 * batch).map(|_| rng.normal()).collect();
            assert_eq!(eng.spmm(&bcs, &x, batch), bcs.spmm(&x, batch), "batch={batch}");
        }
        // a cloned engine shares the same pool (Arc) and stays correct
        let eng2 = eng.clone();
        let x: Vec<f32> = (0..48).map(|_| rng.normal()).collect();
        assert_eq!(eng2.spmv(&bcs, &x), bcs.spmv(&x));
    }

    #[test]
    fn spmm_columns_match_spmv() {
        let t = block_pruned(40, 40, 5);
        let bcs = Bcs::from_dense(&t);
        let mut rng = Rng::new(6);
        let cols: Vec<Vec<f32>> = (0..9)
            .map(|_| (0..40).map(|_| rng.normal()).collect())
            .collect();
        let x = pack_columns(&cols);
        let y = Engine::new(4).spmm(&bcs, &x, 9);
        for (b, col) in cols.iter().enumerate() {
            // inherent serial scalar spmv: the bit-for-bit reference
            assert_eq!(unpack_column(&y, 9, b), bcs.spmv(col), "column {b}");
        }
    }

    #[test]
    fn fused_panels_match_materialized_spmm() {
        let t = block_pruned(48, 32, 11);
        let bcs = Bcs::from_dense(&t);
        let mut rng = Rng::new(12);
        for total in [1usize, 7, 8, 40, 300] {
            let x: Vec<f32> = (0..32 * total).map(|_| rng.normal()).collect();
            let src = SlicePanels::new(&x, 32, total);
            let want = Engine::serial().spmm(&bcs, &x, total);
            for (threads, tile) in [(1usize, 8usize), (1, 256), (4, 8), (4, 24), (4, 256)] {
                let eng = Engine::new(threads).with_tile_cols(tile);
                assert_eq!(
                    eng.spmm_fused(&bcs, &src),
                    want,
                    "total={total} threads={threads} tile={tile}"
                );
            }
        }
    }

    #[test]
    fn spmm_into_reuses_and_zeroes_the_buffer() {
        let t = block_pruned(32, 32, 13);
        let bcs = Bcs::from_dense(&t);
        let x: Vec<f32> = (0..32 * 3).map(|i| (i as f32).sin()).collect();
        let want = bcs.spmm(&x, 3);
        let mut y = vec![f32::NAN; 512]; // stale garbage, larger than needed
        Engine::new(2).spmm_into(&bcs, &x, 3, &mut y);
        assert_eq!(y, want, "stale buffer contents must never leak");
    }

    #[test]
    fn work_units_cover_rows_exactly() {
        let t = block_pruned(50, 30, 7);
        for kernel in [
            Box::new(Bcs::from_dense(&t)) as Box<dyn SparseKernel>,
            Box::new(Csr::from_dense(&t)),
            Box::new(DenseKernel::from_tensor(&t)),
        ] {
            let units = kernel.work_units();
            let mut next = 0usize;
            for u in &units {
                assert_eq!(u.r0, next, "{}: gap/overlap", kernel.label());
                assert!(u.r1 > u.r0);
                next = u.r1;
            }
            assert_eq!(next, 50, "{}", kernel.label());
        }
    }

    #[test]
    fn dispatch_splits_single_long_run() {
        // uniform column pattern -> a single occurrence-run; the engine
        // must still distribute it
        let mut t = Tensor::zeros(&[256, 16]);
        for r in 0..256 {
            t.set2(r, 3, 1.0);
            t.set2(r, 7, -1.0);
        }
        let bcs = Bcs::from_dense(&t);
        assert_eq!(bcs.work_units().len(), 1);
        let eng = Engine::new(4);
        assert!(eng.dispatch_units(&bcs).len() >= 4);
        let costs = eng.worker_costs(&bcs);
        assert!(costs.iter().all(|&c| c > 0), "idle worker: {costs:?}");
        // and results still match serial
        let x: Vec<f32> = (0..16).map(|i| i as f32).collect();
        assert_eq!(eng.spmv(&bcs, &x), bcs.spmv(&x));
    }

    #[test]
    fn predicted_balance_matches_actual_dispatch() {
        let t = block_pruned(128, 96, 8);
        let bcs = Bcs::from_dense(&t);
        let eng = Engine::new(4);
        let predicted = eng.predicted_balance(&bcs);
        let costs = eng.worker_costs(&bcs);
        let total: usize = costs.iter().sum();
        let mean = total as f32 / costs.len() as f32;
        let max = *costs.iter().max().unwrap() as f32;
        let actual = if mean > 0.0 { max / mean } else { 1.0 };
        assert!(
            (predicted.imbalance - actual).abs() < 1e-6,
            "predicted {} vs actual {}",
            predicted.imbalance,
            actual
        );
    }

    #[test]
    fn zero_rows_and_empty_batch() {
        let t = Tensor::zeros(&[0, 8]);
        let bcs = Bcs::from_dense(&t);
        assert_eq!(bcs.dims(), (0, 8));
        let y = Engine::new(4).spmm(&bcs, &[0.0; 24], 3);
        assert!(y.is_empty());
        let t2 = Tensor::zeros(&[4, 4]);
        let y2 = Engine::new(2).spmm(&Bcs::from_dense(&t2), &[], 0);
        assert!(y2.is_empty());
        // fused path over a zero-row / zero-column source
        let src = SlicePanels::new(&[], 8, 0);
        assert!(Engine::new(2).spmm_fused(&bcs, &src).is_empty());
    }

    #[test]
    fn align_to_lane_rounds_up() {
        assert_eq!(align_to_lane(0), LANE);
        assert_eq!(align_to_lane(1), LANE);
        assert_eq!(align_to_lane(LANE), LANE);
        assert_eq!(align_to_lane(LANE + 1), 2 * LANE);
        assert_eq!(align_to_lane(3 * LANE), 3 * LANE);
    }

    #[test]
    fn tile_cols_rounds_to_lane_multiples() {
        assert_eq!(Engine::serial().with_tile_cols(1).tile_cols(), LANE);
        assert_eq!(Engine::serial().with_tile_cols(8).tile_cols(), 8);
        assert_eq!(Engine::serial().with_tile_cols(9).tile_cols(), 16);
        assert_eq!(Engine::serial().with_tile_cols(250).tile_cols(), 256);
        assert_eq!(Engine::serial().tile_cols(), DEFAULT_TILE_COLS);
        assert_eq!(DEFAULT_TILE_COLS % LANE, 0);
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let cols = vec![vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]];
        let x = pack_columns(&cols);
        assert_eq!(x, vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0]);
        assert_eq!(unpack_column(&x, 2, 0), cols[0]);
        assert_eq!(unpack_column(&x, 2, 1), cols[1]);
    }
}
