//! Batched multi-threaded sparse execution engine.
//!
//! The paper's speedups come from compiler-generated kernels that run the
//! BCS format over multi-threaded SIMD hardware; the seed repo only modeled
//! that execution in the simulator.  This module is the real code path:
//!
//! * [`SparseKernel`] — the execution contract: a sparse (or dense
//!   reference) matrix that can compute any row range of `Y = A · X` for a
//!   batched right-hand side (`X` is `[cols, batch]` row-major, one
//!   activation column per sample, exactly the GEMM view the compiler
//!   produces from im2col);
//! * backends — [`DenseKernel`] (reference), [`Csr`](super::Csr), and
//!   [`Bcs`](super::Bcs), the latter dispatching whole occurrence-runs so
//!   the compact column list is resolved once per run;
//! * [`Engine`] — rayon-based threaded dispatch.  Work units (BCS
//!   occurrence-runs; rows for CSR/dense) are assigned to workers by the
//!   same **stride rule** `unit i → worker i % threads` that
//!   [`reorder`](super::reorder) models, so
//!   [`LoadBalance`](super::LoadBalance) statistics computed offline
//!   predict the real per-thread work of this engine.
//!
//! Determinism: a row's dot products are always accumulated in the same
//! element order regardless of thread count or batch size, so
//! `Engine::spmm` with N threads is **bit-for-bit identical** to the serial
//! column-by-column `spmv` of the same backend.

use crate::tensor::Tensor;

use super::reorder::{load_balance, stride_worker, LoadBalance};

/// A contiguous row range plus its cost (retained non-zeros), the unit of
/// thread dispatch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkUnit {
    /// First row (inclusive).
    pub r0: usize,
    /// Last row (exclusive).
    pub r1: usize,
    /// Work estimate: non-zeros in the range (MACs per batch column).
    pub cost: usize,
}

/// The execution contract every sparse backend implements.
///
/// `X` is `[cols, batch]` row-major (`x[c * batch + b]` is element `c` of
/// sample `b`); `Y` is `[rows, batch]`.  With `batch == 1` this degenerates
/// to SpMV.
pub trait SparseKernel: Sync {
    /// (rows, cols) of the operator.
    fn dims(&self) -> (usize, usize);

    /// Retained non-zeros.
    fn nnz(&self) -> usize;

    /// Short display name for benches and reports.
    fn label(&self) -> &'static str;

    /// Dispatchable work units covering `0..rows` exactly once, in row
    /// order.  BCS returns its occurrence-runs; CSR/dense return rows.
    fn work_units(&self) -> Vec<WorkUnit>;

    /// Compute rows `r0..r1` of `Y = A · X` into `out` (length
    /// `(r1 - r0) * batch`, **zero-initialized** by the caller, row-major
    /// relative to `r0`).  Implementations must accumulate each output
    /// element in ascending non-zero order so results are bit-identical
    /// across dispatch strategies.
    fn run_rows(&self, x: &[f32], batch: usize, r0: usize, r1: usize, out: &mut [f32]);

    /// Serial batched product `Y = A · X`.
    fn spmm(&self, x: &[f32], batch: usize) -> Vec<f32> {
        let (rows, cols) = self.dims();
        assert_eq!(x.len(), cols * batch, "X must be [cols, batch] row-major");
        let mut y = vec![0.0f32; rows * batch];
        for u in self.work_units() {
            self.run_rows(x, batch, u.r0, u.r1, &mut y[u.r0 * batch..u.r1 * batch]);
        }
        y
    }

    /// Serial mat-vec (batch = 1 spmm).
    fn spmv_exec(&self, x: &[f32]) -> Vec<f32> {
        self.spmm(x, 1)
    }
}

/// Dense row-major reference backend: every element is touched, zeros
/// included — the baseline sparse backends are validated against.
#[derive(Debug, Clone)]
pub struct DenseKernel {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl DenseKernel {
    pub fn from_tensor(t: &Tensor) -> DenseKernel {
        assert_eq!(t.ndim(), 2);
        DenseKernel {
            rows: t.shape()[0],
            cols: t.shape()[1],
            data: t.data().to_vec(),
        }
    }
}

impl SparseKernel for DenseKernel {
    fn dims(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    fn nnz(&self) -> usize {
        self.data.iter().filter(|v| **v != 0.0).count()
    }

    fn label(&self) -> &'static str {
        "dense"
    }

    fn work_units(&self) -> Vec<WorkUnit> {
        (0..self.rows)
            .map(|r| WorkUnit { r0: r, r1: r + 1, cost: self.cols })
            .collect()
    }

    fn run_rows(&self, x: &[f32], batch: usize, r0: usize, r1: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), (r1 - r0) * batch);
        for r in r0..r1 {
            let row = &self.data[r * self.cols..(r + 1) * self.cols];
            let orow = &mut out[(r - r0) * batch..(r - r0 + 1) * batch];
            for (c, &w) in row.iter().enumerate() {
                let xrow = &x[c * batch..(c + 1) * batch];
                for (o, &xv) in orow.iter_mut().zip(xrow) {
                    *o += w * xv;
                }
            }
        }
    }
}

/// `y.as_mut_ptr()` smuggled across rayon workers.  Sound because each
/// worker writes only the disjoint `[r0 * batch, r1 * batch)` spans of the
/// units it owns (units partition the rows; the stride assignment
/// partitions the units).
struct SyncPtr(*mut f32);

unsafe impl Send for SyncPtr {}
unsafe impl Sync for SyncPtr {}

/// Multi-threaded dispatcher over any [`SparseKernel`].
///
/// Unit `i` goes to worker `i % threads` — the stride assignment
/// [`reorder::load_balance`](super::reorder::load_balance) models — so the
/// offline [`LoadBalance`] report for a matrix is a prediction of this
/// engine's thread utilization (see [`Engine::predicted_balance`]).
#[derive(Debug, Clone, Copy)]
pub struct Engine {
    threads: usize,
}

impl Engine {
    pub fn new(threads: usize) -> Engine {
        Engine { threads: threads.max(1) }
    }

    /// Single-threaded engine (identical output, no rayon dispatch).
    pub fn serial() -> Engine {
        Engine::new(1)
    }

    /// One worker per available core.
    pub fn max_parallel() -> Engine {
        Engine::new(rayon::current_num_threads())
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Dispatch units: the backend's work units, with oversized runs split
    /// so a single long occurrence-run (e.g. a uniform-pattern matrix)
    /// cannot serialize the whole product.  Splitting never changes
    /// results — rows are computed identically wherever they land.
    pub fn dispatch_units<K: SparseKernel + ?Sized>(&self, kernel: &K) -> Vec<WorkUnit> {
        let (rows, _) = kernel.dims();
        let units = kernel.work_units();
        if self.threads == 1 || rows == 0 {
            return units;
        }
        let max_rows = rows.div_ceil(self.threads * 8).max(1);
        let mut out = Vec::with_capacity(units.len());
        for u in units {
            let span = u.r1 - u.r0;
            if span <= max_rows {
                out.push(u);
                continue;
            }
            let mut r = u.r0;
            while r < u.r1 {
                let e = (r + max_rows).min(u.r1);
                out.push(WorkUnit { r0: r, r1: e, cost: u.cost * (e - r) / span });
                r = e;
            }
        }
        out
    }

    /// Batched product `Y = A · X` (`X` is `[cols, batch]` row-major).
    /// Bit-for-bit identical to the serial [`SparseKernel::spmm`] at any
    /// thread count.
    pub fn spmm<K: SparseKernel + ?Sized>(&self, kernel: &K, x: &[f32], batch: usize) -> Vec<f32> {
        let (rows, cols) = kernel.dims();
        assert_eq!(x.len(), cols * batch, "X must be [cols, batch] row-major");
        let mut y = vec![0.0f32; rows * batch];
        let units = self.dispatch_units(kernel);
        let workers = self.threads.min(units.len());
        if workers <= 1 {
            for u in &units {
                kernel.run_rows(x, batch, u.r0, u.r1, &mut y[u.r0 * batch..u.r1 * batch]);
            }
            return y;
        }
        let ptr = SyncPtr(y.as_mut_ptr());
        rayon::scope(|s| {
            let units = &units;
            let ptr = &ptr;
            for w in 0..workers {
                s.spawn(move |_| {
                    // stride assignment: unit i -> worker i % workers
                    for u in units.iter().skip(w).step_by(workers) {
                        let len = (u.r1 - u.r0) * batch;
                        // SAFETY: units cover disjoint row ranges and each
                        // unit is visited by exactly one worker, so these
                        // slices never alias; `y` outlives the scope.
                        let out = unsafe {
                            std::slice::from_raw_parts_mut(ptr.0.add(u.r0 * batch), len)
                        };
                        kernel.run_rows(x, batch, u.r0, u.r1, out);
                    }
                });
            }
        });
        y
    }

    /// Mat-vec through the threaded dispatcher.
    pub fn spmv<K: SparseKernel + ?Sized>(&self, kernel: &K, x: &[f32]) -> Vec<f32> {
        self.spmm(kernel, x, 1)
    }

    /// The offline load-balance prediction for this engine's dispatch of
    /// `kernel`: stride-assigned unit costs, same model as
    /// [`reorder::load_balance`](super::reorder::load_balance).
    pub fn predicted_balance<K: SparseKernel + ?Sized>(&self, kernel: &K) -> LoadBalance {
        let units = self.dispatch_units(kernel);
        let costs: Vec<usize> = units.iter().map(|u| u.cost).collect();
        let order: Vec<usize> = (0..costs.len()).collect();
        load_balance(&costs, &order, self.threads)
    }

    /// Actual per-worker cost split of the dispatch (for tests asserting
    /// the prediction matches reality).
    pub fn worker_costs<K: SparseKernel + ?Sized>(&self, kernel: &K) -> Vec<usize> {
        let units = self.dispatch_units(kernel);
        let workers = self.threads.min(units.len()).max(1);
        let mut costs = vec![0usize; workers];
        for (i, u) in units.iter().enumerate() {
            costs[stride_worker(i, workers)] += u.cost;
        }
        costs
    }
}

/// Pack per-sample input vectors (each `cols` long) into the
/// `[cols, batch]` row-major layout [`SparseKernel::spmm`] consumes.
pub fn pack_columns(columns: &[Vec<f32>]) -> Vec<f32> {
    let batch = columns.len();
    if batch == 0 {
        return Vec::new();
    }
    let cols = columns[0].len();
    let mut x = vec![0.0f32; cols * batch];
    for (b, col) in columns.iter().enumerate() {
        assert_eq!(col.len(), cols, "ragged batch");
        for (c, &v) in col.iter().enumerate() {
            x[c * batch + b] = v;
        }
    }
    x
}

/// Extract output column `b` from a `[rows, batch]` result.
pub fn unpack_column(y: &[f32], batch: usize, b: usize) -> Vec<f32> {
    assert!(b < batch.max(1));
    y.iter().skip(b).step_by(batch.max(1)).copied().collect()
}

#[cfg(test)]
mod tests {
    use super::super::{Bcs, Csr};
    use super::*;
    use crate::pruning::{prune, PatternLibrary, Scheme};
    use crate::rng::Rng;

    fn block_pruned(rows: usize, cols: usize, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let w = Tensor::he_normal(&[rows, cols], cols, &mut rng);
        let r = prune(&w, &Scheme::Block { bp: 8, bq: 8 }, 4.0, &PatternLibrary::default8());
        w.hadamard(&r.mask)
    }

    #[test]
    fn backends_agree_with_dense_reference() {
        let t = block_pruned(64, 48, 1);
        let dense = DenseKernel::from_tensor(&t);
        let csr = Csr::from_dense(&t);
        let bcs = Bcs::from_dense(&t);
        let mut rng = Rng::new(2);
        let batch = 5;
        let x: Vec<f32> = (0..48 * batch).map(|_| rng.normal()).collect();
        let yd = dense.spmm(&x, batch);
        let yc = csr.spmm(&x, batch);
        let yb = bcs.spmm(&x, batch);
        assert_eq!(yd.len(), 64 * batch);
        for i in 0..yd.len() {
            assert!((yd[i] - yc[i]).abs() < 1e-4, "csr[{i}]");
            assert!((yd[i] - yb[i]).abs() < 1e-4, "bcs[{i}]");
        }
    }

    #[test]
    fn threaded_bit_for_bit_serial() {
        let t = block_pruned(96, 64, 3);
        let bcs = Bcs::from_dense(&t);
        let mut rng = Rng::new(4);
        let batch = 7;
        let x: Vec<f32> = (0..64 * batch).map(|_| rng.normal()).collect();
        let serial = Engine::serial().spmm(&bcs, &x, batch);
        for threads in [2, 3, 4, 8, 33] {
            let y = Engine::new(threads).spmm(&bcs, &x, batch);
            assert_eq!(serial, y, "threads={threads}");
        }
    }

    #[test]
    fn spmm_columns_match_spmv() {
        let t = block_pruned(40, 40, 5);
        let bcs = Bcs::from_dense(&t);
        let mut rng = Rng::new(6);
        let cols: Vec<Vec<f32>> = (0..9)
            .map(|_| (0..40).map(|_| rng.normal()).collect())
            .collect();
        let x = pack_columns(&cols);
        let y = Engine::new(4).spmm(&bcs, &x, 9);
        for (b, col) in cols.iter().enumerate() {
            // inherent serial scalar spmv: the bit-for-bit reference
            assert_eq!(unpack_column(&y, 9, b), bcs.spmv(col), "column {b}");
        }
    }

    #[test]
    fn work_units_cover_rows_exactly() {
        let t = block_pruned(50, 30, 7);
        for kernel in [
            Box::new(Bcs::from_dense(&t)) as Box<dyn SparseKernel>,
            Box::new(Csr::from_dense(&t)),
            Box::new(DenseKernel::from_tensor(&t)),
        ] {
            let units = kernel.work_units();
            let mut next = 0usize;
            for u in &units {
                assert_eq!(u.r0, next, "{}: gap/overlap", kernel.label());
                assert!(u.r1 > u.r0);
                next = u.r1;
            }
            assert_eq!(next, 50, "{}", kernel.label());
        }
    }

    #[test]
    fn dispatch_splits_single_long_run() {
        // uniform column pattern -> a single occurrence-run; the engine
        // must still distribute it
        let mut t = Tensor::zeros(&[256, 16]);
        for r in 0..256 {
            t.set2(r, 3, 1.0);
            t.set2(r, 7, -1.0);
        }
        let bcs = Bcs::from_dense(&t);
        assert_eq!(bcs.work_units().len(), 1);
        let eng = Engine::new(4);
        assert!(eng.dispatch_units(&bcs).len() >= 4);
        let costs = eng.worker_costs(&bcs);
        assert!(costs.iter().all(|&c| c > 0), "idle worker: {costs:?}");
        // and results still match serial
        let x: Vec<f32> = (0..16).map(|i| i as f32).collect();
        assert_eq!(eng.spmv(&bcs, &x), bcs.spmv(&x));
    }

    #[test]
    fn predicted_balance_matches_actual_dispatch() {
        let t = block_pruned(128, 96, 8);
        let bcs = Bcs::from_dense(&t);
        let eng = Engine::new(4);
        let predicted = eng.predicted_balance(&bcs);
        let costs = eng.worker_costs(&bcs);
        let total: usize = costs.iter().sum();
        let mean = total as f32 / costs.len() as f32;
        let max = *costs.iter().max().unwrap() as f32;
        let actual = if mean > 0.0 { max / mean } else { 1.0 };
        assert!(
            (predicted.imbalance - actual).abs() < 1e-6,
            "predicted {} vs actual {}",
            predicted.imbalance,
            actual
        );
    }

    #[test]
    fn zero_rows_and_empty_batch() {
        let t = Tensor::zeros(&[0, 8]);
        let bcs = Bcs::from_dense(&t);
        assert_eq!(bcs.dims(), (0, 8));
        let y = Engine::new(4).spmm(&bcs, &[0.0; 24], 3);
        assert!(y.is_empty());
        let t2 = Tensor::zeros(&[4, 4]);
        let y2 = Engine::new(2).spmm(&Bcs::from_dense(&t2), &[], 0);
        assert!(y2.is_empty());
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let cols = vec![vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]];
        let x = pack_columns(&cols);
        assert_eq!(x, vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0]);
        assert_eq!(unpack_column(&x, 2, 0), cols[0]);
        assert_eq!(unpack_column(&x, 2, 1), cols[1]);
    }
}
