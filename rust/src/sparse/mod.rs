//! Sparse weight storage: CSR and the paper's Blocked Compressed Storage
//! (BCS, §4.3 / Fig. 4), plus the row-reordering optimization that the
//! compiler uses for thread load balance.

pub mod bcs;
pub mod csr;
pub mod reorder;

pub use bcs::Bcs;
pub use csr::Csr;
pub use reorder::{load_balance, permute_rows, reorder_rows, row_nnz_counts, LoadBalance};
