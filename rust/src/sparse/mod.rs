//! Sparse weight storage and execution: CSR and the paper's Blocked
//! Compressed Storage (BCS, §4.3 / Fig. 4), the row-reordering optimization
//! the compiler uses for thread load balance, and the batched
//! multi-threaded execution engine that actually runs them ([`exec`]).

pub mod bcs;
pub mod csr;
pub mod exec;
pub mod reorder;

pub use bcs::Bcs;
pub use csr::Csr;
pub use exec::{
    align_to_lane, pack_columns, unpack_column, DenseKernel, Engine, PanelSource, SlicePanels,
    SparseKernel, WorkUnit, DEFAULT_TILE_COLS, LANE,
};
pub use reorder::{
    load_balance, permute_rows, reorder_rows, row_nnz_counts, stride_worker, LoadBalance,
};
