//! Row reordering for thread load balance (paper §4.3).
//!
//! After pruning, rows have unequal non-zero counts; naive row-to-thread
//! assignment diverges.  The compiler groups rows with identical/similar
//! nnz so consecutive rows (processed by the same SIMD thread group) carry
//! equal work, eliminating divergence and enabling multi-row unrolling.

use crate::tensor::Tensor;

/// Load-balance statistics over a row-to-thread partition.
#[derive(Debug, Clone, Copy)]
pub struct LoadBalance {
    /// max(thread work) / mean(thread work); 1.0 = perfectly balanced.
    pub imbalance: f32,
    /// number of distinct nnz values among consecutive row groups — a
    /// proxy for branch count in generated code.
    pub pattern_switches: usize,
}

/// The stride rule shared by this model and the real executor
/// ([`Engine`](super::exec::Engine)): work unit at position `pos` goes to
/// worker `pos % threads`.
pub fn stride_worker(pos: usize, threads: usize) -> usize {
    pos % threads.max(1)
}

/// Compute load balance of the given row order for `threads` threads.
/// Assignment is strided — position `i` goes to thread `i % threads`
/// ([`stride_worker`]) — matching the paper's "continuous rows ...
/// processed by multi-threads simultaneously": each wave of `threads`
/// consecutive rows runs in parallel, so equal-nnz neighbours mean equal
/// per-wave work.  `Engine::predicted_balance` feeds its dispatch units
/// through this same function, so these statistics predict real thread
/// work, not just modeled work.
pub fn load_balance(row_nnz: &[usize], order: &[usize], threads: usize) -> LoadBalance {
    assert_eq!(row_nnz.len(), order.len());
    let n = order.len();
    let threads = threads.max(1).min(n.max(1));
    let mut work = vec![0usize; threads];
    for (pos, &r) in order.iter().enumerate() {
        work[stride_worker(pos, threads)] += row_nnz[r];
    }
    let total: usize = work.iter().sum();
    let mean = total as f32 / threads as f32;
    let max = *work.iter().max().unwrap_or(&0) as f32;
    let imbalance = if mean > 0.0 { max / mean } else { 1.0 };

    let mut switches = 0;
    for w in order.windows(2) {
        if row_nnz[w[0]] != row_nnz[w[1]] {
            switches += 1;
        }
    }
    LoadBalance { imbalance, pattern_switches: switches }
}

/// Reorder rows so identical column *patterns* become adjacent (maximizing
/// BCS occurrence-run length), with patterns ordered by descending nnz so
/// equal-work rows neighbour each other.  Returns the permutation `order`.
pub fn reorder_rows(t: &Tensor) -> Vec<usize> {
    assert_eq!(t.ndim(), 2);
    let rows = t.shape()[0];
    let cols = t.shape()[1];
    let data = t.data();
    // §Perf: one shared pattern arena + (nnz, hash) pre-keys instead of a
    // per-row Vec and full lexicographic compares on every sort step
    let mut arena: Vec<u32> = Vec::new();
    let mut spans: Vec<(usize, usize)> = Vec::with_capacity(rows);
    let mut keyed: Vec<(usize, usize, u64)> = Vec::with_capacity(rows); // (row, nnz, hash)
    for r in 0..rows {
        let start = arena.len();
        let mut hash = 0xcbf2_9ce4_8422_2325u64;
        for (c, v) in data[r * cols..(r + 1) * cols].iter().enumerate() {
            if *v != 0.0 {
                arena.push(c as u32);
                hash = (hash ^ c as u64).wrapping_mul(0x1000_0000_01b3);
            }
        }
        spans.push((start, arena.len()));
        keyed.push((r, arena.len() - start, hash));
    }
    keyed.sort_unstable_by(|a, b| {
        b.1.cmp(&a.1)
            .then(a.2.cmp(&b.2))
            .then_with(|| {
                let pa = &arena[spans[a.0].0..spans[a.0].1];
                let pb = &arena[spans[b.0].0..spans[b.0].1];
                pa.cmp(pb)
            })
            .then(a.0.cmp(&b.0))
    });
    keyed.into_iter().map(|(r, _, _)| r).collect()
}

/// Apply a row permutation: `out[i] = t[order[i]]`.
pub fn permute_rows(t: &Tensor, order: &[usize]) -> Tensor {
    assert_eq!(t.ndim(), 2);
    let (rows, cols) = (t.shape()[0], t.shape()[1]);
    assert_eq!(order.len(), rows);
    let mut out = Tensor::zeros(&[rows, cols]);
    for (i, &r) in order.iter().enumerate() {
        for c in 0..cols {
            out.set2(i, c, t.at2(r, c));
        }
    }
    out
}

/// Row nnz counts of a 2-D tensor.
pub fn row_nnz_counts(t: &Tensor) -> Vec<usize> {
    assert_eq!(t.ndim(), 2);
    let cols = t.shape()[1];
    (0..t.shape()[0])
        .map(|r| (0..cols).filter(|&c| t.at2(r, c) != 0.0).count())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn ragged_tensor(seed: u64) -> Tensor {
        // rows with wildly different nnz
        let mut rng = Rng::new(seed);
        let mut t = Tensor::zeros(&[64, 64]);
        for r in 0..64 {
            let density = if r % 4 == 0 { 0.9 } else { 0.1 };
            for c in 0..64 {
                if rng.bernoulli(density) {
                    t.set2(r, c, rng.normal());
                }
            }
        }
        t
    }

    #[test]
    fn reorder_is_permutation() {
        let t = ragged_tensor(1);
        let order = reorder_rows(&t);
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn reorder_sorts_by_nnz_desc() {
        let t = ragged_tensor(2);
        let nnz = row_nnz_counts(&t);
        let order = reorder_rows(&t);
        for w in order.windows(2) {
            assert!(nnz[w[0]] >= nnz[w[1]]);
        }
    }

    #[test]
    fn reordering_improves_balance() {
        let t = ragged_tensor(3);
        let nnz = row_nnz_counts(&t);
        let identity: Vec<usize> = (0..64).collect();
        let before = load_balance(&nnz, &identity, 8);
        let after = load_balance(&nnz, &reorder_rows(&t), 8);
        assert!(
            after.imbalance <= before.imbalance,
            "imbalance got worse: {} -> {}",
            before.imbalance,
            after.imbalance
        );
        assert!(after.pattern_switches <= before.pattern_switches);
    }

    #[test]
    fn perfect_balance_on_uniform_rows() {
        let mut t = Tensor::zeros(&[16, 16]);
        for r in 0..16 {
            for c in 0..4 {
                t.set2(r, c, 1.0);
            }
        }
        let nnz = row_nnz_counts(&t);
        let lb = load_balance(&nnz, &reorder_rows(&t), 4);
        assert!((lb.imbalance - 1.0).abs() < 1e-6);
        assert_eq!(lb.pattern_switches, 0);
    }

    #[test]
    fn degenerate_inputs() {
        let t = Tensor::zeros(&[4, 4]);
        let nnz = row_nnz_counts(&t);
        let lb = load_balance(&nnz, &reorder_rows(&t), 8);
        assert_eq!(lb.imbalance, 1.0);
    }
}
