//! Synthetic CIFAR-like dataset (the substitution for CIFAR-10/ImageNet in
//! the live training path — DESIGN.md §2).
//!
//! Each class is a fixed random spatial template (class "prototype"); a
//! sample is its class template plus pixel noise and a random brightness
//! shift.  Linearly separable enough to train the proxy CNN to high
//! accuracy in a few hundred steps, hard enough that an untrained model
//! sits at chance — which is all the end-to-end validation needs.

use crate::rng::Rng;

/// Generator for (image, label) batches.
#[derive(Debug, Clone)]
pub struct SynthDataset {
    /// Per-class template, each `elems` long.
    templates: Vec<Vec<f32>>,
    /// Elements per image (C*H*W).
    pub elems: usize,
    /// Pixel noise scale.
    pub noise: f32,
}

impl SynthDataset {
    /// Build with `classes` class templates over C*H*W = `elems`.
    pub fn new(classes: usize, elems: usize, noise: f32, seed: u64) -> SynthDataset {
        let mut rng = Rng::new(seed);
        let templates = (0..classes)
            .map(|_| (0..elems).map(|_| rng.normal()).collect())
            .collect();
        SynthDataset { templates, elems, noise }
    }

    /// CIFAR-shaped default: 10 classes, 3x32x32.
    pub fn cifar_like(seed: u64) -> SynthDataset {
        SynthDataset::new(10, 3 * 32 * 32, 0.6, seed)
    }

    pub fn classes(&self) -> usize {
        self.templates.len()
    }

    /// Sample a batch: returns (flattened images, labels).
    pub fn batch(&self, n: usize, rng: &mut Rng) -> (Vec<f32>, Vec<i32>) {
        let mut x = Vec::with_capacity(n * self.elems);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let cls = rng.below(self.classes());
            let brightness = rng.normal() * 0.2;
            for &t in &self.templates[cls] {
                x.push(t + rng.normal() * self.noise + brightness);
            }
            y.push(cls as i32);
        }
        (x, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_labels() {
        let ds = SynthDataset::cifar_like(1);
        let mut rng = Rng::new(2);
        let (x, y) = ds.batch(8, &mut rng);
        assert_eq!(x.len(), 8 * 3 * 32 * 32);
        assert_eq!(y.len(), 8);
        assert!(y.iter().all(|&l| (0..10).contains(&l)));
    }

    #[test]
    fn deterministic_templates() {
        let a = SynthDataset::cifar_like(7);
        let b = SynthDataset::cifar_like(7);
        assert_eq!(a.templates[0], b.templates[0]);
        let c = SynthDataset::cifar_like(8);
        assert_ne!(a.templates[0], c.templates[0]);
    }

    #[test]
    fn nearest_template_is_recoverable() {
        // a noiseless nearest-template classifier should get the label
        // right almost always at our noise level
        let ds = SynthDataset::cifar_like(3);
        let mut rng = Rng::new(4);
        let (x, y) = ds.batch(32, &mut rng);
        let mut correct = 0;
        for b in 0..32 {
            let img = &x[b * ds.elems..(b + 1) * ds.elems];
            let best = (0..ds.classes())
                .min_by(|&i, &j| {
                    let di: f32 = ds.templates[i]
                        .iter()
                        .zip(img)
                        .map(|(t, v)| (t - v) * (t - v))
                        .sum();
                    let dj: f32 = ds.templates[j]
                        .iter()
                        .zip(img)
                        .map(|(t, v)| (t - v) * (t - v))
                        .sum();
                    di.partial_cmp(&dj).unwrap()
                })
                .unwrap();
            correct += (best == y[b] as usize) as usize;
        }
        assert!(correct >= 30, "only {correct}/32 recoverable");
    }
}
