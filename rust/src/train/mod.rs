//! Training driver: executes the AOT train-step/forward artifacts over
//! PJRT on a synthetic CIFAR-like dataset.
//!
//! This is the *live* counterpart of the analytic accuracy model: the
//! end-to-end example trains the proxy CNN, runs reweighted-regularized
//! epochs with host-side alpha updates, one-shot prunes under a mapped
//! scheme, and masked-retrains — the paper's full pipeline at laptop scale.
//! Python never runs here: the artifacts were lowered once at build time.
//!
//! [`TrainDriver`] needs the PJRT runtime and is therefore compiled only
//! under `--cfg pjrt` (see [`crate::runtime`]); [`SynthDataset`] is always
//! available.

pub mod synth;

pub use synth::SynthDataset;

#[cfg(pjrt)]
use std::sync::Arc;

#[cfg(pjrt)]
use anyhow::{anyhow, Result};

#[cfg(pjrt)]
use crate::accuracy::Assignment;
#[cfg(pjrt)]
use crate::pruning::{prune, PatternLibrary};
#[cfg(pjrt)]
use crate::reweighted;
#[cfg(pjrt)]
use crate::rng::Rng;
#[cfg(pjrt)]
use crate::runtime::{Executable, HostValue, Runtime};
#[cfg(pjrt)]
use crate::tensor::Tensor;

/// Handle over the proxy model's training state.
#[cfg(pjrt)]
pub struct TrainDriver {
    step_exe: Arc<Executable>,
    fwd_exe: Arc<Executable>,
    /// All parameters (weights + biases) in manifest order.
    pub params: Vec<Tensor>,
    /// Shapes per parameter.
    shapes: Vec<Vec<usize>>,
    /// Masks per prunable weight (weight order).
    pub masks: Vec<Tensor>,
    /// Alphas per prunable weight.
    pub alphas: Vec<Tensor>,
    weight_idx: Vec<usize>,
    batch: usize,
    in_elems: usize,
    num_classes: usize,
}

/// One training-step result.
#[derive(Debug, Clone, Copy)]
pub struct StepStats {
    pub ce: f32,
    pub acc: f32,
}

#[cfg(pjrt)]
impl TrainDriver {
    /// Initialize from the runtime's manifest (He-init weights, zero bias,
    /// dense masks, zero alphas).
    pub fn new(rt: &Runtime, seed: u64) -> Result<TrainDriver> {
        let m = rt.manifest().clone();
        let step_exe = rt.load("train_step")?;
        let fwd_exe = rt.load("forward")?;
        let mut rng = Rng::new(seed);
        let mut params = Vec::new();
        let mut shapes = Vec::new();
        for p in &m.params {
            let t = if p.kind == "bias" {
                Tensor::zeros(&p.shape)
            } else {
                let fan_in: usize = match p.kind.as_str() {
                    "conv" => p.shape[1..].iter().product(),
                    _ => p.shape[0],
                };
                Tensor::he_normal(&p.shape, fan_in, &mut rng)
            };
            shapes.push(p.shape.clone());
            params.push(t);
        }
        let masks: Vec<Tensor> = m
            .weight_idx
            .iter()
            .map(|&i| Tensor::ones(&shapes[i]))
            .collect();
        let alphas: Vec<Tensor> = m
            .weight_idx
            .iter()
            .map(|&i| Tensor::zeros(&shapes[i]))
            .collect();
        Ok(TrainDriver {
            step_exe,
            fwd_exe,
            params,
            shapes,
            masks,
            alphas,
            weight_idx: m.weight_idx.clone(),
            batch: m.batch,
            in_elems: m.in_ch * m.img * m.img,
            num_classes: m.num_classes,
        })
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    pub fn num_classes(&self) -> usize {
        self.num_classes
    }

    /// Prunable weight tensors (cloned views).
    pub fn weights(&self) -> Vec<Tensor> {
        self.weight_idx.iter().map(|&i| self.params[i].clone()).collect()
    }

    /// Set the pruning masks (weight order) and re-apply to params.
    pub fn set_masks(&mut self, masks: Vec<Tensor>) -> Result<()> {
        if masks.len() != self.weight_idx.len() {
            return Err(anyhow!("expected {} masks", self.weight_idx.len()));
        }
        for (m, &wi) in masks.iter().zip(&self.weight_idx) {
            if m.shape() != self.shapes[wi].as_slice() {
                return Err(anyhow!("mask shape mismatch for weight {wi}"));
            }
        }
        for (m, &wi) in masks.iter().zip(&self.weight_idx) {
            self.params[wi] = self.params[wi].hadamard(m);
        }
        self.masks = masks;
        Ok(())
    }

    /// Refresh reweighted alphas from current weights under per-layer
    /// schemes (paper Eq. 2-4 alpha update, done between epochs).
    pub fn update_alphas(&mut self, assigns: &[Assignment]) {
        for (k, &wi) in self.weight_idx.iter().enumerate() {
            let scheme = assigns[k].scheme;
            self.alphas[k] = reweighted::alphas(&self.params[wi], &scheme, reweighted::EPS);
        }
    }

    /// One SGD step through the AOT train-step artifact.
    pub fn step(&mut self, x: &[f32], y: &[i32], lr: f32, lam: f32) -> Result<StepStats> {
        debug_assert_eq!(x.len(), self.batch * self.in_elems);
        debug_assert_eq!(y.len(), self.batch);
        let mut inputs: Vec<HostValue> = Vec::with_capacity(self.params.len() + 14);
        for (p, s) in self.params.iter().zip(&self.shapes) {
            inputs.push(HostValue::f32(s, p.data().to_vec()));
        }
        for m in &self.masks {
            inputs.push(HostValue::f32(m.shape(), m.data().to_vec()));
        }
        for a in &self.alphas {
            inputs.push(HostValue::f32(a.shape(), a.data().to_vec()));
        }
        let hw = (self.in_elems / 3).isqrt();
        inputs.push(HostValue::f32(&[self.batch, 3, hw, hw], x.to_vec()));
        inputs.push(HostValue::i32(&[self.batch], y.to_vec()));
        inputs.push(HostValue::scalar_f32(lr));
        inputs.push(HostValue::scalar_f32(lam));

        let out = self.step_exe.run(&inputs)?;
        // outputs: new params (N) + ce + acc
        let n = self.params.len();
        for (i, new_p) in out[..n].iter().enumerate() {
            self.params[i] = Tensor::from_vec(&self.shapes[i], new_p.clone());
        }
        Ok(StepStats { ce: out[n][0], acc: out[n + 1][0] })
    }

    /// Forward pass: returns logits (batch x classes).
    pub fn forward(&self, x: &[f32]) -> Result<Vec<f32>> {
        let mut inputs: Vec<HostValue> = Vec::new();
        for (p, s) in self.params.iter().zip(&self.shapes) {
            inputs.push(HostValue::f32(s, p.data().to_vec()));
        }
        for m in &self.masks {
            inputs.push(HostValue::f32(m.shape(), m.data().to_vec()));
        }
        let hw = (self.in_elems / 3).isqrt();
        inputs.push(HostValue::f32(&[self.batch, 3, hw, hw], x.to_vec()));
        let out = self.fwd_exe.run(&inputs)?;
        Ok(out.into_iter().next().unwrap())
    }

    /// Classification accuracy over a set of batches.
    pub fn eval_acc(&self, ds: &SynthDataset, batches: usize, seed: u64) -> Result<f32> {
        let mut rng = Rng::new(seed);
        let mut correct = 0usize;
        let mut total = 0usize;
        for _ in 0..batches {
            let (x, y) = ds.batch(self.batch, &mut rng);
            let logits = self.forward(&x)?;
            for (b, &label) in y.iter().enumerate() {
                let row = &logits[b * self.num_classes..(b + 1) * self.num_classes];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap();
                correct += (pred == label as usize) as usize;
                total += 1;
            }
        }
        Ok(correct as f32 / total.max(1) as f32)
    }

    /// One-shot magnitude pruning of all weights under the given per-layer
    /// assignments (proxy-model layer order == weight order), then mask.
    pub fn prune_with(&mut self, assigns: &[Assignment], lib: &PatternLibrary) -> Result<Vec<f32>> {
        let mut achieved = Vec::new();
        let mut masks = Vec::new();
        for (k, &wi) in self.weight_idx.iter().enumerate() {
            let a = &assigns[k];
            let r = prune(&self.params[wi], &a.scheme, a.compression, lib);
            achieved.push(r.compression());
            masks.push(r.mask);
        }
        self.set_masks(masks)?;
        Ok(achieved)
    }

    /// Reweighted auto-prune (after regularized training): zero groups the
    /// regularizer drove below tau; returns achieved per-layer compression.
    pub fn auto_prune_with(&mut self, assigns: &[Assignment], tau: f32) -> Result<Vec<f32>> {
        let mut achieved = Vec::new();
        let mut masks = Vec::new();
        for (k, &wi) in self.weight_idx.iter().enumerate() {
            let r = reweighted::auto_prune(&self.params[wi], &assigns[k].scheme, tau);
            achieved.push(r.compression());
            masks.push(r.mask);
        }
        self.set_masks(masks)?;
        Ok(achieved)
    }
}
