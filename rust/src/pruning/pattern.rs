//! Pattern library for pattern-based pruning (paper §2.1.1, Fig. 1e).
//!
//! A *kernel pattern* fixes which 4 of the 9 positions in a 3x3 kernel stay
//! non-zero.  The library is restricted to a small set (8 or 16) so the
//! generated mobile code stays branch-light; patterns are selected for
//! Gaussian-filter / Enhanced-Laplacian-of-Gaussian likeness (central
//! concentration), which Ma et al. showed enhances feature extraction.

use crate::tensor::Tensor;

/// Bitmask over the 9 kernel positions, row-major: bit (3*r + c).
pub type PatternBits = u16;

/// A fixed library of 4-entry kernel patterns.
#[derive(Debug, Clone)]
pub struct PatternLibrary {
    patterns: Vec<PatternBits>,
    /// Pre-decoded live positions per pattern (§Perf: 4 indexed adds per
    /// pattern instead of 9 bit-test+adds in the best-fit inner loop).
    positions: Vec<[u8; 4]>,
}

/// Spatial concentration score: patterns whose live positions hug the
/// center score higher (Gaussian/ELoG-like).  Distance is Chebyshev from
/// the kernel center.
fn concentration_score(bits: PatternBits) -> f32 {
    let mut score = 0.0;
    for r in 0..3 {
        for c in 0..3 {
            if bits & (1 << (3 * r + c)) != 0 {
                let d = ((r as i32 - 1).abs()).max((c as i32 - 1).abs());
                // center: +3, edge-adjacent: +1, corner: 0
                score += match d {
                    0 => 3.0,
                    1 => {
                        if r == 1 || c == 1 {
                            1.0
                        } else {
                            0.0
                        }
                    }
                    _ => 0.0,
                };
            }
        }
    }
    score
}

impl PatternLibrary {
    /// Build the library: enumerate all C(9,4)=126 patterns, keep the
    /// `size` most center-concentrated that include the center position
    /// (all Gaussian/ELoG shapes do), tie-broken deterministically.
    pub fn new(size: usize) -> Self {
        let mut all: Vec<PatternBits> = Vec::new();
        for bits in 0u16..(1 << 9) {
            if bits.count_ones() == 4 {
                all.push(bits);
            }
        }
        // center position = bit 4
        all.retain(|b| b & (1 << 4) != 0);
        all.sort_by(|a, b| {
            concentration_score(*b)
                .partial_cmp(&concentration_score(*a))
                .unwrap()
                .then(a.cmp(b))
        });
        all.truncate(size.max(1));
        let positions = all
            .iter()
            .map(|&bits| {
                let mut pos = [0u8; 4];
                let mut k = 0;
                for p in 0..9u8 {
                    if bits & (1 << p) != 0 {
                        pos[k] = p;
                        k += 1;
                    }
                }
                pos
            })
            .collect();
        PatternLibrary { patterns: all, positions }
    }

    /// The standard 8-pattern library used throughout the evaluation.
    pub fn default8() -> Self {
        Self::new(8)
    }

    pub fn len(&self) -> usize {
        self.patterns.len()
    }

    pub fn is_empty(&self) -> bool {
        self.patterns.is_empty()
    }

    pub fn patterns(&self) -> &[PatternBits] {
        &self.patterns
    }

    /// Pick the library pattern retaining the most kernel energy
    /// (sum of w^2 over live positions); returns (index, retained energy).
    pub fn best_for(&self, kernel: &[f32]) -> (usize, f32) {
        debug_assert_eq!(kernel.len(), 9);
        let mut sq = [0f32; 9];
        for (i, v) in kernel.iter().enumerate() {
            sq[i] = v * v;
        }
        let mut best = (0usize, f32::NEG_INFINITY);
        for (i, pos) in self.positions.iter().enumerate() {
            let e = sq[pos[0] as usize] + sq[pos[1] as usize] + sq[pos[2] as usize]
                + sq[pos[3] as usize];
            if e > best.1 {
                best = (i, e);
            }
        }
        best
    }

    /// Apply pattern-based pruning to a 4-D CONV weight (F, C, 3, 3):
    /// every kernel gets its best-fit pattern; then *connectivity pruning*
    /// removes whole kernels (lowest energy first) until only `keep_frac`
    /// of all weights survive.  Returns the {0,1} mask.
    pub fn apply(&self, w: &Tensor, keep_frac: f32) -> Tensor {
        assert_eq!(w.ndim(), 4);
        let s = w.shape();
        let (f, c, kh, kw) = (s[0], s[1], s[2], s[3]);
        assert_eq!((kh, kw), (3, 3), "pattern pruning is 3x3-only");
        let mut mask = Tensor::zeros(s);
        // per-kernel pattern assignment over contiguous 9-weight slices
        // (§Perf: raw slice iteration replaced per-element at4 arithmetic)
        let wd = w.data();
        let md = mask.data_mut();
        let mut kernel_energy: Vec<(usize, f32)> = Vec::with_capacity(f * c);
        for kid in 0..f * c {
            let base = kid * 9;
            let k9: &[f32] = &wd[base..base + 9];
            let (pi, e) = self.best_for(k9);
            let bits = self.patterns[pi];
            for p in 0..9 {
                if bits & (1 << p) != 0 {
                    md[base + p] = 1.0;
                }
            }
            kernel_energy.push((kid, e));
        }
        // connectivity pruning: drop weakest kernels to reach keep_frac
        let total = (f * c * 9) as f32;
        let per_kernel_kept = 4.0;
        let target_kept = (keep_frac * total).max(0.0);
        let kernels_to_keep =
            ((target_kept / per_kernel_kept).ceil() as usize).min(f * c);
        if kernels_to_keep < f * c {
            kernel_energy.sort_unstable_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
            let n_drop = f * c - kernels_to_keep;
            let md = mask.data_mut();
            for &(kid, _) in kernel_energy.iter().take(n_drop) {
                md[kid * 9..kid * 9 + 9].fill(0.0);
            }
        }
        mask
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn library_sizes() {
        assert_eq!(PatternLibrary::default8().len(), 8);
        assert_eq!(PatternLibrary::new(16).len(), 16);
    }

    #[test]
    fn all_patterns_have_four_entries_and_center() {
        let lib = PatternLibrary::new(16);
        for &p in lib.patterns() {
            assert_eq!(p.count_ones(), 4);
            assert!(p & (1 << 4) != 0, "pattern {p:#b} misses center");
        }
    }

    #[test]
    fn patterns_are_distinct() {
        let lib = PatternLibrary::new(16);
        let mut seen = std::collections::HashSet::new();
        for &p in lib.patterns() {
            assert!(seen.insert(p));
        }
    }

    #[test]
    fn best_for_picks_energy_maximizer() {
        let lib = PatternLibrary::default8();
        // kernel with all energy at center + top edge
        let mut k = [0f32; 9];
        k[4] = 10.0;
        k[1] = 5.0;
        let (pi, e) = lib.best_for(&k);
        let bits = lib.patterns()[pi];
        assert!(bits & (1 << 4) != 0);
        assert!(bits & (1 << 1) != 0, "best pattern should keep position 1");
        assert!((e - 125.0).abs() < 1e-6);
    }

    #[test]
    fn apply_yields_four_per_kernel_without_connectivity() {
        let mut rng = Rng::new(1);
        let w = Tensor::he_normal(&[8, 4, 3, 3], 36, &mut rng);
        let lib = PatternLibrary::default8();
        let mask = lib.apply(&w, 4.0 / 9.0);
        // every kernel keeps exactly 4
        for f in 0..8 {
            for c in 0..4 {
                let kept: f32 = (0..9).map(|p| mask.at4(f, c, p / 3, p % 3)).sum();
                assert_eq!(kept, 4.0);
            }
        }
    }

    #[test]
    fn connectivity_pruning_reaches_higher_compression() {
        let mut rng = Rng::new(2);
        let w = Tensor::he_normal(&[8, 8, 3, 3], 72, &mut rng);
        let lib = PatternLibrary::default8();
        let mask = lib.apply(&w, 0.25); // harsher than 4/9
        let kept = mask.nnz() as f32;
        let total = (8 * 8 * 9) as f32;
        assert!(kept / total <= 0.26, "kept frac {}", kept / total);
        // kernels are either fully dropped or keep 4
        for f in 0..8 {
            for c in 0..8 {
                let k: f32 = (0..9).map(|p| mask.at4(f, c, p / 3, p % 3)).sum();
                assert!(k == 0.0 || k == 4.0);
            }
        }
    }

    #[test]
    #[should_panic]
    fn rejects_non_3x3() {
        let w = Tensor::zeros(&[4, 4, 5, 5]);
        PatternLibrary::default8().apply(&w, 0.4);
    }
}
