//! One-shot magnitude pruning for every regularity (paper §5.1's fast
//! accuracy-proxy path, and the mask generator for the end-to-end example).
//!
//! Group statistics are mean squared magnitude; the lowest-ranked groups
//! are pruned globally per layer until the target compression is met —
//! which is how different blocks end up with different rates (the paper's
//! "compression rate for each block can either be the same or different").

use super::pattern::PatternLibrary;
use super::{PruneResult, Scheme};
use crate::tensor::Tensor;

/// Generate a {0,1} mask for `w` under `scheme` at `compression`x
/// (keep fraction = 1/compression).  CONV weights are 4-D (F, C, KH, KW);
/// FC weights are 2-D (P, Q).
pub fn prune(w: &Tensor, scheme: &Scheme, compression: f32, lib: &PatternLibrary) -> PruneResult {
    let keep_frac = (1.0 / compression.max(1.0)).clamp(0.0, 1.0);
    let mask = match scheme {
        Scheme::None => Tensor::ones(w.shape()),
        Scheme::Unstructured => prune_unstructured(w, keep_frac),
        Scheme::StructuredRow => prune_structured(w, keep_frac, true),
        Scheme::StructuredColumn => prune_structured(w, keep_frac, false),
        Scheme::Pattern => lib.apply(w, keep_frac),
        Scheme::Block { bp, bq } => prune_block_fc(w, *bp, *bq, keep_frac),
        Scheme::BlockPunched { bf, bc } => prune_block_punched(w, *bf, *bc, keep_frac),
    };
    let kept = mask.nnz();
    PruneResult { mask, kept, total: w.len() }
}

/// Keep the top `keep_frac` weights by |w| anywhere in the tensor.
fn prune_unstructured(w: &Tensor, keep_frac: f32) -> Tensor {
    let n = w.len();
    let keep = ((n as f32 * keep_frac).round() as usize).min(n);
    if keep == n {
        return Tensor::ones(w.shape());
    }
    let mut mags: Vec<(f32, usize)> = w
        .data()
        .iter()
        .enumerate()
        .map(|(i, v)| (v.abs(), i))
        .collect();
    mags.select_nth_unstable_by(n - keep.max(1), |a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut mask = Tensor::zeros(w.shape());
    for &(_, i) in &mags[n - keep..] {
        mask.data_mut()[i] = 1.0;
    }
    mask
}

/// Whole-row (filter) or whole-column (channel) pruning.
/// 4-D: row = filter (dim 0), column = input channel (dim 1).
/// 2-D: row = dim 0, column = dim 1.
fn prune_structured(w: &Tensor, keep_frac: f32, rows: bool) -> Tensor {
    let (n_groups, per) = structured_geometry(w, rows);
    let mut stats = vec![0f32; n_groups];
    for g in 0..n_groups {
        stats[g] = structured_group_sqsum(w, g, rows) / per as f32;
    }
    let keep_set = top_groups(&stats, keep_frac);
    let mut mask = Tensor::zeros(w.shape());
    for g in 0..n_groups {
        if keep_set[g] {
            set_structured_group(&mut mask, g, rows, 1.0);
        }
    }
    mask
}

fn structured_geometry(w: &Tensor, rows: bool) -> (usize, usize) {
    let s = w.shape();
    match w.ndim() {
        2 => {
            if rows {
                (s[0], s[1])
            } else {
                (s[1], s[0])
            }
        }
        4 => {
            if rows {
                (s[0], s[1] * s[2] * s[3])
            } else {
                (s[1], s[0] * s[2] * s[3])
            }
        }
        _ => panic!("structured pruning expects 2-D or 4-D weights"),
    }
}

fn structured_group_sqsum(w: &Tensor, g: usize, rows: bool) -> f32 {
    let s = w.shape();
    let mut acc = 0.0;
    match w.ndim() {
        2 => {
            if rows {
                for c in 0..s[1] {
                    let v = w.at2(g, c);
                    acc += v * v;
                }
            } else {
                for r in 0..s[0] {
                    let v = w.at2(r, g);
                    acc += v * v;
                }
            }
        }
        4 => {
            let (f, c, kh, kw) = (s[0], s[1], s[2], s[3]);
            if rows {
                for ci in 0..c {
                    for p in 0..kh * kw {
                        let v = w.at4(g, ci, p / kw, p % kw);
                        acc += v * v;
                    }
                }
            } else {
                for fi in 0..f {
                    for p in 0..kh * kw {
                        let v = w.at4(fi, g, p / kw, p % kw);
                        acc += v * v;
                    }
                }
            }
        }
        _ => unreachable!(),
    }
    acc
}

fn set_structured_group(mask: &mut Tensor, g: usize, rows: bool, v: f32) {
    let s = mask.shape().to_vec();
    match s.len() {
        2 => {
            if rows {
                for c in 0..s[1] {
                    mask.set2(g, c, v);
                }
            } else {
                for r in 0..s[0] {
                    mask.set2(r, g, v);
                }
            }
        }
        4 => {
            let (f, c, kh, kw) = (s[0], s[1], s[2], s[3]);
            if rows {
                for ci in 0..c {
                    for p in 0..kh * kw {
                        mask.set4(g, ci, p / kw, p % kw, v);
                    }
                }
            } else {
                for fi in 0..f {
                    for p in 0..kh * kw {
                        mask.set4(fi, g, p / kw, p % kw, v);
                    }
                }
            }
        }
        _ => unreachable!(),
    }
}

/// Rank groups by stat and return a keep set with ceil(keep_frac * n).
fn top_groups(stats: &[f32], keep_frac: f32) -> Vec<bool> {
    let n = stats.len();
    let keep = ((n as f32 * keep_frac).ceil() as usize).clamp(1, n);
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| stats[b].partial_cmp(&stats[a]).unwrap());
    let mut out = vec![false; n];
    for &i in idx.iter().take(keep) {
        out[i] = true;
    }
    out
}

/// Block-based pruning for FC (paper §4.1.1): the weight matrix is tiled
/// into (bp x bq) blocks; row-groups and column-groups *within each block*
/// are ranked globally and pruned until the target survives.  Row and
/// column pruning each carry half the sparsity (keep = sqrt(keep_frac)
/// per direction).
fn prune_block_fc(w: &Tensor, bp: usize, bq: usize, keep_frac: f32) -> Tensor {
    assert_eq!(w.ndim(), 2, "block-based pruning expects a 2-D FC weight");
    let (p, q) = (w.shape()[0], w.shape()[1]);
    let bp = bp.min(p).max(1);
    let bq = bq.min(q).max(1);
    let nbr = p.div_ceil(bp); // block rows
    let nbc = q.div_ceil(bq); // block cols
    let dir_keep = keep_frac.sqrt();

    // global ranking of (block, row-in-block) / (block, col-in-block)
    // groups; flat ids (§Perf: flat boolean keep-vectors replaced the
    // original HashSet<(br,bc,r)> membership sets — 24x on 1024x1024)
    let data = w.data();
    let row_id = |br: usize, bc_i: usize, r: usize| (br * nbc + bc_i) * bp + (r % bp);
    let col_id = |br: usize, bc_i: usize, c: usize| (br * nbc + bc_i) * bq + (c % bq);
    let mut row_stats = Vec::with_capacity(nbr * nbc * bp); // (mean_sq, id)
    let mut col_stats = Vec::with_capacity(nbr * nbc * bq);
    for br in 0..nbr {
        for bc_i in 0..nbc {
            let r0 = br * bp;
            let c0 = bc_i * bq;
            let r1 = (r0 + bp).min(p);
            let c1 = (c0 + bq).min(q);
            // two row-major passes, each auto-vectorizable (a fused
            // single pass measured ~25% slower — see EXPERIMENTS.md §Perf)
            let mut col_acc = vec![0f32; c1 - c0];
            for r in r0..r1 {
                let row = &data[r * q + c0..r * q + c1];
                let acc: f32 = row.iter().map(|v| v * v).sum();
                row_stats.push((acc / (c1 - c0) as f32, row_id(br, bc_i, r)));
                for (j, v) in row.iter().enumerate() {
                    col_acc[j] += v * v;
                }
            }
            for (j, &acc) in col_acc.iter().enumerate() {
                col_stats.push((acc / (r1 - r0) as f32, col_id(br, bc_i, c0 + j)));
            }
        }
    }
    let keep_rows = ((row_stats.len() as f32 * dir_keep).ceil() as usize).max(1);
    let keep_cols = ((col_stats.len() as f32 * dir_keep).ceil() as usize).max(1);
    row_stats.sort_unstable_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    col_stats.sort_unstable_by(|a, b| b.0.partial_cmp(&a.0).unwrap());

    let mut row_keep = vec![false; nbr * nbc * bp];
    for &(_, id) in row_stats.iter().take(keep_rows) {
        row_keep[id] = true;
    }
    let mut col_keep = vec![false; nbr * nbc * bq];
    for &(_, id) in col_stats.iter().take(keep_cols) {
        col_keep[id] = true;
    }

    let mut mask = Tensor::zeros(w.shape());
    let md = mask.data_mut();
    for br in 0..nbr {
        for bc_i in 0..nbc {
            let r0 = br * bp;
            let c0 = bc_i * bq;
            let r1 = (r0 + bp).min(p);
            let c1 = (c0 + bq).min(q);
            for r in r0..r1 {
                if !row_keep[row_id(br, bc_i, r)] {
                    continue;
                }
                for c in c0..c1 {
                    if col_keep[col_id(br, bc_i, c)] {
                        md[r * q + c] = 1.0;
                    }
                }
            }
        }
    }
    mask
}

/// Block-punched pruning for CONV (paper §4.1.2): kernels are grouped into
/// (bf filters x bc channels) blocks; the prunable unit is a kernel
/// position (m, n) *across every kernel in the block* (Eq. 4's
/// [W_ij]_{:,:,m,n}).  Units are ranked globally within the layer.
fn prune_block_punched(w: &Tensor, bf: usize, bc: usize, keep_frac: f32) -> Tensor {
    assert_eq!(w.ndim(), 4, "block-punched pruning expects a 4-D CONV weight");
    let s = w.shape();
    let (f, c, kh, kw) = (s[0], s[1], s[2], s[3]);
    let bf = bf.min(f).max(1);
    let bc = bc.min(c).max(1);
    let nbf = f.div_ceil(bf);
    let nbc = c.div_ceil(bc);

    // stat per (block, position)
    let mut stats = Vec::with_capacity(nbf * nbc * kh * kw);
    for bfi in 0..nbf {
        for bci in 0..nbc {
            let f0 = bfi * bf;
            let c0 = bci * bc;
            let f1 = (f0 + bf).min(f);
            let c1 = (c0 + bc).min(c);
            for m in 0..kh {
                for n in 0..kw {
                    let mut acc = 0.0;
                    for fi in f0..f1 {
                        for ci in c0..c1 {
                            let v = w.at4(fi, ci, m, n);
                            acc += v * v;
                        }
                    }
                    let cnt = ((f1 - f0) * (c1 - c0)) as f32;
                    stats.push((acc / cnt, bfi, bci, m, n));
                }
            }
        }
    }
    let keep = ((stats.len() as f32 * keep_frac).ceil() as usize).clamp(1, stats.len());
    stats.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());

    let mut mask = Tensor::zeros(s);
    for &(_, bfi, bci, m, n) in stats.iter().take(keep) {
        let f0 = bfi * bf;
        let c0 = bci * bc;
        let f1 = (f0 + bf).min(f);
        let c1 = (c0 + bc).min(c);
        for fi in f0..f1 {
            for ci in c0..c1 {
                mask.set4(fi, ci, m, n, 1.0);
            }
        }
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn lib() -> PatternLibrary {
        PatternLibrary::default8()
    }

    fn rand_w(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let fan: usize = shape.iter().skip(1).product();
        Tensor::he_normal(shape, fan.max(1), &mut rng)
    }

    #[test]
    fn unstructured_hits_target() {
        let w = rand_w(&[64, 64], 1);
        let r = prune(&w, &Scheme::Unstructured, 8.0, &lib());
        assert!((r.compression() - 8.0).abs() < 0.2, "{}", r.compression());
        // kept weights are the largest by magnitude
        let thresh = w
            .data()
            .iter()
            .zip(r.mask.data())
            .filter(|(_, m)| **m == 1.0)
            .map(|(v, _)| v.abs())
            .fold(f32::INFINITY, f32::min);
        let max_pruned = w
            .data()
            .iter()
            .zip(r.mask.data())
            .filter(|(_, m)| **m == 0.0)
            .map(|(v, _)| v.abs())
            .fold(0.0, f32::max);
        assert!(thresh >= max_pruned);
    }

    #[test]
    fn structured_row_prunes_whole_filters() {
        let w = rand_w(&[16, 8, 3, 3], 2);
        let r = prune(&w, &Scheme::StructuredRow, 4.0, &lib());
        for fi in 0..16 {
            let s: f32 = (0..8)
                .flat_map(|c| (0..9).map(move |p| (c, p)))
                .map(|(c, p)| r.mask.at4(fi, c, p / 3, p % 3))
                .sum();
            assert!(s == 0.0 || s == 72.0, "filter {fi} partially pruned: {s}");
        }
        assert!((r.compression() - 4.0).abs() < 0.5);
    }

    #[test]
    fn structured_col_prunes_whole_channels() {
        let w = rand_w(&[8, 16, 3, 3], 3);
        let r = prune(&w, &Scheme::StructuredColumn, 2.0, &lib());
        for ci in 0..16 {
            let s: f32 = (0..8)
                .flat_map(|f| (0..9).map(move |p| (f, p)))
                .map(|(f, p)| r.mask.at4(f, ci, p / 3, p % 3))
                .sum();
            assert!(s == 0.0 || s == 72.0);
        }
    }

    #[test]
    fn structured_fc_rows() {
        let w = rand_w(&[32, 16], 4);
        let r = prune(&w, &Scheme::StructuredRow, 4.0, &lib());
        for row in 0..32 {
            let s: f32 = (0..16).map(|c| r.mask.at2(row, c)).sum();
            assert!(s == 0.0 || s == 16.0);
        }
    }

    #[test]
    fn block_fc_structure_is_blockwise_rows_and_cols() {
        let w = rand_w(&[32, 32], 5);
        let r = prune(&w, &Scheme::Block { bp: 8, bq: 8 }, 4.0, &lib());
        // within each 8x8 block, the mask must be an outer product of a row
        // keep-vector and a col keep-vector
        for br in 0..4 {
            for bc in 0..4 {
                let mut row_any = [false; 8];
                let mut col_any = [false; 8];
                for r_ in 0..8 {
                    for c_ in 0..8 {
                        if r.mask.at2(br * 8 + r_, bc * 8 + c_) == 1.0 {
                            row_any[r_] = true;
                            col_any[c_] = true;
                        }
                    }
                }
                for r_ in 0..8 {
                    for c_ in 0..8 {
                        let expect = row_any[r_] && col_any[c_];
                        assert_eq!(
                            r.mask.at2(br * 8 + r_, bc * 8 + c_) == 1.0,
                            expect,
                            "block ({br},{bc}) not outer-product structured"
                        );
                    }
                }
            }
        }
        // compression in the right ballpark (outer-product granularity is
        // coarse, so allow slack)
        assert!(r.compression() > 2.0 && r.compression() < 8.0, "{}", r.compression());
    }

    #[test]
    fn block_punched_same_positions_within_block() {
        let w = rand_w(&[8, 8, 3, 3], 6);
        let r = prune(&w, &Scheme::BlockPunched { bf: 4, bc: 4 }, 3.0, &lib());
        // within each 4x4 kernel block, every kernel shares the same mask
        for bf in 0..2 {
            for bc in 0..2 {
                let ref_mask: Vec<f32> = (0..9)
                    .map(|p| r.mask.at4(bf * 4, bc * 4, p / 3, p % 3))
                    .collect();
                for fi in bf * 4..bf * 4 + 4 {
                    for ci in bc * 4..bc * 4 + 4 {
                        for p in 0..9 {
                            assert_eq!(
                                r.mask.at4(fi, ci, p / 3, p % 3),
                                ref_mask[p],
                                "kernel ({fi},{ci}) differs from block pattern"
                            );
                        }
                    }
                }
            }
        }
        assert!((r.compression() - 3.0).abs() < 1.0, "{}", r.compression());
    }

    #[test]
    fn block_punched_1x1_prunes_whole_blocks() {
        let w = rand_w(&[16, 16, 1, 1], 7);
        let r = prune(&w, &Scheme::BlockPunched { bf: 4, bc: 4 }, 4.0, &lib());
        for bf in 0..4 {
            for bc in 0..4 {
                let s: f32 = (0..4)
                    .flat_map(|i| (0..4).map(move |j| (i, j)))
                    .map(|(i, j)| r.mask.at4(bf * 4 + i, bc * 4 + j, 0, 0))
                    .sum();
                assert!(s == 0.0 || s == 16.0, "1x1 block partially pruned");
            }
        }
        assert!((r.compression() - 4.0).abs() < 0.5);
    }

    #[test]
    fn pattern_scheme_dispatches() {
        let w = rand_w(&[8, 8, 3, 3], 8);
        let r = prune(&w, &Scheme::Pattern, 9.0 / 4.0, &lib());
        assert!((r.compression() - 2.25).abs() < 0.1);
    }

    #[test]
    fn none_keeps_everything() {
        let w = rand_w(&[8, 8], 9);
        let r = prune(&w, &Scheme::None, 10.0, &lib());
        assert_eq!(r.kept, r.total);
        assert_eq!(r.compression(), 1.0);
    }

    #[test]
    fn higher_compression_prunes_more() {
        let w = rand_w(&[32, 32, 3, 3], 10);
        let lo = prune(&w, &Scheme::BlockPunched { bf: 8, bc: 8 }, 2.0, &lib());
        let hi = prune(&w, &Scheme::BlockPunched { bf: 8, bc: 8 }, 8.0, &lib());
        assert!(hi.kept < lo.kept);
    }

    #[test]
    fn unstructured_equals_block_1x1_granularity() {
        // unstructured = block-punched with 1x1 blocks on conv per paper;
        // both should reach the same compression on the same tensor
        let w = rand_w(&[16, 16, 3, 3], 11);
        let a = prune(&w, &Scheme::Unstructured, 4.0, &lib());
        let b = prune(&w, &Scheme::BlockPunched { bf: 1, bc: 1 }, 4.0, &lib());
        assert!((a.compression() - b.compression()).abs() < 0.2);
        // and the masks agree (both keep the top-magnitude positions)
        let agree = a
            .mask
            .data()
            .iter()
            .zip(b.mask.data())
            .filter(|(x, y)| x == y)
            .count();
        assert!(agree as f32 / a.mask.len() as f32 > 0.95);
    }
}
