//! Pruning regularities and mask generation (paper §2.1, §4.1).
//!
//! Five regularities, exactly the paper's taxonomy (Fig. 1):
//!
//! * **Unstructured** — arbitrary weight locations (a/b);
//! * **Structured** — whole rows (filters) / columns (channels) (c/d);
//! * **Pattern-based** — 4-entry kernel patterns + connectivity pruning,
//!   3x3 CONV only (e);
//! * **Block-punched** — same intra-kernel locations pruned across a
//!   (filters x channels) block of kernels, any CONV kernel size (f);
//! * **Block-based** — independent row+column pruning inside equal-sized
//!   blocks of an FC weight matrix (g).
//!
//! Masks are dense {0,1} tensors in the weight's natural layout (4-D for
//! CONV, 2-D for FC).  One-shot magnitude pruning (used by the RL search's
//! fast accuracy proxy, §5.1) lives in [`magnitude`]; the reweighted
//! dynamic-regularization algorithm that *derives* per-layer rates lives in
//! [`crate::reweighted`].

pub mod magnitude;
pub mod pattern;

pub use magnitude::prune;
pub use pattern::PatternLibrary;

use crate::models::LayerSpec;

/// A pruning scheme choice for one layer: the action space of both mapping
/// methods ({regularity, block size} — §5.1's 2-D action vector).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Scheme {
    /// Leave the layer dense (the rule-based choice for 3x3-DW layers).
    None,
    /// Fine-grained, irregular (block size conceptually 1x1).
    Unstructured,
    /// Whole-row (filter) pruning.
    StructuredRow,
    /// Whole-column (channel) pruning.
    StructuredColumn,
    /// 4-entry kernel patterns + connectivity pruning (3x3 CONV only).
    Pattern,
    /// Block-based pruning for FC: rows/cols inside (bp x bq) blocks.
    Block { bp: usize, bq: usize },
    /// Block-punched pruning for CONV: kernel positions inside a
    /// (bf filters x bc channels) block of kernels.
    BlockPunched { bf: usize, bc: usize },
}

impl Scheme {
    /// Short display name used in reports (matches the paper's tables).
    pub fn label(&self) -> String {
        match self {
            Scheme::None => "none".into(),
            Scheme::Unstructured => "unstructured".into(),
            Scheme::StructuredRow => "structured-row".into(),
            Scheme::StructuredColumn => "structured-col".into(),
            Scheme::Pattern => "pattern".into(),
            Scheme::Block { bp, bq } => format!("block {bp}x{bq}"),
            Scheme::BlockPunched { bf, bc } => format!("punched {bf}x{bc}"),
        }
    }

    /// Whether the scheme can legally be applied to the given layer.
    ///
    /// Block schemes additionally require the block dims to tile the
    /// weight evenly.  The divisibility test is *clamped*: a block dim
    /// larger than the weight dim covers it as one block (the mask
    /// generator clamps the same way), so `Block{64,128}` stays legal on
    /// a 10-class head while `BlockPunched{4,16}` on a 255-filter YOLO
    /// head — where 4 does not divide 255 — is rejected.
    pub fn applicable(&self, layer: &LayerSpec) -> bool {
        use crate::models::LayerKind::*;
        // does clamped block dim `b` tile a weight dim of `dim` evenly?
        let tiles = |dim: usize, b: usize| b >= 1 && dim % b.min(dim).max(1) == 0;
        match self {
            Scheme::None | Scheme::Unstructured => true,
            Scheme::StructuredRow | Scheme::StructuredColumn => true,
            Scheme::Pattern => layer.is_3x3_conv(),
            // FC weight layout is [in_ch, out_ch]: bp tiles rows, bq cols
            Scheme::Block { bp, bq } => {
                layer.kind == Fc && tiles(layer.in_ch, *bp) && tiles(layer.out_ch, *bq)
            }
            // CONV weight layout is [out_ch, in_ch/1, kh, kw]: bf tiles
            // filters, bc tiles channels (depthwise has one channel, so
            // any bc clamps to 1 and only the filter dim constrains)
            Scheme::BlockPunched { bf, bc } => match layer.kind {
                Conv => tiles(layer.out_ch, *bf) && tiles(layer.in_ch, *bc),
                DepthwiseConv => tiles(layer.out_ch, *bf) && *bc >= 1,
                Fc => false,
            },
        }
    }

    /// The block-size grid searched by both mapping methods.
    pub fn block_size_candidates() -> &'static [(usize, usize)] {
        &[(4, 4), (4, 16), (8, 16), (16, 32), (32, 64), (64, 128)]
    }
}

/// Outcome of mask generation.
#[derive(Debug, Clone)]
pub struct PruneResult {
    /// {0,1} mask, same shape as the weight tensor.
    pub mask: crate::tensor::Tensor,
    /// Non-zero (kept) weights.
    pub kept: usize,
    /// Total weights.
    pub total: usize,
}

impl PruneResult {
    /// Achieved compression rate (total / kept).
    pub fn compression(&self) -> f32 {
        self.total as f32 / self.kept.max(1) as f32
    }

    pub fn sparsity(&self) -> f32 {
        1.0 - self.kept as f32 / self.total.max(1) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::LayerSpec;

    #[test]
    fn applicability_rules() {
        let conv3 = LayerSpec::conv("c", 3, 16, 32, 28, 1);
        let conv1 = LayerSpec::conv("c", 1, 16, 32, 28, 1);
        let dw = LayerSpec::dwconv("d", 3, 16, 28, 1);
        let fc = LayerSpec::fc("f", 128, 64);

        assert!(Scheme::Pattern.applicable(&conv3));
        assert!(!Scheme::Pattern.applicable(&conv1));
        assert!(!Scheme::Pattern.applicable(&fc));

        assert!(Scheme::BlockPunched { bf: 4, bc: 4 }.applicable(&conv1));
        assert!(Scheme::BlockPunched { bf: 4, bc: 4 }.applicable(&dw));
        assert!(!Scheme::BlockPunched { bf: 4, bc: 4 }.applicable(&fc));

        assert!(Scheme::Block { bp: 4, bq: 4 }.applicable(&fc));
        assert!(!Scheme::Block { bp: 4, bq: 4 }.applicable(&conv3));

        assert!(Scheme::Unstructured.applicable(&fc));
        assert!(Scheme::None.applicable(&dw));
    }

    #[test]
    fn block_divisibility_is_enforced() {
        // FC weight is [in_ch, out_ch]: bp must tile rows, bq cols
        let fc = LayerSpec::fc("f", 128, 10);
        assert!(Scheme::Block { bp: 8, bq: 2 }.applicable(&fc));
        assert!(!Scheme::Block { bp: 8, bq: 4 }.applicable(&fc), "4 !| 10");
        assert!(!Scheme::Block { bp: 3, bq: 2 }.applicable(&fc), "3 !| 128");
        // oversized blocks clamp to the whole dim and stay legal
        assert!(Scheme::Block { bp: 256, bq: 64 }.applicable(&fc));
        // degenerate zero block dims are never legal
        assert!(!Scheme::Block { bp: 0, bq: 2 }.applicable(&fc));

        // CONV weight is [out_ch, in_ch, kh, kw]: bf tiles filters, bc channels
        let head = LayerSpec::conv("h", 1, 256, 255, 13, 1);
        assert!(!Scheme::BlockPunched { bf: 4, bc: 16 }.applicable(&head), "4 !| 255");
        assert!(Scheme::BlockPunched { bf: 5, bc: 16 }.applicable(&head));
        let conv = LayerSpec::conv("c", 3, 3, 16, 32, 1);
        // first-conv in_ch=3: an oversized bc clamps to the whole channel dim
        assert!(Scheme::BlockPunched { bf: 4, bc: 16 }.applicable(&conv));
        assert!(!Scheme::BlockPunched { bf: 3, bc: 1 }.applicable(&conv), "3 !| 16");
        assert!(!Scheme::BlockPunched { bf: 4, bc: 2 }.applicable(&conv), "2 !| 3");

        // depthwise weight channel dim is 1: only the filter dim constrains
        let dw = LayerSpec::dwconv("d", 3, 24, 28, 1);
        assert!(Scheme::BlockPunched { bf: 8, bc: 16 }.applicable(&dw));
        assert!(!Scheme::BlockPunched { bf: 5, bc: 1 }.applicable(&dw), "5 !| 24");
    }

    #[test]
    fn labels_are_stable() {
        assert_eq!(Scheme::Block { bp: 8, bq: 16 }.label(), "block 8x16");
        assert_eq!(Scheme::Pattern.label(), "pattern");
    }
}
