//! Normalized measurement records (`prunemap.benchrecords.v1`).
//!
//! Every measurement the harness takes — whatever the workload — is
//! flattened to the same shape, so record sets from different PRs,
//! machines, or definition files can be diffed by the
//! [`cmp`](super::cmp) reporter:
//!
//! ```json
//! {
//!   "format": "prunemap.benchrecords.v1",
//!   "records": [
//!     {"name": "spmm/block1024/b32", "engine": "simd",
//!      "config": {"threads": 1, "batch": 32, "tile": 64, "seed": "1"},
//!      "iters": 10, "mean_ns": 812345.0, "stddev_ns": 9123.0,
//!      "min_ns": 798000.0, "checksum": "9c0f...", "rev": "28a1842"}
//!   ]
//! }
//! ```
//!
//! [`RecordSink`] persists records **incrementally** — the output file
//! is rewritten after every push, so a panic or Ctrl-C mid-run keeps
//! every completed measurement instead of silently losing the lot.

use std::path::{Path, PathBuf};
use std::process::Command;

use anyhow::{bail, Context, Result};

use crate::util::json::Value;

/// Record-set format tag.
pub const FORMAT: &str = "prunemap.benchrecords.v1";

/// One normalized measurement.
#[derive(Debug, Clone, PartialEq)]
pub struct Measurement {
    /// Workload id from the definition.
    pub name: String,
    /// Engine variant measured.
    pub engine: String,
    /// Engine-config echo (threads/batch/tile/seed) from the definition.
    pub config: Value,
    /// Timed samples taken.
    pub iters: usize,
    /// Sample mean, nanoseconds per run.
    pub mean_ns: f64,
    /// Sample standard deviation, nanoseconds.
    pub stddev_ns: f64,
    /// Fastest sample, nanoseconds.
    pub min_ns: f64,
    /// Output checksum observed on the warmup run; empty = not recorded
    /// (a placeholder baseline), which the cmp reporter treats as
    /// "cannot drift".
    pub checksum: String,
    /// `git rev-parse --short HEAD` at measurement time ("unknown"
    /// outside a work tree).
    pub rev: String,
}

impl Measurement {
    /// The id records and reporters pair on.
    pub fn id(&self) -> String {
        format!("{}::{}", self.name, self.engine)
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("name", Value::str(&self.name)),
            ("engine", Value::str(&self.engine)),
            ("config", self.config.clone()),
            ("iters", Value::num(self.iters as f64)),
            ("mean_ns", Value::num(self.mean_ns)),
            ("stddev_ns", Value::num(self.stddev_ns)),
            ("min_ns", Value::num(self.min_ns)),
            ("checksum", Value::str(&self.checksum)),
            ("rev", Value::str(&self.rev)),
        ])
    }

    pub fn from_json(v: &Value) -> Result<Measurement> {
        Ok(Measurement {
            name: v.get("name")?.as_str()?.to_string(),
            engine: v.get("engine")?.as_str()?.to_string(),
            config: v.opt("config").cloned().unwrap_or(Value::Null),
            iters: v.get("iters")?.as_usize()?,
            mean_ns: v.get("mean_ns")?.as_f64()?,
            stddev_ns: v.get("stddev_ns")?.as_f64()?,
            min_ns: v.get("min_ns")?.as_f64()?,
            checksum: v.get("checksum")?.as_str()?.to_string(),
            rev: match v.opt("rev") {
                Some(Value::Str(s)) => s.clone(),
                _ => "unknown".to_string(),
            },
        })
    }
}

/// A set of measurements, as read from / written to a records file.
#[derive(Debug, Clone, Default)]
pub struct RecordSet {
    pub records: Vec<Measurement>,
}

impl RecordSet {
    pub fn parse(text: &str) -> Result<RecordSet> {
        let v = Value::parse(text)?;
        let format = v.get("format")?.as_str()?;
        if format != FORMAT {
            bail!("unsupported record format '{format}' (expected '{FORMAT}')");
        }
        let records = v
            .get("records")?
            .as_arr()?
            .iter()
            .map(Measurement::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(RecordSet { records })
    }

    pub fn load(path: impl AsRef<Path>) -> Result<RecordSet> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read records from {}", path.display()))?;
        RecordSet::parse(&text).with_context(|| format!("parse records in {}", path.display()))
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("format", Value::str(FORMAT)),
            ("records", Value::arr(self.records.iter().map(Measurement::to_json).collect())),
        ])
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let mut text = self.to_json().pretty();
        text.push('\n');
        std::fs::write(path, text)
            .with_context(|| format!("write records to {}", path.display()))
    }

    /// Look up a measurement by full id (`name::engine`).
    pub fn find(&self, id: &str) -> Option<&Measurement> {
        self.records.iter().find(|m| m.id() == id)
    }
}

/// Incremental record writer: collects measurements and, when given a
/// path, rewrites the whole output file after **every** push, so an
/// interrupted run keeps everything measured so far.
#[derive(Debug)]
pub struct RecordSink {
    path: Option<PathBuf>,
    set: RecordSet,
}

impl RecordSink {
    /// A sink that persists to `path` after each push; `None` collects
    /// in memory only.
    pub fn new(path: Option<PathBuf>) -> RecordSink {
        RecordSink { path, set: RecordSet::default() }
    }

    /// Append one measurement and flush the file (if any).
    pub fn push(&mut self, m: Measurement) -> Result<()> {
        self.set.records.push(m);
        if let Some(path) = &self.path {
            self.set.save(path)?;
        }
        Ok(())
    }

    pub fn records(&self) -> &[Measurement] {
        &self.set.records
    }

    pub fn into_set(self) -> RecordSet {
        self.set
    }
}

/// Incremental writer for ad-hoc [`Value`] record arrays — the legacy
/// `BENCH {json}` comparison records `benches/hotpaths.rs` collects.
/// Like [`RecordSink`], the output file is rewritten (as a JSON array)
/// after every push, so a panic or Ctrl-C mid-run keeps every record
/// collected so far instead of silently losing the lot.
#[derive(Debug)]
pub struct ValueSink {
    path: Option<PathBuf>,
    vals: Vec<Value>,
}

impl ValueSink {
    /// A sink that persists to `path` after each push; `None` collects
    /// in memory only.
    pub fn new(path: Option<PathBuf>) -> ValueSink {
        ValueSink { path, vals: Vec::new() }
    }

    /// Append one record and flush the file (if any).
    pub fn push(&mut self, v: Value) -> Result<()> {
        self.vals.push(v);
        if let Some(path) = &self.path {
            let mut text = Value::Arr(self.vals.clone()).pretty();
            text.push('\n');
            std::fs::write(path, text)
                .with_context(|| format!("write bench records to {}", path.display()))?;
        }
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.vals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.vals.is_empty()
    }
}

/// `git rev-parse --short HEAD`, or "unknown" when git or a work tree
/// is unavailable — records must never fail over provenance.
pub fn git_rev() -> String {
    Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(name: &str, engine: &str, mean: f64) -> Measurement {
        Measurement {
            name: name.to_string(),
            engine: engine.to_string(),
            config: Value::obj(vec![("threads", Value::num(1.0))]),
            iters: 10,
            mean_ns: mean,
            stddev_ns: mean * 0.01,
            min_ns: mean * 0.97,
            checksum: "00ff".to_string(),
            rev: "abc1234".to_string(),
        }
    }

    #[test]
    fn record_set_roundtrips_through_json() {
        let set = RecordSet { records: vec![m("spmm/x", "simd", 1000.0), m("spmm/x", "scalar", 4000.0)] };
        let text = set.to_json().pretty();
        let back = RecordSet::parse(&text).unwrap();
        assert_eq!(back.records, set.records);
        assert_eq!(back.find("spmm/x::scalar").unwrap().mean_ns, 4000.0);
        assert!(back.find("spmm/x::fused").is_none());
    }

    #[test]
    fn wrong_format_tag_is_rejected() {
        assert!(RecordSet::parse(r#"{"format": "v0", "records": []}"#).is_err());
        assert!(RecordSet::parse(r#"{"records": []}"#).is_err());
    }

    #[test]
    fn sink_flushes_after_every_push() {
        let path = std::env::temp_dir().join(format!(
            "prunemap_sink_{}_{:?}.json",
            std::process::id(),
            std::thread::current().id()
        ));
        let mut sink = RecordSink::new(Some(path.clone()));
        sink.push(m("a", "simd", 100.0)).unwrap();
        // the file is already valid and complete after the FIRST push —
        // this is the crash-durability property hotpaths was missing
        let after_one = RecordSet::load(&path).unwrap();
        assert_eq!(after_one.records.len(), 1);
        sink.push(m("b", "simd", 200.0)).unwrap();
        let after_two = RecordSet::load(&path).unwrap();
        assert_eq!(after_two.records.len(), 2);
        assert_eq!(sink.into_set().records.len(), 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn value_sink_is_valid_json_after_every_push() {
        let path = std::env::temp_dir().join(format!(
            "prunemap_vsink_{}_{:?}.json",
            std::process::id(),
            std::thread::current().id()
        ));
        let mut sink = ValueSink::new(Some(path.clone()));
        sink.push(Value::obj(vec![("bench", Value::str("a"))])).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let v = Value::parse(&text).expect("valid JSON after one push");
        assert_eq!(v.as_arr().unwrap().len(), 1);
        sink.push(Value::obj(vec![("bench", Value::str("b"))])).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(Value::parse(&text).unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(sink.len(), 2);
        assert!(!sink.is_empty());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn memory_only_sink_collects() {
        let mut sink = RecordSink::new(None);
        sink.push(m("a", "simd", 100.0)).unwrap();
        assert_eq!(sink.records().len(), 1);
    }

    #[test]
    fn git_rev_never_fails() {
        let rev = git_rev();
        assert!(!rev.is_empty());
    }
}
