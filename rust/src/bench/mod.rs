//! The benchmark barometer: definitions-as-data, a measurement harness,
//! and a regression-flagging comparison reporter (modeled on rebar's
//! methodology).
//!
//! The subsystem turns the repo's perf story from prose into reviewable
//! data, in four pieces:
//!
//! * [`defs`] — benchmark **definitions as data**: checked-in JSON files
//!   under `benches/defs/` name a workload (spmm / conv-im2col / whole-
//!   network infer / serve burst / routed front door) × engine variant ×
//!   batch × threads × tile, each with warmup/sample counts and an
//!   expected-output **checksum**, so every benchmark is also a
//!   correctness test.
//! * [`runner`] — runs one definition (workload construction, warmup,
//!   timed samples, checksum) and orchestrates a definition set, by
//!   default **one child process per measurement** so no benchmark warms
//!   caches or pools for the next.  `prunemap bench --check` verifies
//!   every definition's checksum without timing anything.
//! * [`records`] — the normalized measurement record set (`name`,
//!   `engine`, engine config, `iters`, `mean_ns`/`stddev_ns`/`min_ns`,
//!   `checksum`, git rev), written to stdout and `--json-out`, with an
//!   incremental [`records::RecordSink`] so an aborted run keeps every
//!   completed record.
//! * [`cmp`] — the reporter: `prunemap bench cmp A.json B.json` pairs two
//!   record sets by benchmark id, prints per-benchmark speedup ratios,
//!   and exits nonzero when any benchmark regresses beyond the noise
//!   threshold (or its output checksum drifted); `prunemap bench rank
//!   A.json` ranks engine variants of the same workload within one
//!   record set.
//!
//! The workflow across PRs: define → `prunemap bench --json-out` →
//! commit the records under `benches/records/` → the next PR's run is
//! `cmp`-ed against them, so one benchmark getting slower while another
//! speeds up is finally visible (see `benches/records/README.md`).

pub mod cmp;
pub mod defs;
pub mod records;
pub mod runner;

pub use cmp::{compare, rank, CmpReport, CmpRow, CmpStatus};
pub use defs::{load_defs, BenchDef, Workload};
pub use records::{Measurement, RecordSet, RecordSink};
pub use runner::{check_defs, measure, CheckOutcome, CheckReport};

/// Default noise threshold for [`cmp::compare`]: a benchmark counts as a
/// regression only when the contender's mean is more than this fraction
/// slower than the baseline's (10% — micro-benchmarks on shared CI
/// hardware jitter; see `benches/records/README.md` for the policy).
pub const NOISE_THRESHOLD: f64 = 0.10;

/// FNV-1a over the little-endian bit patterns of `xs` — the expected-
/// output checksum carried by definitions and measurement records.  The
/// engine is bit-identical across thread counts, batch coalescing, and
/// the fused/materialized im2col paths, so one checksum pins every
/// engine variant of a workload.
pub fn checksum_f32s(xs: &[f32]) -> String {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for x in xs {
        for b in x.to_bits().to_le_bytes() {
            h ^= u64::from(b);
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    }
    format!("{h:016x}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checksum_is_stable_and_bit_sensitive() {
        let a = checksum_f32s(&[1.0, 2.0, 3.0]);
        assert_eq!(a, checksum_f32s(&[1.0, 2.0, 3.0]), "deterministic");
        assert_eq!(a.len(), 16, "fixed-width hex");
        assert_ne!(a, checksum_f32s(&[1.0, 2.0, 3.0000002]), "bit-sensitive");
        // distinguishes payloads float equality cannot (0.0 vs -0.0)
        assert_ne!(checksum_f32s(&[0.0]), checksum_f32s(&[-0.0]));
        assert_ne!(checksum_f32s(&[]), checksum_f32s(&[0.0]));
    }
}
