//! The comparison reporter: diff two record sets, flag regressions.
//!
//! [`compare`] pairs baseline and contender measurements by full id
//! (`name::engine`) and classifies each pair against a noise threshold;
//! the CLI (`prunemap bench cmp A.json B.json`) renders the report and
//! exits nonzero when [`CmpReport::failed`] — any benchmark regressed
//! beyond the threshold or its output checksum drifted.  Benchmarks
//! present in only one record set are reported (so a silently-dropped
//! benchmark is visible) but are not failures.
//!
//! [`rank`] orders the engine variants of each workload within a single
//! record set — the "which engine wins this workload" view.

use std::collections::BTreeMap;

use super::records::{Measurement, RecordSet};

/// How one benchmark pair compares.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CmpStatus {
    /// Contender faster than baseline beyond the noise threshold.
    Improved,
    /// Within the noise threshold either way.
    Within,
    /// Contender slower beyond the noise threshold — a failure.
    Regressed,
    /// Output checksums differ — a correctness failure, timing moot.
    ChecksumDrift,
    /// Measured in the baseline set only.
    BaselineOnly,
    /// Measured in the contender set only.
    ContenderOnly,
}

impl CmpStatus {
    pub fn label(self) -> &'static str {
        match self {
            CmpStatus::Improved => "improved",
            CmpStatus::Within => "ok",
            CmpStatus::Regressed => "REGRESSED",
            CmpStatus::ChecksumDrift => "CHECKSUM DRIFT",
            CmpStatus::BaselineOnly => "baseline only",
            CmpStatus::ContenderOnly => "contender only",
        }
    }
}

/// One row of a comparison report.
#[derive(Debug, Clone)]
pub struct CmpRow {
    /// Full benchmark id (`name::engine`).
    pub id: String,
    /// Baseline mean, ns (absent for contender-only rows).
    pub base_mean_ns: Option<f64>,
    /// Contender mean, ns (absent for baseline-only rows).
    pub cont_mean_ns: Option<f64>,
    /// `baseline / contender` mean ratio (>1 = contender faster);
    /// `None` when either side is missing or degenerate (a zero/
    /// non-finite mean must not poison the report with inf/NaN).
    pub speedup: Option<f64>,
    pub status: CmpStatus,
}

/// The full comparison of two record sets.
#[derive(Debug, Clone)]
pub struct CmpReport {
    pub rows: Vec<CmpRow>,
    /// Fraction of slowdown tolerated as noise (e.g. 0.10).
    pub threshold: f64,
}

impl CmpReport {
    pub fn regressions(&self) -> usize {
        self.rows.iter().filter(|r| r.status == CmpStatus::Regressed).count()
    }

    pub fn drifted(&self) -> usize {
        self.rows.iter().filter(|r| r.status == CmpStatus::ChecksumDrift).count()
    }

    /// Whether the CLI should exit nonzero.
    pub fn failed(&self) -> bool {
        self.regressions() > 0 || self.drifted() > 0
    }

    /// Plain-text table, worst rows first.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let wid = self.rows.iter().map(|r| r.id.len()).max().unwrap_or(4).max(4);
        out.push_str(&format!(
            "{:<wid$}  {:>12}  {:>12}  {:>8}  status\n",
            "id", "base ns", "cont ns", "speedup"
        ));
        for row in &self.rows {
            out.push_str(&format!(
                "{:<wid$}  {:>12}  {:>12}  {:>8}  {}\n",
                row.id,
                fmt_ns(row.base_mean_ns),
                fmt_ns(row.cont_mean_ns),
                match row.speedup {
                    Some(s) => format!("{s:.2}x"),
                    None => "n/a".to_string(),
                },
                row.status.label(),
            ));
        }
        out.push_str(&format!(
            "{} compared, {} regressed, {} drifted (noise threshold {:.0}%)\n",
            self.rows.len(),
            self.regressions(),
            self.drifted(),
            self.threshold * 100.0
        ));
        out
    }
}

fn fmt_ns(x: Option<f64>) -> String {
    match x {
        Some(v) => format!("{v:.0}"),
        None => "-".to_string(),
    }
}

fn speedup_of(base: f64, cont: f64) -> Option<f64> {
    if !base.is_finite() || !cont.is_finite() || base <= 0.0 || cont <= 0.0 {
        return None;
    }
    Some(base / cont)
}

fn severity(s: CmpStatus) -> usize {
    match s {
        CmpStatus::ChecksumDrift => 0,
        CmpStatus::Regressed => 1,
        CmpStatus::BaselineOnly => 2,
        CmpStatus::ContenderOnly => 3,
        CmpStatus::Within => 4,
        CmpStatus::Improved => 5,
    }
}

/// Pair `baseline` and `contender` by benchmark id and classify each
/// pair against `threshold` (fraction of tolerated slowdown; see
/// [`super::NOISE_THRESHOLD`]).  Rows come back worst-first.
pub fn compare(baseline: &RecordSet, contender: &RecordSet, threshold: f64) -> CmpReport {
    let mut rows = Vec::new();
    for base in &baseline.records {
        let id = base.id();
        match contender.find(&id) {
            None => rows.push(CmpRow {
                id,
                base_mean_ns: Some(base.mean_ns),
                cont_mean_ns: None,
                speedup: None,
                status: CmpStatus::BaselineOnly,
            }),
            Some(cont) => {
                let speedup = speedup_of(base.mean_ns, cont.mean_ns);
                // an empty checksum means "not recorded" (e.g. a
                // placeholder baseline) — only two KNOWN checksums can
                // drift apart
                let drift = !base.checksum.is_empty()
                    && !cont.checksum.is_empty()
                    && base.checksum != cont.checksum;
                let status = if drift {
                    CmpStatus::ChecksumDrift
                } else {
                    match speedup {
                        Some(s) if s < 1.0 / (1.0 + threshold) => CmpStatus::Regressed,
                        Some(s) if s > 1.0 + threshold => CmpStatus::Improved,
                        _ => CmpStatus::Within,
                    }
                };
                rows.push(CmpRow {
                    id,
                    base_mean_ns: Some(base.mean_ns),
                    cont_mean_ns: Some(cont.mean_ns),
                    speedup,
                    status,
                });
            }
        }
    }
    for cont in &contender.records {
        if baseline.find(&cont.id()).is_none() {
            rows.push(CmpRow {
                id: cont.id(),
                base_mean_ns: None,
                cont_mean_ns: Some(cont.mean_ns),
                speedup: None,
                status: CmpStatus::ContenderOnly,
            });
        }
    }
    rows.sort_by(|a, b| severity(a.status).cmp(&severity(b.status)).then(a.id.cmp(&b.id)));
    CmpReport { rows, threshold }
}

/// Rank the engine variants of each workload within one record set,
/// fastest first, with the ratio vs the fastest variant.  Returns the
/// rendered table.
pub fn rank(set: &RecordSet) -> String {
    let mut groups: BTreeMap<&str, Vec<&Measurement>> = BTreeMap::new();
    for m in &set.records {
        groups.entry(&m.name).or_default().push(m);
    }
    let mut out = String::new();
    for (name, mut variants) in groups {
        variants.sort_by(|a, b| a.mean_ns.total_cmp(&b.mean_ns));
        let best = variants[0].mean_ns;
        out.push_str(&format!("{name}\n"));
        for m in variants {
            let ratio = match speedup_of(m.mean_ns, best) {
                Some(r) => format!("{r:.2}x"),
                None => "n/a".to_string(),
            };
            out.push_str(&format!(
                "  {:<14} {:>12.0} ns/run  {:>8}  ({} iters)\n",
                m.engine, m.mean_ns, ratio, m.iters
            ));
        }
    }
    if out.is_empty() {
        out.push_str("no records\n");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Value;

    fn m(name: &str, engine: &str, mean: f64, checksum: &str) -> Measurement {
        Measurement {
            name: name.to_string(),
            engine: engine.to_string(),
            config: Value::Null,
            iters: 10,
            mean_ns: mean,
            stddev_ns: 1.0,
            min_ns: mean,
            checksum: checksum.to_string(),
            rev: "test".to_string(),
        }
    }

    fn set(records: Vec<Measurement>) -> RecordSet {
        RecordSet { records }
    }

    #[test]
    fn classifies_win_regression_and_noise() {
        let base = set(vec![
            m("a", "simd", 1000.0, "c1"),
            m("b", "simd", 1000.0, "c2"),
            m("c", "simd", 1000.0, "c3"),
        ]);
        let cont = set(vec![
            m("a", "simd", 500.0, "c1"),  // 2x win
            m("b", "simd", 1200.0, "c2"), // 20% slower: regression at 10%
            m("c", "simd", 1050.0, "c3"), // 5% slower: within noise
        ]);
        let report = compare(&base, &cont, 0.10);
        let by_id = |id: &str| report.rows.iter().find(|r| r.id == format!("{id}::simd")).unwrap();
        assert_eq!(by_id("a").status, CmpStatus::Improved);
        assert_eq!(by_id("a").speedup, Some(2.0));
        assert_eq!(by_id("b").status, CmpStatus::Regressed);
        assert_eq!(by_id("c").status, CmpStatus::Within);
        assert_eq!(report.regressions(), 1);
        assert!(report.failed());
        // worst first: the regression leads the rendered table
        assert_eq!(report.rows[0].id, "b::simd");
    }

    #[test]
    fn checksum_drift_fails_even_when_faster() {
        let base = set(vec![m("a", "simd", 1000.0, "good")]);
        let cont = set(vec![m("a", "simd", 100.0, "evil")]);
        let report = compare(&base, &cont, 0.10);
        assert_eq!(report.rows[0].status, CmpStatus::ChecksumDrift);
        assert!(report.failed(), "a wrong answer is never a speedup");
    }

    #[test]
    fn unknown_checksums_do_not_count_as_drift() {
        // a placeholder baseline (checksum not recorded) must not flag
        // drift against a real run
        let base = set(vec![m("a", "simd", 1000.0, "")]);
        let cont = set(vec![m("a", "simd", 1000.0, "9c0f")]);
        let report = compare(&base, &cont, 0.10);
        assert_eq!(report.rows[0].status, CmpStatus::Within);
        assert!(!report.failed());
    }

    #[test]
    fn one_sided_benchmarks_are_visible_but_not_failures() {
        let base = set(vec![m("old", "simd", 1000.0, "c")]);
        let cont = set(vec![m("new", "simd", 1000.0, "c")]);
        let report = compare(&base, &cont, 0.10);
        assert_eq!(report.rows.len(), 2);
        assert!(report
            .rows
            .iter()
            .any(|r| r.id == "old::simd" && r.status == CmpStatus::BaselineOnly));
        assert!(report
            .rows
            .iter()
            .any(|r| r.id == "new::simd" && r.status == CmpStatus::ContenderOnly));
        assert!(!report.failed());
    }

    #[test]
    fn degenerate_means_yield_no_speedup_not_inf() {
        let base = set(vec![m("a", "simd", 0.0, "c")]);
        let cont = set(vec![m("a", "simd", 1000.0, "c")]);
        let report = compare(&base, &cont, 0.10);
        assert_eq!(report.rows[0].speedup, None);
        assert_eq!(report.rows[0].status, CmpStatus::Within, "no ratio -> no flag");
        let rendered = report.render();
        assert!(rendered.contains("n/a"), "degenerate ratio renders as n/a: {rendered}");
    }

    #[test]
    fn rank_orders_variants_fastest_first() {
        let s = set(vec![
            m("spmm/x", "scalar", 4000.0, "c"),
            m("spmm/x", "simd", 1000.0, "c"),
            m("conv/y", "fused", 500.0, "d"),
        ]);
        let out = rank(&s);
        let simd = out.find("simd").unwrap();
        let scalar = out.find("scalar").unwrap();
        assert!(simd < scalar, "fastest variant listed first:\n{out}");
        assert!(out.contains("4.00x"), "scalar is 4x the fastest:\n{out}");
        assert!(out.contains("conv/y"));
    }
}
